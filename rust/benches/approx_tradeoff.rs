//! E10 — the approximate-recovery tradeoff: sweep the quorum fraction
//! and report, per point, the predicted and measured iteration time, the
//! predicted and measured decoding residual, and the AUC-vs-time effect
//! (time to reach a common AUC target, as in Fig. 4).
//!
//! The exact regime (`quorum = 1.0`) is the rightmost point of the
//! curve: zero residual, longest wait. Shrinking the quorum walks left:
//! the master stops sitting on the straggler tail (iteration time drops
//! toward the fast-arrival order statistics) while the least-squares
//! decoder's residual grows once responder sets stop covering every
//! subset. Training is real (coded gradients, NAG); the clock is the
//! fitted §VI delay model.
//!
//!     cargo bench --bench approx_tradeoff [-- --iters 150]

use gradcode::bench::{json_array, JsonObject, Table};
use gradcode::cli::Command;
use gradcode::coding::{quorum_count, ApproxCode};
use gradcode::coordinator::{train, ExecutionMode, OptChoice, SchemeSpec, TrainConfig};
use gradcode::data::{train_test_split, CategoricalConfig, SyntheticCategorical};
use gradcode::metrics::RunLog;
use gradcode::simulator::approx::{expected_coeff_residual, expected_runtime_at_quorum};
use gradcode::simulator::DelayParams;

/// First simulated time at which the run's AUC reaches `target`.
fn time_to_auc(log: &RunLog, target: f64) -> Option<f64> {
    log.auc_curve().iter().find(|(_, a)| *a >= target).map(|(t, _)| *t)
}

fn main() -> anyhow::Result<()> {
    let args = Command::new("approx_tradeoff", "quorum fraction vs time/error (partial recovery)")
        .flag("n", "10", "workers")
        .flag("d", "3", "replication (subsets per worker)")
        .flag("iters", "150", "training iterations per quorum point")
        .flag("rows", "3000", "dataset rows")
        .flag("quorums", "0.4,0.5,0.6,0.7,0.8,0.9,1.0", "quorum fractions to sweep")
        .flag("samples", "2000", "Monte-Carlo samples for the predicted residual")
        .flag("seed", "6", "seed")
        .flag("json", "BENCH_approx.json", "machine-readable output path (empty to skip)")
        .parse_env();
    let n = args.get_usize("n");
    let d = args.get_usize("d");
    let iters = args.get_usize("iters");
    let seed = args.get_u64("seed");
    let samples = args.get_usize("samples");
    let p = DelayParams::ec2_fit();

    let gen = SyntheticCategorical::new(
        CategoricalConfig {
            columns: 9,
            cardinality: (8, 40),
            label_noise: 0.1,
            ..Default::default()
        },
        seed,
    );
    let raw = gen.generate(args.get_usize("rows"), seed + 1);
    let (train_ds, test_ds) = train_test_split(&raw, 0.25, seed + 2);
    let lr = 1.2 / train_ds.rows as f32;

    let mut runs: Vec<(f64, usize, RunLog)> = Vec::new();
    for q in args.get_f64_list("quorums") {
        let cfg = TrainConfig {
            n,
            scheme: SchemeSpec::Approx { d, quorum: q },
            iters,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: (iters / 60).max(1),
            delays: Some(p),
            mode: ExecutionMode::Virtual,
            seed,
            minibatch: None,
            quorum: None,
            fleet: None,
            chaos: None,
        };
        let (log, _) = train(cfg, &train_ds, Some(&test_ds))?;
        runs.push((q, quorum_count(n, q), log));
    }

    // Common AUC target: 90% of the lowest peak across the sweep, so
    // every run can in principle reach it.
    let peaks: Vec<f64> = runs
        .iter()
        .map(|(_, _, l)| l.auc_curve().iter().map(|(_, a)| *a).fold(0.5, f64::max))
        .collect();
    let floor = peaks.iter().fold(1.0f64, |a, &b| a.min(b));
    let target = 0.5 + (floor - 0.5) * 0.90;

    let time_col = format!("time to AUC {target:.3} (s)");
    let header: Vec<&str> = vec![
        "quorum",
        "wait r",
        "E[T] model (s)",
        "mean iter meas (s)",
        "E[residual] model",
        "residual meas",
        "final AUC",
        time_col.as_str(),
    ];
    let mut table = Table::new(
        &format!("quorum fraction vs time/error, n = {n}, d = {d} (ec2-fit delays)"),
        &header,
    );
    let mut json_rows: Vec<String> = Vec::new();
    for (q, r, log) in &runs {
        let code = ApproxCode::new(n, d, *r)?;
        let predicted_t = expected_runtime_at_quorum(&p, n, d, *r);
        let predicted_res = expected_coeff_residual(&code, *r, samples, seed ^ *r as u64);
        table.row(&[
            format!("{q:.2}"),
            r.to_string(),
            format!("{predicted_t:.3}"),
            format!("{:.3}", log.mean_iteration_sim_time()),
            format!("{predicted_res:.4}"),
            format!("{:.4}", log.mean_decode_residual().unwrap_or(0.0)),
            format!("{:.4}", log.final_auc().unwrap_or(f64::NAN)),
            time_to_auc(log, target).map_or("—".into(), |t| format!("{t:.0}")),
        ]);
        json_rows.push(
            JsonObject::new()
                .field_num("quorum_fraction", *q)
                .field_int("quorum", *r as i64)
                .field_num("predicted_time", predicted_t)
                .field_num("measured_mean_iter", log.mean_iteration_sim_time())
                .field_num("predicted_residual", predicted_res)
                .field_num("measured_residual", log.mean_decode_residual().unwrap_or(0.0))
                .field_num("final_auc", log.final_auc().unwrap_or(f64::NAN))
                .field_num(
                    "time_to_target_auc",
                    time_to_auc(log, target).unwrap_or(f64::NAN),
                )
                .build(),
        );
    }
    table.print();

    let json_path = args.get_str("json");
    if !json_path.is_empty() {
        let root = JsonObject::new()
            .field_str("bench", "approx_tradeoff")
            .field_int("n", n as i64)
            .field_int("d", d as i64)
            .field_int("iters", iters as i64)
            .field_num("target_auc", target)
            .field_raw("points", &json_array(json_rows));
        std::fs::write(json_path, root.build() + "\n")?;
        println!("wrote {json_path}");
    }

    for (q, _, log) in &runs {
        let pts: Vec<String> = log
            .auc_curve()
            .iter()
            .step_by(4)
            .map(|(t, a)| format!("({t:.0},{a:.3})"))
            .collect();
        println!("  curve q={q:.2} {}", pts.join(" "));
    }
    println!(
        "\nexpected shape: iteration time falls as the quorum shrinks; the residual stays ~0 \
         while responder sets still cover every subset (r > n - d with high probability) and \
         grows below that, eventually costing final AUC. The sweet spot is the smallest quorum \
         whose residual is still ~0."
    );
    Ok(())
}
