//! E2 — regenerates Fig. 3: average time per iteration for
//! n ∈ {10, 15, 20}, comparing the naive scheme, the best m = 1 scheme
//! ([11]–[13]) and the two best (m, s) choices of this paper.
//!
//! The cluster clock is the §VI delay model fitted to the paper's EC2
//! regime (`DelayParams::ec2_fit`); the coding path (gradient compute,
//! encode, straggler cutoff, decode) runs for real through the trainer.
//! For each scheme we report both the model-predicted E[T_tot] and the
//! measured mean over simulated training iterations.
//!
//!     cargo bench --bench fig3_time_per_iter [-- --iters 150]

use gradcode::bench::Table;
use gradcode::cli::Command;
use gradcode::coordinator::{
    train, ExecutionMode, OptChoice, SchemeSpec, TrainConfig,
};
use gradcode::data::{CategoricalConfig, SyntheticCategorical};
use gradcode::simulator::optimize::{naive_choice, optimal_triple_m1, TripleChoice};
use gradcode::simulator::order_stats::expected_total_runtime;
use gradcode::simulator::DelayParams;

/// Two best (m, s) pairs with m > 1 under the model (the paper plots two
/// "ours" bars per n).
fn best_two_ours(p: &DelayParams, n: usize) -> Vec<TripleChoice> {
    let mut all = Vec::new();
    for d in 1..=n {
        for m in 2..=d {
            let s = d - m;
            all.push(TripleChoice {
                d,
                s,
                m,
                expected_runtime: expected_total_runtime(p, n, d, s, m),
            });
        }
    }
    all.sort_by(|a, b| a.expected_runtime.partial_cmp(&b.expected_runtime).unwrap());
    all.truncate(2);
    all
}

fn main() -> anyhow::Result<()> {
    let args = Command::new("fig3", "avg time per iteration (paper Fig. 3)")
        .flag("iters", "150", "simulated iterations per scheme")
        .flag("workers", "10,15,20", "worker counts")
        .flag("seed", "3", "seed")
        .parse_env();
    let iters = args.get_usize("iters");
    let p = DelayParams::ec2_fit();
    println!("delay regime (fit to the paper's EC2 numbers): {p:?}\n");

    for n in args.get_usize_list("workers") {
        let naive = naive_choice(&p, n);
        let m1 = optimal_triple_m1(&p, n);
        let ours = best_two_ours(&p, n);
        let mut schemes: Vec<(String, SchemeSpec, TripleChoice)> = vec![
            ("naive".into(), SchemeSpec::Uncoded, naive),
            (
                format!("m=1, s*={} [11]-[13]", m1.s),
                SchemeSpec::Poly { s: m1.s, m: 1 },
                m1,
            ),
        ];
        for t in &ours {
            schemes.push((
                format!("ours m={}, s*={}", t.m, t.s),
                SchemeSpec::Poly { s: t.s, m: t.m },
                *t,
            ));
        }

        // Dataset sized to n subsets of 24 rows (compute is real but the
        // figure's clock is the delay model, as in the paper's §VI fit).
        let gen = SyntheticCategorical::new(
            CategoricalConfig { columns: 8, ..Default::default() },
            77,
        );
        let ds = gen.generate(n * 24, 78);
        let lr = 4.0 / ds.rows as f32;

        let mut table = Table::new(
            &format!("Fig. 3 — avg time per iteration, n = {n}"),
            &["scheme", "(d,s,m)", "model E[T] (s)", "measured mean (s)", "vs naive"],
        );
        let mut measured = Vec::new();
        for (label, spec, choice) in &schemes {
            let cfg = TrainConfig {
                n,
                scheme: spec.clone(),
                iters,
                opt: OptChoice::Nag { lr, momentum: 0.9 },
                eval_every: iters, // metrics off the hot path
                delays: Some(p),
                mode: ExecutionMode::Virtual,
                seed: args.get_u64("seed"),
                minibatch: None,
                quorum: None,
                fleet: None,
                chaos: None,
            };
            let (log, _) = train(cfg, &ds, None)?;
            measured.push((label.clone(), choice, log.mean_iteration_sim_time()));
        }
        let naive_mean = measured[0].2;
        for (label, choice, mean) in &measured {
            table.row(&[
                label.clone(),
                format!("({},{},{})", choice.d, choice.s, choice.m),
                format!("{:.4}", choice.expected_runtime),
                format!("{:.4}", mean),
                format!("-{:.0}%", 100.0 * (1.0 - mean / naive_mean)),
            ]);
        }
        table.print();
        let best_ours = measured[2..]
            .iter()
            .map(|(_, _, m)| *m)
            .fold(f64::INFINITY, f64::min);
        println!(
            "  headline: ours vs naive -{:.0}%, ours vs best m=1 -{:.0}%  \
             (paper: ≥32% and ≥23%)\n",
            100.0 * (1.0 - best_ours / naive_mean),
            100.0 * (1.0 - best_ours / measured[1].2),
        );
    }
    Ok(())
}
