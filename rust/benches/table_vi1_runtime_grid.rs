//! E4 — regenerates the §VI-A expected-runtime grid:
//! n = k = 8, λ₁ = 0.8, λ₂ = 0.1, t₁ = 1.6, t₂ = 6, s = d - m;
//! E[T_tot] for every (d, m), d = column, m = row — the exact numbers the
//! paper prints (36.1138 uncoded, 21.3697 optimum at d=4, m=3, ...).
//!
//! Also cross-checks each cell against Monte-Carlo simulation.
//!
//!     cargo bench --bench table_vi1_runtime_grid

use gradcode::bench::Table;
use gradcode::cli::Command;
use gradcode::simulator::order_stats::expected_total_runtime;
use gradcode::simulator::{DelayParams, VirtualCluster};

fn main() {
    let args = Command::new("table_vi1", "§VI-A E[T_tot] grid (n=8)")
        .flag("n", "8", "workers")
        .flag("mc-iters", "20000", "Monte-Carlo iterations for the check")
        .parse_env();
    let n = args.get_usize("n");
    let p = DelayParams::table_vi1();
    println!("params: {p:?}, s = d - m\n");

    let header: Vec<String> = std::iter::once("m \\ d".to_string())
        .chain((1..=n).map(|d| d.to_string()))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("§VI-A table 1 — E[T_tot] for all (d, m)", &header_refs);
    let mut best = (f64::INFINITY, 0usize, 0usize);
    for m in 1..=n {
        let mut row = vec![m.to_string()];
        for d in 1..=n {
            if m > d {
                row.push(String::new());
                continue;
            }
            let v = expected_total_runtime(&p, n, d, d - m, m);
            if v < best.0 {
                best = (v, d, m);
            }
            row.push(format!("{v:.4}"));
        }
        table.row(&row);
    }
    table.print();
    println!(
        "optimum: d={}, m={} -> {:.4}  (paper: d=4, m=3 -> 21.3697)",
        best.1, best.2, best.0
    );
    let uncoded = expected_total_runtime(&p, n, 1, 0, 1);
    let m1_best = (1..=n)
        .map(|d| expected_total_runtime(&p, n, d, d - 1, 1))
        .fold(f64::INFINITY, f64::min);
    println!("uncoded (1,0,1): {uncoded:.4}  (paper: 36.1138)");
    println!("best m=1:        {m1_best:.4}  (paper: 24.1063, at d=8)");
    println!(
        "improvements: {:.0}% vs uncoded (paper 41%), {:.0}% vs m=1 (paper 11%)\n",
        100.0 * (1.0 - best.0 / uncoded),
        100.0 * (1.0 - best.0 / m1_best)
    );

    // Monte-Carlo cross-check on the three headline cells.
    let iters = args.get_usize("mc-iters");
    let mut check = Table::new(
        "Monte-Carlo cross-check",
        &["(d,s,m)", "quadrature", "simulated", "rel diff"],
    );
    for (d, s, m) in [(1, 0, 1), (best.1, best.1 - best.2, best.2), (8, 7, 1)] {
        let exact = expected_total_runtime(&p, n, d, s, m);
        let mut vc = VirtualCluster::new(&p, n, d, s, m, 99);
        let mc = vc.mean_iteration_time(iters);
        check.row(&[
            format!("({d},{s},{m})"),
            format!("{exact:.4}"),
            format!("{mc:.4}"),
            format!("{:+.2}%", 100.0 * (mc / exact - 1.0)),
        ]);
    }
    check.print();
}
