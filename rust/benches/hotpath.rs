//! Hot-path benchmarks: per-operation microbenches plus the thread-pool
//! scaling sweep the CI gate tracks.
//!
//! Microbenches (the per-iteration compute the paper assumes is
//! negligible next to Θ(N·l) gradient work):
//!
//! - worker encode (f_w = Z·c): streams d gradients of length l once;
//! - master decode (g = Σ W f): streams n-s vectors of length l/m once;
//! - rust-backend partial gradient (GEMV-bound);
//! - PJRT worker_step artifact (when artifacts exist);
//! - decode-weight construction (Vandermonde solve; cached in practice).
//!
//! Scaling sweep: the same full virtual-cluster training run at 1, 2, …,
//! `--threads` pool threads (via [`gradcode::pool::set_global_threads`]),
//! reporting wall seconds per point. The headline `train_speedup`
//! (1-thread wall time over max-thread wall time) lands in
//! `BENCH_hotpath.json` and is gated by `gradcode ci-gate`. The sweep
//! also asserts the determinism contract: the final loss must be
//! bitwise identical at every thread count.
//!
//!     cargo bench --bench hotpath [-- --smoke --json target/bench/BENCH_hotpath.json]

use std::time::Instant;

use gradcode::bench::{black_box, json_array, Bencher, JsonObject, Stats, Table};
use gradcode::cli::Command;
use gradcode::coding::{Decoder, Encoder, PolynomialCode, SchemeConfig};
use gradcode::coordinator::{
    ComputeBackend, OptChoice, RustBackend, SchemeSpec, TrainConfig, Trainer,
};
use gradcode::data::{CategoricalConfig, SyntheticCategorical};
use gradcode::model::LogisticModel;
use gradcode::rngs::{Pcg64, Rng};

fn main() -> anyhow::Result<()> {
    let args = Command::new("hotpath", "encode/decode/gradient microbenches + thread scaling")
        .flag("l", "262144", "gradient dimension (paper: 343474)")
        .flag("n", "10", "workers")
        .flag("s", "1", "stragglers")
        .flag("m", "2", "communication reduction")
        .flag("iters", "30", "timing iterations per microbench")
        .flag("train-iters", "40", "training iterations per scaling-sweep point")
        .flag("rows", "3200", "training rows for the scaling sweep")
        .flag("reps", "2", "sweep repetitions per thread count (minimum wall time wins)")
        .flag("threads", "4", "max pool threads for the scaling sweep")
        .flag("json", "BENCH_hotpath.json", "machine-readable output path (empty to skip)")
        .switch("smoke", "smaller configuration for the CI gate")
        .parse_env();
    let smoke = args.get_bool("smoke");
    if smoke {
        println!(
            "--smoke: overriding --l/--iters/--train-iters/--rows with the fixed CI \
             configuration (l=131072, iters=10, train-iters=30, rows=2400)"
        );
    }
    let l: usize = if smoke { 131072 } else { args.get_usize("l") };
    let (n, s, m) = (args.get_usize("n"), args.get_usize("s"), args.get_usize("m"));
    let iters = if smoke { 10 } else { args.get_usize("iters") };
    let train_iters = if smoke { 30 } else { args.get_usize("train-iters") };
    let rows = if smoke { 2400 } else { args.get_usize("rows") };
    let reps = args.get_usize("reps").max(1);
    let max_threads = args.get_usize("threads").max(1);
    let cfg = SchemeConfig::tight(n, s, m)?;
    let code = PolynomialCode::new(cfg)?;

    // --- thread-scaling sweep: full virtual-cluster training ---------
    // Powers of two up to the max, then the max itself.
    let mut sweep_threads: Vec<usize> = Vec::new();
    let mut t = 1;
    while t < max_threads {
        sweep_threads.push(t);
        t *= 2;
    }
    sweep_threads.push(max_threads);

    let gen = SyntheticCategorical::new(
        CategoricalConfig { columns: 10, cardinality: (16, 48), ..Default::default() },
        5,
    );
    let train_ds = gen.generate(rows, 6);
    let train_cfg = {
        let mut c = TrainConfig::quick(n, SchemeSpec::Poly { s, m }, train_iters);
        c.opt = OptChoice::Nag { lr: 1.2 / rows as f32, momentum: 0.9 };
        c.eval_every = train_iters; // metrics off the hot path
        c
    };

    let mut sweep: Vec<(usize, f64)> = Vec::new();
    let mut loss_bits: Option<u64> = None;
    for &threads in &sweep_threads {
        gradcode::pool::set_global_threads(threads);
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let mut tr = Trainer::new(train_cfg.clone(), &train_ds, None)?;
            let t0 = Instant::now();
            let log = tr.run()?;
            best = best.min(t0.elapsed().as_secs_f64());
            // Determinism contract: identical bits at every thread count.
            let bits = log.final_loss().unwrap_or(f64::NAN).to_bits();
            match loss_bits {
                None => loss_bits = Some(bits),
                Some(expect) => assert_eq!(
                    bits, expect,
                    "final loss changed with the thread count — determinism broken"
                ),
            }
        }
        println!("threads {threads}: train {best:.3}s");
        sweep.push((threads, best));
    }
    let train_speedup = sweep[0].1 / sweep[sweep.len() - 1].1;
    println!(
        "train_speedup: {train_speedup:.2}x at {max_threads} threads \
         (final loss bitwise identical across the sweep)"
    );

    // Microbenches run on the widest pool (the chunked paths engage
    // above their cutovers at this l).
    gradcode::pool::set_global_threads(max_threads);
    let b = Bencher::new(3, iters);
    let mut rng = Pcg64::seed_from_u64(1);

    let mut table = Table::new(
        &format!(
            "hot path @ l={l}, n={n}, d={}, s={s}, m={m}, {max_threads} threads",
            cfg.d
        ),
        &["operation", "mean", "p99", "GB/s streamed"],
    );

    // --- encode ---
    let grads: Vec<Vec<f32>> = (0..cfg.d)
        .map(|_| (0..l).map(|_| rng.next_f64() as f32 - 0.5).collect())
        .collect();
    let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let enc = Encoder::new(&code, 0)?;
    let mut out = Vec::new();
    let st_encode = b.run(|| {
        enc.encode_into(black_box(&views), &mut out).unwrap();
    });
    let bytes = (cfg.d * l + l / m) * 4;
    table.row(&[
        "worker encode".into(),
        Stats::human(st_encode.mean_ns),
        Stats::human(st_encode.p99_ns),
        format!("{:.2}", bytes as f64 / st_encode.mean_ns),
    ]);

    // --- decode ---
    let lv = l / m;
    let fs_store: Vec<Vec<f32>> = (0..n - s)
        .map(|_| (0..lv).map(|_| rng.next_f64() as f32 - 0.5).collect())
        .collect();
    let fs: Vec<&[f32]> = fs_store.iter().map(|f| f.as_slice()).collect();
    let avail: Vec<usize> = (0..n - s).collect();
    let dec = Decoder::new(&code, &avail)?;
    let mut decoded = Vec::new();
    let st_decode = b.run(|| {
        dec.decode_into(black_box(&fs), &mut decoded).unwrap();
    });
    let bytes = ((n - s) * lv + l) * 4;
    table.row(&[
        "master decode".into(),
        Stats::human(st_decode.mean_ns),
        Stats::human(st_decode.p99_ns),
        format!("{:.2}", bytes as f64 / st_decode.mean_ns),
    ]);

    // --- decode-weight construction (uncached cold path) ---
    let st = b.run(|| black_box(Decoder::new(&code, &avail).unwrap()));
    table.row(&[
        "decode weights (cold)".into(),
        Stats::human(st.mean_ns),
        Stats::human(st.p99_ns),
        "—".into(),
    ]);

    // --- rust-backend partial gradient (smaller, realistic shard) ---
    let shard = gen.generate(256, 6).pad_cols(512);
    let beta = vec![0.01f32; shard.cols];
    let mut g = Vec::new();
    let st_grad = b.run(|| {
        LogisticModel::gradient_into(black_box(&shard), black_box(&beta), &mut g);
    });
    let bytes = shard.rows * shard.cols * 4 * 2;
    table.row(&[
        format!("logistic grad ({}x{})", shard.rows, shard.cols),
        Stats::human(st_grad.mean_ns),
        Stats::human(st_grad.p99_ns),
        format!("{:.2}", bytes as f64 / st_grad.mean_ns),
    ]);

    // --- full worker step via rust backend (n=10 artifact shapes) ---
    let code10 = PolynomialCode::new(SchemeConfig::tight(10, 1, 2)?)?;
    let train = gen.generate(640, 7).pad_cols(512);
    let rust_backend = RustBackend::new(&code10, &train)?;
    let beta512 = vec![0.01f32; 512];
    let mut f = Vec::new();
    let st_step = b.run(|| {
        rust_backend.encoded_gradient(0, 0, black_box(&beta512), &mut f).unwrap();
    });
    table.row(&[
        "worker step (rust backend)".into(),
        Stats::human(st_step.mean_ns),
        Stats::human(st_step.p99_ns),
        "—".into(),
    ]);

    // --- full worker step via PJRT artifact (pjrt feature only) ---
    #[cfg(feature = "pjrt")]
    {
        use gradcode::runtime::{Manifest, PjrtBackend};
        let dir = Manifest::default_dir();
        if Manifest::load(&dir).map(|mf| !mf.is_empty()).unwrap_or(false) {
            let pjrt = PjrtBackend::new(&dir, &code10, &train)?;
            let st = b.run(|| {
                pjrt.encoded_gradient(0, 0, black_box(&beta512), &mut f).unwrap();
            });
            table.row(&[
                "worker step (PJRT artifact)".into(),
                Stats::human(st.mean_ns),
                Stats::human(st.p99_ns),
                "—".into(),
            ]);
        } else {
            println!("(skipping PJRT bench: run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(skipping PJRT bench: build with --features pjrt)");

    table.print();
    println!(
        "paper footnote 8: master reconstruction is O(n·l) vs worker computation Θ(N·l);\n\
         decode must stay ≪ gradient time — compare rows 2 and 4."
    );

    let json_path = args.get_str("json");
    if !json_path.is_empty() {
        let sweep_objs = sweep.iter().map(|&(threads, secs)| {
            JsonObject::new()
                .field_int("threads", threads as i64)
                .field_num("train_secs", secs)
                .build()
        });
        let root = JsonObject::new()
            .field_str("bench", "hotpath")
            .field_int("l", l as i64)
            .field_int("n", n as i64)
            .field_int("s", s as i64)
            .field_int("m", m as i64)
            .field_int("train_iters", train_iters as i64)
            .field_int("rows", rows as i64)
            .field_int("max_threads", max_threads as i64)
            .field_int("smoke", i64::from(smoke))
            .field_int("deterministic", 1)
            .field_num("train_speedup", train_speedup)
            .field_raw("sweep", &json_array(sweep_objs))
            .field_num("encode_mean_ns", st_encode.mean_ns)
            .field_num("decode_mean_ns", st_decode.mean_ns)
            .field_num("grad_mean_ns", st_grad.mean_ns)
            .field_num("worker_step_mean_ns", st_step.mean_ns);
        std::fs::write(json_path, root.build() + "\n")?;
        println!("wrote {json_path}");
    }
    Ok(())
}
