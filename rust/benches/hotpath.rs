//! Hot-path microbenchmarks: the per-iteration compute the paper assumes
//! is negligible next to Θ(N·l) gradient work — verified here.
//!
//! - worker encode (f_w = Z·c): streams d gradients of length l once;
//! - master decode (g = Σ W f): streams n-s vectors of length l/m once;
//! - rust-backend partial gradient (GEMV-bound);
//! - PJRT worker_step artifact (when artifacts exist);
//! - decode-weight construction (Vandermonde solve; cached in practice).
//!
//!     cargo bench --bench hotpath

use gradcode::bench::{black_box, Bencher, Stats, Table};
use gradcode::cli::Command;
use gradcode::coding::{Decoder, Encoder, PolynomialCode, SchemeConfig};
use gradcode::coordinator::{ComputeBackend, RustBackend};
use gradcode::data::{CategoricalConfig, SyntheticCategorical};
use gradcode::model::LogisticModel;
use gradcode::rngs::{Pcg64, Rng};

fn main() -> anyhow::Result<()> {
    let args = Command::new("hotpath", "encode/decode/gradient microbenches")
        .flag("l", "262144", "gradient dimension (paper: 343474)")
        .flag("n", "10", "workers")
        .flag("s", "1", "stragglers")
        .flag("m", "2", "communication reduction")
        .flag("iters", "30", "timing iterations")
        .parse_env();
    let l: usize = args.get_usize("l");
    let (n, s, m) = (args.get_usize("n"), args.get_usize("s"), args.get_usize("m"));
    let cfg = SchemeConfig::tight(n, s, m)?;
    let code = PolynomialCode::new(cfg)?;
    let b = Bencher::new(3, args.get_usize("iters"));
    let mut rng = Pcg64::seed_from_u64(1);

    let mut table = Table::new(
        &format!("hot path @ l={l}, n={n}, d={}, s={s}, m={m}", cfg.d),
        &["operation", "mean", "p99", "GB/s streamed"],
    );

    // --- encode ---
    let grads: Vec<Vec<f32>> = (0..cfg.d)
        .map(|_| (0..l).map(|_| rng.next_f64() as f32 - 0.5).collect())
        .collect();
    let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let enc = Encoder::new(&code, 0)?;
    let mut out = Vec::new();
    let st = b.run(|| {
        enc.encode_into(black_box(&views), &mut out).unwrap();
    });
    let bytes = (cfg.d * l + l / m) * 4;
    table.row(&[
        "worker encode".into(),
        Stats::human(st.mean_ns),
        Stats::human(st.p99_ns),
        format!("{:.2}", bytes as f64 / st.mean_ns),
    ]);

    // --- decode ---
    let lv = l / m;
    let fs_store: Vec<Vec<f32>> = (0..n - s)
        .map(|_| (0..lv).map(|_| rng.next_f64() as f32 - 0.5).collect())
        .collect();
    let fs: Vec<&[f32]> = fs_store.iter().map(|f| f.as_slice()).collect();
    let avail: Vec<usize> = (0..n - s).collect();
    let dec = Decoder::new(&code, &avail)?;
    let mut decoded = Vec::new();
    let st = b.run(|| {
        dec.decode_into(black_box(&fs), &mut decoded).unwrap();
    });
    let bytes = ((n - s) * lv + l) * 4;
    table.row(&[
        "master decode".into(),
        Stats::human(st.mean_ns),
        Stats::human(st.p99_ns),
        format!("{:.2}", bytes as f64 / st.mean_ns),
    ]);

    // --- decode-weight construction (uncached cold path) ---
    let st = b.run(|| black_box(Decoder::new(&code, &avail).unwrap()));
    table.row(&[
        "decode weights (cold)".into(),
        Stats::human(st.mean_ns),
        Stats::human(st.p99_ns),
        "—".into(),
    ]);

    // --- rust-backend partial gradient (smaller, realistic shard) ---
    let gen = SyntheticCategorical::new(
        CategoricalConfig { columns: 10, cardinality: (16, 48), ..Default::default() },
        5,
    );
    let shard = gen.generate(256, 6).pad_cols(512);
    let beta = vec![0.01f32; shard.cols];
    let mut g = Vec::new();
    let st = b.run(|| {
        LogisticModel::gradient_into(black_box(&shard), black_box(&beta), &mut g);
    });
    let bytes = shard.rows * shard.cols * 4 * 2;
    table.row(&[
        format!("logistic grad ({}x{})", shard.rows, shard.cols),
        Stats::human(st.mean_ns),
        Stats::human(st.p99_ns),
        format!("{:.2}", bytes as f64 / st.mean_ns),
    ]);

    // --- full worker step via rust backend (n=10 artifact shapes) ---
    let code10 = PolynomialCode::new(SchemeConfig::tight(10, 1, 2)?)?;
    let train = gen.generate(640, 7).pad_cols(512);
    let rust_backend = RustBackend::new(&code10, &train)?;
    let beta512 = vec![0.01f32; 512];
    let mut f = Vec::new();
    let st = b.run(|| {
        rust_backend.encoded_gradient(0, 0, black_box(&beta512), &mut f).unwrap();
    });
    table.row(&[
        "worker step (rust backend)".into(),
        Stats::human(st.mean_ns),
        Stats::human(st.p99_ns),
        "—".into(),
    ]);

    // --- full worker step via PJRT artifact (pjrt feature only) ---
    #[cfg(feature = "pjrt")]
    {
        use gradcode::runtime::{Manifest, PjrtBackend};
        let dir = Manifest::default_dir();
        if Manifest::load(&dir).map(|mf| !mf.is_empty()).unwrap_or(false) {
            let pjrt = PjrtBackend::new(&dir, &code10, &train)?;
            let st = b.run(|| {
                pjrt.encoded_gradient(0, 0, black_box(&beta512), &mut f).unwrap();
            });
            table.row(&[
                "worker step (PJRT artifact)".into(),
                Stats::human(st.mean_ns),
                Stats::human(st.p99_ns),
                "—".into(),
            ]);
        } else {
            println!("(skipping PJRT bench: run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(skipping PJRT bench: build with --features pjrt)");

    table.print();
    println!(
        "paper footnote 8: master reconstruction is O(n·l) vs worker computation Θ(N·l);\n\
         decode must stay ≪ gradient time — compare rows 2 and 4."
    );
    Ok(())
}
