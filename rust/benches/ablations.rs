//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. decoder-weight caching (the master re-solves an (n-s)×(n-s) system
//!    per straggler pattern without it);
//! 2. encode/decode inner-loop specialization by `m` (m ∈ {1,2,4} have
//!    dedicated unrolled paths; other m fall back to the generic loop);
//! 3. payload precision: f32 request path vs f64 reference (accuracy
//!    cost, from the stability module);
//! 4. quadrature vs Monte-Carlo for the §VI expectations (accuracy/time).
//!
//!     cargo bench --bench ablations

use std::time::Instant;

use gradcode::bench::{black_box, Bencher, Stats, Table};
use gradcode::coding::{
    reconstruction_error, reconstruction_error_f64, Decoder, Encoder,
    PolynomialCode, SchemeConfig,
};
use gradcode::rngs::{Pcg64, Rng};
use gradcode::simulator::order_stats::expected_total_runtime;
use gradcode::simulator::{DelayParams, VirtualCluster};

fn main() -> anyhow::Result<()> {
    let b = Bencher::new(3, 25);
    let mut rng = Pcg64::seed_from_u64(7);

    // --- 1. decoder cache ---
    let code = PolynomialCode::new(SchemeConfig::tight(20, 2, 2)?)?;
    let avail: Vec<usize> = (0..18).collect();
    let cold = b.run(|| black_box(Decoder::new(&code, &avail).unwrap()));
    let dec = Decoder::new(&code, &avail)?;
    let lv = 65536;
    let fs_store: Vec<Vec<f32>> =
        (0..18).map(|_| (0..lv).map(|_| rng.next_f64() as f32).collect()).collect();
    let fs: Vec<&[f32]> = fs_store.iter().map(|f| f.as_slice()).collect();
    let mut out = Vec::new();
    let hot = b.run(|| dec.decode_into(black_box(&fs), &mut out).unwrap());
    let mut t = Table::new(
        "ablation 1 — decoder-weight caching (n=20, s=2, l/m=65536)",
        &["path", "cost"],
    );
    t.row(&["weight construction (uncached, per pattern)".into(), Stats::human(cold.mean_ns)]);
    t.row(&["decode with cached weights".into(), Stats::human(hot.mean_ns)]);
    t.row(&[
        "construction / decode ratio".into(),
        format!("{:.2}%", 100.0 * cold.mean_ns / hot.mean_ns),
    ]);
    t.print();
    println!(
        "with C(20,2)=190 possible patterns the cache converges after ~190 misses;\n\
         uncached would add the construction cost to EVERY iteration.\n"
    );

    // --- 2. m-specialization ---
    let l0 = 1 << 18;
    let mut t = Table::new(
        "ablation 2 — encode/decode inner-loop specialization by m (l≈262144)",
        &["m", "path", "encode", "decode", "encode GB/s"],
    );
    for m in [1usize, 2, 3, 4, 6, 8] {
        let l = l0 / m * m; // round down to a multiple of m
        let s = 1;
        let cfg = SchemeConfig::tight(10, s, m)?;
        let code = PolynomialCode::new(cfg)?;
        let enc = Encoder::new(&code, 0)?;
        let grads: Vec<Vec<f32>> = (0..cfg.d)
            .map(|_| (0..l).map(|_| rng.next_f64() as f32 - 0.5).collect())
            .collect();
        let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let mut f = Vec::new();
        let st_e = b.run(|| enc.encode_into(black_box(&views), &mut f).unwrap());
        let avail: Vec<usize> = (0..10 - s).collect();
        let dec = Decoder::new(&code, &avail)?;
        let fstore: Vec<Vec<f32>> = (0..10 - s)
            .map(|_| (0..l / m).map(|_| rng.next_f64() as f32).collect())
            .collect();
        let fviews: Vec<&[f32]> = fstore.iter().map(|v| v.as_slice()).collect();
        let mut g = Vec::new();
        let st_d = b.run(|| dec.decode_into(black_box(&fviews), &mut g).unwrap());
        let path = if matches!(m, 1 | 2 | 4) { "specialized" } else { "generic" };
        let bytes = (cfg.d * l + l / m) * 4;
        t.row(&[
            m.to_string(),
            path.into(),
            Stats::human(st_e.mean_ns),
            Stats::human(st_d.mean_ns),
            format!("{:.2}", bytes as f64 / st_e.mean_ns),
        ]);
    }
    t.print();
    println!("generic-m rows show the specialization headroom for uncommon m.\n");

    // --- 3. payload precision ---
    let mut t = Table::new(
        "ablation 3 — payload precision (worst ℓ∞ rel err, 5 trials)",
        &["config", "f32 (request path)", "f64 (paper precision)"],
    );
    for (n, s, m) in [(10usize, 2usize, 2usize), (20, 2, 2), (20, 2, 4)] {
        let code = PolynomialCode::new(SchemeConfig::tight(n, s, m)?)?;
        let dim = 40 - 40 % m;
        t.row(&[
            format!("n={n}, s={s}, m={m}"),
            format!("{:.2e}", reconstruction_error(&code, dim, 5, 3)),
            format!("{:.2e}", reconstruction_error_f64(&code, dim, 5, 3)),
        ]);
    }
    t.print();
    println!("f32 is the deployed payload (PJRT artifacts are f32); f64 isolates conditioning.\n");

    // --- 4. quadrature vs Monte-Carlo ---
    let p = DelayParams::table_vi1();
    let t0 = Instant::now();
    let exact = expected_total_runtime(&p, 8, 4, 1, 3);
    let t_quad = t0.elapsed();
    let mut t = Table::new(
        "ablation 4 — §VI expectation: quadrature vs Monte-Carlo (d=4,s=1,m=3)",
        &["method", "E[T_tot]", "rel err vs quadrature", "time"],
    );
    t.row(&[
        "adaptive Simpson".into(),
        format!("{exact:.4}"),
        "—".into(),
        format!("{:.2?}", t_quad),
    ]);
    for iters in [1_000usize, 10_000, 100_000] {
        let t0 = Instant::now();
        let mc = VirtualCluster::new(&p, 8, 4, 1, 3, 11).mean_iteration_time(iters);
        let el = t0.elapsed();
        t.row(&[
            format!("Monte-Carlo {iters}"),
            format!("{mc:.4}"),
            format!("{:.3}%", 100.0 * (mc / exact - 1.0).abs()),
            format!("{el:.2?}"),
        ]);
    }
    t.print();
    println!("the tables in §VI-A need 4-digit accuracy — quadrature is both faster and exact.");
    Ok(())
}
