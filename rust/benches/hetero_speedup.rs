//! E11 — heterogeneous placement vs uniform placement across fleet
//! shapes: sweep speed profiles × schemes and report, per profile, the
//! model-predicted and cluster-realized mean iteration time of
//!
//! - uniform-load §III poly (`d = s + m`, flat `n - s` wait) — what you
//!   run when you pretend the fleet is homogeneous;
//! - uniform-load §IV random (same placement, Gaussian decode);
//! - the heterogeneous group scheme (`HeteroCode::from_speeds`:
//!   speed-tier groups, speed-proportional subset sizes, per-group
//!   quorums);
//!
//! plus the `plan_loads` optimum as the model-side reference. On skewed
//! fleets (linear, bimodal) the hetero placement should win on both the
//! predicted and the realized clock; on the uniform fleet it should tie
//! with poly up to the per-subset overhead. Training is real (coded
//! gradients, NAG); the clock is the §VI delay model scaled per worker.
//!
//! Emits the machine-readable `BENCH_hetero.json` (repo root) with the
//! full sweep plus the headline bimodal margin, so the perf trajectory
//! is tracked PR-over-PR (`ci.sh` runs the `--smoke` configuration).
//!
//!     cargo bench --bench hetero_speedup [-- --iters 150 --json out.json]

use gradcode::bench::{json_array, JsonObject, Table};
use gradcode::cli::Command;
use gradcode::coding::HeteroCode;
use gradcode::coordinator::{
    train, ExecutionMode, OptChoice, SchemeSpec, SpeedProfile, TrainConfig,
};
use gradcode::data::{train_test_split, CategoricalConfig, SyntheticCategorical};
use gradcode::simulator::hetero::{expected_fleet_time, expected_hetero_time, plan_loads};
use gradcode::simulator::DelayParams;

struct ProfileResult {
    label: String,
    predicted_uniform: f64,
    predicted_hetero: f64,
    predicted_planned: f64,
    realized_poly: f64,
    realized_random: f64,
    realized_hetero: f64,
}

fn main() -> anyhow::Result<()> {
    let args = Command::new(
        "hetero_speedup",
        "speed profiles × schemes: predicted + realized iteration time",
    )
    .flag("n", "10", "workers")
    .flag("s", "1", "straggler tolerance")
    .flag("m", "2", "communication reduction factor")
    .flag("iters", "120", "training iterations per cell")
    .flag("rows", "2400", "dataset rows")
    .flag(
        "profiles",
        "uniform;linear:3;bimodal:0.5:4",
        "semicolon-separated fleet profiles to sweep",
    )
    .flag("seed", "12", "seed")
    .flag("json", "BENCH_hetero.json", "machine-readable output path (empty to skip)")
    .switch("smoke", "tiny configuration for the CI gate")
    .parse_env();

    let smoke = args.get_bool("smoke");
    if smoke {
        // Keep the CI configuration fixed regardless of other flags, and
        // say so instead of silently discarding them.
        println!(
            "--smoke: overriding --n/--iters/--rows/--profiles with the fixed \
             CI configuration (n=8, iters=25, rows=800, uniform;bimodal:0.5:4)"
        );
    }
    let n = if smoke { 8 } else { args.get_usize("n") };
    let s = args.get_usize("s");
    let m = args.get_usize("m");
    let iters = if smoke { 25 } else { args.get_usize("iters") };
    let rows = if smoke { 800 } else { args.get_usize("rows") };
    let seed = args.get_u64("seed");
    let profiles_spec = if smoke {
        "uniform;bimodal:0.5:4".to_string()
    } else {
        args.get_str("profiles").to_string()
    };
    let p = DelayParams::ec2_fit();

    let gen = SyntheticCategorical::new(
        CategoricalConfig { columns: 9, cardinality: (8, 40), ..Default::default() },
        seed,
    );
    let raw = gen.generate(rows, seed + 1);
    let (train_ds, test_ds) = train_test_split(&raw, 0.25, seed + 2);
    let lr = 1.2 / train_ds.rows as f32;

    let run = |scheme: SchemeSpec, fleet: Option<SpeedProfile>| -> anyhow::Result<f64> {
        let cfg = TrainConfig {
            n,
            scheme,
            iters,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: iters, // metrics off the hot path
            delays: Some(p),
            mode: ExecutionMode::Virtual,
            seed,
            minibatch: None,
            quorum: None,
            fleet,
            chaos: None,
        };
        let (log, _) = train(cfg, &train_ds, Some(&test_ds))?;
        Ok(log.mean_iteration_sim_time())
    };

    let mut results: Vec<ProfileResult> = Vec::new();
    for spec in profiles_spec.split(';').filter(|s| !s.is_empty()) {
        let profile = SpeedProfile::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
        let speeds = profile.try_speeds(n).map_err(|e| anyhow::anyhow!(e))?;
        let hetero_code = HeteroCode::from_speeds(n, s, m, &speeds)?;
        let plan = plan_loads(&p, &speeds, s, m);
        results.push(ProfileResult {
            label: profile.label(),
            predicted_uniform: expected_fleet_time(&p, &speeds, s + m, s, m),
            predicted_hetero: expected_hetero_time(&p, &hetero_code),
            predicted_planned: plan.expected_time,
            realized_poly: run(SchemeSpec::Poly { s, m }, Some(profile.clone()))?,
            realized_random: run(
                SchemeSpec::Random { s, m, seed: seed ^ 0x9a },
                Some(profile.clone()),
            )?,
            realized_hetero: run(
                SchemeSpec::Hetero { s, m, profile: profile.clone() },
                None,
            )?,
        });
    }

    let mut table = Table::new(
        &format!(
            "iteration time by fleet shape, n = {n}, s = {s}, m = {m} (ec2-fit delays)"
        ),
        &[
            "profile",
            "E[T] uniform",
            "E[T] hetero",
            "E[T] planned",
            "meas poly",
            "meas random",
            "meas hetero",
            "speedup",
        ],
    );
    for r in &results {
        table.row(&[
            r.label.clone(),
            format!("{:.3}", r.predicted_uniform),
            format!("{:.3}", r.predicted_hetero),
            format!("{:.3}", r.predicted_planned),
            format!("{:.3}", r.realized_poly),
            format!("{:.3}", r.realized_random),
            format!("{:.3}", r.realized_hetero),
            format!("{:.2}x", r.realized_poly / r.realized_hetero),
        ]);
    }
    table.print();
    println!(
        "expected shape: on the uniform fleet hetero ties poly (within the per-subset \
         overhead); the more skewed the fleet, the larger the hetero margin — slow \
         workers carry smaller subsets and slack groups release the gather early."
    );

    // Headline number for the acceptance gate: the bimodal margin.
    let bimodal = results.iter().find(|r| r.label.starts_with("bimodal"));
    if let Some(b) = bimodal {
        println!(
            "\nbimodal margin: predicted {:.2}x, realized {:.2}x over uniform poly",
            b.predicted_uniform / b.predicted_hetero,
            b.realized_poly / b.realized_hetero,
        );
    }

    let json_path = args.get_str("json");
    if !json_path.is_empty() {
        let profile_objs = results.iter().map(|r| {
            JsonObject::new()
                .field_str("profile", &r.label)
                .field_raw(
                    "predicted",
                    &JsonObject::new()
                        .field_num("uniform_poly", r.predicted_uniform)
                        .field_num("hetero", r.predicted_hetero)
                        .field_num("planned", r.predicted_planned)
                        .field_num("speedup", r.predicted_uniform / r.predicted_hetero)
                        .build(),
                )
                .field_raw(
                    "realized",
                    &JsonObject::new()
                        .field_num("uniform_poly", r.realized_poly)
                        .field_num("random", r.realized_random)
                        .field_num("hetero", r.realized_hetero)
                        .field_num("speedup", r.realized_poly / r.realized_hetero)
                        .build(),
                )
                .build()
        });
        let mut root = JsonObject::new()
            .field_str("bench", "hetero_speedup")
            .field_int("n", n as i64)
            .field_int("s", s as i64)
            .field_int("m", m as i64)
            .field_int("iters", iters as i64)
            .field_int("rows", rows as i64)
            .field_int("smoke", i64::from(smoke))
            .field_raw(
                "delay_params",
                &JsonObject::new()
                    .field_num("lambda1", p.lambda1)
                    .field_num("t1", p.t1)
                    .field_num("lambda2", p.lambda2)
                    .field_num("t2", p.t2)
                    .build(),
            )
            .field_raw("profiles", &json_array(profile_objs));
        if let Some(b) = bimodal {
            root = root.field_raw(
                "bimodal_margin",
                &JsonObject::new()
                    .field_num("predicted_speedup", b.predicted_uniform / b.predicted_hetero)
                    .field_num("realized_speedup", b.realized_poly / b.realized_hetero)
                    .build(),
            );
        }
        std::fs::write(json_path, root.build() + "\n")?;
        println!("wrote {json_path}");
    }
    Ok(())
}
