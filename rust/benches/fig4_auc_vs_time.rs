//! E3 — regenerates Fig. 4: generalization AUC vs wall-clock time for
//! n ∈ {10, 15, 20}, same schemes as Fig. 3.
//!
//! The paper's claim: the m > 1 curves sit strictly to the LEFT of the
//! m = 1 and naive curves — the same AUC is reached earlier. Training is
//! real (coded gradients, NAG); the clock is the fitted §VI delay model.
//!
//!     cargo bench --bench fig4_auc_vs_time [-- --iters 250]

use gradcode::bench::Table;
use gradcode::cli::Command;
use gradcode::coordinator::{
    train, ExecutionMode, OptChoice, SchemeSpec, TrainConfig,
};
use gradcode::data::{train_test_split, CategoricalConfig, SyntheticCategorical};
use gradcode::metrics::RunLog;
use gradcode::simulator::optimize::{optimal_triple, optimal_triple_m1};
use gradcode::simulator::DelayParams;

/// First simulated time at which the run's AUC reaches `target`.
fn time_to_auc(log: &RunLog, target: f64) -> Option<f64> {
    log.auc_curve().iter().find(|(_, a)| *a >= target).map(|(t, _)| *t)
}

fn main() -> anyhow::Result<()> {
    let args = Command::new("fig4", "AUC vs time (paper Fig. 4)")
        .flag("iters", "250", "iterations per scheme")
        .flag("workers", "10,15,20", "worker counts")
        .flag("seed", "4", "seed")
        .parse_env();
    let iters = args.get_usize("iters");
    let p = DelayParams::ec2_fit();

    for n in args.get_usize_list("workers") {
        let m1 = optimal_triple_m1(&p, n);
        let best = optimal_triple(&p, n);
        let schemes = [
            ("naive".to_string(), SchemeSpec::Uncoded),
            (format!("m=1, s={}", m1.s), SchemeSpec::Poly { s: m1.s, m: 1 }),
            (
                format!("ours m={}, s={}", best.m, best.s),
                SchemeSpec::Poly { s: best.s, m: best.m },
            ),
        ];

        let gen = SyntheticCategorical::new(
            CategoricalConfig {
                columns: 9,
                cardinality: (8, 40),
                label_noise: 0.1,
                ..Default::default()
            },
            55,
        );
        let raw = gen.generate(4000, 56);
        let (train_ds, test_ds) = train_test_split(&raw, 0.25, 57);
        let lr = 1.2 / train_ds.rows as f32;

        let mut logs = Vec::new();
        for (label, spec) in &schemes {
            let cfg = TrainConfig {
                n,
                scheme: spec.clone(),
                iters,
                opt: OptChoice::Nag { lr, momentum: 0.9 },
                eval_every: (iters / 60).max(1),
                delays: Some(p),
                mode: ExecutionMode::Virtual,
                seed: args.get_u64("seed"),
                minibatch: None,
                quorum: None,
                fleet: None,
                chaos: None,
            };
            let (log, _) = train(cfg, &train_ds, Some(&test_ds))?;
            logs.push((label.clone(), log));
        }

        // The paper plots curves; we print the curves plus the summary
        // statistic that captures "curve is to the left": time to reach
        // fractions of the best achievable AUC.
        let peak_aucs: Vec<f64> = logs
            .iter()
            .map(|(_, l)| {
                l.auc_curve().iter().map(|(_, a)| *a).fold(0.5, f64::max)
            })
            .collect();
        let target_full = peak_aucs.iter().fold(1.0f64, |a, &b| a.min(b));
        let mut table = Table::new(
            &format!("Fig. 4 — time (s) to reach target AUC, n = {n}"),
            &["scheme", "time to 90% of target AUC", "time to 97%", "final AUC"],
        );
        for (label, log) in &logs {
            let t95 = time_to_auc(log, 0.5 + (target_full - 0.5) * 0.90);
            let t99 = time_to_auc(log, 0.5 + (target_full - 0.5) * 0.97);
            table.row(&[
                label.clone(),
                t95.map_or("—".into(), |t| format!("{t:.0}")),
                t99.map_or("—".into(), |t| format!("{t:.0}")),
                format!("{:.4}", log.final_auc().unwrap_or(f64::NAN)),
            ]);
        }
        table.print();
        for (label, log) in &logs {
            let pts: Vec<String> = log
                .auc_curve()
                .iter()
                .step_by(2)
                .map(|(t, a)| format!("({t:.0},{a:.3})"))
                .collect();
            println!("  curve {label:<16} {}", pts.join(" "));
        }
        println!();
    }
    println!("expected shape: the ours-curve reaches every AUC level first (left-most), naive last.");
    Ok(())
}
