//! Obs — telemetry overhead and per-phase cost of a traced run: trains
//! the same configuration with the recorder disabled and enabled
//! (several repetitions each, keeping the minimum wall time as the
//! noise-robust estimate) and reports
//!
//! - the traced run's phase breakdown (count, mean, p50/p90/p99) — where
//!   an iteration's wall time actually goes;
//! - the overhead delta `traced/untraced − 1` — the price of tracing,
//!   which the ci gate bounds (the recorder is an `Option<Arc>` check
//!   when disabled and ~two `Instant::now` calls per span when enabled,
//!   so the delta should stay in the low single digits);
//! - the live-metrics delta `metrics/untraced − 1` — tracing plus a
//!   [`MetricsRegistry`] scrape endpoint being polled throughout the
//!   run, i.e. the full price of running with `--metrics-addr`. Scrapes
//!   snapshot under short scoped locks off the training thread, so this
//!   should track the plain tracing overhead closely.
//!
//! Emits the machine-readable `BENCH_obs.json` (repo root) so the
//! overhead trajectory is tracked PR-over-PR (`ci.sh` runs the
//! `--smoke` configuration).
//!
//!     cargo bench --bench obs_overhead [-- --iters 80 --json out.json]

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use gradcode::bench::{json_array, JsonObject, Table};
use gradcode::cli::Command;
use gradcode::coordinator::{OptChoice, SchemeSpec, TrainConfig, Trainer};
use gradcode::data::{CategoricalConfig, DenseDataset, SyntheticCategorical};
use gradcode::obs::{MetricsRegistry, Recorder, TelemetrySummary};

fn main() -> anyhow::Result<()> {
    let args = Command::new(
        "obs_overhead",
        "traced vs untraced training: phase breakdown + recorder overhead",
    )
    .flag("n", "8", "workers")
    .flag("s", "1", "straggler tolerance")
    .flag("m", "2", "communication reduction factor")
    .flag("iters", "60", "training iterations per run")
    .flag("rows", "1600", "dataset rows")
    .flag("reps", "3", "repetitions per variant (minimum wall time wins)")
    .flag("seed", "23", "seed")
    .flag("json", "BENCH_obs.json", "machine-readable output path (empty to skip)")
    .switch("smoke", "tiny configuration for the CI gate")
    .parse_env();

    let smoke = args.get_bool("smoke");
    if smoke {
        println!(
            "--smoke: overriding --n/--iters/--rows/--reps with the fixed CI \
             configuration (n=6, iters=30, rows=600, reps=2)"
        );
    }
    let n = if smoke { 6 } else { args.get_usize("n") };
    let s = args.get_usize("s");
    let m = args.get_usize("m");
    let iters = if smoke { 30 } else { args.get_usize("iters") };
    let rows = if smoke { 600 } else { args.get_usize("rows") };
    let reps = if smoke { 2 } else { args.get_usize("reps").max(1) };
    let seed = args.get_u64("seed");

    let gen = SyntheticCategorical::new(CategoricalConfig::default(), seed);
    let ds = gen.generate(rows, seed + 1);

    let cfg = {
        let mut c = TrainConfig::quick(n, SchemeSpec::Poly { s, m }, iters);
        c.opt = OptChoice::Nag { lr: 1.2 / rows as f32, momentum: 0.9 };
        c.eval_every = iters; // metrics off the hot path
        c.seed = seed;
        c
    };

    // One full training run; returns wall seconds and (when traced) the
    // telemetry digest of the last repetition.
    let run = |traced: bool,
               ds: &DenseDataset|
     -> anyhow::Result<(f64, Option<TelemetrySummary>)> {
        let mut tr = Trainer::new(cfg.clone(), ds, None)?;
        let rec = if traced { Recorder::enabled() } else { Recorder::disabled() };
        tr.attach_recorder(&rec);
        let t0 = Instant::now();
        let log = tr.run()?;
        Ok((t0.elapsed().as_secs_f64(), log.telemetry))
    };

    // The full live-metrics stack: traced run + registry + scrape
    // endpoint polled for the whole run, like a fast Prometheus server.
    let run_scraped = |ds: &DenseDataset| -> anyhow::Result<(f64, u64)> {
        let rec = Recorder::enabled();
        let registry = MetricsRegistry::new(&rec);
        let srv = registry.serve("127.0.0.1:0")?;
        let addr = srv.addr();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let scraper = std::thread::spawn(move || {
            let mut scrapes = 0u64;
            while !stop2.load(Ordering::Relaxed) {
                if let Ok(mut s) = TcpStream::connect(addr) {
                    let _ = s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n");
                    let mut body = String::new();
                    let _ = s.read_to_string(&mut body);
                    scrapes += 1;
                }
                std::thread::sleep(Duration::from_millis(25));
            }
            scrapes
        });
        let mut tr = Trainer::new(cfg.clone(), ds, None)?;
        tr.attach_recorder(&rec);
        let t0 = Instant::now();
        tr.run()?;
        let secs = t0.elapsed().as_secs_f64();
        stop.store(true, Ordering::Relaxed);
        let scrapes = scraper.join().unwrap_or(0);
        srv.shutdown();
        Ok((secs, scrapes))
    };

    // Interleave the variants so drift (thermal, cache, scheduler) hits
    // all of them equally; keep the minimum, the standard noise-robust
    // pick.
    let mut untraced = f64::INFINITY;
    let mut traced = f64::INFINITY;
    let mut with_metrics = f64::INFINITY;
    let mut total_scrapes = 0u64;
    let mut digest: Option<TelemetrySummary> = None;
    for rep in 0..reps {
        let (u, _) = run(false, &ds)?;
        let (t, d) = run(true, &ds)?;
        let (w, scrapes) = run_scraped(&ds)?;
        untraced = untraced.min(u);
        traced = traced.min(t);
        with_metrics = with_metrics.min(w);
        total_scrapes += scrapes;
        digest = d.or(digest);
        println!(
            "rep {rep}: untraced {u:.3}s, traced {t:.3}s, live-metrics {w:.3}s \
             ({scrapes} scrapes served)"
        );
    }
    let digest = digest.expect("traced run produces a digest");
    let overhead = traced / untraced - 1.0;
    let metrics_overhead = with_metrics / untraced - 1.0;

    let mut table = Table::new(
        &format!("traced phase breakdown, n = {n}, s = {s}, m = {m}, {iters} iters"),
        &["phase", "count", "total s", "mean ms", "p50 ms", "p90 ms", "p99 ms"],
    );
    for p in &digest.phases {
        table.row(&[
            p.phase.clone(),
            format!("{}", p.count),
            format!("{:.3}", p.total),
            format!("{:.3}", p.mean * 1e3),
            format!("{:.3}", p.p50 * 1e3),
            format!("{:.3}", p.p90 * 1e3),
            format!("{:.3}", p.p99 * 1e3),
        ]);
    }
    table.print();
    println!(
        "\nwall time: untraced {untraced:.3}s, traced {traced:.3}s \
         ({:+.2}% overhead), live-metrics {with_metrics:.3}s \
         ({:+.2}% overhead, {total_scrapes} scrapes served)",
        overhead * 100.0,
        metrics_overhead * 100.0
    );

    let json_path = args.get_str("json");
    if !json_path.is_empty() {
        let phase_objs = digest.phases.iter().map(|p| {
            JsonObject::new()
                .field_str("phase", &p.phase)
                .field_int("count", p.count as i64)
                .field_num("total_s", p.total)
                .field_num("mean_s", p.mean)
                .field_num("p50_s", p.p50)
                .field_num("p90_s", p.p90)
                .field_num("p99_s", p.p99)
                .field_num("max_s", p.max)
                .build()
        });
        let root = JsonObject::new()
            .field_str("bench", "obs_overhead")
            .field_int("n", n as i64)
            .field_int("s", s as i64)
            .field_int("m", m as i64)
            .field_int("iters", iters as i64)
            .field_int("rows", rows as i64)
            .field_int("reps", reps as i64)
            .field_int("smoke", i64::from(smoke))
            .field_num("untraced_secs", untraced)
            .field_num("traced_secs", traced)
            .field_num("overhead_frac", overhead)
            .field_num("metrics_secs", with_metrics)
            .field_num("metrics_overhead_frac", metrics_overhead)
            .field_int("metrics_scrapes", total_scrapes as i64)
            .field_raw("phases", &json_array(phase_objs));
        std::fs::write(json_path, root.build() + "\n")?;
        println!("wrote {json_path}");
    }
    Ok(())
}
