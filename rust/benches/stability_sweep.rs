//! E7 — regenerates the §III-C / §IV numerical-stability findings:
//!
//! - Vandermonde (§III): stable up to n ≈ 20 (rel err ≲ 0.2%), sharply
//!   degrading at n = 23 and collapsing by n = 26;
//! - Gaussian (§IV): stable through n = 30;
//! - worst-case decode condition numbers over straggler patterns (the κ
//!   of Theorem 2), plus the γ(n, n₁, κ) Monte-Carlo estimate.
//!
//! Errors are measured in the paper's precision (f64 payloads) and in
//! the deployed f32 payload path.
//!
//!     cargo bench --bench stability_sweep

use gradcode::bench::Table;
use gradcode::cli::Command;
use gradcode::coding::{
    gamma_gaussian, max_condition_number, reconstruction_error,
    reconstruction_error_f64, PolynomialCode, RandomCode, SchemeConfig,
};

fn fmt_err(e: f64) -> String {
    if e.is_infinite() {
        "FAIL".into()
    } else {
        format!("{e:.2e}")
    }
}

fn main() {
    let args = Command::new("stability", "§III-C/§IV stability sweep")
        .flag("trials", "8", "round trips per configuration")
        .flag("dim", "40", "gradient dimension")
        .flag("budget", "300", "straggler patterns for cond sweep")
        .parse_env();
    let trials = args.get_usize("trials");
    let dim0 = args.get_usize("dim");
    let budget = args.get_usize("budget");

    let mut table = Table::new(
        "ℓ∞ reconstruction relative error & worst decode condition number (s=2, m=2)",
        &["n", "vand cond", "vand err f64", "vand err f32", "gauss cond", "gauss err f64"],
    );
    for n in [5usize, 10, 15, 20, 23, 26, 30] {
        let cfg = SchemeConfig::tight(n, 2, 2).unwrap();
        let dim = dim0 - dim0 % 2;
        let vand = PolynomialCode::new(cfg).unwrap();
        let gauss = RandomCode::new(cfg, 1).unwrap();
        let vc = max_condition_number(&vand, budget, 7).worst_cond;
        let gc = max_condition_number(&gauss, budget, 7).worst_cond;
        table.row(&[
            n.to_string(),
            format!("{vc:.1e}"),
            fmt_err(reconstruction_error_f64(&vand, dim, trials, 11)),
            fmt_err(reconstruction_error(&vand, dim, trials, 11)),
            format!("{gc:.1e}"),
            fmt_err(reconstruction_error_f64(&gauss, dim, trials, 11)),
        ]);
    }
    table.print();
    println!("paper §III-C: Vandermonde err < 0.2% for n ≤ 20, ~80% worst case at n = 23, crash at n = 26.");
    println!("paper §IV:    Gaussian stable for all n ≤ 30.\n");

    // m-sensitivity at n = 20 (where the practical boundary lies).
    let mut mtable = Table::new(
        "m-sensitivity at n = 20 (s = 2): decode cond & f64 error",
        &["m", "d", "cond", "err f64"],
    );
    for m in 1..=5usize {
        let cfg = SchemeConfig::tight(20, 2, m).unwrap();
        let vand = PolynomialCode::new(cfg).unwrap();
        let dim = 40 - 40 % m;
        mtable.row(&[
            m.to_string(),
            cfg.d.to_string(),
            format!("{:.1e}", max_condition_number(&vand, budget, 7).worst_cond),
            fmt_err(reconstruction_error_f64(&vand, dim, trials, 13)),
        ]);
    }
    mtable.print();

    // Theorem 2's γ for Gaussian V: responders needed to certify κ.
    let mut gtable = Table::new(
        "γ(n=20, n₁=16, κ) Monte-Carlo (Gaussian V) — Theorem 2 region",
        &["κ", "γ", "s_κ = n - γ"],
    );
    for kappa in [1e2, 1e3, 1e4, 1e6] {
        match gamma_gaussian(20, 16, kappa, 150, 17) {
            Some(g) => gtable.row(&[
                format!("{kappa:.0e}"),
                g.to_string(),
                (20 - g).to_string(),
            ]),
            None => gtable.row(&[format!("{kappa:.0e}"), "—".into(), "—".into()]),
        }
    }
    gtable.print();
    println!("γ decreases (s_κ increases) as κ loosens — Theorem 2's monotonicity.");
}
