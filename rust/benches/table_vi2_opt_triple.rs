//! E5/E6 — regenerates the two §VI-A optimal-triple grids:
//!
//! table 2: n = 10, λ₁ = 0.6, t₁ = 1.5; argmin (d,s,m) as a function of
//!          λ₂ ∈ {0.05..0.3} × t₂ ∈ {1.5..96};
//! table 3: n = 10, λ₂ = 0.1, t₂ = 6; argmin as a function of
//!          λ₁ ∈ {0.5..1.0} × t₁ ∈ {1..2.8}.
//!
//!     cargo bench --bench table_vi2_opt_triple

use gradcode::bench::Table;
use gradcode::simulator::{optimal_triple, DelayParams};

fn fmt_triple(p: &DelayParams, n: usize) -> String {
    let t = optimal_triple(p, n);
    format!("({},{},{})", t.d, t.s, t.m)
}

fn main() {
    let n = 10;

    // table 2 (vary λ₂, t₂)
    let t2s = [1.5, 3.0, 6.0, 12.0, 24.0, 48.0, 96.0];
    let l2s = [0.05, 0.1, 0.15, 0.2, 0.25, 0.3];
    let header: Vec<String> = std::iter::once("λ₂ \\ t₂".to_string())
        .chain(t2s.iter().map(|t| t.to_string()))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table2 = Table::new(
        "§VI-A table 2 — optimal (d,s,m); n=10, λ₁=0.6, t₁=1.5",
        &header_refs,
    );
    for &l2 in &l2s {
        let mut row = vec![l2.to_string()];
        for &t2 in &t2s {
            row.push(fmt_triple(&DelayParams::table_vi2_base(l2, t2), n));
        }
        table2.row(&row);
    }
    table2.print();
    println!("paper row λ₂=0.05: (10,9,1) (10,8,2) (10,8,2) (10,7,3) (10,6,4) (10,5,5) (10,4,6)");
    println!("paper trend: m increases with t₂; d decreases with λ₂\n");

    // table 3 (vary λ₁, t₁)
    let t1s = [1.0, 1.3, 1.6, 1.9, 2.2, 2.5, 2.8];
    let l1s = [0.5, 0.6, 0.7, 0.8, 0.9, 1.0];
    let header3: Vec<String> = std::iter::once("λ₁ \\ t₁".to_string())
        .chain(t1s.iter().map(|t| t.to_string()))
        .collect();
    let header3_refs: Vec<&str> = header3.iter().map(|s| s.as_str()).collect();
    let mut table3 = Table::new(
        "§VI-A table 3 — optimal (d,s,m); n=10, λ₂=0.1, t₂=6",
        &header3_refs,
    );
    for &l1 in &l1s {
        let mut row = vec![l1.to_string()];
        for &t1 in &t1s {
            row.push(fmt_triple(&DelayParams::table_vi3_base(l1, t1), n));
        }
        table3.row(&row);
    }
    table3.print();
    println!("paper row λ₁=0.5: (10,8,2) (10,8,2) (3,1,2) (3,1,2) (3,1,2) (2,0,2) (2,0,2)");
    println!("paper trend: for fixed λ₁, s decreases with t₁");
}
