//! TCP deployment: master and workers as separate processes over real
//! sockets — the offline analogue of the paper's mpi4py EC2 deployment.
//!
//! - [`RemoteMaster`] listens, handshakes `n` workers (Hello → Setup),
//!   broadcasts `Task` frames each iteration and gathers `Result`s from
//!   the first `n - s` responders (arrival order — real network racing).
//! - [`run_worker`] is the worker process body: connect, receive Setup,
//!   rebuild scheme + data shard deterministically from the seeds, then
//!   serve the task loop until Shutdown.
//!
//! The data "distribution" step is seed-based regeneration (every worker
//! derives its shard from `data_seed`), standing in for the shared
//! filesystem / S3 load of the real deployment.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::{ComputeBackend, RustBackend};
use super::trainer::SchemeSpec;
use super::wire::{
    Message, Setup, MAGIC, SCHEME_APPROX, SCHEME_HETERO, SCHEME_POLY, SCHEME_RANDOM,
    SCHEME_UNCODED,
};
use crate::coding::{ApproxCode, GradientCode, HeteroCode};
use crate::data::{CategoricalConfig, DenseDataset, SyntheticCategorical};

/// Rebuild the scheme from a Setup frame (both sides do this, so encode
/// coefficients and decode weights agree without shipping matrices).
///
/// Kind 3 (approx) carries the replication in `d` and the responder
/// quorum in `quorum`. Kind 4 (hetero) ships the per-worker speed vector
/// (milli-units); both sides rebuild via the deterministic
/// [`HeteroCode::from_speeds`] heuristic and the shipped `loads` vector
/// cross-checks that master and worker agree on the placement.
pub fn scheme_from_setup(setup: &Setup) -> Result<std::sync::Arc<dyn GradientCode>> {
    let n = setup.n as usize;
    let spec = match setup.scheme_kind {
        SCHEME_POLY => SchemeSpec::Poly { s: setup.s as usize, m: setup.m as usize },
        SCHEME_RANDOM => SchemeSpec::Random {
            s: setup.s as usize,
            m: setup.m as usize,
            seed: setup.scheme_seed,
        },
        SCHEME_UNCODED => SchemeSpec::Uncoded,
        SCHEME_APPROX => {
            let quorum = setup.quorum as usize;
            if quorum == 0 || quorum > n {
                bail!("approx setup needs quorum in 1..={n}, got {quorum}");
            }
            let code = ApproxCode::new(n, setup.d as usize, quorum)?;
            return Ok(std::sync::Arc::new(code));
        }
        SCHEME_HETERO => {
            if setup.speeds_milli.len() != n {
                bail!(
                    "hetero setup needs {n} speeds, got {}",
                    setup.speeds_milli.len()
                );
            }
            let code = HeteroCode::from_speeds(
                n,
                setup.s as usize,
                setup.m as usize,
                &setup.speeds(),
            )?;
            if !setup.loads.is_empty() {
                let got: Vec<u32> = code.loads().iter().map(|&d| d as u32).collect();
                if got != setup.loads {
                    bail!(
                        "hetero load vector mismatch: setup says {:?}, rebuilt {:?} \
                         (master and worker must run the same scheme heuristic)",
                        setup.loads,
                        got
                    );
                }
            }
            return Ok(std::sync::Arc::new(code));
        }
        other => bail!("unknown scheme kind {other}"),
    };
    spec.build(n)
}

/// Regenerate the deterministic training set both sides agree on.
pub fn dataset_from_setup(setup: &Setup) -> DenseDataset {
    let gen = SyntheticCategorical::new(
        CategoricalConfig { columns: 10, cardinality: (16, 48), ..Default::default() },
        setup.data_seed,
    );
    gen.generate(setup.rows as usize, setup.data_seed + 1)
        .pad_cols(setup.dim as usize)
}

/// One gathered remote iteration.
#[derive(Debug)]
pub struct RemoteGather {
    /// (worker id, coded vector), in arrival order, length
    /// [`Setup::wait_for`] (`n - s`, or the approx scheme's quorum).
    pub results: Vec<(usize, Vec<f32>)>,
    /// Wall-clock seconds from broadcast to quorum.
    pub elapsed: f64,
}

/// Master side of the TCP deployment.
pub struct RemoteMaster {
    setup: Setup,
    writers: Vec<BufWriter<TcpStream>>,
    /// Fan-in channel fed by per-connection reader threads.
    results: Receiver<(usize, Message)>,
    _reader_handles: Vec<std::thread::JoinHandle<()>>,
}

impl RemoteMaster {
    /// Bind, accept `setup.n` workers, handshake each.
    pub fn listen(addr: impl ToSocketAddrs, setup: Setup) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("binding master socket")?;
        let mut writers: Vec<Option<BufWriter<TcpStream>>> =
            (0..setup.n).map(|_| None).collect();
        let (tx, rx) = channel();
        let mut handles = Vec::new();
        for _ in 0..setup.n {
            let (stream, peer) = listener.accept().context("accepting worker")?;
            stream.set_nodelay(true).ok();
            let mut reader = BufReader::new(stream.try_clone()?);
            // Handshake: Hello -> Setup.
            let hello = Message::read_from(&mut reader)?;
            let worker_id = match hello {
                Message::Hello { magic, worker_id } if magic == MAGIC => worker_id as usize,
                Message::Hello { magic, .. } => bail!("bad magic {magic:#x} from {peer}"),
                other => bail!("expected Hello from {peer}, got {other:?}"),
            };
            if worker_id >= setup.n as usize {
                bail!("worker id {worker_id} out of range");
            }
            if writers[worker_id].is_some() {
                bail!("duplicate worker id {worker_id}");
            }
            let mut writer = BufWriter::new(stream);
            Message::Setup(setup.clone()).write_to(&mut writer)?;
            writers[worker_id] = Some(writer);
            // Reader thread: pump results into the fan-in channel.
            let tx: Sender<(usize, Message)> = tx.clone();
            handles.push(std::thread::spawn(move || {
                loop {
                    match Message::read_from(&mut reader) {
                        Ok(msg) => {
                            if tx.send((worker_id, msg)).is_err() {
                                return;
                            }
                        }
                        Err(_) => return, // connection closed
                    }
                }
            }));
        }
        let writers: Vec<BufWriter<TcpStream>> =
            writers.into_iter().map(|w| w.expect("all ids seen")).collect();
        Ok(RemoteMaster { setup, writers, results: rx, _reader_handles: handles })
    }

    pub fn setup(&self) -> &Setup {
        &self.setup
    }

    /// Broadcast an iteration and gather the first [`Setup::wait_for`]
    /// results.
    pub fn run_iteration(&mut self, iter: u64, beta: &[f32]) -> Result<RemoteGather> {
        let t0 = Instant::now();
        let msg = Message::Task { iter, beta: beta.to_vec() };
        for w in self.writers.iter_mut() {
            // A dead connection = permanent straggler.
            let _ = msg.write_to(w);
        }
        let quorum = self.setup.wait_for();
        let tolerance = self.setup.n as usize - quorum;
        let mut results = Vec::with_capacity(quorum);
        let mut failures = 0usize;
        while results.len() < quorum {
            let (wid, msg) = self
                .results
                .recv()
                .context("all worker connections closed before quorum")?;
            match msg {
                Message::Result { iter: rit, failed, f, .. } if rit == iter => {
                    if failed {
                        failures += 1;
                        if failures > tolerance {
                            bail!("{failures} worker failures exceed tolerance {tolerance}");
                        }
                    } else {
                        results.push((wid, f));
                    }
                }
                Message::Result { .. } => continue, // stale iteration
                other => bail!("unexpected message from worker {wid}: {other:?}"),
            }
        }
        Ok(RemoteGather { results, elapsed: t0.elapsed().as_secs_f64() })
    }

    /// Send Shutdown to everyone.
    pub fn shutdown(mut self) {
        for w in self.writers.iter_mut() {
            let _ = Message::Shutdown.write_to(w);
        }
    }
}

/// Worker process body: connect to the master and serve until Shutdown.
/// Returns the number of tasks served.
pub fn run_worker(addr: impl ToSocketAddrs, worker_id: usize) -> Result<usize> {
    let stream = TcpStream::connect(addr).context("connecting to master")?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    Message::Hello { magic: MAGIC, worker_id: worker_id as u32 }.write_to(&mut writer)?;
    let setup = match Message::read_from(&mut reader)? {
        Message::Setup(s) => s,
        other => bail!("expected Setup, got {other:?}"),
    };
    let code = scheme_from_setup(&setup)?;
    let train = dataset_from_setup(&setup);
    let backend = RustBackend::new(code.as_ref(), &train)?;

    let mut served = 0usize;
    let mut out = Vec::new();
    loop {
        match Message::read_from(&mut reader)? {
            Message::Task { iter, beta } => {
                let failed =
                    backend.encoded_gradient(worker_id, iter as usize, &beta, &mut out).is_err();
                Message::Result {
                    worker: worker_id as u32,
                    iter,
                    failed,
                    f: if failed { Vec::new() } else { out.clone() },
                }
                .write_to(&mut writer)?;
                served += 1;
            }
            Message::Shutdown => return Ok(served),
            other => bail!("unexpected message: {other:?}"),
        }
    }
}

/// Decode helper for the master: reconstruct the sum gradient from a
/// remote gather (arrival-ordered responder list).
pub fn decode_gather(
    code: &dyn GradientCode,
    gather: &RemoteGather,
    cache: &mut HashMap<u64, crate::coding::Decoder>,
) -> Result<Vec<f32>> {
    let mut responders: Vec<usize> = gather.results.iter().map(|(w, _)| *w).collect();
    responders.sort_unstable();
    let key = responders.iter().fold(0u64, |acc, &w| acc | (1 << w));
    if !cache.contains_key(&key) {
        cache.insert(key, crate::coding::Decoder::new(code, &responders)?);
    }
    let dec = &cache[&key];
    let by_worker: HashMap<usize, &[f32]> =
        gather.results.iter().map(|(w, f)| (*w, f.as_slice())).collect();
    let fs: Vec<&[f32]> =
        dec.used_workers().iter().map(|w| by_worker[w]).collect();
    Ok(dec.decode(&fs)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_setup(n: u32, s: u32, m: u32) -> Setup {
        Setup::homogeneous(n, s + m, s, m, SCHEME_POLY, 1, 777, n * 16, 512)
    }

    /// Full multi-"process" deployment over loopback TCP: one master,
    /// n worker bodies on threads, real sockets, real decode.
    #[test]
    fn tcp_cluster_trains_over_loopback() {
        let setup = test_setup(5, 1, 2);
        let listener_addr = {
            // reserve a free port
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = l.local_addr().unwrap();
            drop(l);
            addr
        };
        let master_thread = {
            let setup = setup;
            std::thread::spawn(move || -> Result<Vec<f32>> {
                let mut master = RemoteMaster::listen(listener_addr, setup.clone())?;
                let code = scheme_from_setup(&setup)?;
                let train = dataset_from_setup(&setup);
                let backend = RustBackend::new(code.as_ref(), &train)?;
                let mut cache = HashMap::new();
                let mut beta = vec![0.0f32; setup.dim as usize];
                let lr = 4.0 / train.rows as f32;
                for iter in 0..5u64 {
                    let gather = master.run_iteration(iter, &beta)?;
                    assert_eq!(gather.results.len(), 4); // n - s
                    let grad = decode_gather(code.as_ref(), &gather, &mut cache)?;
                    // cross-check against the local oracle
                    let want = backend.full_gradient(iter as usize, &beta);
                    let scale =
                        want.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
                    for j in 0..grad.len() {
                        assert!(
                            (grad[j] - want[j]).abs() / scale < 1e-3,
                            "iter {iter} coord {j}"
                        );
                    }
                    for (b, g) in beta.iter_mut().zip(&grad) {
                        *b -= lr * g;
                    }
                }
                master.shutdown();
                Ok(beta)
            })
        };
        // workers (threads standing in for processes; the wire path is
        // identical)
        let worker_threads: Vec<_> = (0..5)
            .map(|w| std::thread::spawn(move || run_worker(listener_addr, w)))
            .collect();
        let beta = master_thread.join().unwrap().unwrap();
        assert!(beta.iter().any(|&b| b != 0.0), "training moved the params");
        for (w, h) in worker_threads.into_iter().enumerate() {
            let served = h.join().unwrap().unwrap();
            assert_eq!(served, 5, "worker {w} served all iterations");
        }
    }

    #[test]
    fn duplicate_worker_id_rejected() {
        let setup = test_setup(2, 0, 1);
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        drop(l);
        let master = std::thread::spawn(move || RemoteMaster::listen(addr, setup));
        // two workers claim id 0
        let w1 = std::thread::spawn(move || run_worker(addr, 0));
        std::thread::sleep(std::time::Duration::from_millis(100));
        let _w2 = std::thread::spawn(move || run_worker(addr, 0));
        let res = master.join().unwrap();
        assert!(res.is_err(), "duplicate id must fail the handshake");
        drop(w1);
    }

    #[test]
    fn scheme_from_setup_kinds() {
        let mut s = test_setup(4, 1, 1);
        assert_eq!(scheme_from_setup(&s).unwrap().config().d, 2);
        s.scheme_kind = SCHEME_RANDOM;
        assert!(scheme_from_setup(&s).is_ok());
        s.scheme_kind = SCHEME_UNCODED;
        assert_eq!(scheme_from_setup(&s).unwrap().config().d, 1);
        s.scheme_kind = 9;
        assert!(scheme_from_setup(&s).is_err());
    }

    #[test]
    fn scheme_from_setup_approx_kind() {
        let mut s = test_setup(8, 0, 1);
        s.scheme_kind = SCHEME_APPROX;
        s.d = 3;
        s.quorum = 6;
        let code = scheme_from_setup(&s).unwrap();
        assert_eq!(code.config().wait_for(), 6);
        assert_eq!(s.wait_for(), 6);
        // any 6-responder set decodes (approximately)
        assert!(code.decode_weights(&[0, 1, 2, 3, 4, 5]).is_ok());
        s.quorum = 0;
        assert!(scheme_from_setup(&s).is_err(), "approx needs an explicit quorum");
        s.quorum = 9;
        assert!(scheme_from_setup(&s).is_err());
    }

    #[test]
    fn scheme_from_setup_hetero_kind_rebuilds_and_validates() {
        let speeds = [1.0, 1.0, 1.0, 4.0, 4.0, 4.0];
        let reference = HeteroCode::from_speeds(6, 1, 1, &speeds).unwrap();
        let mut s = test_setup(6, 1, 1);
        s.scheme_kind = SCHEME_HETERO;
        s.d = reference.config().d as u32;
        s.speeds_milli = speeds.iter().map(|&x| (x * 1000.0).round() as u32).collect();
        s.loads = reference.loads().iter().map(|&d| d as u32).collect();
        let code = scheme_from_setup(&s).unwrap();
        // both sides agree on the placement
        for w in 0..6 {
            assert_eq!(code.placement().assigned(w), reference.placement().assigned(w));
        }
        assert_eq!(s.wait_for(), 5, "remote hetero waits the flat n - s");
        // tampered loads are rejected (heuristic drift across versions)
        s.loads[0] += 1;
        assert!(scheme_from_setup(&s).is_err());
        // missing speeds are rejected
        s.loads.clear();
        s.speeds_milli.clear();
        assert!(scheme_from_setup(&s).is_err());
    }

    /// Full loopback deployment of the heterogeneous scheme: kind-4
    /// Setup, weighted shards regenerated on both sides, exact decode
    /// against the local oracle.
    #[test]
    fn tcp_hetero_cluster_decodes_over_loopback() {
        let speeds = [1.0f64, 1.0, 1.0, 4.0, 4.0, 4.0];
        let reference = HeteroCode::from_speeds(6, 1, 1, &speeds).unwrap();
        let mut setup = test_setup(6, 1, 1);
        setup.scheme_kind = SCHEME_HETERO;
        setup.d = reference.config().d as u32;
        setup.speeds_milli =
            speeds.iter().map(|&x| (x * 1000.0).round() as u32).collect();
        setup.loads = reference.loads().iter().map(|&d| d as u32).collect();
        let listener_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = l.local_addr().unwrap();
            drop(l);
            addr
        };
        let master_thread = {
            let setup = setup.clone();
            std::thread::spawn(move || -> Result<()> {
                let mut master = RemoteMaster::listen(listener_addr, setup.clone())?;
                let code = scheme_from_setup(&setup)?;
                let train = dataset_from_setup(&setup);
                let backend = RustBackend::new(code.as_ref(), &train)?;
                let mut cache = HashMap::new();
                let beta = vec![0.005f32; setup.dim as usize];
                for iter in 0..3u64 {
                    let gather = master.run_iteration(iter, &beta)?;
                    assert_eq!(gather.results.len(), 5); // n - s
                    let grad = decode_gather(code.as_ref(), &gather, &mut cache)?;
                    let want = backend.full_gradient(iter as usize, &beta);
                    let scale =
                        want.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
                    for j in 0..grad.len() {
                        assert!(
                            (grad[j] - want[j]).abs() / scale < 1e-3,
                            "iter {iter} coord {j}"
                        );
                    }
                }
                master.shutdown();
                Ok(())
            })
        };
        let worker_threads: Vec<_> = (0..6)
            .map(|w| std::thread::spawn(move || run_worker(listener_addr, w)))
            .collect();
        master_thread.join().unwrap().unwrap();
        for h in worker_threads {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn dataset_from_setup_is_deterministic() {
        let s = test_setup(4, 1, 1);
        let a = dataset_from_setup(&s);
        let b = dataset_from_setup(&s);
        assert_eq!(a.x, b.x);
        assert_eq!(a.cols, 512);
    }
}
