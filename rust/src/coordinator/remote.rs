//! TCP deployment: master and workers as separate processes over real
//! sockets — the offline analogue of the paper's mpi4py EC2 deployment.
//!
//! - [`RemoteMaster`] listens, handshakes `n` workers (Hello → Setup),
//!   broadcasts `Task` frames each iteration and gathers `Result`s from
//!   the first `n - s` responders (arrival order — real network racing).
//! - [`run_worker`] is the worker process body: connect, receive Setup,
//!   rebuild scheme + data shard deterministically from the seeds, then
//!   serve the task loop until Shutdown. [`run_worker_chaos`] is the same
//!   body with a [`FaultPlan`] threaded through it.
//!
//! The data "distribution" step is seed-based regeneration (every worker
//! derives its shard from `data_seed`), standing in for the shared
//! filesystem / S3 load of the real deployment.
//!
//! Gathers are robust: per-connection reader threads classify wire
//! errors ([`WireError::Corrupt`] = frame-aligned, keep reading;
//! [`WireError::Io`] = connection gone), the gather loop runs against a
//! [`GatherPolicy`] deadline with bounded task re-sends, duplicate
//! deliveries are deduped, and a quorum that cannot be met returns a
//! partial [`RemoteGather`] with `complete = false` instead of blocking
//! on `recv()` forever (the pre-v3 master hung exactly there when a
//! worker disconnected mid-gather).

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::{ComputeBackend, RustBackend};
use super::trainer::SchemeSpec;
use super::wire::{
    Message, Setup, WireCounters, WireError, WorkerMetrics, MAGIC, SCHEME_APPROX,
    SCHEME_HETERO, SCHEME_POLY, SCHEME_RANDOM, SCHEME_UNCODED,
};
use crate::chaos::{Effect, FaultKind, FaultPlan, GatherPolicy};
use crate::coding::{ApproxCode, GradientCode, HeteroCode};
use crate::data::{CategoricalConfig, DenseDataset, SyntheticCategorical};
use crate::obs::{phase, Clock, Recorder};

/// Rebuild the scheme from a Setup frame (both sides do this, so encode
/// coefficients and decode weights agree without shipping matrices).
///
/// Kind 3 (approx) carries the replication in `d` and the responder
/// quorum in `quorum`. Kind 4 (hetero) ships the per-worker speed vector
/// (milli-units); both sides rebuild via the deterministic
/// [`HeteroCode::from_speeds`] heuristic and the shipped `loads` vector
/// cross-checks that master and worker agree on the placement.
pub fn scheme_from_setup(setup: &Setup) -> Result<std::sync::Arc<dyn GradientCode>> {
    let n = setup.n as usize;
    let spec = match setup.scheme_kind {
        SCHEME_POLY => SchemeSpec::Poly { s: setup.s as usize, m: setup.m as usize },
        SCHEME_RANDOM => SchemeSpec::Random {
            s: setup.s as usize,
            m: setup.m as usize,
            seed: setup.scheme_seed,
        },
        SCHEME_UNCODED => SchemeSpec::Uncoded,
        SCHEME_APPROX => {
            let quorum = setup.quorum as usize;
            if quorum == 0 || quorum > n {
                bail!("approx setup needs quorum in 1..={n}, got {quorum}");
            }
            let code = ApproxCode::new(n, setup.d as usize, quorum)?;
            return Ok(std::sync::Arc::new(code));
        }
        SCHEME_HETERO => {
            if setup.speeds_milli.len() != n {
                bail!(
                    "hetero setup needs {n} speeds, got {}",
                    setup.speeds_milli.len()
                );
            }
            let code = HeteroCode::from_speeds(
                n,
                setup.s as usize,
                setup.m as usize,
                &setup.speeds(),
            )?;
            if !setup.loads.is_empty() {
                let got: Vec<u32> = code.loads().iter().map(|&d| d as u32).collect();
                if got != setup.loads {
                    bail!(
                        "hetero load vector mismatch: setup says {:?}, rebuilt {:?} \
                         (master and worker must run the same scheme heuristic)",
                        setup.loads,
                        got
                    );
                }
            }
            return Ok(std::sync::Arc::new(code));
        }
        other => bail!("unknown scheme kind {other}"),
    };
    spec.build(n)
}

/// Regenerate the deterministic training set both sides agree on.
pub fn dataset_from_setup(setup: &Setup) -> DenseDataset {
    let gen = SyntheticCategorical::new(
        CategoricalConfig { columns: 10, cardinality: (16, 48), ..Default::default() },
        setup.data_seed,
    );
    gen.generate(setup.rows as usize, setup.data_seed + 1)
        .pad_cols(setup.dim as usize)
}

/// One gathered remote iteration.
#[derive(Debug)]
pub struct RemoteGather {
    /// (worker id, coded vector), in arrival order. When `complete`, the
    /// length is [`Setup::wait_for`] (`n - s`, or the approx scheme's
    /// quorum); otherwise it is whatever arrived before the deadline.
    pub results: Vec<(usize, Vec<f32>)>,
    /// Wall-clock seconds from broadcast to quorum (or deadline).
    pub elapsed: f64,
    /// Whether the quorum was reached. When false the caller must
    /// degrade (partial decode / stale gradient) or abort.
    pub complete: bool,
    /// Workers whose result frames failed the CRC32 check this iteration
    /// (one entry per rejected frame; the sender was treated as a
    /// straggler and re-prodded at most [`GatherPolicy::retries`] times).
    pub rejected: Vec<usize>,
}

/// What a per-connection reader thread observed.
enum ReaderEvent {
    Msg(Message),
    /// A frame failed validation; the stream is still aligned and the
    /// reader keeps going.
    Corrupt,
    /// The connection is gone; the reader exits after sending this.
    Closed,
}

/// Master side of the TCP deployment.
pub struct RemoteMaster {
    setup: Setup,
    policy: GatherPolicy,
    writers: Vec<BufWriter<TcpStream>>,
    /// Fan-in channel fed by per-connection reader threads.
    results: Receiver<(usize, ReaderEvent)>,
    /// Connections observed closed (persists across iterations).
    dead: Vec<bool>,
    /// Framed byte/frame accounting for everything this master sent and
    /// received (handshake included).
    counters: WireCounters,
    /// Telemetry recorder; disabled unless [`RemoteMaster::set_recorder`]
    /// was called.
    obs: Recorder,
    _reader_handles: Vec<std::thread::JoinHandle<()>>,
}

impl RemoteMaster {
    /// Bind, accept `setup.n` workers, handshake each.
    pub fn listen(addr: impl ToSocketAddrs, setup: Setup) -> Result<Self> {
        let listener = TcpListener::bind(addr).context("binding master socket")?;
        let mut writers: Vec<Option<BufWriter<TcpStream>>> =
            (0..setup.n).map(|_| None).collect();
        let (tx, rx) = channel();
        let mut handles = Vec::new();
        let mut counters = WireCounters::default();
        for _ in 0..setup.n {
            let (stream, peer) = listener.accept().context("accepting worker")?;
            stream.set_nodelay(true).ok();
            let mut reader = BufReader::new(stream.try_clone()?);
            // Handshake: Hello -> Setup.
            let hello = Message::read_from(&mut reader)?;
            counters.received(&hello);
            let worker_id = match hello {
                Message::Hello { magic, worker_id } if magic == MAGIC => worker_id as usize,
                Message::Hello { magic, .. } => bail!("bad magic {magic:#x} from {peer}"),
                other => bail!("expected Hello from {peer}, got {other:?}"),
            };
            if worker_id >= setup.n as usize {
                bail!("worker id {worker_id} out of range");
            }
            if writers[worker_id].is_some() {
                bail!("duplicate worker id {worker_id}");
            }
            let mut writer = BufWriter::new(stream);
            let setup_msg = Message::Setup(setup.clone());
            setup_msg.write_to(&mut writer)?;
            counters.sent(&setup_msg);
            writers[worker_id] = Some(writer);
            // Reader thread: pump events into the fan-in channel. Corrupt
            // frames are reported and skipped (the stream stays aligned);
            // an I/O error means the connection is gone.
            let tx: Sender<(usize, ReaderEvent)> = tx.clone();
            handles.push(std::thread::spawn(move || loop {
                let event = match Message::read_from(&mut reader) {
                    Ok(msg) => ReaderEvent::Msg(msg),
                    Err(WireError::Corrupt(_)) => ReaderEvent::Corrupt,
                    Err(WireError::Io(_)) => {
                        let _ = tx.send((worker_id, ReaderEvent::Closed));
                        return;
                    }
                };
                if tx.send((worker_id, event)).is_err() {
                    return;
                }
            }));
        }
        let n = setup.n as usize;
        let writers: Vec<BufWriter<TcpStream>> = writers
            .into_iter()
            .enumerate()
            .map(|(id, w)| {
                w.ok_or_else(|| anyhow::anyhow!("no connection recorded for worker {id}"))
            })
            .collect::<Result<_>>()?;
        Ok(RemoteMaster {
            setup,
            policy: GatherPolicy::default(),
            writers,
            results: rx,
            dead: vec![false; n],
            counters,
            obs: Recorder::disabled(),
            _reader_handles: handles,
        })
    }

    pub fn setup(&self) -> &Setup {
        &self.setup
    }

    /// Override the gather deadline / retry policy.
    pub fn set_gather_policy(&mut self, policy: GatherPolicy) {
        self.policy = policy;
    }

    /// Framed frame/byte totals for everything sent and received so far
    /// (handshake, tasks, results, re-sends, corrupt rejects).
    pub fn wire_counters(&self) -> &WireCounters {
        &self.counters
    }

    /// Attach a telemetry recorder: broadcast/gather spans, per-worker
    /// arrival latencies, and (at shutdown) the wire counters as
    /// `wire.*` gauges.
    pub fn set_recorder(&mut self, rec: &Recorder) {
        self.obs = rec.clone();
    }

    /// Broadcast an iteration and gather the first [`Setup::wait_for`]
    /// results.
    ///
    /// Runs against the [`GatherPolicy`]: the deadline is split into
    /// `retries + 1` waits; on each expiry the task is re-sent to every
    /// worker not yet heard from. A worker disconnecting mid-gather (the
    /// pre-v3 hang) or staying silent costs at most the deadline; the
    /// gather then returns partial results with `complete = false`.
    /// Corrupt result frames are rejected by checksum and the sender is
    /// re-prodded at most `retries` times, then counted as a straggler.
    pub fn run_iteration(&mut self, iter: u64, beta: &[f32]) -> Result<RemoteGather> {
        // lint: allow(wallclock-entropy) realized gather latency metric only; never feeds seeds or decisions
        let t0 = Instant::now();
        let ts0 = self.obs.now();
        let msg = Message::Task { iter, beta: beta.to_vec() };
        {
            let _b = self.obs.span(phase::BROADCAST).iter(iter);
            for w in self.writers.iter_mut() {
                // A dead connection = permanent straggler.
                if msg.write_to(w).is_ok() {
                    self.counters.sent(&msg);
                }
            }
        }
        let n = self.setup.n as usize;
        let quorum = self.setup.wait_for();
        let slice = self.policy.slice();
        let mut retries_left = self.policy.retries;
        let mut results: Vec<(usize, Vec<f32>)> = Vec::with_capacity(quorum);
        let mut rejected: Vec<usize> = Vec::new();
        let mut seen = vec![false; n];
        let mut resends = vec![0u32; n];
        let gather_span = self.obs.span(phase::GATHER_WAIT).iter(iter);
        while results.len() < quorum {
            match self.results.recv_timeout(slice) {
                Ok((wid, ReaderEvent::Msg(m))) => {
                    self.counters.received(&m);
                    match m {
                        Message::Result { iter: rit, failed, metrics, f, .. }
                            if rit == iter =>
                        {
                            if seen[wid] {
                                continue; // duplicate delivery
                            }
                            seen[wid] = true;
                            self.export_fleet_metrics(wid, &metrics);
                            if !failed {
                                self.obs.record_worker_response(
                                    wid,
                                    iter,
                                    ts0,
                                    t0.elapsed().as_secs_f64(),
                                    true,
                                    Clock::Wall,
                                );
                                results.push((wid, f));
                            }
                        }
                        Message::Result { .. } => continue, // stale iteration
                        other => {
                            bail!("unexpected message from worker {wid}: {other:?}")
                        }
                    }
                }
                Ok((wid, ReaderEvent::Corrupt)) => {
                    self.counters.rejected();
                    rejected.push(wid);
                    // Bounded re-prod: a deterministic corrupter would
                    // otherwise ping-pong forever.
                    if !seen[wid] && !self.dead[wid] && resends[wid] < self.policy.retries
                    {
                        resends[wid] += 1;
                        if msg.write_to(&mut self.writers[wid]).is_ok() {
                            self.counters.sent(&msg);
                        }
                    }
                }
                Ok((wid, ReaderEvent::Closed)) => {
                    self.dead[wid] = true;
                    if self.dead.iter().all(|&d| d) {
                        bail!("all worker connections closed before quorum");
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    if retries_left == 0 {
                        break; // deadline spent: degrade with what we have
                    }
                    retries_left -= 1;
                    std::thread::sleep(self.policy.backoff);
                    for w in 0..n {
                        if !seen[w] && !self.dead[w]
                            && msg.write_to(&mut self.writers[w]).is_ok()
                        {
                            self.counters.sent(&msg);
                        }
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    bail!("all reader threads exited")
                }
            }
            // Everyone accounted for and still short: no point waiting out
            // the deadline (covers > s backend failures / closed peers).
            if results.len() < quorum
                && (0..n).all(|w| seen[w] || self.dead[w])
            {
                break;
            }
        }
        drop(gather_span);
        if self.obs.is_enabled() {
            for (w, &heard) in seen.iter().enumerate() {
                if !heard {
                    self.obs.worker_missed(w, iter);
                }
            }
        }
        let complete = results.len() >= quorum;
        // Refresh the live wire.* gauges every iteration so a mid-run
        // scrape of the metrics endpoint agrees with the end-of-run
        // totals (no-op when the recorder is disabled; cumulative
        // counters are overwritten, never accumulated twice).
        self.counters.export(&self.obs, "wire");
        Ok(RemoteGather {
            results,
            elapsed: t0.elapsed().as_secs_f64(),
            complete,
            rejected,
        })
    }

    /// Mirror a worker's piggybacked v4 metrics block into per-worker
    /// `fleet.worker.<id>.<field>` gauges (the metrics registry folds
    /// these into one labeled Prometheus family per field). The block
    /// carries cumulative totals, so overwriting is correct.
    fn export_fleet_metrics(&self, wid: usize, m: &WorkerMetrics) {
        if !self.obs.is_enabled() {
            return;
        }
        let fields: [(&str, i64); 5] = [
            ("compute_us", m.compute_us as i64),
            ("tx_bytes", m.tx_bytes as i64),
            ("rx_bytes", m.rx_bytes as i64),
            ("faults", m.faults as i64),
            ("iters_served", m.iters_served as i64),
        ];
        for (field, value) in fields {
            self.obs.set(&format!("fleet.worker.{wid}.{field}"), value);
        }
    }

    /// Send Shutdown to everyone.
    pub fn shutdown(mut self) {
        let msg = Message::Shutdown;
        for w in self.writers.iter_mut() {
            if msg.write_to(w).is_ok() {
                self.counters.sent(&msg);
            }
        }
        // Final counter snapshot into the telemetry stream (no-op when
        // the recorder is disabled).
        self.counters.export(&self.obs, "wire");
    }
}

/// Read the next valid frame, logging and skipping corrupt ones (the
/// stream is still aligned after a checksum failure). Valid frames and
/// corrupt skips both land in `counters`.
fn read_skip_corrupt(
    r: &mut impl Read,
    counters: &mut WireCounters,
) -> Result<Message, WireError> {
    loop {
        match Message::read_from(r) {
            Err(WireError::Corrupt(why)) => {
                counters.rejected();
                eprintln!("skipping corrupt frame: {why}");
            }
            Ok(msg) => {
                counters.received(&msg);
                return Ok(msg);
            }
            other => return other,
        }
    }
}

/// Worker process body: connect to the master and serve until Shutdown.
/// Returns the number of tasks served.
pub fn run_worker(addr: impl ToSocketAddrs, worker_id: usize) -> Result<usize> {
    run_worker_traced(addr, worker_id, None, &Recorder::disabled())
}

/// [`run_worker`] with a fault plan: before answering each task the
/// worker consults `plan.effect(worker_id, iter)` and crashes, drops,
/// corrupts (one payload byte of the encoded frame — the master's CRC32
/// catches it), duplicates, delays, or hard-resets accordingly.
pub fn run_worker_chaos(
    addr: impl ToSocketAddrs,
    worker_id: usize,
    chaos: Option<FaultPlan>,
) -> Result<usize> {
    run_worker_traced(addr, worker_id, chaos, &Recorder::disabled())
}

/// [`run_worker_chaos`] with a telemetry recorder: compute spans per
/// task (tagged with this worker id), `wire.*` frame/byte gauges on
/// exit, and fault instants for injected effects.
pub fn run_worker_traced(
    addr: impl ToSocketAddrs,
    worker_id: usize,
    chaos: Option<FaultPlan>,
    rec: &Recorder,
) -> Result<usize> {
    let mut counters = WireCounters::default();
    let stream = TcpStream::connect(addr).context("connecting to master")?;
    stream.set_nodelay(true).ok();
    let raw = stream.try_clone()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let hello = Message::Hello { magic: MAGIC, worker_id: worker_id as u32 };
    hello.write_to(&mut writer)?;
    counters.sent(&hello);
    let setup_msg = Message::read_from(&mut reader)?;
    counters.received(&setup_msg);
    let setup = match setup_msg {
        Message::Setup(s) => s,
        other => bail!("expected Setup, got {other:?}"),
    };
    let code = scheme_from_setup(&setup)?;
    let train = dataset_from_setup(&setup);
    let backend = RustBackend::new(code.as_ref(), &train)?;

    let mut served = 0usize;
    // Cumulative totals piggybacked on every v4 Result frame.
    let mut compute_us = 0u64;
    let mut faults_seen = 0u32;
    let mut out = Vec::new();
    loop {
        match read_skip_corrupt(&mut reader, &mut counters)? {
            Message::Task { iter, beta } => {
                let effect =
                    chaos.as_ref().map_or(Effect::None, |p| p.effect(worker_id, iter));
                if let Effect::Fault(k) = &effect {
                    faults_seen = faults_seen.saturating_add(1);
                    if rec.is_enabled() {
                        rec.instant(
                            &format!("fault:{}", k.label()),
                            Some(worker_id),
                            Some(iter),
                        );
                    }
                }
                match effect {
                    Effect::Fault(FaultKind::Reset) => {
                        // Hard reset: slam the socket, no goodbye.
                        let _ = raw.shutdown(std::net::Shutdown::Both);
                        counters.export(rec, "wire");
                        return Ok(served);
                    }
                    e if e.is_silent() => continue, // crash window / drop
                    _ => {}
                }
                if let Effect::Fault(FaultKind::Delay(secs)) = effect {
                    std::thread::sleep(std::time::Duration::from_secs_f64(secs));
                }
                let compute_span =
                    rec.span(phase::WORKER_COMPUTE).worker(worker_id).iter(iter);
                // lint: allow(wallclock-entropy) cumulative compute-time metric only; never feeds seeds or decisions
                let tc = Instant::now();
                let failed =
                    backend.encoded_gradient(worker_id, iter as usize, &beta, &mut out).is_err();
                compute_us =
                    compute_us.saturating_add(tc.elapsed().as_micros() as u64);
                drop(compute_span);
                served += 1;
                let msg = Message::Result {
                    worker: worker_id as u32,
                    iter,
                    failed,
                    // Totals at send time (this Result's own framed bytes
                    // land in the *next* block — the snapshot stays
                    // consistent with what the wire actually carried).
                    metrics: WorkerMetrics {
                        compute_us,
                        tx_bytes: counters.tx_bytes,
                        rx_bytes: counters.rx_bytes,
                        faults: faults_seen,
                        iters_served: served as u32,
                    },
                    f: if failed { Vec::new() } else { out.clone() },
                };
                match effect {
                    Effect::Fault(FaultKind::Corrupt) => {
                        // Flip one payload byte after framing; the CRC in
                        // the trailer still covers the original bytes, so
                        // the master must reject this frame.
                        let mut frame = msg.encode();
                        let plen = u32::from_le_bytes([
                            frame[0], frame[1], frame[2], frame[3],
                        ]) as usize;
                        frame[5 + plen / 2] ^= 0x04;
                        writer.write_all(&frame)?;
                        writer.flush()?;
                        counters.sent(&msg); // same framed length, corrupted
                    }
                    Effect::Fault(FaultKind::Duplicate) => {
                        msg.write_to(&mut writer)?;
                        counters.sent(&msg);
                        msg.write_to(&mut writer)?;
                        counters.sent(&msg);
                    }
                    _ => {
                        msg.write_to(&mut writer)?;
                        counters.sent(&msg);
                    }
                }
            }
            Message::Shutdown => {
                counters.export(rec, "wire");
                return Ok(served);
            }
            other => bail!("unexpected message: {other:?}"),
        }
    }
}

/// Decode helper for the master: reconstruct the sum gradient from a
/// remote gather (arrival-ordered responder list).
pub fn decode_gather(
    code: &dyn GradientCode,
    gather: &RemoteGather,
    cache: &mut HashMap<u64, crate::coding::Decoder>,
) -> Result<Vec<f32>> {
    let mut responders: Vec<usize> = gather.results.iter().map(|(w, _)| *w).collect();
    responders.sort_unstable();
    let key = responders.iter().fold(0u64, |acc, &w| acc | (1 << w));
    if !cache.contains_key(&key) {
        cache.insert(key, crate::coding::Decoder::new(code, &responders)?);
    }
    let dec = &cache[&key];
    let by_worker: HashMap<usize, &[f32]> =
        gather.results.iter().map(|(w, f)| (*w, f.as_slice())).collect();
    let fs: Vec<&[f32]> =
        dec.used_workers().iter().map(|w| by_worker[w]).collect();
    Ok(dec.decode(&fs)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn test_setup(n: u32, s: u32, m: u32) -> Setup {
        Setup::homogeneous(n, s + m, s, m, SCHEME_POLY, 1, 777, n * 16, 512)
    }

    fn free_addr() -> std::net::SocketAddr {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        drop(l);
        addr
    }

    /// Full multi-"process" deployment over loopback TCP: one master,
    /// n worker bodies on threads, real sockets, real decode.
    #[test]
    fn tcp_cluster_trains_over_loopback() {
        let setup = test_setup(5, 1, 2);
        let listener_addr = free_addr();
        let master_thread = {
            let setup = setup;
            std::thread::spawn(move || -> Result<Vec<f32>> {
                let mut master = RemoteMaster::listen(listener_addr, setup.clone())?;
                let rec = Recorder::enabled();
                master.set_recorder(&rec);
                let code = scheme_from_setup(&setup)?;
                let train = dataset_from_setup(&setup);
                let backend = RustBackend::new(code.as_ref(), &train)?;
                let mut cache = HashMap::new();
                let mut beta = vec![0.0f32; setup.dim as usize];
                let lr = 4.0 / train.rows as f32;
                for iter in 0..5u64 {
                    let gather = master.run_iteration(iter, &beta)?;
                    assert!(gather.complete);
                    assert!(gather.rejected.is_empty());
                    assert_eq!(gather.results.len(), 4); // n - s
                    let grad = decode_gather(code.as_ref(), &gather, &mut cache)?;
                    // cross-check against the local oracle
                    let want = backend.full_gradient(iter as usize, &beta);
                    let scale =
                        want.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
                    for j in 0..grad.len() {
                        assert!(
                            (grad[j] - want[j]).abs() / scale < 1e-3,
                            "iter {iter} coord {j}"
                        );
                    }
                    for (b, g) in beta.iter_mut().zip(&grad) {
                        *b -= lr * g;
                    }
                }
                // Wire accounting: 5 Setups out, 5 Tasks per iteration
                // out; 5 Hellos in plus every Result the gather drained
                // (the final iteration's straggler may stay queued).
                let wc = *master.wire_counters();
                assert_eq!(wc.corrupt_rejects, 0);
                assert_eq!(wc.tx_frames, 5 + 5 * 5, "Setups + Tasks");
                assert!(wc.rx_frames >= 5 + 5 * 4, "Hellos + quorum Results");
                assert!(
                    wc.rx_bytes > wc.rx_frames * 9,
                    "framed bytes exceed bare frame overhead"
                );
                master.shutdown();
                // Telemetry: one broadcast/gather span per iteration and
                // 4 used + 1 missed response per iteration; shutdown
                // exported the wire gauges (5 Shutdowns on top).
                let summary = rec.summary();
                for ph in [phase::BROADCAST, phase::GATHER_WAIT] {
                    let st =
                        summary.phases.iter().find(|p| p.phase == ph).unwrap();
                    assert_eq!(st.count, 5, "{ph}");
                }
                let used: u64 =
                    summary.stragglers.workers.iter().map(|w| w.used).sum();
                let missed: u64 =
                    summary.stragglers.workers.iter().map(|w| w.missed).sum();
                assert_eq!(used, 20);
                assert_eq!(missed, 5);
                assert!(summary
                    .counters
                    .iter()
                    .any(|(k, v)| k == "wire.tx_frames" && *v == 35));
                Ok(beta)
            })
        };
        // workers (threads standing in for processes; the wire path is
        // identical)
        let worker_threads: Vec<_> = (0..5)
            .map(|w| std::thread::spawn(move || run_worker(listener_addr, w)))
            .collect();
        let beta = master_thread.join().unwrap().unwrap();
        assert!(beta.iter().any(|&b| b != 0.0), "training moved the params");
        for (w, h) in worker_threads.into_iter().enumerate() {
            let served = h.join().unwrap().unwrap();
            assert_eq!(served, 5, "worker {w} served all iterations");
        }
    }

    /// The pre-v3 master blocked forever on `recv()` when a worker
    /// disconnected mid-gather; the deadline now returns a partial
    /// gather with `complete = false` in bounded time.
    #[test]
    fn gather_returns_partial_when_a_worker_disconnects_mid_gather() {
        let setup = test_setup(2, 0, 1); // quorum = n = 2: the ghost is needed
        let listener_addr = free_addr();
        let master_thread = {
            let setup = setup.clone();
            std::thread::spawn(move || -> Result<()> {
                let mut master = RemoteMaster::listen(listener_addr, setup.clone())?;
                master.set_gather_policy(GatherPolicy {
                    deadline: Duration::from_millis(400),
                    retries: 1,
                    backoff: Duration::from_millis(1),
                });
                let beta = vec![0.0f32; setup.dim as usize];
                // lint: allow(wallclock-entropy) realized gather latency metric only; never feeds seeds or decisions
        let t0 = Instant::now();
                let g = master.run_iteration(0, &beta)?;
                assert!(!g.complete, "quorum 2 is unreachable with a ghost worker");
                assert_eq!(g.results.len(), 1);
                assert!(
                    t0.elapsed() < Duration::from_secs(5),
                    "gather must end at the deadline, not hang"
                );
                master.shutdown();
                Ok(())
            })
        };
        let real = std::thread::spawn(move || run_worker(listener_addr, 0));
        let ghost = std::thread::spawn(move || {
            let stream = TcpStream::connect(listener_addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = BufWriter::new(stream);
            Message::Hello { magic: MAGIC, worker_id: 1 }.write_to(&mut writer).unwrap();
            let setup = Message::read_from(&mut reader).unwrap();
            assert!(matches!(setup, Message::Setup(_)));
            // vanish without a word — the old gather blocked forever here
        });
        master_thread.join().unwrap().unwrap();
        ghost.join().unwrap();
        let served = real.join().unwrap().unwrap();
        assert!(served <= 2, "at most the original task and one re-send");
    }

    #[test]
    fn duplicate_worker_id_rejected() {
        let setup = test_setup(2, 0, 1);
        let addr = free_addr();
        let master = std::thread::spawn(move || RemoteMaster::listen(addr, setup));
        // two workers claim id 0
        let w1 = std::thread::spawn(move || run_worker(addr, 0));
        std::thread::sleep(std::time::Duration::from_millis(100));
        let _w2 = std::thread::spawn(move || run_worker(addr, 0));
        let res = master.join().unwrap();
        assert!(res.is_err(), "duplicate id must fail the handshake");
        drop(w1);
    }

    #[test]
    fn scheme_from_setup_kinds() {
        let mut s = test_setup(4, 1, 1);
        assert_eq!(scheme_from_setup(&s).unwrap().config().d, 2);
        s.scheme_kind = SCHEME_RANDOM;
        assert!(scheme_from_setup(&s).is_ok());
        s.scheme_kind = SCHEME_UNCODED;
        assert_eq!(scheme_from_setup(&s).unwrap().config().d, 1);
        s.scheme_kind = 9;
        assert!(scheme_from_setup(&s).is_err());
    }

    #[test]
    fn scheme_from_setup_approx_kind() {
        let mut s = test_setup(8, 0, 1);
        s.scheme_kind = SCHEME_APPROX;
        s.d = 3;
        s.quorum = 6;
        let code = scheme_from_setup(&s).unwrap();
        assert_eq!(code.config().wait_for(), 6);
        assert_eq!(s.wait_for(), 6);
        // any 6-responder set decodes (approximately)
        assert!(code.decode_weights(&[0, 1, 2, 3, 4, 5]).is_ok());
        s.quorum = 0;
        assert!(scheme_from_setup(&s).is_err(), "approx needs an explicit quorum");
        s.quorum = 9;
        assert!(scheme_from_setup(&s).is_err());
    }

    #[test]
    fn scheme_from_setup_hetero_kind_rebuilds_and_validates() {
        let speeds = [1.0, 1.0, 1.0, 4.0, 4.0, 4.0];
        let reference = HeteroCode::from_speeds(6, 1, 1, &speeds).unwrap();
        let mut s = test_setup(6, 1, 1);
        s.scheme_kind = SCHEME_HETERO;
        s.d = reference.config().d as u32;
        s.speeds_milli = speeds.iter().map(|&x| (x * 1000.0).round() as u32).collect();
        s.loads = reference.loads().iter().map(|&d| d as u32).collect();
        let code = scheme_from_setup(&s).unwrap();
        // both sides agree on the placement
        for w in 0..6 {
            assert_eq!(code.placement().assigned(w), reference.placement().assigned(w));
        }
        assert_eq!(s.wait_for(), 5, "remote hetero waits the flat n - s");
        // tampered loads are rejected (heuristic drift across versions)
        s.loads[0] += 1;
        assert!(scheme_from_setup(&s).is_err());
        // missing speeds are rejected
        s.loads.clear();
        s.speeds_milli.clear();
        assert!(scheme_from_setup(&s).is_err());
    }

    /// Full loopback deployment of the heterogeneous scheme: kind-4
    /// Setup, weighted shards regenerated on both sides, exact decode
    /// against the local oracle.
    #[test]
    fn tcp_hetero_cluster_decodes_over_loopback() {
        let speeds = [1.0f64, 1.0, 1.0, 4.0, 4.0, 4.0];
        let reference = HeteroCode::from_speeds(6, 1, 1, &speeds).unwrap();
        let mut setup = test_setup(6, 1, 1);
        setup.scheme_kind = SCHEME_HETERO;
        setup.d = reference.config().d as u32;
        setup.speeds_milli =
            speeds.iter().map(|&x| (x * 1000.0).round() as u32).collect();
        setup.loads = reference.loads().iter().map(|&d| d as u32).collect();
        let listener_addr = free_addr();
        let master_thread = {
            let setup = setup.clone();
            std::thread::spawn(move || -> Result<()> {
                let mut master = RemoteMaster::listen(listener_addr, setup.clone())?;
                let code = scheme_from_setup(&setup)?;
                let train = dataset_from_setup(&setup);
                let backend = RustBackend::new(code.as_ref(), &train)?;
                let mut cache = HashMap::new();
                let beta = vec![0.005f32; setup.dim as usize];
                for iter in 0..3u64 {
                    let gather = master.run_iteration(iter, &beta)?;
                    assert_eq!(gather.results.len(), 5); // n - s
                    let grad = decode_gather(code.as_ref(), &gather, &mut cache)?;
                    let want = backend.full_gradient(iter as usize, &beta);
                    let scale =
                        want.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
                    for j in 0..grad.len() {
                        assert!(
                            (grad[j] - want[j]).abs() / scale < 1e-3,
                            "iter {iter} coord {j}"
                        );
                    }
                }
                master.shutdown();
                Ok(())
            })
        };
        let worker_threads: Vec<_> = (0..6)
            .map(|w| std::thread::spawn(move || run_worker(listener_addr, w)))
            .collect();
        master_thread.join().unwrap().unwrap();
        for h in worker_threads {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn dataset_from_setup_is_deterministic() {
        let s = test_setup(4, 1, 1);
        let a = dataset_from_setup(&s);
        let b = dataset_from_setup(&s);
        assert_eq!(a.x, b.x);
        assert_eq!(a.cols, 512);
    }
}
