//! Wire protocol for the TCP transport (multi-process deployment).
//!
//! The paper ran master and workers as MPI ranks over a real network;
//! this module is the equivalent seam: a small length-prefixed binary
//! protocol (no serde available offline). All integers are little-endian.
//!
//! Frame:  `u32 payload_len | u8 tag | payload`
//!
//! Messages:
//! - `Hello { worker_id }`                        worker → master
//! - `Setup { n, d, s, m, scheme, seeds, rows, dim, quorum, loads[],
//!            speeds_milli[] }`                   master → worker
//! - `Task { iter, beta[f32; dim] }`              master → worker
//! - `Result { worker, iter, failed, f[f32] }`    worker → master
//! - `Shutdown`                                   master → worker
//!
//! Protocol v2 extends Setup with the partial-recovery quorum (scheme
//! kind 3) and the per-worker load + speed vectors of the heterogeneous
//! scheme (kind 4); the magic was bumped so v1 peers fail the handshake
//! loudly instead of misparsing frames.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// Protocol magic, checked in the Hello frame.
pub const MAGIC: u32 = 0x6743_0002; // "gC" v2

const TAG_HELLO: u8 = 1;
const TAG_SETUP: u8 = 2;
const TAG_TASK: u8 = 3;
const TAG_RESULT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;

/// `Setup::scheme_kind` values.
pub const SCHEME_POLY: u8 = 0;
pub const SCHEME_RANDOM: u8 = 1;
pub const SCHEME_UNCODED: u8 = 2;
pub const SCHEME_APPROX: u8 = 3;
pub const SCHEME_HETERO: u8 = 4;

/// Maximum accepted payload (guards against corrupt frames).
const MAX_PAYLOAD: usize = 1 << 30;

/// A decoded protocol message.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    Hello { magic: u32, worker_id: u32 },
    Setup(Setup),
    Task { iter: u64, beta: Vec<f32> },
    Result { worker: u32, iter: u64, failed: bool, f: Vec<f32> },
    Shutdown,
}

/// Scheme + data configuration sent to each worker at handshake. Workers
/// regenerate their shard deterministically from `data_seed` (the
/// stand-in for "load your shard from shared storage").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Setup {
    pub n: u32,
    pub d: u32,
    pub s: u32,
    pub m: u32,
    /// [`SCHEME_POLY`] | [`SCHEME_RANDOM`] | [`SCHEME_UNCODED`] |
    /// [`SCHEME_APPROX`] | [`SCHEME_HETERO`].
    pub scheme_kind: u8,
    pub scheme_seed: u64,
    pub data_seed: u64,
    pub rows: u32,
    pub dim: u32,
    /// Responders the master proceeds at ([`SCHEME_APPROX`] only; for
    /// the approximate scheme `d` is the replication factor and `s` is
    /// redundant). 0 everywhere else.
    pub quorum: u32,
    /// Per-worker subset loads `d_w` ([`SCHEME_HETERO`] only; workers
    /// verify the scheme they rebuilt from the speeds matches). Empty
    /// otherwise.
    pub loads: Vec<u32>,
    /// Per-worker relative speeds in milli-units (speed × 1000,
    /// [`SCHEME_HETERO`] only). Integers keep the frame `Eq` and make
    /// master/worker scheme reconstruction bit-identical. Empty
    /// otherwise.
    pub speeds_milli: Vec<u32>,
}

impl Setup {
    /// A homogeneous-scheme Setup (kinds 0–2) with the v2 fields empty.
    pub fn homogeneous(
        n: u32,
        d: u32,
        s: u32,
        m: u32,
        scheme_kind: u8,
        scheme_seed: u64,
        data_seed: u64,
        rows: u32,
        dim: u32,
    ) -> Self {
        Setup {
            n,
            d,
            s,
            m,
            scheme_kind,
            scheme_seed,
            data_seed,
            rows,
            dim,
            quorum: 0,
            loads: Vec::new(),
            speeds_milli: Vec::new(),
        }
    }

    /// Responders the master gathers before decoding: the approximate
    /// scheme's quorum, or `n - s` for every exact scheme (the remote
    /// master uses the flat rule — always decodable — rather than the
    /// in-process per-group early stop).
    pub fn wait_for(&self) -> usize {
        if self.scheme_kind == SCHEME_APPROX && self.quorum > 0 {
            self.quorum as usize
        } else {
            (self.n - self.s) as usize
        }
    }

    /// Per-worker speeds decoded from the milli-unit wire form.
    pub fn speeds(&self) -> Vec<f64> {
        self.speeds_milli.iter().map(|&x| x as f64 / 1000.0).collect()
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame: need {n} at {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, count: usize) -> Result<Vec<f32>> {
        let raw = self.take(count * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes in frame", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

impl Message {
    /// Encode as a full frame (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        let tag = match self {
            Message::Hello { magic, worker_id } => {
                payload.extend_from_slice(&magic.to_le_bytes());
                payload.extend_from_slice(&worker_id.to_le_bytes());
                TAG_HELLO
            }
            Message::Setup(s) => {
                for v in [s.n, s.d, s.s, s.m] {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
                payload.push(s.scheme_kind);
                payload.extend_from_slice(&s.scheme_seed.to_le_bytes());
                payload.extend_from_slice(&s.data_seed.to_le_bytes());
                payload.extend_from_slice(&s.rows.to_le_bytes());
                payload.extend_from_slice(&s.dim.to_le_bytes());
                payload.extend_from_slice(&s.quorum.to_le_bytes());
                for list in [&s.loads, &s.speeds_milli] {
                    payload.extend_from_slice(&(list.len() as u32).to_le_bytes());
                    for v in list {
                        payload.extend_from_slice(&v.to_le_bytes());
                    }
                }
                TAG_SETUP
            }
            Message::Task { iter, beta } => {
                payload.extend_from_slice(&iter.to_le_bytes());
                put_f32s(&mut payload, beta);
                TAG_TASK
            }
            Message::Result { worker, iter, failed, f } => {
                payload.extend_from_slice(&worker.to_le_bytes());
                payload.extend_from_slice(&iter.to_le_bytes());
                payload.push(u8::from(*failed));
                put_f32s(&mut payload, f);
                TAG_RESULT
            }
            Message::Shutdown => TAG_SHUTDOWN,
        };
        let mut frame = Vec::with_capacity(payload.len() + 5);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.push(tag);
        frame.extend_from_slice(&payload);
        frame
    }

    /// Decode one message from tag + payload.
    fn decode(tag: u8, payload: &[u8]) -> Result<Message> {
        let mut c = Cursor::new(payload);
        let msg = match tag {
            TAG_HELLO => Message::Hello { magic: c.u32()?, worker_id: c.u32()? },
            TAG_SETUP => {
                let n = c.u32()?;
                let d = c.u32()?;
                let s = c.u32()?;
                let m = c.u32()?;
                let scheme_kind = c.u8()?;
                let scheme_seed = c.u64()?;
                let data_seed = c.u64()?;
                let rows = c.u32()?;
                let dim = c.u32()?;
                let quorum = c.u32()?;
                let mut lists = [Vec::new(), Vec::new()];
                for list in &mut lists {
                    let len = c.u32()? as usize;
                    if len > n as usize {
                        bail!("setup vector of {len} entries exceeds n = {n}");
                    }
                    *list = (0..len).map(|_| c.u32()).collect::<Result<_>>()?;
                }
                let [loads, speeds_milli] = lists;
                Message::Setup(Setup {
                    n,
                    d,
                    s,
                    m,
                    scheme_kind,
                    scheme_seed,
                    data_seed,
                    rows,
                    dim,
                    quorum,
                    loads,
                    speeds_milli,
                })
            }
            TAG_TASK => {
                let iter = c.u64()?;
                let remaining = payload.len() - 8;
                if remaining % 4 != 0 {
                    bail!("task payload not f32-aligned");
                }
                Message::Task { iter, beta: c.f32s(remaining / 4)? }
            }
            TAG_RESULT => {
                let worker = c.u32()?;
                let iter = c.u64()?;
                let failed = c.u8()? != 0;
                let remaining = payload.len() - 13;
                if remaining % 4 != 0 {
                    bail!("result payload not f32-aligned");
                }
                Message::Result { worker, iter, failed, f: c.f32s(remaining / 4)? }
            }
            TAG_SHUTDOWN => Message::Shutdown,
            other => bail!("unknown message tag {other}"),
        };
        c.done()?;
        Ok(msg)
    }

    /// Write a full frame to a stream.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&self.encode()).context("writing frame")?;
        w.flush().context("flushing frame")
    }

    /// Read one full frame from a stream.
    pub fn read_from(r: &mut impl Read) -> Result<Message> {
        let mut header = [0u8; 5];
        r.read_exact(&mut header).context("reading frame header")?;
        let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
        let tag = header[4];
        if len > MAX_PAYLOAD {
            bail!("frame too large: {len}");
        }
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload).context("reading frame payload")?;
        Message::decode(tag, &payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let frame = msg.encode();
        let mut cursor = std::io::Cursor::new(frame);
        let back = Message::read_from(&mut cursor).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Message::Hello { magic: MAGIC, worker_id: 3 });
        roundtrip(Message::Setup(Setup::homogeneous(10, 3, 1, 2, 0, 7, 99, 640, 512)));
        // v2 fields: approx quorum and hetero load/speed vectors
        roundtrip(Message::Setup(Setup {
            quorum: 6,
            ..Setup::homogeneous(8, 3, 2, 1, SCHEME_APPROX, 7, 99, 640, 512)
        }));
        roundtrip(Message::Setup(Setup {
            loads: vec![3, 3, 3, 5, 5],
            speeds_milli: vec![1000, 1000, 1000, 4000, 4000],
            ..Setup::homogeneous(5, 5, 1, 2, SCHEME_HETERO, 7, 99, 640, 512)
        }));
        roundtrip(Message::Task { iter: 42, beta: vec![1.5, -2.25, 0.0] });
        roundtrip(Message::Result {
            worker: 9,
            iter: 42,
            failed: false,
            f: vec![0.125; 7],
        });
        roundtrip(Message::Result { worker: 1, iter: 0, failed: true, f: vec![] });
        roundtrip(Message::Shutdown);
    }

    #[test]
    fn setup_wait_for_covers_all_kinds() {
        let exact = Setup::homogeneous(10, 3, 2, 1, SCHEME_POLY, 1, 2, 64, 32);
        assert_eq!(exact.wait_for(), 8);
        let approx = Setup {
            quorum: 6,
            ..Setup::homogeneous(10, 3, 0, 1, SCHEME_APPROX, 1, 2, 64, 32)
        };
        assert_eq!(approx.wait_for(), 6);
        let hetero = Setup {
            loads: vec![2; 10],
            speeds_milli: vec![1000; 10],
            ..Setup::homogeneous(10, 2, 1, 1, SCHEME_HETERO, 1, 2, 64, 32)
        };
        assert_eq!(hetero.wait_for(), 9, "remote hetero keeps the flat n - s rule");
        assert_eq!(hetero.speeds(), vec![1.0; 10]);
    }

    #[test]
    fn oversized_setup_vector_rejected() {
        let msg = Message::Setup(Setup {
            loads: vec![1; 4],
            ..Setup::homogeneous(4, 1, 0, 1, SCHEME_HETERO, 1, 2, 64, 32)
        });
        let mut frame = msg.encode();
        // Corrupt the loads length (offset: 4 hdr + 1 tag + 16 + 1 + 16 +
        // 8 + 4 = payload offset 45 → frame offset 50) to exceed n.
        let len_off = 5 + 4 * 4 + 1 + 8 + 8 + 4 + 4 + 4;
        frame[len_off] = 200;
        let mut cursor = std::io::Cursor::new(frame);
        assert!(Message::read_from(&mut cursor).is_err());
    }

    #[test]
    fn f32_payload_is_exact() {
        let beta: Vec<f32> = (0..100).map(|i| (i as f32).exp() * 1e-3).collect();
        let msg = Message::Task { iter: 1, beta: beta.clone() };
        let mut cursor = std::io::Cursor::new(msg.encode());
        match Message::read_from(&mut cursor).unwrap() {
            Message::Task { beta: got, .. } => assert_eq!(got, beta),
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn truncated_frame_errors() {
        let frame = Message::Shutdown.encode();
        let cursor = std::io::Cursor::new(&frame[..frame.len() - 1]);
        // shutdown has empty payload; truncate the header instead
        let mut short = std::io::Cursor::new(&frame[..3]);
        assert!(Message::read_from(&mut short).is_err());
        let _ = cursor; // (full shutdown frame is 5 bytes header only)
    }

    #[test]
    fn unknown_tag_errors() {
        let mut frame = Message::Shutdown.encode();
        frame[4] = 250;
        let mut cursor = std::io::Cursor::new(frame);
        assert!(Message::read_from(&mut cursor).is_err());
    }

    #[test]
    fn misaligned_task_errors() {
        // 5-byte payload after iter: not a multiple of 4
        let mut payload = 7u64.to_le_bytes().to_vec();
        payload.extend_from_slice(&[1, 2, 3]);
        let mut frame = (payload.len() as u32).to_le_bytes().to_vec();
        frame.push(3); // TAG_TASK
        frame.extend_from_slice(&payload);
        let mut cursor = std::io::Cursor::new(frame);
        assert!(Message::read_from(&mut cursor).is_err());
    }
}
