//! Wire protocol for the TCP transport (multi-process deployment).
//!
//! The paper ran master and workers as MPI ranks over a real network;
//! this module is the equivalent seam: a small length-prefixed binary
//! protocol (no serde available offline). All integers are little-endian.
//!
//! Frame:  `u32 payload_len | u8 tag | payload | u32 crc32(tag ++ payload)`
//!
//! Messages:
//! - `Hello { worker_id }`                        worker → master
//! - `Setup { n, d, s, m, scheme, seeds, rows, dim, quorum, loads[],
//!            speeds_milli[] }`                   master → worker
//! - `Task { iter, beta[f32; dim] }`              master → worker
//! - `Result { worker, iter, failed, metrics, f[f32] }` worker → master
//! - `Shutdown`                                   master → worker
//!
//! Protocol v2 extended Setup with the partial-recovery quorum (scheme
//! kind 3) and the per-worker load + speed vectors of the heterogeneous
//! scheme (kind 4). Protocol v3 appends an IEEE CRC32 over `tag ++
//! payload` to every frame so in-flight corruption is detected instead
//! of decoded into garbage; the magic was bumped again so v2 peers fail
//! the handshake loudly instead of misparsing frames. Protocol v4
//! inserts a fixed-layout [`WorkerMetrics`] block (compute µs, bytes
//! tx/rx, faults seen, iterations served) between the Result header and
//! the gradient floats, so fleet metrics piggyback on frames the worker
//! sends anyway — no extra round trips for live observability.
//!
//! Errors are the typed [`WireError`]: [`WireError::Corrupt`] means the
//! frame arrived whole but failed validation (bad checksum, bad tag,
//! malformed payload) and — crucially — the stream is still
//! frame-aligned, so a reader may log the corruption and keep reading;
//! [`WireError::Io`] means the transport itself failed (peer closed,
//! reset, truncated stream) and the connection is gone.

use std::io::{Read, Write};

/// Protocol magic, checked in the Hello frame.
pub const MAGIC: u32 = 0x6743_0004; // "gC" v4 (v3 + Result metrics block)

const TAG_HELLO: u8 = 1;
const TAG_SETUP: u8 = 2;
const TAG_TASK: u8 = 3;
const TAG_RESULT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;

/// `Setup::scheme_kind` values.
pub const SCHEME_POLY: u8 = 0;
pub const SCHEME_RANDOM: u8 = 1;
pub const SCHEME_UNCODED: u8 = 2;
pub const SCHEME_APPROX: u8 = 3;
pub const SCHEME_HETERO: u8 = 4;

/// Framing bytes wrapped around every payload: the `u32` length
/// prefix, the `u8` tag, and the trailing `u32` CRC32.
pub const FRAME_OVERHEAD: usize = 4 + 1 + 4;

/// Fixed `Result` payload header ahead of the f32 gradient: `u32`
/// worker + `u64` iter + `u8` failed flag.
pub const RESULT_HEADER_BYTES: usize = 4 + 8 + 1;

/// Fixed v4 [`WorkerMetrics`] block between the `Result` header and the
/// f32 gradient: `u64` compute µs + `u64` tx bytes + `u64` rx bytes +
/// `u32` faults seen + `u32` iterations served.
pub const RESULT_METRICS_BYTES: usize = 8 + 8 + 8 + 4 + 4;

/// Bytes a `Result` frame carrying `floats` f32 values occupies on the
/// wire, framing included. This is what byte-accurate communication
/// accounting must charge per gathered gradient — `floats × 4` alone
/// undercounts by the frame, header, and metrics-block overhead.
pub const fn framed_result_bytes(floats: usize) -> usize {
    FRAME_OVERHEAD + RESULT_HEADER_BYTES + RESULT_METRICS_BYTES + 4 * floats
}

/// Maximum accepted payload. Deliberately far below the old 1 GiB guard:
/// a corrupted length prefix must not be able to request a giant
/// allocation (the payload read is additionally bounded by
/// `Read::take`, so even `MAX_PAYLOAD` is a cap on bytes read, not a
/// pre-allocation).
const MAX_PAYLOAD: usize = 1 << 26;

/// Pinned fingerprint of the v4 frame layout: FNV-1a-64 over
/// `"NAME=<decimal>;"` for every layout constant above, in the fixed
/// registry order of [`layout_fingerprint`]. The `wire-layout-drift`
/// lint re-derives the hash by parsing this file; a layout change that
/// does not bump [`MAGIC`] *and* re-pin this value fails `gradcode
/// lint --deny` (and the unit test below).
pub const WIRE_LAYOUT_FINGERPRINT: u64 = 0x0d00_2c1b_b45e_6b44;

/// Re-derive the layout fingerprint from the live constant values.
///
/// Serialization: for each constant, the ASCII bytes of
/// `"NAME=<decimal>;"`, concatenated in registry order, hashed with
/// FNV-1a-64 (offset `0xcbf29ce484222325`, prime `0x100000001b3`).
/// The linter computes the identical hash from source tokens, so the
/// two detect the same drift.
pub fn layout_fingerprint() -> u64 {
    let entries: [(&str, u64); 15] = [
        ("MAGIC", MAGIC as u64),
        ("TAG_HELLO", TAG_HELLO as u64),
        ("TAG_SETUP", TAG_SETUP as u64),
        ("TAG_TASK", TAG_TASK as u64),
        ("TAG_RESULT", TAG_RESULT as u64),
        ("TAG_SHUTDOWN", TAG_SHUTDOWN as u64),
        ("SCHEME_POLY", SCHEME_POLY as u64),
        ("SCHEME_RANDOM", SCHEME_RANDOM as u64),
        ("SCHEME_UNCODED", SCHEME_UNCODED as u64),
        ("SCHEME_APPROX", SCHEME_APPROX as u64),
        ("SCHEME_HETERO", SCHEME_HETERO as u64),
        ("FRAME_OVERHEAD", FRAME_OVERHEAD as u64),
        ("RESULT_HEADER_BYTES", RESULT_HEADER_BYTES as u64),
        ("RESULT_METRICS_BYTES", RESULT_METRICS_BYTES as u64),
        ("MAX_PAYLOAD", MAX_PAYLOAD as u64),
    ];
    let mut data = String::new();
    for (name, v) in entries {
        data.push_str(name);
        data.push('=');
        data.push_str(&v.to_string());
        data.push(';');
    }
    crate::lint::fnv1a64(data.as_bytes())
}

/// Transport-layer error, split so callers can tell a corrupt frame
/// (stream still aligned — skip and continue) from a dead connection.
#[derive(Debug)]
pub enum WireError {
    /// The frame was read in full but failed validation.
    Corrupt(String),
    /// The underlying stream failed (closed, reset, truncated).
    Io(std::io::Error),
}

impl WireError {
    fn corrupt(msg: impl Into<String>) -> WireError {
        WireError::Corrupt(msg.into())
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Corrupt(msg) => write!(f, "corrupt frame: {msg}"),
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Corrupt(_) => None,
            WireError::Io(e) => Some(e),
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

const fn make_crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// IEEE (reflected, poly 0xEDB88320) CRC32 lookup table.
static CRC32_TABLE: [u32; 256] = make_crc32_table();

#[inline]
fn crc32_step(state: u32, byte: u8) -> u32 {
    CRC32_TABLE[((state ^ byte as u32) & 0xff) as usize] ^ (state >> 8)
}

/// IEEE CRC32 of a byte slice (the checksum appended to every frame).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xffff_ffffu32;
    for &b in bytes {
        c = crc32_step(c, b);
    }
    c ^ 0xffff_ffff
}

/// CRC32 of an f32 slice in its little-endian wire representation.
/// Used by the in-process path to detect injected payload corruption
/// with exactly the same check the TCP frames get.
pub fn crc32_f32s(xs: &[f32]) -> u32 {
    let mut c = 0xffff_ffffu32;
    for x in xs {
        for b in x.to_le_bytes() {
            c = crc32_step(c, b);
        }
    }
    c ^ 0xffff_ffff
}

/// Frame checksum: CRC32 over the tag byte followed by the payload.
fn frame_crc(tag: u8, payload: &[u8]) -> u32 {
    let mut c = crc32_step(0xffff_ffff, tag);
    for &b in payload {
        c = crc32_step(c, b);
    }
    c ^ 0xffff_ffff
}

/// A decoded protocol message.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    Hello { magic: u32, worker_id: u32 },
    Setup(Setup),
    Task { iter: u64, beta: Vec<f32> },
    Result { worker: u32, iter: u64, failed: bool, metrics: WorkerMetrics, f: Vec<f32> },
    Shutdown,
}

/// Fixed-layout worker health block piggybacked on every v4 Result frame
/// (between the Result header and the f32 payload — see
/// [`RESULT_METRICS_BYTES`]). Lets the master expose per-worker fleet
/// gauges live without any extra round trips: the numbers ride on
/// frames the protocol already sends every iteration.
///
/// All fields are cumulative since worker start, so the master can
/// overwrite (not accumulate) its per-worker gauges and a mid-run
/// scrape agrees with end-of-run totals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WorkerMetrics {
    /// Total wall-clock microseconds spent in gradient compute.
    pub compute_us: u64,
    /// Bytes the worker has written to the wire (its own WireCounters).
    pub tx_bytes: u64,
    /// Bytes the worker has read from the wire.
    pub rx_bytes: u64,
    /// Faults the worker observed (injected failures it simulated).
    pub faults: u32,
    /// Task iterations this worker has served.
    pub iters_served: u32,
}

/// Scheme + data configuration sent to each worker at handshake. Workers
/// regenerate their shard deterministically from `data_seed` (the
/// stand-in for "load your shard from shared storage").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Setup {
    pub n: u32,
    pub d: u32,
    pub s: u32,
    pub m: u32,
    /// [`SCHEME_POLY`] | [`SCHEME_RANDOM`] | [`SCHEME_UNCODED`] |
    /// [`SCHEME_APPROX`] | [`SCHEME_HETERO`].
    pub scheme_kind: u8,
    pub scheme_seed: u64,
    pub data_seed: u64,
    pub rows: u32,
    pub dim: u32,
    /// Responders the master proceeds at ([`SCHEME_APPROX`] only; for
    /// the approximate scheme `d` is the replication factor and `s` is
    /// redundant). 0 everywhere else.
    pub quorum: u32,
    /// Per-worker subset loads `d_w` ([`SCHEME_HETERO`] only; workers
    /// verify the scheme they rebuilt from the speeds matches). Empty
    /// otherwise.
    pub loads: Vec<u32>,
    /// Per-worker relative speeds in milli-units (speed × 1000,
    /// [`SCHEME_HETERO`] only). Integers keep the frame `Eq` and make
    /// master/worker scheme reconstruction bit-identical. Empty
    /// otherwise.
    pub speeds_milli: Vec<u32>,
}

impl Setup {
    /// A homogeneous-scheme Setup (kinds 0–2) with the v2 fields empty.
    pub fn homogeneous(
        n: u32,
        d: u32,
        s: u32,
        m: u32,
        scheme_kind: u8,
        scheme_seed: u64,
        data_seed: u64,
        rows: u32,
        dim: u32,
    ) -> Self {
        Setup {
            n,
            d,
            s,
            m,
            scheme_kind,
            scheme_seed,
            data_seed,
            rows,
            dim,
            quorum: 0,
            loads: Vec::new(),
            speeds_milli: Vec::new(),
        }
    }

    /// Responders the master gathers before decoding: the approximate
    /// scheme's quorum, or `n - s` for every exact scheme (the remote
    /// master uses the flat rule — always decodable — rather than the
    /// in-process per-group early stop).
    pub fn wait_for(&self) -> usize {
        if self.scheme_kind == SCHEME_APPROX && self.quorum > 0 {
            self.quorum as usize
        } else {
            (self.n - self.s) as usize
        }
    }

    /// Per-worker speeds decoded from the milli-unit wire form.
    pub fn speeds(&self) -> Vec<f64> {
        self.speeds_milli.iter().map(|&x| x as f64 / 1000.0).collect()
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.buf.len() {
            return Err(WireError::corrupt(format!(
                "truncated frame: need {n} at {}",
                self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    /// Take exactly `N` bytes as a fixed-size array without a fallible
    /// conversion: the length is checked once by `take`.
    fn array<const N: usize>(&mut self) -> Result<[u8; N], WireError> {
        let s = self.take(N)?;
        let mut out = [0u8; N];
        out.copy_from_slice(s);
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.array::<4>()?))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.array::<8>()?))
    }

    fn f32s(&mut self, count: usize) -> Result<Vec<f32>, WireError> {
        let raw = self.take(count * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn done(&self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::corrupt(format!(
                "{} trailing bytes in frame",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

impl Message {
    /// Encode as a full frame (header + payload + checksum).
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        let tag = match self {
            Message::Hello { magic, worker_id } => {
                payload.extend_from_slice(&magic.to_le_bytes());
                payload.extend_from_slice(&worker_id.to_le_bytes());
                TAG_HELLO
            }
            Message::Setup(s) => {
                for v in [s.n, s.d, s.s, s.m] {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
                payload.push(s.scheme_kind);
                payload.extend_from_slice(&s.scheme_seed.to_le_bytes());
                payload.extend_from_slice(&s.data_seed.to_le_bytes());
                payload.extend_from_slice(&s.rows.to_le_bytes());
                payload.extend_from_slice(&s.dim.to_le_bytes());
                payload.extend_from_slice(&s.quorum.to_le_bytes());
                for list in [&s.loads, &s.speeds_milli] {
                    payload.extend_from_slice(&(list.len() as u32).to_le_bytes());
                    for v in list {
                        payload.extend_from_slice(&v.to_le_bytes());
                    }
                }
                TAG_SETUP
            }
            Message::Task { iter, beta } => {
                payload.extend_from_slice(&iter.to_le_bytes());
                put_f32s(&mut payload, beta);
                TAG_TASK
            }
            Message::Result { worker, iter, failed, metrics, f } => {
                payload.extend_from_slice(&worker.to_le_bytes());
                payload.extend_from_slice(&iter.to_le_bytes());
                payload.push(u8::from(*failed));
                payload.extend_from_slice(&metrics.compute_us.to_le_bytes());
                payload.extend_from_slice(&metrics.tx_bytes.to_le_bytes());
                payload.extend_from_slice(&metrics.rx_bytes.to_le_bytes());
                payload.extend_from_slice(&metrics.faults.to_le_bytes());
                payload.extend_from_slice(&metrics.iters_served.to_le_bytes());
                put_f32s(&mut payload, f);
                TAG_RESULT
            }
            Message::Shutdown => TAG_SHUTDOWN,
        };
        let crc = frame_crc(tag, &payload);
        let mut frame = Vec::with_capacity(payload.len() + FRAME_OVERHEAD);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.push(tag);
        frame.extend_from_slice(&payload);
        frame.extend_from_slice(&crc.to_le_bytes());
        frame
    }

    /// Decode one message from tag + payload.
    fn decode(tag: u8, payload: &[u8]) -> Result<Message, WireError> {
        let mut c = Cursor::new(payload);
        let msg = match tag {
            TAG_HELLO => Message::Hello { magic: c.u32()?, worker_id: c.u32()? },
            TAG_SETUP => {
                let n = c.u32()?;
                let d = c.u32()?;
                let s = c.u32()?;
                let m = c.u32()?;
                let scheme_kind = c.u8()?;
                let scheme_seed = c.u64()?;
                let data_seed = c.u64()?;
                let rows = c.u32()?;
                let dim = c.u32()?;
                let quorum = c.u32()?;
                let mut lists = [Vec::new(), Vec::new()];
                for list in &mut lists {
                    let len = c.u32()? as usize;
                    if len > n as usize {
                        return Err(WireError::corrupt(format!(
                            "setup vector of {len} entries exceeds n = {n}"
                        )));
                    }
                    *list = (0..len).map(|_| c.u32()).collect::<Result<_, _>>()?;
                }
                let [loads, speeds_milli] = lists;
                Message::Setup(Setup {
                    n,
                    d,
                    s,
                    m,
                    scheme_kind,
                    scheme_seed,
                    data_seed,
                    rows,
                    dim,
                    quorum,
                    loads,
                    speeds_milli,
                })
            }
            TAG_TASK => {
                let iter = c.u64()?;
                let remaining = payload.len().saturating_sub(8);
                if remaining % 4 != 0 {
                    return Err(WireError::corrupt("task payload not f32-aligned"));
                }
                Message::Task { iter, beta: c.f32s(remaining / 4)? }
            }
            TAG_RESULT => {
                let worker = c.u32()?;
                let iter = c.u64()?;
                let failed = c.u8()? != 0;
                let metrics = WorkerMetrics {
                    compute_us: c.u64()?,
                    tx_bytes: c.u64()?,
                    rx_bytes: c.u64()?,
                    faults: c.u32()?,
                    iters_served: c.u32()?,
                };
                let remaining = payload
                    .len()
                    .saturating_sub(RESULT_HEADER_BYTES + RESULT_METRICS_BYTES);
                if remaining % 4 != 0 {
                    return Err(WireError::corrupt("result payload not f32-aligned"));
                }
                Message::Result { worker, iter, failed, metrics, f: c.f32s(remaining / 4)? }
            }
            TAG_SHUTDOWN => Message::Shutdown,
            other => return Err(WireError::corrupt(format!("unknown message tag {other}"))),
        };
        c.done()?;
        Ok(msg)
    }

    /// Payload bytes this message encodes to (everything between the
    /// tag and the CRC), computed without serializing.
    pub fn payload_len(&self) -> usize {
        match self {
            Message::Hello { .. } => 4 + 4,
            Message::Setup(s) => {
                // n d s m | kind | seeds | rows dim quorum | 2 × (len + entries)
                4 * 4 + 1 + 8 + 8 + 4 + 4 + 4
                    + (4 + 4 * s.loads.len())
                    + (4 + 4 * s.speeds_milli.len())
            }
            Message::Task { beta, .. } => 8 + 4 * beta.len(),
            Message::Result { f, .. } => {
                RESULT_HEADER_BYTES + RESULT_METRICS_BYTES + 4 * f.len()
            }
            Message::Shutdown => 0,
        }
    }

    /// Total bytes this message occupies on the wire, framing included:
    /// `FRAME_OVERHEAD + payload_len()`. Always equals `encode().len()`.
    pub fn wire_len(&self) -> usize {
        FRAME_OVERHEAD + self.payload_len()
    }

    /// Write a full frame to a stream.
    pub fn write_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        w.write_all(&self.encode())?;
        w.flush()
    }

    /// Read one full frame from a stream.
    ///
    /// On [`WireError::Corrupt`] the whole frame (header, payload, and
    /// checksum) has been consumed, so the stream is still aligned and
    /// the caller may keep reading subsequent frames.
    pub fn read_from(r: &mut impl Read) -> Result<Message, WireError> {
        let mut header = [0u8; 5];
        r.read_exact(&mut header)?;
        let len =
            u32::from_le_bytes([header[0], header[1], header[2], header[3]]) as usize;
        let tag = header[4];
        if len > MAX_PAYLOAD {
            return Err(WireError::corrupt(format!("frame too large: {len}")));
        }
        // Bounded read: `take` caps the bytes a lying length prefix can
        // pull, and the initial capacity is small so a huge `len` cannot
        // force a giant allocation before any byte arrives.
        let mut payload = Vec::with_capacity(len.min(64 * 1024));
        let got = r.take(len as u64).read_to_end(&mut payload)?;
        if got < len {
            return Err(WireError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                format!("frame payload truncated: got {got} of {len} bytes"),
            )));
        }
        let mut crc_bytes = [0u8; 4];
        r.read_exact(&mut crc_bytes)?;
        let want = u32::from_le_bytes(crc_bytes);
        let got_crc = frame_crc(tag, &payload);
        if got_crc != want {
            return Err(WireError::corrupt(format!(
                "checksum mismatch: frame says {want:#010x}, computed {got_crc:#010x}"
            )));
        }
        Message::decode(tag, &payload)
    }
}

/// Per-direction frame/byte accounting for one endpoint. Maintained by
/// the remote master and TCP workers and exported into the telemetry
/// counter stream (`wire.tx_*` / `wire.rx_*` / `wire.corrupt_rejects`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WireCounters {
    pub tx_frames: u64,
    pub tx_bytes: u64,
    pub rx_frames: u64,
    pub rx_bytes: u64,
    /// Frames that arrived whole but failed validation (CRC/tag/shape)
    /// and were skipped.
    pub corrupt_rejects: u64,
}

impl WireCounters {
    /// Account one transmitted message (framed size).
    pub fn sent(&mut self, msg: &Message) {
        self.tx_frames += 1;
        self.tx_bytes += msg.wire_len() as u64;
    }

    /// Account one received, validated message (framed size).
    pub fn received(&mut self, msg: &Message) {
        self.rx_frames += 1;
        self.rx_bytes += msg.wire_len() as u64;
    }

    /// Account one corrupt frame that was skipped.
    pub fn rejected(&mut self) {
        self.corrupt_rejects += 1;
    }

    /// Export into a telemetry recorder as gauges under `prefix.`.
    pub fn export(&self, rec: &crate::obs::Recorder, prefix: &str) {
        rec.set(&format!("{prefix}.tx_frames"), self.tx_frames as i64);
        rec.set(&format!("{prefix}.tx_bytes"), self.tx_bytes as i64);
        rec.set(&format!("{prefix}.rx_frames"), self.rx_frames as i64);
        rec.set(&format!("{prefix}.rx_bytes"), self.rx_bytes as i64);
        rec.set(&format!("{prefix}.corrupt_rejects"), self.corrupt_rejects as i64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Re-pinning procedure: this test (and the `wire-layout-drift`
    /// lint) failing means a frame-layout constant changed. That is
    /// only legal together with a version bump — bump `MAGIC` to the
    /// next protocol version, then set `WIRE_LAYOUT_FINGERPRINT` to
    /// the "computed" value this assertion prints. Never re-pin
    /// without the MAGIC bump: peers on the old layout must fail the
    /// Hello handshake, not mis-parse frames.
    #[test]
    fn layout_fingerprint_matches_recorded_pin() {
        assert_eq!(
            layout_fingerprint(),
            WIRE_LAYOUT_FINGERPRINT,
            "wire layout drifted: computed {:#018x} — bump MAGIC and re-pin",
            layout_fingerprint(),
        );
    }

    fn roundtrip(msg: Message) {
        let frame = msg.encode();
        let mut cursor = std::io::Cursor::new(frame);
        let back = Message::read_from(&mut cursor).unwrap();
        assert_eq!(back, msg);
    }

    /// Recompute the trailing checksum after a deliberate payload edit,
    /// so a test can exercise decode-level validation past the CRC.
    fn reseal(frame: &mut [u8]) {
        let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
        let crc = frame_crc(frame[4], &frame[5..5 + len]);
        frame[5 + len..5 + len + 4].copy_from_slice(&crc.to_le_bytes());
    }

    #[test]
    fn crc32_known_answer() {
        // The canonical IEEE CRC32 check value.
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
        // f32 helper matches the byte-wise CRC of the LE representation
        let xs = [1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        let mut bytes = Vec::new();
        for x in xs {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        assert_eq!(crc32_f32s(&xs), crc32(&bytes));
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Message::Hello { magic: MAGIC, worker_id: 3 });
        roundtrip(Message::Setup(Setup::homogeneous(10, 3, 1, 2, 0, 7, 99, 640, 512)));
        // v2 fields: approx quorum and hetero load/speed vectors
        roundtrip(Message::Setup(Setup {
            quorum: 6,
            ..Setup::homogeneous(8, 3, 2, 1, SCHEME_APPROX, 7, 99, 640, 512)
        }));
        roundtrip(Message::Setup(Setup {
            loads: vec![3, 3, 3, 5, 5],
            speeds_milli: vec![1000, 1000, 1000, 4000, 4000],
            ..Setup::homogeneous(5, 5, 1, 2, SCHEME_HETERO, 7, 99, 640, 512)
        }));
        roundtrip(Message::Task { iter: 42, beta: vec![1.5, -2.25, 0.0] });
        roundtrip(Message::Result {
            worker: 9,
            iter: 42,
            failed: false,
            metrics: WorkerMetrics {
                compute_us: 123_456_789_000,
                tx_bytes: 1 << 40,
                rx_bytes: 7,
                faults: 3,
                iters_served: 42,
            },
            f: vec![0.125; 7],
        });
        roundtrip(Message::Result {
            worker: 1,
            iter: 0,
            failed: true,
            metrics: WorkerMetrics::default(),
            f: vec![],
        });
        roundtrip(Message::Shutdown);
    }

    #[test]
    fn wire_len_matches_encoded_frame_for_every_variant() {
        let variants = vec![
            Message::Hello { magic: MAGIC, worker_id: 3 },
            Message::Setup(Setup::homogeneous(10, 3, 1, 2, SCHEME_POLY, 7, 99, 640, 512)),
            Message::Setup(Setup {
                loads: vec![3, 3, 5],
                speeds_milli: vec![1000, 1000, 4000],
                ..Setup::homogeneous(3, 5, 1, 2, SCHEME_HETERO, 7, 99, 640, 512)
            }),
            Message::Task { iter: 42, beta: vec![1.5; 17] },
            Message::Result {
                worker: 9,
                iter: 42,
                failed: false,
                metrics: WorkerMetrics { compute_us: 5, ..WorkerMetrics::default() },
                f: vec![0.125; 7],
            },
            Message::Result {
                worker: 1,
                iter: 0,
                failed: true,
                metrics: WorkerMetrics::default(),
                f: vec![],
            },
            Message::Shutdown,
        ];
        for msg in variants {
            let frame = msg.encode();
            assert_eq!(frame.len(), msg.wire_len(), "wire_len must match encode: {msg:?}");
            assert_eq!(frame.len(), FRAME_OVERHEAD + msg.payload_len());
        }
    }

    #[test]
    fn framed_result_bytes_matches_frame_layout() {
        // Against the documented layout: u32 len | u8 tag | payload |
        // u32 crc, with a 13-byte Result header and a 32-byte metrics
        // block before the floats.
        assert_eq!(FRAME_OVERHEAD, 9);
        assert_eq!(RESULT_HEADER_BYTES, 13);
        assert_eq!(RESULT_METRICS_BYTES, 32);
        for floats in [0usize, 1, 7, 512] {
            let msg = Message::Result {
                worker: 0,
                iter: 1,
                failed: false,
                metrics: WorkerMetrics::default(),
                f: vec![0.5; floats],
            };
            assert_eq!(msg.encode().len(), framed_result_bytes(floats));
        }
        // the framing really is what v4 (MAGIC's protocol rev) promises:
        // overhead beyond the raw floats is constant per frame
        assert_eq!(MAGIC & 0xffff, 4, "protocol rev with metrics-bearing Results");
        assert_eq!(framed_result_bytes(10) - framed_result_bytes(0), 40);
    }

    #[test]
    fn wire_counters_account_framed_bytes() {
        let mut wc = WireCounters::default();
        let task = Message::Task { iter: 1, beta: vec![0.0; 4] };
        let result = Message::Result {
            worker: 0,
            iter: 1,
            failed: false,
            metrics: WorkerMetrics::default(),
            f: vec![0.0; 4],
        };
        wc.sent(&task);
        wc.sent(&task);
        wc.received(&result);
        wc.rejected();
        assert_eq!(wc.tx_frames, 2);
        assert_eq!(wc.tx_bytes, 2 * task.encode().len() as u64);
        assert_eq!(wc.rx_frames, 1);
        assert_eq!(wc.rx_bytes, framed_result_bytes(4) as u64);
        assert_eq!(wc.corrupt_rejects, 1);
        let rec = crate::obs::Recorder::enabled();
        wc.export(&rec, "wire");
        let counters = rec.counters();
        assert!(counters.contains(&("wire.rx_bytes".into(), framed_result_bytes(4) as i64)));
        assert!(counters.contains(&("wire.corrupt_rejects".into(), 1)));
    }

    #[test]
    fn setup_wait_for_covers_all_kinds() {
        let exact = Setup::homogeneous(10, 3, 2, 1, SCHEME_POLY, 1, 2, 64, 32);
        assert_eq!(exact.wait_for(), 8);
        let approx = Setup {
            quorum: 6,
            ..Setup::homogeneous(10, 3, 0, 1, SCHEME_APPROX, 1, 2, 64, 32)
        };
        assert_eq!(approx.wait_for(), 6);
        let hetero = Setup {
            loads: vec![2; 10],
            speeds_milli: vec![1000; 10],
            ..Setup::homogeneous(10, 2, 1, 1, SCHEME_HETERO, 1, 2, 64, 32)
        };
        assert_eq!(hetero.wait_for(), 9, "remote hetero keeps the flat n - s rule");
        assert_eq!(hetero.speeds(), vec![1.0; 10]);
    }

    #[test]
    fn oversized_setup_vector_rejected() {
        let msg = Message::Setup(Setup {
            loads: vec![1; 4],
            ..Setup::homogeneous(4, 1, 0, 1, SCHEME_HETERO, 1, 2, 64, 32)
        });
        let mut frame = msg.encode();
        // Corrupt the loads length (offset: 4 hdr + 1 tag + 16 + 1 + 16 +
        // 8 + 4 = payload offset 45 → frame offset 50) to exceed n, then
        // reseal the checksum so the length check itself is exercised.
        let len_off = 5 + 4 * 4 + 1 + 8 + 8 + 4 + 4 + 4;
        frame[len_off] = 200;
        reseal(&mut frame);
        let mut cursor = std::io::Cursor::new(frame);
        match Message::read_from(&mut cursor) {
            Err(WireError::Corrupt(msg)) => assert!(msg.contains("exceeds n"), "{msg}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn f32_payload_is_exact() {
        let beta: Vec<f32> = (0..100).map(|i| (i as f32).exp() * 1e-3).collect();
        let msg = Message::Task { iter: 1, beta: beta.clone() };
        let mut cursor = std::io::Cursor::new(msg.encode());
        match Message::read_from(&mut cursor).unwrap() {
            Message::Task { beta: got, .. } => assert_eq!(got, beta),
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn truncated_frame_errors() {
        let frame = Message::Task { iter: 1, beta: vec![1.0, 2.0] }.encode();
        // every strict prefix must fail with an Io error, never panic
        for cut in 0..frame.len() {
            let mut short = std::io::Cursor::new(&frame[..cut]);
            match Message::read_from(&mut short) {
                Err(WireError::Io(_)) => {}
                other => panic!("cut at {cut}: expected Io error, got {other:?}"),
            }
        }
    }

    #[test]
    fn unknown_tag_errors() {
        let mut frame = Message::Shutdown.encode();
        frame[4] = 250;
        reseal(&mut frame);
        let mut cursor = std::io::Cursor::new(frame);
        match Message::read_from(&mut cursor) {
            Err(WireError::Corrupt(msg)) => assert!(msg.contains("unknown"), "{msg}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn misaligned_task_errors() {
        // 3-byte payload after iter: not a multiple of 4
        let mut payload = 7u64.to_le_bytes().to_vec();
        payload.extend_from_slice(&[1, 2, 3]);
        let mut frame = (payload.len() as u32).to_le_bytes().to_vec();
        frame.push(3); // TAG_TASK
        frame.extend_from_slice(&payload);
        frame.extend_from_slice(&frame_crc(3, &payload).to_le_bytes());
        let mut cursor = std::io::Cursor::new(frame);
        match Message::read_from(&mut cursor) {
            Err(WireError::Corrupt(msg)) => assert!(msg.contains("f32"), "{msg}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn bit_flip_is_caught_and_stream_stays_aligned() {
        let bad = Message::Result {
            worker: 2,
            iter: 5,
            failed: false,
            metrics: WorkerMetrics::default(),
            f: vec![0.5; 8],
        };
        let good = Message::Task { iter: 6, beta: vec![1.0; 4] };
        let mut stream = bad.encode();
        stream[5 + 13 + 32 + 3] ^= 0x10; // flip one payload (f32) bit, leave the CRC
        stream.extend_from_slice(&good.encode());
        let mut cursor = std::io::Cursor::new(stream);
        match Message::read_from(&mut cursor) {
            Err(WireError::Corrupt(msg)) => assert!(msg.contains("checksum"), "{msg}"),
            other => panic!("expected checksum mismatch, got {other:?}"),
        }
        // the corrupt frame was fully consumed: the next one parses fine
        assert_eq!(Message::read_from(&mut cursor).unwrap(), good);
    }

    #[test]
    fn oversize_length_prefix_rejected_without_allocation() {
        // len = u32::MAX: must be rejected by the MAX_PAYLOAD bound
        let mut frame = u32::MAX.to_le_bytes().to_vec();
        frame.push(TAG_TASK);
        let mut cursor = std::io::Cursor::new(frame);
        match Message::read_from(&mut cursor) {
            Err(WireError::Corrupt(msg)) => assert!(msg.contains("too large"), "{msg}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        // len = MAX_PAYLOAD exactly with a near-empty stream: the take()
        // bound means we fail fast on EOF instead of allocating 64 MiB.
        let mut frame = (MAX_PAYLOAD as u32).to_le_bytes().to_vec();
        frame.push(TAG_TASK);
        frame.extend_from_slice(&[0u8; 16]);
        let mut cursor = std::io::Cursor::new(frame);
        assert!(matches!(Message::read_from(&mut cursor), Err(WireError::Io(_))));
    }

    #[test]
    fn v2_frame_without_checksum_is_rejected() {
        // A v2 peer sends `len | tag | payload` with no trailing CRC. For
        // a lone frame the missing 4 bytes read as EOF; in a stream the
        // next frame's header bytes would be consumed as a bogus CRC and
        // fail the checksum. Either way the frame never decodes.
        let mut v2 = 0u32.to_le_bytes().to_vec();
        v2.push(TAG_SHUTDOWN);
        let mut cursor = std::io::Cursor::new(v2);
        assert!(Message::read_from(&mut cursor).is_err());
    }
}
