//! Wire protocol for the TCP transport (multi-process deployment).
//!
//! The paper ran master and workers as MPI ranks over a real network;
//! this module is the equivalent seam: a small length-prefixed binary
//! protocol (no serde available offline). All integers are little-endian.
//!
//! Frame:  `u32 payload_len | u8 tag | payload`
//!
//! Messages:
//! - `Hello { worker_id }`                        worker → master
//! - `Setup { n, d, s, m, scheme, seed, rows, dim, minibatch }`
//!                                                master → worker
//! - `Task { iter, beta[f32; dim] }`              master → worker
//! - `Result { worker, iter, failed, f[f32] }`    worker → master
//! - `Shutdown`                                   master → worker

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// Protocol magic, checked in the Hello frame.
pub const MAGIC: u32 = 0x6743_0001; // "gC" v1

const TAG_HELLO: u8 = 1;
const TAG_SETUP: u8 = 2;
const TAG_TASK: u8 = 3;
const TAG_RESULT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;

/// Maximum accepted payload (guards against corrupt frames).
const MAX_PAYLOAD: usize = 1 << 30;

/// A decoded protocol message.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    Hello { magic: u32, worker_id: u32 },
    Setup(Setup),
    Task { iter: u64, beta: Vec<f32> },
    Result { worker: u32, iter: u64, failed: bool, f: Vec<f32> },
    Shutdown,
}

/// Scheme + data configuration sent to each worker at handshake. Workers
/// regenerate their shard deterministically from `data_seed` (the
/// stand-in for "load your shard from shared storage").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Setup {
    pub n: u32,
    pub d: u32,
    pub s: u32,
    pub m: u32,
    /// 0 = poly, 1 = random, 2 = uncoded.
    pub scheme_kind: u8,
    pub scheme_seed: u64,
    pub data_seed: u64,
    pub rows: u32,
    pub dim: u32,
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame: need {n} at {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, count: usize) -> Result<Vec<f32>> {
        let raw = self.take(count * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes in frame", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

impl Message {
    /// Encode as a full frame (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        let tag = match self {
            Message::Hello { magic, worker_id } => {
                payload.extend_from_slice(&magic.to_le_bytes());
                payload.extend_from_slice(&worker_id.to_le_bytes());
                TAG_HELLO
            }
            Message::Setup(s) => {
                for v in [s.n, s.d, s.s, s.m] {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
                payload.push(s.scheme_kind);
                payload.extend_from_slice(&s.scheme_seed.to_le_bytes());
                payload.extend_from_slice(&s.data_seed.to_le_bytes());
                payload.extend_from_slice(&s.rows.to_le_bytes());
                payload.extend_from_slice(&s.dim.to_le_bytes());
                TAG_SETUP
            }
            Message::Task { iter, beta } => {
                payload.extend_from_slice(&iter.to_le_bytes());
                put_f32s(&mut payload, beta);
                TAG_TASK
            }
            Message::Result { worker, iter, failed, f } => {
                payload.extend_from_slice(&worker.to_le_bytes());
                payload.extend_from_slice(&iter.to_le_bytes());
                payload.push(u8::from(*failed));
                put_f32s(&mut payload, f);
                TAG_RESULT
            }
            Message::Shutdown => TAG_SHUTDOWN,
        };
        let mut frame = Vec::with_capacity(payload.len() + 5);
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.push(tag);
        frame.extend_from_slice(&payload);
        frame
    }

    /// Decode one message from tag + payload.
    fn decode(tag: u8, payload: &[u8]) -> Result<Message> {
        let mut c = Cursor::new(payload);
        let msg = match tag {
            TAG_HELLO => Message::Hello { magic: c.u32()?, worker_id: c.u32()? },
            TAG_SETUP => Message::Setup(Setup {
                n: c.u32()?,
                d: c.u32()?,
                s: c.u32()?,
                m: c.u32()?,
                scheme_kind: c.u8()?,
                scheme_seed: c.u64()?,
                data_seed: c.u64()?,
                rows: c.u32()?,
                dim: c.u32()?,
            }),
            TAG_TASK => {
                let iter = c.u64()?;
                let remaining = payload.len() - 8;
                if remaining % 4 != 0 {
                    bail!("task payload not f32-aligned");
                }
                Message::Task { iter, beta: c.f32s(remaining / 4)? }
            }
            TAG_RESULT => {
                let worker = c.u32()?;
                let iter = c.u64()?;
                let failed = c.u8()? != 0;
                let remaining = payload.len() - 13;
                if remaining % 4 != 0 {
                    bail!("result payload not f32-aligned");
                }
                Message::Result { worker, iter, failed, f: c.f32s(remaining / 4)? }
            }
            TAG_SHUTDOWN => Message::Shutdown,
            other => bail!("unknown message tag {other}"),
        };
        c.done()?;
        Ok(msg)
    }

    /// Write a full frame to a stream.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&self.encode()).context("writing frame")?;
        w.flush().context("flushing frame")
    }

    /// Read one full frame from a stream.
    pub fn read_from(r: &mut impl Read) -> Result<Message> {
        let mut header = [0u8; 5];
        r.read_exact(&mut header).context("reading frame header")?;
        let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
        let tag = header[4];
        if len > MAX_PAYLOAD {
            bail!("frame too large: {len}");
        }
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload).context("reading frame payload")?;
        Message::decode(tag, &payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let frame = msg.encode();
        let mut cursor = std::io::Cursor::new(frame);
        let back = Message::read_from(&mut cursor).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(Message::Hello { magic: MAGIC, worker_id: 3 });
        roundtrip(Message::Setup(Setup {
            n: 10,
            d: 3,
            s: 1,
            m: 2,
            scheme_kind: 0,
            scheme_seed: 7,
            data_seed: 99,
            rows: 640,
            dim: 512,
        }));
        roundtrip(Message::Task { iter: 42, beta: vec![1.5, -2.25, 0.0] });
        roundtrip(Message::Result {
            worker: 9,
            iter: 42,
            failed: false,
            f: vec![0.125; 7],
        });
        roundtrip(Message::Result { worker: 1, iter: 0, failed: true, f: vec![] });
        roundtrip(Message::Shutdown);
    }

    #[test]
    fn f32_payload_is_exact() {
        let beta: Vec<f32> = (0..100).map(|i| (i as f32).exp() * 1e-3).collect();
        let msg = Message::Task { iter: 1, beta: beta.clone() };
        let mut cursor = std::io::Cursor::new(msg.encode());
        match Message::read_from(&mut cursor).unwrap() {
            Message::Task { beta: got, .. } => assert_eq!(got, beta),
            other => panic!("wrong message: {other:?}"),
        }
    }

    #[test]
    fn truncated_frame_errors() {
        let frame = Message::Shutdown.encode();
        let cursor = std::io::Cursor::new(&frame[..frame.len() - 1]);
        // shutdown has empty payload; truncate the header instead
        let mut short = std::io::Cursor::new(&frame[..3]);
        assert!(Message::read_from(&mut short).is_err());
        let _ = cursor; // (full shutdown frame is 5 bytes header only)
    }

    #[test]
    fn unknown_tag_errors() {
        let mut frame = Message::Shutdown.encode();
        frame[4] = 250;
        let mut cursor = std::io::Cursor::new(frame);
        assert!(Message::read_from(&mut cursor).is_err());
    }

    #[test]
    fn misaligned_task_errors() {
        // 5-byte payload after iter: not a multiple of 4
        let mut payload = 7u64.to_le_bytes().to_vec();
        payload.extend_from_slice(&[1, 2, 3]);
        let mut frame = (payload.len() as u32).to_le_bytes().to_vec();
        frame.push(3); // TAG_TASK
        frame.extend_from_slice(&payload);
        let mut cursor = std::io::Cursor::new(frame);
        assert!(Message::read_from(&mut cursor).is_err());
    }
}
