//! Worker compute backends.
//!
//! [`ComputeBackend`] is the seam between the coordinator and the numeric
//! stack: given the broadcast parameters it produces worker `w`'s coded
//! vector `f_w`. [`RustBackend`] is the pure-rust reference
//! implementation (partial gradients via `model::LogisticModel`, coded
//! combine via `coding::Encoder`); `runtime::PjrtBackend` (same trait)
//! executes the AOT JAX/Pallas artifact instead.
//!
//! Mini-batch SGD (§II: "our results apply to both batch gradient
//! descent and mini-batch SGD"): [`RustBackend::with_minibatch`] samples
//! a per-iteration row subset of every data subset. The sample is a
//! deterministic function of `(iteration, subset index)` — NOT of the
//! worker — so all `d` holders of a subset compute the *same* partial
//! gradient and the coded decode stays exact.

use std::sync::Arc;

use crate::coding::{Encoder, GradientCode};
use crate::data::DenseDataset;
use crate::model::LogisticModel;
use crate::rngs::{Pcg64, Rng};

/// Computes a worker's transmitted vector. Implementations must be
/// thread-safe: each worker thread calls into its own worker id, but the
/// backend object is shared.
pub trait ComputeBackend: Send + Sync {
    /// Gradient dimension `l` (already padded to a multiple of `m`).
    fn dim(&self) -> usize;

    /// Transmitted dimension `l/m`.
    fn out_dim(&self) -> usize;

    /// Compute `f_w` for iteration `iter` into `out` (resized /
    /// overwritten). `iter` seeds mini-batch selection; full-batch
    /// backends ignore it.
    fn encoded_gradient(
        &self,
        worker: usize,
        iter: usize,
        beta: &[f32],
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()>;
}

/// Pure-rust backend: per-subset logistic partial gradients + encode.
pub struct RustBackend {
    /// `D_1..D_n`, shared (each subset is referenced by `d` workers).
    subsets: Vec<Arc<DenseDataset>>,
    /// Per-worker assigned subset indices (placement order).
    assigned: Vec<Vec<usize>>,
    /// Per-worker encoder.
    encoders: Vec<Encoder>,
    l: usize,
    m: usize,
    /// Mini-batch fraction in (0, 1]; `None` = full batch.
    minibatch: Option<f64>,
    /// Base seed for the (iter, subset) → row-sample map.
    mb_seed: u64,
}

impl RustBackend {
    /// Full-batch backend. Partitions `train` into `n` equal subsets per
    /// the scheme's placement and prebuilds encoders. `train.cols` must
    /// already be a multiple of `m`.
    pub fn new(code: &dyn GradientCode, train: &DenseDataset) -> anyhow::Result<Self> {
        Self::build(code, train, None, 0)
    }

    /// Mini-batch SGD backend: each iteration every subset contributes a
    /// deterministic `fraction` sample of its rows.
    pub fn with_minibatch(
        code: &dyn GradientCode,
        train: &DenseDataset,
        fraction: f64,
        seed: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            fraction > 0.0 && fraction <= 1.0,
            "minibatch fraction must be in (0,1], got {fraction}"
        );
        Self::build(code, train, Some(fraction), seed)
    }

    fn build(
        code: &dyn GradientCode,
        train: &DenseDataset,
        minibatch: Option<f64>,
        mb_seed: u64,
    ) -> anyhow::Result<Self> {
        let cfg = *code.config();
        cfg.check_dim(train.cols)?;
        // Heterogeneous schemes size subsets proportionally to their
        // group's speed; homogeneous schemes keep the equal §II split.
        // Both paths use the same `rows - rows % n` prefix so every
        // scheme optimizes the identical objective (partition_rows drops
        // the remainder; the weighted split must match, or hetero-vs-poly
        // comparisons would train on different data).
        let usable = train.rows - train.rows % cfg.n;
        let parts = match code.subset_weights() {
            Some(ws) => crate::data::partition_rows_weighted(usable, &ws),
            None => crate::data::partition_rows(train.rows, cfg.n),
        };
        let subsets: Vec<Arc<DenseDataset>> =
            parts.iter().map(|idx| Arc::new(train.select_rows(idx))).collect();
        let mut assigned = Vec::with_capacity(cfg.n);
        let mut encoders = Vec::with_capacity(cfg.n);
        for w in 0..cfg.n {
            assigned.push(code.placement().assigned(w));
            encoders.push(Encoder::new(code, w)?);
        }
        Ok(RustBackend {
            subsets,
            assigned,
            encoders,
            l: train.cols,
            m: cfg.m,
            minibatch,
            mb_seed,
        })
    }

    /// The deterministic row sample of subset `t` at iteration `iter`.
    /// Same for every worker holding `t` — the coded-decode invariant.
    fn minibatch_rows(&self, iter: usize, t: usize, rows: usize) -> Option<Vec<usize>> {
        let fraction = self.minibatch?;
        let count = ((rows as f64 * fraction).round() as usize).clamp(1, rows);
        if count == rows {
            return None; // full subset
        }
        // Seed mixes (base, iter, subset) but NOT the worker id.
        let seed = self
            .mb_seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add((iter as u64) << 20)
            .wrapping_add(t as u64);
        let mut rng = Pcg64::seed_from_u64(seed);
        Some(rng.sample_indices(rows, count))
    }

    /// Partial gradient of subset `t` at iteration `iter` (mini-batch
    /// aware); used by both the worker path and the test oracle.
    pub fn subset_gradient(&self, iter: usize, t: usize, beta: &[f32]) -> Vec<f32> {
        let ds = &self.subsets[t];
        match self.minibatch_rows(iter, t, ds.rows) {
            None => LogisticModel::gradient(ds, beta),
            Some(rows) => LogisticModel::gradient(&ds.select_rows(&rows), beta),
        }
    }

    /// Direct (un-coded) sum gradient over all subsets — test oracle.
    pub fn full_gradient(&self, iter: usize, beta: &[f32]) -> Vec<f32> {
        let mut g = vec![0.0f32; self.l];
        for t in 0..self.subsets.len() {
            let part = self.subset_gradient(iter, t, beta);
            crate::linalg::axpy_f32(1.0, &part, &mut g);
        }
        g
    }
}

impl ComputeBackend for RustBackend {
    fn dim(&self) -> usize {
        self.l
    }

    fn out_dim(&self) -> usize {
        self.l / self.m
    }

    fn encoded_gradient(
        &self,
        worker: usize,
        iter: usize,
        beta: &[f32],
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let assigned = &self.assigned[worker];
        // d partial gradients (computed concurrently across the pool —
        // each is an independent dataset pass, so the fork is trivially
        // deterministic), then the coded combine.
        let grads: Vec<Vec<f32>> = crate::pool::global()
            .map_indexed(assigned.len(), |j| {
                self.subset_gradient(iter, assigned[j], beta)
            });
        let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        self.encoders[worker].encode_into(&views, out)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{Decoder, PolynomialCode, SchemeConfig};
    use crate::data::{CategoricalConfig, SyntheticCategorical};

    fn setup(n: usize, s: usize, m: usize) -> (PolynomialCode, DenseDataset) {
        let code = PolynomialCode::new(SchemeConfig::tight(n, s, m).unwrap()).unwrap();
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), 31);
        let ds = gen.generate(n * 20, 32);
        let ds = SyntheticCategorical::pad_to_multiple(&ds, m);
        (code, ds)
    }

    fn check_roundtrip(code: &PolynomialCode, backend: &RustBackend, iter: usize, l: usize) {
        let beta = vec![0.01f32; l];
        let n = code.config().n;
        let mut fs = Vec::new();
        for w in 0..n {
            let mut f = Vec::new();
            backend.encoded_gradient(w, iter, &beta, &mut f).unwrap();
            assert_eq!(f.len(), backend.out_dim());
            fs.push(f);
        }
        let avail: Vec<usize> = (0..n).filter(|&w| w != 2).collect();
        let dec = Decoder::new(code, &avail).unwrap();
        let views: Vec<&[f32]> =
            dec.used_workers().iter().map(|&w| fs[w].as_slice()).collect();
        let got = dec.decode(&views).unwrap();
        let want = backend.full_gradient(iter, &beta);
        let scale = want.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-20);
        for j in 0..got.len() {
            assert!(
                (got[j] - want[j]).abs() / scale < 1e-4,
                "iter {iter} coord {j}: {} vs {}",
                got[j],
                want[j]
            );
        }
    }

    #[test]
    fn coded_pipeline_reconstructs_full_gradient() {
        let (code, ds) = setup(5, 1, 2);
        let backend = RustBackend::new(&code, &ds).unwrap();
        check_roundtrip(&code, &backend, 0, ds.cols);
    }

    #[test]
    fn minibatch_pipeline_reconstructs_minibatch_gradient() {
        // The decode must equal the sum of *mini-batch* gradients: all d
        // holders of a subset sampled identical rows.
        let (code, ds) = setup(5, 1, 2);
        let backend = RustBackend::with_minibatch(&code, &ds, 0.5, 99).unwrap();
        for iter in [0usize, 1, 7] {
            check_roundtrip(&code, &backend, iter, ds.cols);
        }
    }

    #[test]
    fn minibatch_varies_with_iteration_but_not_worker() {
        let (code, ds) = setup(4, 1, 1);
        let backend = RustBackend::with_minibatch(&code, &ds, 0.4, 3).unwrap();
        let beta = vec![0.02f32; ds.cols];
        let g0 = backend.subset_gradient(0, 1, &beta);
        let g0_again = backend.subset_gradient(0, 1, &beta);
        let g1 = backend.subset_gradient(1, 1, &beta);
        assert_eq!(g0, g0_again, "same (iter, subset) must be deterministic");
        assert_ne!(g0, g1, "different iterations must resample");
    }

    #[test]
    fn backend_dims_are_consistent() {
        let (code, ds) = setup(6, 2, 2);
        let backend = RustBackend::new(&code, &ds).unwrap();
        assert_eq!(backend.dim(), ds.cols);
        assert_eq!(backend.out_dim(), ds.cols / 2);
    }

    #[test]
    fn minibatch_rejects_bad_fraction() {
        let (code, ds) = setup(4, 1, 1);
        assert!(RustBackend::with_minibatch(&code, &ds, 0.0, 1).is_err());
        assert!(RustBackend::with_minibatch(&code, &ds, 1.5, 1).is_err());
    }

    #[test]
    fn hetero_backend_reconstructs_weighted_full_gradient() {
        use crate::coding::HeteroCode;
        // Bimodal fleet: fast subsets carry more rows; the coded decode
        // must still equal the sum over *all* rows.
        let speeds = [1.0, 1.0, 1.0, 4.0, 4.0, 4.0];
        let code = HeteroCode::from_speeds(6, 1, 1, &speeds).unwrap();
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), 31);
        let ds = gen.generate(6 * 20, 33);
        let backend = RustBackend::new(&code, &ds).unwrap();
        // fast subsets got more rows than slow ones
        assert!(backend.subsets[5].rows > backend.subsets[0].rows);
        assert_eq!(
            backend.subsets.iter().map(|s| s.rows).sum::<usize>(),
            ds.rows - ds.rows % 6,
            "weighted split covers the same row prefix as the uniform one"
        );
        let beta = vec![0.01f32; ds.cols];
        let n = 6;
        let mut fs = Vec::new();
        for w in 0..n {
            let mut f = Vec::new();
            backend.encoded_gradient(w, 0, &beta, &mut f).unwrap();
            fs.push(f);
        }
        let avail: Vec<usize> = (0..n).filter(|&w| w != 4).collect();
        let dec = Decoder::new(&code, &avail).unwrap();
        let views: Vec<&[f32]> =
            dec.used_workers().iter().map(|&w| fs[w].as_slice()).collect();
        let got = dec.decode(&views).unwrap();
        let want = backend.full_gradient(0, &beta);
        let scale = want.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-20);
        for j in 0..got.len() {
            assert!(
                (got[j] - want[j]).abs() / scale < 1e-4,
                "coord {j}: {} vs {}",
                got[j],
                want[j]
            );
        }
    }
}
