//! The in-process cluster: runs the virtual workers on the shared
//! compute pool (real-time mode keeps dedicated threads), owns the
//! channels, and gathers per-iteration responses for the master.
//!
//! Virtual mode computes every worker's coded partial gradient for an
//! iteration concurrently on [`crate::pool`] (the `--threads` /
//! `GRADCODE_THREADS` knob bounds the parallelism; one thread is a
//! plain serial loop). Each virtual worker keeps its own delay-RNG
//! stream, so responder order and the virtual clock are bitwise
//! identical for any thread count.
//!
//! Gathers are fault-aware: duplicated deliveries are deduped, payloads
//! failing their CRC32 check are rejected (the sender is treated as a
//! straggler), and an unsatisfiable wait rule returns a partial
//! [`GatherResult`] with `satisfied = false` instead of panicking — the
//! trainer's degradation ladder decides what to do with it. Real-time
//! gathers run against a [`GatherPolicy`] deadline with task
//! re-broadcasts, so a silently dead worker can no longer hang an
//! iteration.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::backend::ComputeBackend;
use super::messages::{Task, WorkerResult};
use super::wire::crc32_f32s;
use super::worker::{DelayInjector, WorkerLoop};
use crate::chaos::{Effect, FaultKind, FaultPlan, GatherPolicy};
use crate::coding::SchemeConfig;
use crate::obs::{phase, Clock, Recorder};
use crate::rngs::Pcg64;
use crate::simulator::DelayParams;

/// How straggling and time are realized.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ExecutionMode {
    /// Collect all `n` results; responder order and the iteration clock
    /// come from sampled virtual delays. Deterministic given seeds.
    Virtual,
    /// Workers sleep `scale ×` their sampled delay; the master takes the
    /// first arrivals off the wire. Exercises the real racy path.
    RealTime { scale: f64 },
}

/// When the master stops gathering and proceeds to decode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WaitRule {
    /// Proceed at the first `count` healthy arrivals (the scheme's
    /// `n - s`, or a quorum override).
    Count(usize),
    /// Proceed once every group has its quorum: `(members, need)` pairs
    /// from [`crate::coding::GradientCode::group_quorums`]. Lets the
    /// heterogeneous schemes stop before slack groups' slow tails.
    PerGroup(Vec<(Vec<usize>, usize)>),
    /// [`WaitRule::Count`] with an explicit per-iteration gather
    /// deadline: proceed at `count` healthy arrivals, or with whatever
    /// arrived when `timeout` expires (after the policy's re-broadcast
    /// retries). Virtual mode treats it exactly like `Count` — virtual
    /// gathers count every worker once and cannot hang.
    Deadline { count: usize, timeout: Duration },
}

impl WaitRule {
    /// Fewest responders that can satisfy the rule.
    pub fn min_responders(&self) -> usize {
        match self {
            WaitRule::Count(c) => *c,
            WaitRule::PerGroup(gs) => gs.iter().map(|(_, need)| need).sum(),
            WaitRule::Deadline { count, .. } => *count,
        }
    }

    fn validate(&self, n: usize) {
        match self {
            WaitRule::Count(c) | WaitRule::Deadline { count: c, .. } => {
                assert!(*c >= 1 && *c <= n, "quorum {c} must be in 1..={n}")
            }
            WaitRule::PerGroup(gs) => {
                assert!(!gs.is_empty(), "per-group rule needs groups");
                let mut seen = vec![false; n];
                for (members, need) in gs {
                    assert!(
                        *need >= 1 && *need <= members.len(),
                        "group quorum {need} must be in 1..={}",
                        members.len()
                    );
                    for &w in members {
                        assert!(w < n, "group member {w} out of range");
                        assert!(!seen[w], "worker {w} in two groups");
                        seen[w] = true;
                    }
                }
                // Fail at spawn, not on the first gather: every worker
                // must belong to exactly one group.
                assert!(
                    seen.iter().all(|&x| x),
                    "per-group rule must cover every worker"
                );
            }
        }
    }
}

/// Tracks gather progress against a [`WaitRule`].
struct QuorumTracker {
    /// worker -> group index (0 for the flat rule).
    group_of: Vec<usize>,
    have: Vec<usize>,
    need: Vec<usize>,
    /// Failures a group can still absorb.
    fail_slack: Vec<usize>,
    satisfied_groups: usize,
}

impl QuorumTracker {
    fn new(rule: &WaitRule, n: usize) -> Self {
        match rule {
            WaitRule::Count(c) | WaitRule::Deadline { count: c, .. } => QuorumTracker {
                group_of: vec![0; n],
                have: vec![0],
                need: vec![*c],
                fail_slack: vec![n - c],
                satisfied_groups: 0,
            },
            WaitRule::PerGroup(gs) => {
                let mut group_of = vec![usize::MAX; n];
                let mut need = Vec::new();
                let mut fail_slack = Vec::new();
                for (gi, (members, need_g)) in gs.iter().enumerate() {
                    for &w in members {
                        group_of[w] = gi;
                    }
                    need.push(*need_g);
                    fail_slack.push(members.len() - need_g);
                }
                assert!(
                    group_of.iter().all(|&g| g != usize::MAX),
                    "per-group rule must cover every worker"
                );
                QuorumTracker {
                    group_of,
                    have: vec![0; gs.len()],
                    need,
                    fail_slack,
                    satisfied_groups: 0,
                }
            }
        }
    }

    /// Record a healthy arrival; returns true once the rule is satisfied.
    fn arrive(&mut self, worker: usize) -> bool {
        let g = self.group_of[worker];
        self.have[g] += 1;
        if self.have[g] == self.need[g] {
            self.satisfied_groups += 1;
        }
        self.satisfied_groups == self.need.len()
    }

    /// Record a failure; returns false when the rule became unsatisfiable.
    fn fail(&mut self, worker: usize) -> bool {
        let g = self.group_of[worker];
        if self.fail_slack[g] == 0 {
            return false;
        }
        self.fail_slack[g] -= 1;
        true
    }
}

/// Per-worker delay scaling for heterogeneous fleets: relative speeds
/// and compute loads in baseline-subset units (see
/// [`crate::coding::GradientCode::compute_units`]). Homogeneous
/// clusters use `speed = 1, work = d` implicitly.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetProfile {
    pub speeds: Vec<f64>,
    pub work: Vec<f64>,
}

/// Result of one gathered iteration.
#[derive(Debug)]
pub struct GatherResult {
    /// Results ordered by (virtual or wall-clock) arrival. Virtual mode
    /// collects all healthy workers; real-time mode only those gathered
    /// before the rule was met (or the deadline expired).
    pub results: Vec<WorkerResult>,
    /// Leading results that satisfy the wait rule — the responder set
    /// the master decodes from (`results[..quorum_len]`). When the rule
    /// went unsatisfied this is simply `results.len()`.
    pub quorum_len: usize,
    /// Iteration runtime on the relevant clock (seconds): virtual finish
    /// of the arrival that satisfied the rule, or measured wall time.
    pub iteration_time: f64,
    /// Max measured worker compute among used responders.
    pub worker_compute: f64,
    /// Whether the wait rule was actually satisfied. When false the
    /// results are a best-effort partial set and the caller must degrade
    /// (partial decode / stale gradient) or abort.
    pub satisfied: bool,
    /// Workers whose results failed the CRC32 payload check this
    /// iteration (treated as stragglers, excluded from `results`).
    pub rejected: Vec<usize>,
    /// Duplicate deliveries discarded by the dedupe.
    pub duplicates: usize,
}

/// In-process master handle over `n` workers (pool tasks in virtual
/// mode, dedicated threads in real-time mode).
pub struct Cluster {
    cfg: SchemeConfig,
    mode: ExecutionMode,
    /// Gather stopping rule. Defaults to the scheme's `n - s`
    /// ([`WaitRule::Count`]); quorum overrides and the heterogeneous
    /// per-group rule arrive via [`Cluster::spawn_full`].
    rule: WaitRule,
    policy: GatherPolicy,
    chaos: Option<Arc<FaultPlan>>,
    backend: Arc<dyn ComputeBackend>,
    /// Virtual mode only: per-worker delay injectors, index = worker id.
    /// Each pool task locks only its own worker's slot, so the mutexes
    /// are uncontended — they exist to make the vector shareable across
    /// the fork/join region.
    injectors: Vec<Mutex<Option<DelayInjector>>>,
    task_txs: Vec<Sender<Task>>,
    results: Receiver<WorkerResult>,
    handles: Vec<JoinHandle<()>>,
    /// Telemetry sink. Disabled (zero-cost) unless the caller attaches
    /// an enabled recorder via [`Cluster::set_recorder`].
    obs: Recorder,
    /// Cumulative virtual time across gathers; anchors per-worker
    /// response spans on the virtual-clock timeline.
    virtual_clock: f64,
}

impl Cluster {
    /// Spawn the workers. `delays` enables §VI delay injection (scaled by
    /// the scheme's `d` and `m` per assumptions 1–2); `seed` drives all
    /// worker RNGs.
    pub fn spawn(
        cfg: SchemeConfig,
        backend: Arc<dyn ComputeBackend>,
        mode: ExecutionMode,
        delays: Option<DelayParams>,
        seed: u64,
    ) -> Self {
        let wait_for = cfg.wait_for();
        Self::spawn_with_quorum(cfg, backend, mode, delays, seed, wait_for)
    }

    /// [`Cluster::spawn`] with an explicit quorum: the master proceeds
    /// once `wait_for` responses for the current iteration have arrived
    /// instead of the scheme's exact `n - s`. Used by the approximate
    /// (partial-recovery) regime, where `wait_for` may be well below the
    /// exact-decode threshold.
    pub fn spawn_with_quorum(
        cfg: SchemeConfig,
        backend: Arc<dyn ComputeBackend>,
        mode: ExecutionMode,
        delays: Option<DelayParams>,
        seed: u64,
        wait_for: usize,
    ) -> Self {
        Self::spawn_full(cfg, backend, mode, delays, seed, WaitRule::Count(wait_for), None)
    }

    /// Full-control spawn: explicit [`WaitRule`] and optional per-worker
    /// [`FleetProfile`] (heterogeneous delay scaling). With
    /// `rule = Count(n - s)` and `profile = None` this is exactly
    /// [`Cluster::spawn`].
    pub fn spawn_full(
        cfg: SchemeConfig,
        backend: Arc<dyn ComputeBackend>,
        mode: ExecutionMode,
        delays: Option<DelayParams>,
        seed: u64,
        rule: WaitRule,
        profile: Option<FleetProfile>,
    ) -> Self {
        Self::spawn_chaos(
            cfg,
            backend,
            mode,
            delays,
            seed,
            rule,
            profile,
            None,
            GatherPolicy::default(),
        )
    }

    /// [`Cluster::spawn_full`] plus fault injection: every worker thread
    /// consults `chaos` per task, and real-time gathers run against
    /// `policy`'s deadline/retry schedule.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_chaos(
        cfg: SchemeConfig,
        backend: Arc<dyn ComputeBackend>,
        mode: ExecutionMode,
        delays: Option<DelayParams>,
        seed: u64,
        rule: WaitRule,
        profile: Option<FleetProfile>,
        chaos: Option<Arc<FaultPlan>>,
        policy: GatherPolicy,
    ) -> Self {
        rule.validate(cfg.n);
        if let Some(p) = &profile {
            assert_eq!(p.speeds.len(), cfg.n, "one speed per worker");
            assert_eq!(p.work.len(), cfg.n, "one load per worker");
        }
        if let Some(plan) = &chaos {
            assert_eq!(plan.n(), cfg.n, "fault plan sized for a different fleet");
        }
        let (result_tx, result_rx) = channel::<WorkerResult>();
        let mut task_txs = Vec::new();
        let mut handles = Vec::new();
        let mut injectors = Vec::new();
        let mut root = Pcg64::seed_from_u64(seed);
        for w in 0..cfg.n {
            let (work, speed) = match &profile {
                Some(p) => (p.work[w], p.speeds[w]),
                None => (cfg.d as f64, 1.0),
            };
            // The fork order (and thus every worker's delay stream) is
            // identical in both modes and unchanged from the threaded
            // implementation, so seeds reproduce across versions.
            let injector = delays
                .as_ref()
                .map(|p| DelayInjector::scaled(p, work, speed, cfg.m, root.fork(w as u64 + 1)));
            match mode {
                ExecutionMode::Virtual => {
                    // Virtual workers are pool tasks, not threads: the
                    // injector stays with the master and is sampled
                    // inside the per-iteration fork/join region.
                    injectors.push(Mutex::new(injector));
                }
                ExecutionMode::RealTime { scale } => {
                    let (task_tx, task_rx) = channel::<Task>();
                    task_txs.push(task_tx);
                    let looper = WorkerLoop {
                        id: w,
                        backend: Arc::clone(&backend),
                        tasks: task_rx,
                        results: result_tx.clone(),
                        delays: injector,
                        sleep_scale: scale,
                        skip_stale: true,
                        chaos: chaos.as_ref().map(Arc::clone),
                        tombstone_faults: false,
                    };
                    handles.push(
                        std::thread::Builder::new()
                            .name(format!("gradcode-worker-{w}"))
                            .spawn(move || looper.run())
                            // lint: allow(panic-in-lib) startup-time spawn failure is unrecoverable; no distributed state exists yet
                            .expect("spawn worker"),
                    );
                }
            }
        }
        Cluster {
            cfg,
            mode,
            rule,
            policy,
            chaos,
            backend,
            injectors,
            task_txs,
            results: result_rx,
            handles,
            obs: Recorder::disabled(),
            virtual_clock: 0.0,
        }
    }

    pub fn n(&self) -> usize {
        self.cfg.n
    }

    /// Attach a telemetry recorder: subsequent gathers emit
    /// broadcast/gather-wait spans, per-worker response spans on the
    /// virtual (or wall) timeline, wait-rule outcome counters, and the
    /// per-worker aggregates behind the straggler report.
    pub fn set_recorder(&mut self, rec: &Recorder) {
        self.obs = rec.clone();
    }

    /// Fewest responses that satisfy the wait rule (the exact `n - s`
    /// for the flat rule).
    pub fn wait_for(&self) -> usize {
        self.rule.min_responders()
    }

    /// The gather stopping rule.
    pub fn rule(&self) -> &WaitRule {
        &self.rule
    }

    /// The fault plan threaded through the workers, if any.
    pub fn chaos(&self) -> Option<&Arc<FaultPlan>> {
        self.chaos.as_ref()
    }

    fn crc_ok(r: &WorkerResult) -> bool {
        match r.crc {
            Some(c) => crc32_f32s(&r.f) == c,
            None => true,
        }
    }

    /// One virtual worker's report(s) for one iteration — exactly the
    /// per-task behaviour the dedicated worker threads used to have
    /// (see [`WorkerLoop`], which real-time mode still runs), inlined
    /// as a pool task so all `n` workers compute concurrently. Returns
    /// one message, or two under a duplicate fault.
    fn virtual_worker_reports(
        w: usize,
        iter: usize,
        beta: &[f32],
        backend: &dyn ComputeBackend,
        injector: &Mutex<Option<DelayInjector>>,
        chaos: Option<&FaultPlan>,
    ) -> Vec<WorkerResult> {
        // Sample the delay before consulting the plan so the delay RNG
        // stream stays aligned with a fault-free run of the same seed.
        let mut virtual_finish = {
            let mut inj = injector.lock().unwrap_or_else(|e| e.into_inner());
            inj.as_mut().map_or(0.0, |d| d.sample())
        };
        let effect = chaos.map_or(Effect::None, |p| p.effect(w, iter as u64));
        if effect.is_silent() {
            // Virtual gathers count every worker exactly once, so a
            // silent fault must still report: tombstone.
            return vec![WorkerResult {
                worker: w,
                iter,
                f: Vec::new(),
                virtual_finish,
                compute_secs: 0.0,
                failed: true,
                crc: None,
            }];
        }
        if let Effect::Fault(FaultKind::Delay(secs)) = effect {
            virtual_finish += secs;
        }
        // lint: allow(wallclock-entropy) realized latency metric only; never feeds seeds or decisions
        let t0 = Instant::now();
        let mut out = Vec::new();
        let failed = match backend.encoded_gradient(w, iter, beta, &mut out) {
            Ok(()) => false,
            Err(e) => {
                // A failed worker behaves like a straggler, but it must
                // still report. The master tolerates up to s.
                eprintln!("worker {w}: backend error: {e}");
                out.clear();
                true
            }
        };
        let compute_secs = t0.elapsed().as_secs_f64();
        // Checksum the TRUE payload, then corrupt: the master's CRC
        // check must flag the flipped bit exactly like the TCP frame
        // checksum would.
        let crc = chaos.map(|_| crc32_f32s(&out));
        if matches!(effect, Effect::Fault(FaultKind::Corrupt)) && !out.is_empty() {
            let idx = (iter * 31 + w) % out.len();
            out[idx] = f32::from_bits(out[idx].to_bits() ^ 1);
        }
        let msg = WorkerResult {
            worker: w,
            iter,
            f: out,
            virtual_finish,
            compute_secs,
            failed,
            crc,
        };
        if matches!(effect, Effect::Fault(FaultKind::Duplicate)) {
            vec![msg.clone(), msg]
        } else {
            vec![msg]
        }
    }

    /// Wait-rule outcome counters for one gather (enabled recorders only).
    fn record_gather_counters(&self, satisfied: bool, rejected: &[usize], duplicates: usize) {
        self.obs
            .add(if satisfied { "gather.satisfied" } else { "gather.unsatisfied" }, 1);
        if !rejected.is_empty() {
            self.obs.add("gather.crc_rejects", rejected.len() as i64);
        }
        if duplicates > 0 {
            self.obs.add("gather.duplicates", duplicates as i64);
        }
    }

    /// Broadcast an iteration and gather responses.
    ///
    /// Virtual mode: computes all `n` coded partial gradients
    /// concurrently on [`crate::pool`] and collects one report per
    /// worker (silent faults tombstone, so this cannot hang; results
    /// are bitwise identical for any thread count), sorts by virtual
    /// finish, returns all healthy ones; `quorum_len` marks the shortest arrival
    /// prefix that satisfies the wait rule (the trainer decodes from that
    /// prefix). Real-time mode: returns once the rule is satisfied by the
    /// arrived results, or when the gather deadline expires after the
    /// policy's re-broadcast retries; stale results from previous
    /// iterations are discarded. Either way, too few healthy responders
    /// yields `satisfied = false` rather than a panic.
    pub fn run_iteration(&mut self, iter: usize, beta: Arc<Vec<f32>>) -> GatherResult {
        // lint: allow(wallclock-entropy) realized latency metric only; never feeds seeds or decisions
        let t0 = Instant::now();
        let ts0 = self.obs.now();
        {
            let _b = self.obs.span(phase::BROADCAST).iter(iter as u64);
            // Virtual mode has no task channels (workers are pool tasks);
            // the span is still recorded so phase counters are mode-
            // independent.
            for tx in &self.task_txs {
                // A dead worker (backend error) is a permanent straggler; the
                // send fails silently and the decode path handles the gap.
                let _ = tx.send(Task { iter, beta: Arc::clone(&beta) });
            }
        }
        let n = self.cfg.n;
        let mut results: Vec<WorkerResult> = Vec::with_capacity(n);
        let mut seen = vec![false; n];
        let mut duplicates = 0usize;
        let mut rejected: Vec<usize> = Vec::new();
        match self.mode {
            ExecutionMode::Virtual => {
                // All n coded partial gradients for this iteration are
                // computed concurrently on the shared pool instead of by
                // dedicated worker threads. Every worker reports exactly
                // once: backend failures and injected silent faults
                // report `failed = true` tombstones rather than going
                // silent, and duplicate faults are deduped before
                // counting — so the gather is deterministic and cannot
                // hang, for any thread count.
                let reports: Vec<Vec<WorkerResult>> = {
                    let _g = self.obs.span(phase::GATHER_WAIT).iter(iter as u64);
                    let backend = self.backend.as_ref();
                    let injectors = &self.injectors;
                    let chaos = self.chaos.as_deref();
                    let beta_ref: &[f32] = &beta;
                    crate::pool::global().map_indexed(n, |w| {
                        Self::virtual_worker_reports(
                            w,
                            iter,
                            beta_ref,
                            backend,
                            &injectors[w],
                            chaos,
                        )
                    })
                };
                for r in reports.into_iter().flatten() {
                    if seen[r.worker] {
                        duplicates += 1;
                        continue;
                    }
                    seen[r.worker] = true;
                    if r.failed {
                        continue;
                    }
                    if !Self::crc_ok(&r) {
                        rejected.push(r.worker);
                        continue;
                    }
                    results.push(r);
                }
                results.sort_by(|a, b| {
                    a.virtual_finish.total_cmp(&b.virtual_finish)
                });
                // Shortest arrival prefix satisfying the rule.
                let mut tracker = QuorumTracker::new(&self.rule, n);
                let mut prefix = None;
                for (i, r) in results.iter().enumerate() {
                    if tracker.arrive(r.worker) {
                        prefix = Some(i + 1);
                        break;
                    }
                }
                let satisfied = prefix.is_some();
                let quorum_len = prefix.unwrap_or(results.len());
                let iteration_time = if quorum_len > 0 {
                    results[quorum_len - 1].virtual_finish
                } else {
                    0.0
                };
                let worker_compute = results[..quorum_len]
                    .iter()
                    .map(|r| r.compute_secs)
                    .fold(0.0, f64::max);
                if self.obs.is_enabled() {
                    // Anchor each response span at the cumulative virtual
                    // clock so the Chrome trace lays iterations end to end.
                    let base = self.virtual_clock;
                    for (i, r) in results.iter().enumerate() {
                        self.obs.record_worker_response(
                            r.worker,
                            iter as u64,
                            base,
                            r.virtual_finish,
                            i < quorum_len,
                            Clock::Virtual,
                        );
                        self.obs.observe(phase::WORKER_COMPUTE, r.compute_secs);
                    }
                    let mut healthy = vec![false; n];
                    for r in &results {
                        healthy[r.worker] = true;
                    }
                    for (w, ok) in healthy.iter().enumerate() {
                        if !ok {
                            self.obs.worker_missed(w, iter as u64);
                        }
                    }
                    self.record_gather_counters(satisfied, &rejected, duplicates);
                }
                self.virtual_clock += iteration_time;
                GatherResult {
                    results,
                    quorum_len,
                    iteration_time,
                    worker_compute,
                    satisfied,
                    rejected,
                    duplicates,
                }
            }
            ExecutionMode::RealTime { .. } => {
                let deadline = match &self.rule {
                    WaitRule::Deadline { timeout, .. } => *timeout,
                    _ => self.policy.deadline,
                };
                let slice = deadline / (self.policy.retries + 1).max(1);
                let mut retries_left = self.policy.retries;
                let mut tracker = QuorumTracker::new(&self.rule, n);
                let mut satisfied = false;
                let mut received = 0usize;
                let mut arrivals: Vec<f64> = Vec::new();
                {
                    let _g = self.obs.span(phase::GATHER_WAIT).iter(iter as u64);
                    while !satisfied && received < n {
                        match self.results.recv_timeout(slice) {
                            Ok(r) if r.iter == iter => {
                                if seen[r.worker] {
                                    duplicates += 1;
                                    continue;
                                }
                                seen[r.worker] = true;
                                received += 1;
                                if r.failed || !Self::crc_ok(&r) {
                                    if !Self::crc_ok(&r) {
                                        rejected.push(r.worker);
                                    }
                                    // An unsatisfiable rule is not fatal any
                                    // more: keep gathering — later arrivals
                                    // still feed the degraded decode.
                                    let _ = tracker.fail(r.worker);
                                } else {
                                    satisfied = tracker.arrive(r.worker);
                                    arrivals.push(t0.elapsed().as_secs_f64());
                                    results.push(r);
                                }
                            }
                            Ok(_) => continue, // stale from a previous iteration
                            Err(RecvTimeoutError::Timeout) => {
                                if retries_left == 0 {
                                    break; // deadline spent: degrade with what we have
                                }
                                retries_left -= 1;
                                std::thread::sleep(self.policy.backoff);
                                // Re-prod only the workers we haven't heard from.
                                for (w, tx) in self.task_txs.iter().enumerate() {
                                    if !seen[w] {
                                        let _ =
                                            tx.send(Task { iter, beta: Arc::clone(&beta) });
                                    }
                                }
                            }
                            Err(RecvTimeoutError::Disconnected) => break, // all workers gone
                        }
                    }
                }
                let iteration_time = t0.elapsed().as_secs_f64();
                if self.obs.is_enabled() {
                    for (r, lat) in results.iter().zip(&arrivals) {
                        // Real-time responses all contributed to the rule
                        // attempt; workers the rule never heard from show
                        // up as misses below.
                        self.obs.record_worker_response(
                            r.worker,
                            iter as u64,
                            ts0,
                            *lat,
                            true,
                            Clock::Wall,
                        );
                        self.obs.observe(phase::WORKER_COMPUTE, r.compute_secs);
                    }
                    for (w, &heard) in seen.iter().enumerate() {
                        let healthy = results.iter().any(|r| r.worker == w);
                        if !heard || !healthy {
                            self.obs.worker_missed(w, iter as u64);
                        }
                    }
                    self.record_gather_counters(satisfied, &rejected, duplicates);
                }
                let worker_compute =
                    results.iter().map(|r| r.compute_secs).fold(0.0, f64::max);
                let quorum_len = results.len();
                GatherResult {
                    results,
                    quorum_len,
                    iteration_time,
                    worker_compute,
                    satisfied,
                    rejected,
                    duplicates,
                }
            }
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.task_txs.clear(); // close task channels -> workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::FaultKind;
    use crate::coding::{GradientCode, HeteroCode, PolynomialCode};
    use crate::coordinator::backend::RustBackend;
    use crate::data::{CategoricalConfig, SyntheticCategorical};
    use crate::simulator::SpeedProfile;

    fn setup(
        n: usize,
        s: usize,
        m: usize,
    ) -> (Arc<PolynomialCode>, Arc<RustBackend>, usize) {
        let code =
            Arc::new(PolynomialCode::new(SchemeConfig::tight(n, s, m).unwrap()).unwrap());
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), 41);
        let ds = SyntheticCategorical::pad_to_multiple(&gen.generate(n * 12, 42), m);
        let backend = Arc::new(RustBackend::new(code.as_ref(), &ds).unwrap());
        let l = ds.cols;
        (code, backend, l)
    }

    fn spawn_with_plan(
        n: usize,
        s: usize,
        m: usize,
        mode: ExecutionMode,
        plan: FaultPlan,
        policy: GatherPolicy,
    ) -> (Cluster, usize) {
        let (code, backend, l) = setup(n, s, m);
        let cfg = *code.config();
        let rule = WaitRule::Count(cfg.wait_for());
        let cluster = Cluster::spawn_chaos(
            cfg,
            backend,
            mode,
            Some(DelayParams::table_vi1()),
            11,
            rule,
            None,
            Some(Arc::new(plan)),
            policy,
        );
        (cluster, l)
    }

    #[test]
    fn virtual_mode_gathers_all_and_orders() {
        let (code, backend, l) = setup(5, 1, 2);
        let mut cluster = Cluster::spawn(
            *code.config(),
            backend,
            ExecutionMode::Virtual,
            Some(DelayParams::table_vi1()),
            1,
        );
        let beta = Arc::new(vec![0.0f32; l]);
        for iter in 0..3 {
            let g = cluster.run_iteration(iter, Arc::clone(&beta));
            assert_eq!(g.results.len(), 5);
            assert_eq!(g.quorum_len, 4);
            assert!(g.satisfied);
            assert!(g.rejected.is_empty());
            assert_eq!(g.duplicates, 0);
            for w in g.results.windows(2) {
                assert!(w[0].virtual_finish <= w[1].virtual_finish);
            }
            assert_eq!(g.iteration_time, g.results[3].virtual_finish);
            for r in &g.results {
                assert_eq!(r.f.len(), l / 2);
                assert_eq!(r.iter, iter);
                assert!(r.crc.is_none(), "no chaos, no checksum");
            }
        }
    }

    #[test]
    fn realtime_mode_returns_after_quorum() {
        let (code, backend, l) = setup(5, 2, 1);
        let mut cluster = Cluster::spawn(
            *code.config(),
            backend,
            // tiny sleep scale so the test is fast but ordering is racy
            ExecutionMode::RealTime { scale: 1e-4 },
            Some(DelayParams::table_vi1()),
            2,
        );
        let beta = Arc::new(vec![0.0f32; l]);
        for iter in 0..3 {
            let g = cluster.run_iteration(iter, Arc::clone(&beta));
            assert!(g.results.len() >= 3, "quorum is n-s = 3");
            assert_eq!(g.quorum_len, g.results.len());
            assert!(g.satisfied);
            assert!(g.results.iter().all(|r| r.iter == iter));
        }
    }

    #[test]
    fn quorum_override_changes_the_cutoff() {
        // Same scheme, quorum forced below the exact n - s: the virtual
        // clock must advance only to the 3rd arrival.
        let (code, backend, l) = setup(5, 1, 2);
        let mut cluster = Cluster::spawn_with_quorum(
            *code.config(),
            backend,
            ExecutionMode::Virtual,
            Some(DelayParams::table_vi1()),
            9,
            3,
        );
        assert_eq!(cluster.wait_for(), 3);
        let g = cluster.run_iteration(0, Arc::new(vec![0.0f32; l]));
        assert_eq!(g.results.len(), 5, "virtual mode still collects everyone");
        assert_eq!(g.quorum_len, 3);
        assert_eq!(g.iteration_time, g.results[2].virtual_finish);
    }

    #[test]
    fn quorum_override_in_realtime_returns_at_quorum() {
        let (code, backend, l) = setup(5, 1, 2);
        let mut cluster = Cluster::spawn_with_quorum(
            *code.config(),
            backend,
            ExecutionMode::RealTime { scale: 1e-4 },
            Some(DelayParams::table_vi1()),
            10,
            3,
        );
        for iter in 0..2 {
            let g = cluster.run_iteration(iter, Arc::new(vec![0.0f32; l]));
            assert_eq!(g.results.len(), 3, "real-time gather stops at the quorum");
        }
    }

    #[test]
    fn no_delay_injection_gives_zero_virtual_time() {
        let (code, backend, l) = setup(4, 1, 1);
        let mut cluster =
            Cluster::spawn(*code.config(), backend, ExecutionMode::Virtual, None, 3);
        let g = cluster.run_iteration(0, Arc::new(vec![0.0f32; l]));
        assert!(g.results.iter().all(|r| r.virtual_finish == 0.0));
    }

    #[test]
    fn per_group_rule_stops_before_flat_n_minus_s() {
        // Bimodal fleet: the fast group has slack (d_g > s + m), so its
        // quorum is small and the rule can be met before n - s arrivals.
        let speeds = SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 }.speeds(10);
        let code = Arc::new(HeteroCode::from_speeds(10, 1, 2, &speeds).unwrap());
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), 43);
        let ds = SyntheticCategorical::pad_to_multiple(&gen.generate(160, 44), 2);
        let backend = Arc::new(RustBackend::new(code.as_ref(), &ds).unwrap());
        let rule = WaitRule::PerGroup(code.group_quorums().unwrap());
        assert!(rule.min_responders() < 9);
        let profile = FleetProfile {
            speeds: speeds.clone(),
            work: (0..10).map(|w| code.compute_units(w)).collect(),
        };
        let mut cluster = Cluster::spawn_full(
            *code.config(),
            backend,
            ExecutionMode::Virtual,
            Some(DelayParams::ec2_fit()),
            5,
            rule,
            Some(profile),
        );
        let beta = Arc::new(vec![0.0f32; ds.cols]);
        for iter in 0..4 {
            let g = cluster.run_iteration(iter, Arc::clone(&beta));
            assert_eq!(g.results.len(), 10);
            assert!(g.quorum_len <= 9, "rule met by arrival {}", g.quorum_len);
            assert_eq!(g.iteration_time, g.results[g.quorum_len - 1].virtual_finish);
            // the prefix really is decodable
            let responders: Vec<usize> =
                g.results[..g.quorum_len].iter().map(|r| r.worker).collect();
            assert!(code.decode_weights(&responders).is_ok());
        }
    }

    #[test]
    fn hetero_profile_shifts_fast_workers_earlier() {
        // With a strongly bimodal profile and balanced work, fast workers
        // still finish no later on average than under uniform injection
        // with the same seed; smoke-check that per-worker scaling is
        // actually applied by comparing mean finish of slow vs fast tier
        // under *unbalanced* work (uniform d).
        let (code, backend, l) = setup(6, 1, 1);
        let speeds = vec![1.0, 1.0, 1.0, 8.0, 8.0, 8.0];
        let profile =
            FleetProfile { speeds, work: vec![code.config().d as f64; 6] };
        // Compute-dominant params: speed scaling applies to computation
        // only, so a tiny communication share keeps the contrast visible.
        let params = DelayParams { lambda1: 0.8, t1: 1.6, lambda2: 10.0, t2: 0.1 };
        let mut cluster = Cluster::spawn_full(
            *code.config(),
            Arc::clone(&backend) as Arc<dyn ComputeBackend>,
            ExecutionMode::Virtual,
            Some(params),
            7,
            WaitRule::Count(5),
            Some(profile),
        );
        let beta = Arc::new(vec![0.0f32; l]);
        let mut slow_mean = 0.0;
        let mut fast_mean = 0.0;
        for iter in 0..20 {
            let g = cluster.run_iteration(iter, Arc::clone(&beta));
            for r in &g.results {
                if r.worker < 3 {
                    slow_mean += r.virtual_finish;
                } else {
                    fast_mean += r.virtual_finish;
                }
            }
        }
        assert!(
            fast_mean < slow_mean * 0.7,
            "fast tier should finish much earlier: {fast_mean} vs {slow_mean}"
        );
    }

    #[test]
    fn wait_rule_helpers() {
        assert_eq!(WaitRule::Count(4).min_responders(), 4);
        let dl = WaitRule::Deadline { count: 3, timeout: Duration::from_secs(1) };
        assert_eq!(dl.min_responders(), 3);
        let rule = WaitRule::PerGroup(vec![(vec![0, 1, 2], 2), (vec![3, 4], 1)]);
        assert_eq!(rule.min_responders(), 3);
        let mut t = QuorumTracker::new(&rule, 5);
        assert!(!t.arrive(0));
        assert!(!t.arrive(3)); // fast group satisfied, slow not
        assert!(t.arrive(2));
        let mut t = QuorumTracker::new(&rule, 5);
        assert!(t.fail(0), "slow group absorbs one failure");
        assert!(!t.fail(1), "second slow failure breaks the quorum");
    }

    #[test]
    fn chaos_crash_excludes_worker_in_virtual_mode() {
        // n=5, s=1: one permanent crash is within tolerance.
        let mut plan = FaultPlan::new(5);
        plan.schedule(2, 1, FaultKind::Crash { restart_after: None });
        let (mut cluster, l) =
            spawn_with_plan(5, 1, 2, ExecutionMode::Virtual, plan, GatherPolicy::default());
        let beta = Arc::new(vec![0.0f32; l]);
        let g0 = cluster.run_iteration(0, Arc::clone(&beta));
        assert_eq!(g0.results.len(), 5, "no fault before the crash iteration");
        for iter in 1..4 {
            let g = cluster.run_iteration(iter, Arc::clone(&beta));
            assert_eq!(g.results.len(), 4, "crashed worker tombstones");
            assert!(g.satisfied, "n - s = 4 responders still satisfy the rule");
            assert!(g.results.iter().all(|r| r.worker != 2));
        }
    }

    #[test]
    fn chaos_corrupt_payload_is_rejected_by_crc() {
        let mut plan = FaultPlan::new(5);
        plan.schedule(0, 0, FaultKind::Corrupt);
        let (mut cluster, l) =
            spawn_with_plan(5, 1, 2, ExecutionMode::Virtual, plan, GatherPolicy::default());
        let g = cluster.run_iteration(0, Arc::new(vec![0.0f32; l]));
        assert_eq!(g.rejected, vec![0], "flipped bit must fail the checksum");
        assert_eq!(g.results.len(), 4);
        assert!(g.satisfied);
        assert!(g.results.iter().all(|r| r.worker != 0));
        // after the one-shot fault the worker is healthy again
        let g = cluster.run_iteration(1, Arc::new(vec![0.0f32; l]));
        assert!(g.rejected.is_empty());
        assert_eq!(g.results.len(), 5);
    }

    #[test]
    fn chaos_duplicate_results_are_deduped() {
        let mut plan = FaultPlan::new(5);
        plan.schedule(3, 0, FaultKind::Duplicate);
        let (mut cluster, l) =
            spawn_with_plan(5, 1, 2, ExecutionMode::Virtual, plan, GatherPolicy::default());
        let g = cluster.run_iteration(0, Arc::new(vec![0.0f32; l]));
        assert_eq!(g.duplicates, 1);
        assert_eq!(g.results.len(), 5, "the duplicate is discarded, not double-counted");
        assert!(g.satisfied);
    }

    #[test]
    fn too_many_crashes_degrade_instead_of_panicking() {
        // n=5, s=1 but two permanent crashes: the old gather panicked;
        // now it returns everything it has with satisfied = false.
        let mut plan = FaultPlan::new(5);
        plan.schedule(1, 0, FaultKind::Crash { restart_after: None });
        plan.schedule(4, 0, FaultKind::Crash { restart_after: None });
        let (mut cluster, l) =
            spawn_with_plan(5, 1, 2, ExecutionMode::Virtual, plan, GatherPolicy::default());
        let g = cluster.run_iteration(0, Arc::new(vec![0.0f32; l]));
        assert!(!g.satisfied);
        assert_eq!(g.results.len(), 3);
        assert_eq!(g.quorum_len, 3, "unsatisfied gather exposes all survivors");
    }

    #[test]
    fn recorder_captures_gather_telemetry() {
        let (code, backend, l) = setup(5, 1, 2);
        let mut cluster = Cluster::spawn(
            *code.config(),
            backend,
            ExecutionMode::Virtual,
            Some(DelayParams::table_vi1()),
            1,
        );
        let rec = Recorder::enabled();
        cluster.set_recorder(&rec);
        let beta = Arc::new(vec![0.0f32; l]);
        for iter in 0..3 {
            cluster.run_iteration(iter, Arc::clone(&beta));
        }
        let s = rec.summary();
        let count_of = |name: &str| {
            s.phases.iter().find(|p| p.phase == name).map(|p| p.count).unwrap_or(0)
        };
        assert_eq!(count_of(phase::BROADCAST), 3);
        assert_eq!(count_of(phase::GATHER_WAIT), 3);
        assert_eq!(count_of(phase::WORKER_COMPUTE), 15, "5 workers × 3 iterations");
        let workers = &s.stragglers.workers;
        assert_eq!(workers.len(), 5);
        assert_eq!(workers.iter().map(|w| w.responses).sum::<u64>(), 15);
        // the quorum prefix is n - s = 4 each iteration
        assert_eq!(workers.iter().map(|w| w.used).sum::<u64>(), 12);
        assert_eq!(workers.iter().map(|w| w.straggled).sum::<u64>(), 3);
        assert_eq!(workers.iter().map(|w| w.missed).sum::<u64>(), 0);
        assert!(s.counters.contains(&("gather.satisfied".to_string(), 3)));
        // the virtual timeline anchors response spans end to end across iterations
        let evs = rec.events();
        assert!(evs.iter().any(|e| matches!(e,
            crate::obs::TraceEvent::Span { clock: Clock::Virtual, ts, .. } if *ts > 0.0)));
    }

    #[test]
    fn realtime_gather_deadline_breaks_the_silent_worker_hang() {
        // A genuinely silent worker in real-time mode used to block the
        // gather forever; the deadline now returns a partial result.
        let mut plan = FaultPlan::new(4);
        plan.schedule(1, 0, FaultKind::Crash { restart_after: None });
        let policy = GatherPolicy {
            deadline: Duration::from_millis(300),
            retries: 1,
            backoff: Duration::from_millis(1),
        };
        let (mut cluster, l) =
            spawn_with_plan(4, 0, 1, ExecutionMode::RealTime { scale: 1e-4 }, plan, policy);
        let t0 = Instant::now();
        let g = cluster.run_iteration(0, Arc::new(vec![0.0f32; l]));
        assert!(!g.satisfied, "rule needs all 4, only 3 can answer");
        assert_eq!(g.results.len(), 3);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "gather must end at the deadline, not hang"
        );
    }
}
