//! The in-process cluster: spawns worker threads, owns the channels, and
//! gathers per-iteration responses for the master.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::backend::ComputeBackend;
use super::messages::{Task, WorkerResult};
use super::worker::{DelayInjector, WorkerLoop};
use crate::coding::SchemeConfig;
use crate::rngs::{Pcg64, ShiftedExponential};
use crate::simulator::DelayParams;

/// How straggling and time are realized.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ExecutionMode {
    /// Collect all `n` results; responder order and the iteration clock
    /// come from sampled virtual delays. Deterministic given seeds.
    Virtual,
    /// Workers sleep `scale ×` their sampled delay; the master takes the
    /// first `n-s` arrivals off the wire. Exercises the real racy path.
    RealTime { scale: f64 },
}

/// Result of one gathered iteration.
#[derive(Debug)]
pub struct GatherResult {
    /// Results ordered by (virtual or wall-clock) arrival.
    pub results: Vec<WorkerResult>,
    /// Iteration runtime on the relevant clock (seconds): virtual finish
    /// of the `(n-s)`-th responder, or measured wall time.
    pub iteration_time: f64,
    /// Max measured worker compute among used responders.
    pub worker_compute: f64,
}

/// In-process master handle over `n` worker threads.
pub struct Cluster {
    cfg: SchemeConfig,
    mode: ExecutionMode,
    /// Responses gathered per iteration before the master proceeds.
    /// Defaults to the scheme's `n - s`; the quorum policy of the
    /// approximate regime overrides it (see [`Cluster::spawn_with_quorum`]).
    wait_for: usize,
    task_txs: Vec<Sender<Task>>,
    results: Receiver<WorkerResult>,
    handles: Vec<JoinHandle<()>>,
}

impl Cluster {
    /// Spawn the workers. `delays` enables §VI delay injection (scaled by
    /// the scheme's `d` and `m` per assumptions 1–2); `seed` drives all
    /// worker RNGs.
    pub fn spawn(
        cfg: SchemeConfig,
        backend: Arc<dyn ComputeBackend>,
        mode: ExecutionMode,
        delays: Option<DelayParams>,
        seed: u64,
    ) -> Self {
        let wait_for = cfg.wait_for();
        Self::spawn_with_quorum(cfg, backend, mode, delays, seed, wait_for)
    }

    /// [`Cluster::spawn`] with an explicit quorum: the master proceeds
    /// once `wait_for` responses for the current iteration have arrived
    /// instead of the scheme's exact `n - s`. Used by the approximate
    /// (partial-recovery) regime, where `wait_for` may be well below the
    /// exact-decode threshold.
    pub fn spawn_with_quorum(
        cfg: SchemeConfig,
        backend: Arc<dyn ComputeBackend>,
        mode: ExecutionMode,
        delays: Option<DelayParams>,
        seed: u64,
        wait_for: usize,
    ) -> Self {
        assert!(
            wait_for >= 1 && wait_for <= cfg.n,
            "quorum {wait_for} must be in 1..={}",
            cfg.n
        );
        let (result_tx, result_rx) = channel::<WorkerResult>();
        let mut task_txs = Vec::with_capacity(cfg.n);
        let mut handles = Vec::with_capacity(cfg.n);
        let mut root = Pcg64::seed_from_u64(seed);
        for w in 0..cfg.n {
            let (task_tx, task_rx) = channel::<Task>();
            task_txs.push(task_tx);
            let injector = delays.as_ref().map(|p| {
                DelayInjector::new(
                    ShiftedExponential::new(cfg.d as f64 * p.t1, p.lambda1 / cfg.d as f64),
                    ShiftedExponential::new(p.t2 / cfg.m as f64, cfg.m as f64 * p.lambda2),
                    root.fork(w as u64 + 1),
                )
            });
            let looper = WorkerLoop {
                id: w,
                backend: Arc::clone(&backend),
                tasks: task_rx,
                results: result_tx.clone(),
                delays: injector,
                sleep_scale: match mode {
                    ExecutionMode::Virtual => 0.0,
                    ExecutionMode::RealTime { scale } => scale,
                },
                skip_stale: matches!(mode, ExecutionMode::RealTime { .. }),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("gradcode-worker-{w}"))
                    .spawn(move || looper.run())
                    .expect("spawn worker"),
            );
        }
        Cluster { cfg, mode, wait_for, task_txs, results: result_rx, handles }
    }

    pub fn n(&self) -> usize {
        self.cfg.n
    }

    /// Responses gathered before the master proceeds.
    pub fn wait_for(&self) -> usize {
        self.wait_for
    }

    /// Broadcast an iteration and gather responses.
    ///
    /// Virtual mode: waits for all `n` results, sorts by virtual finish,
    /// returns all (the trainer uses the first `wait_for`).
    /// Real-time mode: returns after the first `wait_for` results for
    /// this iteration arrive; stale results from previous iterations are
    /// discarded. `wait_for` is the scheme's `n - s` unless a quorum
    /// override was given at spawn time.
    pub fn run_iteration(&mut self, iter: usize, beta: Arc<Vec<f32>>) -> GatherResult {
        let t0 = Instant::now();
        for tx in &self.task_txs {
            // A dead worker (backend error) is a permanent straggler; the
            // send fails silently and the decode path handles the gap.
            let _ = tx.send(Task { iter, beta: Arc::clone(&beta) });
        }
        let wait_for = self.wait_for;
        let mut results: Vec<WorkerResult> = Vec::with_capacity(self.cfg.n);
        match self.mode {
            ExecutionMode::Virtual => {
                // Every worker reports exactly once per iteration, failures
                // included (a backend failure is a permanent straggler and
                // reports `failed = true` rather than going silent).
                let mut received = 0usize;
                while received < self.cfg.n {
                    match self.results.recv() {
                        Ok(r) if r.iter == iter => {
                            received += 1;
                            if !r.failed {
                                results.push(r);
                            }
                        }
                        Ok(_) => continue, // stale (shouldn't happen here)
                        Err(_) => break,   // all workers died
                    }
                }
                assert!(
                    results.len() >= wait_for,
                    "only {} healthy results of {} workers (need {wait_for}; \
                     the gather tolerates {} failures)",
                    results.len(),
                    self.cfg.n,
                    self.cfg.n - wait_for
                );
                results.sort_by(|a, b| {
                    a.virtual_finish.partial_cmp(&b.virtual_finish).unwrap()
                });
                let iteration_time = results[wait_for - 1].virtual_finish;
                let worker_compute = results[..wait_for]
                    .iter()
                    .map(|r| r.compute_secs)
                    .fold(0.0, f64::max);
                GatherResult { results, iteration_time, worker_compute }
            }
            ExecutionMode::RealTime { .. } => {
                let mut failures = 0usize;
                while results.len() < wait_for {
                    match self.results.recv() {
                        Ok(r) if r.iter == iter => {
                            if r.failed {
                                failures += 1;
                                assert!(
                                    failures <= self.cfg.n - wait_for,
                                    "{failures} worker failures exceed gather tolerance {}",
                                    self.cfg.n - wait_for
                                );
                            } else {
                                results.push(r);
                            }
                        }
                        Ok(_) => continue, // stale from a previous iteration
                        Err(_) => panic!("all workers exited mid-iteration"),
                    }
                }
                let iteration_time = t0.elapsed().as_secs_f64();
                let worker_compute =
                    results.iter().map(|r| r.compute_secs).fold(0.0, f64::max);
                GatherResult { results, iteration_time, worker_compute }
            }
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.task_txs.clear(); // close task channels -> workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{GradientCode, PolynomialCode};
    use crate::coordinator::backend::RustBackend;
    use crate::data::{CategoricalConfig, SyntheticCategorical};

    fn setup(
        n: usize,
        s: usize,
        m: usize,
    ) -> (Arc<PolynomialCode>, Arc<RustBackend>, usize) {
        let code =
            Arc::new(PolynomialCode::new(SchemeConfig::tight(n, s, m).unwrap()).unwrap());
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), 41);
        let ds = SyntheticCategorical::pad_to_multiple(&gen.generate(n * 12, 42), m);
        let backend = Arc::new(RustBackend::new(code.as_ref(), &ds).unwrap());
        let l = ds.cols;
        (code, backend, l)
    }

    #[test]
    fn virtual_mode_gathers_all_and_orders() {
        let (code, backend, l) = setup(5, 1, 2);
        let mut cluster = Cluster::spawn(
            *code.config(),
            backend,
            ExecutionMode::Virtual,
            Some(DelayParams::table_vi1()),
            1,
        );
        let beta = Arc::new(vec![0.0f32; l]);
        for iter in 0..3 {
            let g = cluster.run_iteration(iter, Arc::clone(&beta));
            assert_eq!(g.results.len(), 5);
            for w in g.results.windows(2) {
                assert!(w[0].virtual_finish <= w[1].virtual_finish);
            }
            assert_eq!(g.iteration_time, g.results[3].virtual_finish);
            for r in &g.results {
                assert_eq!(r.f.len(), l / 2);
                assert_eq!(r.iter, iter);
            }
        }
    }

    #[test]
    fn realtime_mode_returns_after_quorum() {
        let (code, backend, l) = setup(5, 2, 1);
        let mut cluster = Cluster::spawn(
            *code.config(),
            backend,
            // tiny sleep scale so the test is fast but ordering is racy
            ExecutionMode::RealTime { scale: 1e-4 },
            Some(DelayParams::table_vi1()),
            2,
        );
        let beta = Arc::new(vec![0.0f32; l]);
        for iter in 0..3 {
            let g = cluster.run_iteration(iter, Arc::clone(&beta));
            assert!(g.results.len() >= 3, "quorum is n-s = 3");
            assert!(g.results.iter().all(|r| r.iter == iter));
        }
    }

    #[test]
    fn quorum_override_changes_the_cutoff() {
        // Same scheme, quorum forced below the exact n - s: the virtual
        // clock must advance only to the 3rd arrival.
        let (code, backend, l) = setup(5, 1, 2);
        let mut cluster = Cluster::spawn_with_quorum(
            *code.config(),
            backend,
            ExecutionMode::Virtual,
            Some(DelayParams::table_vi1()),
            9,
            3,
        );
        assert_eq!(cluster.wait_for(), 3);
        let g = cluster.run_iteration(0, Arc::new(vec![0.0f32; l]));
        assert_eq!(g.results.len(), 5, "virtual mode still collects everyone");
        assert_eq!(g.iteration_time, g.results[2].virtual_finish);
    }

    #[test]
    fn quorum_override_in_realtime_returns_at_quorum() {
        let (code, backend, l) = setup(5, 1, 2);
        let mut cluster = Cluster::spawn_with_quorum(
            *code.config(),
            backend,
            ExecutionMode::RealTime { scale: 1e-4 },
            Some(DelayParams::table_vi1()),
            10,
            3,
        );
        for iter in 0..2 {
            let g = cluster.run_iteration(iter, Arc::new(vec![0.0f32; l]));
            assert_eq!(g.results.len(), 3, "real-time gather stops at the quorum");
        }
    }

    #[test]
    fn no_delay_injection_gives_zero_virtual_time() {
        let (code, backend, l) = setup(4, 1, 1);
        let mut cluster =
            Cluster::spawn(*code.config(), backend, ExecutionMode::Virtual, None, 3);
        let g = cluster.run_iteration(0, Arc::new(vec![0.0f32; l]));
        assert!(g.results.iter().all(|r| r.virtual_finish == 0.0));
    }
}
