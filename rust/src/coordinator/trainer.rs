//! The training loop: scheme + cluster + optimizer + metrics.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use super::backend::{ComputeBackend, RustBackend};
use super::cluster::{Cluster, ExecutionMode, FleetProfile, GatherResult, WaitRule};
use super::wire::framed_result_bytes;
use crate::chaos::{ChaosConfig, FaultEvent, FaultLog, GatherPolicy, LadderRung};
use crate::coding::{
    ls_partial_decode, quorum_count, ApproxCode, Decoder, GradientCode, HeteroCode,
    PolynomialCode, RandomCode, SchemeConfig, UncodedScheme,
};
use crate::data::{auc, DenseDataset, SyntheticCategorical};
use crate::metrics::{IterationRecord, RunLog};
use crate::model::LogisticModel;
use crate::obs::{phase, HealthConfig, HealthWatchdog, Recorder};
use crate::optim::{Momentum, Nag, Optimizer, Sgd};
use crate::simulator::{expected_wait_time, DelayParams, SpeedProfile};

/// Which coding scheme to deploy.
#[derive(Debug, Clone)]
pub enum SchemeSpec {
    /// §III recursive-polynomial scheme with the paper's θ grid.
    Poly { s: usize, m: usize },
    /// §IV Gaussian random-matrix scheme.
    Random { s: usize, m: usize, seed: u64 },
    /// Naive uncoded baseline (d=1, wait for all).
    Uncoded,
    /// Approximate gradient coding with partial recovery: replication
    /// `d`, master proceeds at `ceil(quorum·n)` responders and accepts
    /// the least-squares decode (see [`ApproxCode`]).
    Approx { d: usize, quorum: f64 },
    /// Heterogeneous group-based exact coding: workers partitioned by
    /// speed, per-group loads `d_g >= s + m`, subset sizes scaled to
    /// group speed (see [`HeteroCode`]). The `profile` describes the
    /// fleet the placement adapts to; unless [`TrainConfig::fleet`]
    /// overrides it, the same profile also drives the delay injection.
    Hetero { s: usize, m: usize, profile: SpeedProfile },
}

impl SchemeSpec {
    /// Human-readable label used in logs and bench tables.
    pub fn label(&self) -> String {
        match self {
            SchemeSpec::Poly { s, m } => format!("poly(s={s},m={m})"),
            SchemeSpec::Random { s, m, .. } => format!("random(s={s},m={m})"),
            SchemeSpec::Uncoded => "naive".to_string(),
            SchemeSpec::Approx { d, quorum } => format!("approx(d={d},q={quorum})"),
            SchemeSpec::Hetero { s, m, profile } => {
                format!("hetero(s={s},m={m},{})", profile.label())
            }
        }
    }

    /// Instantiate the scheme for `n` workers.
    pub fn build(&self, n: usize) -> anyhow::Result<Arc<dyn GradientCode>> {
        Ok(match self {
            SchemeSpec::Poly { s, m } => {
                Arc::new(PolynomialCode::new(SchemeConfig::tight(n, *s, *m)?)?)
            }
            SchemeSpec::Random { s, m, seed } => {
                Arc::new(RandomCode::new(SchemeConfig::tight(n, *s, *m)?, *seed)?)
            }
            SchemeSpec::Uncoded => Arc::new(UncodedScheme::new(n)),
            SchemeSpec::Approx { d, quorum } => {
                Arc::new(ApproxCode::with_quorum_fraction(n, *d, *quorum)?)
            }
            SchemeSpec::Hetero { s, m, profile } => {
                let speeds =
                    profile.try_speeds(n).map_err(|e| anyhow::anyhow!(e))?;
                Arc::new(HeteroCode::from_speeds(n, *s, *m, &speeds)?)
            }
        })
    }
}

/// Optimizer choice (the paper uses NAG).
#[derive(Debug, Clone, Copy)]
pub enum OptChoice {
    Nag { lr: f32, momentum: f32 },
    NagScheduled { lr: f32 },
    Sgd { lr: f32 },
    Momentum { lr: f32, mu: f32 },
}

impl OptChoice {
    fn build(&self, x0: Vec<f32>) -> Box<dyn Optimizer> {
        match *self {
            OptChoice::Nag { lr, momentum } => Box::new(Nag::new(x0, lr, momentum)),
            OptChoice::NagScheduled { lr } => Box::new(Nag::scheduled(x0, lr)),
            OptChoice::Sgd { lr } => Box::new(Sgd::new(x0, lr)),
            OptChoice::Momentum { lr, mu } => Box::new(Momentum::new(x0, lr, mu)),
        }
    }
}

/// Full training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub n: usize,
    pub scheme: SchemeSpec,
    pub iters: usize,
    pub opt: OptChoice,
    /// Evaluate loss/AUC every this many iterations (and at the end).
    pub eval_every: usize,
    /// §VI delay injection; `None` disables straggler simulation.
    pub delays: Option<DelayParams>,
    pub mode: ExecutionMode,
    pub seed: u64,
    /// Mini-batch fraction in (0, 1] for the rust backend; `None` = full
    /// batch (§II: the scheme applies to both batch GD and mini-batch SGD).
    pub minibatch: Option<f64>,
    /// Early-termination policy: proceed once this fraction of workers
    /// has responded (`ceil(quorum·n)`, clamped to `1..=n`) instead of
    /// the scheme's own wait rule. `None` keeps the scheme's wait.
    /// Below the exact threshold this only makes sense with
    /// [`SchemeSpec::Approx`], whose partial decoder accepts any
    /// responder count; exact schemes will fail to decode. Rejected for
    /// group-quorum schemes ([`SchemeSpec::Hetero`]) — a flat cutoff
    /// cannot guarantee every group stays decodable, and their gather
    /// already stops at the earliest decodable prefix.
    pub quorum: Option<f64>,
    /// Speed profile of the *fleet* the delay injection simulates.
    /// `None` = uniform speeds, except [`SchemeSpec::Hetero`] defaults
    /// to its own profile. Setting this lets a homogeneous scheme run on
    /// a skewed fleet (the baseline the hetero benches compare against).
    pub fleet: Option<SpeedProfile>,
    /// Fault injection: a deterministic [`crate::chaos::FaultPlan`] plus
    /// the gather and degradation policies. `None` disables chaos
    /// entirely (no per-result CRCs, no fault log) *and* makes an
    /// unsatisfied gather a hard error instead of a degraded iteration.
    pub chaos: Option<ChaosConfig>,
}

impl TrainConfig {
    pub fn quick(n: usize, scheme: SchemeSpec, iters: usize) -> Self {
        TrainConfig {
            n,
            scheme,
            iters,
            opt: OptChoice::Nag { lr: 1e-3, momentum: 0.9 },
            eval_every: 10,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::Virtual,
            seed: 0xfeed,
            minibatch: None,
            quorum: None,
            fleet: None,
            chaos: None,
        }
    }
}

/// Owns the cluster and optimizer for one training run.
pub struct Trainer {
    cfg: TrainConfig,
    code: Arc<dyn GradientCode>,
    cluster: Cluster,
    out_dim: usize,
    /// Fewest responders the master can proceed at (the flat rule's
    /// count, or the per-group minimum for heterogeneous schemes).
    wait_for: usize,
    opt: Box<dyn Optimizer>,
    /// Per-responder-set decoder plus the scheme's reported decode
    /// residual (`None` for exact schemes).
    decoder_cache: HashMap<u64, (Decoder, Option<f64>)>,
    decoder_cache_hits: usize,
    decoder_cache_misses: usize,
    /// Eval data (train loss / test AUC); train eval is subsampled.
    train_eval: DenseDataset,
    test: Option<DenseDataset>,
    /// Telemetry recorder; disabled (and free) unless
    /// [`Trainer::attach_recorder`] was called.
    obs: Recorder,
    /// Fleet speeds the delay injection simulates (kept for the §VI
    /// model-deviation line in the telemetry digest).
    speeds: Vec<f64>,
    /// Per-worker compute load in units of one partition's gradient.
    work: Vec<f64>,
}

impl Trainer {
    /// Build with the pure-rust backend over `train`.
    pub fn new(
        cfg: TrainConfig,
        train: &DenseDataset,
        test: Option<&DenseDataset>,
    ) -> anyhow::Result<Self> {
        let code = cfg.scheme.build(cfg.n)?;
        let m = code.config().m;
        let train_padded = SyntheticCategorical::pad_to_multiple(train, m);
        let backend: Arc<dyn ComputeBackend> = match cfg.minibatch {
            None => Arc::new(RustBackend::new(code.as_ref(), &train_padded)?),
            Some(frac) => Arc::new(RustBackend::with_minibatch(
                code.as_ref(),
                &train_padded,
                frac,
                cfg.seed ^ 0x6d62, // "mb"
            )?),
        };
        Self::with_backend(cfg, code, backend, &train_padded, test)
    }

    /// Build with an explicit backend (e.g. the PJRT artifact backend).
    /// `train_eval` must already be padded to the scheme's `m`.
    pub fn with_backend(
        cfg: TrainConfig,
        code: Arc<dyn GradientCode>,
        backend: Arc<dyn ComputeBackend>,
        train_eval: &DenseDataset,
        test: Option<&DenseDataset>,
    ) -> anyhow::Result<Self> {
        let l = backend.dim();
        let out_dim = backend.out_dim();
        anyhow::ensure!(l % code.config().m == 0, "backend dim not divisible by m");
        // Subsample train eval to bound metric cost on big runs.
        let train_eval = if train_eval.rows > 4096 {
            let idx: Vec<usize> = (0..4096).map(|i| i * (train_eval.rows / 4096)).collect();
            train_eval.select_rows(&idx)
        } else {
            train_eval.clone()
        };
        // Gather stopping rule: quorum override > scheme group rule >
        // scheme n - s.
        let rule = match cfg.quorum {
            Some(q) => {
                anyhow::ensure!(
                    q > 0.0 && q <= 1.0,
                    "quorum fraction must be in (0, 1], got {q}"
                );
                // A flat arrival cutoff cannot guarantee each group its
                // per-group minimum (the last arrivals cluster in the
                // slow tier), so it would abort mid-run on the first
                // unlucky prefix. The group rule already stops as early
                // as decode allows — reject the combination instead.
                anyhow::ensure!(
                    code.group_quorums().is_none(),
                    "TrainConfig::quorum cannot override a group-quorum \
                     scheme (the hetero gather already stops at the \
                     earliest decodable prefix)"
                );
                WaitRule::Count(quorum_count(cfg.n, q))
            }
            None => match code.group_quorums() {
                Some(groups) => WaitRule::PerGroup(groups),
                None => WaitRule::Count(code.config().wait_for()),
            },
        };
        let wait_for = rule.min_responders();
        // Fleet speeds: explicit override, else the hetero scheme's own
        // profile, else uniform.
        let speeds = match (&cfg.fleet, &cfg.scheme) {
            (Some(p), _) => p.try_speeds(cfg.n).map_err(|e| anyhow::anyhow!(e))?,
            (None, SchemeSpec::Hetero { profile, .. }) => {
                profile.try_speeds(cfg.n).map_err(|e| anyhow::anyhow!(e))?
            }
            _ => vec![1.0; cfg.n],
        };
        let work: Vec<f64> = (0..cfg.n).map(|w| code.compute_units(w)).collect();
        let (plan, policy) = match &cfg.chaos {
            Some(c) => (Some(Arc::clone(&c.plan)), c.policy),
            None => (None, GatherPolicy::default()),
        };
        // Under chaos in real-time mode a flat count can become
        // unsatisfiable (crashed workers never answer), so the gather
        // gets an explicit deadline; per-group rules keep their own
        // stopping logic, and virtual gathers cannot hang.
        let rule = match (&cfg.chaos, cfg.mode, rule) {
            (Some(c), ExecutionMode::RealTime { .. }, WaitRule::Count(count)) => {
                WaitRule::Deadline { count, timeout: c.policy.deadline }
            }
            (_, _, r) => r,
        };
        let cluster = Cluster::spawn_chaos(
            *code.config(),
            backend,
            cfg.mode,
            cfg.delays,
            cfg.seed,
            rule,
            Some(FleetProfile { speeds: speeds.clone(), work: work.clone() }),
            plan,
            policy,
        );
        let opt = cfg.opt.build(vec![0.0f32; l]);
        let test = test.map(|t| {
            // Pad test data columns to match l if needed.
            if t.cols == l {
                t.clone()
            } else {
                assert!(t.cols < l, "test wider than train");
                let mut x = vec![0.0f32; t.rows * l];
                for r in 0..t.rows {
                    x[r * l..r * l + t.cols].copy_from_slice(t.row(r));
                }
                DenseDataset { x, y: t.y.clone(), rows: t.rows, cols: l }
            }
        });
        Ok(Trainer {
            cfg,
            code,
            cluster,
            out_dim,
            wait_for,
            opt,
            decoder_cache: HashMap::new(),
            decoder_cache_hits: 0,
            decoder_cache_misses: 0,
            train_eval,
            test,
            obs: Recorder::disabled(),
            speeds,
            work,
        })
    }

    /// Fewest responders the master proceeds at each iteration.
    pub fn wait_for(&self) -> usize {
        self.wait_for
    }

    /// Attach a telemetry recorder. The trainer tags the master phases
    /// (iteration, decode, step, eval) and mirrors injected faults into
    /// the event stream; the recorder is forwarded to the cluster, which
    /// adds broadcast/gather spans and per-worker response latencies.
    /// Call before [`Trainer::run`]; a disabled recorder stays a no-op.
    pub fn attach_recorder(&mut self, rec: &Recorder) {
        self.obs = rec.clone();
        self.cluster.set_recorder(rec);
    }

    /// Bitmask cache key for a sorted responder set (n <= 64).
    fn mask(responders: &[usize]) -> u64 {
        responders.iter().fold(0u64, |acc, &w| acc | (1 << w))
    }

    /// §VI-model per-iteration wait time for the *declared* fleet
    /// profile and this run's wait rule. `None` without a delay model.
    /// Feeds both the end-of-run straggler report and the live
    /// [`HealthWatchdog`], so both compare against the same number.
    fn model_expected_wait(&self) -> Option<f64> {
        self.cfg.delays.as_ref().map(|p| {
            let groups = match self.cluster.rule() {
                WaitRule::PerGroup(gs) => gs.clone(),
                WaitRule::Count(c) | WaitRule::Deadline { count: c, .. } => {
                    vec![((0..self.cfg.n).collect(), *c)]
                }
            };
            expected_wait_time(p, self.code.config().m, &self.work, &self.speeds, &groups)
        })
    }

    /// Run the configured number of iterations.
    pub fn run(&mut self) -> anyhow::Result<RunLog> {
        let mut log = RunLog::new(self.cfg.scheme.label());
        let mut sim_clock = 0.0f64;
        let full_dim = self.out_dim * self.code.config().m;
        let mut grad = Vec::with_capacity(full_dim);
        let chaos = self.cfg.chaos.clone();
        let ladder = chaos.as_ref().map(|c| c.ladder).unwrap_or_default();
        let mut faults = FaultLog::new();
        let mut consecutive_stale = 0usize;
        // Post-mortem flight dump: if this run aborts (ladder exhaustion,
        // decode failure, panic unwinding through run()), the guard dumps
        // the global flight ring; a clean finish disarms it below.
        let mut flight_guard = crate::obs::FlightDumpGuard::arm_default();
        // Straggler-regime watchdog: realized iteration times vs the
        // declared-profile model (active whenever a delay model exists;
        // the comparison uses the same units — simulated seconds).
        let mut watchdog = self
            .model_expected_wait()
            .map(|e| HealthWatchdog::new(e, HealthConfig::default()));
        if let Some(w) = &watchdog {
            w.export(&self.obs);
        }
        for iter in 0..self.cfg.iters {
            let _iteration_span = self.obs.span(phase::ITERATION).iter(iter as u64);
            let beta = Arc::new(self.opt.eval_point().to_vec());
            let gather = self.cluster.run_iteration(iter, beta);
            // lint: allow(wallclock-entropy) realized latency metric only; never feeds seeds or decisions
            let t0 = Instant::now();

            // Master-side replay of the deterministic plan, so the log
            // shows what was injected even when the fault was silent.
            if let Some(c) = &chaos {
                for (w, kind) in c.plan.events_at(iter as u64) {
                    faults.record(iter as u64, Some(w), FaultEvent::Injected(kind));
                    if self.obs.is_enabled() {
                        self.obs.instant(
                            &format!("fault:{}", kind.label()),
                            Some(w),
                            Some(iter as u64),
                        );
                    }
                }
            }
            for &w in &gather.rejected {
                faults.record(iter as u64, Some(w), FaultEvent::ChecksumReject);
            }
            if gather.duplicates > 0 {
                faults.record(
                    iter as u64,
                    None,
                    FaultEvent::DuplicatesDiscarded { count: gather.duplicates },
                );
            }
            if !gather.satisfied {
                faults.record(
                    iter as u64,
                    None,
                    FaultEvent::DeadlineExpired {
                        responders: gather.results.len(),
                        needed: self.wait_for,
                    },
                );
            }

            let decode_span = self.obs.span(phase::DECODE).iter(iter as u64);

            // Responders: the arrival prefix that satisfied the wait rule
            // (the exact n-s, a quorum override, or the heterogeneous
            // per-group rule), then sorted so the decoder cache key is
            // order-insensitive. When the rule went unsatisfied this is
            // every healthy responder the gather managed to collect.
            let mut responders: Vec<usize> = gather
                .results
                .iter()
                .take(gather.quorum_len)
                .map(|r| r.worker)
                .collect();
            responders.sort_unstable();

            // Degradation ladder: exact decode while the wait rule holds,
            // least-squares partial decode from whoever answered below
            // that, stale gradient when nothing is decodable at all.
            let (rung, decode_residual) = if gather.satisfied {
                let key = Self::mask(&responders);
                if self.decoder_cache.contains_key(&key) {
                    self.decoder_cache_hits += 1;
                } else {
                    self.decoder_cache_misses += 1;
                    let (dw, residual) =
                        self.code.decode_weights_with_residual(&responders)?;
                    self.decoder_cache
                        .insert(key, (Decoder::from_weights(&dw), residual));
                }
                let (dec, residual) = &self.decoder_cache[&key];
                apply_decoder(dec, &gather, self.cfg.n, &mut grad)?;
                (LadderRung::Exact, *residual)
            } else if chaos.is_none() {
                anyhow::bail!(
                    "iteration {iter}: wait rule unsatisfied ({} of {} responders \
                     healthy) and no chaos config to authorize degradation",
                    gather.results.len(),
                    self.wait_for,
                );
            } else {
                match ls_partial_decode(self.code.as_ref(), &responders) {
                    Ok(ls) => {
                        // Uncached: degraded responder sets are transient,
                        // caching them would only pollute the exact-path
                        // cache and its hit-rate accounting.
                        let dec = Decoder::from_weights(&ls.weights);
                        apply_decoder(&dec, &gather, self.cfg.n, &mut grad)?;
                        (LadderRung::Degraded, Some(ls.coeff_residual))
                    }
                    Err(_) => {
                        // Last rung: repeat the previous gradient (a zero
                        // step when none exists yet).
                        if grad.is_empty() {
                            grad.resize(full_dim, 0.0);
                        }
                        (LadderRung::Stale, None)
                    }
                }
            };
            drop(decode_span);
            if rung == LadderRung::Stale {
                consecutive_stale += 1;
                anyhow::ensure!(
                    consecutive_stale <= ladder.max_stale,
                    "aborting after {consecutive_stale} consecutive stale \
                     iterations (max_stale = {})",
                    ladder.max_stale
                );
            } else {
                consecutive_stale = 0;
            }
            if chaos.is_some() || rung != LadderRung::Exact {
                faults.record(
                    iter as u64,
                    None,
                    FaultEvent::Rung { rung, residual: decode_residual },
                );
            }
            {
                let _step_span = self.obs.span(phase::STEP).iter(iter as u64);
                self.opt.step(&grad);
            }
            let master_compute = t0.elapsed().as_secs_f64();

            sim_clock += gather.iteration_time;
            // Always-on breadcrumb in the bounded flight ring (dumped on
            // abort; negligible cost — one slot overwrite per iteration).
            crate::obs::flight::global().record(
                "iteration",
                None,
                Some(iter as u64),
                &format!(
                    "rung={} responders={} sim_time={:.6}",
                    rung.as_str(),
                    responders.len(),
                    gather.iteration_time
                ),
            );
            if let Some(w) = &mut watchdog {
                if let Some(warning) = w.observe(iter as u64, gather.iteration_time) {
                    eprintln!("{warning}");
                    log.health_warnings.push(warning);
                }
                w.export(&self.obs);
            }
            let evaluate = iter % self.cfg.eval_every == 0 || iter + 1 == self.cfg.iters;
            let (loss, auc_val) = if evaluate {
                let _eval_span = self.obs.span(phase::EVAL).iter(iter as u64);
                let beta_now = self.opt.iterate();
                let loss = LogisticModel::loss(&self.train_eval, beta_now);
                let auc_val = self.test.as_ref().map(|t| {
                    auc(&LogisticModel::predict(t, beta_now), &t.y)
                });
                (Some(loss), auc_val)
            } else {
                (None, None)
            };
            log.push(IterationRecord {
                iter,
                sim_time: gather.iteration_time,
                sim_clock,
                master_compute,
                worker_compute: gather.worker_compute,
                responders,
                floats_transmitted: gather.results.len() * self.out_dim,
                wire_bytes: gather.results.len() * framed_result_bytes(self.out_dim),
                decode_residual,
                loss,
                auc: auc_val,
                rung,
            });
        }
        log.decoder_cache_hits = self.decoder_cache_hits;
        log.decoder_cache_misses = self.decoder_cache_misses;
        log.faults = faults;
        if self.obs.is_enabled() {
            // Telemetry digest: phase breakdown, counters, and the
            // straggler report with the realized mean iteration time set
            // against the §VI model's expectation for this fleet + rule
            // (the same number the live watchdog compared windows to).
            let mut summary = self.obs.summary();
            summary
                .stragglers
                .set_model(self.model_expected_wait(), log.mean_iteration_sim_time());
            log.telemetry = Some(summary);
        }
        // Clean finish: no post-mortem dump wanted.
        flight_guard.disarm();
        Ok(log)
    }

    /// Current parameters.
    pub fn params(&self) -> &[f32] {
        self.opt.iterate()
    }

    pub fn scheme(&self) -> &dyn GradientCode {
        self.code.as_ref()
    }
}

/// Decode `gather`'s results through `dec` into `grad`.
fn apply_decoder(
    dec: &Decoder,
    gather: &GatherResult,
    n: usize,
    grad: &mut Vec<f32>,
) -> anyhow::Result<()> {
    // Map worker id -> returned vector.
    let mut by_worker: Vec<Option<&[f32]>> = vec![None; n];
    for r in &gather.results {
        by_worker[r.worker] = Some(&r.f);
    }
    let fs: Vec<&[f32]> = dec
        .used_workers()
        .iter()
        .map(|&w| {
            by_worker[w].ok_or_else(|| anyhow::anyhow!("decoder used worker {w} but no result arrived"))
        })
        .collect::<anyhow::Result<_>>()?;
    dec.decode_into(&fs, grad)?;
    Ok(())
}

/// One-call convenience: train and return (log, final parameters).
pub fn train(
    cfg: TrainConfig,
    train_ds: &DenseDataset,
    test_ds: Option<&DenseDataset>,
) -> anyhow::Result<(RunLog, Vec<f32>)> {
    let mut tr = Trainer::new(cfg, train_ds, test_ds)?;
    let log = tr.run()?;
    let params = tr.params().to_vec();
    Ok((log, params))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{train_test_split, CategoricalConfig};

    fn dataset(rows: usize, seed: u64) -> (DenseDataset, DenseDataset) {
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), seed);
        let ds = gen.generate(rows, seed + 1);
        train_test_split(&ds, 0.25, seed + 2)
    }

    #[test]
    fn coded_training_learns() {
        let (train_ds, test_ds) = dataset(1200, 51);
        let lr = 6.0 / train_ds.rows as f32;
        let cfg = TrainConfig {
            n: 5,
            scheme: SchemeSpec::Poly { s: 1, m: 2 },
            iters: 150,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: 10,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::Virtual,
            seed: 7,
            minibatch: None,
            quorum: None,
            fleet: None,
            chaos: None,
        };
        let (log, _beta) = train(cfg, &train_ds, Some(&test_ds)).unwrap();
        assert_eq!(log.records.len(), 150);
        let first_loss = log.records[0].loss.unwrap();
        let last_loss = log.final_loss().unwrap();
        assert!(last_loss < first_loss * 0.9, "{first_loss} -> {last_loss}");
        assert!(log.final_auc().unwrap() > 0.7, "AUC {:?}", log.final_auc());
        assert!(log.total_sim_time() > 0.0);
        // n = 5, s = 1: only C(5,4) = 5 distinct responder sets exist, so
        // over 150 iterations the decode-weights cache must be hot.
        assert_eq!(
            log.decoder_cache_hits + log.decoder_cache_misses,
            150,
            "one lookup per iteration"
        );
        assert!(log.decoder_cache_misses <= 5);
        assert!(log.decoder_cache_hit_rate().unwrap() > 0.9);
    }

    #[test]
    fn coded_and_uncoded_reach_same_solution() {
        // The paper's point: coding changes the clock, not the learning —
        // identical gradients mean identical trajectories.
        let (train_ds, _) = dataset(400, 61);
        let lr = 4.0 / train_ds.rows as f32;
        let mk = |scheme| TrainConfig {
            n: 4,
            scheme,
            iters: 25,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: 25,
            delays: None,
            mode: ExecutionMode::Virtual,
            seed: 9,
            minibatch: None,
            quorum: None,
            fleet: None,
            chaos: None,
        };
        let (_, beta_coded) =
            train(mk(SchemeSpec::Poly { s: 1, m: 1 }), &train_ds, None).unwrap();
        let (_, beta_naive) = train(mk(SchemeSpec::Uncoded), &train_ds, None).unwrap();
        let max_diff = beta_coded
            .iter()
            .zip(&beta_naive)
            .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()));
        let scale = beta_naive.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
        assert!(
            max_diff / scale < 1e-2,
            "trajectory divergence {max_diff} (scale {scale})"
        );
    }

    #[test]
    fn random_scheme_trains_too() {
        let (train_ds, test_ds) = dataset(400, 71);
        let lr = 4.0 / train_ds.rows as f32;
        let cfg = TrainConfig {
            n: 6,
            scheme: SchemeSpec::Random { s: 2, m: 2, seed: 3 },
            iters: 40,
            opt: OptChoice::NagScheduled { lr },
            eval_every: 10,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::Virtual,
            seed: 11,
            minibatch: None,
            quorum: None,
            fleet: None,
            chaos: None,
        };
        let (log, _) = train(cfg, &train_ds, Some(&test_ds)).unwrap();
        assert!(log.final_auc().unwrap() > 0.65);
    }

    #[test]
    fn approx_scheme_trains_with_partial_quorum() {
        let (train_ds, _) = dataset(600, 91);
        let lr = 4.0 / train_ds.rows as f32;
        let cfg = TrainConfig {
            n: 8,
            scheme: SchemeSpec::Approx { d: 3, quorum: 0.75 },
            iters: 40,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: 10,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::Virtual,
            seed: 17,
            minibatch: None,
            quorum: None,
            fleet: None,
            chaos: None,
        };
        let (log, _) = train(cfg, &train_ds, None).unwrap();
        assert_eq!(log.records.len(), 40);
        // ceil(0.75 · 8) = 6 responders per iteration, residual reported
        assert!(log.records.iter().all(|r| r.responders.len() == 6));
        assert!(log.records.iter().all(|r| r.decode_residual.is_some()));
        let first = log.records[0].loss.unwrap();
        let last = log.final_loss().unwrap();
        assert!(last < first, "approximate training must still learn: {first} -> {last}");
    }

    #[test]
    fn quorum_override_applies_to_any_scheme() {
        // An uncoded scheme normally waits for everyone; the quorum
        // override can only be exercised by a scheme whose decoder
        // accepts fewer responders, so use approx with q = 1.0 built in
        // and a *tighter* runtime override.
        let (train_ds, _) = dataset(400, 93);
        let lr = 4.0 / train_ds.rows as f32;
        let cfg = TrainConfig {
            n: 6,
            scheme: SchemeSpec::Approx { d: 2, quorum: 1.0 },
            iters: 10,
            opt: OptChoice::Sgd { lr },
            eval_every: 5,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::Virtual,
            seed: 19,
            minibatch: None,
            quorum: Some(2.0 / 3.0),
            fleet: None,
            chaos: None,
        };
        let mut tr = Trainer::new(cfg, &train_ds, None).unwrap();
        assert_eq!(tr.wait_for(), 4, "override ceil(6·2/3) = 4 beats the scheme's 6");
        let log = tr.run().unwrap();
        assert!(log.records.iter().all(|r| r.responders.len() == 4));
    }

    #[test]
    fn realtime_mode_trains() {
        let (train_ds, _) = dataset(300, 81);
        let lr = 4.0 / train_ds.rows as f32;
        let cfg = TrainConfig {
            n: 4,
            scheme: SchemeSpec::Poly { s: 1, m: 1 },
            iters: 8,
            opt: OptChoice::Sgd { lr },
            eval_every: 4,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::RealTime { scale: 1e-4 },
            seed: 13,
            minibatch: None,
            quorum: None,
            fleet: None,
            chaos: None,
        };
        let (log, _) = train(cfg, &train_ds, None).unwrap();
        assert_eq!(log.records.len(), 8);
        // responders are a strict subset when s > 0
        assert!(log.records.iter().all(|r| r.responders.len() == 3));
    }

    #[test]
    fn hetero_scheme_trains_and_uses_group_quorums() {
        let (train_ds, test_ds) = dataset(1500, 101);
        let lr = 5.0 / train_ds.rows as f32;
        let profile = SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 };
        let cfg = TrainConfig {
            n: 10,
            scheme: SchemeSpec::Hetero { s: 1, m: 2, profile },
            iters: 60,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: 10,
            delays: Some(DelayParams::ec2_fit()),
            mode: ExecutionMode::Virtual,
            seed: 23,
            minibatch: None,
            quorum: None,
            fleet: None,
            chaos: None,
        };
        let mut tr = Trainer::new(cfg, &train_ds, Some(&test_ds)).unwrap();
        assert!(
            tr.wait_for() < 9,
            "per-group rule should need fewer than n - s = 9 responders"
        );
        let log = tr.run().unwrap();
        assert_eq!(log.records.len(), 60);
        // exact recovery: no residual reported
        assert!(log.records.iter().all(|r| r.decode_residual.is_none()));
        // the per-group rule keeps responder sets below the flat n - s
        assert!(log.records.iter().all(|r| r.responders.len() <= 9));
        let first_loss = log.records[0].loss.unwrap();
        let last_loss = log.final_loss().unwrap();
        assert!(last_loss < first_loss, "{first_loss} -> {last_loss}");
    }

    #[test]
    fn hetero_training_matches_uncoded_trajectory() {
        // Exactness end-to-end: hetero decode (weighted subsets, group
        // codes) must produce the same gradients as the naive sum.
        let (train_ds, _) = dataset(600, 111);
        let lr = 4.0 / train_ds.rows as f32;
        let mk = |scheme| TrainConfig {
            n: 6,
            scheme,
            iters: 20,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: 20,
            delays: None,
            mode: ExecutionMode::Virtual,
            seed: 29,
            minibatch: None,
            quorum: None,
            fleet: None,
            chaos: None,
        };
        let profile = SpeedProfile::Custom(vec![1.0, 1.0, 1.0, 3.0, 3.0, 3.0]);
        let (_, beta_het) = train(
            mk(SchemeSpec::Hetero { s: 1, m: 1, profile }),
            &train_ds,
            None,
        )
        .unwrap();
        let (_, beta_naive) = train(mk(SchemeSpec::Uncoded), &train_ds, None).unwrap();
        let max_diff = beta_het
            .iter()
            .zip(&beta_naive)
            .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()));
        let scale = beta_naive.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
        assert!(
            max_diff / scale < 1e-2,
            "trajectory divergence {max_diff} (scale {scale})"
        );
    }

    #[test]
    fn attached_recorder_produces_a_telemetry_digest() {
        let (train_ds, _) = dataset(400, 141);
        let cfg = TrainConfig::quick(5, SchemeSpec::Poly { s: 1, m: 2 }, 12);
        let mut tr = Trainer::new(cfg, &train_ds, None).unwrap();
        let rec = Recorder::enabled();
        tr.attach_recorder(&rec);
        let log = tr.run().unwrap();
        let tel = log.telemetry.as_ref().expect("traced run carries a digest");
        // Every master phase fired once per iteration (eval is sparser).
        for ph in [
            phase::ITERATION,
            phase::BROADCAST,
            phase::GATHER_WAIT,
            phase::DECODE,
            phase::STEP,
        ] {
            let st = tel
                .phases
                .iter()
                .find(|p| p.phase == ph)
                .unwrap_or_else(|| panic!("missing phase {ph}"));
            assert_eq!(st.count, 12, "{ph}");
        }
        // quick() injects table_vi1 delays, so the §VI model line exists
        // and the realized mean can be set against it.
        assert!(tel.stragglers.model_expected.unwrap() > 0.0);
        assert!(tel.stragglers.deviation.is_some());
        assert_eq!(tel.stragglers.workers.len(), 5);
        // Framed wire accounting strictly exceeds the raw payload bytes.
        assert!(log
            .records
            .iter()
            .all(|r| r.wire_bytes > r.floats_transmitted * 4));
    }

    #[test]
    fn untraced_run_carries_no_telemetry() {
        let (train_ds, _) = dataset(300, 143);
        let cfg = TrainConfig::quick(4, SchemeSpec::Poly { s: 1, m: 1 }, 5);
        let (log, _) = train(cfg, &train_ds, None).unwrap();
        assert!(log.telemetry.is_none());
    }

    #[test]
    fn quorum_override_rejected_for_group_quorum_schemes() {
        // A flat cutoff cannot guarantee per-group decodability on a
        // hetero scheme; the combination must fail at construction, not
        // abort mid-run on the first unlucky arrival prefix.
        let (train_ds, _) = dataset(400, 131);
        let profile = SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 };
        let mut cfg =
            TrainConfig::quick(6, SchemeSpec::Hetero { s: 1, m: 1, profile }, 5);
        cfg.quorum = Some(0.9);
        assert!(Trainer::new(cfg, &train_ds, None).is_err());
    }

    #[test]
    fn fleet_override_runs_homogeneous_scheme_on_skewed_fleet() {
        // A poly scheme on a bimodal fleet: same math, skewed clock. The
        // uniform-load baseline the hetero bench compares against.
        let (train_ds, _) = dataset(500, 121);
        let lr = 4.0 / train_ds.rows as f32;
        let mk = |fleet| TrainConfig {
            n: 6,
            scheme: SchemeSpec::Poly { s: 1, m: 2 },
            iters: 30,
            opt: OptChoice::Sgd { lr },
            eval_every: 15,
            delays: Some(DelayParams::ec2_fit()),
            mode: ExecutionMode::Virtual,
            seed: 31,
            minibatch: None,
            quorum: None,
            fleet,
            chaos: None,
        };
        let (log_uniform, _) = train(mk(None), &train_ds, None).unwrap();
        let (log_fast, _) = train(
            mk(Some(SpeedProfile::Custom(vec![4.0; 6]))),
            &train_ds,
            None,
        )
        .unwrap();
        // an all-fast fleet must beat the baseline clock
        assert!(
            log_fast.mean_iteration_sim_time() < log_uniform.mean_iteration_sim_time(),
            "{} vs {}",
            log_fast.mean_iteration_sim_time(),
            log_uniform.mean_iteration_sim_time()
        );
    }
}
