//! The training loop: scheme + cluster + optimizer + metrics.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use super::backend::{ComputeBackend, RustBackend};
use super::cluster::{Cluster, ExecutionMode};
use crate::coding::{
    quorum_count, ApproxCode, Decoder, GradientCode, PolynomialCode, RandomCode,
    SchemeConfig, UncodedScheme,
};
use crate::data::{auc, DenseDataset, SyntheticCategorical};
use crate::metrics::{IterationRecord, RunLog};
use crate::model::LogisticModel;
use crate::optim::{Momentum, Nag, Optimizer, Sgd};
use crate::simulator::DelayParams;

/// Which coding scheme to deploy.
#[derive(Debug, Clone, Copy)]
pub enum SchemeSpec {
    /// §III recursive-polynomial scheme with the paper's θ grid.
    Poly { s: usize, m: usize },
    /// §IV Gaussian random-matrix scheme.
    Random { s: usize, m: usize, seed: u64 },
    /// Naive uncoded baseline (d=1, wait for all).
    Uncoded,
    /// Approximate gradient coding with partial recovery: replication
    /// `d`, master proceeds at `ceil(quorum·n)` responders and accepts
    /// the least-squares decode (see [`ApproxCode`]).
    Approx { d: usize, quorum: f64 },
}

impl SchemeSpec {
    /// Human-readable label used in logs and bench tables.
    pub fn label(&self) -> String {
        match self {
            SchemeSpec::Poly { s, m } => format!("poly(s={s},m={m})"),
            SchemeSpec::Random { s, m, .. } => format!("random(s={s},m={m})"),
            SchemeSpec::Uncoded => "naive".to_string(),
            SchemeSpec::Approx { d, quorum } => format!("approx(d={d},q={quorum})"),
        }
    }

    /// Instantiate the scheme for `n` workers.
    pub fn build(&self, n: usize) -> anyhow::Result<Arc<dyn GradientCode>> {
        Ok(match *self {
            SchemeSpec::Poly { s, m } => {
                Arc::new(PolynomialCode::new(SchemeConfig::tight(n, s, m)?)?)
            }
            SchemeSpec::Random { s, m, seed } => {
                Arc::new(RandomCode::new(SchemeConfig::tight(n, s, m)?, seed)?)
            }
            SchemeSpec::Uncoded => Arc::new(UncodedScheme::new(n)),
            SchemeSpec::Approx { d, quorum } => {
                Arc::new(ApproxCode::with_quorum_fraction(n, d, quorum)?)
            }
        })
    }
}

/// Optimizer choice (the paper uses NAG).
#[derive(Debug, Clone, Copy)]
pub enum OptChoice {
    Nag { lr: f32, momentum: f32 },
    NagScheduled { lr: f32 },
    Sgd { lr: f32 },
    Momentum { lr: f32, mu: f32 },
}

impl OptChoice {
    fn build(&self, x0: Vec<f32>) -> Box<dyn Optimizer> {
        match *self {
            OptChoice::Nag { lr, momentum } => Box::new(Nag::new(x0, lr, momentum)),
            OptChoice::NagScheduled { lr } => Box::new(Nag::scheduled(x0, lr)),
            OptChoice::Sgd { lr } => Box::new(Sgd::new(x0, lr)),
            OptChoice::Momentum { lr, mu } => Box::new(Momentum::new(x0, lr, mu)),
        }
    }
}

/// Full training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub n: usize,
    pub scheme: SchemeSpec,
    pub iters: usize,
    pub opt: OptChoice,
    /// Evaluate loss/AUC every this many iterations (and at the end).
    pub eval_every: usize,
    /// §VI delay injection; `None` disables straggler simulation.
    pub delays: Option<DelayParams>,
    pub mode: ExecutionMode,
    pub seed: u64,
    /// Mini-batch fraction in (0, 1] for the rust backend; `None` = full
    /// batch (§II: the scheme applies to both batch GD and mini-batch SGD).
    pub minibatch: Option<f64>,
    /// Early-termination policy: proceed once this fraction of workers
    /// has responded (`ceil(quorum·n)`, clamped to `1..=n`) instead of
    /// the scheme's exact `n - s`. `None` keeps the scheme's own wait.
    /// Below the exact threshold this only makes sense with
    /// [`SchemeSpec::Approx`], whose partial decoder accepts any
    /// responder count; exact schemes will fail to decode.
    pub quorum: Option<f64>,
}

impl TrainConfig {
    pub fn quick(n: usize, scheme: SchemeSpec, iters: usize) -> Self {
        TrainConfig {
            n,
            scheme,
            iters,
            opt: OptChoice::Nag { lr: 1e-3, momentum: 0.9 },
            eval_every: 10,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::Virtual,
            seed: 0xfeed,
            minibatch: None,
            quorum: None,
        }
    }
}

/// Owns the cluster and optimizer for one training run.
pub struct Trainer {
    cfg: TrainConfig,
    code: Arc<dyn GradientCode>,
    cluster: Cluster,
    out_dim: usize,
    /// Responders the master proceeds at (scheme's `n - s`, or the
    /// `cfg.quorum` override).
    wait_for: usize,
    opt: Box<dyn Optimizer>,
    /// Per-responder-set decoder plus the scheme's reported decode
    /// residual (`None` for exact schemes).
    decoder_cache: HashMap<u64, (Decoder, Option<f64>)>,
    /// Eval data (train loss / test AUC); train eval is subsampled.
    train_eval: DenseDataset,
    test: Option<DenseDataset>,
}

impl Trainer {
    /// Build with the pure-rust backend over `train`.
    pub fn new(
        cfg: TrainConfig,
        train: &DenseDataset,
        test: Option<&DenseDataset>,
    ) -> anyhow::Result<Self> {
        let code = cfg.scheme.build(cfg.n)?;
        let m = code.config().m;
        let train_padded = SyntheticCategorical::pad_to_multiple(train, m);
        let backend: Arc<dyn ComputeBackend> = match cfg.minibatch {
            None => Arc::new(RustBackend::new(code.as_ref(), &train_padded)?),
            Some(frac) => Arc::new(RustBackend::with_minibatch(
                code.as_ref(),
                &train_padded,
                frac,
                cfg.seed ^ 0x6d62, // "mb"
            )?),
        };
        Self::with_backend(cfg, code, backend, &train_padded, test)
    }

    /// Build with an explicit backend (e.g. the PJRT artifact backend).
    /// `train_eval` must already be padded to the scheme's `m`.
    pub fn with_backend(
        cfg: TrainConfig,
        code: Arc<dyn GradientCode>,
        backend: Arc<dyn ComputeBackend>,
        train_eval: &DenseDataset,
        test: Option<&DenseDataset>,
    ) -> anyhow::Result<Self> {
        let l = backend.dim();
        let out_dim = backend.out_dim();
        anyhow::ensure!(l % code.config().m == 0, "backend dim not divisible by m");
        // Subsample train eval to bound metric cost on big runs.
        let train_eval = if train_eval.rows > 4096 {
            let idx: Vec<usize> = (0..4096).map(|i| i * (train_eval.rows / 4096)).collect();
            train_eval.select_rows(&idx)
        } else {
            train_eval.clone()
        };
        let wait_for = match cfg.quorum {
            Some(q) => {
                anyhow::ensure!(
                    q > 0.0 && q <= 1.0,
                    "quorum fraction must be in (0, 1], got {q}"
                );
                quorum_count(cfg.n, q)
            }
            None => code.config().wait_for(),
        };
        let cluster = Cluster::spawn_with_quorum(
            *code.config(),
            backend,
            cfg.mode,
            cfg.delays,
            cfg.seed,
            wait_for,
        );
        let opt = cfg.opt.build(vec![0.0f32; l]);
        let test = test.map(|t| {
            // Pad test data columns to match l if needed.
            if t.cols == l {
                t.clone()
            } else {
                assert!(t.cols < l, "test wider than train");
                let mut x = vec![0.0f32; t.rows * l];
                for r in 0..t.rows {
                    x[r * l..r * l + t.cols].copy_from_slice(t.row(r));
                }
                DenseDataset { x, y: t.y.clone(), rows: t.rows, cols: l }
            }
        });
        Ok(Trainer {
            cfg,
            code,
            cluster,
            out_dim,
            wait_for,
            opt,
            decoder_cache: HashMap::new(),
            train_eval,
            test,
        })
    }

    /// Responders the master proceeds at each iteration.
    pub fn wait_for(&self) -> usize {
        self.wait_for
    }

    /// Bitmask cache key for a sorted responder set (n <= 64).
    fn mask(responders: &[usize]) -> u64 {
        responders.iter().fold(0u64, |acc, &w| acc | (1 << w))
    }

    /// Run the configured number of iterations.
    pub fn run(&mut self) -> anyhow::Result<RunLog> {
        let mut log = RunLog::new(self.cfg.scheme.label());
        let mut sim_clock = 0.0f64;
        let wait_for = self.wait_for;
        let mut grad = Vec::with_capacity(self.out_dim * self.code.config().m);
        for iter in 0..self.cfg.iters {
            let beta = Arc::new(self.opt.eval_point().to_vec());
            let gather = self.cluster.run_iteration(iter, beta);
            let t0 = Instant::now();

            // Responders: first `wait_for` by arrival order (the exact
            // n-s, or the configured quorum), then sorted so the decoder
            // cache key is order-insensitive.
            let mut responders: Vec<usize> = gather
                .results
                .iter()
                .take(wait_for)
                .map(|r| r.worker)
                .collect();
            responders.sort_unstable();
            let key = Self::mask(&responders);
            if !self.decoder_cache.contains_key(&key) {
                let (dw, residual) = self.code.decode_weights_with_residual(&responders)?;
                self.decoder_cache.insert(key, (Decoder::from_weights(&dw), residual));
            }
            let (dec, decode_residual) = &self.decoder_cache[&key];
            let decode_residual = *decode_residual;

            // Map worker id -> returned vector.
            let mut by_worker: Vec<Option<&[f32]>> = vec![None; self.cfg.n];
            for r in &gather.results {
                by_worker[r.worker] = Some(&r.f);
            }
            let fs: Vec<&[f32]> = dec
                .used_workers()
                .iter()
                .map(|&w| by_worker[w].expect("responder result present"))
                .collect();
            dec.decode_into(&fs, &mut grad)?;
            self.opt.step(&grad);
            let master_compute = t0.elapsed().as_secs_f64();

            sim_clock += gather.iteration_time;
            let evaluate = iter % self.cfg.eval_every == 0 || iter + 1 == self.cfg.iters;
            let (loss, auc_val) = if evaluate {
                let beta_now = self.opt.iterate();
                let loss = LogisticModel::loss(&self.train_eval, beta_now);
                let auc_val = self.test.as_ref().map(|t| {
                    auc(&LogisticModel::predict(t, beta_now), &t.y)
                });
                (Some(loss), auc_val)
            } else {
                (None, None)
            };
            log.push(IterationRecord {
                iter,
                sim_time: gather.iteration_time,
                sim_clock,
                master_compute,
                worker_compute: gather.worker_compute,
                responders,
                floats_transmitted: gather.results.len() * self.out_dim,
                decode_residual,
                loss,
                auc: auc_val,
            });
        }
        Ok(log)
    }

    /// Current parameters.
    pub fn params(&self) -> &[f32] {
        self.opt.iterate()
    }

    pub fn scheme(&self) -> &dyn GradientCode {
        self.code.as_ref()
    }
}

/// One-call convenience: train and return (log, final parameters).
pub fn train(
    cfg: TrainConfig,
    train_ds: &DenseDataset,
    test_ds: Option<&DenseDataset>,
) -> anyhow::Result<(RunLog, Vec<f32>)> {
    let mut tr = Trainer::new(cfg, train_ds, test_ds)?;
    let log = tr.run()?;
    let params = tr.params().to_vec();
    Ok((log, params))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{train_test_split, CategoricalConfig};

    fn dataset(rows: usize, seed: u64) -> (DenseDataset, DenseDataset) {
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), seed);
        let ds = gen.generate(rows, seed + 1);
        train_test_split(&ds, 0.25, seed + 2)
    }

    #[test]
    fn coded_training_learns() {
        let (train_ds, test_ds) = dataset(1200, 51);
        let lr = 6.0 / train_ds.rows as f32;
        let cfg = TrainConfig {
            n: 5,
            scheme: SchemeSpec::Poly { s: 1, m: 2 },
            iters: 150,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: 10,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::Virtual,
            seed: 7,
            minibatch: None,
            quorum: None,
        };
        let (log, _beta) = train(cfg, &train_ds, Some(&test_ds)).unwrap();
        assert_eq!(log.records.len(), 150);
        let first_loss = log.records[0].loss.unwrap();
        let last_loss = log.final_loss().unwrap();
        assert!(last_loss < first_loss * 0.9, "{first_loss} -> {last_loss}");
        assert!(log.final_auc().unwrap() > 0.7, "AUC {:?}", log.final_auc());
        assert!(log.total_sim_time() > 0.0);
    }

    #[test]
    fn coded_and_uncoded_reach_same_solution() {
        // The paper's point: coding changes the clock, not the learning —
        // identical gradients mean identical trajectories.
        let (train_ds, _) = dataset(400, 61);
        let lr = 4.0 / train_ds.rows as f32;
        let mk = |scheme| TrainConfig {
            n: 4,
            scheme,
            iters: 25,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: 25,
            delays: None,
            mode: ExecutionMode::Virtual,
            seed: 9,
            minibatch: None,
            quorum: None,
        };
        let (_, beta_coded) =
            train(mk(SchemeSpec::Poly { s: 1, m: 1 }), &train_ds, None).unwrap();
        let (_, beta_naive) = train(mk(SchemeSpec::Uncoded), &train_ds, None).unwrap();
        let max_diff = beta_coded
            .iter()
            .zip(&beta_naive)
            .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()));
        let scale = beta_naive.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
        assert!(
            max_diff / scale < 1e-2,
            "trajectory divergence {max_diff} (scale {scale})"
        );
    }

    #[test]
    fn random_scheme_trains_too() {
        let (train_ds, test_ds) = dataset(400, 71);
        let lr = 4.0 / train_ds.rows as f32;
        let cfg = TrainConfig {
            n: 6,
            scheme: SchemeSpec::Random { s: 2, m: 2, seed: 3 },
            iters: 40,
            opt: OptChoice::NagScheduled { lr },
            eval_every: 10,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::Virtual,
            seed: 11,
            minibatch: None,
            quorum: None,
        };
        let (log, _) = train(cfg, &train_ds, Some(&test_ds)).unwrap();
        assert!(log.final_auc().unwrap() > 0.65);
    }

    #[test]
    fn approx_scheme_trains_with_partial_quorum() {
        let (train_ds, _) = dataset(600, 91);
        let lr = 4.0 / train_ds.rows as f32;
        let cfg = TrainConfig {
            n: 8,
            scheme: SchemeSpec::Approx { d: 3, quorum: 0.75 },
            iters: 40,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: 10,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::Virtual,
            seed: 17,
            minibatch: None,
            quorum: None,
        };
        let (log, _) = train(cfg, &train_ds, None).unwrap();
        assert_eq!(log.records.len(), 40);
        // ceil(0.75 · 8) = 6 responders per iteration, residual reported
        assert!(log.records.iter().all(|r| r.responders.len() == 6));
        assert!(log.records.iter().all(|r| r.decode_residual.is_some()));
        let first = log.records[0].loss.unwrap();
        let last = log.final_loss().unwrap();
        assert!(last < first, "approximate training must still learn: {first} -> {last}");
    }

    #[test]
    fn quorum_override_applies_to_any_scheme() {
        // An uncoded scheme normally waits for everyone; the quorum
        // override can only be exercised by a scheme whose decoder
        // accepts fewer responders, so use approx with q = 1.0 built in
        // and a *tighter* runtime override.
        let (train_ds, _) = dataset(400, 93);
        let lr = 4.0 / train_ds.rows as f32;
        let cfg = TrainConfig {
            n: 6,
            scheme: SchemeSpec::Approx { d: 2, quorum: 1.0 },
            iters: 10,
            opt: OptChoice::Sgd { lr },
            eval_every: 5,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::Virtual,
            seed: 19,
            minibatch: None,
            quorum: Some(2.0 / 3.0),
        };
        let mut tr = Trainer::new(cfg, &train_ds, None).unwrap();
        assert_eq!(tr.wait_for(), 4, "override ceil(6·2/3) = 4 beats the scheme's 6");
        let log = tr.run().unwrap();
        assert!(log.records.iter().all(|r| r.responders.len() == 4));
    }

    #[test]
    fn realtime_mode_trains() {
        let (train_ds, _) = dataset(300, 81);
        let lr = 4.0 / train_ds.rows as f32;
        let cfg = TrainConfig {
            n: 4,
            scheme: SchemeSpec::Poly { s: 1, m: 1 },
            iters: 8,
            opt: OptChoice::Sgd { lr },
            eval_every: 4,
            delays: Some(DelayParams::table_vi1()),
            mode: ExecutionMode::RealTime { scale: 1e-4 },
            seed: 13,
            minibatch: None,
            quorum: None,
        };
        let (log, _) = train(cfg, &train_ds, None).unwrap();
        assert_eq!(log.records.len(), 8);
        // responders are a strict subset when s > 0
        assert!(log.records.iter().all(|r| r.responders.len() == 3));
    }
}
