//! Worker thread: receive a task, compute the coded gradient through the
//! backend, optionally sleep an injected delay (real-time mode), apply
//! any scheduled fault from the chaos plan, report.
//!
//! Only real-time mode runs this loop on dedicated threads (the racy
//! wire path is the point there). Virtual mode inlines the identical
//! per-task behaviour as pool tasks — see
//! `Cluster::virtual_worker_reports` in `cluster.rs`.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use super::backend::ComputeBackend;
use super::messages::{Task, WorkerResult};
use super::wire::crc32_f32s;
use crate::chaos::{Effect, FaultKind, FaultPlan};
use crate::rngs::{Pcg64, ShiftedExponential};
use crate::simulator::DelayParams;

/// Per-worker delay injector (the §VI model's two components).
pub struct DelayInjector {
    comp: ShiftedExponential,
    comm: ShiftedExponential,
    rng: Pcg64,
}

impl DelayInjector {
    pub fn new(comp: ShiftedExponential, comm: ShiftedExponential, rng: Pcg64) -> Self {
        DelayInjector { comp, comm, rng }
    }

    /// Injector for one worker of a (possibly heterogeneous) fleet:
    /// `work` baseline-subset compute units at relative speed `speed`,
    /// messages of `l/m` floats. Computation scales with both (`work·t₁/
    /// speed` shift, `speed·λ₁/work` rate); communication is governed by
    /// the message size only. `work = d, speed = 1` reproduces the
    /// paper's homogeneous assumptions 1–2 exactly.
    pub fn scaled(params: &DelayParams, work: f64, speed: f64, m: usize, rng: Pcg64) -> Self {
        assert!(work > 0.0 && speed > 0.0 && m >= 1);
        DelayInjector::new(
            ShiftedExponential::new(work * params.t1 / speed, speed * params.lambda1 / work),
            ShiftedExponential::new(params.t2 / m as f64, m as f64 * params.lambda2),
            rng,
        )
    }

    /// Sample a total virtual finish time (computation + communication).
    pub fn sample(&mut self) -> f64 {
        self.comp.sample(&mut self.rng) + self.comm.sample(&mut self.rng)
    }
}

pub(super) struct WorkerLoop {
    pub id: usize,
    pub backend: Arc<dyn ComputeBackend>,
    pub tasks: Receiver<Task>,
    pub results: Sender<WorkerResult>,
    pub delays: Option<DelayInjector>,
    /// Seconds of real sleep per unit of virtual delay (0 = virtual mode,
    /// no sleeping).
    pub sleep_scale: f64,
    /// In real-time mode, skip to the newest queued task (stale tasks
    /// would only produce results the master already gave up on). This
    /// matters even more under the approximate regime's quorum policy:
    /// the master proceeds at `ceil(q·n)` arrivals, so with small
    /// quorums a slow worker can fall several iterations behind — it
    /// drains the queue and computes only the freshest parameters
    /// instead of burning compute on results nobody will decode.
    pub skip_stale: bool,
    /// Deterministic fault schedule, queried per task.
    pub chaos: Option<Arc<FaultPlan>>,
    /// Virtual mode sends a `failed = true` tombstone for silent faults
    /// (the virtual gather counts every worker exactly once, so it needs
    /// no timeout and stays deterministic); real-time mode keeps them
    /// genuinely silent so the master's gather deadline is exercised.
    pub tombstone_faults: bool,
}

impl WorkerLoop {
    pub fn run(mut self) {
        let mut out = Vec::new();
        while let Ok(mut task) = self.tasks.recv() {
            if self.skip_stale {
                while let Ok(newer) = self.tasks.try_recv() {
                    task = newer;
                }
            }
            // Sample the delay before consulting the plan so the delay RNG
            // stream stays aligned with a fault-free run of the same seed.
            let mut virtual_finish = self.delays.as_mut().map_or(0.0, |d| d.sample());
            let effect = self
                .chaos
                .as_ref()
                .map_or(Effect::None, |p| p.effect(self.id, task.iter as u64));
            if effect.is_silent() {
                if self.tombstone_faults {
                    let msg = WorkerResult {
                        worker: self.id,
                        iter: task.iter,
                        f: Vec::new(),
                        virtual_finish,
                        compute_secs: 0.0,
                        failed: true,
                        crc: None,
                    };
                    if self.results.send(msg).is_err() {
                        return;
                    }
                }
                continue;
            }
            if let Effect::Fault(FaultKind::Delay(secs)) = effect {
                virtual_finish += secs;
            }
            if self.sleep_scale > 0.0 && virtual_finish > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(
                    virtual_finish * self.sleep_scale,
                ));
            }
            // lint: allow(wallclock-entropy) realized latency metric only; never feeds seeds or decisions
            let t0 = Instant::now();
            let failed = match self
                .backend
                .encoded_gradient(self.id, task.iter, &task.beta, &mut out)
            {
                Ok(()) => false,
                Err(e) => {
                    // A failed worker behaves like a straggler, but it must
                    // still REPORT (an unreported failure would deadlock the
                    // virtual-mode gather). The master tolerates up to s.
                    eprintln!("worker {}: backend error: {e}", self.id);
                    out.clear();
                    true
                }
            };
            let compute_secs = t0.elapsed().as_secs_f64();
            // Checksum the TRUE payload, then corrupt: the master's CRC
            // check must flag the flipped bit exactly like the TCP frame
            // checksum would.
            let crc = self.chaos.as_ref().map(|_| crc32_f32s(&out));
            if matches!(effect, Effect::Fault(FaultKind::Corrupt)) && !out.is_empty() {
                let idx = (task.iter * 31 + self.id) % out.len();
                out[idx] = f32::from_bits(out[idx].to_bits() ^ 1);
            }
            let msg = WorkerResult {
                worker: self.id,
                iter: task.iter,
                f: out.clone(),
                virtual_finish,
                compute_secs,
                failed,
                crc,
            };
            let copies =
                if matches!(effect, Effect::Fault(FaultKind::Duplicate)) { 2 } else { 1 };
            for _ in 0..copies {
                if self.results.send(msg.clone()).is_err() {
                    return; // master gone
                }
            }
        }
    }
}
