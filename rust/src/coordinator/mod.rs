//! L3 coordinator: the distributed synchronous gradient-descent runtime.
//!
//! Topology is the paper's: one master, `n` workers. Workers compute the
//! partial gradients of their `d` assigned subsets and transmit the coded
//! `l/m`-dimensional vector; the master waits for the first `n-s`
//! responders, decodes the sum gradient, and steps the optimizer.
//!
//! Offline substitution for the paper's EC2/mpi4py deployment: each
//! worker is an OS thread connected by channels ([`Cluster`]), and
//! straggling is injected from the §VI shifted-exponential delay model.
//! Two execution modes:
//! - [`ExecutionMode::Virtual`] — all results are collected, responder
//!   order and the iteration clock come from sampled virtual delays
//!   (bit-reproducible; used by the figure benches);
//! - [`ExecutionMode::RealTime`] — workers *sleep* their sampled delays
//!   (scaled) and the master takes the first `n-s` arrivals off the wire,
//!   exercising the real racy straggler path.
//!
//! The gradient+encode compute itself always runs for real, through a
//! [`ComputeBackend`] — either the pure-rust reference backend or the
//! PJRT backend executing the AOT-compiled JAX/Pallas artifacts (behind
//! the `pjrt` feature).
//!
//! **Quorum policy (approximate regime).** By default the master waits
//! for the scheme's exact `n - s`. With [`SchemeSpec::Approx`] — or an
//! explicit `TrainConfig::quorum` fraction — it proceeds at
//! `ceil(quorum·n)` responders and applies the least-squares partial
//! decoder of [`crate::coding::ApproxCode`], recording the reported
//! decode residual in each [`crate::metrics::IterationRecord`]. This
//! trades a bounded gradient error for a much shorter straggler tail
//! (see `rust/benches/approx_tradeoff.rs` for the measured curve).
//!
//! **Fault tolerance (chaos).** `TrainConfig::chaos` threads a
//! deterministic [`crate::chaos::FaultPlan`] through every worker and
//! arms the robustness machinery: per-result CRC32 checksums (rejected
//! payloads count as stragglers), gather dedupe, a per-iteration gather
//! deadline with task re-broadcasts ([`crate::chaos::GatherPolicy`]),
//! and the degradation ladder ([`crate::chaos::DegradeLadder`]) — exact
//! decode while the wait rule holds, least-squares partial decode below
//! it, stale gradient as the last resort. Everything injected and every
//! recovery decision lands in the run's [`crate::chaos::FaultLog`].
//!
//! **Heterogeneous fleets.** [`SchemeSpec::Hetero`] adapts the placement
//! to a per-worker [`SpeedProfile`]: workers are partitioned into speed
//! groups with group-local loads and speed-proportional subset sizes
//! ([`crate::coding::HeteroCode`]), the delay injection scales each
//! worker's shifted exponentials by its speed and compute load
//! ([`FleetProfile`]), and the gather stops under the per-group
//! [`WaitRule`] as soon as every group is decodable — usually before the
//! flat `n - s`-th arrival. `TrainConfig::fleet` runs any scheme on a
//! skewed fleet (the uniform-load baseline of
//! `rust/benches/hetero_speedup.rs`).
//!
//! **Observability.** [`Trainer::attach_recorder`] threads a
//! [`crate::obs::Recorder`] through the whole stack: master phase spans
//! (broadcast → gather_wait → decode → step → eval), per-worker response
//! latencies on the virtual or wall clock, gather outcome and wire
//! frame/byte counters, and injected-fault instants. The run's
//! [`crate::metrics::RunLog`] then carries a
//! [`crate::obs::TelemetrySummary`] digest, and the raw stream exports to
//! JSONL ([`crate::obs::Recorder::to_jsonl`]) or a Perfetto-loadable
//! Chrome trace ([`crate::obs::Recorder::to_chrome`]). The TCP deployment
//! mirrors this via [`RemoteMaster::set_recorder`] and
//! [`run_worker_traced`].
//!
//! # Example: training on the in-process backend
//!
//! ```
//! use gradcode::coordinator::{train, SchemeSpec, TrainConfig};
//! use gradcode::data::{CategoricalConfig, SyntheticCategorical};
//!
//! // Synthetic one-hot categorical data (the paper's workload shape).
//! let gen = SyntheticCategorical::new(CategoricalConfig::default(), 7);
//! let ds = gen.generate(200, 8);
//!
//! // n = 4 workers, §III scheme with s = 1, m = 1; 3 iterations.
//! let cfg = TrainConfig::quick(4, SchemeSpec::Poly { s: 1, m: 1 }, 3);
//! let (log, beta) = train(cfg, &ds, None).unwrap();
//! assert_eq!(log.records.len(), 3);
//! assert_eq!(beta.len(), ds.cols);
//! // s = 1 ⇒ every iteration used n - s = 3 responders
//! assert!(log.records.iter().all(|r| r.responders.len() == 3));
//! ```
//!
//! # Example: proceeding at a quorum (approximate recovery)
//!
//! ```
//! use gradcode::coordinator::{train, SchemeSpec, TrainConfig};
//! use gradcode::data::{CategoricalConfig, SyntheticCategorical};
//!
//! let gen = SyntheticCategorical::new(CategoricalConfig::default(), 9);
//! let ds = gen.generate(200, 10);
//!
//! // Replication d = 2, master proceeds at 75% of workers.
//! let cfg = TrainConfig::quick(4, SchemeSpec::Approx { d: 2, quorum: 0.75 }, 3);
//! let (log, _beta) = train(cfg, &ds, None).unwrap();
//! assert!(log.records.iter().all(|r| r.responders.len() == 3));
//! // the partial decoder reports its residual every iteration
//! assert!(log.records.iter().all(|r| r.decode_residual.is_some()));
//! ```

mod backend;
mod cluster;
mod messages;
pub mod remote;
mod trainer;
pub mod wire;
mod worker;

pub use backend::{ComputeBackend, RustBackend};
pub use cluster::{Cluster, ExecutionMode, FleetProfile, WaitRule};
pub use messages::{Task, WorkerResult};
pub use remote::{
    run_worker, run_worker_chaos, run_worker_traced, RemoteGather, RemoteMaster,
};
pub use trainer::{train, OptChoice, SchemeSpec, TrainConfig, Trainer};
pub use wire::WireCounters;
// The fleet-shape vocabulary lives in the simulator (it parameterizes the
// §VI delay model) but is part of the coordinator's configuration surface.
pub use crate::simulator::SpeedProfile;
