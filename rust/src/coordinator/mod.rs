//! L3 coordinator: the distributed synchronous gradient-descent runtime.
//!
//! Topology is the paper's: one master, `n` workers. Workers compute the
//! partial gradients of their `d` assigned subsets and transmit the coded
//! `l/m`-dimensional vector; the master waits for the first `n-s`
//! responders, decodes the sum gradient, and steps the optimizer.
//!
//! Offline substitution for the paper's EC2/mpi4py deployment: each
//! worker is an OS thread connected by channels ([`Cluster`]), and
//! straggling is injected from the §VI shifted-exponential delay model.
//! Two execution modes:
//! - [`ExecutionMode::Virtual`] — all results are collected, responder
//!   order and the iteration clock come from sampled virtual delays
//!   (bit-reproducible; used by the figure benches);
//! - [`ExecutionMode::RealTime`] — workers *sleep* their sampled delays
//!   (scaled) and the master takes the first `n-s` arrivals off the wire,
//!   exercising the real racy straggler path.
//!
//! The gradient+encode compute itself always runs for real, through a
//! [`ComputeBackend`] — either the pure-rust reference backend or the
//! PJRT backend executing the AOT-compiled JAX/Pallas artifacts.

mod backend;
mod cluster;
mod messages;
pub mod remote;
mod trainer;
pub mod wire;
mod worker;

pub use backend::{ComputeBackend, RustBackend};
pub use cluster::{Cluster, ExecutionMode};
pub use messages::{Task, WorkerResult};
pub use remote::{run_worker, RemoteMaster};
pub use trainer::{train, OptChoice, SchemeSpec, TrainConfig, Trainer};
