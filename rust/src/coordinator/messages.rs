//! Master ⇄ worker protocol messages.

use std::sync::Arc;

/// Master → worker: compute the coded gradient at `beta` for `iter`.
#[derive(Debug, Clone)]
pub struct Task {
    pub iter: usize,
    /// Shared parameter vector (broadcast without copying per worker).
    pub beta: Arc<Vec<f32>>,
}

/// Worker → master: the coded `l/m`-dimensional vector plus timing.
#[derive(Debug, Clone)]
pub struct WorkerResult {
    pub worker: usize,
    pub iter: usize,
    /// Transmitted coded vector `f_w` (empty when `failed`).
    pub f: Vec<f32>,
    /// Sampled virtual finish time under the §VI delay model (seconds);
    /// 0 when delay injection is disabled.
    pub virtual_finish: f64,
    /// Measured wall-clock seconds spent in gradient + encode.
    pub compute_secs: f64,
    /// Backend failure: the worker behaves as a permanent straggler; the
    /// scheme tolerates up to `s` of these.
    pub failed: bool,
    /// CRC32 of `f` (its little-endian wire form), attached when fault
    /// injection is active so the master can detect payload corruption on
    /// the in-process path with exactly the check TCP frames get. `None`
    /// when chaos is off (no verification cost on the happy path).
    pub crc: Option<u32>,
}
