//! [`ComputeBackend`] implementation that executes the AOT-compiled
//! `worker_step` artifact (L1 Pallas gradient + coded encode fused in one
//! HLO module) through PJRT.
//!
//! The `xla` crate's client and executables are `Rc`-based (`!Send`), so
//! they live on a dedicated **executor service thread**; worker threads
//! submit requests over a channel and block on a reply. Execution is
//! therefore serialized at the PJRT boundary — the CPU PJRT runtime
//! parallelizes internally across its own thread pool, so worker-level
//! concurrency would buy nothing on this backend anyway.

use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::artifact::{ArtifactKey, Manifest};
use super::engine::PjrtEngine;
use crate::coding::GradientCode;
use crate::coordinator::ComputeBackend;
use crate::data::DenseDataset;

/// Per-worker frozen inputs (the worker's data shards never change).
struct WorkerInputs {
    /// `d·rows·dim` flattened design blocks.
    xs: Vec<f32>,
    /// `d·rows` labels.
    ys: Vec<f32>,
    /// `d·m` encode coefficients.
    coeffs: Vec<f32>,
}

struct EncodeRequest {
    worker: usize,
    beta: Vec<f32>,
    reply: Sender<Result<Vec<f32>>>,
}

/// PJRT-backed compute: the request path the paper's workers run.
pub struct PjrtBackend {
    tx: Mutex<Option<Sender<EncodeRequest>>>,
    handle: Mutex<Option<JoinHandle<()>>>,
    m: usize,
    dim: usize,
}

impl PjrtBackend {
    /// Build from a scheme + padded training data, resolving the worker
    /// artifact via the manifest in `artifact_dir`. Spawns the executor
    /// thread and fails fast if the artifact is missing or won't compile.
    pub fn new(
        artifact_dir: &Path,
        code: &dyn GradientCode,
        train: &DenseDataset,
    ) -> Result<Self> {
        let cfg = *code.config();
        cfg.check_dim(train.cols)?;
        let rows = train.rows / cfg.n;
        anyhow::ensure!(rows > 0, "not enough rows for n={} subsets", cfg.n);
        let manifest = Manifest::load(artifact_dir)?;
        let key = ArtifactKey::worker(cfg.n, cfg.d, cfg.m, rows, train.cols);
        let path: PathBuf = manifest.resolve(&key).with_context(|| {
            format!(
                "no artifact for n={} d={} m={} rows={rows} dim={} — run \
                 `make artifacts` or python -m compile.aot with these shapes",
                cfg.n, cfg.d, cfg.m, train.cols
            )
        })?;

        // Freeze per-worker inputs (pure-rust work, done on this thread).
        let parts = crate::data::partition_rows(rows * cfg.n, cfg.n);
        let subsets: Vec<DenseDataset> =
            parts.iter().map(|idx| train.select_rows(idx)).collect();
        let mut workers = Vec::with_capacity(cfg.n);
        for w in 0..cfg.n {
            let assigned = code.placement().assigned(w);
            let mut xs = Vec::with_capacity(cfg.d * rows * train.cols);
            let mut ys = Vec::with_capacity(cfg.d * rows);
            for &t in &assigned {
                xs.extend_from_slice(&subsets[t].x);
                ys.extend_from_slice(&subsets[t].y);
            }
            let coeffs: Vec<f32> =
                code.encode_coeffs(w)?.iter().map(|&c| c as f32).collect();
            workers.push(WorkerInputs { xs, ys, coeffs });
        }

        let (tx, rx) = channel::<EncodeRequest>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let (d, m, dim) = (cfg.d, cfg.m, train.cols);
        let handle = std::thread::Builder::new()
            .name("gradcode-pjrt".into())
            .spawn(move || {
                executor_loop(path, workers, d, m, rows, dim, rx, ready_tx)
            })
            .context("spawning PJRT executor thread")?;
        ready_rx
            .recv()
            .context("PJRT executor thread died during startup")??;
        Ok(PjrtBackend {
            tx: Mutex::new(Some(tx)),
            handle: Mutex::new(Some(handle)),
            m: cfg.m,
            dim: train.cols,
        })
    }
}

fn executor_loop(
    path: PathBuf,
    workers: Vec<WorkerInputs>,
    d: usize,
    m: usize,
    rows: usize,
    dim: usize,
    rx: Receiver<EncodeRequest>,
    ready_tx: Sender<Result<()>>,
) {
    // All PJRT (Rc-based) state is created and used on this thread only.
    let setup = (|| -> Result<_> {
        let engine = PjrtEngine::cpu()?;
        let exe = engine.load_hlo_text(&path)?;
        Ok((engine, exe))
    })();
    let (_engine, exe) = match setup {
        Ok(pair) => {
            let _ = ready_tx.send(Ok(()));
            pair
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        let wi = &workers[req.worker];
        let result = exe.run_f32(&[
            (&wi.xs, &[d, rows, dim]),
            (&wi.ys, &[d, rows]),
            (&req.beta, &[dim]),
            (&wi.coeffs, &[d, m]),
        ]);
        let _ = req.reply.send(result);
    }
}

impl ComputeBackend for PjrtBackend {
    fn dim(&self) -> usize {
        self.dim
    }

    fn out_dim(&self) -> usize {
        self.dim / self.m
    }

    fn encoded_gradient(
        &self,
        worker: usize,
        _iter: usize,
        beta: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let (reply_tx, reply_rx) = channel();
        {
            let guard = self.tx.lock().unwrap_or_else(|e| e.into_inner());
            let tx = guard.as_ref().context("PJRT executor stopped")?;
            tx.send(EncodeRequest {
                worker,
                beta: beta.to_vec(),
                reply: reply_tx,
            })
            .ok()
            .context("PJRT executor channel closed")?;
        }
        let result = reply_rx.recv().context("PJRT executor dropped request")??;
        out.clear();
        out.extend_from_slice(&result);
        Ok(())
    }
}

impl Drop for PjrtBackend {
    fn drop(&mut self) {
        // Close the request channel, then join the executor.
        self.tx.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some(h) = self.handle.lock().unwrap_or_else(|e| e.into_inner()).take() {
            let _ = h.join();
        }
    }
}

/// Master-side evaluator backed by the `predict` artifact. Single-thread
/// use (`!Send` PJRT state stays on the caller's thread).
pub struct PjrtPredictor {
    exe: super::engine::Executable,
    rows: usize,
    dim: usize,
}

impl PjrtPredictor {
    pub fn new(
        engine: &PjrtEngine,
        artifact_dir: &Path,
        rows: usize,
        dim: usize,
    ) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let key = ArtifactKey::predict(rows, dim);
        let path = manifest
            .resolve(&key)
            .with_context(|| format!("no predict artifact for rows={rows} dim={dim}"))?;
        Ok(PjrtPredictor { exe: engine.load_hlo_text(&path)?, rows, dim })
    }

    /// σ(Xβ) for an `rows × dim` block.
    pub fn predict(&self, x: &[f32], beta: &[f32]) -> Result<Vec<f32>> {
        self.exe.run_f32(&[(x, &[self.rows, self.dim]), (beta, &[self.dim])])
    }
}
