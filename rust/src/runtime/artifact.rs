//! Artifact resolution: map (kind, shape signature) -> HLO file via the
//! manifest written by `python/compile/aot.py`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Shape signature of an artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    /// "worker" or "predict".
    pub kind: ArtifactKind,
    pub n: usize,
    pub d: usize,
    pub m: usize,
    pub rows: usize,
    pub dim: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    Worker,
    Predict,
}

impl ArtifactKey {
    pub fn worker(n: usize, d: usize, m: usize, rows: usize, dim: usize) -> Self {
        ArtifactKey { kind: ArtifactKind::Worker, n, d, m, rows, dim }
    }

    pub fn predict(rows: usize, dim: usize) -> Self {
        ArtifactKey { kind: ArtifactKind::Predict, n: 0, d: 0, m: 0, rows, dim }
    }
}

/// Parsed `manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    dir: PathBuf,
    entries: HashMap<ArtifactKey, String>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`. Each line: `name kind n d m rows dim`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut entries = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(
                parts.len() == 7,
                "manifest line {} malformed: {line:?}",
                lineno + 1
            );
            let kind = match parts[1] {
                "worker" => ArtifactKind::Worker,
                "predict" => ArtifactKind::Predict,
                other => anyhow::bail!("unknown artifact kind {other:?}"),
            };
            let nums: Vec<usize> = parts[2..7]
                .iter()
                .map(|p| p.parse().context("manifest number"))
                .collect::<Result<_>>()?;
            let key = ArtifactKey {
                kind,
                n: nums[0],
                d: nums[1],
                m: nums[2],
                rows: nums[3],
                dim: nums[4],
            };
            entries.insert(key, parts[0].to_string());
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Path of the artifact for `key`, if present.
    pub fn resolve(&self, key: &ArtifactKey) -> Option<PathBuf> {
        self.entries.get(key).map(|name| self.dir.join(name))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All worker-artifact keys (for `gradcode info`).
    pub fn worker_keys(&self) -> Vec<ArtifactKey> {
        let mut v: Vec<ArtifactKey> = self
            .entries
            .keys()
            .filter(|k| k.kind == ArtifactKind::Worker)
            .copied()
            .collect();
        v.sort_by_key(|k| (k.n, k.d, k.m, k.rows, k.dim));
        v
    }

    /// Default artifacts directory: `$GRADCODE_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("GRADCODE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
    }

    #[test]
    fn parses_and_resolves() {
        let dir = std::env::temp_dir().join(format!("gradcode-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(
            &dir,
            "worker_n10_d3_m2_r64_l512.hlo.txt worker 10 3 2 64 512\n\
             predict_r256_l512.hlo.txt predict 0 0 0 256 512\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.len(), 2);
        let p = m.resolve(&ArtifactKey::worker(10, 3, 2, 64, 512)).unwrap();
        assert!(p.ends_with("worker_n10_d3_m2_r64_l512.hlo.txt"));
        assert!(m.resolve(&ArtifactKey::worker(9, 3, 2, 64, 512)).is_none());
        assert_eq!(m.worker_keys().len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_line_errors() {
        let dir = std::env::temp_dir().join(format!("gradcode-manifest-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, "bad line\n");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
