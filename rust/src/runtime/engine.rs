//! PJRT client + executable wrappers over the `xla` crate.

use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

/// Shared PJRT CPU client. Compilation is serialized behind a mutex (the
/// underlying client is not documented thread-safe for compile); execution
/// of distinct executables proceeds without locking.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    compile_lock: Mutex<()>,
}

impl PjrtEngine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtEngine { client, compile_lock: Mutex::new(()) })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it to an executable.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let _guard = self.compile_lock.lock().unwrap_or_else(|e| e.into_inner());
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

/// A compiled HLO module. All our artifacts are lowered with
/// `return_tuple=True`, so outputs are unwrapped from a 1-tuple.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 buffer inputs of the given shapes; returns the
    /// first (and only) tuple element as a flat f32 vector.
    ///
    /// `inputs` are (data, dims) pairs; data length must equal the dim
    /// product.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<f32>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let expected: usize = dims.iter().product();
            anyhow::ensure!(
                data.len() == expected,
                "input length {} != shape product {expected}",
                data.len()
            );
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims_i64)?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_creates_cpu_client() {
        let engine = PjrtEngine::cpu().unwrap();
        assert_eq!(engine.platform_name(), "cpu");
        assert!(engine.device_count() >= 1);
    }
}
