//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them on
//! the request path — the rust binary is self-contained once
//! `make artifacts` has run; python never executes at serving time.
//!
//! - [`PjrtEngine`] wraps the `xla` crate's CPU PJRT client and compiles
//!   HLO-text modules into reusable executables.
//! - [`artifact`] resolves artifact files by shape signature via the
//!   manifest `python/compile/aot.py` writes.
//! - [`PjrtBackend`] implements [`crate::coordinator::ComputeBackend`]
//!   by invoking the `worker_step` artifact (Pallas gradient + coded
//!   encode fused into one HLO module).

pub mod artifact;
mod engine;
mod pjrt_backend;

pub use artifact::{ArtifactKey, Manifest};
pub use engine::{Executable, PjrtEngine};
pub use pjrt_backend::{PjrtBackend, PjrtPredictor};

use anyhow::Result;

/// Returns the PJRT CPU platform name (build-chain smoke check, also used
/// by `gradcode info`).
pub fn platform_name() -> Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(client.platform_name())
}
