//! Property-testing substrate (no `proptest` offline).
//!
//! A small seeded harness: generate `cases` random inputs from closures
//! over a [`Pcg64`], check an invariant, and on failure print a
//! copy-pasteable reproducer (root seed + failing attempt + the failing
//! input) so the failure replays deterministically. `TESTKIT_SEED`
//! (decimal or `0x…` hex) overrides every property's root seed for
//! ad-hoc replay and for pinning CI runs. Used to sweep coding-scheme
//! invariants (any-(n-s)-workers decodability, placement counts, bound
//! tightness) and the chaos engine's recovery invariants across
//! randomized parameter space.

use std::time::Duration;

use crate::rngs::{Pcg64, Rng};

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0x5eed_c0de }
    }
}

/// Outcome of a single case.
pub enum CaseResult {
    Pass,
    /// Failure with human-readable context.
    Fail(String),
    /// Case rejected by a precondition; does not count toward `cases`.
    Discard,
}

/// The root seed a property run actually uses: the `TESTKIT_SEED`
/// environment variable (decimal or `0x…` hex) when set, else the
/// configured seed. A malformed override panics rather than silently
/// running the default seed.
pub fn root_seed(cfg: &Config) -> u64 {
    match std::env::var("TESTKIT_SEED") {
        Ok(v) => crate::chaos::parse_u64(&v)
            .unwrap_or_else(|| panic!("TESTKIT_SEED `{v}` is not a u64 (decimal or 0x-hex)")),
        Err(_) => cfg.seed,
    }
}

/// Run `prop` over `cfg.cases` generated inputs; panics with a
/// copy-pasteable reproducer on the first failure. `gen` draws an input
/// from the RNG.
pub fn check<T: std::fmt::Debug>(
    cfg: Config,
    name: &str,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> CaseResult,
) {
    let seed = root_seed(&cfg);
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut passed = 0usize;
    let mut discarded = 0usize;
    let max_attempts = cfg.cases * 20;
    let mut attempts = 0usize;
    while passed < cfg.cases && attempts < max_attempts {
        attempts += 1;
        // Fork a per-case RNG so a failing case replays from (seed, index).
        let mut case_rng = rng.fork(attempts as u64);
        let input = gen(&mut case_rng);
        match prop(&input) {
            CaseResult::Pass => passed += 1,
            CaseResult::Discard => discarded += 1,
            CaseResult::Fail(why) => panic!(
                "property `{name}` failed at attempt {attempts} (seed={seed:#x}): \
                 {why}\nfailing input: {input:?}\n\
                 reproduce with: TESTKIT_SEED={seed:#x} cargo test {name}"
            ),
        }
    }
    assert!(
        passed >= cfg.cases,
        "property `{name}`: too many discards ({discarded} discards, {passed} passes)"
    );
}

/// Run `f` under a wall-clock watchdog: panics with `name` if it has not
/// finished within `limit`, and re-raises `f`'s own panic unchanged.
/// Chaos properties assert "never deadlocks" with this — a hung gather
/// fails the test instead of hanging the whole suite.
///
/// The worker thread is detached on timeout (it cannot be killed), so a
/// tripped watchdog should be treated as a failure to fix, not retried.
pub fn with_watchdog<R: Send + 'static>(
    limit: Duration,
    name: &str,
    f: impl FnOnce() -> R + Send + 'static,
) -> R {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::Builder::new()
        .name(format!("watchdog-{name}"))
        .spawn(move || {
            let _ = tx.send(f());
        })
        .expect("spawn watchdog thread");
    match rx.recv_timeout(limit) {
        Ok(r) => {
            let _ = handle.join();
            r
        }
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            panic!("watchdog `{name}`: no result within {limit:?} (deadlock or hang)")
        }
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => match handle.join() {
            // The closure panicked: surface the original panic payload.
            Err(payload) => std::panic::resume_unwind(payload),
            Ok(_) => unreachable!("sender dropped without a send or a panic"),
        },
    }
}

/// Convenience: boolean property.
pub fn check_bool<T: std::fmt::Debug>(
    cfg: Config,
    name: &str,
    gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    check(cfg, name, gen, |t| {
        if prop(t) {
            CaseResult::Pass
        } else {
            CaseResult::Fail("predicate returned false".into())
        }
    });
}

/// Generator helpers for common parameter shapes.
pub mod gen {
    use super::*;
    use crate::chaos::{FaultKind, FaultPlan};

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn usize_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        lo + rng.next_index(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * rng.next_f64()
    }

    /// A valid paper triple `(n, d, s, m)` with `n=k`, `d = s + m`,
    /// `1 <= d <= n`, `m >= 1`, `s >= 0`, bounded by `n_max`.
    pub fn scheme_triple(rng: &mut Pcg64, n_min: usize, n_max: usize) -> (usize, usize, usize, usize) {
        let n = usize_in(rng, n_min, n_max);
        let d = usize_in(rng, 1, n);
        let m = usize_in(rng, 1, d);
        let s = d - m;
        (n, d, s, m)
    }

    /// Random f32 gradient matrix (k × l) with entries in [-1, 1).
    pub fn gradients(rng: &mut Pcg64, k: usize, l: usize) -> Vec<Vec<f32>> {
        (0..k)
            .map(|_| (0..l).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect())
            .collect()
    }

    /// A random [`FaultPlan`] for an `n`-worker, `iters`-iteration run
    /// with up to `max_faults` scheduled events drawn uniformly over
    /// cells and [`FaultKind`]s (restartable and permanent crashes,
    /// drops, corruptions, duplicates, delays, resets).
    pub fn fault_plan(rng: &mut Pcg64, n: usize, iters: u64, max_faults: usize) -> FaultPlan {
        let mut plan = FaultPlan::new(n);
        for _ in 0..usize_in(rng, 0, max_faults) {
            let worker = rng.next_index(n);
            let iter = rng.next_bounded(iters.max(1));
            let kind = match rng.next_index(7) {
                0 => FaultKind::Crash { restart_after: None },
                1 => FaultKind::Crash {
                    restart_after: Some(usize_in(rng, 1, 4) as u32),
                },
                2 => FaultKind::Drop,
                3 => FaultKind::Corrupt,
                4 => FaultKind::Duplicate,
                5 => FaultKind::Delay(f64_in(rng, 0.01, 2.0)),
                _ => FaultKind::Reset,
            };
            plan.schedule(worker, iter, kind);
        }
        plan
    }

    /// A sorted responder subset of `0..n` with at least `min_size`
    /// members (at most all of them).
    pub fn responder_subset(rng: &mut Pcg64, n: usize, min_size: usize) -> Vec<usize> {
        assert!(min_size >= 1 && min_size <= n);
        let size = usize_in(rng, min_size, n);
        rng.sample_indices(n, size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check_bool(
            Config { cases: 32, seed: 1 },
            "add-commutes",
            |rng| (rng.next_f64(), rng.next_f64()),
            |&(a, b)| a + b == b + a,
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_context() {
        check_bool(
            Config { cases: 8, seed: 2 },
            "always-fails",
            |rng| rng.next_u64(),
            |_| false,
        );
    }

    #[test]
    fn discards_do_not_count() {
        let mut discards = 0;
        check(
            Config { cases: 10, seed: 3 },
            "half-discarded",
            |rng| rng.next_u64(),
            |&x| {
                if x % 2 == 0 {
                    discards += 1;
                    CaseResult::Discard
                } else {
                    CaseResult::Pass
                }
            },
        );
        assert!(discards > 0);
    }

    #[test]
    fn scheme_triple_is_valid() {
        let mut rng = Pcg64::seed_from_u64(4);
        for _ in 0..200 {
            let (n, d, s, m) = gen::scheme_triple(&mut rng, 2, 16);
            assert!(d >= 1 && d <= n);
            assert!(m >= 1);
            assert_eq!(d, s + m);
        }
    }

    #[test]
    fn failure_message_contains_reproducer() {
        let caught = std::panic::catch_unwind(|| {
            check_bool(
                Config { cases: 4, seed: 0xabc },
                "repro-check",
                |rng| rng.next_u64(),
                |_| false,
            );
        })
        .unwrap_err();
        let msg = caught.downcast_ref::<String>().expect("panic carries a String");
        assert!(msg.contains("TESTKIT_SEED=0xabc cargo test repro-check"), "{msg}");
        assert!(msg.contains("failing input:"), "{msg}");
    }

    #[test]
    fn fault_plan_generator_stays_in_bounds() {
        let mut rng = Pcg64::seed_from_u64(5);
        for _ in 0..100 {
            let plan = gen::fault_plan(&mut rng, 6, 20, 10);
            assert_eq!(plan.n(), 6);
            assert!(plan.len() <= 10);
            for it in 0..20 {
                for (w, _) in plan.events_at(it) {
                    assert!(w < 6);
                }
            }
        }
    }

    #[test]
    fn responder_subset_is_sorted_distinct_and_big_enough() {
        let mut rng = Pcg64::seed_from_u64(6);
        for _ in 0..200 {
            let s = gen::responder_subset(&mut rng, 9, 3);
            assert!(s.len() >= 3 && s.len() <= 9);
            for pair in s.windows(2) {
                assert!(pair[0] < pair[1], "sorted and distinct: {s:?}");
            }
            assert!(s.iter().all(|&w| w < 9));
        }
    }

    #[test]
    fn watchdog_passes_results_and_trips_on_hangs() {
        assert_eq!(with_watchdog(Duration::from_secs(5), "quick", || 41 + 1), 42);
        let caught = std::panic::catch_unwind(|| {
            with_watchdog(Duration::from_millis(50), "hang", || {
                std::thread::sleep(Duration::from_secs(30));
            })
        });
        assert!(caught.is_err(), "watchdog must trip");
    }

    #[test]
    fn watchdog_reraises_inner_panics() {
        let caught = std::panic::catch_unwind(|| {
            with_watchdog(Duration::from_secs(5), "inner", || panic!("boom-inner"));
        })
        .unwrap_err();
        let msg = caught.downcast_ref::<&str>().expect("payload is the inner &str");
        assert!(msg.contains("boom-inner"));
    }
}
