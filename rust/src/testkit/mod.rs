//! Property-testing substrate (no `proptest` offline).
//!
//! A small seeded harness: generate `cases` random inputs from closures
//! over a [`Pcg64`], check an invariant, and on failure report the exact
//! case index + root seed so the failure replays deterministically. Used
//! to sweep coding-scheme invariants (any-(n-s)-workers decodability,
//! placement counts, bound tightness) across randomized parameter space.

use crate::rngs::{Pcg64, Rng};

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0x5eed_c0de }
    }
}

/// Outcome of a single case.
pub enum CaseResult {
    Pass,
    /// Failure with human-readable context.
    Fail(String),
    /// Case rejected by a precondition; does not count toward `cases`.
    Discard,
}

/// Run `prop` over `cfg.cases` generated inputs; panics with replay info
/// on the first failure. `gen` draws an input from the RNG.
pub fn check<T: std::fmt::Debug>(
    cfg: Config,
    name: &str,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> CaseResult,
) {
    let mut rng = Pcg64::seed_from_u64(cfg.seed);
    let mut passed = 0usize;
    let mut discarded = 0usize;
    let max_attempts = cfg.cases * 20;
    let mut attempts = 0usize;
    while passed < cfg.cases && attempts < max_attempts {
        attempts += 1;
        // Fork a per-case RNG so a failing case replays from (seed, index).
        let mut case_rng = rng.fork(attempts as u64);
        let input = gen(&mut case_rng);
        match prop(&input) {
            CaseResult::Pass => passed += 1,
            CaseResult::Discard => discarded += 1,
            CaseResult::Fail(why) => panic!(
                "property `{name}` failed at attempt {attempts} \
                 (seed={:#x}): {why}\ninput: {input:?}",
                cfg.seed
            ),
        }
    }
    assert!(
        passed >= cfg.cases,
        "property `{name}`: too many discards ({discarded} discards, {passed} passes)"
    );
}

/// Convenience: boolean property.
pub fn check_bool<T: std::fmt::Debug>(
    cfg: Config,
    name: &str,
    gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    check(cfg, name, gen, |t| {
        if prop(t) {
            CaseResult::Pass
        } else {
            CaseResult::Fail("predicate returned false".into())
        }
    });
}

/// Generator helpers for common parameter shapes.
pub mod gen {
    use super::*;

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn usize_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        lo + rng.next_index(hi - lo + 1)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * rng.next_f64()
    }

    /// A valid paper triple `(n, d, s, m)` with `n=k`, `d = s + m`,
    /// `1 <= d <= n`, `m >= 1`, `s >= 0`, bounded by `n_max`.
    pub fn scheme_triple(rng: &mut Pcg64, n_min: usize, n_max: usize) -> (usize, usize, usize, usize) {
        let n = usize_in(rng, n_min, n_max);
        let d = usize_in(rng, 1, n);
        let m = usize_in(rng, 1, d);
        let s = d - m;
        (n, d, s, m)
    }

    /// Random f32 gradient matrix (k × l) with entries in [-1, 1).
    pub fn gradients(rng: &mut Pcg64, k: usize, l: usize) -> Vec<Vec<f32>> {
        (0..k)
            .map(|_| (0..l).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check_bool(
            Config { cases: 32, seed: 1 },
            "add-commutes",
            |rng| (rng.next_f64(), rng.next_f64()),
            |&(a, b)| a + b == b + a,
        );
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics_with_context() {
        check_bool(
            Config { cases: 8, seed: 2 },
            "always-fails",
            |rng| rng.next_u64(),
            |_| false,
        );
    }

    #[test]
    fn discards_do_not_count() {
        let mut discards = 0;
        check(
            Config { cases: 10, seed: 3 },
            "half-discarded",
            |rng| rng.next_u64(),
            |&x| {
                if x % 2 == 0 {
                    discards += 1;
                    CaseResult::Discard
                } else {
                    CaseResult::Pass
                }
            },
        );
        assert!(discards > 0);
    }

    #[test]
    fn scheme_triple_is_valid() {
        let mut rng = Pcg64::seed_from_u64(4);
        for _ in 0..200 {
            let (n, d, s, m) = gen::scheme_triple(&mut rng, 2, 16);
            assert!(d >= 1 && d <= n);
            assert!(m >= 1);
            assert_eq!(d, s + m);
        }
    }
}
