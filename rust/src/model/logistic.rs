//! Logistic regression: loss, probabilities, and the partial-gradient
//! kernel `g = X^T (σ(Xβ) - y)` — the compute hot spot of the paper's
//! workload (the L1 Pallas kernel implements exactly this map).

use crate::data::DenseDataset;

/// Stateless logistic-regression compute over dense f32 data.
pub struct LogisticModel;

/// 4-way-unrolled f32 dot (the forward half of the fused gradient pass).
#[inline]
fn dot_f32(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let chunks = x.len() / 4 * 4;
    let mut acc = [0.0f32; 4];
    let mut i = 0;
    while i < chunks {
        acc[0] += x[i] * y[i];
        acc[1] += x[i + 1] * y[i + 1];
        acc[2] += x[i + 2] * y[i + 2];
        acc[3] += x[i + 3] * y[i + 3];
        i += 4;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for k in chunks..x.len() {
        s += x[k] * y[k];
    }
    s
}

#[inline]
pub(crate) fn sigmoid(z: f32) -> f32 {
    // Numerically-stable split to avoid exp overflow.
    if z >= 0.0 {
        let e = (-z).exp();
        1.0 / (1.0 + e)
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

impl LogisticModel {
    /// Predicted probabilities `σ(Xβ)`.
    pub fn predict(ds: &DenseDataset, beta: &[f32]) -> Vec<f32> {
        assert_eq!(beta.len(), ds.cols);
        let mut probs = vec![0.0f32; ds.rows];
        crate::linalg::gemv_f32(ds.rows, ds.cols, &ds.x, beta, &mut probs);
        for p in probs.iter_mut() {
            *p = sigmoid(*p);
        }
        probs
    }

    /// Mean negative log-likelihood (cross-entropy) loss.
    pub fn loss(ds: &DenseDataset, beta: &[f32]) -> f64 {
        let probs = Self::predict(ds, beta);
        let mut acc = 0.0f64;
        for (&p, &y) in probs.iter().zip(&ds.y) {
            let p = (p as f64).clamp(1e-12, 1.0 - 1e-12);
            acc -= if y >= 0.5 { p.ln() } else { (1.0 - p).ln() };
        }
        acc / ds.rows as f64
    }

    /// Sum gradient over the dataset: `g = X^T (σ(Xβ) - y)`, length `cols`.
    pub fn gradient(ds: &DenseDataset, beta: &[f32]) -> Vec<f32> {
        let mut g = vec![0.0f32; ds.cols];
        Self::gradient_into(ds, beta, &mut g);
        g
    }

    /// Allocation-free gradient (hot path of the rust backend).
    ///
    /// Row-chunked across [`crate::pool`]: the chunk grid is a function
    /// of `ds.rows` only (never the thread count) and the per-chunk
    /// partials combine in [`crate::pool::tree_combine`]'s fixed
    /// binary-tree order, so the result is bitwise identical for any
    /// pool width. Datasets at or below [`ROW_CHUNK`] rows take the
    /// single-chunk path, which is the exact pre-pool serial kernel.
    pub fn gradient_into(ds: &DenseDataset, beta: &[f32], g: &mut Vec<f32>) {
        assert_eq!(beta.len(), ds.cols);
        g.clear();
        g.resize(ds.cols, 0.0);
        if ds.rows <= ROW_CHUNK {
            Self::gradient_range(ds, beta, 0, ds.rows, g);
            return;
        }
        let n_chunks = (ds.rows + ROW_CHUNK - 1) / ROW_CHUNK;
        let parts: Vec<Vec<f32>> = crate::pool::global().map_indexed(n_chunks, |c| {
            let start = c * ROW_CHUNK;
            let end = (start + ROW_CHUNK).min(ds.rows);
            let mut part = vec![0.0f32; ds.cols];
            Self::gradient_range(ds, beta, start, end, &mut part);
            part
        });
        match crate::pool::tree_combine(parts, |mut a, b| {
            crate::linalg::axpy_f32(1.0, &b, &mut a);
            a
        }) {
            Some(total) => g.copy_from_slice(&total),
            // Unreachable for rows > ROW_CHUNK, but fall back to the
            // serial kernel rather than panic.
            None => Self::gradient_range(ds, beta, 0, ds.rows, g),
        }
    }

    /// The fused gradient kernel over rows `[start, end)`, accumulated
    /// into `g` (length `cols`, pre-zeroed by the caller).
    ///
    /// Single fused pass over `X`: for each row, the forward dot
    /// `z = x·β`, the residual `r = σ(z) - y`, and the rank-1 accumulate
    /// `g += r·x` happen while the row is still in cache — halving the
    /// memory traffic of the two-pass (GEMV then X^T·r) formulation.
    /// (§Perf: two-pass measured 288 µs at 256×512; fused ~2× less X
    /// traffic.)
    fn gradient_range(ds: &DenseDataset, beta: &[f32], start: usize, end: usize, g: &mut [f32]) {
        let cols = ds.cols;
        let blocks = start + (end - start) / 4 * 4;
        let mut i = start;
        // 4-row blocks: four forward dots, then one fused rank-4 update
        // g += Σ r_k·x_k — a single pass over the (L1-resident) g per
        // four rows instead of four.
        while i < blocks {
            let x0 = &ds.x[i * cols..(i + 1) * cols];
            let x1 = &ds.x[(i + 1) * cols..(i + 2) * cols];
            let x2 = &ds.x[(i + 2) * cols..(i + 3) * cols];
            let x3 = &ds.x[(i + 3) * cols..(i + 4) * cols];
            let r0 = sigmoid(dot_f32(x0, beta)) - ds.y[i];
            let r1 = sigmoid(dot_f32(x1, beta)) - ds.y[i + 1];
            let r2 = sigmoid(dot_f32(x2, beta)) - ds.y[i + 2];
            let r3 = sigmoid(dot_f32(x3, beta)) - ds.y[i + 3];
            for (k, gv) in g.iter_mut().enumerate() {
                *gv += r0 * x0[k] + r1 * x1[k] + r2 * x2[k] + r3 * x3[k];
            }
            i += 4;
        }
        for i in blocks..end {
            let row = ds.row(i);
            let r = sigmoid(dot_f32(row, beta)) - ds.y[i];
            if r != 0.0 {
                crate::linalg::axpy_f32(r, row, g);
            }
        }
    }
}

/// Rows per parallel gradient chunk (a multiple of 4, so every chunk
/// keeps the kernel's 4-row block alignment). The grid depends only on
/// the dataset size: chunking — and therefore the combine tree and the
/// f32 summation order — is identical whether the pool has 1 thread or
/// 16.
pub const ROW_CHUNK: usize = 1024;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CategoricalConfig, SyntheticCategorical};

    fn toy() -> DenseDataset {
        DenseDataset {
            x: vec![1., 0., 0., 1., 1., 1.],
            y: vec![1., 0., 1.],
            rows: 3,
            cols: 2,
        }
    }

    #[test]
    fn sigmoid_basic() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(20.0) > 0.999_999);
        assert!(sigmoid(-20.0) < 1e-6);
        // stability at extremes
        assert!(sigmoid(500.0).is_finite());
        assert!(sigmoid(-500.0).is_finite());
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let ds = toy();
        let beta = vec![0.3f32, -0.2];
        let g = LogisticModel::gradient(&ds, &beta);
        let eps = 1e-3f32;
        for j in 0..2 {
            let mut bp = beta.clone();
            bp[j] += eps;
            let mut bm = beta.clone();
            bm[j] -= eps;
            // loss() is mean-NLL; gradient() is the SUM gradient.
            let fd = (LogisticModel::loss(&ds, &bp) - LogisticModel::loss(&ds, &bm)) as f32
                / (2.0 * eps)
                * ds.rows as f32;
            assert!((g[j] - fd).abs() < 1e-2, "coord {j}: {} vs {fd}", g[j]);
        }
    }

    #[test]
    fn zero_beta_gradient_is_half_minus_y_projection() {
        // σ(0) = 0.5 → g = X^T (0.5 - y).
        let ds = toy();
        let g = LogisticModel::gradient(&ds, &[0.0, 0.0]);
        // manual: rows (1,0),(0,1),(1,1); resid = (-.5, .5, -.5)
        assert!((g[0] - (-0.5 + 0.0 - 0.5)).abs() < 1e-6);
        assert!((g[1] - (0.0 + 0.5 - 0.5)).abs() < 1e-6);
    }

    #[test]
    fn training_reduces_loss_and_gets_good_auc() {
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), 11);
        let ds = gen.generate(1500, 12);
        let mut beta = vec![0.0f32; ds.cols];
        let l0 = LogisticModel::loss(&ds, &beta);
        let lr = 2.0 / ds.rows as f32;
        for _ in 0..150 {
            let g = LogisticModel::gradient(&ds, &beta);
            for (b, &gv) in beta.iter_mut().zip(&g) {
                *b -= lr * gv;
            }
        }
        let l1 = LogisticModel::loss(&ds, &beta);
        assert!(l1 < l0 * 0.8, "loss {l0} -> {l1}");
        let auc = crate::data::auc(&LogisticModel::predict(&ds, &beta), &ds.y);
        assert!(auc > 0.8, "train AUC {auc}");
    }
}
