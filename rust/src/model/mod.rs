//! Model layer: the pure-rust logistic-regression reference backend.
//!
//! The production request path computes partial gradients through the AOT
//! PJRT artifacts (see `runtime/` and `python/compile/`); this module is
//! the numerically-identical rust implementation used as (a) the hermetic
//! test/bench backend when artifacts are absent, and (b) the oracle the
//! PJRT integration tests compare against.

mod logistic;

pub use logistic::LogisticModel;
