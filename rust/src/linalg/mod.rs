//! Dense linear-algebra substrate.
//!
//! No BLAS/LAPACK or `ndarray`/`nalgebra` crates are available in the
//! offline build environment, so the coding layer's matrix machinery —
//! LU solves for Vandermonde inversion, Jacobi SVD for condition numbers,
//! and the f32 hot-path kernels for encode/decode — is implemented here.
//!
//! Coefficient matrices (`B`, `V`, decode weights) are small (`O(n·m)` with
//! `n <= 30`) and kept in `f64`. Gradient payloads are large (`l` up to
//! hundreds of thousands) and kept in `f32`, matching the PJRT artifacts.

mod blas;
mod lu;
mod svd;

pub use blas::{
    axpy_f32, dot_f64, gemv_colmajor_f32, gemv_f32, gemm_f64, weighted_sum_f32,
    AXPY_PAR_CHUNK, GEMV_PAR_ROWS,
};
pub use lu::Lu;
pub use svd::{condition_number, singular_values};

use std::fmt;

/// Dense row-major `f64` matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data: data.to_vec() }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Submatrix from row and column index sets (order preserved).
    pub fn submatrix(&self, row_idx: &[usize], col_idx: &[usize]) -> Matrix {
        Matrix::from_fn(row_idx.len(), col_idx.len(), |i, j| self[(row_idx[i], col_idx[j])])
    }

    /// Select whole columns.
    pub fn select_cols(&self, col_idx: &[usize]) -> Matrix {
        let rows: Vec<usize> = (0..self.rows).collect();
        self.submatrix(&rows, col_idx)
    }

    /// Matrix product `self * rhs`.
    pub fn matmul(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.cols, rhs.rows, "inner dimension mismatch");
        let mut out = Matrix::zeros(self.rows, rhs.cols);
        gemm_f64(
            self.rows,
            self.cols,
            rhs.cols,
            &self.data,
            &rhs.data,
            &mut out.data,
        );
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "dimension mismatch");
        (0..self.rows).map(|i| dot_f64(self.row(i), v)).collect()
    }

    /// Max-abs entry (ℓ∞ on entries).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |a, &x| a.max(x.abs()))
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// `self - rhs`.
    pub fn sub(&self, rhs: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data: Vec<f64> =
            self.data.iter().zip(&rhs.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Scale all entries.
    pub fn scale(&self, s: f64) -> Matrix {
        let data: Vec<f64> = self.data.iter().map(|x| x * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Inverse via LU with partial pivoting. Errors on singular input.
    pub fn inverse(&self) -> Result<Matrix, LinalgError> {
        Lu::factor(self)?.inverse()
    }

    /// Solve `self * x = b`.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>, LinalgError> {
        Lu::factor(self)?.solve(b)
    }

    /// 2-norm condition number via Jacobi SVD.
    pub fn cond2(&self) -> f64 {
        condition_number(self)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

/// Errors from dense factorizations.
#[derive(Debug)]
pub enum LinalgError {
    Singular { step: usize, pivot: f64 },
    Dimension(String),
}

impl fmt::Display for LinalgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LinalgError::Singular { step, pivot } => {
                write!(f, "matrix is singular (pivot {pivot:.3e} at step {step})")
            }
            LinalgError::Dimension(msg) => write!(f, "dimension mismatch: {msg}"),
        }
    }
}

impl std::error::Error for LinalgError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul_is_noop() {
        let a = Matrix::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let i = Matrix::identity(2);
        assert_eq!(i.matmul(&a).data(), a.data());
        let i3 = Matrix::identity(3);
        assert_eq!(a.matmul(&i3).data(), a.data());
    }

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_rows(2, 2, &[1., 2., 3., 4.]);
        let b = Matrix::from_rows(2, 2, &[5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose().data(), a.data());
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::from_rows(3, 2, &[1., 2., 3., 4., 5., 6.]);
        let v = vec![0.5, -1.0];
        let got = a.matvec(&v);
        assert_eq!(got, vec![1. * 0.5 - 2., 3. * 0.5 - 4., 5. * 0.5 - 6.]);
    }

    #[test]
    fn submatrix_and_select_cols() {
        let a = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let s = a.submatrix(&[1, 3], &[0, 2]);
        assert_eq!(s.data(), &[4., 6., 12., 14.]);
        let c = a.select_cols(&[3]);
        assert_eq!(c.data(), &[3., 7., 11., 15.]);
    }

    #[test]
    fn inverse_roundtrip() {
        let a = Matrix::from_rows(3, 3, &[4., 2., 1., 2., 5., 3., 1., 3., 6.]);
        let inv = a.inverse().unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.sub(&Matrix::identity(3)).max_abs() < 1e-12);
    }

    #[test]
    fn singular_matrix_errors() {
        let a = Matrix::from_rows(2, 2, &[1., 2., 2., 4.]);
        assert!(a.inverse().is_err());
    }
}
