//! Low-level kernels. The f32 routines are the L3 hot path: the master's
//! decode is a weighted sum of `(n-s)` returned vectors of length `l/m`,
//! and the rust reference backend's encode is a `(l/m, d·m) × (d·m)`
//! matvec. Loops are written unrolled-by-4 over contiguous slices so LLVM
//! auto-vectorizes them.
//!
//! Large AXPY/GEMV calls additionally fan out across [`crate::pool`].
//! Both are per-output-element independent (no cross-thread reduction),
//! so the parallel results are bitwise identical to the serial kernels
//! for any thread count; the cutover thresholds only decide *when* the
//! fork overhead is worth paying, never *what* is computed.

/// Elements per parallel AXPY chunk; inputs shorter than two chunks run
/// serially (fork overhead would dominate the memory-bound kernel).
pub const AXPY_PAR_CHUNK: usize = 32 * 1024;

/// Rows per parallel GEMV chunk; matrices with fewer than two chunks of
/// rows run serially.
pub const GEMV_PAR_ROWS: usize = 256;

/// `y += a * x` over f32 slices (hot decode kernel). Chunks across the
/// pool above [`AXPY_PAR_CHUNK`]; per-element independent, so bitwise
/// identical at any thread count.
#[inline]
pub fn axpy_f32(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    if y.len() >= 2 * AXPY_PAR_CHUNK {
        crate::pool::global().for_each_chunk_mut(y, AXPY_PAR_CHUNK, |c, yc| {
            let start = c * AXPY_PAR_CHUNK;
            axpy_serial(a, &x[start..start + yc.len()], yc);
        });
        return;
    }
    axpy_serial(a, x, y);
}

/// The serial AXPY kernel: `y += a * x`.
#[inline]
fn axpy_serial(a: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len();
    let chunks = n / 8 * 8;
    // Manually chunked so the bound checks vanish and LLVM emits SIMD.
    let (xh, xt) = x.split_at(chunks);
    let (yh, yt) = y.split_at_mut(chunks);
    for (xc, yc) in xh.chunks_exact(8).zip(yh.chunks_exact_mut(8)) {
        for k in 0..8 {
            yc[k] += a * xc[k];
        }
    }
    for (xv, yv) in xt.iter().zip(yt.iter_mut()) {
        *yv += a * xv;
    }
}

/// Weighted sum `out = Σ_i w[i] * xs[i]` of equal-length f32 vectors.
/// Processes four vectors per pass to stay in cache and amortize the
/// traversal of `out` (the decode inner loop).
pub fn weighted_sum_f32(w: &[f32], xs: &[&[f32]], out: &mut [f32]) {
    assert_eq!(w.len(), xs.len(), "weights/vectors mismatch");
    out.iter_mut().for_each(|o| *o = 0.0);
    let mut i = 0;
    while i + 4 <= xs.len() {
        let (w0, w1, w2, w3) = (w[i], w[i + 1], w[i + 2], w[i + 3]);
        let (x0, x1, x2, x3) = (xs[i], xs[i + 1], xs[i + 2], xs[i + 3]);
        assert!(x0.len() == out.len() && x1.len() == out.len() && x2.len() == out.len() && x3.len() == out.len());
        for (k, o) in out.iter_mut().enumerate() {
            *o += w0 * x0[k] + w1 * x1[k] + w2 * x2[k] + w3 * x3[k];
        }
        i += 4;
    }
    while i < xs.len() {
        axpy_f32(w[i], xs[i], out);
        i += 1;
    }
}

/// Row-major f32 GEMV: `out[r] = Σ_c a[r*cols+c] v[c]`. Row-chunks
/// across the pool above [`GEMV_PAR_ROWS`]; each output row is an
/// independent dot product, so bitwise identical at any thread count.
pub fn gemv_f32(rows: usize, cols: usize, a: &[f32], v: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), rows * cols);
    assert_eq!(v.len(), cols);
    assert_eq!(out.len(), rows);
    if rows >= 2 * GEMV_PAR_ROWS {
        crate::pool::global().for_each_chunk_mut(out, GEMV_PAR_ROWS, |c, oc| {
            let r0 = c * GEMV_PAR_ROWS;
            gemv_rows_serial(r0, cols, &a[r0 * cols..(r0 + oc.len()) * cols], v, oc);
        });
        return;
    }
    gemv_rows_serial(0, cols, a, v, out);
}

/// Serial GEMV over a row block: `out[i] = Σ_c a[i*cols+c] v[c]` where
/// `a` holds `out.len()` consecutive rows (the caller offsets by `r0`,
/// kept only for debug assertions).
fn gemv_rows_serial(_r0: usize, cols: usize, a: &[f32], v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), out.len() * cols);
    for (r, o) in out.iter_mut().enumerate() {
        let row = &a[r * cols..(r + 1) * cols];
        let mut acc0 = 0.0f32;
        let mut acc1 = 0.0f32;
        let mut acc2 = 0.0f32;
        let mut acc3 = 0.0f32;
        let chunks = cols / 4 * 4;
        let mut c = 0;
        while c < chunks {
            acc0 += row[c] * v[c];
            acc1 += row[c + 1] * v[c + 1];
            acc2 += row[c + 2] * v[c + 2];
            acc3 += row[c + 3] * v[c + 3];
            c += 4;
        }
        let mut acc = acc0 + acc1 + acc2 + acc3;
        for k in chunks..cols {
            acc += row[k] * v[k];
        }
        *o = acc;
    }
}

/// Column-traversal f32 GEMV for a row-major matrix: `out += a^T-layout`
/// access pattern `out[r] = Σ_c a[c*rows + r] v[c]` — i.e. `a` stores the
/// matrix column-by-column (equivalently, computes `M^T v` for row-major
/// `M`). This is the encode layout: gradients arrive as `d·m` contiguous
/// rows of length `l/m`, and the output is a combination of those rows.
pub fn gemv_colmajor_f32(rows: usize, cols: usize, a: &[f32], v: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), rows * cols);
    assert_eq!(v.len(), cols);
    assert_eq!(out.len(), rows);
    out.iter_mut().for_each(|o| *o = 0.0);
    for c in 0..cols {
        axpy_f32(v[c], &a[c * rows..(c + 1) * rows], out);
    }
}

/// f64 dot product with 4-way accumulators.
#[inline]
pub fn dot_f64(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4 * 4;
    let mut acc = [0.0f64; 4];
    let mut i = 0;
    while i < chunks {
        acc[0] += x[i] * y[i];
        acc[1] += x[i + 1] * y[i + 1];
        acc[2] += x[i + 2] * y[i + 2];
        acc[3] += x[i + 3] * y[i + 3];
        i += 4;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for k in chunks..n {
        s += x[k] * y[k];
    }
    s
}

/// Row-major f64 GEMM: `c[m×p] = a[m×n] * b[n×p]` (ikj loop order so the
/// inner loop streams both `b` and `c` rows).
pub fn gemm_f64(m: usize, n: usize, p: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), n * p);
    assert_eq!(c.len(), m * p);
    c.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..m {
        let crow = &mut c[i * p..(i + 1) * p];
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[k * p..(k + 1) * p];
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += aik * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = vec![1.0f32; 19];
        let mut y = vec![2.0f32; 19];
        axpy_f32(3.0, &x, &mut y);
        assert!(y.iter().all(|&v| (v - 5.0).abs() < 1e-6));
    }

    #[test]
    fn weighted_sum_matches_naive() {
        let xs_store: Vec<Vec<f32>> = (0..7)
            .map(|i| (0..33).map(|k| (i * 33 + k) as f32 * 0.1).collect())
            .collect();
        let xs: Vec<&[f32]> = xs_store.iter().map(|v| v.as_slice()).collect();
        let w: Vec<f32> = (0..7).map(|i| 0.3 - 0.1 * i as f32).collect();
        let mut out = vec![0.0f32; 33];
        weighted_sum_f32(&w, &xs, &mut out);
        for k in 0..33 {
            let naive: f32 = (0..7).map(|i| w[i] * xs[i][k]).sum();
            assert!((out[k] - naive).abs() < 1e-4, "k={k}: {} vs {naive}", out[k]);
        }
    }

    #[test]
    fn gemv_matches_naive() {
        let (rows, cols) = (5, 13);
        let a: Vec<f32> = (0..rows * cols).map(|i| (i as f32).sin()).collect();
        let v: Vec<f32> = (0..cols).map(|i| (i as f32).cos()).collect();
        let mut out = vec![0.0f32; rows];
        gemv_f32(rows, cols, &a, &v, &mut out);
        for r in 0..rows {
            let naive: f32 = (0..cols).map(|c| a[r * cols + c] * v[c]).sum();
            assert!((out[r] - naive).abs() < 1e-4);
        }
    }

    #[test]
    fn gemv_colmajor_matches_transposed_gemv() {
        let (rows, cols) = (9, 4);
        let a_col: Vec<f32> = (0..rows * cols).map(|i| (i as f32 * 0.7).sin()).collect();
        let v: Vec<f32> = (0..cols).map(|i| i as f32 + 0.5).collect();
        let mut out = vec![0.0f32; rows];
        gemv_colmajor_f32(rows, cols, &a_col, &v, &mut out);
        for r in 0..rows {
            let naive: f32 = (0..cols).map(|c| a_col[c * rows + r] * v[c]).sum();
            assert!((out[r] - naive).abs() < 1e-4);
        }
    }

    #[test]
    fn dot_f64_known() {
        let x: Vec<f64> = (0..9).map(|i| i as f64).collect();
        let y = vec![2.0; 9];
        assert_eq!(dot_f64(&x, &y), 2.0 * 36.0);
    }

    #[test]
    fn large_axpy_parallel_is_bitwise_serial() {
        // Above the cutover the pool path must produce the exact bits
        // of the serial kernel (per-element independence).
        let n = 2 * AXPY_PAR_CHUNK + 17;
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut y_par: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut y_ser = y_par.clone();
        axpy_f32(1.7, &x, &mut y_par);
        axpy_serial(1.7, &x, &mut y_ser);
        assert!(y_par
            .iter()
            .zip(&y_ser)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn large_gemv_parallel_is_bitwise_serial() {
        let (rows, cols) = (2 * GEMV_PAR_ROWS + 3, 33);
        let a: Vec<f32> = (0..rows * cols).map(|i| (i as f32 * 0.13).sin()).collect();
        let v: Vec<f32> = (0..cols).map(|i| (i as f32 * 0.7).cos()).collect();
        let mut out_par = vec![0.0f32; rows];
        let mut out_ser = vec![0.0f32; rows];
        gemv_f32(rows, cols, &a, &v, &mut out_par);
        gemv_rows_serial(0, cols, &a, &v, &mut out_ser);
        assert!(out_par
            .iter()
            .zip(&out_ser)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn gemm_matches_naive() {
        let (m, n, p) = (3, 4, 5);
        let a: Vec<f64> = (0..m * n).map(|i| (i as f64 * 0.3).cos()).collect();
        let b: Vec<f64> = (0..n * p).map(|i| (i as f64 * 0.2).sin()).collect();
        let mut c = vec![0.0; m * p];
        gemm_f64(m, n, p, &a, &b, &mut c);
        for i in 0..m {
            for j in 0..p {
                let naive: f64 = (0..n).map(|k| a[i * n + k] * b[k * p + j]).sum();
                assert!((c[i * p + j] - naive).abs() < 1e-12);
            }
        }
    }
}
