//! Singular values via one-sided Jacobi, used for 2-norm condition
//! numbers.
//!
//! Theorem 2's achievable region is phrased through an upper bound `κ` on
//! the condition number of `V_F V_F^T` over all straggler patterns `F`;
//! `coding::stability` sweeps those patterns calling into here. One-sided
//! Jacobi is slow but extremely robust and accurate for the tiny
//! (≤ 30×30) matrices involved — exactly what a certification pass wants.

use super::Matrix;

/// Singular values of `a` in non-increasing order, via one-sided Jacobi
/// rotations applied to the columns of a working copy of `a` (for
/// rows < cols the transpose is factored instead, singular values match).
pub fn singular_values(a: &Matrix) -> Vec<f64> {
    let work = if a.rows() >= a.cols() { a.clone() } else { a.transpose() };
    let m = work.rows();
    let n = work.cols();
    // Column-major copy for cache-friendly column rotations.
    let mut u: Vec<Vec<f64>> = (0..n).map(|j| work.col(j)).collect();

    let eps = 1e-15;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in p + 1..n {
                // 2x2 Gram block [app apq; apq aqq].
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    app += u[p][i] * u[p][i];
                    aqq += u[q][i] * u[q][i];
                    apq += u[p][i] * u[q][i];
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(f64::MIN_POSITIVE));
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[p][i];
                    let uq = u[q][i];
                    u[p][i] = c * up - s * uq;
                    u[q][i] = s * up + c * uq;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }

    let mut sv: Vec<f64> = u
        .iter()
        .map(|col| col.iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    sv.sort_by(|a, b| b.total_cmp(a));
    sv
}

/// 2-norm condition number `σ_max / σ_min`; `f64::INFINITY` if rank
/// deficient to machine precision.
pub fn condition_number(a: &Matrix) -> f64 {
    let sv = singular_values(a);
    let (Some(&smax), Some(&smin)) = (sv.first(), sv.last()) else {
        return f64::INFINITY;
    };
    if smin <= smax * 1e-300 || smin == 0.0 {
        f64::INFINITY
    } else {
        smax / smin
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_singular_values() {
        let a = Matrix::from_rows(3, 3, &[3., 0., 0., 0., -5., 0., 0., 0., 1.]);
        let sv = singular_values(&a);
        assert!((sv[0] - 5.0).abs() < 1e-12);
        assert!((sv[1] - 3.0).abs() < 1e-12);
        assert!((sv[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn orthogonal_matrix_cond_is_one() {
        let t = std::f64::consts::FRAC_PI_4;
        let a = Matrix::from_rows(2, 2, &[t.cos(), -t.sin(), t.sin(), t.cos()]);
        assert!((condition_number(&a) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn rank_deficient_is_infinite() {
        let a = Matrix::from_rows(2, 2, &[1., 2., 2., 4.]);
        assert!(condition_number(&a).is_infinite());
    }

    #[test]
    fn rectangular_matches_gram_eigs() {
        // For A (4x2), σ_i^2 are eigenvalues of A^T A; verify against a
        // hand-computable case.
        let a = Matrix::from_rows(4, 2, &[1., 0., 0., 1., 1., 0., 0., 1.]);
        let sv = singular_values(&a);
        assert_eq!(sv.len(), 2);
        assert!((sv[0] - 2.0f64.sqrt()).abs() < 1e-12);
        assert!((sv[1] - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn frobenius_identity_holds() {
        // Σ σ_i^2 = ||A||_F^2 for a pseudo-random matrix.
        let a = Matrix::from_fn(6, 5, |i, j| ((i * 7 + j * 3) as f64 * 0.41).sin());
        let sv = singular_values(&a);
        let sum_sq: f64 = sv.iter().map(|s| s * s).sum();
        let fro2 = a.frobenius().powi(2);
        assert!((sum_sq - fro2).abs() < 1e-10, "{sum_sq} vs {fro2}");
    }

    #[test]
    fn vandermonde_condition_grows_with_n() {
        // The §III-C observation: Vandermonde condition numbers blow up.
        let cond_of = |n: usize| {
            let theta: Vec<f64> = (0..n).map(|i| i as f64 - (n as f64 - 1.0) / 2.0).collect();
            let v = Matrix::from_fn(n, n, |i, j| theta[j].powi(i as i32));
            condition_number(&v)
        };
        let c5 = cond_of(5);
        let c10 = cond_of(10);
        assert!(c10 > c5 * 10.0, "c5={c5:.3e} c10={c10:.3e}");
    }
}
