//! LU decomposition with partial pivoting.
//!
//! Used to invert the `(n-s)×(n-s)` Vandermonde submatrix `A` (Eq. 20) in
//! the decode path, to form `S_i^{-1}` in the random-matrix construction
//! (§IV), and as a general solve for the runtime-model fits. Matrices here
//! are tiny (`n <= 30`), so a dense textbook factorization is the right
//! tool; stability of the *inputs* is what the paper's §III-C/§IV is
//! about, and that is handled by `coding::stability`.

use super::{LinalgError, Matrix};

/// Packed LU factorization `P·A = L·U` with row pivots.
pub struct Lu {
    lu: Matrix,
    piv: Vec<usize>,
    /// Number of row swaps (determinant sign).
    swaps: usize,
}

impl Lu {
    /// Factor a square matrix. Errors if a pivot underflows.
    pub fn factor(a: &Matrix) -> Result<Lu, LinalgError> {
        if a.rows() != a.cols() {
            return Err(LinalgError::Dimension(format!(
                "LU requires square matrix, got {}x{}",
                a.rows(),
                a.cols()
            )));
        }
        let n = a.rows();
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut swaps = 0usize;

        for k in 0..n {
            // Partial pivot: largest |entry| in column k at/below diagonal.
            let mut p = k;
            let mut pmax = lu[(k, k)].abs();
            for i in k + 1..n {
                let v = lu[(i, k)].abs();
                if v > pmax {
                    pmax = v;
                    p = i;
                }
            }
            if pmax < f64::MIN_POSITIVE * 16.0 {
                return Err(LinalgError::Singular { step: k, pivot: pmax });
            }
            if p != k {
                for j in 0..n {
                    let t = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = t;
                }
                piv.swap(k, p);
                swaps += 1;
            }
            let pivot = lu[(k, k)];
            for i in k + 1..n {
                let f = lu[(i, k)] / pivot;
                lu[(i, k)] = f;
                for j in k + 1..n {
                    let upd = lu[(k, j)] * f;
                    lu[(i, j)] -= upd;
                }
            }
        }
        Ok(Lu { lu, piv, swaps })
    }

    fn n(&self) -> usize {
        self.lu.rows()
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>, LinalgError> {
        let n = self.n();
        if b.len() != n {
            return Err(LinalgError::Dimension(format!(
                "rhs length {} != {}",
                b.len(),
                n
            )));
        }
        // Apply permutation then forward/back substitution.
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        for i in 1..n {
            let mut s = x[i];
            for j in 0..i {
                s -= self.lu[(i, j)] * x[j];
            }
            x[i] = s;
        }
        for i in (0..n).rev() {
            let mut s = x[i];
            for j in i + 1..n {
                s -= self.lu[(i, j)] * x[j];
            }
            x[i] = s / self.lu[(i, i)];
        }
        Ok(x)
    }

    /// Full inverse (column-by-column solve).
    pub fn inverse(&self) -> Result<Matrix, LinalgError> {
        let n = self.n();
        let mut inv = Matrix::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.solve(&e)?;
            e[j] = 0.0;
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
        }
        Ok(inv)
    }

    /// Determinant from the diagonal of U and swap parity.
    pub fn det(&self) -> f64 {
        let n = self.n();
        let mut d = if self.swaps % 2 == 0 { 1.0 } else { -1.0 };
        for i in 0..n {
            d *= self.lu[(i, i)];
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_known_system() {
        // [2 1; 1 3] x = [3; 5] -> x = [4/5, 7/5]
        let a = Matrix::from_rows(2, 2, &[2., 1., 1., 3.]);
        let x = Lu::factor(&a).unwrap().solve(&[3., 5.]).unwrap();
        assert!((x[0] - 0.8).abs() < 1e-14);
        assert!((x[1] - 1.4).abs() < 1e-14);
    }

    #[test]
    fn det_of_permutation_needs_sign() {
        let a = Matrix::from_rows(2, 2, &[0., 1., 1., 0.]);
        let d = Lu::factor(&a).unwrap().det();
        assert!((d + 1.0).abs() < 1e-14, "det {d}");
    }

    #[test]
    fn inverse_of_vandermonde_5() {
        // The paper's θ grid for n=5: {0, ±1, ±1.5} style points.
        let theta: [f64; 5] = [-2.0, -1.0, 0.0, 1.0, 2.0];
        let a = Matrix::from_fn(5, 5, |i, j| theta[j].powi(i as i32));
        let inv = Lu::factor(&a).unwrap().inverse().unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.sub(&Matrix::identity(5)).max_abs() < 1e-12);
    }

    #[test]
    fn random_solve_residual_small() {
        // Deterministic pseudo-random fill.
        let n = 12;
        let a = Matrix::from_fn(n, n, |i, j| ((i * 31 + j * 17) as f64 * 0.739).sin() + if i == j { 3.0 } else { 0.0 });
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 1.3).cos()).collect();
        let x = Lu::factor(&a).unwrap().solve(&b).unwrap();
        let r = a.matvec(&x);
        for i in 0..n {
            assert!((r[i] - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn singular_reports_error() {
        let a = Matrix::from_rows(3, 3, &[1., 2., 3., 2., 4., 6., 1., 0., 1.]);
        assert!(matches!(Lu::factor(&a), Err(LinalgError::Singular { .. })));
    }
}
