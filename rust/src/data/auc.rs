//! Area under the ROC curve via the rank-sum (Mann–Whitney) statistic,
//! with midrank tie handling — the paper's "Generalization AUC" metric
//! (computed there with `sklearn.metrics.auc`).

/// AUC of `scores` against binary `labels` (1.0 = positive).
/// Returns 0.5 when one class is empty.
pub fn auc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
    // Midranks (1-based), averaging within tied score groups.
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = mid;
        }
        i = j + 1;
    }
    let pos = labels.iter().filter(|&&y| y >= 0.5).count();
    let neg = n - pos;
    if pos == 0 || neg == 0 {
        return 0.5;
    }
    let rank_sum_pos: f64 = (0..n).filter(|&k| labels[k] >= 0.5).map(|k| ranks[k]).sum();
    let u = rank_sum_pos - (pos * (pos + 1)) as f64 / 2.0;
    u / (pos as f64 * neg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation_is_one() {
        let scores = [0.1f32, 0.2, 0.8, 0.9];
        let labels = [0.0f32, 0.0, 1.0, 1.0];
        assert_eq!(auc(&scores, &labels), 1.0);
    }

    #[test]
    fn reversed_separation_is_zero() {
        let scores = [0.9f32, 0.8, 0.2, 0.1];
        let labels = [0.0f32, 0.0, 1.0, 1.0];
        assert_eq!(auc(&scores, &labels), 0.0);
    }

    #[test]
    fn all_tied_is_half() {
        let scores = [0.5f32; 6];
        let labels = [0.0f32, 1.0, 0.0, 1.0, 0.0, 1.0];
        assert!((auc(&scores, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn single_class_is_half() {
        assert_eq!(auc(&[0.1, 0.9], &[1.0, 1.0]), 0.5);
    }

    #[test]
    fn matches_pairwise_definition() {
        // AUC = P(score_pos > score_neg) + 0.5 P(tie), checked brute force.
        let scores = [0.3f32, 0.7, 0.7, 0.1, 0.9, 0.4];
        let labels = [0.0f32, 1.0, 0.0, 0.0, 1.0, 1.0];
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..6 {
            for j in 0..6 {
                if labels[i] >= 0.5 && labels[j] < 0.5 {
                    den += 1.0;
                    if scores[i] > scores[j] {
                        num += 1.0;
                    } else if scores[i] == scores[j] {
                        num += 0.5;
                    }
                }
            }
        }
        assert!((auc(&scores, &labels) - num / den).abs() < 1e-12);
    }
}
