//! Dataset substrate.
//!
//! The paper trains logistic regression on the Amazon Employee Access
//! dataset (Kaggle): categorical features one-hot encoded (with
//! interactions) to `l = 343,474` binary columns, `N = 26,220` training
//! samples. That data cannot be redistributed, so [`categorical`]
//! generates a synthetic stand-in with the same compute shape: skewed
//! categorical columns, one-hot encoding (optionally with pairwise
//! interactions), labels from a sparse ground-truth logistic model.
//! [`auc`](crate::data::auc::auc) provides the generalization AUC metric
//! and [`split`]/`partition_rows` the train/test and `D_1..D_k` splits.

pub mod auc;
pub mod categorical;
pub mod split;

pub use auc::auc;
pub use categorical::{CategoricalConfig, SyntheticCategorical};
pub use split::{partition_rows, partition_rows_weighted, train_test_split};

/// Dense row-major f32 design matrix + labels.
#[derive(Debug, Clone)]
pub struct DenseDataset {
    /// `rows × cols`, row-major.
    pub x: Vec<f32>,
    /// Length `rows`, values in {0, 1}.
    pub y: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
}

impl DenseDataset {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.cols..(i + 1) * self.cols]
    }

    /// Restrict to a set of row indices (subset extraction).
    pub fn select_rows(&self, idx: &[usize]) -> DenseDataset {
        let mut x = Vec::with_capacity(idx.len() * self.cols);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        DenseDataset { x, y, rows: idx.len(), cols: self.cols }
    }

    /// Zero-pad columns up to `target` (e.g. to match a fixed-shape AOT
    /// artifact). No-op if already that wide.
    pub fn pad_cols(&self, target: usize) -> DenseDataset {
        assert!(target >= self.cols, "cannot shrink from {} to {target}", self.cols);
        if target == self.cols {
            return self.clone();
        }
        let mut x = vec![0.0f32; self.rows * target];
        for r in 0..self.rows {
            x[r * target..r * target + self.cols].copy_from_slice(self.row(r));
        }
        DenseDataset { x, y: self.y.clone(), rows: self.rows, cols: target }
    }

    /// Positive-label rate (sanity diagnostics).
    pub fn positive_rate(&self) -> f64 {
        self.y.iter().map(|&v| v as f64).sum::<f64>() / self.rows.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_rows_picks_correct_data() {
        let d = DenseDataset {
            x: vec![1., 2., 3., 4., 5., 6.],
            y: vec![0., 1., 0.],
            rows: 3,
            cols: 2,
        };
        let s = d.select_rows(&[2, 0]);
        assert_eq!(s.x, vec![5., 6., 1., 2.]);
        assert_eq!(s.y, vec![0., 0.]);
        assert_eq!(s.rows, 2);
    }
}
