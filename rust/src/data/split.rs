//! Train/test splitting and the `D_1..D_k` partition.

use super::DenseDataset;
use crate::rngs::{Pcg64, Rng};

/// Shuffle rows and split into (train, test) with `test_fraction` held out.
pub fn train_test_split(
    ds: &DenseDataset,
    test_fraction: f64,
    seed: u64,
) -> (DenseDataset, DenseDataset) {
    assert!((0.0..1.0).contains(&test_fraction));
    let mut idx: Vec<usize> = (0..ds.rows).collect();
    let mut rng = Pcg64::seed_from_u64(seed);
    rng.shuffle(&mut idx);
    let n_test = ((ds.rows as f64) * test_fraction).round() as usize;
    let (test_idx, train_idx) = idx.split_at(n_test);
    (ds.select_rows(train_idx), ds.select_rows(test_idx))
}

/// Partition rows into `k` equal-size subsets `D_1..D_k` (trailing rows
/// that don't fill a subset are dropped, matching the equal-size
/// assumption in §II). Returns the row-index sets.
pub fn partition_rows(rows: usize, k: usize) -> Vec<Vec<usize>> {
    assert!(k > 0);
    let per = rows / k;
    assert!(per > 0, "not enough rows ({rows}) for k={k} subsets");
    (0..k).map(|i| (i * per..(i + 1) * per).collect()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(rows: usize) -> DenseDataset {
        DenseDataset {
            x: (0..rows * 2).map(|i| i as f32).collect(),
            y: (0..rows).map(|i| (i % 2) as f32).collect(),
            rows,
            cols: 2,
        }
    }

    #[test]
    fn split_sizes_and_disjointness() {
        let ds = toy(100);
        let (train, test) = train_test_split(&ds, 0.25, 1);
        assert_eq!(test.rows, 25);
        assert_eq!(train.rows, 75);
        // disjoint: each original row id (encoded in x) appears once
        let mut seen: Vec<f32> = train
            .x
            .chunks(2)
            .chain(test.x.chunks(2))
            .map(|r| r[0])
            .collect();
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want: Vec<f32> = (0..100).map(|i| (i * 2) as f32).collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn partition_equal_sizes() {
        let parts = partition_rows(103, 10);
        assert_eq!(parts.len(), 10);
        for p in &parts {
            assert_eq!(p.len(), 10);
        }
        // disjoint and within range
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 100);
    }

    #[test]
    #[should_panic(expected = "not enough rows")]
    fn partition_rejects_tiny_datasets() {
        partition_rows(3, 10);
    }
}
