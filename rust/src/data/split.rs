//! Train/test splitting and the `D_1..D_k` partition.

use super::DenseDataset;
use crate::rngs::{Pcg64, Rng};

/// Shuffle rows and split into (train, test) with `test_fraction` held out.
pub fn train_test_split(
    ds: &DenseDataset,
    test_fraction: f64,
    seed: u64,
) -> (DenseDataset, DenseDataset) {
    assert!((0.0..1.0).contains(&test_fraction));
    let mut idx: Vec<usize> = (0..ds.rows).collect();
    let mut rng = Pcg64::seed_from_u64(seed);
    rng.shuffle(&mut idx);
    let n_test = ((ds.rows as f64) * test_fraction).round() as usize;
    let (test_idx, train_idx) = idx.split_at(n_test);
    (ds.select_rows(train_idx), ds.select_rows(test_idx))
}

/// Partition rows into `k` equal-size subsets `D_1..D_k` (trailing rows
/// that don't fill a subset are dropped, matching the equal-size
/// assumption in §II). Returns the row-index sets.
pub fn partition_rows(rows: usize, k: usize) -> Vec<Vec<usize>> {
    assert!(k > 0);
    let per = rows / k;
    assert!(per > 0, "not enough rows ({rows}) for k={k} subsets");
    (0..k).map(|i| (i * per..(i + 1) * per).collect()).collect()
}

/// Partition rows into `k` contiguous subsets sized proportionally to
/// `weights` (largest-remainder apportionment, at least one row each).
/// This is the heterogeneous-placement analogue of [`partition_rows`]:
/// subset `t` receives a `weights[t]/Σweights` share of the rows, so
/// faster groups' subsets carry more data. All `rows` are used.
pub fn partition_rows_weighted(rows: usize, weights: &[f64]) -> Vec<Vec<usize>> {
    let k = weights.len();
    assert!(k > 0);
    assert!(rows >= k, "not enough rows ({rows}) for k={k} subsets");
    assert!(
        weights.iter().all(|&w| w.is_finite() && w > 0.0),
        "weights must be finite and positive"
    );
    let total: f64 = weights.iter().sum();
    // Largest-remainder with a one-row floor: start from floor(share),
    // clamp up to 1, then distribute the remaining rows by remainder.
    let spare = rows - k;
    let mut sizes: Vec<usize> = Vec::with_capacity(k);
    let mut rems: Vec<(f64, usize)> = Vec::with_capacity(k);
    let mut assigned = 0usize;
    for (t, &w) in weights.iter().enumerate() {
        let share = spare as f64 * w / total;
        let base = share.floor() as usize;
        sizes.push(1 + base);
        assigned += base;
        rems.push((share - base as f64, t));
    }
    // The remainders sum to exactly `spare - assigned < k`; hand the
    // leftover rows to the largest remainders (ties by subset id).
    let left = spare - assigned;
    rems.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(_, t) in rems.iter().take(left) {
        sizes[t] += 1;
    }
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for &sz in &sizes {
        out.push((start..start + sz).collect());
        start += sz;
    }
    debug_assert_eq!(start, rows);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(rows: usize) -> DenseDataset {
        DenseDataset {
            x: (0..rows * 2).map(|i| i as f32).collect(),
            y: (0..rows).map(|i| (i % 2) as f32).collect(),
            rows,
            cols: 2,
        }
    }

    #[test]
    fn split_sizes_and_disjointness() {
        let ds = toy(100);
        let (train, test) = train_test_split(&ds, 0.25, 1);
        assert_eq!(test.rows, 25);
        assert_eq!(train.rows, 75);
        // disjoint: each original row id (encoded in x) appears once
        let mut seen: Vec<f32> = train
            .x
            .chunks(2)
            .chain(test.x.chunks(2))
            .map(|r| r[0])
            .collect();
        seen.sort_by(|a, b| a.total_cmp(b));
        let want: Vec<f32> = (0..100).map(|i| (i * 2) as f32).collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn partition_equal_sizes() {
        let parts = partition_rows(103, 10);
        assert_eq!(parts.len(), 10);
        for p in &parts {
            assert_eq!(p.len(), 10);
        }
        // disjoint and within range
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 100);
    }

    #[test]
    #[should_panic(expected = "not enough rows")]
    fn partition_rejects_tiny_datasets() {
        partition_rows(3, 10);
    }

    #[test]
    fn weighted_partition_apportions_proportionally() {
        let parts = partition_rows_weighted(100, &[1.0, 1.0, 2.0, 4.0]);
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 100, "every row used");
        assert!(sizes[3] > sizes[2] && sizes[2] > sizes[0]);
        // shares within one row of the ideal apportionment of the spare
        for (sz, w) in sizes.iter().zip([1.0, 1.0, 2.0, 4.0]) {
            let ideal = 1.0 + 96.0 * w / 8.0;
            assert!((*sz as f64 - ideal).abs() <= 1.0, "{sz} vs {ideal}");
        }
        // contiguous and disjoint
        let all: Vec<usize> = parts.concat();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_partition_uniform_matches_equal_shares() {
        let parts = partition_rows_weighted(40, &[1.0; 8]);
        assert!(parts.iter().all(|p| p.len() == 5));
    }

    #[test]
    fn weighted_partition_never_empties_a_subset() {
        let parts = partition_rows_weighted(7, &[0.2, 10.0, 0.2, 10.0, 0.2]);
        assert!(parts.iter().all(|p| !p.is_empty()));
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 7);
    }

    #[test]
    #[should_panic(expected = "not enough rows")]
    fn weighted_partition_rejects_tiny_datasets() {
        partition_rows_weighted(2, &[1.0, 1.0, 1.0]);
    }
}
