//! Synthetic categorical dataset (Amazon-Employee-Access stand-in).
//!
//! `columns` categorical features with Zipf-skewed cardinalities and
//! Zipf-skewed value frequencies, one-hot encoded (optionally with
//! pairwise interaction columns, mirroring the paper's preprocessing).
//! Labels are drawn from a ground-truth sparse logistic model over the
//! one-hot features plus label-flip noise, so a trained model has a
//! meaningful, less-than-perfect generalization AUC — matching the shape
//! of the paper's Fig. 4 curves.

use super::DenseDataset;
use crate::rngs::{Bernoulli, Normal, Pcg64, Rng, Zipf};

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct CategoricalConfig {
    /// Number of raw categorical columns.
    pub columns: usize,
    /// Cardinality of each column is drawn uniformly from this range.
    pub cardinality: (usize, usize),
    /// Zipf exponent for value frequencies within a column.
    pub value_skew: f64,
    /// Add one-hot columns for pairs of adjacent raw columns
    /// (a bounded version of the paper's interaction terms).
    pub interactions: bool,
    /// Fraction of one-hot weights that are non-zero in the ground truth.
    pub signal_density: f64,
    /// Std of the non-zero ground-truth weights.
    pub signal_scale: f64,
    /// Probability of flipping a label (irreducible error).
    pub label_noise: f64,
}

impl Default for CategoricalConfig {
    fn default() -> Self {
        CategoricalConfig {
            columns: 8,
            cardinality: (4, 32),
            value_skew: 1.1,
            interactions: false,
            signal_density: 0.3,
            signal_scale: 1.5,
            label_noise: 0.05,
        }
    }
}

/// Materialized generator (schema + ground truth fixed at construction).
pub struct SyntheticCategorical {
    cfg: CategoricalConfig,
    /// Cardinality per raw column.
    cards: Vec<usize>,
    /// Zipf sampler per raw column.
    samplers: Vec<Zipf>,
    /// One-hot offset of each raw column.
    offsets: Vec<usize>,
    /// Interaction-pair offsets: (col_a, col_b, offset).
    inter: Vec<(usize, usize, usize)>,
    /// Total one-hot dimension.
    dim: usize,
    /// Ground-truth weights over the one-hot space.
    beta_star: Vec<f32>,
    /// Ground-truth intercept.
    intercept: f32,
}

impl SyntheticCategorical {
    pub fn new(cfg: CategoricalConfig, seed: u64) -> Self {
        assert!(cfg.columns > 0);
        assert!(cfg.cardinality.0 >= 2 && cfg.cardinality.1 >= cfg.cardinality.0);
        let mut rng = Pcg64::seed_from_u64(seed);
        let cards: Vec<usize> = (0..cfg.columns)
            .map(|_| {
                cfg.cardinality.0
                    + rng.next_index(cfg.cardinality.1 - cfg.cardinality.0 + 1)
            })
            .collect();
        let samplers: Vec<Zipf> =
            cards.iter().map(|&c| Zipf::new(c, cfg.value_skew)).collect();
        let mut offsets = Vec::with_capacity(cfg.columns);
        let mut dim = 0usize;
        for &c in &cards {
            offsets.push(dim);
            dim += c;
        }
        let mut inter = Vec::new();
        if cfg.interactions {
            for a in 0..cfg.columns.saturating_sub(1) {
                let b = a + 1;
                inter.push((a, b, dim));
                dim += cards[a] * cards[b];
            }
        }
        // Sparse ground truth.
        let mut normal = Normal::new();
        let keep = Bernoulli::new(cfg.signal_density);
        let beta_star: Vec<f32> = (0..dim)
            .map(|_| {
                if keep.sample(&mut rng) {
                    (normal.sample(&mut rng) * cfg.signal_scale) as f32
                } else {
                    0.0
                }
            })
            .collect();
        let intercept = normal.sample(&mut rng) as f32 * 0.5;
        SyntheticCategorical { cfg, cards, samplers, offsets, inter, dim, beta_star, intercept }
    }

    /// One-hot dimension `l` of generated rows.
    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn ground_truth(&self) -> &[f32] {
        &self.beta_star
    }

    /// Generate `rows` samples.
    pub fn generate(&self, rows: usize, seed: u64) -> DenseDataset {
        let mut rng = Pcg64::seed_from_u64(seed);
        let flip = Bernoulli::new(self.cfg.label_noise);
        let mut x = vec![0.0f32; rows * self.dim];
        let mut y = Vec::with_capacity(rows);
        let mut values = vec![0usize; self.cfg.columns];
        for r in 0..rows {
            let row = &mut x[r * self.dim..(r + 1) * self.dim];
            for (c, sampler) in self.samplers.iter().enumerate() {
                let v = sampler.sample(&mut rng) - 1; // 0-based value
                values[c] = v;
                row[self.offsets[c] + v] = 1.0;
            }
            for &(a, b, off) in &self.inter {
                row[off + values[a] * self.cards[b] + values[b]] = 1.0;
            }
            // Label from ground-truth logistic model.
            let mut logit = self.intercept;
            for (j, &xv) in row.iter().enumerate() {
                if xv != 0.0 {
                    logit += self.beta_star[j];
                }
            }
            let p = 1.0 / (1.0 + (-logit as f64).exp());
            let mut label = rng.next_f64() < p;
            if flip.sample(&mut rng) {
                label = !label;
            }
            y.push(if label { 1.0 } else { 0.0 });
        }
        DenseDataset { x, y, rows, cols: self.dim }
    }

    /// Pad the one-hot dimension up to a multiple of `m` (the paper pads
    /// gradient vectors with zeros when `m ∤ l`). Returns a new dataset
    /// with zero columns appended.
    pub fn pad_to_multiple(ds: &DenseDataset, m: usize) -> DenseDataset {
        let rem = ds.cols % m;
        if rem == 0 {
            return ds.clone();
        }
        let new_cols = ds.cols + (m - rem);
        let mut x = vec![0.0f32; ds.rows * new_cols];
        for r in 0..ds.rows {
            x[r * new_cols..r * new_cols + ds.cols]
                .copy_from_slice(ds.row(r));
        }
        DenseDataset { x, y: ds.y.clone(), rows: ds.rows, cols: new_cols }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_valid_one_hot() {
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), 1);
        let ds = gen.generate(50, 2);
        assert_eq!(ds.cols, gen.dim());
        for r in 0..ds.rows {
            let row = ds.row(r);
            // exactly one hot entry per raw column
            let ones = row.iter().filter(|&&v| v == 1.0).count();
            assert_eq!(ones, 8, "row {r}");
            assert!(row.iter().all(|&v| v == 0.0 || v == 1.0));
        }
    }

    #[test]
    fn interactions_add_columns_and_hots() {
        let cfg = CategoricalConfig { interactions: true, columns: 4, ..Default::default() };
        let gen = SyntheticCategorical::new(cfg, 3);
        let ds = gen.generate(20, 4);
        for r in 0..ds.rows {
            let ones = ds.row(r).iter().filter(|&&v| v == 1.0).count();
            // 4 raw + 3 interaction pairs
            assert_eq!(ones, 7, "row {r}");
        }
    }

    #[test]
    fn labels_correlate_with_ground_truth() {
        // A model scoring with β* itself must beat chance by a wide margin.
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), 5);
        let ds = gen.generate(2000, 6);
        let scores: Vec<f32> = (0..ds.rows)
            .map(|r| {
                ds.row(r)
                    .iter()
                    .zip(gen.ground_truth())
                    .map(|(&x, &b)| x * b)
                    .sum()
            })
            .collect();
        let auc = crate::data::auc(&scores, &ds.y);
        assert!(auc > 0.75, "ground-truth AUC {auc}");
    }

    #[test]
    fn deterministic_given_seeds() {
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), 7);
        let a = gen.generate(30, 8);
        let b = gen.generate(30, 8);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn padding_preserves_rows_and_adds_zero_cols() {
        let gen = SyntheticCategorical::new(CategoricalConfig::default(), 9);
        let ds = gen.generate(10, 10);
        let m = 7;
        let padded = SyntheticCategorical::pad_to_multiple(&ds, m);
        assert_eq!(padded.cols % m, 0);
        assert!(padded.cols >= ds.cols);
        for r in 0..ds.rows {
            assert_eq!(&padded.row(r)[..ds.cols], ds.row(r));
            assert!(padded.row(r)[ds.cols..].iter().all(|&v| v == 0.0));
        }
    }
}
