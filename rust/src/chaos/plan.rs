//! Deterministic fault plans: *what* goes wrong, *where*, and *when*.
//!
//! A [`FaultPlan`] is a pure schedule over `(worker, iteration)` cells.
//! It has no interior mutability and no clocks: both the injection sites
//! (worker loops, TCP worker body) and the master-side logger query the
//! same plan and therefore agree on every injected fault without any
//! cross-thread bookkeeping. Plans are built explicitly
//! ([`FaultPlan::schedule`]) or sampled from a [`ChaosSpec`] with a
//! seeded [`Pcg64`] ([`FaultPlan::random`]), so a failing chaos run
//! replays bit-identically from its seed.

use std::collections::BTreeMap;

use crate::rngs::{Pcg64, Rng};

/// One injectable fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Worker stops responding at the scheduled iteration.
    /// `restart_after = Some(k)` brings it back `k` iterations later;
    /// `None` is a permanent crash.
    Crash { restart_after: Option<u32> },
    /// The result for this iteration is silently not delivered.
    Drop,
    /// One bit of the result payload flips in flight. The frame CRC32
    /// catches it on the TCP path; the in-process path ships the
    /// pre-corruption checksum so the master rejects it identically.
    Corrupt,
    /// The result frame is delivered twice (master must dedupe).
    Duplicate,
    /// The result is late by this many seconds (virtual seconds in
    /// virtual mode, sleep-scaled real seconds otherwise).
    Delay(f64),
    /// Connection reset: the TCP worker hard-closes its socket; the
    /// in-process analogue is a permanent crash from this iteration on.
    Reset,
}

impl FaultKind {
    /// Short stable label used in logs and CSV.
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Crash { .. } => "crash",
            FaultKind::Drop => "drop",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Duplicate => "duplicate",
            FaultKind::Delay(_) => "delay",
            FaultKind::Reset => "reset",
        }
    }
}

/// The plan's verdict for one `(worker, iteration)` cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Effect {
    /// Behave normally.
    None,
    /// Apply the fault scheduled exactly at this iteration.
    Fault(FaultKind),
    /// Inside a crash window (or past a permanent crash/reset): stay
    /// silent.
    Dead,
}

impl Effect {
    /// Whether the worker produces no usable result this iteration
    /// (dead, crashing, dropping, or resetting).
    pub fn is_silent(&self) -> bool {
        matches!(
            self,
            Effect::Dead
                | Effect::Fault(FaultKind::Crash { .. })
                | Effect::Fault(FaultKind::Drop)
                | Effect::Fault(FaultKind::Reset)
        )
    }
}

/// A deterministic per-`(worker, iteration)` fault schedule.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    n: usize,
    events: BTreeMap<(usize, u64), FaultKind>,
}

impl FaultPlan {
    /// An empty plan for `n` workers (injects nothing).
    pub fn new(n: usize) -> Self {
        FaultPlan { n, events: BTreeMap::new() }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of scheduled fault events (crash windows count once).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Schedule `kind` for `worker` at `iter` (replaces any previous
    /// event in that cell).
    pub fn schedule(&mut self, worker: usize, iter: u64, kind: FaultKind) -> &mut Self {
        assert!(worker < self.n, "worker {worker} out of range (n={})", self.n);
        self.events.insert((worker, iter), kind);
        self
    }

    /// What `worker` should do at `iter`. Crash windows dominate: a crash
    /// scheduled at `i0` silences the worker for `iter ∈ [i0, i0+k)`
    /// (forever when permanent), and a reset silences it for every
    /// iteration after the reset itself.
    pub fn effect(&self, worker: usize, iter: u64) -> Effect {
        if worker >= self.n {
            return Effect::None;
        }
        for (&(_, i0), kind) in self.events.range((worker, 0)..=(worker, iter)) {
            match kind {
                FaultKind::Crash { restart_after } => {
                    let dead = match restart_after {
                        None => true,
                        Some(k) => iter < i0 + *k as u64,
                    };
                    if dead {
                        return Effect::Dead;
                    }
                }
                FaultKind::Reset if i0 < iter => return Effect::Dead,
                _ => {}
            }
        }
        match self.events.get(&(worker, iter)) {
            Some(&k) => Effect::Fault(k),
            None => Effect::None,
        }
    }

    /// All events scheduled exactly at `iter` (master-side logging).
    pub fn events_at(&self, iter: u64) -> Vec<(usize, FaultKind)> {
        self.events
            .iter()
            .filter(|&(&(_, i), _)| i == iter)
            .map(|(&(w, _), &k)| (w, k))
            .collect()
    }

    /// Workers silent at `iter` (scheduled-silent or inside a window).
    pub fn silent_at(&self, iter: u64) -> Vec<usize> {
        (0..self.n).filter(|&w| self.effect(w, iter).is_silent()).collect()
    }

    /// Sample a plan from per-iteration fault probabilities. Seeded by
    /// `spec.seed`; per-worker streams are forked so the plan for worker
    /// `w` does not depend on `n`. At most one fault per cell; a crash
    /// suppresses further sampling until the worker restarts (or forever).
    pub fn random(n: usize, iters: u64, spec: &ChaosSpec) -> FaultPlan {
        let mut plan = FaultPlan::new(n);
        let mut root = Pcg64::seed_from_u64(spec.seed);
        for w in 0..n {
            let mut rng = root.fork(w as u64 + 1);
            let mut it = 0u64;
            while it < iters {
                let u = rng.next_f64();
                let mut edge = spec.crash;
                if u < edge {
                    plan.schedule(w, it, FaultKind::Crash { restart_after: spec.restart_after });
                    match spec.restart_after {
                        None => break, // permanently dead: nothing left to sample
                        Some(k) => {
                            it += k as u64 + 1;
                            continue;
                        }
                    }
                }
                edge += spec.drop;
                if u < edge {
                    plan.schedule(w, it, FaultKind::Drop);
                } else {
                    edge += spec.corrupt;
                    if u < edge {
                        plan.schedule(w, it, FaultKind::Corrupt);
                    } else {
                        edge += spec.duplicate;
                        if u < edge {
                            plan.schedule(w, it, FaultKind::Duplicate);
                        } else {
                            edge += spec.delay;
                            if u < edge {
                                plan.schedule(w, it, FaultKind::Delay(spec.delay_secs));
                            } else if u < edge + spec.reset {
                                plan.schedule(w, it, FaultKind::Reset);
                                break; // connection gone for good
                            }
                        }
                    }
                }
                it += 1;
            }
        }
        plan
    }
}

/// Per-iteration fault probabilities for [`FaultPlan::random`], plus the
/// CLI `--chaos` syntax: comma-separated `key=value` pairs, e.g.
/// `"crash=0.02,drop=0.05,corrupt=0.02,restart=3,seed=99"`.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosSpec {
    pub crash: f64,
    pub drop: f64,
    pub corrupt: f64,
    pub duplicate: f64,
    pub delay: f64,
    /// Lateness injected by a sampled `delay` fault, seconds.
    pub delay_secs: f64,
    pub reset: f64,
    /// Crash-restart window (`restart=0` on the CLI means permanent).
    pub restart_after: Option<u32>,
    pub seed: u64,
}

impl Default for ChaosSpec {
    fn default() -> Self {
        ChaosSpec {
            crash: 0.0,
            drop: 0.0,
            corrupt: 0.0,
            duplicate: 0.0,
            delay: 0.0,
            delay_secs: 0.5,
            reset: 0.0,
            restart_after: Some(3),
            seed: 0xc4a0_5,
        }
    }
}

impl ChaosSpec {
    /// Parse the CLI spec. Unknown keys and out-of-range probabilities
    /// are errors (a typoed chaos run should fail loudly, not silently
    /// inject nothing).
    pub fn parse(spec: &str) -> Result<ChaosSpec, String> {
        let mut out = ChaosSpec::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("chaos spec entry `{part}` is not key=value"))?;
            let prob = |v: &str| -> Result<f64, String> {
                let p: f64 =
                    v.parse().map_err(|_| format!("chaos spec: bad number `{v}`"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("chaos spec: probability {p} not in [0, 1]"));
                }
                Ok(p)
            };
            match key {
                "crash" => out.crash = prob(value)?,
                "drop" => out.drop = prob(value)?,
                "corrupt" => out.corrupt = prob(value)?,
                "dup" | "duplicate" => out.duplicate = prob(value)?,
                "delay" => out.delay = prob(value)?,
                "reset" => out.reset = prob(value)?,
                "delay_secs" => {
                    out.delay_secs = value
                        .parse()
                        .map_err(|_| format!("chaos spec: bad delay_secs `{value}`"))?;
                    if !(out.delay_secs >= 0.0) {
                        return Err(format!("chaos spec: delay_secs {value} must be >= 0"));
                    }
                }
                "restart" => {
                    let k: u32 = value
                        .parse()
                        .map_err(|_| format!("chaos spec: bad restart `{value}`"))?;
                    out.restart_after = if k == 0 { None } else { Some(k) };
                }
                "seed" => {
                    out.seed = parse_u64(value)
                        .ok_or_else(|| format!("chaos spec: bad seed `{value}`"))?;
                }
                other => return Err(format!("chaos spec: unknown key `{other}`")),
            }
        }
        let total = out.crash + out.drop + out.corrupt + out.duplicate + out.delay + out.reset;
        if total > 1.0 {
            return Err(format!(
                "chaos spec: fault probabilities sum to {total:.3} > 1"
            ));
        }
        Ok(out)
    }
}

/// Parse a u64 that may be written `0x…` hex or decimal.
pub(crate) fn parse_u64(v: &str) -> Option<u64> {
    if let Some(hex) = v.strip_prefix("0x").or_else(|| v.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        v.parse().ok()
    }
}
