//! The graceful-degradation policy the trainer walks when responders run
//! short: exact decode → least-squares partial decode → stale gradient.

use std::fmt;

/// Which rung of the degradation ladder an iteration decoded on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LadderRung {
    /// The scheme's own decode succeeded (for [`crate::coding::ApproxCode`]
    /// this includes its bounded-residual quorum decode — "exact" means
    /// "the configured recovery guarantee held").
    Exact,
    /// Too few responders for the scheme: the generic least-squares
    /// partial decode ([`crate::coding::ls_partial_decode`]) produced a
    /// bounded-residual estimate from whoever responded.
    Degraded,
    /// Nothing decodable at all: the iteration reused the previous
    /// gradient (a no-op step when no gradient exists yet).
    Stale,
}

impl LadderRung {
    /// Stable label used in CSV and logs.
    pub fn as_str(&self) -> &'static str {
        match self {
            LadderRung::Exact => "exact",
            LadderRung::Degraded => "degraded",
            LadderRung::Stale => "stale",
        }
    }
}

impl Default for LadderRung {
    fn default() -> Self {
        LadderRung::Exact
    }
}

impl fmt::Display for LadderRung {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Policy knobs for the degradation ladder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DegradeLadder {
    /// Consecutive [`LadderRung::Stale`] iterations tolerated before the
    /// run aborts (a cluster that stopped responding entirely should fail
    /// the run, not spin on stale gradients forever).
    pub max_stale: usize,
}

impl Default for DegradeLadder {
    fn default() -> Self {
        DegradeLadder { max_stale: 5 }
    }
}
