//! Deterministic fault injection ("chaos engine") for the coordinator.
//!
//! The paper's schemes tolerate *slow* workers by construction; this
//! module exercises everything else that goes wrong in a real
//! deployment — crashes, dropped results, corrupted payloads, duplicate
//! deliveries, late arrivals, and connection resets — and the matching
//! robustness machinery the coordinator grew for them:
//!
//! - [`FaultPlan`] / [`ChaosSpec`]: a pure, seeded schedule of
//!   [`FaultKind`]s per `(worker, iteration)` cell, threaded through
//!   both the in-process cluster and the TCP worker body. Determinism is
//!   the point: a failed chaos run replays bit-identically from its seed.
//! - [`GatherPolicy`]: per-iteration gather deadline and per-worker
//!   retry/backoff used by `Cluster` (real-time mode) and `RemoteMaster`.
//! - [`DegradeLadder`] / [`LadderRung`]: the graceful-degradation policy
//!   the trainer walks when responders run short — exact decode at
//!   `>= n - s` responders, least-squares partial decode below that
//!   (via [`crate::coding::ls_partial_decode`]), and a stale-gradient
//!   no-op step as the last resort.
//! - [`FaultLog`]: every injected fault and recovery decision, surfaced
//!   through `RunLog`/CSV and the `chaos-report` CLI subcommand.

mod ladder;
mod log;
mod plan;

pub use ladder::{DegradeLadder, LadderRung};
pub use log::{FaultEvent, FaultLog, FaultLogEntry};
pub use plan::{ChaosSpec, Effect, FaultKind, FaultPlan};

pub(crate) use plan::parse_u64;

use std::sync::Arc;
use std::time::Duration;

/// Gather robustness policy: how long the master waits for an iteration
/// and how aggressively it re-prods missing workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GatherPolicy {
    /// Total per-iteration gather deadline; when it expires the master
    /// proceeds with whatever arrived (the degrade ladder takes over).
    pub deadline: Duration,
    /// Task re-broadcasts to silent workers before giving up. The
    /// deadline is split into `retries + 1` equal waits, one per attempt.
    pub retries: u32,
    /// Pause before each re-broadcast (results keep queueing meanwhile).
    pub backoff: Duration,
}

impl Default for GatherPolicy {
    fn default() -> Self {
        GatherPolicy {
            deadline: Duration::from_secs(10),
            retries: 2,
            backoff: Duration::from_millis(10),
        }
    }
}

impl GatherPolicy {
    /// The wait budget for one attempt (`deadline / (retries + 1)`).
    pub fn slice(&self) -> Duration {
        self.deadline / (self.retries + 1).max(1)
    }
}

/// Everything the trainer needs to run under injected faults: the plan,
/// the gather policy, and the degradation policy.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    pub plan: Arc<FaultPlan>,
    pub policy: GatherPolicy,
    pub ladder: DegradeLadder,
}

impl ChaosConfig {
    /// Wrap an explicit plan with default policies.
    pub fn new(plan: FaultPlan) -> Self {
        ChaosConfig {
            plan: Arc::new(plan),
            policy: GatherPolicy::default(),
            ladder: DegradeLadder::default(),
        }
    }

    /// Sample a random plan for an `n`-worker, `iters`-iteration run.
    pub fn from_spec(n: usize, iters: u64, spec: &ChaosSpec) -> Self {
        Self::new(FaultPlan::random(n, iters, spec))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_and_effect() {
        let mut plan = FaultPlan::new(4);
        plan.schedule(1, 3, FaultKind::Drop);
        plan.schedule(2, 5, FaultKind::Corrupt);
        assert_eq!(plan.effect(1, 3), Effect::Fault(FaultKind::Drop));
        assert_eq!(plan.effect(1, 2), Effect::None);
        assert_eq!(plan.effect(1, 4), Effect::None);
        assert_eq!(plan.effect(2, 5), Effect::Fault(FaultKind::Corrupt));
        assert_eq!(plan.effect(0, 3), Effect::None);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan.events_at(3), vec![(1, FaultKind::Drop)]);
    }

    #[test]
    fn crash_windows() {
        let mut plan = FaultPlan::new(3);
        plan.schedule(0, 2, FaultKind::Crash { restart_after: Some(3) });
        plan.schedule(1, 4, FaultKind::Crash { restart_after: None });
        // restartable: dead for iters 2, 3, 4; back at 5
        assert_eq!(plan.effect(0, 1), Effect::None);
        for it in 2..5 {
            assert_eq!(plan.effect(0, it), Effect::Dead, "iter {it}");
        }
        assert_eq!(plan.effect(0, 5), Effect::None);
        // permanent: dead from 4 on
        assert_eq!(plan.effect(1, 3), Effect::None);
        assert_eq!(plan.effect(1, 4), Effect::Dead);
        assert_eq!(plan.effect(1, 1000), Effect::Dead);
        assert_eq!(plan.silent_at(4), vec![0, 1]);
    }

    #[test]
    fn reset_kills_the_connection_afterwards() {
        let mut plan = FaultPlan::new(2);
        plan.schedule(0, 1, FaultKind::Reset);
        assert_eq!(plan.effect(0, 0), Effect::None);
        assert_eq!(plan.effect(0, 1), Effect::Fault(FaultKind::Reset));
        assert_eq!(plan.effect(0, 2), Effect::Dead);
        assert!(plan.effect(0, 1).is_silent());
    }

    #[test]
    fn random_plans_are_deterministic_and_seed_sensitive() {
        let spec = ChaosSpec {
            crash: 0.02,
            drop: 0.05,
            corrupt: 0.03,
            duplicate: 0.02,
            delay: 0.04,
            reset: 0.01,
            seed: 42,
            ..ChaosSpec::default()
        };
        let a = FaultPlan::random(6, 100, &spec);
        let b = FaultPlan::random(6, 100, &spec);
        assert_eq!(a, b, "same spec must give the same plan");
        assert!(!a.is_empty(), "these rates over 600 cells should fire");
        let other = FaultPlan::random(6, 100, &ChaosSpec { seed: 43, ..spec });
        assert_ne!(a, other, "different seeds should differ");
    }

    #[test]
    fn random_respects_crash_windows() {
        // With only crash probability set, every sampled event is a crash
        // and no event lands inside another crash's window.
        let spec = ChaosSpec { crash: 0.2, restart_after: Some(4), ..ChaosSpec::default() };
        let plan = FaultPlan::random(4, 200, &spec);
        for w in 0..4 {
            let mut crashes: Vec<u64> = (0..200)
                .filter(|&it| {
                    matches!(plan.effect(w, it), Effect::Fault(FaultKind::Crash { .. }))
                })
                .collect();
            crashes.sort_unstable();
            for pair in crashes.windows(2) {
                assert!(pair[1] >= pair[0] + 5, "crash inside a crash window");
            }
        }
    }

    #[test]
    fn spec_parses_and_validates() {
        let spec =
            ChaosSpec::parse("crash=0.02, drop=0.05,corrupt=0.01,dup=0.02,delay=0.1,delay_secs=2.5,reset=0.01,restart=7,seed=0xbeef")
                .unwrap();
        assert_eq!(spec.crash, 0.02);
        assert_eq!(spec.drop, 0.05);
        assert_eq!(spec.duplicate, 0.02);
        assert_eq!(spec.delay_secs, 2.5);
        assert_eq!(spec.restart_after, Some(7));
        assert_eq!(spec.seed, 0xbeef);
        assert_eq!(ChaosSpec::parse("restart=0").unwrap().restart_after, None);
        assert!(ChaosSpec::parse("crash=1.5").is_err());
        assert!(ChaosSpec::parse("unknown=1").is_err());
        assert!(ChaosSpec::parse("crash").is_err());
        assert!(ChaosSpec::parse("crash=0.6,drop=0.6").is_err(), "probs sum > 1");
        assert!(ChaosSpec::parse("").is_ok(), "empty spec = no faults");
    }

    #[test]
    fn fault_log_counts_and_csv() {
        let mut log = FaultLog::new();
        log.record(0, Some(2), FaultEvent::Injected(FaultKind::Drop));
        log.record(0, None, FaultEvent::Rung { rung: LadderRung::Exact, residual: None });
        log.record(1, Some(3), FaultEvent::ChecksumReject);
        log.record(
            1,
            None,
            FaultEvent::Rung { rung: LadderRung::Degraded, residual: Some(0.25) },
        );
        log.record(2, None, FaultEvent::Rung { rung: LadderRung::Stale, residual: None });
        assert_eq!(log.injected(), 1);
        assert_eq!(log.checksum_rejects(), 1);
        assert_eq!(log.rung_counts(), (1, 1, 1));
        assert_eq!(log.rung_of(1), Some(LadderRung::Degraded));
        assert_eq!(log.rung_of(7), None);
        let csv = log.to_csv();
        assert!(csv.starts_with("iter,worker,event,detail\n"));
        assert!(csv.contains("1,3,checksum_reject,"));
        assert!(csv.contains("degraded residual=0.250000"));
        let summary = log.summary();
        assert!(summary.contains("exact=1 degraded=1 stale=1"), "{summary}");
    }

    #[test]
    fn gather_policy_slices_the_deadline() {
        let p = GatherPolicy {
            deadline: Duration::from_secs(9),
            retries: 2,
            backoff: Duration::ZERO,
        };
        assert_eq!(p.slice(), Duration::from_secs(3));
        let p0 = GatherPolicy { retries: 0, ..p };
        assert_eq!(p0.slice(), p.deadline);
    }
}
