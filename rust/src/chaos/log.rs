//! The fault log: every injected fault and every recovery decision,
//! recorded per iteration and surfaced through `RunLog`/CSV and the
//! `chaos-report` CLI subcommand.

use std::fmt::Write as _;

use super::ladder::LadderRung;
use super::plan::FaultKind;

/// One observable fault or recovery event.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// A planned fault fired (recomputed master-side from the
    /// deterministic [`super::FaultPlan`]).
    Injected(FaultKind),
    /// A result failed its CRC32 check; the sender was treated as a
    /// straggler for this iteration.
    ChecksumReject,
    /// Duplicated result frames discarded by the master's dedupe.
    DuplicatesDiscarded { count: usize },
    /// The gather deadline expired before the wait rule was satisfied.
    DeadlineExpired { responders: usize, needed: usize },
    /// A worker connection closed mid-run (TCP path).
    ConnectionClosed,
    /// The recovery decision for the iteration.
    Rung { rung: LadderRung, residual: Option<f64> },
}

impl FaultEvent {
    /// Stable label used in the CSV export.
    pub fn label(&self) -> &'static str {
        match self {
            FaultEvent::Injected(k) => k.label(),
            FaultEvent::ChecksumReject => "checksum_reject",
            FaultEvent::DuplicatesDiscarded { .. } => "dup_discarded",
            FaultEvent::DeadlineExpired { .. } => "deadline",
            FaultEvent::ConnectionClosed => "conn_closed",
            FaultEvent::Rung { .. } => "rung",
        }
    }

    /// Free-form detail column for the CSV export (and the flight ring).
    pub fn detail(&self) -> String {
        match self {
            FaultEvent::Injected(FaultKind::Crash { restart_after }) => match restart_after {
                Some(k) => format!("restart_after={k}"),
                None => "permanent".to_string(),
            },
            FaultEvent::Injected(FaultKind::Delay(secs)) => format!("secs={secs}"),
            FaultEvent::Injected(_) => String::new(),
            FaultEvent::ChecksumReject | FaultEvent::ConnectionClosed => String::new(),
            FaultEvent::DuplicatesDiscarded { count } => format!("count={count}"),
            FaultEvent::DeadlineExpired { responders, needed } => {
                format!("responders={responders}/{needed}")
            }
            FaultEvent::Rung { rung, residual } => match residual {
                Some(r) => format!("{rung} residual={r:.6}"),
                None => rung.as_str().to_string(),
            },
        }
    }
}

/// One log line.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultLogEntry {
    pub iter: u64,
    /// Worker involved; `None` for iteration-level events.
    pub worker: Option<usize>,
    pub event: FaultEvent,
}

/// Ordered record of everything that went wrong — and what the
/// coordinator did about it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultLog {
    pub entries: Vec<FaultLogEntry>,
}

impl FaultLog {
    pub fn new() -> Self {
        FaultLog::default()
    }

    pub fn record(&mut self, iter: u64, worker: Option<usize>, event: FaultEvent) {
        // Every fault also lands in the always-on flight ring, so a
        // post-mortem dump shows the recent fault history even on runs
        // that never enabled tracing. This is the single chokepoint all
        // fault paths flow through.
        crate::obs::flight::global().record(
            event.label(),
            worker,
            Some(iter),
            &event.detail(),
        );
        self.entries.push(FaultLogEntry { iter, worker, event });
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Number of injected-fault entries.
    pub fn injected(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| matches!(e.event, FaultEvent::Injected(_)))
            .count()
    }

    /// Number of checksum rejections.
    pub fn checksum_rejects(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| matches!(e.event, FaultEvent::ChecksumReject))
            .count()
    }

    /// `(exact, degraded, stale)` iteration counts among recorded rungs.
    pub fn rung_counts(&self) -> (usize, usize, usize) {
        let mut counts = (0usize, 0usize, 0usize);
        for e in &self.entries {
            if let FaultEvent::Rung { rung, .. } = e.event {
                match rung {
                    LadderRung::Exact => counts.0 += 1,
                    LadderRung::Degraded => counts.1 += 1,
                    LadderRung::Stale => counts.2 += 1,
                }
            }
        }
        counts
    }

    /// The recovery rung recorded for `iter`, if any.
    pub fn rung_of(&self, iter: u64) -> Option<LadderRung> {
        self.entries.iter().rev().find_map(|e| match e.event {
            FaultEvent::Rung { rung, .. } if e.iter == iter => Some(rung),
            _ => None,
        })
    }

    /// CSV export: `iter,worker,event,detail`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("iter,worker,event,detail\n");
        for e in &self.entries {
            let _ = writeln!(
                s,
                "{},{},{},{}",
                e.iter,
                e.worker.map_or(String::new(), |w| w.to_string()),
                e.event.label(),
                e.event.detail(),
            );
        }
        s
    }

    /// Human-readable summary (the `chaos-report` body).
    pub fn summary(&self) -> String {
        let (exact, degraded, stale) = self.rung_counts();
        let mut by_kind: Vec<(&'static str, usize)> = Vec::new();
        for e in &self.entries {
            if let FaultEvent::Injected(k) = e.event {
                match by_kind.iter_mut().find(|(l, _)| *l == k.label()) {
                    Some((_, c)) => *c += 1,
                    None => by_kind.push((k.label(), 1)),
                }
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "fault log: {} entries", self.len());
        let _ = writeln!(s, "  injected faults: {}", self.injected());
        for (label, count) in &by_kind {
            let _ = writeln!(s, "    {label:<10} {count}");
        }
        let _ = writeln!(s, "  checksum rejects: {}", self.checksum_rejects());
        let _ = writeln!(
            s,
            "  recovery rungs:   exact={exact} degraded={degraded} stale={stale}"
        );
        s
    }
}
