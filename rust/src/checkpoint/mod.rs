//! Checkpointing: save/restore parameter vectors and run logs.
//!
//! Binary format (no serde offline): `magic u32 | version u32 | dim u64 |
//! iter u64 | f32[dim]`, little-endian. Used by the trainer CLI so long
//! coded-training runs survive restarts, and by the examples to hand a
//! trained model to the predict artifact.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

const MAGIC: u32 = 0x6743_ca1e;
const VERSION: u32 = 1;

/// A saved model state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Completed iterations.
    pub iter: u64,
    /// Parameter vector.
    pub beta: Vec<f32>,
}

impl Checkpoint {
    pub fn new(iter: u64, beta: Vec<f32>) -> Self {
        Checkpoint { iter, beta }
    }

    /// Serialize to a writer.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&MAGIC.to_le_bytes())?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.beta.len() as u64).to_le_bytes())?;
        w.write_all(&self.iter.to_le_bytes())?;
        for x in &self.beta {
            w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    /// Deserialize from a reader.
    pub fn read_from(r: &mut impl Read) -> Result<Self> {
        let mut head = [0u8; 24];
        r.read_exact(&mut head).context("checkpoint header")?;
        let magic = u32::from_le_bytes([head[0], head[1], head[2], head[3]]);
        if magic != MAGIC {
            bail!("not a gradcode checkpoint (magic {magic:#x})");
        }
        let version = u32::from_le_bytes([head[4], head[5], head[6], head[7]]);
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let dim = u64::from_le_bytes([
            head[8], head[9], head[10], head[11], head[12], head[13], head[14], head[15],
        ]) as usize;
        let iter = u64::from_le_bytes([
            head[16], head[17], head[18], head[19], head[20], head[21], head[22], head[23],
        ]);
        if dim > (1 << 31) {
            bail!("implausible checkpoint dim {dim}");
        }
        let mut raw = vec![0u8; dim * 4];
        r.read_exact(&mut raw).context("checkpoint payload")?;
        let beta = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Checkpoint { iter, beta })
    }

    /// Save atomically (write + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        self.write_to(&mut f)?;
        f.sync_all().ok();
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        Self::read_from(&mut f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_in_memory() {
        let ck = Checkpoint::new(42, (0..100).map(|i| i as f32 * 0.5).collect());
        let mut buf = Vec::new();
        ck.write_to(&mut buf).unwrap();
        let back = Checkpoint::read_from(&mut std::io::Cursor::new(buf)).unwrap();
        assert_eq!(back, ck);
    }

    #[test]
    fn save_and_load_file() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("gradcode-ck-{}.bin", std::process::id()));
        let ck = Checkpoint::new(7, vec![1.5, -2.0, 0.25]);
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_magic_rejected() {
        let mut buf = Vec::new();
        Checkpoint::new(1, vec![0.0]).write_to(&mut buf).unwrap();
        buf[0] ^= 0xff;
        assert!(Checkpoint::read_from(&mut std::io::Cursor::new(buf)).is_err());
    }

    #[test]
    fn truncated_payload_rejected() {
        let mut buf = Vec::new();
        Checkpoint::new(1, vec![0.0; 10]).write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 8);
        assert!(Checkpoint::read_from(&mut std::io::Cursor::new(buf)).is_err());
    }
}
