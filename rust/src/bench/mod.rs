//! Benchmark harness substrate (no `criterion` offline).
//!
//! Two pieces:
//! - [`Bencher`]: warmup + timed iterations with mean / stddev / p50 / p99
//!   and ns-per-op reporting, for the hot-path microbenches.
//! - [`Table`]: aligned ASCII table printer so each `rust/benches/*` bin
//!   emits rows directly comparable to the paper's tables and figures.

use std::time::{Duration, Instant};

/// Summary statistics of a timed run.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// Human-readable time string.
    pub fn human(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {} ± {} (p50 {}, p99 {}, n={})",
            Stats::human(self.mean_ns),
            Stats::human(self.std_ns),
            Stats::human(self.p50_ns),
            Stats::human(self.p99_ns),
            self.iters
        )
    }
}

/// Timing driver: runs `f` for `warmup` untimed and `iters` timed passes.
pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 3, iters: 30 }
    }
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher { warmup, iters }
    }

    /// Time a closure; the closure's return value is black-boxed so the
    /// optimizer cannot elide the work.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        stats_from(&mut samples)
    }

    /// Time a closure under a wall-clock budget: stops after `iters` or
    /// `budget`, whichever first (for expensive end-to-end passes).
    pub fn run_budget<T>(&self, budget: Duration, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup.min(1) {
            black_box(f());
        }
        let start = Instant::now();
        let mut samples = Vec::new();
        while samples.len() < self.iters && (samples.is_empty() || start.elapsed() < budget) {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        stats_from(&mut samples)
    }
}

fn stats_from(samples: &mut [f64]) -> Stats {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    let pct = |q: f64| samples[((q * (n - 1) as f64).round() as usize).min(n - 1)];
    Stats {
        iters: n,
        mean_ns: mean,
        std_ns: var.sqrt(),
        p50_ns: pct(0.5),
        p99_ns: pct(0.99),
        min_ns: samples[0],
        max_ns: samples[n - 1],
    }
}

/// Optimizer barrier (stable-rust version of `std::hint::black_box`
/// semantics; std's is available and used directly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Aligned ASCII table printer for paper-style output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:>w$} | ", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Minimal JSON object builder for the machine-readable bench artifacts
/// (`BENCH_*.json`; no serde offline). Values are appended in insertion
/// order; nested objects/arrays go through [`JsonObject::field_raw`] /
/// [`json_array`].
#[derive(Debug, Clone, Default)]
pub struct JsonObject {
    parts: Vec<String>,
}

impl JsonObject {
    pub fn new() -> Self {
        JsonObject { parts: Vec::new() }
    }

    pub fn field_str(mut self, key: &str, value: &str) -> Self {
        self.parts.push(format!("{}: {}", json_string(key), json_string(value)));
        self
    }

    pub fn field_int(mut self, key: &str, value: i64) -> Self {
        self.parts.push(format!("{}: {value}", json_string(key)));
        self
    }

    /// Non-finite floats serialize as `null` (JSON has no NaN/inf).
    pub fn field_num(mut self, key: &str, value: f64) -> Self {
        let v = if value.is_finite() { format!("{value}") } else { "null".into() };
        self.parts.push(format!("{}: {v}", json_string(key)));
        self
    }

    /// Pre-rendered JSON (an object from [`JsonObject::build`] or an
    /// array from [`json_array`]).
    pub fn field_raw(mut self, key: &str, raw: &str) -> Self {
        self.parts.push(format!("{}: {raw}", json_string(key)));
        self
    }

    pub fn build(&self) -> String {
        format!("{{{}}}", self.parts.join(", "))
    }
}

/// Render pre-serialized JSON values as an array.
pub fn json_array<I: IntoIterator<Item = String>>(items: I) -> String {
    format!("[{}]", items.into_iter().collect::<Vec<_>>().join(", "))
}

/// Parsed JSON value — the read side of the `BENCH_*.json` artifacts
/// (the `ci-gate` subcommand compares fresh runs against committed
/// baselines; no serde offline).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<JsonValue>),
    Object(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Member lookup on objects (first match; `None` elsewhere).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Walk a dotted path of object members, e.g.
    /// `"bimodal_margin.realized_speedup"`.
    pub fn get_path(&self, path: &str) -> Option<&JsonValue> {
        let mut cur = self;
        for key in path.split('.') {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parse one JSON document (trailing whitespace allowed, nothing else).
/// Errors are positioned for "which baseline file is broken" debugging,
/// not spec-grade diagnostics.
pub fn parse_json(text: &str) -> Result<JsonValue, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing characters at byte {pos}"));
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(JsonValue::Object(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    JsonValue::Str(s) => s,
                    _ => return Err(format!("object key at byte {pos} is not a string")),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}"));
                }
                *pos += 1;
                fields.push((key, parse_value(b, pos)?));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(JsonValue::Object(fields));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(JsonValue::Array(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(JsonValue::Array(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                }
            }
        }
        Some(b'"') => {
            *pos += 1;
            let mut out = String::new();
            loop {
                match b.get(*pos) {
                    None => return Err("unterminated string".into()),
                    Some(b'"') => {
                        *pos += 1;
                        return Ok(JsonValue::Str(out));
                    }
                    Some(b'\\') => {
                        *pos += 1;
                        match b.get(*pos) {
                            Some(b'"') => out.push('"'),
                            Some(b'\\') => out.push('\\'),
                            Some(b'/') => out.push('/'),
                            Some(b'n') => out.push('\n'),
                            Some(b'r') => out.push('\r'),
                            Some(b't') => out.push('\t'),
                            Some(b'b') => out.push('\u{8}'),
                            Some(b'f') => out.push('\u{c}'),
                            Some(b'u') => {
                                let hex = text_slice(b, *pos + 1, *pos + 5)?;
                                let cp = u32::from_str_radix(hex, 16)
                                    .map_err(|_| format!("bad \\u escape at byte {pos}"))?;
                                // Surrogate pairs don't occur in our own
                                // artifacts; map them to U+FFFD.
                                out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                                *pos += 4;
                            }
                            _ => return Err(format!("bad escape at byte {pos}")),
                        }
                        *pos += 1;
                    }
                    Some(_) => {
                        // Multi-byte UTF-8 sequences pass through intact.
                        let start = *pos;
                        while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                            *pos += 1;
                        }
                        out.push_str(text_slice(b, start, *pos)?);
                    }
                }
            }
        }
        Some(b't') => expect_lit(b, pos, "true", JsonValue::Bool(true)),
        Some(b'f') => expect_lit(b, pos, "false", JsonValue::Bool(false)),
        Some(b'n') => expect_lit(b, pos, "null", JsonValue::Null),
        Some(_) => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let s = text_slice(b, start, *pos)?;
            s.parse::<f64>()
                .map(JsonValue::Num)
                .map_err(|_| format!("invalid number {s:?} at byte {start}"))
        }
    }
}

fn expect_lit(
    b: &[u8],
    pos: &mut usize,
    lit: &str,
    value: JsonValue,
) -> Result<JsonValue, String> {
    if b.len() >= *pos + lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("invalid literal at byte {pos}"))
    }
}

fn text_slice(b: &[u8], start: usize, end: usize) -> Result<&str, String> {
    if end > b.len() {
        return Err("unexpected end of input".into());
    }
    std::str::from_utf8(&b[start..end]).map_err(|_| format!("invalid UTF-8 at byte {start}"))
}

/// JSON string literal with the mandatory escapes.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_builder_emits_valid_shapes() {
        let obj = JsonObject::new()
            .field_str("name", "hetero \"speedup\"")
            .field_int("n", 10)
            .field_num("time", 1.5)
            .field_num("bad", f64::NAN)
            .field_raw("list", &json_array([1.0, 2.0].iter().map(|x| x.to_string())))
            .build();
        assert_eq!(
            obj,
            "{\"name\": \"hetero \\\"speedup\\\"\", \"n\": 10, \"time\": 1.5, \
             \"bad\": null, \"list\": [1, 2]}"
        );
        assert_eq!(json_string("a\nb"), "\"a\\nb\"");
    }

    #[test]
    fn bencher_times_something() {
        let b = Bencher::new(1, 10);
        let s = b.run(|| {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(s.iters, 10);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.max_ns);
    }

    #[test]
    fn stats_ordering_invariants() {
        let mut samples = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        let s = stats_from(&mut samples);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 5.0);
        assert_eq!(s.p50_ns, 3.0);
        assert!((s.mean_ns - 3.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["scheme", "time"]);
        t.row(&["naive".into(), "36.11".into()]);
        t.row(&["ours (d=4,m=3)".into(), "21.37".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("naive"));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    fn human_units() {
        assert!(Stats::human(500.0).ends_with("ns"));
        assert!(Stats::human(5_000.0).ends_with("µs"));
        assert!(Stats::human(5_000_000.0).ends_with("ms"));
        assert!(Stats::human(5e9).ends_with('s'));
    }

    #[test]
    fn parser_roundtrips_builder_output() {
        // The gate reads exactly what the benches write: the parser must
        // invert JsonObject/json_array output, nesting included.
        let text = JsonObject::new()
            .field_str("bench", "hotpath \"smoke\"")
            .field_int("n", 10)
            .field_num("train_speedup", 2.25)
            .field_num("bad", f64::NAN)
            .field_raw(
                "bimodal_margin",
                &JsonObject::new().field_num("realized_speedup", 1.75).build(),
            )
            .field_raw(
                "sweep",
                &json_array([1.0, 2.5].iter().map(|x| x.to_string())),
            )
            .build();
        let doc = parse_json(&text).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("hotpath \"smoke\""));
        assert_eq!(doc.get("n").unwrap().as_f64(), Some(10.0));
        assert_eq!(doc.get_path("train_speedup").unwrap().as_f64(), Some(2.25));
        assert_eq!(doc.get("bad"), Some(&JsonValue::Null));
        assert_eq!(
            doc.get_path("bimodal_margin.realized_speedup").unwrap().as_f64(),
            Some(1.75)
        );
        match doc.get("sweep").unwrap() {
            JsonValue::Array(items) => {
                assert_eq!(items.len(), 2);
                assert_eq!(items[1].as_f64(), Some(2.5));
            }
            other => panic!("sweep parsed as {other:?}"),
        }
    }

    #[test]
    fn parser_handles_scalars_whitespace_and_escapes() {
        assert_eq!(parse_json(" true ").unwrap(), JsonValue::Bool(true));
        assert_eq!(parse_json("false").unwrap(), JsonValue::Bool(false));
        assert_eq!(parse_json("null").unwrap(), JsonValue::Null);
        assert_eq!(parse_json("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(parse_json("[]").unwrap(), JsonValue::Array(vec![]));
        assert_eq!(parse_json("{}").unwrap(), JsonValue::Object(vec![]));
        assert_eq!(
            parse_json("\"a\\n\\t\\\\b\\u0041\"").unwrap().as_str(),
            Some("a\n\t\\bA")
        );
    }

    #[test]
    fn parser_rejects_malformed_documents() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "{\"a\": 1} trailing", "{1: 2}", "nul"] {
            assert!(parse_json(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn path_getter_misses_cleanly() {
        let doc = parse_json("{\"a\": {\"b\": 3}}").unwrap();
        assert_eq!(doc.get_path("a.b").unwrap().as_f64(), Some(3.0));
        assert!(doc.get_path("a.c").is_none());
        assert!(doc.get_path("a.b.c").is_none());
        assert!(doc.get("missing").is_none());
    }
}
