//! Benchmark harness substrate (no `criterion` offline).
//!
//! Two pieces:
//! - [`Bencher`]: warmup + timed iterations with mean / stddev / p50 / p99
//!   and ns-per-op reporting, for the hot-path microbenches.
//! - [`Table`]: aligned ASCII table printer so each `rust/benches/*` bin
//!   emits rows directly comparable to the paper's tables and figures.

use std::time::{Duration, Instant};

/// Summary statistics of a timed run.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// Human-readable time string.
    pub fn human(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.3} s", ns / 1e9)
        }
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {} ± {} (p50 {}, p99 {}, n={})",
            Stats::human(self.mean_ns),
            Stats::human(self.std_ns),
            Stats::human(self.p50_ns),
            Stats::human(self.p99_ns),
            self.iters
        )
    }
}

/// Timing driver: runs `f` for `warmup` untimed and `iters` timed passes.
pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 3, iters: 30 }
    }
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher { warmup, iters }
    }

    /// Time a closure; the closure's return value is black-boxed so the
    /// optimizer cannot elide the work.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        stats_from(&mut samples)
    }

    /// Time a closure under a wall-clock budget: stops after `iters` or
    /// `budget`, whichever first (for expensive end-to-end passes).
    pub fn run_budget<T>(&self, budget: Duration, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup.min(1) {
            black_box(f());
        }
        let start = Instant::now();
        let mut samples = Vec::new();
        while samples.len() < self.iters && (samples.is_empty() || start.elapsed() < budget) {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        stats_from(&mut samples)
    }
}

fn stats_from(samples: &mut [f64]) -> Stats {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    let pct = |q: f64| samples[((q * (n - 1) as f64).round() as usize).min(n - 1)];
    Stats {
        iters: n,
        mean_ns: mean,
        std_ns: var.sqrt(),
        p50_ns: pct(0.5),
        p99_ns: pct(0.99),
        min_ns: samples[0],
        max_ns: samples[n - 1],
    }
}

/// Optimizer barrier (stable-rust version of `std::hint::black_box`
/// semantics; std's is available and used directly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Aligned ASCII table printer for paper-style output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("| ");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:>w$} | ", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Minimal JSON object builder for the machine-readable bench artifacts
/// (`BENCH_*.json`; no serde offline). Values are appended in insertion
/// order; nested objects/arrays go through [`JsonObject::field_raw`] /
/// [`json_array`].
#[derive(Debug, Clone, Default)]
pub struct JsonObject {
    parts: Vec<String>,
}

impl JsonObject {
    pub fn new() -> Self {
        JsonObject { parts: Vec::new() }
    }

    pub fn field_str(mut self, key: &str, value: &str) -> Self {
        self.parts.push(format!("{}: {}", json_string(key), json_string(value)));
        self
    }

    pub fn field_int(mut self, key: &str, value: i64) -> Self {
        self.parts.push(format!("{}: {value}", json_string(key)));
        self
    }

    /// Non-finite floats serialize as `null` (JSON has no NaN/inf).
    pub fn field_num(mut self, key: &str, value: f64) -> Self {
        let v = if value.is_finite() { format!("{value}") } else { "null".into() };
        self.parts.push(format!("{}: {v}", json_string(key)));
        self
    }

    /// Pre-rendered JSON (an object from [`JsonObject::build`] or an
    /// array from [`json_array`]).
    pub fn field_raw(mut self, key: &str, raw: &str) -> Self {
        self.parts.push(format!("{}: {raw}", json_string(key)));
        self
    }

    pub fn build(&self) -> String {
        format!("{{{}}}", self.parts.join(", "))
    }
}

/// Render pre-serialized JSON values as an array.
pub fn json_array<I: IntoIterator<Item = String>>(items: I) -> String {
    format!("[{}]", items.into_iter().collect::<Vec<_>>().join(", "))
}

/// JSON string literal with the mandatory escapes.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_builder_emits_valid_shapes() {
        let obj = JsonObject::new()
            .field_str("name", "hetero \"speedup\"")
            .field_int("n", 10)
            .field_num("time", 1.5)
            .field_num("bad", f64::NAN)
            .field_raw("list", &json_array([1.0, 2.0].iter().map(|x| x.to_string())))
            .build();
        assert_eq!(
            obj,
            "{\"name\": \"hetero \\\"speedup\\\"\", \"n\": 10, \"time\": 1.5, \
             \"bad\": null, \"list\": [1, 2]}"
        );
        assert_eq!(json_string("a\nb"), "\"a\\nb\"");
    }

    #[test]
    fn bencher_times_something() {
        let b = Bencher::new(1, 10);
        let s = b.run(|| {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(s.iters, 10);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.max_ns);
    }

    #[test]
    fn stats_ordering_invariants() {
        let mut samples = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        let s = stats_from(&mut samples);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 5.0);
        assert_eq!(s.p50_ns, 3.0);
        assert!((s.mean_ns - 3.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["scheme", "time"]);
        t.row(&["naive".into(), "36.11".into()]);
        t.row(&["ours (d=4,m=3)".into(), "21.37".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("naive"));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    fn human_units() {
        assert!(Stats::human(500.0).ends_with("ns"));
        assert!(Stats::human(5_000.0).ends_with("µs"));
        assert!(Stats::human(5_000_000.0).ends_with("ms"));
        assert!(Stats::human(5e9).ends_with('s'));
    }
}
