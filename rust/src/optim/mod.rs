//! First-order optimizers.
//!
//! The paper's EC2 experiments train with Nesterov's Accelerated Gradient
//! (NAG, Bubeck §3.7); plain SGD and classical momentum are provided as
//! baselines. The coordinator is optimizer-generic: it feeds the decoded
//! sum gradient into [`Optimizer::step`] each iteration, and asks
//! [`Optimizer::eval_point`] where the next gradient must be evaluated
//! (for NAG that is the lookahead sequence `y_t`, not the iterate `x_t`).

mod momentum;
mod nag;
mod sgd;

pub use momentum::Momentum;
pub use nag::Nag;
pub use sgd::Sgd;

/// Gradient-based parameter updater (the `h` of Eq. 2).
pub trait Optimizer: Send {
    /// Apply one update given the gradient evaluated at
    /// [`Self::eval_point`].
    fn step(&mut self, grad: &[f32]);

    /// Where the next gradient should be evaluated.
    fn eval_point(&self) -> &[f32];

    /// The current iterate (what should be used for prediction/metrics).
    fn iterate(&self) -> &[f32];

    /// Completed update count.
    fn t(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quadratic bowl f(x) = 0.5‖x - c‖²; all optimizers must converge.
    fn converges<O: Optimizer>(mut opt: O, c: &[f32], iters: usize) -> f32 {
        for _ in 0..iters {
            let g: Vec<f32> = opt.eval_point().iter().zip(c).map(|(&x, &ci)| x - ci).collect();
            opt.step(&g);
        }
        opt.iterate()
            .iter()
            .zip(c)
            .map(|(&x, &ci)| (x - ci) * (x - ci))
            .sum::<f32>()
            .sqrt()
    }

    #[test]
    fn all_optimizers_converge_on_quadratic() {
        let c = vec![3.0f32, -1.0, 0.5];
        let d = c.len();
        assert!(converges(Sgd::new(vec![0.0; d], 0.3), &c, 200) < 1e-3);
        assert!(converges(Momentum::new(vec![0.0; d], 0.1, 0.9), &c, 300) < 1e-3);
        assert!(converges(Nag::new(vec![0.0; d], 0.1, 0.9), &c, 300) < 1e-3);
    }

    #[test]
    fn nag_beats_sgd_on_ill_conditioned_quadratic() {
        // f(x) = 0.5 (x₀² + 25 x₁²): momentum methods should make more
        // progress per iteration at the stable step size.
        let grad = |p: &[f32]| vec![p[0], 25.0 * p[1]];
        let x0 = vec![10.0f32, 10.0];
        let lr = 0.03; // stable for L = 25
        let iters = 60;
        let mut sgd = Sgd::new(x0.clone(), lr);
        let mut nag = Nag::new(x0, lr, 0.9);
        for _ in 0..iters {
            let g = grad(sgd.eval_point());
            sgd.step(&g);
            let g = grad(nag.eval_point());
            nag.step(&g);
        }
        let norm = |p: &[f32]| (p[0] * p[0] + 25.0 * p[1] * p[1]).sqrt();
        assert!(
            norm(nag.iterate()) < norm(sgd.iterate()),
            "NAG {} vs SGD {}",
            norm(nag.iterate()),
            norm(sgd.iterate())
        );
    }
}
