//! Plain (full-batch or mini-batch) gradient descent.

use super::Optimizer;

/// `x ← x - lr·g`.
pub struct Sgd {
    x: Vec<f32>,
    lr: f32,
    t: usize,
}

impl Sgd {
    pub fn new(x0: Vec<f32>, lr: f32) -> Self {
        assert!(lr > 0.0);
        Sgd { x: x0, lr, t: 0 }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, grad: &[f32]) {
        assert_eq!(grad.len(), self.x.len());
        for (x, &g) in self.x.iter_mut().zip(grad) {
            *x -= self.lr * g;
        }
        self.t += 1;
    }

    fn eval_point(&self) -> &[f32] {
        &self.x
    }

    fn iterate(&self) -> &[f32] {
        &self.x
    }

    fn t(&self) -> usize {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_moves_against_gradient() {
        let mut s = Sgd::new(vec![1.0, 2.0], 0.5);
        s.step(&[2.0, -2.0]);
        assert_eq!(s.iterate(), &[0.0, 3.0]);
        assert_eq!(s.t(), 1);
    }
}
