//! Nesterov's Accelerated Gradient (Bubeck §3.7) — the optimizer used in
//! the paper's EC2 experiments.
//!
//! Two-sequence form:
//! `x_{t+1} = y_t - lr · ∇f(y_t)`
//! `y_{t+1} = x_{t+1} + μ · (x_{t+1} - x_t)`
//!
//! The coordinator evaluates gradients at `y_t` ([`Optimizer::eval_point`])
//! and reports metrics at `x_t` ([`Optimizer::iterate`]).

use super::Optimizer;

/// NAG with constant momentum `μ` (set `μ = 0` to recover plain GD).
pub struct Nag {
    /// Iterate `x_t`.
    x: Vec<f32>,
    /// Lookahead `y_t` (gradient evaluation point).
    y: Vec<f32>,
    lr: f32,
    mu: f32,
    t: usize,
}

impl Nag {
    pub fn new(x0: Vec<f32>, lr: f32, mu: f32) -> Self {
        assert!(lr > 0.0 && (0.0..1.0).contains(&mu));
        Nag { y: x0.clone(), x: x0, lr, mu, t: 0 }
    }

    /// NAG with the `t/(t+3)` momentum schedule (the convex-case choice in
    /// Bubeck §3.7); `mu` is ignored and recomputed each step.
    pub fn scheduled(x0: Vec<f32>, lr: f32) -> Self {
        let mut n = Nag::new(x0, lr, 0.0);
        n.mu = f32::NAN; // sentinel: use schedule
        n
    }

    fn momentum_at(&self, t: usize) -> f32 {
        if self.mu.is_nan() {
            t as f32 / (t as f32 + 3.0)
        } else {
            self.mu
        }
    }
}

impl Optimizer for Nag {
    fn step(&mut self, grad: &[f32]) {
        assert_eq!(grad.len(), self.x.len());
        let mu = self.momentum_at(self.t + 1);
        for i in 0..self.x.len() {
            let x_new = self.y[i] - self.lr * grad[i];
            let dx = x_new - self.x[i];
            self.x[i] = x_new;
            self.y[i] = x_new + mu * dx;
        }
        self.t += 1;
    }

    fn eval_point(&self) -> &[f32] {
        &self.y
    }

    fn iterate(&self) -> &[f32] {
        &self.x
    }

    fn t(&self) -> usize {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_momentum_reduces_to_gd() {
        let mut nag = Nag::new(vec![1.0], 0.1, 0.0);
        nag.step(&[1.0]);
        assert!((nag.iterate()[0] - 0.9).abs() < 1e-7);
        assert_eq!(nag.eval_point(), nag.iterate());
    }

    #[test]
    fn lookahead_differs_from_iterate_with_momentum() {
        let mut nag = Nag::new(vec![1.0], 0.1, 0.9);
        nag.step(&[1.0]);
        // x = 0.9, y = 0.9 + 0.9·(0.9-1.0) = 0.81
        assert!((nag.iterate()[0] - 0.9).abs() < 1e-7);
        assert!((nag.eval_point()[0] - 0.81).abs() < 1e-7);
    }

    #[test]
    fn scheduled_momentum_converges_on_quadratic() {
        let c = 4.0f32;
        let mut nag = Nag::scheduled(vec![0.0], 0.2);
        for _ in 0..300 {
            let g = vec![nag.eval_point()[0] - c];
            nag.step(&g);
        }
        assert!((nag.iterate()[0] - c).abs() < 1e-3);
    }
}
