//! Classical (heavy-ball) momentum.

use super::Optimizer;

/// `v ← μ·v - lr·g ; x ← x + v`.
pub struct Momentum {
    x: Vec<f32>,
    v: Vec<f32>,
    lr: f32,
    mu: f32,
    t: usize,
}

impl Momentum {
    pub fn new(x0: Vec<f32>, lr: f32, mu: f32) -> Self {
        assert!(lr > 0.0 && (0.0..1.0).contains(&mu));
        let d = x0.len();
        Momentum { x: x0, v: vec![0.0; d], lr, mu, t: 0 }
    }
}

impl Optimizer for Momentum {
    fn step(&mut self, grad: &[f32]) {
        assert_eq!(grad.len(), self.x.len());
        for ((x, v), &g) in self.x.iter_mut().zip(self.v.iter_mut()).zip(grad) {
            *v = self.mu * *v - self.lr * g;
            *x += *v;
        }
        self.t += 1;
    }

    fn eval_point(&self) -> &[f32] {
        &self.x
    }

    fn iterate(&self) -> &[f32] {
        &self.x
    }

    fn t(&self) -> usize {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn velocity_accumulates() {
        let mut m = Momentum::new(vec![0.0], 1.0, 0.5);
        m.step(&[-1.0]); // v = 1, x = 1
        m.step(&[0.0]); // v = 0.5, x = 1.5
        assert!((m.iterate()[0] - 1.5).abs() < 1e-6);
    }
}
