//! §VI runtime model extended to heterogeneous fleets: per-worker
//! shifted-exponential delays scaled by speed and load, expected
//! iteration time under the group-quorum stopping rule, and the
//! [`plan_loads`] optimizer.
//!
//! The homogeneous model (Eq. 27–29) makes the `n` worker finish times
//! i.i.d., so the iteration time is a classical order statistic. On a
//! heterogeneous fleet worker `w` with speed `σ_w` and compute load `u_w`
//! (baseline-subset units) finishes at
//!
//! ```text
//!   T_w = u_w·t₁/σ_w + t₂/m + Exp(σ_w·λ₁/u_w) + Exp(m·λ₂)
//! ```
//!
//! — non-identical across workers — and the master's stopping rule is
//! "every group `g` has `need_g` responders" ([`crate::coding::HeteroCode`]'s
//! per-group quorums; the flat `n - s` rule is the single-group special
//! case). The number of finished workers in a group at time `t` is then
//! Poisson–binomial, so
//!
//! ```text
//!   P(group g done by t)  = P(Binom(F_w(t) : w ∈ g) >= need_g)
//!   E[T_iter]             = ∫₀^∞ (1 − Π_g P(group g done by t)) dt
//! ```
//!
//! which [`expected_rule_time`] evaluates with the crate's adaptive
//! quadrature (and [`mean_rule_time_mc`] cross-checks by Monte-Carlo —
//! the agreement is asserted in the unit tests, and against the live
//! virtual cluster in `rust/tests/end_to_end.rs`).
//!
//! [`plan_loads`] searches group partitions (contiguous in speed order)
//! and per-group loads `d_g` for the plan minimizing the predicted
//! iteration time, reporting the margin over the uniform-load §III
//! scheme on the same fleet. [`SpeedProfile`] provides the canonical
//! fleet shapes (uniform / linear / bimodal / custom) used by the CLI,
//! the trainer, and the benches.

use super::model::{DelayParams, WorkerRuntime};
use super::order_stats::expected_order_stat;
use super::quadrature::integrate_tail;
use crate::coding::hetero::{balanced_group_weights, GroupPlan, SUBSET_OVERHEAD};
use crate::coding::{GradientCode, HeteroCode};
use crate::rngs::{Exponential, Pcg64};

/// Canonical per-worker speed shapes. Speeds are relative multipliers:
/// `1.0` is the fleet baseline the [`DelayParams`] are calibrated to, a
/// worker with speed `σ` computes `σ×` faster (communication is governed
/// by the message size `l/m` and stays speed-independent).
#[derive(Debug, Clone, PartialEq)]
pub enum SpeedProfile {
    /// All workers at baseline speed — the paper's homogeneous setting.
    Uniform,
    /// Speeds linearly spaced from `1.0` (worker 0) to `ratio` (worker
    /// n-1).
    Linear { ratio: f64 },
    /// A `slow_frac` fraction of the fleet at baseline speed, the rest
    /// at `ratio` — the EC2 "two instance generations" shape.
    Bimodal { slow_frac: f64, ratio: f64 },
    /// Explicit per-worker speeds (must match the worker count).
    Custom(Vec<f64>),
}

impl SpeedProfile {
    /// Materialize the per-worker speed vector for `n` workers.
    ///
    /// Panics where [`SpeedProfile::try_speeds`] would error (a `Custom`
    /// profile of the wrong length, or a parameter out of range) — use
    /// the fallible variant on user-facing paths.
    pub fn speeds(&self, n: usize) -> Vec<f64> {
        // lint: allow(panic-in-lib) documented panicking convenience; user-facing paths use try_speeds
        self.try_speeds(n).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible [`SpeedProfile::speeds`]: the length of a `Custom`
    /// profile can only be checked once the worker count is known, so
    /// CLI paths validate here rather than panicking mid-run.
    pub fn try_speeds(&self, n: usize) -> Result<Vec<f64>, String> {
        match self {
            SpeedProfile::Uniform => Ok(vec![1.0; n]),
            SpeedProfile::Linear { ratio } => {
                if *ratio <= 0.0 {
                    return Err(format!("linear ratio must be positive, got {ratio}"));
                }
                if n <= 1 {
                    return Ok(vec![1.0; n]);
                }
                Ok((0..n)
                    .map(|w| 1.0 + (ratio - 1.0) * w as f64 / (n - 1) as f64)
                    .collect())
            }
            SpeedProfile::Bimodal { slow_frac, ratio } => {
                if !(0.0..=1.0).contains(slow_frac) {
                    return Err(format!(
                        "slow fraction must be in [0, 1], got {slow_frac}"
                    ));
                }
                if *ratio <= 0.0 {
                    return Err(format!("bimodal ratio must be positive, got {ratio}"));
                }
                let slow = ((slow_frac * n as f64).round() as usize).min(n);
                Ok((0..n).map(|w| if w < slow { 1.0 } else { *ratio }).collect())
            }
            SpeedProfile::Custom(v) => {
                if v.len() != n {
                    return Err(format!(
                        "custom profile has {} speeds but the fleet has {n} workers",
                        v.len()
                    ));
                }
                if v.iter().any(|&x| !(x.is_finite() && x > 0.0)) {
                    return Err("custom speeds must be finite and positive".into());
                }
                Ok(v.clone())
            }
        }
    }

    /// Parse a CLI spec: `uniform`, `linear[:RATIO]`,
    /// `bimodal[:SLOW_FRAC[:RATIO]]`, or `custom:v1,v2,…`.
    pub fn parse(spec: &str) -> Result<SpeedProfile, String> {
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or("");
        let rest: Vec<&str> = parts.collect();
        let f64_at = |i: usize, default: f64| -> Result<f64, String> {
            match rest.get(i) {
                None => Ok(default),
                Some(s) => s
                    .parse::<f64>()
                    .map_err(|e| format!("bad number {s:?} in profile: {e}")),
            }
        };
        match kind {
            "uniform" => Ok(SpeedProfile::Uniform),
            "linear" => {
                let ratio = f64_at(0, 4.0)?;
                if ratio <= 0.0 {
                    return Err(format!("linear ratio must be positive, got {ratio}"));
                }
                Ok(SpeedProfile::Linear { ratio })
            }
            "bimodal" => {
                let slow_frac = f64_at(0, 0.5)?;
                let ratio = f64_at(1, 4.0)?;
                if !(0.0..=1.0).contains(&slow_frac) {
                    return Err(format!("slow fraction must be in [0,1], got {slow_frac}"));
                }
                if ratio <= 0.0 {
                    return Err(format!("bimodal ratio must be positive, got {ratio}"));
                }
                Ok(SpeedProfile::Bimodal { slow_frac, ratio })
            }
            "custom" => {
                let raw = rest.join(":");
                let speeds: Result<Vec<f64>, String> = raw
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| {
                        s.trim()
                            .parse::<f64>()
                            .map_err(|e| format!("bad speed {s:?}: {e}"))
                    })
                    .collect();
                let speeds = speeds?;
                if speeds.is_empty() {
                    return Err("custom profile needs at least one speed".into());
                }
                if speeds.iter().any(|&x| !(x.is_finite() && x > 0.0)) {
                    return Err("custom speeds must be finite and positive".into());
                }
                Ok(SpeedProfile::Custom(speeds))
            }
            other => Err(format!(
                "unknown profile {other:?} (uniform | linear[:R] | bimodal[:F[:R]] | custom:…)"
            )),
        }
    }

    /// Short label for logs and bench tables.
    pub fn label(&self) -> String {
        match self {
            SpeedProfile::Uniform => "uniform".into(),
            SpeedProfile::Linear { ratio } => format!("linear(r={ratio})"),
            SpeedProfile::Bimodal { slow_frac, ratio } => {
                format!("bimodal(f={slow_frac},r={ratio})")
            }
            SpeedProfile::Custom(v) => format!("custom(n={})", v.len()),
        }
    }
}

/// Runtime distribution of one heterogeneous worker: load `work`
/// (baseline-subset compute units) at relative speed `speed`, messages
/// of `l/m` floats. Reduces to [`WorkerRuntime::new`] at
/// `work = d, speed = 1`.
pub fn worker_runtime(params: &DelayParams, m: usize, work: f64, speed: f64) -> WorkerRuntime {
    assert!(work > 0.0 && speed > 0.0 && m >= 1);
    WorkerRuntime {
        a: speed * params.lambda1 / work,
        b: m as f64 * params.lambda2,
        shift: work * params.t1 / speed + params.t2 / m as f64,
    }
}

/// CDF of a worker's *total* finish time (shift + random part).
pub fn finish_cdf(rt: &WorkerRuntime, t: f64) -> f64 {
    if t <= rt.shift {
        0.0
    } else {
        rt.cdf_random(t - rt.shift)
    }
}

/// Poisson–binomial tail: probability that at least `need` of the
/// independent Bernoulli trials with success probabilities `ps` succeed.
pub fn prob_at_least(ps: &[f64], need: usize) -> f64 {
    if need == 0 {
        return 1.0;
    }
    if need > ps.len() {
        return 0.0;
    }
    let cap = need;
    // dp[j] = P(exactly j successes so far), with dp[cap] absorbing ">=".
    let mut dp = vec![0.0f64; cap + 1];
    dp[0] = 1.0;
    for &p in ps {
        dp[cap] += dp[cap - 1] * p;
        for j in (1..cap).rev() {
            dp[j] = dp[j] * (1.0 - p) + dp[j - 1] * p;
        }
        dp[0] *= 1.0 - p;
    }
    dp[cap]
}

/// Expected iteration time under a group-quorum stopping rule: the
/// master proceeds at the first `t` where every group `g` has `need_g`
/// finished workers. `groups` lists `(member indices into runtimes,
/// need)`; the flat "`r` of all `n`" rule is a single group.
pub fn expected_rule_time(runtimes: &[WorkerRuntime], groups: &[(Vec<usize>, usize)]) -> f64 {
    assert!(!runtimes.is_empty() && !groups.is_empty());
    for (members, need) in groups {
        assert!(!members.is_empty() && *need >= 1 && *need <= members.len());
        assert!(members.iter().all(|&w| w < runtimes.len()));
    }
    let survival = |t: f64| -> f64 {
        let mut done = 1.0;
        for (members, need) in groups {
            let ps: Vec<f64> =
                members.iter().map(|&w| finish_cdf(&runtimes[w], t)).collect();
            done *= prob_at_least(&ps, *need);
            if done == 0.0 {
                break;
            }
        }
        1.0 - done
    };
    let n = runtimes.len() as f64;
    let scale = runtimes
        .iter()
        .map(|rt| rt.shift + rt.mean_random() * (1.0 + n.ln()))
        .fold(0.0f64, f64::max);
    let slowest_rate = runtimes
        .iter()
        .map(|rt| rt.a.min(rt.b))
        .fold(f64::INFINITY, f64::min);
    // E[T] = ∫₀^∞ P(not finished by t) dt for the nonnegative stop time.
    integrate_tail(survival, scale, slowest_rate, 1e-9)
}

/// Sample one iteration's stop time under the same rule (Monte-Carlo
/// cross-check for [`expected_rule_time`] and the planner tests).
pub fn sample_rule_time(
    runtimes: &[WorkerRuntime],
    groups: &[(Vec<usize>, usize)],
    rng: &mut Pcg64,
) -> f64 {
    let finish: Vec<f64> = runtimes
        .iter()
        .map(|rt| {
            rt.shift
                + Exponential::new(rt.a).sample(rng)
                + Exponential::new(rt.b).sample(rng)
        })
        .collect();
    groups
        .iter()
        .map(|(members, need)| {
            let mut ts: Vec<f64> = members.iter().map(|&w| finish[w]).collect();
            ts.sort_by(|a, b| a.total_cmp(b));
            ts[need - 1]
        })
        .fold(0.0f64, f64::max)
}

/// Mean of [`sample_rule_time`] over `iters` draws.
pub fn mean_rule_time_mc(
    runtimes: &[WorkerRuntime],
    groups: &[(Vec<usize>, usize)],
    iters: usize,
    seed: u64,
) -> f64 {
    let mut rng = Pcg64::seed_from_u64(seed);
    (0..iters).map(|_| sample_rule_time(runtimes, groups, &mut rng)).sum::<f64>()
        / iters as f64
}

/// Predicted expected iteration time of a built [`HeteroCode`] on its
/// own fleet: per-worker runtimes from the code's compute units and
/// speeds, stopping per its group quorums. This is the number the
/// virtual cluster realizes (same delay scaling, same stopping rule).
pub fn expected_hetero_time(params: &DelayParams, code: &HeteroCode) -> f64 {
    let n = code.config().n;
    let m = code.config().m;
    let speeds = code.speeds();
    let runtimes: Vec<WorkerRuntime> = (0..n)
        .map(|w| worker_runtime(params, m, code.compute_units(w), speeds[w]))
        .collect();
    // A code without group structure degrades to the flat wait-for-(n-s) rule.
    let groups = code
        .group_quorums()
        .unwrap_or_else(|| vec![((0..n).collect(), n - code.config().s)]);
    expected_rule_time(&runtimes, &groups)
}

/// Predicted expected iteration time of a *uniform-load* scheme
/// `(d, s, m)` on a heterogeneous fleet: every worker computes `d`
/// baseline subsets at its own speed, the master waits for `n - s`.
/// With all speeds 1 this reproduces Eq. 28–29
/// ([`super::order_stats::expected_total_runtime`]).
pub fn expected_fleet_time(
    params: &DelayParams,
    speeds: &[f64],
    d: usize,
    s: usize,
    m: usize,
) -> f64 {
    let n = speeds.len();
    assert!(s < n);
    let runtimes: Vec<WorkerRuntime> = speeds
        .iter()
        .map(|&sp| worker_runtime(params, m, d as f64, sp))
        .collect();
    expected_rule_time(&runtimes, &[((0..n).collect(), n - s)])
}

/// §VI-model expected per-iteration wait time for an arbitrary fleet
/// (per-worker `work` units at per-worker `speed`) under an arbitrary
/// group-quorum stopping rule. This is the telemetry layer's
/// model-deviation hook: the trainer evaluates it with the exact
/// speeds, loads, and wait rule of the live run, and the
/// [`StragglerReport`](crate::obs::StragglerReport) compares it against
/// the realized mean iteration time.
pub fn expected_wait_time(
    params: &DelayParams,
    m: usize,
    work: &[f64],
    speeds: &[f64],
    groups: &[(Vec<usize>, usize)],
) -> f64 {
    assert_eq!(work.len(), speeds.len());
    let runtimes: Vec<WorkerRuntime> = work
        .iter()
        .zip(speeds)
        .map(|(&w, &sp)| worker_runtime(params, m, w, sp))
        .collect();
    expected_rule_time(&runtimes, groups)
}

/// Planner search bounds.
#[derive(Debug, Clone, Copy)]
pub struct PlanOpts {
    /// Maximum number of speed groups to consider.
    pub max_groups: usize,
    /// Maximum number of candidate cut positions (quantiles + the
    /// largest speed jumps) considered between groups.
    pub cut_candidates: usize,
}

impl Default for PlanOpts {
    fn default() -> Self {
        PlanOpts { max_groups: 3, cut_candidates: 8 }
    }
}

/// The planner's output: a deployable group plan plus its predictions.
#[derive(Debug, Clone)]
pub struct LoadPlan {
    /// Group plan, slowest group first (feed to
    /// [`HeteroCode::from_groups`] to deploy).
    pub groups: Vec<GroupPlan>,
    /// Per-worker subset loads `d_w`.
    pub loads: Vec<usize>,
    /// Per-worker compute units (row-weighted load + per-subset
    /// overhead) — what the delay model charges each worker.
    pub work: Vec<f64>,
    /// Predicted expected iteration time of the plan (exact model).
    pub expected_time: f64,
    /// Predicted expected iteration time of uniform-load tight §III
    /// `(d = s + m)` on the same fleet.
    pub uniform_time: f64,
    /// `uniform_time / expected_time` (> 1 means the plan wins).
    pub speedup: f64,
}

/// Cheap surrogate objective used inside the coordinate-descent search:
/// every group is approximated as i.i.d. at its mean speed, so each
/// group's completion is a classical order statistic and the iteration
/// time is bounded below by the worst group's expectation.
fn surrogate_time(
    params: &DelayParams,
    m: usize,
    mean_speed: &[f64],
    sizes: &[usize],
    ds: &[usize],
) -> f64 {
    let ws = balanced_group_weights(mean_speed, sizes, ds);
    let mut worst = 0.0f64;
    for (((&ng, &sp), &d), &w) in sizes.iter().zip(mean_speed).zip(ds).zip(&ws) {
        let work = d as f64 * (w + SUBSET_OVERHEAD);
        let rt = worker_runtime(params, m, work, sp);
        let need = ng - (d - m);
        // need-th order statistic of ng i.i.d. draws = (ng - s)-th with
        // s = ng - need.
        let e = rt.shift + expected_order_stat(&rt, ng, ng - need);
        worst = worst.max(e);
    }
    worst
}

/// Exact model evaluation of a candidate plan (per-worker speeds, group
/// rule, Poisson–binomial quadrature).
fn exact_time(
    params: &DelayParams,
    m: usize,
    speeds: &[f64],
    partition: &[Vec<usize>],
    ds: &[usize],
    ws: &[f64],
) -> f64 {
    let runtimes: Vec<WorkerRuntime> = {
        let mut rts = vec![None; speeds.len()];
        for ((members, &d), &w) in partition.iter().zip(ds).zip(ws) {
            for &wk in members {
                let work = d as f64 * (w + SUBSET_OVERHEAD);
                rts[wk] = Some(worker_runtime(params, m, work, speeds[wk]));
            }
        }
        // lint: allow(panic-in-lib) the partition is a contiguous cover of 0..n by construction
        rts.into_iter().map(|r| r.expect("partition covers all")).collect()
    };
    let groups: Vec<(Vec<usize>, usize)> = partition
        .iter()
        .zip(ds)
        .map(|(members, &d)| (members.clone(), members.len() - (d - m)))
        .collect();
    expected_rule_time(&runtimes, &groups)
}

/// Search group partitions and per-group loads for the plan minimizing
/// the predicted expected iteration time on the given fleet. See
/// [`plan_loads_opts`] for the search bounds; the returned plan deploys
/// through [`HeteroCode::from_groups`].
pub fn plan_loads(params: &DelayParams, speeds: &[f64], s: usize, m: usize) -> LoadPlan {
    plan_loads_opts(params, speeds, s, m, PlanOpts::default())
}

/// [`plan_loads`] with explicit search bounds.
///
/// The search enumerates contiguous partitions of the speed-sorted
/// worker list (cut positions restricted to the largest speed jumps and
/// even quantiles, every segment at least `s + m` wide), optimizes the
/// per-group loads `d_g ∈ [s+m, n_g]` by coordinate descent on a cheap
/// i.i.d.-within-group surrogate, then ranks the per-partition winners
/// by the exact Poisson–binomial model.
pub fn plan_loads_opts(
    params: &DelayParams,
    speeds: &[f64],
    s: usize,
    m: usize,
    opts: PlanOpts,
) -> LoadPlan {
    let n = speeds.len();
    assert!(n >= 1 && m >= 1 && s + m <= n, "infeasible (n={n}, s={s}, m={m})");
    assert!(speeds.iter().all(|&x| x.is_finite() && x > 0.0));
    let min_size = s + m;

    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| speeds[a].total_cmp(&speeds[b]).then(a.cmp(&b)));

    // Candidate cut positions in the sorted order: the largest relative
    // speed jumps plus even quantiles.
    let mut cuts: Vec<usize> = Vec::new();
    if n > 1 {
        let mut jumps: Vec<(f64, usize)> = (1..n)
            .map(|i| (speeds[order[i]] / speeds[order[i - 1]], i))
            .collect();
        jumps.sort_by(|a, b| b.0.total_cmp(&a.0));
        for &(ratio, pos) in jumps.iter().take(opts.cut_candidates / 2) {
            if ratio > 1.05 {
                cuts.push(pos);
            }
        }
        let quantiles = opts.cut_candidates - opts.cut_candidates / 2;
        for k in 1..=quantiles {
            cuts.push((k * n / (quantiles + 1)).clamp(1, n - 1));
        }
        cuts.sort_unstable();
        cuts.dedup();
    }

    // Enumerate partitions: choose up to max_groups - 1 cut positions.
    let mut partitions: Vec<Vec<(usize, usize)>> = vec![vec![(0, n)]];
    let mut frontier: Vec<Vec<usize>> = vec![vec![]];
    for _ in 1..opts.max_groups {
        let mut next = Vec::new();
        for chosen in &frontier {
            let lo = chosen.last().map_or(0, |&c| c);
            for &c in cuts.iter().filter(|&&c| c > lo) {
                let mut v = chosen.clone();
                v.push(c);
                next.push(v);
            }
        }
        for chosen in &next {
            let mut segs = Vec::new();
            let mut start = 0;
            for &c in chosen {
                segs.push((start, c));
                start = c;
            }
            segs.push((start, n));
            if segs.iter().all(|&(a, b)| b - a >= min_size) {
                partitions.push(segs);
            }
        }
        frontier = next;
        if frontier.is_empty() {
            break;
        }
    }

    let mut best: Option<(f64, Vec<Vec<usize>>, Vec<usize>, Vec<f64>)> = None;
    for segs in &partitions {
        let partition: Vec<Vec<usize>> = segs
            .iter()
            .map(|&(a, b)| order[a..b].to_vec())
            .collect();
        let sizes: Vec<usize> = partition.iter().map(|p| p.len()).collect();
        let mean_speed: Vec<f64> = partition
            .iter()
            .map(|p| p.iter().map(|&w| speeds[w]).sum::<f64>() / p.len() as f64)
            .collect();
        // Coordinate descent on the surrogate from the tight floor.
        let mut ds: Vec<usize> = vec![s + m; sizes.len()];
        let mut cur = surrogate_time(params, m, &mean_speed, &sizes, &ds);
        for _round in 0..4 {
            let mut improved = false;
            for g in 0..ds.len() {
                let keep = ds[g];
                let mut local_best = (cur, keep);
                for d in (s + m)..=sizes[g] {
                    if d == keep {
                        continue;
                    }
                    ds[g] = d;
                    let t = surrogate_time(params, m, &mean_speed, &sizes, &ds);
                    if t < local_best.0 - 1e-12 {
                        local_best = (t, d);
                    }
                }
                ds[g] = local_best.1;
                if ds[g] != keep {
                    improved = true;
                    cur = local_best.0;
                }
            }
            if !improved {
                break;
            }
        }
        let ws = balanced_group_weights(&mean_speed, &sizes, &ds);
        let t = exact_time(params, m, speeds, &partition, &ds, &ws);
        if best.as_ref().map_or(true, |b| t < b.0) {
            best = Some((t, partition, ds, ws));
        }
    }

    // lint: allow(panic-in-lib) the enumeration always yields the trivial single-group partition
    let (expected_time, partition, ds, ws) = best.expect("at least one partition");
    let uniform_time = expected_fleet_time(params, speeds, s + m, s, m);
    let groups: Vec<GroupPlan> = partition
        .iter()
        .zip(&ds)
        .zip(&ws)
        .map(|((workers, &d), &weight)| GroupPlan { workers: workers.clone(), d, weight })
        .collect();
    let mut loads = vec![0usize; n];
    let mut work = vec![0.0f64; n];
    for g in &groups {
        for &w in &g.workers {
            loads[w] = g.d;
            work[w] = g.d as f64 * (g.weight + SUBSET_OVERHEAD);
        }
    }
    LoadPlan {
        groups,
        loads,
        work,
        expected_time,
        uniform_time,
        speedup: uniform_time / expected_time,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::order_stats::expected_total_runtime;

    #[test]
    fn expected_wait_time_generalizes_the_fleet_model() {
        let p = DelayParams::table_vi1();
        let speeds = vec![1.0, 1.0, 2.0, 2.0, 4.0, 4.0];
        let (d, s, m) = (3usize, 2usize, 1usize);
        let work = vec![d as f64; speeds.len()];
        let flat = vec![((0..speeds.len()).collect::<Vec<_>>(), speeds.len() - s)];
        let got = expected_wait_time(&p, m, &work, &speeds, &flat);
        let want = expected_fleet_time(&p, &speeds, d, s, m);
        assert!((got - want).abs() < 1e-9, "flat rule must match expected_fleet_time");
        // waiting for fewer responders can only shrink the expectation
        let looser = vec![((0..speeds.len()).collect::<Vec<_>>(), speeds.len() - s - 1)];
        assert!(expected_wait_time(&p, m, &work, &speeds, &looser) <= got);
    }

    #[test]
    fn profiles_materialize_and_parse() {
        assert_eq!(SpeedProfile::Uniform.speeds(4), vec![1.0; 4]);
        let lin = SpeedProfile::Linear { ratio: 3.0 }.speeds(5);
        assert_eq!(lin[0], 1.0);
        assert_eq!(lin[4], 3.0);
        assert!(lin.windows(2).all(|w| w[1] > w[0]));
        let bi = SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 }.speeds(6);
        assert_eq!(bi, vec![1.0, 1.0, 1.0, 4.0, 4.0, 4.0]);
        assert_eq!(SpeedProfile::Linear { ratio: 2.0 }.speeds(1), vec![1.0]);

        assert_eq!(SpeedProfile::parse("uniform").unwrap(), SpeedProfile::Uniform);
        assert_eq!(
            SpeedProfile::parse("linear:3").unwrap(),
            SpeedProfile::Linear { ratio: 3.0 }
        );
        assert_eq!(
            SpeedProfile::parse("bimodal:0.3:5").unwrap(),
            SpeedProfile::Bimodal { slow_frac: 0.3, ratio: 5.0 }
        );
        assert_eq!(
            SpeedProfile::parse("bimodal").unwrap(),
            SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 }
        );
        assert_eq!(
            SpeedProfile::parse("custom:1,2,4").unwrap(),
            SpeedProfile::Custom(vec![1.0, 2.0, 4.0])
        );
        assert!(SpeedProfile::parse("warp").is_err());
        assert!(SpeedProfile::parse("bimodal:1.5").is_err());
        assert!(SpeedProfile::parse("custom:0,-1").is_err());
        assert!(SpeedProfile::parse("linear:x").is_err());
        // the custom length check needs n and must error, not panic
        assert!(SpeedProfile::Custom(vec![1.0, 2.0]).try_speeds(10).is_err());
        assert_eq!(
            SpeedProfile::Custom(vec![1.0, 2.0]).try_speeds(2).unwrap(),
            vec![1.0, 2.0]
        );
        // API-constructed profiles are bounds-checked too, not just parse()
        assert!(SpeedProfile::Custom(vec![0.0, 1.0]).try_speeds(2).is_err());
        assert!(SpeedProfile::Custom(vec![f64::NAN, 1.0]).try_speeds(2).is_err());
        assert!(SpeedProfile::Linear { ratio: -1.0 }.try_speeds(3).is_err());
        assert!(SpeedProfile::Bimodal { slow_frac: 2.0, ratio: 4.0 }
            .try_speeds(3)
            .is_err());
    }

    #[test]
    fn worker_runtime_reduces_to_homogeneous_model() {
        let p = DelayParams::table_vi1();
        let hom = WorkerRuntime::new(&p, 4, 3);
        let het = worker_runtime(&p, 3, 4.0, 1.0);
        assert!((hom.a - het.a).abs() < 1e-15);
        assert!((hom.b - het.b).abs() < 1e-15);
        assert!((hom.shift - het.shift).abs() < 1e-12);
        // 2x speed halves the deterministic compute and doubles the rate
        let fast = worker_runtime(&p, 3, 4.0, 2.0);
        assert!((fast.a - 2.0 * het.a).abs() < 1e-15);
        assert!(fast.shift < het.shift);
    }

    #[test]
    fn prob_at_least_matches_binomial() {
        // identical p: Poisson-binomial = binomial
        let ps = vec![0.3; 5];
        let mut want = 0.0;
        for k in 2..=5u32 {
            let c = [1.0, 5.0, 10.0, 10.0, 5.0, 1.0][k as usize];
            want += c * 0.3f64.powi(k as i32) * 0.7f64.powi(5 - k as i32);
        }
        assert!((prob_at_least(&ps, 2) - want).abs() < 1e-12);
        assert_eq!(prob_at_least(&ps, 0), 1.0);
        assert_eq!(prob_at_least(&ps, 6), 0.0);
        assert!((prob_at_least(&[1.0, 0.0, 1.0], 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn flat_rule_matches_order_stat_quadrature() {
        // All speeds 1, single group waiting for n - s: must reproduce
        // the Eq. 28/29 expectation.
        let p = DelayParams::table_vi1();
        for (d, s, m) in [(1usize, 0usize, 1usize), (4, 1, 3), (8, 7, 1)] {
            let speeds = vec![1.0; 8];
            let got = expected_fleet_time(&p, &speeds, d, s, m);
            let want = expected_total_runtime(&p, 8, d, s, m);
            let rel = (got - want).abs() / want;
            assert!(rel < 1e-4, "(d={d},s={s},m={m}): {got} vs {want}");
        }
    }

    #[test]
    fn monte_carlo_matches_quadrature_on_hetero_rule() {
        let p = DelayParams::ec2_fit();
        let speeds = SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 }.speeds(10);
        let code = HeteroCode::from_speeds(10, 1, 2, &speeds).unwrap();
        let exact = expected_hetero_time(&p, &code);
        let runtimes: Vec<WorkerRuntime> = (0..10)
            .map(|w| worker_runtime(&p, 2, code.compute_units(w), speeds[w]))
            .collect();
        let groups = code.group_quorums().unwrap();
        let mc = mean_rule_time_mc(&runtimes, &groups, 60_000, 42);
        let rel = (mc - exact).abs() / exact;
        assert!(rel < 0.02, "MC {mc:.4} vs quadrature {exact:.4}");
    }

    #[test]
    fn faster_fleet_finishes_faster() {
        let p = DelayParams::table_vi1();
        let slow = expected_fleet_time(&p, &[1.0; 6], 3, 1, 2);
        let fast = expected_fleet_time(&p, &[2.0; 6], 3, 1, 2);
        assert!(fast < slow);
        // skew helps the uniform scheme a little (fast workers leave the
        // tail), but the wait is still dominated by the slow half
        let skew = expected_fleet_time(
            &p,
            &SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 }.speeds(6),
            3,
            1,
            2,
        );
        assert!(skew < slow && skew > fast);
    }

    #[test]
    fn planner_beats_uniform_on_bimodal() {
        let p = DelayParams::ec2_fit();
        let speeds = SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 }.speeds(10);
        let plan = plan_loads(&p, &speeds, 1, 2);
        assert!(
            plan.speedup > 1.15,
            "planner should clearly beat uniform on a bimodal fleet: {:?}",
            plan.speedup
        );
        assert!(plan.expected_time < plan.uniform_time);
        // plan is deployable and consistent
        let code = HeteroCode::from_groups(1, 2, &speeds, &plan.groups).unwrap();
        assert_eq!(code.loads(), plan.loads);
        for (got, want) in (0..10).map(|w| (code.compute_units(w), plan.work[w])) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
        // every load respects the Theorem-1 floor
        assert!(plan.loads.iter().all(|&d| d >= 3));
        // the deployed prediction matches the planner's number
        let deployed = expected_hetero_time(&p, &code);
        assert!((deployed - plan.expected_time).abs() / deployed < 1e-9);
    }

    #[test]
    fn planner_on_uniform_fleet_matches_best_homogeneous_design() {
        // On a homogeneous fleet there is no *heterogeneity* to exploit;
        // any margin over tight poly must come from the paper's own
        // replication slack (d > s + m buys straggler tolerance — the
        // §VI optimal-triple effect), never from grouping. So the plan
        // must land within the per-subset overhead of the best
        // single-group homogeneous design at this m.
        let p = DelayParams::table_vi1();
        let (s, m, n) = (1usize, 2usize, 8usize);
        let plan = plan_loads(&p, &vec![1.0; n], s, m);
        let best_hom = (s + m..=n)
            .map(|d| expected_fleet_time(&p, &vec![1.0; n], d, d - m, m))
            .fold(f64::INFINITY, f64::min);
        // (0.97: the planner may interpolate a fractional effective load
        // via subset weights, but the overhead charge keeps it from
        // meaningfully undercutting the homogeneous frontier.)
        assert!(
            plan.expected_time >= best_hom * 0.97,
            "grouping cannot meaningfully beat the homogeneous optimum on \
             iid workers: plan {} vs best {}",
            plan.expected_time,
            best_hom
        );
        assert!(
            plan.expected_time <= best_hom * 1.15,
            "plan should stay within the overhead margin of the best \
             homogeneous design: plan {} vs best {}",
            plan.expected_time,
            best_hom
        );
    }

    #[test]
    fn hetero_prediction_beats_uniform_prediction_for_from_speeds_too() {
        // The acceptance comparison: the default heuristic (not just the
        // planner) must already beat uniform-load poly on a bimodal fleet.
        let p = DelayParams::ec2_fit();
        let speeds = SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 }.speeds(10);
        let code = HeteroCode::from_speeds(10, 1, 2, &speeds).unwrap();
        let hetero = expected_hetero_time(&p, &code);
        let uniform = expected_fleet_time(&p, &speeds, 3, 1, 2);
        assert!(
            hetero < uniform * 0.9,
            "hetero {hetero:.3} must beat uniform {uniform:.3} by >10%"
        );
    }
}
