//! §VI model extended with worker *silence*: each worker independently
//! fails to answer an iteration with probability `p_silent` (crashes,
//! drops, resets — anything the chaos engine makes silent). Predicts how
//! often a run decodes exactly versus falling off the wait rule onto the
//! degradation ladder, and the expected iteration time under both.
//!
//! The exact-decode fraction is a binomial tail: the iteration stays
//! exact iff at most `s` of the `n` workers are silent, so
//! `P[degraded] = P[Bin(n, p_silent) > s]` ([`degraded_fraction`]).
//! Iteration time comes from Monte-Carlo over the assumption-1–2 delay
//! model: an exact iteration ends at the `(n-s)`-th order statistic of
//! the alive finish times, a degraded one waits for every survivor
//! (the virtual gather collects all of them before decoding).

use crate::rngs::{Pcg64, Rng, ShiftedExponential};
use crate::simulator::DelayParams;

/// Exact probability that more than `s` of `n` independent workers are
/// silent at `p_silent` each — the fraction of iterations the trainer
/// must serve from the degradation ladder instead of an exact decode.
pub fn degraded_fraction(n: usize, s: usize, p_silent: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p_silent), "p_silent must be in [0, 1]");
    assert!(s <= n);
    // 1 - P[Bin(n, p) <= s], with the binomial coefficient built
    // multiplicatively (n is a worker count, overflow is not a concern).
    let mut below = 0.0f64;
    for k in 0..=s.min(n) {
        let mut coeff = 1.0f64;
        for i in 1..=k {
            coeff *= (n - k + i) as f64 / i as f64;
        }
        below += coeff * p_silent.powi(k as i32) * (1.0 - p_silent).powi((n - k) as i32);
    }
    (1.0 - below).clamp(0.0, 1.0)
}

/// Monte-Carlo forecast of a chaos run (see [`forecast`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosForecast {
    /// Mean iteration time over exact and degraded iterations, seconds.
    pub mean_iteration_time: f64,
    /// Fraction of iterations decodable exactly (`>= n - s` alive).
    pub exact_fraction: f64,
    /// Fraction served from the degradation ladder.
    pub degraded_fraction: f64,
}

/// Simulate `iters` iterations of an `(n, d, s, m)` deployment under the
/// assumption-1–2 delay model with each worker silent independently with
/// probability `p_silent`. Deterministic in `seed`.
pub fn forecast(
    params: &DelayParams,
    n: usize,
    d: usize,
    s: usize,
    m: usize,
    p_silent: f64,
    iters: usize,
    seed: u64,
) -> ChaosForecast {
    assert!(n >= 1 && d >= 1 && m >= 1 && s < n && iters >= 1);
    assert!((0.0..=1.0).contains(&p_silent));
    let mut rng = Pcg64::seed_from_u64(seed);
    let comp = ShiftedExponential::new(d as f64 * params.t1, params.lambda1 / d as f64);
    let comm = ShiftedExponential::new(params.t2 / m as f64, m as f64 * params.lambda2);
    let mut total = 0.0f64;
    let mut exact = 0usize;
    let mut finishes = Vec::with_capacity(n);
    for _ in 0..iters {
        finishes.clear();
        for _ in 0..n {
            let silent = rng.next_f64() < p_silent;
            // Sample the finish time unconditionally so the delay stream
            // matches a silence-free run of the same seed (the same
            // convention the worker loop uses).
            let t = comp.sample(&mut rng) + comm.sample(&mut rng);
            if !silent {
                finishes.push(t);
            }
        }
        finishes.sort_by(|a, b| a.total_cmp(b));
        if finishes.len() >= n - s {
            exact += 1;
            total += finishes[n - s - 1];
        } else if let Some(&last) = finishes.last() {
            total += last;
        }
        // zero survivors: the gather returns immediately (time 0)
    }
    ChaosForecast {
        mean_iteration_time: total / iters as f64,
        exact_fraction: exact as f64 / iters as f64,
        degraded_fraction: 1.0 - exact as f64 / iters as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degraded_fraction_matches_hand_computation() {
        // n = 6, s = 2, p = 0.25: tail = 1 - sum_{k<=2} C(6,k) p^k q^(6-k)
        let got = degraded_fraction(6, 2, 0.25);
        assert!((got - 0.16943359375).abs() < 1e-12, "{got}");
    }

    #[test]
    fn degraded_fraction_edges_and_monotonicity() {
        assert_eq!(degraded_fraction(5, 1, 0.0), 0.0);
        assert!((degraded_fraction(5, 1, 1.0) - 1.0).abs() < 1e-12);
        assert_eq!(degraded_fraction(4, 4, 0.9), 0.0, "s = n can never degrade");
        let mut prev = 0.0;
        for i in 0..=10 {
            let f = degraded_fraction(8, 2, i as f64 / 10.0);
            assert!(f >= prev - 1e-12, "tail must grow with p");
            prev = f;
        }
    }

    #[test]
    fn forecast_agrees_with_the_binomial_tail() {
        let p = DelayParams::table_vi1();
        let f = forecast(&p, 6, 4, 2, 2, 0.25, 4000, 7);
        let want = degraded_fraction(6, 2, 0.25);
        assert!(
            (f.degraded_fraction - want).abs() < 0.02,
            "MC {} vs exact {want}",
            f.degraded_fraction
        );
        assert!((f.exact_fraction + f.degraded_fraction - 1.0).abs() < 1e-12);
        assert!(f.mean_iteration_time > 0.0);
    }

    #[test]
    fn forecast_is_deterministic_in_seed() {
        let p = DelayParams::table_vi1();
        let a = forecast(&p, 5, 3, 1, 2, 0.1, 500, 11);
        let b = forecast(&p, 5, 3, 1, 2, 0.1, 500, 11);
        assert_eq!(a, b);
        let c = forecast(&p, 5, 3, 1, 2, 0.1, 500, 12);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn silence_free_forecast_is_all_exact() {
        let p = DelayParams::table_vi1();
        let f = forecast(&p, 6, 3, 1, 2, 0.0, 200, 3);
        assert_eq!(f.exact_fraction, 1.0);
        assert_eq!(f.degraded_fraction, 0.0);
    }
}
