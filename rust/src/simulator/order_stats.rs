//! Order-statistic expectations (Eq. 29).
//!
//! `T_{d,s,m}` is the `(n-s)`-th order statistic of `n` i.i.d. copies of
//! the random part `T`. Its density is
//! `n!/((n-s-1)!·s!) · F(t)^{n-s-1} · (1-F(t))^s · f(t)`,
//! and `E[T_tot] = d·t₁ + t₂/m + ∫ t·dens(t) dt`.

use super::model::{DelayParams, WorkerRuntime};
use super::quadrature::integrate_tail;

/// ln n! via lgamma-free accumulation (n <= a few hundred here).
fn ln_factorial(n: usize) -> f64 {
    (2..=n).map(|i| (i as f64).ln()).sum()
}

/// The combinatorial prefactor `n!/((n-s-1)!·s!)` (in log space to avoid
/// overflow for larger n).
fn order_prefactor(n: usize, s: usize) -> f64 {
    (ln_factorial(n) - ln_factorial(n - s - 1) - ln_factorial(s)).exp()
}

/// Density of the `(n-s)`-th order statistic of the random part.
pub fn order_stat_pdf(w: &WorkerRuntime, n: usize, s: usize, t: f64) -> f64 {
    let f = w.cdf_random(t);
    let pdf = w.pdf_random(t);
    if pdf == 0.0 {
        return 0.0;
    }
    let pre = order_prefactor(n, s);
    pre * f.powi((n - s - 1) as i32) * (1.0 - f).powi(s as i32) * pdf
}

/// `E[T_{d,s,m}]` — expectation of the `(n-s)`-th order statistic.
pub fn expected_order_stat(w: &WorkerRuntime, n: usize, s: usize) -> f64 {
    // Scale: order stats of n samples sit around mean·ln(n) at worst.
    let scale = w.mean_random() * (1.0 + (n as f64).ln());
    integrate_tail(
        |t| t * order_stat_pdf(w, n, s, t),
        scale,
        w.a.min(w.b),
        1e-10,
    )
}

/// Full expected iteration runtime (Eq. 28 expectation):
/// `E[T_tot] = d·t₁ + t₂/m + E[T_{d,s,m}]`.
pub fn expected_total_runtime(params: &DelayParams, n: usize, d: usize, s: usize, m: usize) -> f64 {
    let w = WorkerRuntime::new(params, d, m);
    w.shift + expected_order_stat(&w, n, s)
}

/// Closed form for the computation-dominant extreme (§VI, Eq. 30):
/// `E[T_tot] = d·t₁ + (d/λ₁)·Σ_{i=0}^{n-d} 1/(n-i)` for `m = 1, s = d-1`,
/// ignoring communication. Used as a test oracle.
pub fn computation_dominant_expectation(params: &DelayParams, n: usize, d: usize) -> f64 {
    let sum: f64 = (0..=n - d).map(|i| 1.0 / (n - i) as f64).sum();
    d as f64 * params.t1 + d as f64 / params.lambda1 * sum
}

/// Closed form for the communication-dominant extreme (§VI):
/// `E[T_tot] = t₂/m + (1/(m·λ₂))·Σ_{i=0}^{m-1} 1/(n-i)` for `d = n`,
/// `s = n-m`, ignoring computation.
pub fn communication_dominant_expectation(params: &DelayParams, n: usize, m: usize) -> f64 {
    let sum: f64 = (0..m).map(|i| 1.0 / (n - i) as f64).sum();
    params.t2 / m as f64 + sum / (m as f64 * params.lambda2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefactor_small_values() {
        // n=5, s=1: 5!/3!/1! = 20
        assert!((order_prefactor(5, 1) - 20.0).abs() < 1e-9);
        // n=8, s=0: 8!/7!/0! = 8
        assert!((order_prefactor(8, 0) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn order_stat_pdf_integrates_to_one() {
        let p = DelayParams::table_vi1();
        let w = WorkerRuntime::new(&p, 4, 3);
        let mass = integrate_tail(
            |t| order_stat_pdf(&w, 8, 1, t),
            w.mean_random() * 3.0,
            w.a.min(w.b),
            1e-10,
        );
        assert!((mass - 1.0).abs() < 1e-6, "mass {mass}");
    }

    #[test]
    fn max_order_stat_of_exponentials_harmonic() {
        // Degenerate check: communication rate huge → T ≈ Exp(λ₁/d) alone;
        // s = 0 (wait for all) gives E[max] = (d/λ₁)·H_n.
        let p = DelayParams { lambda1: 1.0, t1: 0.0, lambda2: 1e9, t2: 0.0 };
        let w = WorkerRuntime::new(&p, 1, 1);
        let n = 6;
        let got = expected_order_stat(&w, n, 0);
        let want: f64 = (1..=n).map(|i| 1.0 / i as f64).sum();
        assert!((got - want).abs() < 1e-3, "{got} vs {want}");
    }

    #[test]
    fn computation_dominant_matches_quadrature() {
        // λ₂ huge and t₂ = 0 → communication vanishes; quadrature must
        // match the Eq. 30 closed form.
        let p = DelayParams { lambda1: 0.8, t1: 1.6, lambda2: 1e9, t2: 0.0 };
        for d in [1usize, 3, 8] {
            let n = 8;
            let s = d - 1;
            let got = expected_total_runtime(&p, n, d, s, 1);
            let want = computation_dominant_expectation(&p, n, d);
            assert!((got - want).abs() < 2e-3, "d={d}: {got} vs {want}");
        }
    }

    #[test]
    fn communication_dominant_matches_quadrature() {
        let p = DelayParams { lambda1: 1e9, t1: 0.0, lambda2: 0.1, t2: 6.0 };
        let n = 10;
        for m in [1usize, 2, 5] {
            let got = expected_total_runtime(&p, n, n, n - m, m);
            let want = communication_dominant_expectation(&p, n, m);
            assert!((got - want).abs() < 2e-3, "m={m}: {got} vs {want}");
        }
    }

    #[test]
    fn table_vi1_spot_values() {
        // §VI-A numeric table (n=8, λ₁=.8, λ₂=.1, t₁=1.6, t₂=6), s=d-m:
        // uncoded (1,0,1) = 36.1138; optimum (4,1,3) = 21.3697;
        // best m=1 (8,7,1) = 24.1063.
        let p = DelayParams::table_vi1();
        let cases = [
            (1usize, 0usize, 1usize, 36.1138),
            (4, 1, 3, 21.3697),
            (8, 7, 1, 24.1063),
            (2, 0, 2, 23.1036),
            (8, 0, 8, 42.0638),
        ];
        for (d, s, m, want) in cases {
            let got = expected_total_runtime(&p, 8, d, s, m);
            assert!(
                (got - want).abs() < 5e-4,
                "(d={d},s={s},m={m}): got {got:.4}, paper {want}"
            );
        }
    }
}
