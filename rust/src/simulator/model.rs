//! The §VI per-worker runtime distribution.
//!
//! For a triple `(d, s, m)` a worker's finish time is
//! `d·t₁ + t₂/m + T` where `T = X + Y`, `X ~ Exp(λ₁/d)` (random part of
//! computation) and `Y ~ Exp(m·λ₂)` (random part of communication).
//! `T` is hypoexponential; Eq. 27 gives its CDF for `λ₁/d ≠ m·λ₂` and the
//! Erlang-2 special case otherwise (paper footnote 9).

use crate::rngs::{Exponential, Pcg64, ShiftedExponential};

/// The four delay parameters of the paper's model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DelayParams {
    /// Straggling rate of computation (`λ₁`; smaller = heavier tail).
    pub lambda1: f64,
    /// Minimum per-subset computation time (`t₁`).
    pub t1: f64,
    /// Straggling rate of communication (`λ₂`).
    pub lambda2: f64,
    /// Minimum full-vector communication time (`t₂`).
    pub t2: f64,
}

impl DelayParams {
    /// §VI-A first table: n = 8, λ₁ = 0.8, λ₂ = 0.1, t₁ = 1.6, t₂ = 6.
    pub fn table_vi1() -> Self {
        DelayParams { lambda1: 0.8, t1: 1.6, lambda2: 0.1, t2: 6.0 }
    }

    /// Regime fitted so the model reproduces the paper's §V EC2 headline
    /// numbers (ours ≥23% over best-m=1 and ≥32% over naive at
    /// n ∈ {10,15,20}); used by the Fig. 3 / Fig. 4 benches.
    pub fn ec2_fit() -> Self {
        DelayParams { lambda1: 1.2, t1: 1.0, lambda2: 0.2, t2: 6.0 }
    }

    /// §VI-A second table base: n = 10, λ₁ = 0.6, t₁ = 1.5 (λ₂, t₂ vary).
    pub fn table_vi2_base(lambda2: f64, t2: f64) -> Self {
        DelayParams { lambda1: 0.6, t1: 1.5, lambda2, t2 }
    }

    /// §VI-A third table base: n = 10, λ₂ = 0.1, t₂ = 6 (λ₁, t₁ vary).
    pub fn table_vi3_base(lambda1: f64, t1: f64) -> Self {
        DelayParams { lambda1, t1, lambda2: 0.1, t2: 6.0 }
    }
}

/// Distribution of a single worker's runtime under `(d, m)`.
#[derive(Debug, Clone, Copy)]
pub struct WorkerRuntime {
    /// Rate of the computation exponential: `a = λ₁/d`.
    pub a: f64,
    /// Rate of the communication exponential: `b = m·λ₂`.
    pub b: f64,
    /// Deterministic offset `d·t₁ + t₂/m`.
    pub shift: f64,
}

impl WorkerRuntime {
    pub fn new(params: &DelayParams, d: usize, m: usize) -> Self {
        assert!(d >= 1 && m >= 1);
        WorkerRuntime {
            a: params.lambda1 / d as f64,
            b: m as f64 * params.lambda2,
            shift: d as f64 * params.t1 + params.t2 / m as f64,
        }
    }

    /// CDF of the *random part* `T` (Eq. 27), `t >= 0`.
    pub fn cdf_random(&self, t: f64) -> f64 {
        if t <= 0.0 {
            return 0.0;
        }
        let (a, b) = (self.a, self.b);
        if (a - b).abs() > 1e-9 * a.max(b) {
            let v = 1.0 - a / (a - b) * (-b * t).exp() - b / (b - a) * (-a * t).exp();
            v.clamp(0.0, 1.0)
        } else {
            // Erlang(2, a)
            let v = 1.0 - (-a * t).exp() - a * t * (-a * t).exp();
            v.clamp(0.0, 1.0)
        }
    }

    /// PDF of the random part.
    pub fn pdf_random(&self, t: f64) -> f64 {
        if t < 0.0 {
            return 0.0;
        }
        let (a, b) = (self.a, self.b);
        if (a - b).abs() > 1e-9 * a.max(b) {
            (a * b / (a - b) * ((-b * t).exp() - (-a * t).exp())).max(0.0)
        } else {
            a * a * t * (-a * t).exp()
        }
    }

    /// Mean of the random part (`1/a + 1/b`).
    pub fn mean_random(&self) -> f64 {
        1.0 / self.a + 1.0 / self.b
    }

    /// Sample a full worker runtime (shift + random part).
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.shift + Exponential::new(self.a).sample(rng) + Exponential::new(self.b).sample(rng)
    }

    /// The two shifted-exponential components, for event-level simulation
    /// (compute finish vs message arrival are separate events).
    pub fn components(&self, params: &DelayParams, d: usize, m: usize) -> (ShiftedExponential, ShiftedExponential) {
        let comp = ShiftedExponential::new(d as f64 * params.t1, params.lambda1 / d as f64);
        let comm = ShiftedExponential::new(params.t2 / m as f64, m as f64 * params.lambda2);
        (comp, comm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Pcg64;
    use crate::simulator::quadrature::integrate_tail;

    #[test]
    fn cdf_is_monotone_and_limits() {
        let w = WorkerRuntime::new(&DelayParams::table_vi1(), 4, 3);
        assert_eq!(w.cdf_random(0.0), 0.0);
        let mut prev = 0.0;
        for i in 1..200 {
            let t = i as f64 * 0.5;
            let c = w.cdf_random(t);
            assert!(c >= prev - 1e-12, "CDF must be monotone");
            prev = c;
        }
        assert!(prev > 0.999);
    }

    #[test]
    fn pdf_integrates_to_one() {
        let w = WorkerRuntime::new(&DelayParams::table_vi1(), 2, 2);
        let mass = integrate_tail(
            |t| w.pdf_random(t),
            w.mean_random(),
            w.a.min(w.b),
            1e-10,
        );
        assert!((mass - 1.0).abs() < 1e-6, "mass {mass}");
    }

    #[test]
    fn pdf_mean_matches_formula() {
        let w = WorkerRuntime::new(&DelayParams::table_vi1(), 3, 1);
        let mean = integrate_tail(
            |t| t * w.pdf_random(t),
            w.mean_random(),
            w.a.min(w.b),
            1e-10,
        );
        assert!((mean - w.mean_random()).abs() < 1e-5, "{mean} vs {}", w.mean_random());
    }

    #[test]
    fn erlang_branch_taken_when_rates_equal() {
        // λ₁/d = m·λ₂ → Erlang(2). Pick params to force equality.
        let p = DelayParams { lambda1: 0.8, t1: 1.0, lambda2: 0.1, t2: 1.0 };
        let w = WorkerRuntime::new(&p, 4, 2); // a = 0.2, b = 0.2
        assert!((w.a - w.b).abs() < 1e-15);
        let mass = integrate_tail(|t| w.pdf_random(t), w.mean_random(), w.a, 1e-10);
        assert!((mass - 1.0).abs() < 1e-6);
    }

    #[test]
    fn sample_mean_matches_analytic() {
        let p = DelayParams::table_vi1();
        let w = WorkerRuntime::new(&p, 4, 3);
        let mut rng = Pcg64::seed_from_u64(5);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| w.sample(&mut rng)).sum::<f64>() / n as f64;
        let want = w.shift + w.mean_random();
        assert!((mean - want).abs() < 0.05, "{mean} vs {want}");
    }
}
