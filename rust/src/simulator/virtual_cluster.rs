//! Virtual-time cluster: Monte-Carlo event simulation of coded iterations.
//!
//! The paper measured wall-clock on EC2 t2.micro workers; offline we
//! synthesize worker delays from the paper's own §VI model (assumptions
//! 1–3: per-worker computation time `d·T⁽¹⁾`, communication time
//! `T⁽²⁾/m`, i.i.d. shifted exponentials) while the coding path (encode,
//! straggler cutoff, decode) runs for real. The virtual clock advances to
//! the `(n-s)`-th finish event each iteration, which is what Fig. 3 and
//! Fig. 4 plot on their time axes.

use crate::rngs::{Pcg64, ShiftedExponential};

/// One simulated iteration: per-worker finish times plus the responders.
#[derive(Debug, Clone)]
pub struct ClusterSample {
    /// Finish time (computation + communication) per worker.
    pub finish: Vec<f64>,
    /// Worker ids sorted by finish time (fastest first).
    pub order: Vec<usize>,
    /// Time at which the master has `n - s` results (iteration runtime).
    pub iteration_time: f64,
}

impl ClusterSample {
    /// The first `count` responders (sorted by arrival).
    pub fn responders(&self, count: usize) -> Vec<usize> {
        let mut r: Vec<usize> = self.order[..count].to_vec();
        r.sort_unstable();
        r
    }

    /// The stragglers (everyone after the cutoff).
    pub fn stragglers(&self, wait_for: usize) -> Vec<usize> {
        let mut r: Vec<usize> = self.order[wait_for..].to_vec();
        r.sort_unstable();
        r
    }
}

/// Samples iteration timings for a fixed `(n, d, s, m)` design.
#[derive(Debug, Clone)]
pub struct VirtualCluster {
    n: usize,
    wait_for: usize,
    comp: ShiftedExponential,
    comm: ShiftedExponential,
    rng: Pcg64,
}

impl VirtualCluster {
    /// `params` are the paper's delay parameters; `d`/`m` scale them per
    /// assumptions 1–2.
    pub fn new(
        params: &super::model::DelayParams,
        n: usize,
        d: usize,
        s: usize,
        m: usize,
        seed: u64,
    ) -> Self {
        assert!(d >= 1 && m >= 1 && s < n);
        VirtualCluster {
            n,
            wait_for: n - s,
            comp: ShiftedExponential::new(d as f64 * params.t1, params.lambda1 / d as f64),
            comm: ShiftedExponential::new(params.t2 / m as f64, m as f64 * params.lambda2),
            rng: Pcg64::seed_from_u64(seed),
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn wait_for(&self) -> usize {
        self.wait_for
    }

    /// Simulate one iteration.
    pub fn sample_iteration(&mut self) -> ClusterSample {
        let finish: Vec<f64> = (0..self.n)
            .map(|_| self.comp.sample(&mut self.rng) + self.comm.sample(&mut self.rng))
            .collect();
        let mut order: Vec<usize> = (0..self.n).collect();
        order.sort_by(|&a, &b| finish[a].total_cmp(&finish[b]));
        let iteration_time = finish[order[self.wait_for - 1]];
        ClusterSample { finish, order, iteration_time }
    }

    /// Mean iteration time over `iters` simulated iterations.
    ///
    /// Trials run in fixed blocks of [`MC_CHUNK`] across [`crate::pool`]:
    /// each block gets its own RNG stream forked from `self.rng` (fork
    /// order = block order, a function of `iters` alone), block sums
    /// combine through [`crate::pool::tree_combine`]'s fixed tree, so
    /// the estimate is bitwise identical for any thread count. Note the
    /// trial streams therefore differ from (but are statistically
    /// equivalent to) drawing all `iters` samples from one stream.
    pub fn mean_iteration_time(&mut self, iters: usize) -> f64 {
        if iters == 0 {
            return 0.0;
        }
        let n_chunks = (iters + MC_CHUNK - 1) / MC_CHUNK;
        // Fork one child stream per block up front — sequentially, so
        // the parent stream advances the same way regardless of how the
        // blocks are later scheduled.
        let children: Vec<Pcg64> =
            (0..n_chunks).map(|c| self.rng.fork(c as u64)).collect();
        let proto = self.clone();
        let sums: Vec<f64> = crate::pool::global().map_indexed(n_chunks, |c| {
            let mut vc = proto.clone();
            vc.rng = children[c].clone();
            let trials = MC_CHUNK.min(iters - c * MC_CHUNK);
            (0..trials).map(|_| vc.sample_iteration().iteration_time).sum::<f64>()
        });
        crate::pool::tree_combine(sums, |a, b| a + b).unwrap_or(0.0) / iters as f64
    }
}

/// Monte-Carlo trials per parallel block. The block grid (and the fork
/// schedule of per-block RNG streams) depends only on the trial count,
/// never the thread count.
pub const MC_CHUNK: usize = 2048;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::model::DelayParams;
    use crate::simulator::order_stats::expected_total_runtime;

    #[test]
    fn sample_orders_are_consistent() {
        let p = DelayParams::table_vi1();
        let mut vc = VirtualCluster::new(&p, 8, 4, 1, 3, 1);
        for _ in 0..100 {
            let s = vc.sample_iteration();
            assert_eq!(s.finish.len(), 8);
            // order sorted by finish
            for w in s.order.windows(2) {
                assert!(s.finish[w[0]] <= s.finish[w[1]]);
            }
            // iteration time = (n-s)-th smallest
            assert_eq!(s.iteration_time, s.finish[s.order[6]]);
            // responders + stragglers partition workers
            let mut all = s.responders(7);
            all.extend(s.stragglers(7));
            all.sort_unstable();
            assert_eq!(all, (0..8).collect::<Vec<_>>());
        }
    }

    #[test]
    fn monte_carlo_matches_quadrature() {
        // The simulated mean iteration time must converge to the Eq. 28/29
        // expectation computed by quadrature.
        let p = DelayParams::table_vi1();
        for (d, s, m) in [(1usize, 0usize, 1usize), (4, 1, 3), (8, 7, 1)] {
            let mut vc = VirtualCluster::new(&p, 8, d, s, m, 42);
            let mc = vc.mean_iteration_time(60_000);
            let exact = expected_total_runtime(&p, 8, d, s, m);
            let rel = (mc - exact).abs() / exact;
            assert!(rel < 0.02, "(d={d},s={s},m={m}): MC {mc:.3} vs exact {exact:.3}");
        }
    }

    #[test]
    fn more_stragglers_tolerated_means_faster_iterations() {
        let p = DelayParams::table_vi1();
        // Same d: waiting for fewer workers can only help the clock.
        let mut a = VirtualCluster::new(&p, 8, 4, 0, 4, 7).mean_iteration_time(20_000);
        let mut_b = VirtualCluster::new(&p, 8, 4, 3, 1, 7).mean_iteration_time(20_000);
        // (d=4,s=0,m=4) waits for all 8 but sends 1/4 of the data;
        // (d=4,s=3,m=1) waits for 5 but sends everything. Just sanity-check
        // both are positive and finite; the ordering is parameter-dependent.
        assert!(a.is_finite() && mut_b.is_finite());
        a = a.max(mut_b);
        assert!(a > 0.0);
    }
}
