//! Optimal-triple search and the paper's closed-form extremes.
//!
//! The achievability frontier is `d = s + m` (Eq. 5), so the search space
//! for fixed `n` is `{(d, m) : 1 <= m <= d <= n}` with `s = d - m` —
//! exactly the lower-triangular table of §VI-A. Propositions 1 and 2 are
//! provided both as closed forms and as test oracles for the search.

use super::model::DelayParams;
use super::order_stats::expected_total_runtime;

/// A chosen design point with its predicted expected iteration time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TripleChoice {
    pub d: usize,
    pub s: usize,
    pub m: usize,
    pub expected_runtime: f64,
}

/// Exhaustive search over the tight frontier `s = d - m`.
pub fn optimal_triple(params: &DelayParams, n: usize) -> TripleChoice {
    let mut best: Option<TripleChoice> = None;
    for d in 1..=n {
        for m in 1..=d {
            let s = d - m;
            let e = expected_total_runtime(params, n, d, s, m);
            if best.map_or(true, |b| e < b.expected_runtime) {
                best = Some(TripleChoice { d, s, m, expected_runtime: e });
            }
        }
    }
    best.unwrap_or(TripleChoice { d: 1, s: 0, m: 1, expected_runtime: f64::INFINITY })
}

/// Search restricted to `m = 1` — the best the straggler-only schemes of
/// \[11\]–\[13\] can do (baseline for Fig. 3 / §VI-A comparisons).
pub fn optimal_triple_m1(params: &DelayParams, n: usize) -> TripleChoice {
    let mut best: Option<TripleChoice> = None;
    for d in 1..=n {
        let s = d - 1;
        let e = expected_total_runtime(params, n, d, s, 1);
        if best.map_or(true, |b| e < b.expected_runtime) {
            best = Some(TripleChoice { d, s, m: 1, expected_runtime: e });
        }
    }
    best.unwrap_or(TripleChoice { d: 1, s: 0, m: 1, expected_runtime: f64::INFINITY })
}

/// The naive uncoded scheme: `d = 1, s = 0, m = 1` (wait for everyone).
pub fn naive_choice(params: &DelayParams, n: usize) -> TripleChoice {
    TripleChoice {
        d: 1,
        s: 0,
        m: 1,
        expected_runtime: expected_total_runtime(params, n, 1, 0, 1),
    }
}

/// Proposition 1 (computation-dominant): the optimal `d` is `n` when
/// `λ₁·t₁ < (Σ_{i=2}^n 1/i)/(n-1)` and `1` otherwise.
pub fn prop1_optimal_d(params: &DelayParams, n: usize) -> usize {
    let threshold: f64 = (2..=n).map(|i| 1.0 / i as f64).sum::<f64>() / (n as f64 - 1.0);
    if params.lambda1 * params.t1 < threshold {
        n
    } else {
        1
    }
}

/// Proposition 2 (communication-dominant, large n): the optimal ratio
/// `α = m/n` is the unique root in (0,1) of
/// `α/(1-α) + ln(1-α) = λ₂·t₂`. Solved by bisection.
pub fn optimal_alpha(lambda2: f64, t2: f64) -> f64 {
    let target = lambda2 * t2;
    let h = |a: f64| a / (1.0 - a) + (1.0 - a).ln() - target;
    let (mut lo, mut hi) = (1e-12, 1.0 - 1e-12);
    // h is increasing, h(0)=-target<0, h(1-)=+inf.
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if h(mid) < 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::order_stats::computation_dominant_expectation;

    #[test]
    fn table_vi1_optimum_is_d4_m3() {
        let p = DelayParams::table_vi1();
        let best = optimal_triple(&p, 8);
        assert_eq!((best.d, best.s, best.m), (4, 1, 3));
        assert!((best.expected_runtime - 21.3697).abs() < 5e-4);
    }

    #[test]
    fn table_vi1_best_m1_is_d8() {
        let p = DelayParams::table_vi1();
        let best = optimal_triple_m1(&p, 8);
        assert_eq!((best.d, best.s, best.m), (8, 7, 1));
        assert!((best.expected_runtime - 24.1063).abs() < 5e-4);
    }

    #[test]
    fn improvement_factors_match_paper() {
        // §VI-A: "outperforms the uncoded scheme by 41% and the schemes in
        // [11]-[13] by 11%".
        let p = DelayParams::table_vi1();
        let ours = optimal_triple(&p, 8).expected_runtime;
        let naive = naive_choice(&p, 8).expected_runtime;
        let m1 = optimal_triple_m1(&p, 8).expected_runtime;
        let vs_naive = 1.0 - ours / naive;
        let vs_m1 = 1.0 - ours / m1;
        assert!((vs_naive - 0.41).abs() < 0.01, "vs naive {vs_naive}");
        assert!((vs_m1 - 0.11).abs() < 0.01, "vs m1 {vs_m1}");
    }

    #[test]
    fn prop1_extremes() {
        // Small λ₁t₁ → replicate everything (d = n); large → d = 1.
        let n = 10;
        let fast = DelayParams { lambda1: 0.1, t1: 0.1, lambda2: 1.0, t2: 0.0 };
        assert_eq!(prop1_optimal_d(&fast, n), n);
        let slow = DelayParams { lambda1: 2.0, t1: 2.0, lambda2: 1.0, t2: 0.0 };
        assert_eq!(prop1_optimal_d(&slow, n), 1);
    }

    #[test]
    fn prop1_agrees_with_closed_form_search() {
        // In the computation-dominant regime, searching the closed form
        // (Eq. 30) over d must yield the Prop-1 endpoint.
        let n = 12;
        for (l1, t1) in [(0.3, 0.2), (1.5, 1.2), (0.9, 0.3), (0.8, 1.0)] {
            let p = DelayParams { lambda1: l1, t1, lambda2: 1e9, t2: 0.0 };
            let best_d = (1..=n)
                .min_by(|&a, &b| {
                    computation_dominant_expectation(&p, n, a)
                        .partial_cmp(&computation_dominant_expectation(&p, n, b))
                        .unwrap()
                })
                .unwrap();
            assert_eq!(best_d, prop1_optimal_d(&p, n), "λ₁t₁ = {}", l1 * t1);
        }
    }

    #[test]
    fn optimal_alpha_solves_equation() {
        for (l2, t2) in [(0.1, 6.0), (0.5, 2.0), (1.0, 10.0)] {
            let a = optimal_alpha(l2, t2);
            assert!(a > 0.0 && a < 1.0);
            let lhs = a / (1.0 - a) + (1.0 - a).ln();
            assert!((lhs - l2 * t2).abs() < 1e-9, "α={a}");
        }
    }

    #[test]
    fn optimal_alpha_increases_with_t2() {
        // More fixed communication cost → larger reduction factor.
        let a1 = optimal_alpha(0.1, 2.0);
        let a2 = optimal_alpha(0.1, 20.0);
        assert!(a2 > a1);
    }

    #[test]
    fn table_vi2_spot_cells() {
        // §VI-A second table (n=10, λ₁=0.6, t₁=1.5):
        //   λ₂=0.05, t₂=1.5  → (10,9,1)
        //   λ₂=0.1,  t₂=12   → (4,1,3)
        //   λ₂=0.3,  t₂=1.5  → (1,0,1)
        //   λ₂=0.2,  t₂=48   → (10,6,4)
        let cases = [
            (0.05, 1.5, (10, 9, 1)),
            (0.1, 12.0, (4, 1, 3)),
            (0.3, 1.5, (1, 0, 1)),
            (0.2, 48.0, (10, 6, 4)),
        ];
        for (l2, t2, want) in cases {
            let p = DelayParams::table_vi2_base(l2, t2);
            let best = optimal_triple(&p, 10);
            assert_eq!(
                (best.d, best.s, best.m),
                want,
                "λ₂={l2}, t₂={t2}: got ({},{},{})",
                best.d,
                best.s,
                best.m
            );
        }
    }

    #[test]
    fn table_vi3_spot_cells() {
        // §VI-A third table (n=10, λ₂=0.1, t₂=6):
        //   λ₁=0.5, t₁=1   → (10,8,2);  λ₁=0.8, t₁=1.6 → (4,1,3);
        //   λ₁=0.5, t₁=2.8 → (2,0,2).
        let cases = [
            (0.5, 1.0, (10, 8, 2)),
            (0.8, 1.6, (4, 1, 3)),
            (0.5, 2.8, (2, 0, 2)),
        ];
        for (l1, t1, want) in cases {
            let p = DelayParams::table_vi3_base(l1, t1);
            let best = optimal_triple(&p, 10);
            assert_eq!(
                (best.d, best.s, best.m),
                want,
                "λ₁={l1}, t₁={t1}: got ({},{},{})",
                best.d,
                best.s,
                best.m
            );
        }
    }
}
