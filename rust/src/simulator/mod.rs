//! §VI probabilistic runtime model and the virtual cluster built on it.
//!
//! The paper models per-worker computation time as `d·T⁽¹⁾` with
//! `T⁽¹⁾ ~ t₁ + Exp(λ₁)` and communication time for an `l/m`-dimensional
//! vector as `T⁽²⁾/m` with `T⁽²⁾ ~ t₂ + Exp(λ₂)` (assumptions 1–3).
//! The total per-iteration runtime is the `(n-s)`-th order statistic of
//! the n i.i.d. worker finish times (Eq. 28–29).
//!
//! - [`model`]: the mixture distribution (Eq. 27) and `E[T_tot]`
//!   quadrature — regenerates the §VI-A numeric tables.
//! - [`order_stats`]: generic order-statistic expectation machinery.
//! - [`quadrature`]: adaptive Simpson integrator substrate.
//! - [`optimize`]: optimal `(d, s, m)` search + Propositions 1–2.
//! - [`virtual_cluster`]: Monte-Carlo event simulation used by the Fig. 3
//!   and Fig. 4 benches (and by the coordinator's virtual-time mode).
//! - [`approx`]: the model extended to partial recovery — expected
//!   iteration time and expected decoding residual versus quorum size.
//! - [`hetero`]: the model extended to heterogeneous fleets — per-worker
//!   delay params scaled by speed and load, Poisson–binomial group
//!   quorums, and the [`plan_loads`] load-vector optimizer.
//! - [`chaos`]: the model extended to faulty workers — the exact binomial
//!   fraction of degraded iterations under i.i.d. worker silence and a
//!   Monte-Carlo forecast of expected iteration time on the ladder.
//!
//! # Example: planning a deployment
//!
//! ```
//! use gradcode::simulator::order_stats::expected_total_runtime;
//! use gradcode::simulator::{optimal_triple, DelayParams};
//!
//! // The §VI-A regime: n = 8, λ₁ = 0.8, λ₂ = 0.1, t₁ = 1.6, t₂ = 6.
//! let p = DelayParams::table_vi1();
//! let best = optimal_triple(&p, 8);
//! assert_eq!((best.d, best.s, best.m), (4, 1, 3)); // the paper's optimum
//! let naive = expected_total_runtime(&p, 8, 1, 0, 1);
//! assert!(best.expected_runtime < naive); // coding beats uncoded
//! ```
//!
//! # Example: the approximate-recovery tradeoff
//!
//! ```
//! use gradcode::simulator::approx::expected_runtime_at_quorum;
//! use gradcode::simulator::DelayParams;
//!
//! let p = DelayParams::table_vi1();
//! // Proceeding at 6 of 10 responders is strictly faster than waiting
//! // for all 10 — the price is a nonzero decoding residual.
//! let at6 = expected_runtime_at_quorum(&p, 10, 3, 6);
//! let at10 = expected_runtime_at_quorum(&p, 10, 3, 10);
//! assert!(at6 < at10);
//! ```

pub mod approx;
pub mod chaos;
pub mod hetero;
pub mod model;
pub mod optimize;
pub mod order_stats;
pub mod quadrature;
pub mod virtual_cluster;

pub use approx::{expected_coeff_residual, expected_runtime_at_quorum, QuorumPoint};
pub use chaos::{degraded_fraction, forecast as forecast_chaos, ChaosForecast};
pub use hetero::{
    expected_fleet_time, expected_hetero_time, expected_wait_time, plan_loads,
    plan_loads_opts, LoadPlan, PlanOpts, SpeedProfile,
};
pub use model::{DelayParams, WorkerRuntime};
pub use optimize::{optimal_alpha, optimal_triple, prop1_optimal_d, TripleChoice};
pub use virtual_cluster::{ClusterSample, VirtualCluster};
