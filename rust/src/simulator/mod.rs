//! §VI probabilistic runtime model and the virtual cluster built on it.
//!
//! The paper models per-worker computation time as `d·T⁽¹⁾` with
//! `T⁽¹⁾ ~ t₁ + Exp(λ₁)` and communication time for an `l/m`-dimensional
//! vector as `T⁽²⁾/m` with `T⁽²⁾ ~ t₂ + Exp(λ₂)` (assumptions 1–3).
//! The total per-iteration runtime is the `(n-s)`-th order statistic of
//! the n i.i.d. worker finish times (Eq. 28–29).
//!
//! - [`model`]: the mixture distribution (Eq. 27) and `E[T_tot]`
//!   quadrature — regenerates the §VI-A numeric tables.
//! - [`order_stats`]: generic order-statistic expectation machinery.
//! - [`quadrature`]: adaptive Simpson integrator substrate.
//! - [`optimize`]: optimal `(d, s, m)` search + Propositions 1–2.
//! - [`virtual_cluster`]: Monte-Carlo event simulation used by the Fig. 3
//!   and Fig. 4 benches (and by the coordinator's virtual-time mode).

pub mod model;
pub mod optimize;
pub mod order_stats;
pub mod quadrature;
pub mod virtual_cluster;

pub use model::{DelayParams, WorkerRuntime};
pub use optimize::{optimal_alpha, optimal_triple, prop1_optimal_d, TripleChoice};
pub use virtual_cluster::{ClusterSample, VirtualCluster};
