//! Adaptive Simpson quadrature substrate.
//!
//! The §VI expectations are integrals of smooth, exponentially-decaying
//! densities on `[0, ∞)`; adaptive Simpson with a tail cutoff chosen from
//! the mixture's slowest rate reproduces the paper's tables to ≥ 6
//! significant digits.

/// Adaptive Simpson on `[a, b]` with absolute tolerance `tol`.
pub fn adaptive_simpson(f: &impl Fn(f64) -> f64, a: f64, b: f64, tol: f64) -> f64 {
    let fa = f(a);
    let fb = f(b);
    let m = 0.5 * (a + b);
    let fm = f(m);
    simpson_rec(f, a, b, fa, fb, fm, simpson_est(a, b, fa, fm, fb), tol, 50)
}

fn simpson_est(a: f64, b: f64, fa: f64, fm: f64, fb: f64) -> f64 {
    (b - a) / 6.0 * (fa + 4.0 * fm + fb)
}

#[allow(clippy::too_many_arguments)]
fn simpson_rec(
    f: &impl Fn(f64) -> f64,
    a: f64,
    b: f64,
    fa: f64,
    fb: f64,
    fm: f64,
    whole: f64,
    tol: f64,
    depth: u32,
) -> f64 {
    let m = 0.5 * (a + b);
    let lm = 0.5 * (a + m);
    let rm = 0.5 * (m + b);
    let flm = f(lm);
    let frm = f(rm);
    let left = simpson_est(a, m, fa, flm, fm);
    let right = simpson_est(m, b, fm, frm, fb);
    let delta = left + right - whole;
    if depth == 0 || delta.abs() <= 15.0 * tol {
        left + right + delta / 15.0
    } else {
        simpson_rec(f, a, m, fa, fm, flm, left, tol * 0.5, depth - 1)
            + simpson_rec(f, m, b, fm, fb, frm, right, tol * 0.5, depth - 1)
    }
}

/// Integrate `f` over `[0, ∞)` assuming `f` decays at least exponentially
/// with rate `>= slowest_rate` beyond a few multiples of `scale`. The tail
/// cutoff is chosen so the neglected mass is below `tol`.
pub fn integrate_tail(f: impl Fn(f64) -> f64, scale: f64, slowest_rate: f64, tol: f64) -> f64 {
    assert!(slowest_rate > 0.0 && scale > 0.0);
    // Beyond t*, e^{-rate·t} terms are < tol relative to scale.
    let cutoff = (scale * 10.0).max(-(tol.ln()) / slowest_rate * 4.0);
    // Split at `scale` so the adaptive pass resolves the bump near the
    // mode without wasting evaluations in the tail.
    adaptive_simpson(&f, 0.0, scale, tol * 0.5)
        + adaptive_simpson(&f, scale, cutoff, tol * 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrates_polynomial_exactly() {
        // ∫₀¹ x² dx = 1/3 (Simpson is exact on cubics)
        let got = adaptive_simpson(&|x| x * x, 0.0, 1.0, 1e-12);
        assert!((got - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn integrates_sin() {
        let got = adaptive_simpson(&|x: f64| x.sin(), 0.0, std::f64::consts::PI, 1e-10);
        assert!((got - 2.0).abs() < 1e-9);
    }

    #[test]
    fn exponential_mean_via_tail() {
        // ∫₀^∞ t·λe^{-λt} dt = 1/λ
        let lambda = 0.7;
        let got = integrate_tail(
            |t| t * lambda * (-lambda * t).exp(),
            1.0 / lambda,
            lambda,
            1e-10,
        );
        assert!((got - 1.0 / lambda).abs() < 1e-7, "got {got}");
    }

    #[test]
    fn erlang2_mean_via_tail() {
        // Erlang(2, λ): mean 2/λ
        let lambda = 0.35;
        let got = integrate_tail(
            |t| t * lambda * lambda * t * (-lambda * t).exp(),
            2.0 / lambda,
            lambda,
            1e-10,
        );
        assert!((got - 2.0 / lambda).abs() < 1e-6, "got {got}");
    }
}
