//! §VI runtime model extended to the approximate (partial-recovery)
//! regime: expected iteration time *and* expected decoding residual as a
//! function of the quorum size.
//!
//! In the exact regime the master waits for the `(n-s)`-th order
//! statistic of the worker finish times (Eq. 28–29). With a quorum of
//! `r` responders the wait is simply the `r`-th order statistic of the
//! same i.i.d. distribution, so [`expected_runtime_at_quorum`] reuses
//! the Eq. 29 quadrature with `s = n - r`. Shrinking `r` shortens the
//! tail the master sits on — that is the whole point of approximate
//! gradient coding — but leaves a residual decode error.
//!
//! The residual side has no closed form for arbitrary `(n, d, r)`, but
//! under assumptions 1–3 the worker finish times are i.i.d., so the set
//! of the `r` fastest workers is *uniform* over all `C(n, r)` subsets.
//! [`expected_coeff_residual`] therefore estimates
//! `E_F[ ε(F) ] = E_F[ min_a ‖A_F^T a − 1‖₂ ]` by seeded Monte-Carlo
//! over uniform `r`-subsets, using the same least-squares decoder the
//! live master runs ([`ApproxCode::partial_decode`]) — which is exactly
//! why the prediction agrees with the measured residual on a virtual
//! cluster (asserted in `rust/tests/approx_recovery.rs`).

use super::model::DelayParams;
use super::order_stats::expected_order_stat;
use crate::coding::ApproxCode;
use crate::rngs::{Pcg64, Rng};
use crate::simulator::model::WorkerRuntime;

/// Expected iteration time when the master proceeds at the `r`-th
/// arrival (`1 <= r <= n`) under replication `d` (and `m = 1`, the
/// approximate scheme's communication shape):
/// `E[T] = d·t₁ + t₂ + E[T_(r)]`.
pub fn expected_runtime_at_quorum(params: &DelayParams, n: usize, d: usize, r: usize) -> f64 {
    assert!(r >= 1 && r <= n, "quorum r={r} must be in 1..={n}");
    let w = WorkerRuntime::new(params, d, 1);
    w.shift + expected_order_stat(&w, n, n - r)
}

/// Monte-Carlo estimate of the expected coefficient residual
/// `E_F[ε(F)]` over uniform responder sets of size `r`. Deterministic
/// given `seed`. `samples` in the low thousands is plenty for the small
/// `n` of the paper's experiments (each sample is one `r × r` solve).
pub fn expected_coeff_residual(
    code: &ApproxCode,
    r: usize,
    samples: usize,
    seed: u64,
) -> f64 {
    let n = code.config().n;
    assert!(r >= 1 && r <= n, "quorum r={r} must be in 1..={n}");
    if r == n {
        return 0.0; // full quorum decodes exactly
    }
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut acc = 0.0f64;
    for _ in 0..samples {
        let set = rng.sample_indices(n, r);
        acc += code
            .partial_decode(&set)
            // lint: allow(panic-in-lib) sample_indices(n, r>=1) is non-empty, for which partial_decode is total
            .expect("partial decode is defined for every non-empty responder set")
            .coeff_residual;
    }
    acc / samples as f64
}

/// One row of the quorum tradeoff: what the master buys (time) and pays
/// (residual) by proceeding at `quorum` responders.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuorumPoint {
    /// Responders waited for.
    pub quorum: usize,
    /// Quorum as a fraction of `n`.
    pub fraction: f64,
    /// Predicted expected iteration time (Eq. 28–29 at the `r`-th order
    /// statistic).
    pub expected_time: f64,
    /// Predicted expected coefficient residual `E_F[ε(F)]`.
    pub expected_residual: f64,
}

/// Sweep the full tradeoff curve for a scheme: one [`QuorumPoint`] per
/// quorum size in `1..=n`.
pub fn quorum_tradeoff(
    params: &DelayParams,
    code: &ApproxCode,
    samples: usize,
    seed: u64,
) -> Vec<QuorumPoint> {
    let n = code.config().n;
    let d = code.config().d;
    (1..=n)
        .map(|r| QuorumPoint {
            quorum: r,
            fraction: r as f64 / n as f64,
            expected_time: expected_runtime_at_quorum(params, n, d, r),
            expected_residual: expected_coeff_residual(code, r, samples, seed ^ r as u64),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::order_stats::expected_total_runtime;

    #[test]
    fn quorum_runtime_matches_exact_model_at_n_minus_s() {
        // Waiting for r = n - s responders is the Eq. 28 expectation with
        // m = 1 — the two entry points must agree exactly.
        let p = DelayParams::table_vi1();
        for (n, d, s) in [(8usize, 4usize, 1usize), (10, 3, 2), (6, 2, 0)] {
            let via_quorum = expected_runtime_at_quorum(&p, n, d, n - s);
            let via_exact = expected_total_runtime(&p, n, d, s, 1);
            assert!(
                (via_quorum - via_exact).abs() < 1e-9,
                "(n={n},d={d},s={s}): {via_quorum} vs {via_exact}"
            );
        }
    }

    #[test]
    fn quorum_runtime_is_monotone_in_r() {
        let p = DelayParams::table_vi1();
        let mut prev = 0.0;
        for r in 1..=10usize {
            let t = expected_runtime_at_quorum(&p, 10, 3, r);
            assert!(t > prev, "E[T] must grow with the quorum: r={r} gives {t}");
            prev = t;
        }
    }

    #[test]
    fn residual_zero_at_full_quorum_and_for_full_replication() {
        let code = ApproxCode::new(8, 3, 6).unwrap();
        assert_eq!(expected_coeff_residual(&code, 8, 100, 1), 0.0);
        // d = n: any single responder decodes exactly.
        let full = ApproxCode::new(6, 6, 1).unwrap();
        assert!(expected_coeff_residual(&full, 1, 200, 2) < 1e-9);
    }

    #[test]
    fn residual_shrinks_as_quorum_grows() {
        let code = ApproxCode::new(9, 3, 6).unwrap();
        let res: Vec<f64> =
            (1..=9).map(|r| expected_coeff_residual(&code, r, 2000, 7)).collect();
        for r in 1..res.len() {
            // expectation is provably monotone; the slack covers the
            // Monte-Carlo noise of independent sample sets per r
            assert!(
                res[r] <= res[r - 1] + 0.02,
                "E[residual] must shrink with quorum: {:?}",
                res
            );
        }
        assert!(res[0] > 0.5, "tiny quorums must leave a large residual: {}", res[0]);
        assert_eq!(res[8], 0.0);
    }

    #[test]
    fn tradeoff_sweep_is_consistent() {
        let p = DelayParams::table_vi1();
        let code = ApproxCode::new(6, 2, 4).unwrap();
        let curve = quorum_tradeoff(&p, &code, 300, 3);
        assert_eq!(curve.len(), 6);
        for (i, pt) in curve.iter().enumerate() {
            assert_eq!(pt.quorum, i + 1);
            assert!((pt.fraction - (i + 1) as f64 / 6.0).abs() < 1e-12);
        }
        // time up, residual down along the curve (MC slack on the latter)
        for w in curve.windows(2) {
            assert!(w[1].expected_time > w[0].expected_time);
            assert!(w[1].expected_residual <= w[0].expected_residual + 0.02);
        }
    }
}
