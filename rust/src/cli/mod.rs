//! Declarative command-line parser substrate (no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean switches, defaults,
//! typed accessors, subcommands, and auto-generated `--help` text. Used by
//! the `gradcode` binary, the examples, and every bench harness so each
//! table/figure regenerator exposes its sweep parameters.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Error raised while parsing arguments.
#[derive(Debug)]
pub enum CliError {
    UnknownFlag(String),
    MissingValue(String),
    InvalidValue { flag: String, value: String, reason: String },
    UnknownSubcommand(String),
    HelpRequested,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownFlag(flag) => write!(f, "unknown flag `{flag}` (try --help)"),
            CliError::MissingValue(flag) => write!(f, "flag `--{flag}` expects a value"),
            CliError::InvalidValue { flag, value, reason } => {
                write!(f, "invalid value `{value}` for `--{flag}`: {reason}")
            }
            CliError::UnknownSubcommand(cmd) => {
                write!(f, "unknown subcommand `{cmd}` (try --help)")
            }
            CliError::HelpRequested => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

#[derive(Debug, Clone)]
struct FlagSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_switch: bool,
}

/// Declarative flag set for one command.
#[derive(Debug, Clone, Default)]
pub struct Command {
    name: String,
    about: String,
    flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Command { name: name.into(), about: about.into(), flags: Vec::new() }
    }

    /// Flag taking a value, with a default.
    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_switch: false,
        });
        self
    }

    /// Flag taking a value, required (no default).
    pub fn required(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_switch: false,
        });
        self
    }

    /// Boolean switch (present = true).
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.flags.push(FlagSpec {
            name: name.into(),
            help: help.into(),
            default: Some("false".into()),
            is_switch: true,
        });
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "\nflags:");
        for f in &self.flags {
            let d = match (&f.default, f.is_switch) {
                (_, true) => " [switch]".to_string(),
                (Some(d), _) => format!(" [default: {d}]"),
                (None, _) => " [required]".to_string(),
            };
            let _ = writeln!(s, "  --{:<18} {}{}", f.name, f.help, d);
        }
        s
    }

    /// Parse an argument list (without argv\[0\]).
    pub fn parse(&self, args: &[String]) -> Result<Args, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        for f in &self.flags {
            if let Some(d) = &f.default {
                values.insert(f.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        let mut positional = Vec::new();
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::HelpRequested);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                // `cargo bench` appends `--bench` to every bench binary's
                // argv; tolerate it (criterion-compatible behavior).
                if name == "bench" && !self.flags.iter().any(|f| f.name == "bench") {
                    i += 1;
                    continue;
                }
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| CliError::UnknownFlag(a.clone()))?;
                let value = if spec.is_switch {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    args.get(i)
                        .cloned()
                        .ok_or_else(|| CliError::MissingValue(name.clone()))?
                };
                values.insert(name, value);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        for f in &self.flags {
            if !values.contains_key(&f.name) {
                return Err(CliError::MissingValue(f.name.clone()));
            }
        }
        Ok(Args { values, positional })
    }

    /// Parse `std::env::args()`, printing help and exiting on `--help` or
    /// error.
    pub fn parse_env(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(CliError::HelpRequested) => {
                println!("{}", self.help());
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("error: {e}\n\n{}", self.help());
                std::process::exit(2);
            }
        }
    }
}

/// Parsed argument values with typed accessors.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn get_str(&self, name: &str) -> &str {
        self.values
            .get(name)
            // lint: allow(panic-in-lib) programmer error: the accessor names a flag the command never declared
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get_str(name);
        raw.parse::<T>().map_err(|e| CliError::InvalidValue {
            flag: name.into(),
            value: raw.into(),
            reason: e.to_string(),
        })
    }

    pub fn get_usize(&self, name: &str) -> usize {
        // lint: allow(panic-in-lib) CLI user-input boundary: a malformed flag aborts before any distributed state exists
        self.parse_as(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        // lint: allow(panic-in-lib) CLI user-input boundary: a malformed flag aborts before any distributed state exists
        self.parse_as(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        // lint: allow(panic-in-lib) CLI user-input boundary: a malformed flag aborts before any distributed state exists
        self.parse_as(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.get_str(name) == "true"
    }

    /// Comma-separated usize list, e.g. `--workers 10,15,20`.
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        self.get_str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            // lint: allow(panic-in-lib) CLI user-input boundary: a malformed flag aborts before any distributed state exists
            .map(|s| s.trim().parse().unwrap_or_else(|e| panic!("--{name}: {e}")))
            .collect()
    }

    /// Comma-separated f64 list.
    pub fn get_f64_list(&self, name: &str) -> Vec<f64> {
        self.get_str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            // lint: allow(panic-in-lib) CLI user-input boundary: a malformed flag aborts before any distributed state exists
            .map(|s| s.trim().parse().unwrap_or_else(|e| panic!("--{name}: {e}")))
            .collect()
    }
}

/// Subcommand dispatcher for the main binary.
pub struct App {
    pub name: String,
    pub about: String,
    pub commands: Vec<Command>,
}

impl App {
    pub fn new(name: &str, about: &str) -> Self {
        App { name: name.into(), about: about.into(), commands: Vec::new() }
    }

    pub fn command(mut self, c: Command) -> Self {
        self.commands.push(c);
        self
    }

    pub fn help(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n\nsubcommands:", self.name, self.about);
        for c in &self.commands {
            let _ = writeln!(s, "  {:<14} {}", c.name, c.about);
        }
        let _ = writeln!(s, "\nrun `{} <subcommand> --help` for flags", self.name);
        s
    }

    /// Split argv into (subcommand, parsed args).
    pub fn dispatch(&self, argv: &[String]) -> Result<(String, Args), CliError> {
        let first = argv.first().ok_or(CliError::HelpRequested)?;
        if first == "--help" || first == "-h" {
            return Err(CliError::HelpRequested);
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| &c.name == first)
            .ok_or_else(|| CliError::UnknownSubcommand(first.clone()))?;
        let parsed = cmd.parse(&argv[1..])?;
        Ok((cmd.name.clone(), parsed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("t", "test")
            .flag("n", "10", "workers")
            .flag("rate", "0.5", "rate")
            .switch("verbose", "talk more")
            .required("out", "output path")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&args(&["--out", "x.txt", "--n", "20"])).unwrap();
        assert_eq!(a.get_usize("n"), 20);
        assert_eq!(a.get_f64("rate"), 0.5);
        assert!(!a.get_bool("verbose"));
        assert_eq!(a.get_str("out"), "x.txt");
    }

    #[test]
    fn equals_syntax_and_switch() {
        let a = cmd().parse(&args(&["--out=y", "--rate=1.25", "--verbose"])).unwrap();
        assert_eq!(a.get_f64("rate"), 1.25);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(matches!(cmd().parse(&args(&["--n", "5"])), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(matches!(
            cmd().parse(&args(&["--out", "x", "--bogus", "1"])),
            Err(CliError::UnknownFlag(_))
        ));
    }

    #[test]
    fn list_parsing() {
        let c = Command::new("t", "t").flag("ws", "10,15,20", "worker counts");
        let a = c.parse(&[]).unwrap();
        assert_eq!(a.get_usize_list("ws"), vec![10, 15, 20]);
    }

    #[test]
    fn help_contains_flags() {
        let h = cmd().help();
        assert!(h.contains("--n"));
        assert!(h.contains("[default: 10]"));
        assert!(h.contains("[required]"));
    }

    #[test]
    fn app_dispatch() {
        let app = App::new("g", "x").command(cmd());
        let (name, a) = app.dispatch(&args(&["t", "--out", "z"])).unwrap();
        assert_eq!(name, "t");
        assert_eq!(a.get_str("out"), "z");
        assert!(matches!(
            app.dispatch(&args(&["nope"])),
            Err(CliError::UnknownSubcommand(_))
        ));
    }
}
