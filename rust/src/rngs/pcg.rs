//! PCG-XSL-RR 128/64: O'Neill's permuted congruential generator with a
//! 128-bit LCG state and a 64-bit xorshift-low / random-rotation output
//! permutation. Matches the reference `pcg64` parameterization.

use super::Rng;

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;
const PCG_DEFAULT_INC: u128 = 0x5851_f42d_4c95_7f2d_1405_7b7e_f767_814f;

/// Deterministic 128-bit-state PRNG. `Clone` so experiment harnesses can
/// fork independent, reproducible streams.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // must be odd
}

impl Pcg64 {
    /// Construct from full 128-bit state and stream-selector.
    pub fn new(state: u128, stream: u128) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_add(state);
        rng.step();
        rng
    }

    /// Convenience constructor: expand a 64-bit seed with splitmix64 so
    /// nearby seeds produce unrelated streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let s0 = next() as u128;
        let s1 = next() as u128;
        Pcg64::new((s0 << 64) | s1, PCG_DEFAULT_INC >> 1)
    }

    /// Derive an independent child stream (worker-local RNGs).
    pub fn fork(&mut self, stream_tag: u64) -> Pcg64 {
        let state = ((self.next_u64() as u128) << 64) | self.next_u64() as u128;
        Pcg64::new(state, PCG_DEFAULT_INC.wrapping_add(stream_tag as u128) >> 1)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }
}

impl Rng for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.step();
        let s = self.state;
        // XSL-RR output function.
        let xored = ((s >> 64) as u64) ^ (s as u64);
        let rot = (s >> 122) as u32;
        xored.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Pcg64::seed_from_u64(12345);
        let mut b = Pcg64::seed_from_u64(12345);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Pcg64::seed_from_u64(7);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn mean_of_uniforms_is_half() {
        let mut rng = Pcg64::seed_from_u64(99);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
    }
}
