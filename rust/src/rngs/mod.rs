//! Random-number substrate.
//!
//! The offline build environment ships no `rand` crate, so the library
//! carries its own deterministic PRNG ([`Pcg64`], the PCG-XSL-RR 128/64
//! generator) plus the distribution samplers the paper's experiments need
//! (uniform, normal, exponential, shifted exponential, Zipf, Bernoulli).
//!
//! Every stochastic experiment in the repository takes an explicit seed so
//! that tables and figures regenerate bit-identically.

mod dist;
mod pcg;

pub use dist::{Bernoulli, Exponential, Normal, ShiftedExponential, Zipf};
pub use pcg::Pcg64;

/// Minimal RNG interface used across the crate.
pub trait Rng {
    /// Next raw 64 uniformly random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits: (u >> 11) * 2^-53.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in the open interval `(0, 1)`; safe for `ln()`.
    fn next_f64_open(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                return u;
            }
        }
    }

    /// Uniform integer in `[0, bound)` using Lemire's rejection method.
    fn next_bounded(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        // 128-bit multiply-shift with rejection to remove modulo bias.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    fn next_index(&mut self, bound: usize) -> usize {
        self.next_bounded(bound as u64) as usize
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (straggler sets etc.).
    fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut all: Vec<usize> = (0..n).collect();
        self.shuffle(&mut all);
        all.truncate(k);
        all.sort_unstable();
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seed_from_u64(7);
        for _ in 0..10_000 {
            let u = rng.next_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn bounded_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::seed_from_u64(42);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.next_bounded(10) as usize] += 1;
        }
        for &c in &counts {
            // expected 10_000 per bucket; allow generous slack
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut rng = Pcg64::seed_from_u64(3);
        for _ in 0..100 {
            let s = rng.sample_indices(20, 7);
            assert_eq!(s.len(), 7);
            for w in s.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed_from_u64(9);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
