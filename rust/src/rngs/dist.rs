//! Distribution samplers used by the experiments.
//!
//! The §VI runtime model of the paper draws computation and communication
//! times from *shifted exponential* distributions
//! `Pr(T <= t) = 1 - exp(-λ (t - t0))` for `t >= t0`; the data generator
//! uses Zipf-distributed categorical cardinalities and Bernoulli labels,
//! and the random coding scheme (§IV) needs Gaussians.

use super::Rng;

/// Exponential distribution with rate `lambda` (mean `1/lambda`).
#[derive(Debug, Clone, Copy)]
pub struct Exponential {
    lambda: f64,
}

impl Exponential {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0, "rate must be positive, got {lambda}");
        Exponential { lambda }
    }

    pub fn sample<R: Rng>(&self, rng: &mut R) -> f64 {
        // Inverse-CDF: -ln(U)/λ with U in (0,1).
        -rng.next_f64_open().ln() / self.lambda
    }

    pub fn mean(&self) -> f64 {
        1.0 / self.lambda
    }
}

/// Shifted exponential: constant `shift` plus `Exp(lambda)` — the paper's
/// model for both per-subset computation time and full-vector
/// communication time (§VI assumptions 1–2).
#[derive(Debug, Clone, Copy)]
pub struct ShiftedExponential {
    pub shift: f64,
    pub exp: Exponential,
}

impl ShiftedExponential {
    pub fn new(shift: f64, lambda: f64) -> Self {
        assert!(shift >= 0.0, "shift must be nonnegative, got {shift}");
        ShiftedExponential { shift, exp: Exponential::new(lambda) }
    }

    pub fn sample<R: Rng>(&self, rng: &mut R) -> f64 {
        self.shift + self.exp.sample(rng)
    }

    pub fn mean(&self) -> f64 {
        self.shift + self.exp.mean()
    }

    /// CDF `Pr(T <= t)`.
    pub fn cdf(&self, t: f64) -> f64 {
        if t < self.shift {
            0.0
        } else {
            1.0 - (-(t - self.shift) * self.exp.lambda).exp()
        }
    }

    pub fn lambda(&self) -> f64 {
        self.exp.lambda
    }
}

/// Standard normal via Box–Muller (polar form); caches the spare value.
#[derive(Debug, Clone, Copy, Default)]
pub struct Normal {
    spare: Option<f64>,
}

impl Normal {
    pub fn new() -> Self {
        Normal { spare: None }
    }

    /// Standard normal sample.
    pub fn sample<R: Rng>(&mut self, rng: &mut R) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = 2.0 * rng.next_f64() - 1.0;
            let v = 2.0 * rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with given mean / standard deviation.
    pub fn sample_with<R: Rng>(&mut self, rng: &mut R, mean: f64, std: f64) -> f64 {
        mean + std * self.sample(rng)
    }
}

/// Zipf distribution on `{1, ..., n}` with exponent `a`: used for the
/// synthetic categorical dataset's column cardinalities / value skew
/// (one-hot categorical data such as Amazon Employee Access is heavily
/// skewed).
#[derive(Debug, Clone)]
pub struct Zipf {
    /// Cumulative probabilities, `cum[i] = Pr(X <= i+1)`.
    cum: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, a: f64) -> Self {
        assert!(n > 0, "support must be non-empty");
        assert!(a > 0.0, "exponent must be positive");
        let mut cum = Vec::with_capacity(n);
        let mut total = 0.0;
        for k in 1..=n {
            total += (k as f64).powf(-a);
            cum.push(total);
        }
        for c in cum.iter_mut() {
            *c /= total;
        }
        Zipf { cum }
    }

    /// Sample a value in `{1, ..., n}`.
    pub fn sample<R: Rng>(&self, rng: &mut R) -> usize {
        let u = rng.next_f64();
        // Binary search for the first cum[i] >= u.
        match self.cum.binary_search_by(|c| c.total_cmp(&u)) {
            Ok(i) => i + 1,
            Err(i) => (i + 1).min(self.cum.len()),
        }
    }
}

/// Bernoulli(p).
#[derive(Debug, Clone, Copy)]
pub struct Bernoulli {
    p: f64,
}

impl Bernoulli {
    pub fn new(p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "p must be in [0,1], got {p}");
        Bernoulli { p }
    }

    pub fn sample<R: Rng>(&self, rng: &mut R) -> bool {
        rng.next_f64() < self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Pcg64;

    #[test]
    fn exponential_mean_matches() {
        let mut rng = Pcg64::seed_from_u64(11);
        let d = Exponential::new(0.5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shifted_exponential_support_and_mean() {
        let mut rng = Pcg64::seed_from_u64(12);
        let d = ShiftedExponential::new(1.6, 0.8);
        let n = 100_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let x = d.sample(&mut rng);
            assert!(x >= 1.6);
            mean += x;
        }
        mean /= n as f64;
        assert!((mean - (1.6 + 1.25)).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shifted_exponential_cdf() {
        let d = ShiftedExponential::new(2.0, 1.0);
        assert_eq!(d.cdf(1.0), 0.0);
        assert!((d.cdf(3.0) - (1.0 - (-1.0f64).exp())).abs() < 1e-12);
        assert!(d.cdf(50.0) > 0.999_999);
    }

    #[test]
    fn normal_mean_and_var() {
        let mut rng = Pcg64::seed_from_u64(13);
        let mut nd = Normal::new();
        let n = 200_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = nd.sample(&mut rng);
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.01, "mean {m}");
        assert!((v - 1.0).abs() < 0.02, "var {v}");
    }

    #[test]
    fn zipf_is_skewed_and_in_support() {
        let mut rng = Pcg64::seed_from_u64(14);
        let z = Zipf::new(100, 1.2);
        let mut c1 = 0usize;
        for _ in 0..10_000 {
            let x = z.sample(&mut rng);
            assert!((1..=100).contains(&x));
            if x == 1 {
                c1 += 1;
            }
        }
        // value 1 should dominate under Zipf(1.2)
        assert!(c1 > 2_000, "count of 1s: {c1}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Pcg64::seed_from_u64(15);
        let b = Bernoulli::new(0.3);
        let hits = (0..100_000).filter(|_| b.sample(&mut rng)).count();
        assert!((28_000..32_000).contains(&hits), "hits {hits}");
    }
}
