//! §IV random-matrix construction (Theorem 2).
//!
//! `V` is a Gaussian `(n-s) × n` matrix; for each data subset `t` the
//! coefficient block is `B_t = -R_t S_t^{-1}` where `S_t` (`(n-d)×(n-d)`)
//! and `R_t` (`m×(n-d)`) are the top/bottom row bands of `V` restricted
//! to the circulant-consecutive column window starting at `t`. Stacking
//! `[B_t  I_m]` rows gives a `B` with the same two key properties as the
//! §III construction — identity block columns (Eq. 15) and orthogonality
//! of row-block `t` to the V-columns of workers not holding `D_t` — but
//! with much better conditioning for `n > 20`.
//!
//! Decoding multiplies by `V_F^T (V_F V_F^T)^{-1}`, which is exact for
//! *any* responder set `F` with `|F| >= n-s` (more responders only
//! improve conditioning), unlike the square Vandermonde inverse of §III.

use super::{
    CodingError, DecodeWeights, GradientCode, Placement, SchemeConfig,
};
use crate::linalg::{dot_f64, Lu, Matrix};
use crate::rngs::{Normal, Pcg64};

/// The §IV scheme.
pub struct RandomCode {
    cfg: SchemeConfig,
    placement: Placement,
    /// `(n-s) × n` Gaussian evaluation matrix.
    v: Matrix,
    /// `(m·n) × (n-s)` coefficient matrix.
    b: Matrix,
}

impl RandomCode {
    /// Build with a seeded Gaussian `V`. The one-time `S_t^{-1}` solves are
    /// done in f64 (the paper's remark: construction is offline, so high
    /// precision there is acceptable even if `S_t` is ill-conditioned).
    pub fn new(cfg: SchemeConfig, seed: u64) -> Result<Self, CodingError> {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut normal = Normal::new();
        let rows = cfg.n - cfg.s;
        let v = Matrix::from_fn(rows, cfg.n, |_, _| normal.sample(&mut rng));
        Self::with_v(cfg, v)
    }

    /// Build from an explicit `V` (tests; also how a Vandermonde `V` can be
    /// pushed through the §IV machinery for comparison).
    pub fn with_v(cfg: SchemeConfig, v: Matrix) -> Result<Self, CodingError> {
        let (n, d, s, m) = (cfg.n, cfg.d, cfg.s, cfg.m);
        if v.rows() != n - s || v.cols() != n {
            return Err(CodingError::InvalidConfig(format!(
                "V must be {}x{}, got {}x{}",
                n - s,
                n,
                v.rows(),
                v.cols()
            )));
        }
        let nd = n - d;
        let mut b = Matrix::zeros(m * n, n - s);
        for t in 0..n {
            // circulant-consecutive column window starting at t, width n-d
            let cols: Vec<usize> = (0..nd).map(|j| (t + j) % n).collect();
            let top_rows: Vec<usize> = (0..nd).collect();
            let bot_rows: Vec<usize> = (nd..n - s).collect();
            let s_t = v.submatrix(&top_rows, &cols);
            let r_t = v.submatrix(&bot_rows, &cols);
            // B_t = -R_t S_t^{-1}  ⇔  solve S_t^T X^T = -R_t^T column-wise.
            let s_inv = Lu::factor(&s_t)
                .and_then(|lu| lu.inverse())
                .map_err(|e| CodingError::SingularDecode {
                    available: cols.clone(),
                    source: e,
                })?;
            let b_t = r_t.matmul(&s_inv).scale(-1.0);
            for u in 0..m {
                for j in 0..nd {
                    b[(t * m + u, j)] = b_t[(u, j)];
                }
                // identity block columns (Eq. 15)
                b[(t * m + u, nd + u)] = 1.0;
            }
        }
        Ok(RandomCode { cfg, placement: Placement::cyclic_shifted(n, d), v, b })
    }
}

impl GradientCode for RandomCode {
    fn config(&self) -> &SchemeConfig {
        &self.cfg
    }

    fn placement(&self) -> &Placement {
        &self.placement
    }

    fn encode_coeffs(&self, worker: usize) -> Result<Vec<f64>, CodingError> {
        let (n, m) = (self.cfg.n, self.cfg.m);
        if worker >= n {
            return Err(CodingError::WorkerOutOfRange(worker));
        }
        let vcol = self.v.col(worker);
        let assigned = self.placement.assigned(worker);
        let mut coeffs = Vec::with_capacity(assigned.len() * m);
        for &t in &assigned {
            for u in 0..m {
                coeffs.push(dot_f64(self.b.row(t * m + u), &vcol));
            }
        }
        Ok(coeffs)
    }

    fn decode_weights(&self, available: &[usize]) -> Result<DecodeWeights, CodingError> {
        let (n, d, s, m) = (self.cfg.n, self.cfg.d, self.cfg.s, self.cfg.m);
        let need = n - s;
        if available.len() < need {
            return Err(CodingError::NotEnoughWorkers { need, got: available.len() });
        }
        for &w in available {
            if w >= n {
                return Err(CodingError::WorkerOutOfRange(w));
            }
        }
        // Use ALL available responders: W = G^T (G G^T)^{-1} [cols n-d..].
        let used: Vec<usize> = available.to_vec();
        let g = self.v.select_cols(&used);
        let gram = g.matmul(&g.transpose());
        let lu = Lu::factor(&gram).map_err(|e| CodingError::SingularDecode {
            available: used.clone(),
            source: e,
        })?;
        let mut weights = vec![0.0; used.len() * m];
        let mut e = vec![0.0; need];
        for u in 0..m {
            e[n - d + u] = 1.0;
            let x = lu.solve(&e).map_err(|er| CodingError::SingularDecode {
                available: used.clone(),
                source: er,
            })?;
            e[n - d + u] = 0.0;
            // w_u = G^T x
            for (i, _) in used.iter().enumerate() {
                let mut acc = 0.0;
                for r in 0..need {
                    acc += g[(r, i)] * x[r];
                }
                weights[i * m + u] = acc;
            }
        }
        Ok(DecodeWeights { used, weights, m })
    }

    fn matrix_b(&self) -> Matrix {
        self.b.clone()
    }

    fn matrix_v(&self) -> Matrix {
        self.v.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::decode::sum_gradients;
    use crate::coding::{Decoder, Encoder};
    use crate::rngs::Rng;

    fn scheme(n: usize, s: usize, m: usize, seed: u64) -> RandomCode {
        RandomCode::new(SchemeConfig::tight(n, s, m).unwrap(), seed).unwrap()
    }

    #[test]
    fn b_has_identity_block_columns() {
        let c = scheme(8, 2, 3, 7);
        let b = c.matrix_b();
        let (n, d, m) = (8, 5, 3);
        for t in 0..n {
            for u in 0..m {
                for uu in 0..m {
                    let want = if u == uu { 1.0 } else { 0.0 };
                    assert!((b[(t * m + u, n - d + uu)] - want).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn rows_orthogonal_to_non_holder_columns() {
        let c = scheme(7, 2, 2, 9);
        let bv = c.matrix_b().matmul(&c.matrix_v());
        let m = 2;
        for t in 0..7 {
            for u in 0..m {
                for w in 0..7 {
                    let val = bv[(t * m + u, w)];
                    if !c.placement().is_assigned(w, t) {
                        assert!(val.abs() < 1e-8, "BV[({t},{u}),{w}] = {val}");
                    }
                }
            }
        }
    }

    fn roundtrip_err(code: &RandomCode, l: usize, stragglers: &[usize], seed: u64) -> f64 {
        let cfg = *code.config();
        let mut rng = Pcg64::seed_from_u64(seed);
        let grads: Vec<Vec<f32>> = (0..cfg.n)
            .map(|_| (0..l).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect())
            .collect();
        let mut transmitted = Vec::new();
        for w in 0..cfg.n {
            let enc = Encoder::new(code, w).unwrap();
            let views: Vec<&[f32]> = code
                .placement()
                .assigned(w)
                .iter()
                .map(|&t| grads[t].as_slice())
                .collect();
            transmitted.push(enc.encode(&views).unwrap());
        }
        let available: Vec<usize> = (0..cfg.n).filter(|w| !stragglers.contains(w)).collect();
        let dec = Decoder::new(code, &available).unwrap();
        let fs: Vec<&[f32]> =
            dec.used_workers().iter().map(|&w| transmitted[w].as_slice()).collect();
        let got = dec.decode(&fs).unwrap();
        let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let want = sum_gradients(&views);
        let scale = want.iter().fold(0.0f64, |a, &x| a.max(x.abs() as f64)).max(1e-30);
        got.iter()
            .zip(&want)
            .fold(0.0f64, |a, (&x, &y)| a.max((x as f64 - y as f64).abs()))
            / scale
    }

    #[test]
    fn roundtrip_all_single_straggler_patterns() {
        let code = scheme(6, 1, 2, 21);
        for st in 0..6 {
            let err = roundtrip_err(&code, 24, &[st], 5);
            assert!(err < 1e-3, "straggler {st}: {err}");
        }
    }

    #[test]
    fn roundtrip_with_extra_responders_uses_all() {
        // s=2 but only one worker actually straggles: decode should accept
        // the larger set (n-1 > n-s responders).
        let code = scheme(6, 2, 2, 22);
        let err = roundtrip_err(&code, 24, &[3], 6);
        assert!(err < 1e-3, "err {err}");
    }

    #[test]
    fn stable_at_n30_where_vandermonde_fails() {
        // §IV headline: Gaussian V keeps the scheme numerically stable up
        // to n = 30 for all (d, s, m).
        let code = scheme(30, 3, 3, 23);
        let err = roundtrip_err(&code, 60, &[4, 11, 27], 7);
        assert!(err < 5e-2, "n=30 reconstruction rel err {err}");
    }
}
