//! Gradient-coding core — the paper's primary contribution.
//!
//! Implements both constructions achieving the three-way tradeoff
//! `d >= s + m` (Theorem 1, with `k = n`):
//!
//! - [`PolynomialCode`] — §III recursive-polynomial scheme over a
//!   Vandermonde evaluation matrix (Eq. 8–23, Algorithm 1);
//! - [`RandomCode`] — §IV Gaussian-matrix scheme with
//!   `B_i = -R_i S_i^{-1}` and pseudo-inverse decoding, trading exact
//!   Vandermonde structure for numerical stability (Theorem 2).
//!
//! Both expose the same [`GradientCode`] interface: a *placement* (which
//! data subsets each worker computes), per-worker *encode coefficients*
//! (the dense vector `c_i = B·V_i` restricted to assigned subsets), and
//! *decode weights* turning any admissible set of returned vectors back
//! into the sum gradient.
//!
//! Conventions: all indices are 0-based in code (the paper is 1-based);
//! worker `w`'s transmitted vector has dimension `l/m`; gradients are
//! `f32` payloads while coefficients stay `f64` until the final cast.

mod bounds;
mod decode;
mod encode;
mod placement;
mod poly;
mod random_scheme;
mod stability;
mod uncoded;
mod vandermonde;

pub use bounds::{is_achievable, verify_placement_bound};
pub use decode::{sum_gradients, Decoder};
pub use encode::Encoder;
pub use placement::Placement;
pub use poly::PolynomialCode;
pub use random_scheme::RandomCode;
pub use stability::{
    decode_condition, gamma_gaussian, max_condition_number, reconstruction_error,
    reconstruction_error_f64, StabilityReport,
};
pub use uncoded::UncodedScheme;
pub use vandermonde::{integer_thetas, paper_thetas, vandermonde};

use crate::linalg::Matrix;

/// Scheme parameters. `k = n` throughout (Remark 1: only the ratio `d/k`
/// matters; the library fixes `k = n` like the paper's §III–§VI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchemeConfig {
    /// Number of workers (= number of data subsets).
    pub n: usize,
    /// Data subsets per worker (computation load).
    pub d: usize,
    /// Stragglers tolerated (decode needs any `n - s` workers).
    pub s: usize,
    /// Communication reduction factor (transmit `l/m` instead of `l`).
    pub m: usize,
}

impl SchemeConfig {
    /// Validate against Theorem 1 (`d >= s + m`, with `k = n`) and basic
    /// range constraints.
    pub fn new(n: usize, d: usize, s: usize, m: usize) -> Result<Self, CodingError> {
        if n == 0 || d == 0 || m == 0 {
            return Err(CodingError::InvalidConfig(format!(
                "n, d, m must be positive (n={n}, d={d}, m={m})"
            )));
        }
        if d > n {
            return Err(CodingError::InvalidConfig(format!("d={d} exceeds n={n}")));
        }
        if s >= n {
            return Err(CodingError::InvalidConfig(format!("s={s} must be < n={n}")));
        }
        if d < s + m {
            return Err(CodingError::NotAchievable { n, d, s, m });
        }
        Ok(SchemeConfig { n, d, s, m })
    }

    /// The tight configuration `d = s + m` used everywhere in the paper.
    pub fn tight(n: usize, s: usize, m: usize) -> Result<Self, CodingError> {
        Self::new(n, s + m, s, m)
    }

    /// Number of worker results the master must wait for.
    pub fn wait_for(&self) -> usize {
        self.n - self.s
    }

    /// Check a gradient dimension is compatible (`m | l`).
    pub fn check_dim(&self, l: usize) -> Result<(), CodingError> {
        if l % self.m != 0 {
            return Err(CodingError::DimensionNotDivisible { l, m: self.m });
        }
        Ok(())
    }
}

/// Errors from scheme construction, encoding, or decoding.
#[derive(Debug, thiserror::Error)]
pub enum CodingError {
    #[error("invalid configuration: {0}")]
    InvalidConfig(String),
    #[error("(d={d}, s={s}, m={m}) violates Theorem 1 for n={n}: need d >= s+m")]
    NotAchievable { n: usize, d: usize, s: usize, m: usize },
    #[error("gradient dimension l={l} is not divisible by m={m} (pad with zeros)")]
    DimensionNotDivisible { l: usize, m: usize },
    #[error("need at least {need} worker results, got {got}")]
    NotEnoughWorkers { need: usize, got: usize },
    #[error("worker index {0} out of range")]
    WorkerOutOfRange(usize),
    #[error("decode matrix is singular for worker set {available:?}: {source}")]
    SingularDecode {
        available: Vec<usize>,
        #[source]
        source: crate::linalg::LinalgError,
    },
}

/// Common interface over the §III and §IV constructions.
pub trait GradientCode: Send + Sync {
    fn config(&self) -> &SchemeConfig;

    /// Data-subset placement.
    fn placement(&self) -> &Placement;

    /// Dense coefficient vector for worker `w`, length `d·m`, ordered
    /// `[local subset 0..d][component shift u in 0..m]`; local subset `j`
    /// refers to `placement().assigned(w)[j]`. The worker's transmitted
    /// vector is `f_w[v] = Σ_{j,u} c[j·m+u] · g_{assigned[j]}(v·m+u)`.
    fn encode_coeffs(&self, worker: usize) -> Result<Vec<f64>, CodingError>;

    /// Decode weights for a set of responding workers (must contain at
    /// least `n - s` entries; implementations may use more for stability).
    /// Returns a row-major `(used_workers.len() × m)` weight matrix `W`
    /// and the subset of `available` actually used, such that
    /// `g_sum(v·m+u) = Σ_i W[i·m+u] · f_{used[i]}[v]`.
    fn decode_weights(&self, available: &[usize]) -> Result<DecodeWeights, CodingError>;

    /// Full `(m·n) × (n-s)` encoding matrix `B` (diagnostics/tests).
    fn matrix_b(&self) -> Matrix;

    /// Evaluation matrix `V` (`(n-s) × n`; Vandermonde or Gaussian).
    fn matrix_v(&self) -> Matrix;
}

/// Result of [`GradientCode::decode_weights`].
#[derive(Debug, Clone)]
pub struct DecodeWeights {
    /// Workers whose results the weights refer to (subset of `available`).
    pub used: Vec<usize>,
    /// Row-major `used.len() × m`.
    pub weights: Vec<f64>,
    /// m (columns of `weights`).
    pub m: usize,
}

impl DecodeWeights {
    pub fn weight(&self, i: usize, u: usize) -> f64 {
        self.weights[i * self.m + u]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_accepts_tight_triples() {
        let c = SchemeConfig::tight(5, 1, 2).unwrap();
        assert_eq!(c.d, 3);
        assert_eq!(c.wait_for(), 4);
    }

    #[test]
    fn config_rejects_theorem1_violations() {
        assert!(matches!(
            SchemeConfig::new(5, 2, 2, 1),
            Err(CodingError::NotAchievable { .. })
        ));
        assert!(SchemeConfig::new(5, 3, 2, 1).is_ok());
    }

    #[test]
    fn config_rejects_degenerate() {
        assert!(SchemeConfig::new(0, 1, 0, 1).is_err());
        assert!(SchemeConfig::new(5, 6, 0, 1).is_err());
        assert!(SchemeConfig::new(5, 5, 5, 1).is_err());
        assert!(SchemeConfig::new(5, 3, 0, 0).is_err());
    }

    #[test]
    fn check_dim_divisibility() {
        let c = SchemeConfig::tight(5, 1, 2).unwrap();
        assert!(c.check_dim(10).is_ok());
        assert!(c.check_dim(11).is_err());
    }
}
