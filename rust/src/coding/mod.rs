//! Gradient-coding core — the paper's primary contribution.
//!
//! Implements both constructions achieving the three-way tradeoff
//! `d >= s + m` (Theorem 1, with `k = n`):
//!
//! - [`PolynomialCode`] — §III recursive-polynomial scheme over a
//!   Vandermonde evaluation matrix (Eq. 8–23, Algorithm 1);
//! - [`RandomCode`] — §IV Gaussian-matrix scheme with
//!   `B_i = -R_i S_i^{-1}` and pseudo-inverse decoding, trading exact
//!   Vandermonde structure for numerical stability (Theorem 2).
//!
//! Both expose the same [`GradientCode`] interface: a *placement* (which
//! data subsets each worker computes), per-worker *encode coefficients*
//! (the dense vector `c_i = B·V_i` restricted to assigned subsets), and
//! *decode weights* turning any admissible set of returned vectors back
//! into the sum gradient.
//!
//! Beyond the paper's exact constructions, [`ApproxCode`] implements the
//! *approximate* regime (partial recovery): the master proceeds at a
//! configurable quorum of responders and a least-squares partial decoder
//! returns the minimum-ℓ2-error estimate of the gradient sum together
//! with a computed error bound.
//!
//! For non-identical fleets, [`hetero`] provides [`HeteroCode`]: workers
//! are partitioned into speed groups, each group runs its own §III code
//! over a contiguous slice of the subsets (with group-local load `d_g >=
//! s + m` and subset sizes scaled to the group's speed), and the master
//! sums the per-group exact decodes — still exact under any `s`
//! stragglers, while fast workers carry more data. The homogeneous
//! schemes are the uniform-speed special case (a single group).
//!
//! Conventions: all indices are 0-based in code (the paper is 1-based);
//! worker `w`'s transmitted vector has dimension `l/m`; gradients are
//! `f32` payloads while coefficients stay `f64` until the final cast.
//!
//! # Example: exact recovery (§III scheme)
//!
//! ```
//! use gradcode::coding::{Decoder, Encoder, GradientCode, PolynomialCode, SchemeConfig};
//!
//! // n = 5 workers, tolerate s = 1 straggler, transmit l/m with m = 2;
//! // Theorem 1 forces d = s + m = 3 subsets per worker.
//! let cfg = SchemeConfig::tight(5, 1, 2).unwrap();
//! let code = PolynomialCode::new(cfg).unwrap();
//!
//! // Toy partial gradients g_0..g_4, each of dimension l = 4.
//! let grads: Vec<Vec<f32>> = (0..5).map(|t| vec![t as f32; 4]).collect();
//! let transmitted: Vec<Vec<f32>> = (0..5)
//!     .map(|w| {
//!         let views: Vec<&[f32]> = code
//!             .placement()
//!             .assigned(w)
//!             .iter()
//!             .map(|&t| grads[t].as_slice())
//!             .collect();
//!         Encoder::new(&code, w).unwrap().encode(&views).unwrap()
//!     })
//!     .collect();
//! assert_eq!(transmitted[0].len(), 2); // l/m floats on the wire
//!
//! // Worker 2 straggles; any n - s = 4 responders reconstruct exactly.
//! let dec = Decoder::new(&code, &[0, 1, 3, 4]).unwrap();
//! let fs: Vec<&[f32]> = dec.used_workers().iter().map(|&w| transmitted[w].as_slice()).collect();
//! let sum = dec.decode(&fs).unwrap();
//! assert!((sum[0] - 10.0).abs() < 1e-4); // 0+1+2+3+4
//! ```
//!
//! # Example: approximate recovery (partial decoder)
//!
//! ```
//! use gradcode::coding::{ApproxCode, Decoder, Encoder, GradientCode};
//!
//! // n = 6 workers, replication d = 2, proceed at any 4 responders.
//! let code = ApproxCode::new(6, 2, 4).unwrap();
//! let grads: Vec<Vec<f32>> = (0..6).map(|t| vec![t as f32; 3]).collect();
//! let transmitted: Vec<Vec<f32>> = (0..6)
//!     .map(|w| {
//!         let views: Vec<&[f32]> = code
//!             .placement()
//!             .assigned(w)
//!             .iter()
//!             .map(|&t| grads[t].as_slice())
//!             .collect();
//!         Encoder::new(&code, w).unwrap().encode(&views).unwrap()
//!     })
//!     .collect();
//!
//! // Workers 1 and 4 straggle: least-squares estimate from the rest,
//! // with the decoder reporting its own coefficient residual.
//! let partial = code.partial_decode(&[0, 2, 3, 5]).unwrap();
//! let dec = Decoder::from_weights(&partial.weights);
//! let fs: Vec<&[f32]> = dec.used_workers().iter().map(|&w| transmitted[w].as_slice()).collect();
//! let estimate = dec.decode(&fs).unwrap();
//! assert_eq!(estimate.len(), 3);
//! assert!(partial.coeff_residual >= 0.0);
//!
//! // With everyone responding the same decoder is exact (residual 0).
//! let full = code.partial_decode(&[0, 1, 2, 3, 4, 5]).unwrap();
//! assert!(full.is_exact(1e-12));
//! ```

mod approx;
mod bounds;
mod decode;
mod encode;
pub mod hetero;
mod placement;
mod poly;
mod random_scheme;
mod stability;
mod uncoded;
mod vandermonde;

pub use approx::{ls_partial_decode, quorum_count, ApproxCode, LsDecode, PartialDecode};
pub use bounds::{is_achievable, verify_placement_bound};
pub use decode::{sum_gradients, Decoder};
pub use encode::Encoder;
pub use hetero::{GroupPlan, HeteroCode, SUBSET_OVERHEAD};
pub use placement::Placement;
pub use poly::PolynomialCode;
pub use random_scheme::RandomCode;
pub use stability::{
    decode_condition, gamma_gaussian, max_condition_number, reconstruction_error,
    reconstruction_error_f64, StabilityReport,
};
pub use uncoded::UncodedScheme;
pub use vandermonde::{integer_thetas, paper_thetas, vandermonde};

use crate::linalg::Matrix;

/// Scheme parameters. `k = n` throughout (Remark 1: only the ratio `d/k`
/// matters; the library fixes `k = n` like the paper's §III–§VI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchemeConfig {
    /// Number of workers (= number of data subsets).
    pub n: usize,
    /// Data subsets per worker (computation load).
    pub d: usize,
    /// Stragglers tolerated (decode needs any `n - s` workers).
    pub s: usize,
    /// Communication reduction factor (transmit `l/m` instead of `l`).
    pub m: usize,
}

impl SchemeConfig {
    /// Validate against Theorem 1 (`d >= s + m`, with `k = n`) and basic
    /// range constraints.
    pub fn new(n: usize, d: usize, s: usize, m: usize) -> Result<Self, CodingError> {
        if n == 0 || d == 0 || m == 0 {
            return Err(CodingError::InvalidConfig(format!(
                "n, d, m must be positive (n={n}, d={d}, m={m})"
            )));
        }
        if d > n {
            return Err(CodingError::InvalidConfig(format!("d={d} exceeds n={n}")));
        }
        if s >= n {
            return Err(CodingError::InvalidConfig(format!("s={s} must be < n={n}")));
        }
        if d < s + m {
            return Err(CodingError::NotAchievable { n, d, s, m });
        }
        Ok(SchemeConfig { n, d, s, m })
    }

    /// The tight configuration `d = s + m` used everywhere in the paper.
    pub fn tight(n: usize, s: usize, m: usize) -> Result<Self, CodingError> {
        Self::new(n, s + m, s, m)
    }

    /// Number of worker results the master must wait for.
    pub fn wait_for(&self) -> usize {
        self.n - self.s
    }

    /// Check a gradient dimension is compatible (`m | l`).
    pub fn check_dim(&self, l: usize) -> Result<(), CodingError> {
        if l % self.m != 0 {
            return Err(CodingError::DimensionNotDivisible { l, m: self.m });
        }
        Ok(())
    }
}

/// Errors from scheme construction, encoding, or decoding.
///
/// (`Display`/`Error` are hand-implemented — the offline build carries
/// no `thiserror` derive.)
#[derive(Debug)]
pub enum CodingError {
    InvalidConfig(String),
    NotAchievable { n: usize, d: usize, s: usize, m: usize },
    DimensionNotDivisible { l: usize, m: usize },
    NotEnoughWorkers { need: usize, got: usize },
    WorkerOutOfRange(usize),
    SingularDecode { available: Vec<usize>, source: crate::linalg::LinalgError },
}

impl std::fmt::Display for CodingError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodingError::InvalidConfig(msg) => write!(f, "invalid configuration: {msg}"),
            CodingError::NotAchievable { n, d, s, m } => write!(
                f,
                "(d={d}, s={s}, m={m}) violates Theorem 1 for n={n}: need d >= s+m"
            ),
            CodingError::DimensionNotDivisible { l, m } => write!(
                f,
                "gradient dimension l={l} is not divisible by m={m} (pad with zeros)"
            ),
            CodingError::NotEnoughWorkers { need, got } => {
                write!(f, "need at least {need} worker results, got {got}")
            }
            CodingError::WorkerOutOfRange(w) => write!(f, "worker index {w} out of range"),
            CodingError::SingularDecode { available, source } => {
                write!(f, "decode matrix is singular for worker set {available:?}: {source}")
            }
        }
    }
}

impl std::error::Error for CodingError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CodingError::SingularDecode { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Common interface over the §III and §IV constructions.
pub trait GradientCode: Send + Sync {
    fn config(&self) -> &SchemeConfig;

    /// Data-subset placement.
    fn placement(&self) -> &Placement;

    /// Dense coefficient vector for worker `w`, length `d·m`, ordered
    /// `[local subset 0..d][component shift u in 0..m]`; local subset `j`
    /// refers to `placement().assigned(w)[j]`. The worker's transmitted
    /// vector is `f_w[v] = Σ_{j,u} c[j·m+u] · g_{assigned[j]}(v·m+u)`.
    fn encode_coeffs(&self, worker: usize) -> Result<Vec<f64>, CodingError>;

    /// Decode weights for a set of responding workers (exact schemes
    /// require at least `n - s` entries and may use more for stability;
    /// [`ApproxCode`] accepts any non-empty set). Returns a row-major
    /// `(used_workers.len() × m)` weight matrix `W` and the subset of
    /// `available` actually used, such that
    /// `g_sum(v·m+u) = Σ_i W[i·m+u] · f_{used[i]}[v]`.
    fn decode_weights(&self, available: &[usize]) -> Result<DecodeWeights, CodingError>;

    /// Coefficient-space decoding residual for this responder set:
    /// `None` for exact schemes (decode is exact whenever
    /// `decode_weights` succeeds), `Some(ε)` for approximate schemes
    /// whose estimate satisfies `‖ĝ − g_sum‖₂ ≤ ε·√(Σ_t ‖g_t‖₂²)`.
    fn decode_residual(&self, _available: &[usize]) -> Option<f64> {
        None
    }

    /// Weights and residual in one call — the trainer's per-responder-set
    /// entry point. The default covers exact schemes; [`ApproxCode`]
    /// overrides it so the least-squares system is solved once, not once
    /// per piece.
    fn decode_weights_with_residual(
        &self,
        available: &[usize],
    ) -> Result<(DecodeWeights, Option<f64>), CodingError> {
        Ok((self.decode_weights(available)?, None))
    }

    /// Full `(m·n) × (n-s)` encoding matrix `B` (diagnostics/tests).
    /// Heterogeneous schemes return the block-diagonal composition of
    /// their per-group matrices (column count then differs from `n-s`);
    /// the invariant preserved by every scheme is that `B·V`'s entry
    /// `(t·m+u, w)` is the coefficient of `g_t`'s `u`-component in `f_w`.
    fn matrix_b(&self) -> Matrix;

    /// Evaluation matrix `V` (`(n-s) × n`; Vandermonde or Gaussian).
    fn matrix_v(&self) -> Matrix;

    /// Relative data-subset sizes (mean 1.0): subset `t` holds a
    /// `weights[t]/n`-fraction of the training rows. `None` means the
    /// uniform equal-rows partition every homogeneous scheme uses;
    /// [`HeteroCode`] returns `Some` so fast groups' subsets carry more
    /// rows.
    fn subset_weights(&self) -> Option<Vec<f64>> {
        None
    }

    /// Per-worker compute cost in "baseline subset" units (the unit the
    /// §VI delay model's `t₁`/`λ₁` are expressed in). Homogeneous
    /// schemes: the load `d`. Heterogeneous schemes: the row-weighted
    /// load plus a small per-subset overhead (see
    /// [`SUBSET_OVERHEAD`]).
    fn compute_units(&self, worker: usize) -> f64 {
        self.placement().load(worker) as f64
    }

    /// Group-quorum structure, if the scheme decodes per worker group:
    /// `(members, need)` pairs meaning "the master needs `need`
    /// responders out of `members`". `None` (the default) means the flat
    /// rule "any `n - s` responders". The coordinator uses this to stop
    /// the gather as soon as every group is decodable.
    fn group_quorums(&self) -> Option<Vec<(Vec<usize>, usize)>> {
        None
    }
}

/// Result of [`GradientCode::decode_weights`].
#[derive(Debug, Clone)]
pub struct DecodeWeights {
    /// Workers whose results the weights refer to (subset of `available`).
    pub used: Vec<usize>,
    /// Row-major `used.len() × m`.
    pub weights: Vec<f64>,
    /// m (columns of `weights`).
    pub m: usize,
}

impl DecodeWeights {
    pub fn weight(&self, i: usize, u: usize) -> f64 {
        self.weights[i * self.m + u]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_accepts_tight_triples() {
        let c = SchemeConfig::tight(5, 1, 2).unwrap();
        assert_eq!(c.d, 3);
        assert_eq!(c.wait_for(), 4);
    }

    #[test]
    fn config_rejects_theorem1_violations() {
        assert!(matches!(
            SchemeConfig::new(5, 2, 2, 1),
            Err(CodingError::NotAchievable { .. })
        ));
        assert!(SchemeConfig::new(5, 3, 2, 1).is_ok());
    }

    #[test]
    fn config_rejects_degenerate() {
        assert!(SchemeConfig::new(0, 1, 0, 1).is_err());
        assert!(SchemeConfig::new(5, 6, 0, 1).is_err());
        assert!(SchemeConfig::new(5, 5, 5, 1).is_err());
        assert!(SchemeConfig::new(5, 3, 0, 0).is_err());
    }

    #[test]
    fn check_dim_divisibility() {
        let c = SchemeConfig::tight(5, 1, 2).unwrap();
        assert!(c.check_dim(10).is_ok());
        assert!(c.check_dim(11).is_err());
    }
}
