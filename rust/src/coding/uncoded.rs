//! The naive uncoded baseline (§V "naive scheme"): data divided uniformly
//! with no replication (`d = 1`), every worker transmits its full partial
//! gradient (`m = 1`), and the master must wait for all `n` workers
//! (`s = 0`). Expressed through the [`GradientCode`] interface so the
//! coordinator and benches treat it uniformly.

use super::{
    CodingError, DecodeWeights, GradientCode, Placement, SchemeConfig,
};
use crate::linalg::Matrix;

/// `d = 1, s = 0, m = 1`, identity encode, all-ones decode.
pub struct UncodedScheme {
    cfg: SchemeConfig,
    placement: Placement,
}

impl UncodedScheme {
    pub fn new(n: usize) -> Self {
        UncodedScheme {
            cfg: SchemeConfig { n, d: 1, s: 0, m: 1 },
            placement: Placement::cyclic(n, 1),
        }
    }
}

impl GradientCode for UncodedScheme {
    fn config(&self) -> &SchemeConfig {
        &self.cfg
    }

    fn placement(&self) -> &Placement {
        &self.placement
    }

    fn encode_coeffs(&self, worker: usize) -> Result<Vec<f64>, CodingError> {
        if worker >= self.cfg.n {
            return Err(CodingError::WorkerOutOfRange(worker));
        }
        Ok(vec![1.0])
    }

    fn decode_weights(&self, available: &[usize]) -> Result<DecodeWeights, CodingError> {
        let n = self.cfg.n;
        if available.len() < n {
            return Err(CodingError::NotEnoughWorkers { need: n, got: available.len() });
        }
        let used: Vec<usize> = available[..n].to_vec();
        Ok(DecodeWeights { weights: vec![1.0; n], used, m: 1 })
    }

    fn matrix_b(&self) -> Matrix {
        Matrix::identity(self.cfg.n)
    }

    fn matrix_v(&self) -> Matrix {
        Matrix::identity(self.cfg.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{Decoder, Encoder};

    #[test]
    fn uncoded_roundtrip_is_plain_sum() {
        let code = UncodedScheme::new(4);
        let grads: Vec<Vec<f32>> =
            (0..4).map(|t| vec![t as f32, 2.0 * t as f32, -1.0]).collect();
        let mut fs = Vec::new();
        for w in 0..4 {
            let enc = Encoder::new(&code, w).unwrap();
            fs.push(enc.encode(&[&grads[w]]).unwrap());
            assert_eq!(fs[w], grads[w], "uncoded transmit = own gradient");
        }
        let dec = Decoder::new(&code, &[0, 1, 2, 3]).unwrap();
        let views: Vec<&[f32]> = fs.iter().map(|f| f.as_slice()).collect();
        let got = dec.decode(&views).unwrap();
        assert_eq!(got, vec![0.0 + 1.0 + 2.0 + 3.0, 0.0 + 2.0 + 4.0 + 6.0, -4.0]);
    }

    #[test]
    fn uncoded_cannot_tolerate_stragglers() {
        let code = UncodedScheme::new(4);
        assert!(code.decode_weights(&[0, 1, 2]).is_err());
    }
}
