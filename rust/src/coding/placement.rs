//! Cyclic data placement.
//!
//! §III assigns worker `W_i` the subsets `D_i, D_{i⊕1}, …, D_{i⊕(d-1)}`;
//! §IV's orthogonality pattern corresponds to the rotation
//! `D_{i⊕1}, …, D_{i⊕d}`. Both are cyclic windows; [`Placement`] captures
//! a window of width `d` starting at `w + offset (mod n)`.

/// Cyclic placement of `n` data subsets onto `n` workers, `d` per worker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    n: usize,
    d: usize,
    offset: usize,
}

impl Placement {
    /// §III placement: worker `w` gets subsets `w, w+1, …, w+d-1 (mod n)`.
    pub fn cyclic(n: usize, d: usize) -> Self {
        Placement { n, d, offset: 0 }
    }

    /// §IV placement: worker `w` gets subsets `w+1, …, w+d (mod n)`.
    pub fn cyclic_shifted(n: usize, d: usize) -> Self {
        Placement { n, d, offset: 1 }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Subsets assigned to worker `w`, in local order `0..d`.
    pub fn assigned(&self, w: usize) -> Vec<usize> {
        assert!(w < self.n, "worker {w} out of range (n={})", self.n);
        (0..self.d).map(|j| (w + self.offset + j) % self.n).collect()
    }

    /// Whether subset `t` is assigned to worker `w`.
    pub fn is_assigned(&self, w: usize, t: usize) -> bool {
        // t ∈ {w+offset, …, w+offset+d-1} (mod n)
        let rel = (t + self.n - (w + self.offset) % self.n) % self.n;
        rel < self.d
    }

    /// Workers holding subset `t` (inverse map), ascending.
    pub fn holders(&self, t: usize) -> Vec<usize> {
        (0..self.n).filter(|&w| self.is_assigned(w, t)).collect()
    }

    /// Local index of subset `t` within worker `w`'s assignment, if any.
    pub fn local_index(&self, w: usize, t: usize) -> Option<usize> {
        let rel = (t + self.n - (w + self.offset) % self.n) % self.n;
        (rel < self.d).then_some(rel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cyclic_matches_paper_example() {
        // n=5, d=3 (Fig. 2): W_1 (0-based 0) holds D_1,D_2,D_3 → {0,1,2}.
        let p = Placement::cyclic(5, 3);
        assert_eq!(p.assigned(0), vec![0, 1, 2]);
        assert_eq!(p.assigned(3), vec![3, 4, 0]);
        assert_eq!(p.assigned(4), vec![4, 0, 1]);
    }

    #[test]
    fn shifted_rotates_by_one() {
        let p = Placement::cyclic_shifted(5, 3);
        assert_eq!(p.assigned(0), vec![1, 2, 3]);
        assert_eq!(p.assigned(4), vec![0, 1, 2]);
    }

    #[test]
    fn every_subset_held_by_exactly_d_workers() {
        for n in [3usize, 5, 8, 13] {
            for d in 1..=n {
                let p = Placement::cyclic(n, d);
                for t in 0..n {
                    assert_eq!(p.holders(t).len(), d, "n={n} d={d} t={t}");
                }
            }
        }
    }

    #[test]
    fn local_index_consistent_with_assigned() {
        let p = Placement::cyclic(7, 4);
        for w in 0..7 {
            let a = p.assigned(w);
            for (j, &t) in a.iter().enumerate() {
                assert_eq!(p.local_index(w, t), Some(j));
                assert!(p.is_assigned(w, t));
            }
            for t in 0..7 {
                if !a.contains(&t) {
                    assert_eq!(p.local_index(w, t), None);
                    assert!(!p.is_assigned(w, t));
                }
            }
        }
    }
}
