//! Data placement: which subsets each worker computes.
//!
//! §III assigns worker `W_i` the subsets `D_i, D_{i⊕1}, …, D_{i⊕(d-1)}`;
//! §IV's orthogonality pattern corresponds to the rotation
//! `D_{i⊕1}, …, D_{i⊕d}`. Both are cyclic windows of a *uniform* width
//! `d`. The heterogeneous subsystem ([`crate::coding::HeteroCode`])
//! additionally needs *non-uniform* loads — worker `w` holds `d_w`
//! subsets with `d_w` varying across workers — so [`Placement`] carries
//! either a cyclic window or an explicit per-worker assignment list
//! behind one interface. [`Placement::d`] reports the *maximum*
//! per-worker load; [`Placement::load`] the per-worker one.

/// Placement of `n` data subsets onto `n` workers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    n: usize,
    kind: Kind,
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum Kind {
    /// Cyclic window of uniform width `d` starting at `w + offset`.
    Cyclic { d: usize, offset: usize },
    /// Arbitrary per-worker subset lists (heterogeneous loads).
    Explicit { assigned: Vec<Vec<usize>>, max_load: usize },
}

impl Placement {
    /// §III placement: worker `w` gets subsets `w, w+1, …, w+d-1 (mod n)`.
    pub fn cyclic(n: usize, d: usize) -> Self {
        Placement { n, kind: Kind::Cyclic { d, offset: 0 } }
    }

    /// §IV placement: worker `w` gets subsets `w+1, …, w+d (mod n)`.
    pub fn cyclic_shifted(n: usize, d: usize) -> Self {
        Placement { n, kind: Kind::Cyclic { d, offset: 1 } }
    }

    /// Explicit placement: `assigned[w]` lists worker `w`'s subsets in
    /// local order. There are `assigned.len()` workers over the same
    /// number of subsets (`k = n` as everywhere in the crate); every
    /// subset id must be in range and per-worker lists must be
    /// duplicate-free and non-empty.
    pub fn explicit(assigned: Vec<Vec<usize>>) -> Self {
        let n = assigned.len();
        let mut max_load = 0;
        for (w, list) in assigned.iter().enumerate() {
            assert!(!list.is_empty(), "worker {w} has an empty assignment");
            let mut seen = vec![false; n];
            for &t in list {
                assert!(t < n, "worker {w}: subset {t} out of range (n={n})");
                assert!(!seen[t], "worker {w}: duplicate subset {t}");
                seen[t] = true;
            }
            max_load = max_load.max(list.len());
        }
        Placement { n, kind: Kind::Explicit { assigned, max_load } }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Maximum per-worker load (uniform placements: the common `d`).
    pub fn d(&self) -> usize {
        match &self.kind {
            Kind::Cyclic { d, .. } => *d,
            Kind::Explicit { max_load, .. } => *max_load,
        }
    }

    /// Alias of [`Placement::d`] with the heterogeneous reading.
    pub fn max_load(&self) -> usize {
        self.d()
    }

    /// Number of subsets assigned to worker `w`.
    pub fn load(&self, w: usize) -> usize {
        assert!(w < self.n, "worker {w} out of range (n={})", self.n);
        match &self.kind {
            Kind::Cyclic { d, .. } => *d,
            Kind::Explicit { assigned, .. } => assigned[w].len(),
        }
    }

    /// Total load `Σ_w d_w` (the feasibility side of `Σd_w >= n(s+m)`).
    pub fn total_load(&self) -> usize {
        (0..self.n).map(|w| self.load(w)).sum()
    }

    /// Subsets assigned to worker `w`, in local order `0..load(w)`.
    pub fn assigned(&self, w: usize) -> Vec<usize> {
        assert!(w < self.n, "worker {w} out of range (n={})", self.n);
        match &self.kind {
            Kind::Cyclic { d, offset } => {
                (0..*d).map(|j| (w + offset + j) % self.n).collect()
            }
            Kind::Explicit { assigned, .. } => assigned[w].clone(),
        }
    }

    /// Whether subset `t` is assigned to worker `w`.
    pub fn is_assigned(&self, w: usize, t: usize) -> bool {
        self.local_index(w, t).is_some()
    }

    /// Workers holding subset `t` (inverse map), ascending.
    pub fn holders(&self, t: usize) -> Vec<usize> {
        (0..self.n).filter(|&w| self.is_assigned(w, t)).collect()
    }

    /// Local index of subset `t` within worker `w`'s assignment, if any.
    pub fn local_index(&self, w: usize, t: usize) -> Option<usize> {
        assert!(w < self.n, "worker {w} out of range (n={})", self.n);
        match &self.kind {
            Kind::Cyclic { d, offset } => {
                // t ∈ {w+offset, …, w+offset+d-1} (mod n)
                let rel = (t + self.n - (w + offset) % self.n) % self.n;
                (rel < *d).then_some(rel)
            }
            Kind::Explicit { assigned, .. } => {
                assigned[w].iter().position(|&x| x == t)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cyclic_matches_paper_example() {
        // n=5, d=3 (Fig. 2): W_1 (0-based 0) holds D_1,D_2,D_3 → {0,1,2}.
        let p = Placement::cyclic(5, 3);
        assert_eq!(p.assigned(0), vec![0, 1, 2]);
        assert_eq!(p.assigned(3), vec![3, 4, 0]);
        assert_eq!(p.assigned(4), vec![4, 0, 1]);
    }

    #[test]
    fn shifted_rotates_by_one() {
        let p = Placement::cyclic_shifted(5, 3);
        assert_eq!(p.assigned(0), vec![1, 2, 3]);
        assert_eq!(p.assigned(4), vec![0, 1, 2]);
    }

    #[test]
    fn every_subset_held_by_exactly_d_workers() {
        for n in [3usize, 5, 8, 13] {
            for d in 1..=n {
                let p = Placement::cyclic(n, d);
                for t in 0..n {
                    assert_eq!(p.holders(t).len(), d, "n={n} d={d} t={t}");
                }
            }
        }
    }

    #[test]
    fn local_index_consistent_with_assigned() {
        let p = Placement::cyclic(7, 4);
        for w in 0..7 {
            let a = p.assigned(w);
            for (j, &t) in a.iter().enumerate() {
                assert_eq!(p.local_index(w, t), Some(j));
                assert!(p.is_assigned(w, t));
            }
            for t in 0..7 {
                if !a.contains(&t) {
                    assert_eq!(p.local_index(w, t), None);
                    assert!(!p.is_assigned(w, t));
                }
            }
        }
    }

    #[test]
    fn explicit_placement_supports_uneven_loads() {
        let p = Placement::explicit(vec![
            vec![0, 1],       // worker 0: load 2
            vec![1, 2, 3, 0], // worker 1: load 4
            vec![2],          // worker 2: load 1
            vec![3, 2],       // worker 3: load 2
        ]);
        assert_eq!(p.n(), 4);
        assert_eq!(p.load(0), 2);
        assert_eq!(p.load(1), 4);
        assert_eq!(p.load(2), 1);
        assert_eq!(p.d(), 4, "d() reports the max load");
        assert_eq!(p.max_load(), 4);
        assert_eq!(p.total_load(), 9);
        assert_eq!(p.assigned(1), vec![1, 2, 3, 0]);
        assert_eq!(p.local_index(1, 3), Some(2));
        assert_eq!(p.local_index(0, 3), None);
        assert_eq!(p.holders(2), vec![1, 2, 3]);
        assert!(p.is_assigned(3, 2));
        assert!(!p.is_assigned(0, 2));
    }

    #[test]
    fn explicit_matches_cyclic_when_uniform() {
        let cyc = Placement::cyclic(6, 3);
        let exp = Placement::explicit((0..6).map(|w| cyc.assigned(w)).collect());
        for w in 0..6 {
            assert_eq!(cyc.assigned(w), exp.assigned(w));
            assert_eq!(cyc.load(w), exp.load(w));
            for t in 0..6 {
                assert_eq!(cyc.local_index(w, t), exp.local_index(w, t));
            }
        }
        for t in 0..6 {
            assert_eq!(cyc.holders(t), exp.holders(t));
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn explicit_rejects_out_of_range_subset() {
        let _ = Placement::explicit(vec![vec![0], vec![2]]);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn explicit_rejects_duplicate_subset() {
        let _ = Placement::explicit(vec![vec![0, 0], vec![1]]);
    }
}
