//! Master-side decoding: reconstruct the sum gradient from the first
//! `n - s` transmitted vectors.
//!
//! Given decode weights `W` (from [`GradientCode::decode_weights`]) and
//! the returned vectors `f_i ∈ R^{l/m}`, the sum gradient is
//! `g_sum[v·m + u] = Σ_i W[i][u] · f_i[v]`   (Eq. 19–21 / §IV decode).
//!
//! The inner loop writes each `m`-strided output block from one streamed
//! pass over the `f_i`, using the same specialization trick as encode.

use super::{CodingError, DecodeWeights, GradientCode};

/// Precomputed decoder for a fixed responding-worker set.
pub struct Decoder {
    /// Row-major `(used × m)` — indexing `weights[i*m + u]`.
    weights: Vec<f32>,
    /// Transposed `(m × used)` — contiguous per-`u` rows, the layout the
    /// fused decode loops stream (avoids strided weight loads).
    weights_by_u: Vec<f32>,
    used: Vec<usize>,
    m: usize,
}

impl Decoder {
    /// Build for the given responder set (order = order of `fs` later).
    pub fn new(code: &dyn GradientCode, available: &[usize]) -> Result<Self, CodingError> {
        let dw = code.decode_weights(available)?;
        Ok(Decoder::from_weights(&dw))
    }

    pub fn from_weights(dw: &DecodeWeights) -> Self {
        let used = dw.used.len();
        let m = dw.m;
        let weights: Vec<f32> = dw.weights.iter().map(|&x| x as f32).collect();
        let mut weights_by_u = vec![0.0f32; used * m];
        for i in 0..used {
            for u in 0..m {
                weights_by_u[u * used + i] = weights[i * m + u];
            }
        }
        Decoder { weights, weights_by_u, used: dw.used.clone(), m }
    }

    /// Worker ids whose vectors must be passed to [`Self::decode`], in
    /// this exact order.
    pub fn used_workers(&self) -> &[usize] {
        &self.used
    }

    /// Reconstruct the full `l`-dimensional sum gradient from the
    /// responders' `l/m`-dimensional vectors.
    pub fn decode(&self, fs: &[&[f32]]) -> Result<Vec<f32>, CodingError> {
        let mut out = Vec::new();
        self.decode_into(fs, &mut out)?;
        Ok(out)
    }

    /// Allocation-free variant for the request path.
    ///
    /// Fused across responders: a single pass over the output with all
    /// `n-s` returned vectors read concurrently — each `f_i[v]` is loaded
    /// once and contributes to all `m` interleaved output coordinates
    /// (§Perf: the per-responder formulation re-traversed `out` n-s times
    /// and measured ~2.4 ms at n-s=9, l=262144). The output pass is
    /// chunked across [`crate::pool`] on `m`-aligned boundaries — every
    /// `m`-block is an independent combination of the `f_i[v]`, so the
    /// parallel result is bitwise identical to the serial one for any
    /// thread count.
    pub fn decode_into(&self, fs: &[&[f32]], out: &mut Vec<f32>) -> Result<(), CodingError> {
        let used = self.used.len();
        if fs.len() < used {
            return Err(CodingError::NotEnoughWorkers { need: used, got: fs.len() });
        }
        let lv = fs[0].len();
        for (i, f) in fs.iter().take(used).enumerate() {
            assert_eq!(f.len(), lv, "returned vector {i} length mismatch");
        }
        let m = self.m;
        out.clear();
        out.resize(lv * m, 0.0);
        if lv >= 2 * DECODE_CHUNK_V {
            // Chunk in units of v (m output elements each) so every
            // chunk boundary stays m-aligned.
            let chunk_elems = DECODE_CHUNK_V * m;
            crate::pool::global().for_each_chunk_mut(out, chunk_elems, |ci, oc| {
                self.decode_range(fs, ci * DECODE_CHUNK_V, oc);
            });
        } else {
            self.decode_range(fs, 0, out);
        }
        Ok(())
    }

    /// Decode output components for `v ∈ [v0, v0 + out.len()/m)` into
    /// `out` (an `m`-aligned chunk of the full output). Dimension checks
    /// happen in [`Decoder::decode_into`].
    fn decode_range(&self, fs: &[&[f32]], v0: usize, out: &mut [f32]) {
        let used = self.used.len();
        let m = self.m;
        debug_assert_eq!(out.len() % m, 0);
        let lv = out.len() / m;
        let w = &self.weights;
        match m {
            1 => {
                // g[v] = Σ_i w_i f_i[v] — the 4-stream fused weighted
                // sum over this chunk's subslice of every responder.
                let views: Vec<&[f32]> =
                    fs[..used].iter().map(|f| &f[v0..v0 + lv]).collect();
                crate::linalg::weighted_sum_f32(&w[..used], &views, out);
            }
            2 => {
                let (w0, w1) = self.weights_by_u.split_at(used);
                for dv in 0..lv {
                    let v = v0 + dv;
                    let mut a0 = 0.0f32;
                    let mut a1 = 0.0f32;
                    for (i, f) in fs[..used].iter().enumerate() {
                        let fv = f[v];
                        a0 += w0[i] * fv;
                        a1 += w1[i] * fv;
                    }
                    out[2 * dv] = a0;
                    out[2 * dv + 1] = a1;
                }
            }
            4 => {
                for dv in 0..lv {
                    let v = v0 + dv;
                    let mut acc = [0.0f32; 4];
                    for (i, f) in fs[..used].iter().enumerate() {
                        let fv = f[v];
                        let wi = &w[4 * i..4 * i + 4];
                        acc[0] += wi[0] * fv;
                        acc[1] += wi[1] * fv;
                        acc[2] += wi[2] * fv;
                        acc[3] += wi[3] * fv;
                    }
                    out[4 * dv..4 * dv + 4].copy_from_slice(&acc);
                }
            }
            _ => {
                for dv in 0..lv {
                    let v = v0 + dv;
                    let chunk = &mut out[dv * m..(dv + 1) * m];
                    for (i, f) in fs[..used].iter().enumerate() {
                        let fv = f[v];
                        let wi = &w[i * m..(i + 1) * m];
                        for (o, &wu) in chunk.iter_mut().zip(wi) {
                            *o += wu * fv;
                        }
                    }
                }
            }
        }
    }
}

/// Output blocks (`v` units, i.e. `m` floats each) per parallel decode
/// chunk. The grid is a function of `l/m` only, and each block is
/// independent, so chunking never changes the bits.
pub const DECODE_CHUNK_V: usize = 16 * 1024;

/// Direct sum of gradients — the decode oracle for tests.
pub fn sum_gradients(gradients: &[&[f32]]) -> Vec<f32> {
    let l = gradients[0].len();
    let mut out = vec![0.0f32; l];
    for g in gradients {
        assert_eq!(g.len(), l);
        // f64 accumulation would be more accurate, but the oracle must
        // match the payload precision of the real path.
        crate::linalg::axpy_f32(1.0, g, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{Encoder, GradientCode, PolynomialCode, SchemeConfig};
    use crate::rngs::{Pcg64, Rng};

    /// ℓ∞ relative error between two vectors.
    fn rel_err(a: &[f32], b: &[f32]) -> f64 {
        let scale = b.iter().fold(0.0f64, |acc, &x| acc.max(x.abs() as f64)).max(1e-30);
        a.iter()
            .zip(b)
            .fold(0.0f64, |acc, (&x, &y)| acc.max((x as f64 - y as f64).abs()))
            / scale
    }

    /// Full encode→(drop stragglers)→decode round trip for a scheme.
    fn roundtrip(code: &dyn GradientCode, l: usize, stragglers: &[usize], seed: u64) -> f64 {
        let cfg = *code.config();
        let mut rng = Pcg64::seed_from_u64(seed);
        let grads: Vec<Vec<f32>> = (0..cfg.n)
            .map(|_| (0..l).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect())
            .collect();
        // each worker encodes
        let mut transmitted: Vec<Vec<f32>> = Vec::new();
        for w in 0..cfg.n {
            let enc = Encoder::new(code, w).unwrap();
            let assigned = code.placement().assigned(w);
            let views: Vec<&[f32]> = assigned.iter().map(|&t| grads[t].as_slice()).collect();
            transmitted.push(enc.encode(&views).unwrap());
        }
        // master sees everyone except the stragglers
        let available: Vec<usize> =
            (0..cfg.n).filter(|w| !stragglers.contains(w)).collect();
        let dec = Decoder::new(code, &available).unwrap();
        let fs: Vec<&[f32]> =
            dec.used_workers().iter().map(|&w| transmitted[w].as_slice()).collect();
        let got = dec.decode(&fs).unwrap();
        let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let want = sum_gradients(&views);
        rel_err(&got, &want)
    }

    #[test]
    fn roundtrip_no_stragglers() {
        let code = PolynomialCode::new(SchemeConfig::tight(5, 1, 2).unwrap()).unwrap();
        let err = roundtrip(&code, 24, &[], 1);
        assert!(err < 1e-4, "rel err {err}");
    }

    #[test]
    fn roundtrip_every_straggler_pattern_n5() {
        // s=1: decoding must survive any single straggler.
        let code = PolynomialCode::new(SchemeConfig::tight(5, 1, 2).unwrap()).unwrap();
        for straggler in 0..5 {
            let err = roundtrip(&code, 32, &[straggler], 2);
            assert!(err < 1e-4, "straggler {straggler}: rel err {err}");
        }
    }

    #[test]
    fn roundtrip_two_stragglers_m1() {
        // Fig. 2a regime: s=2, m=1.
        let code = PolynomialCode::new(SchemeConfig::tight(5, 2, 1).unwrap()).unwrap();
        for a in 0..5 {
            for b in a + 1..5 {
                let err = roundtrip(&code, 16, &[a, b], 3);
                assert!(err < 1e-4, "stragglers ({a},{b}): rel err {err}");
            }
        }
    }

    #[test]
    fn roundtrip_larger_scheme_all_patterns() {
        let code = PolynomialCode::new(SchemeConfig::tight(8, 2, 3).unwrap()).unwrap();
        for a in 0..8 {
            for b in a + 1..8 {
                let err = roundtrip(&code, 42, &[a, b], 4);
                assert!(err < 1e-3, "stragglers ({a},{b}): rel err {err}");
            }
        }
    }

    #[test]
    fn large_decode_parallel_is_bitwise_serial() {
        // Above the cutover the chunked pool path must produce the
        // exact bits of a single full-range pass.
        let code = PolynomialCode::new(SchemeConfig::tight(5, 1, 2).unwrap()).unwrap();
        let dec = Decoder::new(&code, &[0, 1, 3, 4]).unwrap();
        let lv = 2 * DECODE_CHUNK_V + 7;
        let fs_store: Vec<Vec<f32>> = (0..dec.used_workers().len())
            .map(|i| (0..lv).map(|v| ((i + v) as f32 * 0.003).sin()).collect())
            .collect();
        let fs: Vec<&[f32]> = fs_store.iter().map(|v| v.as_slice()).collect();
        let mut par = Vec::new();
        dec.decode_into(&fs, &mut par).unwrap();
        let mut ser = vec![0.0f32; lv * 2];
        dec.decode_range(&fs, 0, &mut ser);
        assert!(par.iter().zip(&ser).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn decoder_rejects_missing_vectors() {
        let code = PolynomialCode::new(SchemeConfig::tight(5, 1, 2).unwrap()).unwrap();
        let dec = Decoder::new(&code, &[0, 1, 2, 3]).unwrap();
        let f = vec![0.0f32; 4];
        assert!(dec.decode(&[&f, &f, &f]).is_err());
    }

    #[test]
    fn sum_gradients_oracle() {
        let a = vec![1.0f32, 2.0];
        let b = vec![10.0f32, 20.0];
        assert_eq!(sum_gradients(&[&a, &b]), vec![11.0, 22.0]);
    }
}
