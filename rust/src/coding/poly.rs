//! §III recursive-polynomial construction (the paper's main technical
//! novelty).
//!
//! For each data subset `t` define (Eq. 8, 0-based)
//! `p_t(x) = Π_{j=1..n-d} (x - θ_{(t+j) mod n})`,
//! so `p_t(θ_w) = 0` exactly for the `n-d` workers *not* holding `D_t`.
//! The recursion (Eq. 9)
//! `p_t^{(1)} = p_t`,
//! `p_t^{(u)}(x) = x·p_t^{(u-1)}(x) - p^{(u-1)}_{t,n-d-1}·p_t^{(1)}(x)`
//! produces `m` polynomials per subset whose coefficient rows stack into
//! the `(m·n) × (n-s)` matrix `B` (Eq. 13 / Algorithm 1), with the key
//! properties:
//! - columns `n-d .. n-d+m-1` of `B` form stacked `I_m` blocks (Eq. 15),
//!   which is what lets the master read off the *sum* gradient, and
//! - row `(t,u)` of `B·V` vanishes at every worker not holding `D_t`
//!   (Eq. 11), which is what bounds the computation load by `d`.

use super::{
    CodingError, DecodeWeights, GradientCode, Placement, SchemeConfig,
};
use crate::coding::vandermonde::{paper_thetas, vandermonde};
use crate::linalg::{Lu, Matrix};

/// Dense polynomial, coefficients ascending (`c[j]` is the `x^j` term).
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct Poly(pub Vec<f64>);

impl Poly {
    /// Monic polynomial with the given roots: `Π (x - r)`.
    pub fn from_roots(roots: &[f64]) -> Poly {
        let mut c = vec![1.0];
        for &r in roots {
            // multiply by (x - r)
            let mut next = vec![0.0; c.len() + 1];
            for (j, &cj) in c.iter().enumerate() {
                next[j + 1] += cj;
                next[j] -= r * cj;
            }
            c = next;
        }
        Poly(c)
    }

    /// Coefficient of `x^j` (0 beyond degree).
    pub fn coeff(&self, j: usize) -> f64 {
        self.0.get(j).copied().unwrap_or(0.0)
    }

    /// Horner evaluation.
    #[cfg(test)]
    pub fn eval(&self, x: f64) -> f64 {
        self.0.iter().rev().fold(0.0, |acc, &c| acc * x + c)
    }

    /// `x·self - lambda·other`, truncated to nothing (exact).
    pub fn shift_sub(&self, lambda: f64, other: &Poly) -> Poly {
        let deg = (self.0.len() + 1).max(other.0.len());
        let mut c = vec![0.0; deg];
        for (j, &cj) in self.0.iter().enumerate() {
            c[j + 1] += cj;
        }
        for (j, &oj) in other.0.iter().enumerate() {
            c[j] -= lambda * oj;
        }
        // trim trailing zeros (keep at least the constant term)
        while c.len() > 1 && c.last() == Some(&0.0) {
            c.pop();
        }
        Poly(c)
    }
}

/// The §III scheme for a tight or slack triple (`d >= s + m`).
pub struct PolynomialCode {
    cfg: SchemeConfig,
    placement: Placement,
    thetas: Vec<f64>,
    /// `(m·n) × (n-s)`; row `t·m + u` holds the coefficients of
    /// `p_t^{(u+1)}` padded to degree `n-s-1`.
    b: Matrix,
    /// `(n-s) × n` Vandermonde `V[r][w] = θ_w^r`.
    v: Matrix,
}

impl PolynomialCode {
    /// Build with the paper's θ grid (Eq. 23).
    pub fn new(cfg: SchemeConfig) -> Result<Self, CodingError> {
        Self::with_thetas(cfg, &paper_thetas(cfg.n))
    }

    /// Build with custom evaluation points (must be distinct).
    pub fn with_thetas(cfg: SchemeConfig, thetas: &[f64]) -> Result<Self, CodingError> {
        if thetas.len() != cfg.n {
            return Err(CodingError::InvalidConfig(format!(
                "need {} thetas, got {}",
                cfg.n,
                thetas.len()
            )));
        }
        for i in 0..thetas.len() {
            for j in i + 1..thetas.len() {
                if thetas[i] == thetas[j] {
                    return Err(CodingError::InvalidConfig(format!(
                        "evaluation points must be distinct (θ[{i}] == θ[{j}] == {})",
                        thetas[i]
                    )));
                }
            }
        }
        let (n, d, s, m) = (cfg.n, cfg.d, cfg.s, cfg.m);
        let cols = n - s;

        // Algorithm 1, expressed through the Poly recursion.
        let mut b = Matrix::zeros(m * n, cols);
        for t in 0..n {
            // roots θ_{(t+j) mod n}, j = 1..n-d  (Eq. 8)
            let roots: Vec<f64> = (1..=n - d).map(|j| thetas[(t + j) % n]).collect();
            let p1 = Poly::from_roots(&roots);
            debug_assert_eq!(p1.0.len(), n - d + 1);
            debug_assert!((p1.coeff(n - d) - 1.0).abs() < 1e-12, "p_t must be monic");
            let mut pu = p1.clone();
            for u in 0..m {
                if u > 0 {
                    // Eq. 9: multiplier is the x^{n-d-1} coefficient of the
                    // previous polynomial. When d = n, p_t ≡ 1 and that
                    // coefficient (of x^{-1}) is zero, so the recursion
                    // degenerates to p^{(u)} = x^{u-1} as required.
                    let lambda = if n > d { pu.coeff(n - d - 1) } else { 0.0 };
                    pu = pu.shift_sub(lambda, &p1);
                }
                for j in 0..cols {
                    b[(t * m + u, j)] = pu.coeff(j);
                }
            }
        }

        let v = vandermonde(cols, thetas);
        Ok(PolynomialCode {
            cfg,
            placement: Placement::cyclic(n, d),
            thetas: thetas.to_vec(),
            b,
            v,
        })
    }

    pub fn thetas(&self) -> &[f64] {
        &self.thetas
    }
}

impl GradientCode for PolynomialCode {
    fn config(&self) -> &SchemeConfig {
        &self.cfg
    }

    fn placement(&self) -> &Placement {
        &self.placement
    }

    fn encode_coeffs(&self, worker: usize) -> Result<Vec<f64>, CodingError> {
        let n = self.cfg.n;
        if worker >= n {
            return Err(CodingError::WorkerOutOfRange(worker));
        }
        let m = self.cfg.m;
        let cols = n - self.cfg.s;
        // V column for this worker: powers of θ_worker.
        let theta = self.thetas[worker];
        let mut pw = Vec::with_capacity(cols);
        let mut acc = 1.0;
        for _ in 0..cols {
            pw.push(acc);
            acc *= theta;
        }
        let assigned = self.placement.assigned(worker);
        let mut coeffs = Vec::with_capacity(assigned.len() * m);
        for &t in &assigned {
            for u in 0..m {
                coeffs.push(crate::linalg::dot_f64(self.b.row(t * m + u), &pw));
            }
        }
        Ok(coeffs)
    }

    fn decode_weights(&self, available: &[usize]) -> Result<DecodeWeights, CodingError> {
        let (n, d, s, m) = (self.cfg.n, self.cfg.d, self.cfg.s, self.cfg.m);
        let need = n - s;
        if available.len() < need {
            return Err(CodingError::NotEnoughWorkers { need, got: available.len() });
        }
        for &w in available {
            if w >= n {
                return Err(CodingError::WorkerOutOfRange(w));
            }
        }
        // Use exactly the first n-s responders: A = V restricted to those
        // columns (Eq. 20), W = columns n-d .. n-d+m-1 of A^{-1}.
        let used: Vec<usize> = available[..need].to_vec();
        let a = self.v.select_cols(&used);
        let lu = Lu::factor(&a).map_err(|e| CodingError::SingularDecode {
            available: used.clone(),
            source: e,
        })?;
        let inv = lu.inverse().map_err(|e| CodingError::SingularDecode {
            available: used.clone(),
            source: e,
        })?;
        let mut weights = vec![0.0; need * m];
        for i in 0..need {
            for u in 0..m {
                weights[i * m + u] = inv[(i, n - d + u)];
            }
        }
        Ok(DecodeWeights { used, weights, m })
    }

    fn matrix_b(&self) -> Matrix {
        self.b.clone()
    }

    fn matrix_v(&self) -> Matrix {
        self.v.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::vandermonde::integer_thetas;

    fn scheme(n: usize, s: usize, m: usize) -> PolynomialCode {
        PolynomialCode::new(SchemeConfig::tight(n, s, m).unwrap()).unwrap()
    }

    #[test]
    fn poly_from_roots_expands() {
        // (x-1)(x+2) = x^2 + x - 2
        let p = Poly::from_roots(&[1.0, -2.0]);
        assert_eq!(p.0, vec![-2.0, 1.0, 1.0]);
        assert_eq!(p.eval(1.0), 0.0);
        assert_eq!(p.eval(-2.0), 0.0);
        assert_eq!(p.eval(0.0), -2.0);
    }

    #[test]
    fn b_has_identity_block_columns() {
        // Eq. 15: columns n-d..n-d+m-1 of B are stacked I_m blocks.
        for (n, s, m) in [(5, 1, 2), (5, 2, 1), (8, 2, 3), (10, 0, 4), (7, 3, 2)] {
            let c = scheme(n, s, m);
            let b = c.matrix_b();
            let (n, d, m) = (c.cfg.n, c.cfg.d, c.cfg.m);
            for t in 0..n {
                for u in 0..m {
                    for uu in 0..m {
                        let want = if u == uu { 1.0 } else { 0.0 };
                        let got = b[(t * m + u, n - d + uu)];
                        assert!(
                            (got - want).abs() < 1e-9,
                            "B[{t},{u}] col {uu}: got {got}, want {want} (n={n},d={d},m={m})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn rows_vanish_at_non_holders() {
        // Eq. 11: p_t^{(u)}(θ_w) = 0 whenever worker w does not hold D_t.
        for (n, s, m) in [(5, 1, 2), (6, 2, 2), (9, 3, 3)] {
            let c = scheme(n, s, m);
            let bv = c.matrix_b().matmul(&c.matrix_v());
            for t in 0..n {
                for u in 0..c.cfg.m {
                    for w in 0..n {
                        let val = bv[(t * c.cfg.m + u, w)];
                        if !c.placement.is_assigned(w, t) {
                            assert!(
                                val.abs() < 1e-7,
                                "BV[({t},{u}),{w}] = {val} should vanish (n={n},s={s},m={m})"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn encode_coeffs_match_bv_product() {
        let c = scheme(7, 2, 2);
        let bv = c.matrix_b().matmul(&c.matrix_v());
        for w in 0..7 {
            let coeffs = c.encode_coeffs(w).unwrap();
            let assigned = c.placement.assigned(w);
            for (j, &t) in assigned.iter().enumerate() {
                for u in 0..c.cfg.m {
                    let want = bv[(t * c.cfg.m + u, w)];
                    let got = coeffs[j * c.cfg.m + u];
                    assert!((got - want).abs() < 1e-8, "w={w} t={t} u={u}");
                }
            }
        }
    }

    #[test]
    fn decode_rejects_short_worker_sets() {
        let c = scheme(5, 2, 1);
        assert!(matches!(
            c.decode_weights(&[0, 1]),
            Err(CodingError::NotEnoughWorkers { need: 3, got: 2 })
        ));
    }

    #[test]
    fn fig2b_table2_semantics_reproduced() {
        // Fig. 2b / Table II: n=5, d=3, s=1, m=2, θ = (-2,-1,0,1,2), l=2.
        // Each worker transmits ONE scalar and the master reconstructs
        // both coordinates of the sum gradient from any 4 workers.
        //
        // Note: the paper's printed Table II coefficients correspond to an
        // unstated normalization of the figure's B; decode weights under
        // Definition 1 are *unique* given V (B has full column rank), so
        // we verify the table's semantics — exact reconstruction for every
        // straggler pattern — plus the defining identity A·w = e_{n-d+u}.
        let cfg = SchemeConfig::tight(5, 1, 2).unwrap();
        let c = PolynomialCode::with_thetas(cfg, &integer_thetas(5)).unwrap();
        let thetas = integer_thetas(5);
        for straggler in 0..5 {
            let avail: Vec<usize> = (0..5).filter(|&w| w != straggler).collect();
            let dw = c.decode_weights(&avail).unwrap();
            // Defining identity: Σ_i w_u[i] θ_i^r = [r == n-d+u].
            for u in 0..2 {
                for r in 0..4 {
                    let got: f64 = (0..4)
                        .map(|i| dw.weight(i, u) * thetas[avail[i]].powi(r as i32))
                        .sum();
                    let want = if r == 2 + u { 1.0 } else { 0.0 };
                    assert!(
                        (got - want).abs() < 1e-9,
                        "straggler {straggler} u={u} r={r}: {got} vs {want}"
                    );
                }
            }
            // Semantic check at l = 2: reconstruct both coordinates of the
            // sum from the four scalars f_i (each of dimension l/m = 1).
            let grads: Vec<Vec<f32>> = (0..5)
                .map(|t| vec![(t as f32 + 1.0) * 0.5, (t as f32) - 2.0])
                .collect();
            let mut transmitted = Vec::new();
            for w in 0..5 {
                let enc = crate::coding::Encoder::new(&c, w).unwrap();
                let views: Vec<&[f32]> = c
                    .placement()
                    .assigned(w)
                    .iter()
                    .map(|&t| grads[t].as_slice())
                    .collect();
                let f = enc.encode(&views).unwrap();
                assert_eq!(f.len(), 1, "each worker transmits one scalar");
                transmitted.push(f);
            }
            let dec = crate::coding::Decoder::new(&c, &avail).unwrap();
            let fs: Vec<&[f32]> = dec
                .used_workers()
                .iter()
                .map(|&w| transmitted[w].as_slice())
                .collect();
            let got = dec.decode(&fs).unwrap();
            let want0: f32 = grads.iter().map(|g| g[0]).sum();
            let want1: f32 = grads.iter().map(|g| g[1]).sum();
            assert!((got[0] - want0).abs() < 1e-4, "straggler {straggler}: coord 0");
            assert!((got[1] - want1).abs() < 1e-4, "straggler {straggler}: coord 1");
        }
    }

    #[test]
    fn slack_config_d_greater_than_s_plus_m_still_decodes() {
        // d > s+m is admissible (slack in Theorem 1's inequality).
        let cfg = SchemeConfig::new(6, 5, 2, 2).unwrap();
        let c = PolynomialCode::new(cfg).unwrap();
        assert!(c.decode_weights(&[0, 2, 3, 5]).is_ok());
    }
}
