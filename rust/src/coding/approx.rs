//! Approximate gradient coding with partial recovery.
//!
//! The exact schemes of §III/§IV guarantee perfect reconstruction of the
//! sum gradient from *any* `n - s` responders, at the price of Theorem
//! 1's load `d >= s + m`. The approximate regime studied by Wang, Liu &
//! Shroff ("Fundamental Limits of Approximate Gradient Coding") and
//! Sarmasarkar, Lalitha & Karamchandani ("On Gradient Coding with
//! Partial Recovery") relaxes exactness: the master proceeds once a
//! *quorum* of `r` responders (possibly `r < n - s_exact`) has arrived
//! and accepts a bounded decoding error in exchange for a much shorter
//! straggler tail.
//!
//! [`ApproxCode`] implements the fractional-repetition-style member of
//! that family on the cyclic placement: worker `w` holds subsets
//! `w, …, w+d-1 (mod n)` and transmits the *uniform average*
//! `f_w = (1/d) Σ_{t ∈ assigned(w)} g_t` (so `m = 1` and every subset is
//! replicated `d` times, like the FRC/BGC constructions). Decoding is a
//! **least-squares partial decoder**: for a responder set `F` it solves
//!
//! ```text
//!   min_a ‖ A_F^T a − 1 ‖₂        (A_F = responder rows of the n×n
//!                                  encode matrix A, 1 = all-ones target)
//! ```
//!
//! via the normal equations `A_F A_F^T a = A_F 1 = 1` and returns both
//! the combining weights `a` and the *coefficient residual*
//! `ε(F) = ‖A_F^T a − 1‖₂` — the quantity the approximate-GC literature
//! calls the decoding error. The estimate `ĝ = Σ_i a_i f_i` then
//! satisfies the computable bound
//!
//! ```text
//!   ‖ĝ − g_sum‖₂  ≤  Σ_t |e_t| · ‖g_t‖₂   ≤   ε(F) · √(Σ_t ‖g_t‖₂²)
//! ```
//!
//! with `e = A_F^T a − 1` (triangle inequality per subset, then
//! Cauchy–Schwarz). Key properties, asserted in the tests below:
//!
//! - **exactness at full quorum**: with all `n` responders the all-ones
//!   weights reproduce `g_sum` exactly (`ε = 0`), so the scheme degrades
//!   to exact recovery when nobody straggles;
//! - **monotone error bound**: removing responders can only grow the
//!   least-squares residual, so the reported bound is monotone
//!   non-increasing in the quorum size;
//! - **validity**: the measured ℓ2 error of the f32 decode path stays
//!   within the reported bound.
//!
//! The quorum policy that consumes this scheme lives in
//! [`crate::coordinator`] (`TrainConfig::quorum`), and the §VI runtime
//! model extension that predicts time *and* residual versus quorum lives
//! in [`crate::simulator::approx`].

use super::{CodingError, DecodeWeights, GradientCode, Placement, SchemeConfig};
use crate::linalg::{dot_f64, Lu, Matrix};

/// Fractional-repetition-style approximate gradient code (cyclic
/// placement, uniform-average encode, least-squares partial decode).
pub struct ApproxCode {
    cfg: SchemeConfig,
    placement: Placement,
    /// `n × n` encode matrix `A`: `A[w][t] = 1/d` iff worker `w` holds
    /// subset `t`.
    a: Matrix,
}

impl ApproxCode {
    /// Build for `n` workers with replication `d` and a target quorum of
    /// `quorum` responders (the master proceeds once `quorum` results
    /// have arrived; `quorum = n` degenerates to exact recovery).
    ///
    /// Note the deliberate difference from the exact schemes: the triple
    /// is *not* constrained by Theorem 1 (`d >= s + m`) because recovery
    /// below full coverage is approximate by design. `SchemeConfig.s` is
    /// set to `n - quorum` so that [`SchemeConfig::wait_for`] returns the
    /// quorum and the coordinator treats the scheme uniformly.
    pub fn new(n: usize, d: usize, quorum: usize) -> Result<Self, CodingError> {
        if n == 0 || d == 0 {
            return Err(CodingError::InvalidConfig(format!(
                "n and d must be positive (n={n}, d={d})"
            )));
        }
        if d > n {
            return Err(CodingError::InvalidConfig(format!("d={d} exceeds n={n}")));
        }
        if quorum == 0 || quorum > n {
            return Err(CodingError::InvalidConfig(format!(
                "quorum={quorum} must be in 1..={n}"
            )));
        }
        let placement = Placement::cyclic(n, d);
        let inv_d = 1.0 / d as f64;
        let mut a = Matrix::zeros(n, n);
        for w in 0..n {
            for t in placement.assigned(w) {
                a[(w, t)] = inv_d;
            }
        }
        let cfg = SchemeConfig { n, d, s: n - quorum, m: 1 };
        Ok(ApproxCode { cfg, placement, a })
    }

    /// Build from a quorum *fraction* `q ∈ (0, 1]`: the master waits for
    /// `ceil(q·n)` responders.
    pub fn with_quorum_fraction(n: usize, d: usize, q: f64) -> Result<Self, CodingError> {
        if !(q > 0.0 && q <= 1.0) {
            return Err(CodingError::InvalidConfig(format!(
                "quorum fraction {q} must be in (0, 1]"
            )));
        }
        Self::new(n, d, quorum_count(n, q))
    }

    /// Number of responders the master waits for.
    pub fn quorum(&self) -> usize {
        self.cfg.wait_for()
    }

    /// The `n × n` encode matrix `A` (row per worker, column per subset).
    pub fn matrix_a(&self) -> &Matrix {
        &self.a
    }

    /// Least-squares partial decode for an arbitrary responder set
    /// (any non-empty subset of workers — fewer than the quorum is
    /// accepted too, with a correspondingly larger residual).
    pub fn partial_decode(&self, available: &[usize]) -> Result<PartialDecode, CodingError> {
        let n = self.cfg.n;
        if available.is_empty() {
            return Err(CodingError::NotEnoughWorkers { need: 1, got: 0 });
        }
        let mut seen = vec![false; n];
        for &w in available {
            if w >= n {
                return Err(CodingError::WorkerOutOfRange(w));
            }
            if seen[w] {
                return Err(CodingError::InvalidConfig(format!(
                    "duplicate worker {w} in responder set"
                )));
            }
            seen[w] = true;
        }
        let r = available.len();
        let weights = if r == n {
            // Full quorum: Σ_w f_w = (1/d)·d·Σ_t g_t = g_sum — the
            // all-ones weights are exact for any responder ordering, and
            // skipping the solve avoids the (possibly singular) Gram.
            vec![1.0; n]
        } else {
            // Normal equations  (A_F A_F^T) a = A_F·1 = 1  (the rhs is
            // all-ones because every row of A sums to d·(1/d) = 1).
            let mut gram = Matrix::from_fn(r, r, |i, j| {
                dot_f64(self.a.row(available[i]), self.a.row(available[j]))
            });
            let rhs = vec![1.0; r];
            match Lu::factor(&gram).and_then(|lu| lu.solve(&rhs)) {
                Ok(a) => a,
                Err(_) => {
                    // Rank-deficient responder pattern (duplicated
                    // coverage): Tikhonov fallback. The residual below is
                    // computed from the weights actually used, so the
                    // reported bound stays valid.
                    let delta = 1e-9 * (0..r).map(|i| gram[(i, i)]).sum::<f64>().max(1.0)
                        / r as f64;
                    for i in 0..r {
                        gram[(i, i)] += delta;
                    }
                    Lu::factor(&gram).and_then(|lu| lu.solve(&rhs)).map_err(|e| {
                        CodingError::SingularDecode {
                            available: available.to_vec(),
                            source: e,
                        }
                    })?
                }
            }
        };
        // e_t = Σ_i a_i A[w_i, t] − 1: the per-subset coefficient error.
        let mut subset_errors = vec![-1.0f64; n];
        for (i, &w) in available.iter().enumerate() {
            let ai = weights[i];
            for t in self.placement.assigned(w) {
                subset_errors[t] += ai * self.a[(w, t)];
            }
        }
        let coeff_residual = subset_errors.iter().map(|e| e * e).sum::<f64>().sqrt();
        Ok(PartialDecode {
            weights: DecodeWeights { used: available.to_vec(), weights, m: 1 },
            subset_errors,
            coeff_residual,
        })
    }
}

/// Quorum count for a fraction `q` of `n` workers (`ceil`, clamped to
/// `1..=n`; 0 for `n = 0`, which scheme construction then rejects).
pub fn quorum_count(n: usize, q: f64) -> usize {
    if n == 0 {
        return 0;
    }
    ((q * n as f64).ceil() as usize).clamp(1, n)
}

/// Result of [`ApproxCode::partial_decode`]: combining weights plus the
/// decoder's own error accounting.
#[derive(Debug, Clone)]
pub struct PartialDecode {
    /// Weights for [`crate::coding::Decoder::from_weights`] (`m = 1`).
    pub weights: DecodeWeights,
    /// `e_t = (A_F^T a − 1)_t` — signed coefficient error per subset.
    pub subset_errors: Vec<f64>,
    /// `ε(F) = ‖e‖₂`, the scheme's decoding residual (0 ⇔ exact).
    pub coeff_residual: f64,
}

impl PartialDecode {
    /// Computable ℓ2 error bound given the per-subset gradient norms:
    /// `‖ĝ − g_sum‖₂ ≤ Σ_t |e_t|·‖g_t‖₂`.
    pub fn error_bound(&self, subset_norms: &[f64]) -> f64 {
        assert_eq!(subset_norms.len(), self.subset_errors.len(), "one norm per subset");
        self.subset_errors
            .iter()
            .zip(subset_norms)
            .map(|(e, g)| e.abs() * g)
            .sum()
    }

    /// Norm-free bound with a uniform cap `‖g_t‖₂ ≤ max_norm`:
    /// `‖ĝ − g_sum‖₂ ≤ ‖e‖₁ · max_norm`.
    pub fn uniform_error_bound(&self, max_norm: f64) -> f64 {
        self.subset_errors.iter().map(|e| e.abs()).sum::<f64>() * max_norm
    }

    /// Whether this responder set recovers the sum exactly (up to `tol`
    /// in coefficient space).
    pub fn is_exact(&self, tol: f64) -> bool {
        self.coeff_residual <= tol
    }
}

/// Result of [`ls_partial_decode`]: combining weights plus the
/// coefficient-space residual of the least-squares fit.
#[derive(Debug, Clone)]
pub struct LsDecode {
    /// Weights for [`crate::coding::Decoder::from_weights`].
    pub weights: DecodeWeights,
    /// `ε(F) = ‖C·W − Y‖_F` over all `m` components: 0 ⇔ the responder
    /// set recovers the sum exactly; otherwise the estimate satisfies
    /// `‖ĝ − g_sum‖₂ ≤ ε·√(Σ_t ‖g_t‖₂²)` (Cauchy–Schwarz per component).
    pub coeff_residual: f64,
}

/// Generic least-squares partial decode for **any** [`GradientCode`] —
/// the degradation-ladder fallback when fewer than `n - s` workers
/// respond and the scheme's own exact decode is impossible.
///
/// Works directly from the scheme's `B·V` coefficient matrix, whose
/// entry `(t·m+u, w)` is the coefficient of `g_t`'s `u`-component in
/// `f_w` (the invariant every scheme upholds). For each output component
/// `u ∈ 0..m` it solves
///
/// ```text
///   min_w ‖ C w − y_u ‖₂     C[row, i] = (B·V)[row, available_i]
///                            y_u[t·m+u'] = 1 iff u' = u
/// ```
///
/// via one normal-equation factorization shared across the `m`
/// right-hand sides, and returns the stacked weights in
/// [`DecodeWeights`] layout plus the total residual. Properties:
///
/// - for an exact scheme with at least `n - s` responders the residual
///   is ~0 and the decode is exact (a zero-residual solution exists);
/// - for [`ApproxCode`] this reduces to [`ApproxCode::partial_decode`]
///   (identical normal equations);
/// - for [`crate::coding::UncodedScheme`] with `r` of `n` responders the
///   weights are all ones and the residual is `√(n−r)` (the missing
///   subsets are simply gone).
pub fn ls_partial_decode(
    code: &dyn GradientCode,
    available: &[usize],
) -> Result<LsDecode, CodingError> {
    let cfg = *code.config();
    let (n, m) = (cfg.n, cfg.m);
    if available.is_empty() {
        return Err(CodingError::NotEnoughWorkers { need: 1, got: 0 });
    }
    let mut seen = vec![false; n];
    for &w in available {
        if w >= n {
            return Err(CodingError::WorkerOutOfRange(w));
        }
        if seen[w] {
            return Err(CodingError::InvalidConfig(format!(
                "duplicate worker {w} in responder set"
            )));
        }
        seen[w] = true;
    }
    let bv = code.matrix_b().matmul(&code.matrix_v());
    debug_assert_eq!(bv.rows(), m * n, "BV must have one row per (subset, component)");
    debug_assert_eq!(bv.cols(), n, "BV must have one column per worker");
    let r = available.len();
    let rows = m * n;
    let mut gram = Matrix::from_fn(r, r, |i, j| {
        (0..rows)
            .map(|row| bv[(row, available[i])] * bv[(row, available[j])])
            .sum()
    });
    let singular = |e: crate::linalg::LinalgError| CodingError::SingularDecode {
        available: available.to_vec(),
        source: e,
    };
    let lu = match Lu::factor(&gram) {
        Ok(lu) => lu,
        Err(_) => {
            // Rank-deficient responder pattern: Tikhonov fallback, same
            // recipe as `ApproxCode::partial_decode`. The residual below
            // is computed from the weights actually used, so the reported
            // bound stays valid.
            let delta =
                1e-9 * (0..r).map(|i| gram[(i, i)]).sum::<f64>().max(1.0) / r as f64;
            for i in 0..r {
                gram[(i, i)] += delta;
            }
            Lu::factor(&gram).map_err(singular)?
        }
    };
    let mut weights = vec![0.0f64; r * m];
    let mut residual_sq = 0.0f64;
    for u in 0..m {
        // y_u has a 1 in row t·m+u for every subset t, so (Cᵀ y_u)_i is
        // the sum of worker available_i's u-rows.
        let rhs: Vec<f64> = (0..r)
            .map(|i| (0..n).map(|t| bv[(t * m + u, available[i])]).sum())
            .collect();
        let w_u = lu.solve(&rhs).map_err(singular)?;
        for t in 0..n {
            for up in 0..m {
                let row = t * m + up;
                let pred: f64 =
                    (0..r).map(|i| w_u[i] * bv[(row, available[i])]).sum();
                let target = if up == u { 1.0 } else { 0.0 };
                let e = pred - target;
                residual_sq += e * e;
            }
        }
        for i in 0..r {
            weights[i * m + u] = w_u[i];
        }
    }
    Ok(LsDecode {
        weights: DecodeWeights { used: available.to_vec(), weights, m },
        coeff_residual: residual_sq.sqrt(),
    })
}

impl GradientCode for ApproxCode {
    fn config(&self) -> &SchemeConfig {
        &self.cfg
    }

    fn placement(&self) -> &Placement {
        &self.placement
    }

    fn encode_coeffs(&self, worker: usize) -> Result<Vec<f64>, CodingError> {
        if worker >= self.cfg.n {
            return Err(CodingError::WorkerOutOfRange(worker));
        }
        Ok(vec![1.0 / self.cfg.d as f64; self.cfg.d])
    }

    /// Unlike the exact schemes, *any* non-empty responder set is
    /// accepted; the weights are the least-squares solution and the
    /// decode is approximate whenever [`ApproxCode::partial_decode`]
    /// reports a nonzero residual.
    fn decode_weights(&self, available: &[usize]) -> Result<DecodeWeights, CodingError> {
        self.partial_decode(available).map(|p| p.weights)
    }

    fn decode_residual(&self, available: &[usize]) -> Option<f64> {
        self.partial_decode(available).ok().map(|p| p.coeff_residual)
    }

    /// One least-squares solve serves both pieces (the default would
    /// solve the same system twice).
    fn decode_weights_with_residual(
        &self,
        available: &[usize],
    ) -> Result<(DecodeWeights, Option<f64>), CodingError> {
        let partial = self.partial_decode(available)?;
        Ok((partial.weights, Some(partial.coeff_residual)))
    }

    /// For the approximate scheme the `B·V` factorization degenerates:
    /// `B = A^T` (row per subset, column per worker) and `V = I`, so that
    /// `B·V` keeps the invariant "entry `(t, w)` is the coefficient of
    /// `g_t` in `f_w`" shared with the exact schemes.
    fn matrix_b(&self) -> Matrix {
        self.a.transpose()
    }

    fn matrix_v(&self) -> Matrix {
        Matrix::identity(self.cfg.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::decode::sum_gradients;
    use crate::coding::{Decoder, Encoder};
    use crate::rngs::{Pcg64, Rng};

    fn random_grads(n: usize, l: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seed_from_u64(seed);
        (0..n)
            .map(|_| (0..l).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect())
            .collect()
    }

    fn transmit_all(code: &ApproxCode, grads: &[Vec<f32>]) -> Vec<Vec<f32>> {
        (0..code.config().n)
            .map(|w| {
                let views: Vec<&[f32]> = code
                    .placement()
                    .assigned(w)
                    .iter()
                    .map(|&t| grads[t].as_slice())
                    .collect();
                Encoder::new(code, w).unwrap().encode(&views).unwrap()
            })
            .collect()
    }

    fn decode_estimate(
        code: &ApproxCode,
        transmitted: &[Vec<f32>],
        available: &[usize],
    ) -> (Vec<f32>, PartialDecode) {
        let partial = code.partial_decode(available).unwrap();
        let dec = Decoder::from_weights(&partial.weights);
        let fs: Vec<&[f32]> =
            dec.used_workers().iter().map(|&w| transmitted[w].as_slice()).collect();
        (dec.decode(&fs).unwrap(), partial)
    }

    fn l2(v: &[f64]) -> f64 {
        v.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    fn l2_diff(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| {
                let d = x as f64 - y as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt()
    }

    #[test]
    fn construction_validates_parameters() {
        assert!(ApproxCode::new(0, 1, 1).is_err());
        assert!(ApproxCode::new(5, 0, 3).is_err());
        assert!(ApproxCode::new(5, 6, 3).is_err());
        assert!(ApproxCode::new(5, 2, 0).is_err());
        assert!(ApproxCode::new(5, 2, 6).is_err());
        assert!(ApproxCode::with_quorum_fraction(5, 2, 0.0).is_err());
        assert!(ApproxCode::with_quorum_fraction(5, 2, 1.2).is_err());
        // n = 0 must error cleanly, not panic in quorum_count's clamp
        assert!(ApproxCode::with_quorum_fraction(0, 1, 0.5).is_err());
        let c = ApproxCode::new(6, 2, 4).unwrap();
        assert_eq!(c.quorum(), 4);
        assert_eq!(c.config().wait_for(), 4);
        assert_eq!(c.config().m, 1);
    }

    #[test]
    fn quorum_count_rounds_up() {
        assert_eq!(quorum_count(10, 0.7), 7);
        assert_eq!(quorum_count(10, 0.61), 7);
        assert_eq!(quorum_count(10, 1.0), 10);
        assert_eq!(quorum_count(10, 0.01), 1);
        assert_eq!(quorum_count(3, 0.5), 2);
    }

    #[test]
    fn encode_is_uniform_average() {
        let c = ApproxCode::new(7, 3, 5).unwrap();
        for w in 0..7 {
            let coeffs = c.encode_coeffs(w).unwrap();
            assert_eq!(coeffs.len(), 3);
            for x in coeffs {
                assert!((x - 1.0 / 3.0).abs() < 1e-15);
            }
        }
        assert!(c.encode_coeffs(7).is_err());
    }

    #[test]
    fn full_quorum_decodes_exactly() {
        // n=6, d=2 is deliberately a rank-deficient full Gram (the
        // alternating-sign row combination vanishes): the full-set
        // shortcut must keep it exact anyway.
        for (n, d, l, seed) in [(6usize, 2usize, 24usize, 1u64), (7, 3, 30, 2), (5, 5, 20, 3)] {
            let code = ApproxCode::new(n, d, n).unwrap();
            let grads = random_grads(n, l, seed);
            let transmitted = transmit_all(&code, &grads);
            let all: Vec<usize> = (0..n).collect();
            let (got, partial) = decode_estimate(&code, &transmitted, &all);
            assert!(partial.is_exact(1e-12), "residual {}", partial.coeff_residual);
            let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            let want = sum_gradients(&views);
            let scale = l2(&want.iter().map(|&x| x as f64).collect::<Vec<_>>()).max(1e-12);
            assert!(
                l2_diff(&got, &want) / scale < 1e-5,
                "(n={n},d={d}): rel l2 err {}",
                l2_diff(&got, &want) / scale
            );
        }
    }

    #[test]
    fn full_replication_decodes_from_single_worker() {
        // d = n: every worker holds everything, so one responder suffices
        // and the LS solve must find the exact weight n·(1/1)… i.e. a = n
        // with f_w = (1/n)·g_sum.
        let n = 5;
        let code = ApproxCode::new(n, n, 1).unwrap();
        let grads = random_grads(n, 12, 9);
        let transmitted = transmit_all(&code, &grads);
        let (got, partial) = decode_estimate(&code, &transmitted, &[3]);
        assert!(partial.is_exact(1e-9), "residual {}", partial.coeff_residual);
        let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let want = sum_gradients(&views);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "{g} vs {w}");
        }
    }

    #[test]
    fn residual_grows_as_quorum_shrinks() {
        // Least-squares residual over a subset of responders can only be
        // larger: check along nested chains.
        let n = 7;
        let code = ApproxCode::new(n, 3, 4).unwrap();
        let mut rng = Pcg64::seed_from_u64(11);
        for _ in 0..20 {
            let mut order: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut order);
            let mut prev = -1.0f64;
            // shrink from the full set down to a single responder
            for keep in (1..=n).rev() {
                let set: Vec<usize> = order[..keep].to_vec();
                let res = code.partial_decode(&set).unwrap().coeff_residual;
                assert!(
                    res + 1e-7 >= prev,
                    "residual not monotone: |F|={keep} gives {res} after {prev}"
                );
                prev = res;
            }
        }
    }

    #[test]
    fn measured_error_within_reported_bound() {
        let n = 9;
        let l = 18;
        let code = ApproxCode::new(n, 3, 6).unwrap();
        let grads = random_grads(n, l, 21);
        let transmitted = transmit_all(&code, &grads);
        let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let want = sum_gradients(&views);
        let norms: Vec<f64> = grads
            .iter()
            .map(|g| l2(&g.iter().map(|&x| x as f64).collect::<Vec<_>>()))
            .collect();
        let max_norm = norms.iter().fold(0.0f64, |a, &b| a.max(b));
        let mut rng = Pcg64::seed_from_u64(22);
        for quorum in [3usize, 5, 7, 9] {
            for _ in 0..10 {
                let set = rng.sample_indices(n, quorum);
                let (got, partial) = decode_estimate(&code, &transmitted, &set);
                let measured = l2_diff(&got, &want);
                let bound = partial.error_bound(&norms);
                let slack = 1e-3 * max_norm * n as f64;
                assert!(
                    measured <= bound + slack,
                    "quorum {quorum} set {set:?}: measured {measured} > bound {bound}"
                );
                // the uniform bound dominates the norm-aware one
                assert!(partial.uniform_error_bound(max_norm) + 1e-12 >= bound);
            }
        }
    }

    #[test]
    fn decode_weights_trait_path_matches_partial() {
        let code = ApproxCode::new(8, 3, 5).unwrap();
        let set = [0usize, 2, 3, 6, 7];
        let dw = code.decode_weights(&set).unwrap();
        let partial = code.partial_decode(&set).unwrap();
        assert_eq!(dw.used, partial.weights.used);
        assert_eq!(dw.weights, partial.weights.weights);
        assert_eq!(dw.m, 1);
        assert_eq!(
            code.decode_residual(&set),
            Some(partial.coeff_residual),
            "trait residual must match partial_decode"
        );
        let (dw2, res2) = code.decode_weights_with_residual(&set).unwrap();
        assert_eq!(dw2.weights, partial.weights.weights);
        assert_eq!(res2, Some(partial.coeff_residual));
    }

    #[test]
    fn rejects_empty_and_out_of_range() {
        let code = ApproxCode::new(5, 2, 3).unwrap();
        assert!(matches!(
            code.partial_decode(&[]),
            Err(CodingError::NotEnoughWorkers { .. })
        ));
        assert!(matches!(
            code.partial_decode(&[0, 5]),
            Err(CodingError::WorkerOutOfRange(5))
        ));
    }

    #[test]
    fn ls_decode_matches_approx_partial_decode() {
        // For ApproxCode (m = 1, BV = Aᵀ) the generic solver's normal
        // equations are literally the same system.
        let code = ApproxCode::new(8, 3, 5).unwrap();
        for set in [vec![0usize, 2, 3, 6, 7], vec![1, 4], (0..8).collect()] {
            let ls = ls_partial_decode(&code, &set).unwrap();
            let partial = code.partial_decode(&set).unwrap();
            assert!(
                (ls.coeff_residual - partial.coeff_residual).abs() < 1e-9,
                "set {set:?}: {} vs {}",
                ls.coeff_residual,
                partial.coeff_residual
            );
            // full-set shortcut aside, the weights agree too
            if set.len() < 8 {
                for (a, b) in ls.weights.weights.iter().zip(&partial.weights.weights) {
                    assert!((a - b).abs() < 1e-7, "{a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn ls_decode_is_exact_for_poly_at_n_minus_s() {
        use crate::coding::PolynomialCode;
        for (n, s, m) in [(6usize, 2usize, 1usize), (6, 1, 2)] {
            let code =
                PolynomialCode::new(crate::coding::SchemeConfig::tight(n, s, m).unwrap())
                    .unwrap();
            let l = 4 * m;
            let grads = random_grads(n, l, 31 + n as u64);
            let transmitted: Vec<Vec<f32>> = (0..n)
                .map(|w| {
                    let views: Vec<&[f32]> = code
                        .placement()
                        .assigned(w)
                        .iter()
                        .map(|&t| grads[t].as_slice())
                        .collect();
                    Encoder::new(&code, w).unwrap().encode(&views).unwrap()
                })
                .collect();
            let avail: Vec<usize> = (0..n - s).collect();
            let ls = ls_partial_decode(&code, &avail).unwrap();
            assert!(
                ls.coeff_residual < 1e-5,
                "(n={n},s={s},m={m}): exact-capable set has residual {}",
                ls.coeff_residual
            );
            let dec = Decoder::from_weights(&ls.weights);
            let fs: Vec<&[f32]> = dec
                .used_workers()
                .iter()
                .map(|&w| transmitted[w].as_slice())
                .collect();
            let got = dec.decode(&fs).unwrap();
            let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
            let want = sum_gradients(&views);
            let scale =
                l2(&want.iter().map(|&x| x as f64).collect::<Vec<_>>()).max(1e-12);
            assert!(
                l2_diff(&got, &want) / scale < 1e-3,
                "(n={n},s={s},m={m}): rel err {}",
                l2_diff(&got, &want) / scale
            );
        }
    }

    #[test]
    fn ls_decode_below_quorum_is_finite_with_positive_residual() {
        use crate::coding::PolynomialCode;
        let code =
            PolynomialCode::new(crate::coding::SchemeConfig::tight(6, 1, 1).unwrap())
                .unwrap();
        // 3 responders where exact decode needs 5: approximate territory.
        let ls = ls_partial_decode(&code, &[0, 2, 4]).unwrap();
        assert!(ls.coeff_residual > 1e-3, "short set cannot be exact");
        assert!(ls.coeff_residual.is_finite());
        assert!(ls.weights.weights.iter().all(|w| w.is_finite()));
        assert_eq!(ls.weights.used, vec![0, 2, 4]);
    }

    #[test]
    fn ls_decode_uncoded_gives_unit_weights_and_sqrt_residual() {
        use crate::coding::UncodedScheme;
        let code = UncodedScheme::new(5);
        let ls = ls_partial_decode(&code, &[0, 1, 3]).unwrap();
        for w in &ls.weights.weights {
            assert!((w - 1.0).abs() < 1e-9, "uncoded weight {w}");
        }
        assert!(
            (ls.coeff_residual - (2.0f64).sqrt()).abs() < 1e-9,
            "two missing subsets -> residual sqrt(2), got {}",
            ls.coeff_residual
        );
    }

    #[test]
    fn ls_decode_validates_input() {
        let code = ApproxCode::new(5, 2, 3).unwrap();
        assert!(matches!(
            ls_partial_decode(&code, &[]),
            Err(CodingError::NotEnoughWorkers { .. })
        ));
        assert!(matches!(
            ls_partial_decode(&code, &[0, 5]),
            Err(CodingError::WorkerOutOfRange(5))
        ));
        assert!(ls_partial_decode(&code, &[1, 1]).is_err());
    }

    #[test]
    fn matrix_bv_has_coefficient_semantics() {
        // BV entry (t, w) = coefficient of g_t in f_w, matching the exact
        // schemes' convention.
        let code = ApproxCode::new(6, 2, 4).unwrap();
        let bv = code.matrix_b().matmul(&code.matrix_v());
        for t in 0..6 {
            for w in 0..6 {
                let want = if code.placement().is_assigned(w, t) { 0.5 } else { 0.0 };
                assert!((bv[(t, w)] - want).abs() < 1e-15, "BV[{t},{w}]");
            }
        }
    }
}
