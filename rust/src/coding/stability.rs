//! Numerical-stability certification (§III-C, §IV, Theorem 2).
//!
//! Three tools:
//! - [`max_condition_number`]: worst condition number of the decode
//!   operator over straggler patterns (exhaustive when the pattern count
//!   is small, seeded-sampled otherwise) — the quantity Theorem 2 bounds
//!   by `κ`.
//! - [`reconstruction_error`]: measured end-to-end ℓ∞ relative error of
//!   encode→straggle→decode round trips — reproduces the §III-C numbers
//!   (≲0.2% for n ≤ 20 Vandermonde, ~80% at n = 23, blow-up at n = 26,
//!   stable ≤ 30 for Gaussian).
//! - [`gamma_gaussian`]: Monte-Carlo estimate of the function
//!   `γ(n, n₁, n₂, κ)` from Theorem 2 for Gaussian `V` (smallest
//!   responder count whose worst-case Gram condition number stays ≤ κ).

use super::{Decoder, Encoder, GradientCode};
use crate::linalg::{condition_number, Matrix};
use crate::rngs::{Pcg64, Rng};

/// Result of a condition-number sweep.
#[derive(Debug, Clone)]
pub struct StabilityReport {
    /// Worst condition number seen.
    pub worst_cond: f64,
    /// Straggler pattern (worker ids that were dropped) achieving it.
    pub worst_stragglers: Vec<usize>,
    /// Number of straggler patterns evaluated.
    pub patterns: usize,
    /// Whether the sweep was exhaustive over all C(n, s) patterns.
    pub exhaustive: bool,
}

/// Condition number of the decode operator for one responder set:
/// `cond(V_F)` when square (`|F| = n-s`), `cond(V_F V_F^T)` otherwise —
/// the latter is the quantity in Theorem 2's definition of γ.
pub fn decode_condition(v: &Matrix, responders: &[usize]) -> f64 {
    let g = v.select_cols(responders);
    if g.cols() == g.rows() {
        condition_number(&g)
    } else {
        let gram = g.matmul(&g.transpose());
        condition_number(&gram)
    }
}

/// Sweep straggler patterns of size exactly `s`. Exhaustive when
/// `C(n, s) <= budget`, otherwise `budget` seeded-random patterns.
pub fn max_condition_number(
    code: &dyn GradientCode,
    budget: usize,
    seed: u64,
) -> StabilityReport {
    let cfg = *code.config();
    let v = code.matrix_v();
    let total = binomial(cfg.n, cfg.s);
    let mut worst = (0.0f64, Vec::new());
    let mut patterns = 0usize;
    let mut consider = |stragglers: &[usize]| {
        let responders: Vec<usize> =
            (0..cfg.n).filter(|w| !stragglers.contains(w)).collect();
        let c = decode_condition(&v, &responders);
        patterns += 1;
        if c > worst.0 {
            worst = (c, stragglers.to_vec());
        }
    };
    let exhaustive = total <= budget as u128;
    if exhaustive {
        let mut pattern = Vec::new();
        enumerate_subsets(cfg.n, cfg.s, 0, &mut pattern, &mut consider);
    } else {
        let mut rng = Pcg64::seed_from_u64(seed);
        for _ in 0..budget {
            let st = rng.sample_indices(cfg.n, cfg.s);
            consider(&st);
        }
    }
    StabilityReport {
        worst_cond: worst.0,
        worst_stragglers: worst.1,
        patterns,
        exhaustive,
    }
}

/// Worst measured ℓ∞ relative reconstruction error over `trials`
/// random-gradient round trips with random straggler patterns.
/// Returns `f64::INFINITY` if any decode fails outright (the paper's
/// "our algorithm crushes" regime at n = 26).
pub fn reconstruction_error(
    code: &dyn GradientCode,
    l: usize,
    trials: usize,
    seed: u64,
) -> f64 {
    let cfg = *code.config();
    let mut rng = Pcg64::seed_from_u64(seed);
    // Pre-build encoders once (they are per-worker, pattern-independent).
    let encoders: Vec<Encoder> = match (0..cfg.n)
        .map(|w| Encoder::new(code, w))
        .collect::<Result<Vec<_>, _>>()
    {
        Ok(e) => e,
        Err(_) => return f64::INFINITY,
    };
    let mut worst = 0.0f64;
    for _ in 0..trials {
        let grads: Vec<Vec<f32>> = (0..cfg.n)
            .map(|_| (0..l).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect())
            .collect();
        let mut transmitted = Vec::with_capacity(cfg.n);
        for w in 0..cfg.n {
            let views: Vec<&[f32]> = code
                .placement()
                .assigned(w)
                .iter()
                .map(|&t| grads[t].as_slice())
                .collect();
            match encoders[w].encode(&views) {
                Ok(f) => transmitted.push(f),
                Err(_) => return f64::INFINITY,
            }
        }
        let stragglers = rng.sample_indices(cfg.n, cfg.s);
        let available: Vec<usize> =
            (0..cfg.n).filter(|w| !stragglers.contains(w)).collect();
        let dec = match Decoder::new(code, &available) {
            Ok(d) => d,
            Err(_) => return f64::INFINITY,
        };
        let fs: Vec<&[f32]> =
            dec.used_workers().iter().map(|&w| transmitted[w].as_slice()).collect();
        let got = match dec.decode(&fs) {
            Ok(g) => g,
            Err(_) => return f64::INFINITY,
        };
        // oracle
        let mut want = vec![0.0f64; l];
        for g in &grads {
            for (o, &x) in want.iter_mut().zip(g.iter()) {
                *o += x as f64;
            }
        }
        let scale = want.iter().fold(0.0f64, |a, &x| a.max(x.abs())).max(1e-30);
        let err = got
            .iter()
            .zip(&want)
            .fold(0.0f64, |a, (&x, &y)| a.max((x as f64 - y).abs()))
            / scale;
        if !err.is_finite() {
            return f64::INFINITY;
        }
        worst = worst.max(err);
    }
    worst
}

/// Same round trip in f64 end to end — the paper's precision (§III-C was
/// measured in Python doubles). Use this to reproduce the paper's
/// stability table; [`reconstruction_error`] measures the deployed f32
/// payload path instead.
pub fn reconstruction_error_f64(
    code: &dyn GradientCode,
    l: usize,
    trials: usize,
    seed: u64,
) -> f64 {
    let cfg = *code.config();
    let m = cfg.m;
    if l % m != 0 {
        return f64::INFINITY;
    }
    let lv = l / m;
    let mut rng = Pcg64::seed_from_u64(seed);
    let coeffs: Vec<Vec<f64>> = match (0..cfg.n)
        .map(|w| code.encode_coeffs(w))
        .collect::<Result<Vec<_>, _>>()
    {
        Ok(c) => c,
        Err(_) => return f64::INFINITY,
    };
    let mut worst = 0.0f64;
    for _ in 0..trials {
        let grads: Vec<Vec<f64>> = (0..cfg.n)
            .map(|_| (0..l).map(|_| rng.next_f64() * 2.0 - 1.0).collect())
            .collect();
        // encode in f64
        let mut fs: Vec<Vec<f64>> = Vec::with_capacity(cfg.n);
        for w in 0..cfg.n {
            let assigned = code.placement().assigned(w);
            let mut f = vec![0.0f64; lv];
            for (j, &t) in assigned.iter().enumerate() {
                for (v, fv) in f.iter_mut().enumerate() {
                    for u in 0..m {
                        *fv += coeffs[w][j * m + u] * grads[t][v * m + u];
                    }
                }
            }
            fs.push(f);
        }
        let stragglers = rng.sample_indices(cfg.n, cfg.s);
        let available: Vec<usize> =
            (0..cfg.n).filter(|w| !stragglers.contains(w)).collect();
        let dw = match code.decode_weights(&available) {
            Ok(d) => d,
            Err(_) => return f64::INFINITY,
        };
        let mut got = vec![0.0f64; l];
        for (i, &w) in dw.used.iter().enumerate() {
            for v in 0..lv {
                for u in 0..m {
                    got[v * m + u] += dw.weight(i, u) * fs[w][v];
                }
            }
        }
        let mut want = vec![0.0f64; l];
        for g in &grads {
            for (o, &x) in want.iter_mut().zip(g.iter()) {
                *o += x;
            }
        }
        let scale = want.iter().fold(0.0f64, |a, &x| a.max(x.abs())).max(1e-30);
        let err = got
            .iter()
            .zip(&want)
            .fold(0.0f64, |a, (&x, &y)| a.max((x - y).abs()))
            / scale;
        if !err.is_finite() {
            return f64::INFINITY;
        }
        worst = worst.max(err);
    }
    worst
}

/// Monte-Carlo estimate of Theorem 2's `γ(n, n₁, ·, κ)` for Gaussian `V`:
/// the smallest responder count `n₃ >= n₁` such that the sampled maximum
/// of `cond(V_F V_F^T)` over `|F| = n₃` is `<= κ`. Returns `None` if even
/// `n₃ = n` exceeds `κ`.
pub fn gamma_gaussian(
    n: usize,
    n1: usize,
    kappa: f64,
    trials: usize,
    seed: u64,
) -> Option<usize> {
    assert!(n1 <= n);
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut normal = crate::rngs::Normal::new();
    let v = Matrix::from_fn(n1, n, |_, _| normal.sample(&mut rng));
    'outer: for n3 in n1..=n {
        let total = binomial(n, n - n3);
        let mut worst = 0.0f64;
        if total <= trials as u128 {
            let mut pattern = Vec::new();
            let mut check = |stragglers: &[usize]| {
                let f: Vec<usize> = (0..n).filter(|w| !stragglers.contains(w)).collect();
                worst = worst.max(decode_condition(&v, &f));
            };
            enumerate_subsets(n, n - n3, 0, &mut pattern, &mut check);
        } else {
            for _ in 0..trials {
                let f = rng.sample_indices(n, n3);
                worst = worst.max(decode_condition(&v, &f));
            }
        }
        if worst <= kappa {
            return Some(n3);
        }
        if n3 == n {
            break 'outer;
        }
    }
    None
}

/// C(n, k) in u128 (saturating; only used to pick exhaustive vs sampled).
fn binomial(n: usize, k: usize) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc.saturating_mul((n - i) as u128) / (i + 1) as u128;
    }
    acc
}

fn enumerate_subsets(
    n: usize,
    k: usize,
    start: usize,
    pattern: &mut Vec<usize>,
    f: &mut impl FnMut(&[usize]),
) {
    if pattern.len() == k {
        f(pattern);
        return;
    }
    for i in start..n {
        pattern.push(i);
        enumerate_subsets(n, k, i + 1, pattern, f);
        pattern.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{PolynomialCode, RandomCode, SchemeConfig};

    #[test]
    fn binomial_values() {
        assert_eq!(binomial(5, 2), 10);
        assert_eq!(binomial(20, 0), 1);
        assert_eq!(binomial(10, 10), 1);
        assert_eq!(binomial(30, 15), 155117520);
    }

    #[test]
    fn exhaustive_sweep_counts_patterns() {
        let code = PolynomialCode::new(SchemeConfig::tight(6, 2, 1).unwrap()).unwrap();
        let rep = max_condition_number(&code, 1000, 0);
        assert!(rep.exhaustive);
        assert_eq!(rep.patterns, 15); // C(6,2)
        assert!(rep.worst_cond >= 1.0);
    }

    #[test]
    fn vandermonde_n20_error_small_as_paper_claims() {
        let code = PolynomialCode::new(SchemeConfig::tight(20, 2, 2).unwrap()).unwrap();
        let err = reconstruction_error(&code, 40, 10, 1);
        // §III-C: "when n <= 20 ... relative error less than 0.2%"
        assert!(err < 2e-3, "n=20 rel err {err}");
    }

    #[test]
    fn f64_roundtrip_matches_paper_precision_regime() {
        // In the paper's (double) precision, the n=20 Vandermonde scheme is
        // far below the 0.2% bound in the regime its experiments exercise
        // (m <= 2; the best Fig. 3 configs use small m). Measured boundary
        // for larger m is reported by the stability bench + EXPERIMENTS.md.
        let code = PolynomialCode::new(SchemeConfig::tight(20, 2, 2).unwrap()).unwrap();
        let err = reconstruction_error_f64(&code, 40, 5, 4);
        assert!(err < 2e-3, "n=20 m=2 f64 rel err {err}");
        // And the f64 path is no worse than the f32 path on easy configs.
        let easy = PolynomialCode::new(SchemeConfig::tight(8, 2, 2).unwrap()).unwrap();
        let e32 = reconstruction_error(&easy, 32, 5, 5);
        let e64 = reconstruction_error_f64(&easy, 32, 5, 5);
        assert!(e64 <= e32 * 1.5 + 1e-12, "f64 {e64} vs f32 {e32}");
    }

    #[test]
    fn gaussian_beats_vandermonde_at_n26() {
        // §IV's motivation, measured: at n=26 the Vandermonde scheme is
        // unusable while the Gaussian scheme still reconstructs.
        let cfg = SchemeConfig::tight(26, 2, 2).unwrap();
        let vander = PolynomialCode::new(cfg).unwrap();
        let gauss = RandomCode::new(cfg, 9).unwrap();
        let ev = reconstruction_error_f64(&vander, 52, 5, 6);
        let eg = reconstruction_error_f64(&gauss, 52, 5, 6);
        assert!(ev > 1e-3, "vandermonde unexpectedly fine at n=26: {ev}");
        assert!(eg < 1e-6, "gaussian should be stable at n=26: {eg}");
    }

    #[test]
    fn vandermonde_n26_blows_up() {
        let code = PolynomialCode::new(SchemeConfig::tight(26, 3, 2).unwrap()).unwrap();
        let err = reconstruction_error(&code, 40, 10, 2);
        // §III-C: "when n = 26, our algorithm crushes" — anything beyond a
        // few percent counts as unusable; typically it is O(1) or worse.
        assert!(err > 0.05, "n=26 rel err unexpectedly small: {err}");
    }

    #[test]
    fn gaussian_n30_stays_stable() {
        let code = RandomCode::new(SchemeConfig::tight(30, 3, 3).unwrap(), 5).unwrap();
        let err = reconstruction_error(&code, 60, 5, 3);
        assert!(err < 5e-2, "n=30 Gaussian rel err {err}");
    }

    #[test]
    fn gamma_is_monotone_in_kappa() {
        let g_loose = gamma_gaussian(16, 12, 1e6, 60, 11);
        let g_tight = gamma_gaussian(16, 12, 1e2, 60, 11);
        let gl = g_loose.unwrap();
        if let Some(gt) = g_tight {
            assert!(gt >= gl, "γ must not decrease as κ tightens: {gt} < {gl}");
        }
        assert!(gl >= 12);
    }
}
