//! Theorem 1's converse side: feasibility checks.
//!
//! `is_achievable` is the tradeoff inequality (Eq. 4) in exact integer
//! arithmetic; `verify_placement_bound` checks Claim 1 (every data subset
//! must be held by at least `s + m` workers) against a concrete placement
//! — the structural fact the converse proof rests on.

use super::Placement;

/// Theorem 1: `(d, s, m)` is achievable for `(n, k)` iff
/// `d/k >= (s+m)/n`, evaluated as `d·n >= k·(s+m)` in integers.
pub fn is_achievable(n: usize, k: usize, d: usize, s: usize, m: usize) -> bool {
    if n == 0 || k == 0 || d == 0 || m == 0 || d > k || s >= n {
        return false;
    }
    d * n >= k * (s + m)
}

/// Claim 1 check: with straggler tolerance `s` and reduction factor `m`,
/// every subset must appear on at least `s + m` workers.
pub fn verify_placement_bound(p: &Placement, s: usize, m: usize) -> bool {
    (0..p.n()).all(|t| p.holders(t).len() >= s + m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{self, gen, Config};

    #[test]
    fn tight_triples_achievable() {
        assert!(is_achievable(5, 5, 3, 2, 1));
        assert!(is_achievable(5, 5, 3, 1, 2));
        assert!(!is_achievable(5, 5, 3, 2, 2));
        // k != n: d/k >= (s+m)/n
        assert!(is_achievable(4, 8, 6, 2, 1)); // 6/8 >= 3/4
        assert!(!is_achievable(4, 8, 5, 2, 1)); // 5/8 < 3/4
    }

    #[test]
    fn degenerate_inputs_rejected() {
        assert!(!is_achievable(0, 5, 1, 0, 1));
        assert!(!is_achievable(5, 5, 0, 0, 1));
        assert!(!is_achievable(5, 5, 6, 0, 1));
        assert!(!is_achievable(5, 5, 3, 5, 1));
        assert!(!is_achievable(5, 5, 3, 1, 0));
    }

    #[test]
    fn cyclic_placement_meets_claim1_exactly_at_tight_point() {
        // d = s + m: cyclic placement puts each subset on exactly d workers.
        let p = Placement::cyclic(7, 4);
        assert!(verify_placement_bound(&p, 2, 2)); // s+m = 4 = d
        assert!(!verify_placement_bound(&p, 3, 2)); // s+m = 5 > d
    }

    #[test]
    fn property_tight_random_triples_are_achievable_and_placed() {
        testkit::check_bool(
            Config { cases: 128, seed: 0xb0 },
            "tight-triples-achievable",
            |rng| gen::scheme_triple(rng, 2, 24),
            |&(n, d, s, m)| {
                is_achievable(n, n, d, s, m)
                    && verify_placement_bound(&Placement::cyclic(n, d), s, m)
                    && verify_placement_bound(&Placement::cyclic_shifted(n, d), s, m)
            },
        );
    }

    #[test]
    fn property_violations_never_pass() {
        // d = s + m - 1 must always be rejected (when still >= 1).
        testkit::check_bool(
            Config { cases: 128, seed: 0xb1 },
            "sub-threshold-rejected",
            |rng| gen::scheme_triple(rng, 3, 24),
            |&(n, d, s, m)| {
                if d == 1 {
                    return true; // can't go below
                }
                !is_achievable(n, n, d - 1, s, m)
            },
        );
    }
}
