//! Heterogeneous (group-based) exact gradient coding.
//!
//! The §III/§IV constructions assume `n` *identical* workers: every
//! worker gets the same load `d` over equal-size subsets, and the master
//! waits for any `n - s`. Real fleets are heterogeneous; "Optimal
//! Communication-Computation Trade-Off in Heterogeneous Gradient Coding"
//! (Jahani-Nezhad & Maddah-Ali) shows the optimal response is *unequal
//! per-worker loads realized through group-based codes*. [`HeteroCode`]
//! is that idea expressed through this crate's [`GradientCode`] seam:
//!
//! 1. **Groups.** Workers are partitioned into groups of similar speed.
//!    Group `g` (size `n_g`) owns a contiguous slice of `n_g` data
//!    subsets and runs its *own* §III polynomial code over them with a
//!    group-local load `d_g >= s + m` (tight inner frontier
//!    `s_g = d_g - m`). The total sum gradient is the sum of the per-group
//!    slice sums, so the master simply concatenates the groups' decode
//!    weights — decode stays **exact**.
//! 2. **Straggler tolerance.** Each group independently tolerates
//!    `s_g = d_g - m >= s` stragglers, so *any* global pattern of at most
//!    `s` stragglers is admissible (each group sees at most `s <= s_g` of
//!    them). Groups with slack (`d_g > s + m`) let the master stop the
//!    gather before their slow tail — see
//!    [`GradientCode::group_quorums`].
//! 3. **Speed-proportional placement.** Subset *sizes* scale with the
//!    owning group's speed ([`GradientCode::subset_weights`]): group `g`'s
//!    subsets hold a `w_g` multiple of the baseline `rows/n` rows, chosen
//!    so per-worker compute time `d_g·w_g/σ_g` is balanced across groups.
//!    Fast workers therefore carry more data; slow workers carry less —
//!    instead of being written off as permanent stragglers.
//!
//! The homogeneous schemes are the uniform-speed special case: a single
//! group with `d = s + m` and weight 1 is exactly the §III code.
//!
//! Feasibility: every group needs `n_g >= d_g >= s + m` subsets/workers,
//! so the total load satisfies `Σ_w d_w >= n·(s+m)` — the Theorem 1
//! budget paid once per group instead of once globally.
//!
//! The matching runtime model (per-worker shifted exponentials scaled by
//! speed and load, expected iteration time under the group quorum rule,
//! and the `plan_loads` optimizer) lives in [`crate::simulator::hetero`].
//!
//! # Example
//!
//! ```
//! use gradcode::coding::{Decoder, Encoder, GradientCode, HeteroCode};
//!
//! // 6 workers: three at baseline speed, three 4x faster; tolerate s = 1
//! // straggler at m = 2 communication reduction.
//! let speeds = [1.0, 1.0, 1.0, 4.0, 4.0, 4.0];
//! let code = HeteroCode::from_speeds(6, 1, 2, &speeds).unwrap();
//!
//! // Fast workers carry more rows per subset than slow ones.
//! let ws = code.subset_weights().unwrap();
//! assert!(ws[5] > ws[0]);
//!
//! // Exact decode from any n - s = 5 responders.
//! let grads: Vec<Vec<f32>> = (0..6).map(|t| vec![t as f32; 4]).collect();
//! let transmitted: Vec<Vec<f32>> = (0..6)
//!     .map(|w| {
//!         let views: Vec<&[f32]> = code
//!             .placement()
//!             .assigned(w)
//!             .iter()
//!             .map(|&t| grads[t].as_slice())
//!             .collect();
//!         Encoder::new(&code, w).unwrap().encode(&views).unwrap()
//!     })
//!     .collect();
//! let dec = Decoder::new(&code, &[0, 1, 3, 4, 5]).unwrap(); // worker 2 straggles
//! let fs: Vec<&[f32]> = dec.used_workers().iter().map(|&w| transmitted[w].as_slice()).collect();
//! let sum = dec.decode(&fs).unwrap();
//! assert!((sum[0] - 15.0).abs() < 1e-3); // 0+1+2+3+4+5
//! ```

use super::{
    CodingError, DecodeWeights, GradientCode, Placement, PolynomialCode, SchemeConfig,
};
use crate::linalg::Matrix;

/// Per-subset bookkeeping overhead, in baseline-subset compute units per
/// assigned subset. This is what keeps "replicate everything inside the
/// group" from being a free lunch in the model: raising `d_g` buys
/// straggler tolerance but costs `SUBSET_OVERHEAD·t₁` of deterministic
/// compute per extra subset. Used identically by
/// [`HeteroCode::compute_units`] (which drives the virtual cluster's
/// delay injection) and by the [`crate::simulator::hetero`] predictions,
/// so predicted and realized times stay comparable.
pub const SUBSET_OVERHEAD: f64 = 0.05;

/// Floor for subset-size multipliers: no subset shrinks below 10% of the
/// baseline `rows/n` (keeps every shard trainable and the apportionment
/// well-posed on small datasets).
const MIN_WEIGHT: f64 = 0.1;

/// Speed-tier cut: a new group starts when a worker is more than this
/// factor faster than the slowest worker of the current group.
const TIER_RATIO: f64 = 1.5;

/// Compute-balancing subset weights for a candidate grouping: group `g`
/// of `sizes[g]` workers at mean speed `mean_speed[g]` with load
/// `ds[g]` gets the weight that equalizes per-worker compute time
/// `d_g·(w_g + SUBSET_OVERHEAD)/σ̄_g` across groups, subject to the
/// `MIN_WEIGHT` floor and `Σ_g n_g·w_g = n` (mean subset size
/// preserved). Solving `u_g/σ̄_g = c` with the row budget gives
/// `c = n·(1 + overhead)/Σ_g(n_g·σ̄_g/d_g)` and `w_g = c·σ̄_g/d_g −
/// overhead`. Shared by [`HeteroCode::from_speeds`] and the
/// [`crate::simulator::hetero`] planner so predicted and deployed
/// weights cannot drift apart.
pub fn balanced_group_weights(
    mean_speed: &[f64],
    sizes: &[usize],
    ds: &[usize],
) -> Vec<f64> {
    assert_eq!(mean_speed.len(), sizes.len());
    assert_eq!(ds.len(), sizes.len());
    let k = sizes.len();
    let n: usize = sizes.iter().sum();
    let denom: f64 = sizes
        .iter()
        .zip(mean_speed)
        .zip(ds)
        .map(|((&ng, &sp), &d)| ng as f64 * sp / d as f64)
        .sum();
    let c = n as f64 * (1.0 + SUBSET_OVERHEAD) / denom;
    // Unfloored balance targets (Σ n_g·raw_g = n by construction of c).
    let raw: Vec<f64> = mean_speed
        .iter()
        .zip(ds)
        .map(|(&sp, &d)| c * sp / d as f64 - SUBSET_OVERHEAD)
        .collect();
    // Water-filling against the floor: groups pinned at MIN_WEIGHT keep
    // it exactly; the remaining row budget is split proportionally among
    // the rest, re-pinning anyone the rescale pushes under the floor.
    // Terminates: each pass pins at least one more group, and not all
    // can pin (Σ n_g·MIN_WEIGHT < n).
    let mut pinned = vec![false; k];
    loop {
        let fixed: f64 = sizes
            .iter()
            .zip(&pinned)
            .filter(|(_, &p)| p)
            .map(|(&ng, _)| ng as f64 * MIN_WEIGHT)
            .sum();
        let free_raw: f64 = sizes
            .iter()
            .zip(&raw)
            .zip(&pinned)
            .filter(|(_, &p)| !p)
            .map(|((&ng, &r), _)| ng as f64 * r)
            .sum();
        let scale = (n as f64 - fixed) / free_raw;
        let mut repinned = false;
        for g in 0..k {
            if !pinned[g] && raw[g] * scale < MIN_WEIGHT {
                pinned[g] = true;
                repinned = true;
            }
        }
        if !repinned {
            return raw
                .iter()
                .zip(&pinned)
                .map(|(&r, &p)| if p { MIN_WEIGHT } else { r * scale })
                .collect();
        }
    }
}

/// One group of a heterogeneous plan: which workers, their common load
/// `d`, and the subset-size multiplier `weight` for the group's slice.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupPlan {
    /// Global worker ids (any order; a partition across the plan).
    pub workers: Vec<usize>,
    /// Subsets per worker within the group (`s + m <= d <= workers.len()`).
    pub d: usize,
    /// Relative subset size for the group's slice (baseline 1.0).
    pub weight: f64,
}

/// A built group: plan + slice + inner code.
struct Group {
    workers: Vec<usize>,
    /// Global subset ids of the group's slice (contiguous, `n_g` of them).
    subsets: Vec<usize>,
    d: usize,
    weight: f64,
    /// Inner §III code over the slice: `(n_g, d, d - m, m)`.
    code: PolynomialCode,
}

/// Read-only view of one group (planning/debug surface).
#[derive(Debug, Clone, PartialEq)]
pub struct GroupView<'a> {
    pub workers: &'a [usize],
    pub subsets: &'a [usize],
    pub d: usize,
    pub weight: f64,
    /// Responders the master needs from this group (`n_g - (d - m)`).
    pub need: usize,
}

/// Group-based heterogeneous gradient code (exact recovery).
pub struct HeteroCode {
    /// `d` is the *maximum* per-group load; `wait_for()` is the global
    /// `n - s` (the per-group rule in [`GradientCode::group_quorums`] can
    /// stop the gather earlier).
    cfg: SchemeConfig,
    placement: Placement,
    speeds: Vec<f64>,
    groups: Vec<Group>,
    /// worker id → (group index, local index within the group).
    worker_group: Vec<(usize, usize)>,
    subset_weights: Vec<f64>,
}

impl HeteroCode {
    /// Build from an explicit group plan. `speeds` is recorded for
    /// planning/telemetry (it does not enter the code construction);
    /// weights are renormalized so `Σ_g n_g·w_g = n` (mean subset size
    /// preserved).
    pub fn from_groups(
        s: usize,
        m: usize,
        speeds: &[f64],
        plan: &[GroupPlan],
    ) -> Result<Self, CodingError> {
        let n = speeds.len();
        if n == 0 || m == 0 {
            return Err(CodingError::InvalidConfig(format!(
                "n and m must be positive (n={n}, m={m})"
            )));
        }
        if speeds.iter().any(|&x| !(x.is_finite() && x > 0.0)) {
            return Err(CodingError::InvalidConfig(
                "speeds must be finite and positive".into(),
            ));
        }
        if plan.is_empty() {
            return Err(CodingError::InvalidConfig("empty group plan".into()));
        }
        // Workers must form a partition of 0..n.
        let mut seen = vec![false; n];
        for g in plan {
            if g.workers.is_empty() {
                return Err(CodingError::InvalidConfig("empty group".into()));
            }
            for &w in &g.workers {
                if w >= n {
                    return Err(CodingError::WorkerOutOfRange(w));
                }
                if seen[w] {
                    return Err(CodingError::InvalidConfig(format!(
                        "worker {w} appears in two groups"
                    )));
                }
                seen[w] = true;
            }
            let ng = g.workers.len();
            if g.d < s + m {
                // The global guarantee "any s stragglers" needs every
                // group to tolerate s on its own: d_g - m >= s.
                return Err(CodingError::NotAchievable { n: ng, d: g.d, s, m });
            }
            if g.d > ng {
                return Err(CodingError::InvalidConfig(format!(
                    "group load d={} exceeds group size {ng}",
                    g.d
                )));
            }
            if !(g.weight.is_finite() && g.weight > 0.0) {
                return Err(CodingError::InvalidConfig(format!(
                    "group weight {} must be finite and positive",
                    g.weight
                )));
            }
        }
        if seen.iter().any(|&x| !x) {
            return Err(CodingError::InvalidConfig(
                "group plan does not cover every worker".into(),
            ));
        }

        // Normalize weights: Σ_g n_g·w_g = n keeps the mean subset at the
        // baseline rows/n.
        let raw_total: f64 =
            plan.iter().map(|g| g.workers.len() as f64 * g.weight).sum();
        let norm = n as f64 / raw_total;

        let mut groups = Vec::with_capacity(plan.len());
        let mut worker_group = vec![(0usize, 0usize); n];
        let mut subset_weights = vec![0.0f64; n];
        let mut next_subset = 0usize;
        for (gi, g) in plan.iter().enumerate() {
            let ng = g.workers.len();
            let weight = g.weight * norm;
            let subsets: Vec<usize> = (next_subset..next_subset + ng).collect();
            next_subset += ng;
            for (local, &w) in g.workers.iter().enumerate() {
                worker_group[w] = (gi, local);
            }
            for &t in &subsets {
                subset_weights[t] = weight;
            }
            // Inner §III code over the slice, tight at the group level:
            // s_g = d_g - m.
            let inner_cfg = SchemeConfig::new(ng, g.d, g.d - m, m)?;
            let code = PolynomialCode::new(inner_cfg)?;
            groups.push(Group {
                workers: g.workers.clone(),
                subsets,
                d: g.d,
                weight,
                code,
            });
        }

        // Global placement: worker w's subsets are its group's inner
        // cyclic window translated to the slice's global ids.
        let mut assigned = vec![Vec::new(); n];
        for g in &groups {
            for (local, &w) in g.workers.iter().enumerate() {
                assigned[w] = g
                    .code
                    .placement()
                    .assigned(local)
                    .iter()
                    .map(|&lt| g.subsets[lt])
                    .collect();
            }
        }
        let placement = Placement::explicit(assigned);
        let d_max = groups.iter().map(|g| g.d).fold(0, usize::max);
        if s >= n {
            return Err(CodingError::InvalidConfig(format!("s={s} must be < n={n}")));
        }
        Ok(HeteroCode {
            cfg: SchemeConfig { n, d: d_max, s, m },
            placement,
            speeds: speeds.to_vec(),
            groups,
            worker_group,
            subset_weights,
        })
    }

    /// Build with the default speed-proportional heuristic:
    ///
    /// 1. sort workers by speed and cut into tiers wherever the speed
    ///    jumps by more than [`TIER_RATIO`]×, merging tiers below the
    ///    minimum viable size `s + m`;
    /// 2. give tier `g` the load `d_g = clamp(round((s+m)·σ̄_g/σ̄_min),
    ///    s+m, n_g)` — fast groups buy extra straggler tolerance;
    /// 3. choose subset weights that balance per-worker compute time
    ///    `(d_g·w_g + SUBSET_OVERHEAD·d_g)/σ̄_g` across groups.
    ///
    /// Uniform speeds degenerate to a single group with `d = s + m`:
    /// exactly the §III code. Deterministic — master and remote workers
    /// rebuild identical schemes from the same speed vector.
    pub fn from_speeds(
        n: usize,
        s: usize,
        m: usize,
        speeds: &[f64],
    ) -> Result<Self, CodingError> {
        if speeds.len() != n {
            return Err(CodingError::InvalidConfig(format!(
                "need {n} speeds, got {}",
                speeds.len()
            )));
        }
        if n == 0 || m == 0 {
            return Err(CodingError::InvalidConfig(format!(
                "n and m must be positive (n={n}, m={m})"
            )));
        }
        if speeds.iter().any(|&x| !(x.is_finite() && x > 0.0)) {
            return Err(CodingError::InvalidConfig(
                "speeds must be finite and positive".into(),
            ));
        }
        if s + m > n {
            return Err(CodingError::NotAchievable { n, d: s + m, s, m });
        }

        // Speed-sorted worker order (stable on ties via the id).
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            speeds[a].total_cmp(&speeds[b]).then(a.cmp(&b))
        });

        // Tier by relative speed jumps.
        let mut tiers: Vec<Vec<usize>> = Vec::new();
        for &w in &order {
            match tiers.last_mut() {
                Some(tier)
                    if speeds[w] <= speeds[tier[0]] * TIER_RATIO =>
                {
                    tier.push(w)
                }
                _ => tiers.push(vec![w]),
            }
        }
        // Merge tiers below the minimum viable group size (need
        // n_g >= s + m so that d_g = s + m fits).
        let min_size = s + m;
        let mut i = 0;
        while tiers.len() > 1 && i < tiers.len() {
            if tiers[i].len() < min_size {
                // Merge into the adjacent tier with the closer mean speed
                // (ends have only one neighbor).
                let mean = |t: &[usize]| {
                    t.iter().map(|&w| speeds[w]).sum::<f64>() / t.len() as f64
                };
                let into = if i == 0 {
                    1
                } else if i + 1 == tiers.len() {
                    i - 1
                } else if (mean(&tiers[i]) - mean(&tiers[i - 1])).abs()
                    <= (mean(&tiers[i + 1]) - mean(&tiers[i])).abs()
                {
                    i - 1
                } else {
                    i + 1
                };
                let small = tiers.remove(i);
                let into = if into > i { into - 1 } else { into };
                tiers[into].extend(small);
                tiers[into].sort_by(|&a, &b| {
                    speeds[a].total_cmp(&speeds[b]).then(a.cmp(&b))
                });
                i = 0; // re-scan from the start after a merge
            } else {
                i += 1;
            }
        }

        // Loads: proportional to mean group speed, floored at s + m.
        let mean_speed: Vec<f64> = tiers
            .iter()
            .map(|t| t.iter().map(|&w| speeds[w]).sum::<f64>() / t.len() as f64)
            .collect();
        let slowest = mean_speed.iter().cloned().fold(f64::INFINITY, f64::min);
        let ds: Vec<usize> = tiers
            .iter()
            .zip(&mean_speed)
            .map(|(t, &sp)| {
                let want = ((s + m) as f64 * sp / slowest).round() as usize;
                want.clamp(s + m, t.len())
            })
            .collect();

        let sizes: Vec<usize> = tiers.iter().map(|t| t.len()).collect();
        let weights = balanced_group_weights(&mean_speed, &sizes, &ds);

        let plan: Vec<GroupPlan> = tiers
            .into_iter()
            .zip(ds)
            .zip(weights)
            .map(|((workers, d), weight)| GroupPlan { workers, d, weight })
            .collect();
        Self::from_groups(s, m, speeds, &plan)
    }

    /// The per-worker speed vector the code was built for.
    pub fn speeds(&self) -> &[f64] {
        &self.speeds
    }

    /// Per-worker subset loads `d_w` (the Σd_w >= n(s+m) side).
    pub fn loads(&self) -> Vec<usize> {
        (0..self.cfg.n).map(|w| self.placement.load(w)).collect()
    }

    /// The group plan (for wire validation and planner round-trips).
    pub fn plan(&self) -> Vec<GroupPlan> {
        self.groups
            .iter()
            .map(|g| GroupPlan { workers: g.workers.clone(), d: g.d, weight: g.weight })
            .collect()
    }

    /// Read-only group views (workers, slice, load, weight, quorum).
    pub fn groups(&self) -> Vec<GroupView<'_>> {
        self.groups
            .iter()
            .map(|g| GroupView {
                workers: &g.workers,
                subsets: &g.subsets,
                d: g.d,
                weight: g.weight,
                need: g.workers.len() - (g.d - self.cfg.m),
            })
            .collect()
    }
}

impl GradientCode for HeteroCode {
    fn config(&self) -> &SchemeConfig {
        &self.cfg
    }

    fn placement(&self) -> &Placement {
        &self.placement
    }

    fn encode_coeffs(&self, worker: usize) -> Result<Vec<f64>, CodingError> {
        if worker >= self.cfg.n {
            return Err(CodingError::WorkerOutOfRange(worker));
        }
        let (gi, local) = self.worker_group[worker];
        self.groups[gi].code.encode_coeffs(local)
    }

    /// Per-group decode: split the responders by group, decode each
    /// group's slice sum with its inner §III code, concatenate the
    /// weights. Exact whenever every group has at least
    /// `n_g - (d_g - m)` responders — guaranteed for any `n - s`
    /// responders since every `d_g >= s + m`.
    fn decode_weights(&self, available: &[usize]) -> Result<DecodeWeights, CodingError> {
        let n = self.cfg.n;
        let mut seen = vec![false; n];
        for &w in available {
            if w >= n {
                return Err(CodingError::WorkerOutOfRange(w));
            }
            if seen[w] {
                return Err(CodingError::InvalidConfig(format!(
                    "duplicate worker {w} in responder set"
                )));
            }
            seen[w] = true;
        }
        let mut used = Vec::new();
        let mut weights = Vec::new();
        for (gi, g) in self.groups.iter().enumerate() {
            // This group's responders, in arrival order, as local ids.
            let local: Vec<usize> = available
                .iter()
                .filter(|&&w| self.worker_group[w].0 == gi)
                .map(|&w| self.worker_group[w].1)
                .collect();
            let dw = g.code.decode_weights(&local)?;
            for &l in &dw.used {
                used.push(g.workers[l]);
            }
            weights.extend_from_slice(&dw.weights);
        }
        Ok(DecodeWeights { used, weights, m: self.cfg.m })
    }

    /// Block-diagonal stack of the per-group `B` matrices: rows ordered
    /// by global subset id (slices are contiguous), columns by group.
    fn matrix_b(&self) -> Matrix {
        let m = self.cfg.m;
        let total_cols: usize =
            self.groups.iter().map(|g| g.code.matrix_b().cols()).sum();
        let mut b = Matrix::zeros(m * self.cfg.n, total_cols);
        let mut col0 = 0;
        for g in &self.groups {
            let gb = g.code.matrix_b();
            let row0 = m * g.subsets[0];
            for r in 0..gb.rows() {
                for c in 0..gb.cols() {
                    b[(row0 + r, col0 + c)] = gb[(r, c)];
                }
            }
            col0 += gb.cols();
        }
        b
    }

    /// Block-diagonal stack of the per-group evaluation matrices, with
    /// columns scattered to the groups' global worker ids.
    fn matrix_v(&self) -> Matrix {
        let total_rows: usize =
            self.groups.iter().map(|g| g.code.matrix_v().rows()).sum();
        let mut v = Matrix::zeros(total_rows, self.cfg.n);
        let mut row0 = 0;
        for g in &self.groups {
            let gv = g.code.matrix_v();
            for r in 0..gv.rows() {
                for (local, &w) in g.workers.iter().enumerate() {
                    v[(row0 + r, w)] = gv[(r, local)];
                }
            }
            row0 += gv.rows();
        }
        v
    }

    fn subset_weights(&self) -> Option<Vec<f64>> {
        Some(self.subset_weights.clone())
    }

    /// Row-weighted load plus the per-subset overhead:
    /// `d_g·w_g + SUBSET_OVERHEAD·d_g` baseline-subset units.
    fn compute_units(&self, worker: usize) -> f64 {
        let (gi, _) = self.worker_group[worker];
        let g = &self.groups[gi];
        g.d as f64 * (g.weight + SUBSET_OVERHEAD)
    }

    fn group_quorums(&self) -> Option<Vec<(Vec<usize>, usize)>> {
        Some(
            self.groups
                .iter()
                .map(|g| {
                    (g.workers.clone(), g.workers.len() - (g.d - self.cfg.m))
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::decode::sum_gradients;
    use crate::coding::{Decoder, Encoder};
    use crate::rngs::{Pcg64, Rng};

    fn random_grads(n: usize, l: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seed_from_u64(seed);
        (0..n)
            .map(|_| (0..l).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect())
            .collect()
    }

    fn transmit_all(code: &HeteroCode, grads: &[Vec<f32>]) -> Vec<Vec<f32>> {
        (0..code.config().n)
            .map(|w| {
                let views: Vec<&[f32]> = code
                    .placement()
                    .assigned(w)
                    .iter()
                    .map(|&t| grads[t].as_slice())
                    .collect();
                Encoder::new(code, w).unwrap().encode(&views).unwrap()
            })
            .collect()
    }

    fn roundtrip_err(code: &HeteroCode, available: &[usize], l: usize, seed: u64) -> f64 {
        let n = code.config().n;
        let grads = random_grads(n, l, seed);
        let transmitted = transmit_all(code, &grads);
        let dec = Decoder::new(code, available).unwrap();
        let fs: Vec<&[f32]> =
            dec.used_workers().iter().map(|&w| transmitted[w].as_slice()).collect();
        let got = dec.decode(&fs).unwrap();
        let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
        let want = sum_gradients(&views);
        let scale = want.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
        got.iter()
            .zip(&want)
            .fold(0.0f64, |a, (&x, &y)| a.max((x - y).abs() as f64))
            / scale as f64
    }

    fn bimodal(n: usize, slow: usize, ratio: f64) -> Vec<f64> {
        (0..n).map(|w| if w < slow { 1.0 } else { ratio }).collect()
    }

    #[test]
    fn uniform_speeds_degenerate_to_single_tight_group() {
        let code = HeteroCode::from_speeds(6, 1, 2, &[1.0; 6]).unwrap();
        let groups = code.groups();
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].d, 3, "single group is tight: d = s + m");
        assert_eq!(groups[0].need, 5, "need n - s responders");
        assert!((groups[0].weight - 1.0).abs() < 1e-12);
        assert_eq!(code.config().d, 3);
        assert_eq!(code.loads(), vec![3; 6]);
        // exact under every single-straggler pattern
        for straggler in 0..6 {
            let avail: Vec<usize> = (0..6).filter(|&w| w != straggler).collect();
            let err = roundtrip_err(&code, &avail, 8, 3 + straggler as u64);
            assert!(err < 1e-4, "straggler {straggler}: rel err {err}");
        }
    }

    #[test]
    fn bimodal_splits_into_two_groups_with_skewed_weights() {
        let speeds = bimodal(10, 5, 4.0);
        let code = HeteroCode::from_speeds(10, 1, 2, &speeds).unwrap();
        let groups = code.groups();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].workers, &[0, 1, 2, 3, 4], "slow tier first");
        assert_eq!(groups[1].workers, &[5, 6, 7, 8, 9]);
        assert_eq!(groups[0].d, 3, "slow group at the floor d = s + m");
        assert!(groups[1].d > 3, "fast group buys extra tolerance");
        assert!(groups[1].need < groups[0].need);
        assert!(
            groups[1].weight > groups[0].weight,
            "fast subsets must be bigger: {} vs {}",
            groups[1].weight,
            groups[0].weight
        );
        // weights normalized: mean subset size = baseline
        let ws = code.subset_weights().unwrap();
        let total: f64 = ws.iter().sum();
        assert!((total - 10.0).abs() < 1e-9, "Σ weights = n, got {total}");
        // compute balanced: per-worker units / speed roughly equal
        let per_speed: Vec<f64> =
            (0..10).map(|w| code.compute_units(w) / speeds[w]).collect();
        let (lo, hi) = per_speed
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), &x| (lo.min(x), hi.max(x)));
        assert!(hi / lo < 1.3, "compute imbalance {per_speed:?}");
        // feasibility budget
        assert!(code.placement().total_load() >= 10 * 3);
    }

    #[test]
    fn decodes_exactly_under_every_s_straggler_pattern() {
        let speeds = bimodal(8, 4, 4.0);
        let code = HeteroCode::from_speeds(8, 1, 1, &speeds).unwrap();
        for straggler in 0..8 {
            let avail: Vec<usize> = (0..8).filter(|&w| w != straggler).collect();
            let err = roundtrip_err(&code, &avail, 6, 11 + straggler as u64);
            assert!(err < 1e-4, "straggler {straggler}: rel err {err}");
        }
        // s = 2 pattern sweep on a linear fleet
        let speeds: Vec<f64> = (0..9).map(|w| 1.0 + 0.5 * w as f64).collect();
        let code = HeteroCode::from_speeds(9, 2, 1, &speeds).unwrap();
        for a in 0..9 {
            for b in a + 1..9 {
                let avail: Vec<usize> =
                    (0..9).filter(|&w| w != a && w != b).collect();
                let err = roundtrip_err(&code, &avail, 5, (a * 9 + b) as u64);
                assert!(err < 1e-4, "stragglers ({a},{b}): rel err {err}");
            }
        }
    }

    #[test]
    fn group_quorum_sets_decode_too() {
        // The per-group rule admits sets smaller than n - s when a group
        // has slack: drop d_g - m from each group simultaneously.
        let speeds = bimodal(10, 5, 4.0);
        let code = HeteroCode::from_speeds(10, 1, 2, &speeds).unwrap();
        let quorums = code.group_quorums().unwrap();
        let mut avail = Vec::new();
        for (members, need) in &quorums {
            avail.extend_from_slice(&members[..*need]);
        }
        assert!(
            avail.len() < 9,
            "per-group minimum {} should beat n - s = 9",
            avail.len()
        );
        avail.sort_unstable();
        let err = roundtrip_err(&code, &avail, 8, 77);
        assert!(err < 1e-4, "rel err {err}");
    }

    #[test]
    fn insufficient_group_responders_fail_cleanly() {
        let speeds = bimodal(10, 5, 4.0);
        let code = HeteroCode::from_speeds(10, 1, 2, &speeds).unwrap();
        // All fast workers but only 3 of 5 slow ones (slow need is 4).
        let avail = [0usize, 1, 2, 5, 6, 7, 8, 9];
        assert!(matches!(
            code.decode_weights(&avail),
            Err(CodingError::NotEnoughWorkers { .. })
        ));
        assert!(matches!(
            code.decode_weights(&[0, 0, 1]),
            Err(CodingError::InvalidConfig(_))
        ));
        assert!(matches!(
            code.decode_weights(&[0, 99]),
            Err(CodingError::WorkerOutOfRange(99))
        ));
    }

    #[test]
    fn matrix_bv_has_coefficient_semantics() {
        let speeds = bimodal(7, 4, 3.0);
        let code = HeteroCode::from_speeds(7, 1, 1, &speeds).unwrap();
        let bv = code.matrix_b().matmul(&code.matrix_v());
        for t in 0..7 {
            for w in 0..7 {
                let val = bv[(t, w)];
                if !code.placement().is_assigned(w, t) {
                    assert!(val.abs() < 1e-7, "BV[{t},{w}] = {val} should vanish");
                }
            }
        }
        // Encode coeffs must match the BV columns restricted to the
        // worker's assignment (same invariant as the exact schemes).
        for w in 0..7 {
            let coeffs = code.encode_coeffs(w).unwrap();
            let assigned = code.placement().assigned(w);
            for (j, &t) in assigned.iter().enumerate() {
                let want = bv[(t, w)];
                let got = coeffs[j];
                assert!((got - want).abs() < 1e-8, "w={w} t={t}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn from_groups_validates() {
        let sp = [1.0, 1.0, 2.0, 2.0];
        let mk = |workers: Vec<Vec<usize>>, d: Vec<usize>| {
            let plan: Vec<GroupPlan> = workers
                .into_iter()
                .zip(d)
                .map(|(workers, d)| GroupPlan { workers, d, weight: 1.0 })
                .collect();
            HeteroCode::from_groups(1, 1, &sp, &plan)
        };
        assert!(mk(vec![vec![0, 1], vec![2, 3]], vec![2, 2]).is_ok());
        // load below s + m
        assert!(mk(vec![vec![0, 1], vec![2, 3]], vec![1, 2]).is_err());
        // load above group size
        assert!(mk(vec![vec![0, 1], vec![2, 3]], vec![3, 2]).is_err());
        // non-partition
        assert!(mk(vec![vec![0, 1], vec![1, 2, 3]], vec![2, 2]).is_err());
        assert!(mk(vec![vec![0, 1]], vec![2]).is_err());
        // infeasible from_speeds
        assert!(HeteroCode::from_speeds(3, 2, 2, &[1.0, 1.0, 1.0]).is_err());
        assert!(HeteroCode::from_speeds(4, 1, 1, &[1.0, -1.0, 1.0, 1.0]).is_err());
        assert!(HeteroCode::from_speeds(4, 1, 1, &[1.0; 3]).is_err());
    }

    #[test]
    fn from_speeds_is_deterministic() {
        let speeds = [1.0, 3.9, 1.1, 4.0, 1.05, 3.8];
        let a = HeteroCode::from_speeds(6, 1, 1, &speeds).unwrap();
        let b = HeteroCode::from_speeds(6, 1, 1, &speeds).unwrap();
        assert_eq!(a.plan(), b.plan());
        assert_eq!(a.loads(), b.loads());
        // interleaved ids are grouped by speed, not position
        assert_eq!(a.groups()[0].workers, &[0, 4, 2]);
        assert_eq!(a.groups()[1].workers, &[5, 1, 3]);
    }

    #[test]
    fn extreme_skew_respects_min_weight_floor() {
        // One very slow worker on an otherwise-fast fleet: its subset is
        // clamped to the 10% floor and the budget redistribution must not
        // push it back under (the water-filling invariant).
        let mut speeds = vec![100.0; 10];
        speeds[0] = 1.0;
        let code = HeteroCode::from_speeds(10, 0, 1, &speeds).unwrap();
        let ws = code.subset_weights().unwrap();
        assert!(
            ws.iter().all(|&w| w >= 0.1 - 1e-9),
            "weights must respect the floor: {ws:?}"
        );
        assert!((ws.iter().sum::<f64>() - 10.0).abs() < 1e-9, "row budget: {ws:?}");
    }

    #[test]
    fn tiny_tiers_are_merged_to_viability() {
        // One very fast worker cannot form its own group when s + m = 3.
        let speeds = [1.0, 1.0, 1.0, 1.0, 10.0];
        let code = HeteroCode::from_speeds(5, 1, 2, &speeds).unwrap();
        assert_eq!(code.groups().len(), 1, "merged into a single viable group");
        assert_eq!(code.loads(), vec![3; 5]);
    }
}
