//! Worker-side encoding (Eq. 18/25): the f32 hot path.
//!
//! Worker `w` holds `d` partial gradients `g_{t_0}, …, g_{t_{d-1}}` (each
//! length `l`) and transmits `f_w ∈ R^{l/m}` with
//! `f_w[v] = Σ_{j<d} Σ_{u<m} c[j·m+u] · g_{t_j}[v·m+u]`,
//! where `c` comes from [`GradientCode::encode_coeffs`]. Each inner term
//! is a dot product of `c`'s `m`-chunk with a contiguous `m`-chunk of the
//! gradient, so the pass streams each gradient exactly once.

use super::{CodingError, GradientCode};

/// Precomputed per-worker encoder.
pub struct Encoder {
    /// `d·m` coefficients in f32 (payload precision).
    coeffs: Vec<f32>,
    d: usize,
    m: usize,
}

impl Encoder {
    /// Build for `worker` under `code`. The gradient count is derived
    /// from the coefficient vector (`len / m`), not from the scheme-wide
    /// `d`, so heterogeneous schemes with per-worker loads `d_w` work
    /// through the same path (uniform schemes: `len / m == d`).
    pub fn new(code: &dyn GradientCode, worker: usize) -> Result<Self, CodingError> {
        let c64 = code.encode_coeffs(worker)?;
        let m = code.config().m;
        if c64.len() % m != 0 {
            // A silent floor of d = len/m would truncate coefficients and
            // encode a wrong vector; fail loudly instead.
            return Err(CodingError::InvalidConfig(format!(
                "worker {worker}: {} encode coefficients are not a multiple of m={m}",
                c64.len()
            )));
        }
        Ok(Encoder {
            d: c64.len() / m,
            coeffs: c64.iter().map(|&x| x as f32).collect(),
            m,
        })
    }

    /// Build directly from f64 coefficients (testing / custom schemes).
    pub fn from_coeffs(coeffs: &[f64], d: usize, m: usize) -> Self {
        assert_eq!(coeffs.len(), d * m);
        Encoder { coeffs: coeffs.iter().map(|&x| x as f32).collect(), d, m }
    }

    pub fn coeffs(&self) -> &[f32] {
        &self.coeffs
    }

    /// Encode `d` partial gradients (each of length `l`, `m | l`) into the
    /// transmitted vector of length `l/m`.
    pub fn encode(&self, gradients: &[&[f32]]) -> Result<Vec<f32>, CodingError> {
        let mut out = Vec::new();
        self.encode_into(gradients, &mut out)?;
        Ok(out)
    }

    /// Allocation-free variant for the request path: `out` is resized to
    /// `l/m` and overwritten.
    ///
    /// Fused across the `d` gradients: one pass over the output with all
    /// `d` input streams read concurrently (§Perf: the per-gradient
    /// formulation re-traversed `out` d times and measured ~963 µs at
    /// d=3, l=262144; the fused loops are a single write pass). The
    /// output pass is chunked across [`crate::pool`] — every `out[v]`
    /// is an independent dot product, so the parallel result is bitwise
    /// identical to the serial one for any thread count.
    pub fn encode_into(
        &self,
        gradients: &[&[f32]],
        out: &mut Vec<f32>,
    ) -> Result<(), CodingError> {
        assert_eq!(gradients.len(), self.d, "expected {} gradients", self.d);
        let l = gradients[0].len();
        if l % self.m != 0 {
            return Err(CodingError::DimensionNotDivisible { l, m: self.m });
        }
        for (j, g) in gradients.iter().enumerate() {
            assert_eq!(g.len(), l, "gradient {j} length mismatch");
        }
        let lv = l / self.m;
        out.clear();
        out.resize(lv, 0.0);
        if lv >= 2 * ENCODE_CHUNK {
            crate::pool::global().for_each_chunk_mut(out, ENCODE_CHUNK, |ci, oc| {
                self.encode_range(gradients, ci * ENCODE_CHUNK, oc);
            });
        } else {
            self.encode_range(gradients, 0, out);
        }
        Ok(())
    }

    /// Encode output components `v0 .. v0 + out.len()` (one chunk of the
    /// transmitted vector). Dimension checks happen in
    /// [`Encoder::encode_into`].
    fn encode_range(&self, gradients: &[&[f32]], v0: usize, out: &mut [f32]) {
        let m = self.m;
        let c = &self.coeffs;
        match m {
            1 => {
                // f[v] = Σ_j c_j g_j[v] — the 4-stream fused weighted
                // sum over this chunk's subslice of every gradient.
                let views: Vec<&[f32]> =
                    gradients.iter().map(|g| &g[v0..v0 + out.len()]).collect();
                crate::linalg::weighted_sum_f32(c, &views, out);
            }
            2 => {
                for (dv, o) in out.iter_mut().enumerate() {
                    let base = 2 * (v0 + dv);
                    let mut acc = 0.0f32;
                    for (j, g) in gradients.iter().enumerate() {
                        acc += c[2 * j] * g[base] + c[2 * j + 1] * g[base + 1];
                    }
                    *o = acc;
                }
            }
            4 => {
                for (dv, o) in out.iter_mut().enumerate() {
                    let base = 4 * (v0 + dv);
                    let mut acc = 0.0f32;
                    for (j, g) in gradients.iter().enumerate() {
                        let cj = &c[4 * j..4 * j + 4];
                        acc += cj[0] * g[base]
                            + cj[1] * g[base + 1]
                            + cj[2] * g[base + 2]
                            + cj[3] * g[base + 3];
                    }
                    *o = acc;
                }
            }
            _ => {
                for (dv, o) in out.iter_mut().enumerate() {
                    let base = (v0 + dv) * m;
                    let mut acc = 0.0f32;
                    for (j, g) in gradients.iter().enumerate() {
                        let cj = &c[j * m..(j + 1) * m];
                        let chunk = &g[base..base + m];
                        for (cv, gv) in cj.iter().zip(chunk) {
                            acc += cv * gv;
                        }
                    }
                    *o = acc;
                }
            }
        }
    }
}

/// Output components per parallel encode chunk. The grid is a function
/// of `l/m` only, and each component is independent, so chunking never
/// changes the bits.
pub const ENCODE_CHUNK: usize = 16 * 1024;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::{PolynomialCode, SchemeConfig};

    fn naive_encode(coeffs: &[f64], gradients: &[&[f32]], m: usize) -> Vec<f32> {
        let l = gradients[0].len();
        let lv = l / m;
        let mut out = vec![0.0f32; lv];
        for v in 0..lv {
            let mut acc = 0.0f64;
            for (j, g) in gradients.iter().enumerate() {
                for u in 0..m {
                    acc += coeffs[j * m + u] * g[v * m + u] as f64;
                }
            }
            out[v] = acc as f32;
        }
        out
    }

    #[test]
    fn encode_matches_naive_all_m() {
        for (d, m, l) in [(3, 1, 24), (3, 2, 24), (4, 4, 32), (5, 3, 30)] {
            let coeffs: Vec<f64> = (0..d * m).map(|i| (i as f64 * 0.37).sin()).collect();
            let grads_store: Vec<Vec<f32>> = (0..d)
                .map(|j| (0..l).map(|k| ((j * l + k) as f32 * 0.11).cos()).collect())
                .collect();
            let grads: Vec<&[f32]> = grads_store.iter().map(|v| v.as_slice()).collect();
            let enc = Encoder::from_coeffs(&coeffs, d, m);
            let got = enc.encode(&grads).unwrap();
            let want = naive_encode(&coeffs, &grads, m);
            assert_eq!(got.len(), l / m);
            for v in 0..got.len() {
                assert!((got[v] - want[v]).abs() < 1e-4, "d={d} m={m} v={v}");
            }
        }
    }

    #[test]
    fn large_encode_parallel_is_bitwise_serial() {
        // Above the cutover the chunked pool path must produce the
        // exact bits of a single full-range pass.
        let (d, m) = (3, 2);
        let l = 2 * ENCODE_CHUNK * m + 10;
        let coeffs: Vec<f64> = (0..d * m).map(|i| (i as f64 * 0.7).cos()).collect();
        let grads_store: Vec<Vec<f32>> = (0..d)
            .map(|j| (0..l).map(|k| ((j + k) as f32 * 0.001).sin()).collect())
            .collect();
        let grads: Vec<&[f32]> = grads_store.iter().map(|v| v.as_slice()).collect();
        let enc = Encoder::from_coeffs(&coeffs, d, m);
        let mut par = Vec::new();
        enc.encode_into(&grads, &mut par).unwrap();
        let mut ser = vec![0.0f32; l / m];
        enc.encode_range(&grads, 0, &mut ser);
        assert_eq!(par.len(), ser.len());
        assert!(par.iter().zip(&ser).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn encode_rejects_bad_dimension() {
        let enc = Encoder::from_coeffs(&[1.0, 2.0], 1, 2);
        let g = vec![1.0f32; 7];
        assert!(enc.encode(&[&g]).is_err());
    }

    #[test]
    fn encoder_from_scheme_has_dm_coeffs() {
        let code = PolynomialCode::new(SchemeConfig::tight(5, 1, 2).unwrap()).unwrap();
        let enc = Encoder::new(&code, 2).unwrap();
        assert_eq!(enc.coeffs().len(), 3 * 2);
    }

    #[test]
    fn encode_into_reuses_buffer() {
        let enc = Encoder::from_coeffs(&[0.5, -1.0], 1, 2);
        let g = vec![2.0f32; 8];
        let mut buf = Vec::new();
        enc.encode_into(&[&g], &mut buf).unwrap();
        assert_eq!(buf.len(), 4);
        for &x in &buf {
            assert!((x - (0.5 * 2.0 - 1.0 * 2.0)).abs() < 1e-6);
        }
        // second call must overwrite, not accumulate
        enc.encode_into(&[&g], &mut buf).unwrap();
        for &x in &buf {
            assert!((x + 1.0).abs() < 1e-6);
        }
    }
}
