//! Vandermonde evaluation matrix and the paper's θ grid.

use crate::linalg::Matrix;

/// The paper's evaluation points (Eq. 23):
/// even `n`:  {±(1 + i/2) : i = 0..n/2-1}
/// odd  `n`:  {0} ∪ {±(1 + i/2) : i = 0..(n-1)/2-1}
///
/// Returned ascending, so `n = 5` gives `{-1.5, -1, 0, 1, 1.5}`. (The toy
/// Fig. 2 example instead uses `{-2,-1,0,1,2}`; pass custom θ for that.)
pub fn paper_thetas(n: usize) -> Vec<f64> {
    assert!(n > 0);
    let mut t = Vec::with_capacity(n);
    let half = n / 2;
    if n % 2 == 1 {
        t.push(0.0);
    }
    for i in 0..half {
        let v = 1.0 + i as f64 / 2.0;
        t.push(v);
        t.push(-v);
    }
    t.sort_by(|a, b| a.total_cmp(b));
    t
}

/// `rows × thetas.len()` Vandermonde matrix `V[r][j] = θ_j^r` (Eq. 22).
pub fn vandermonde(rows: usize, thetas: &[f64]) -> Matrix {
    Matrix::from_fn(rows, thetas.len(), |r, j| thetas[j].powi(r as i32))
}

/// Integer evaluation grid centered at zero (`{-2,-1,0,1,2}` for n=5),
/// used by the paper's Fig. 2 / Table II example.
pub fn integer_thetas(n: usize) -> Vec<f64> {
    (0..n).map(|i| i as f64 - ((n - 1) as f64) / 2.0).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_thetas_even() {
        let t = paper_thetas(4);
        assert_eq!(t, vec![-1.5, -1.0, 1.0, 1.5]);
    }

    #[test]
    fn paper_thetas_odd() {
        let t = paper_thetas(5);
        assert_eq!(t, vec![-1.5, -1.0, 0.0, 1.0, 1.5]);
    }

    #[test]
    fn thetas_distinct_for_all_n() {
        for n in 1..=30 {
            let t = paper_thetas(n);
            assert_eq!(t.len(), n);
            for w in t.windows(2) {
                assert!(w[0] < w[1], "n={n}: {:?}", t);
            }
        }
    }

    #[test]
    fn vandermonde_shape_and_entries() {
        let t = [2.0, 3.0];
        let v = vandermonde(3, &t);
        assert_eq!((v.rows(), v.cols()), (3, 2));
        assert_eq!(v[(0, 0)], 1.0);
        assert_eq!(v[(2, 1)], 9.0);
    }

    #[test]
    fn integer_thetas_centered() {
        assert_eq!(integer_thetas(5), vec![-2.0, -1.0, 0.0, 1.0, 2.0]);
        assert_eq!(integer_thetas(4), vec![-1.5, -0.5, 0.5, 1.5]);
    }
}
