//! Run-level metrics: per-iteration records, aggregation, and CSV export
//! for the figure benches.

use std::fmt::Write as _;

use crate::chaos::{FaultLog, LadderRung};
use crate::obs::{Histogram, TelemetrySummary};

/// One training iteration as observed by the master.
#[derive(Debug, Clone)]
pub struct IterationRecord {
    pub iter: usize,
    /// Simulated cluster time for this iteration (§VI model), seconds.
    pub sim_time: f64,
    /// Cumulative simulated time at the end of this iteration.
    pub sim_clock: f64,
    /// Measured wall-clock spent in master-side compute (decode + step),
    /// seconds.
    pub master_compute: f64,
    /// Measured wall-clock spent by workers on gradient+encode (max over
    /// responders), seconds.
    pub worker_compute: f64,
    /// Workers whose results were used.
    pub responders: Vec<usize>,
    /// f32 values transmitted by all workers this iteration (comm cost).
    pub floats_transmitted: usize,
    /// Bytes those results occupy on the wire, framing included
    /// (`wire::framed_result_bytes` per responder): payload floats plus
    /// the per-frame length/tag/CRC and Result-header overhead.
    pub wire_bytes: usize,
    /// Coefficient-space decoding residual reported by the scheme
    /// (`Some` only for approximate partial recovery; 0 = exact).
    pub decode_residual: Option<f64>,
    /// Training loss at eval points (`None` when not evaluated).
    pub loss: Option<f64>,
    /// Test AUC at eval points.
    pub auc: Option<f64>,
    /// Which rung of the degradation ladder served this iteration
    /// (`Exact` on every iteration of a fault-free run).
    pub rung: LadderRung,
}

/// Full log of a training run.
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    pub records: Vec<IterationRecord>,
    pub scheme: String,
    /// Responder-set → decode-weights cache hits across the run (the
    /// trainer reuses a solved decode whenever a responder set repeats).
    pub decoder_cache_hits: usize,
    /// Cache misses (each one paid a fresh weight solve).
    pub decoder_cache_misses: usize,
    /// Injected faults and recovery actions observed during the run
    /// (empty unless chaos injection was enabled).
    pub faults: FaultLog,
    /// Telemetry digest (phase breakdown, counters, straggler report);
    /// `Some` only when the run was traced with an enabled
    /// [`Recorder`](crate::obs::Recorder).
    pub telemetry: Option<TelemetrySummary>,
    /// Warnings raised by the straggler health watchdog (realized
    /// iteration times drifting beyond threshold from the
    /// declared-profile §VI model). Empty on a healthy run or when no
    /// delay model was configured.
    pub health_warnings: Vec<String>,
}

impl RunLog {
    pub fn new(scheme: impl Into<String>) -> Self {
        RunLog {
            records: Vec::new(),
            scheme: scheme.into(),
            decoder_cache_hits: 0,
            decoder_cache_misses: 0,
            faults: FaultLog::new(),
            telemetry: None,
            health_warnings: Vec::new(),
        }
    }

    /// Count of iterations served at each ladder rung:
    /// `(exact, degraded, stale)`.
    pub fn rung_counts(&self) -> (usize, usize, usize) {
        let mut counts = (0, 0, 0);
        for r in &self.records {
            match r.rung {
                LadderRung::Exact => counts.0 += 1,
                LadderRung::Degraded => counts.1 += 1,
                LadderRung::Stale => counts.2 += 1,
            }
        }
        counts
    }

    /// Fraction of *decodes* served from the decoder cache (`None`
    /// before any decode happened). Note this is per decode, not per
    /// iteration: stale iterations decode nothing (contributing to
    /// neither count), so on a run with stale fallbacks the denominator
    /// is smaller than the iteration count.
    pub fn decoder_cache_hit_rate(&self) -> Option<f64> {
        let total = self.decoder_cache_hits + self.decoder_cache_misses;
        (total > 0).then(|| self.decoder_cache_hits as f64 / total as f64)
    }

    pub fn push(&mut self, r: IterationRecord) {
        self.records.push(r);
    }

    pub fn total_sim_time(&self) -> f64 {
        self.records.last().map_or(0.0, |r| r.sim_clock)
    }

    pub fn mean_iteration_sim_time(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.sim_time).sum::<f64>() / self.records.len() as f64
    }

    pub fn total_floats_transmitted(&self) -> usize {
        self.records.iter().map(|r| r.floats_transmitted).sum()
    }

    /// Total framed bytes the gathered results occupied on the wire
    /// (see [`IterationRecord::wire_bytes`]).
    pub fn total_wire_bytes(&self) -> usize {
        self.records.iter().map(|r| r.wire_bytes).sum()
    }

    /// `(p50, p95, p99)` of per-iteration `sim_time`, estimated via a
    /// log-bucketed [`Histogram`] (≈ 9% relative bucketing error; p99
    /// of a short run degenerates to the max). `None` on an empty log.
    pub fn sim_time_quantiles(&self) -> Option<(f64, f64, f64)> {
        if self.records.is_empty() {
            return None;
        }
        let h = Histogram::from_values(self.records.iter().map(|r| r.sim_time));
        Some((h.p50(), h.p95(), h.p99()))
    }

    pub fn final_auc(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.auc)
    }

    pub fn final_loss(&self) -> Option<f64> {
        self.records.iter().rev().find_map(|r| r.loss)
    }

    /// (sim_clock, auc) series for Fig. 4-style curves.
    pub fn auc_curve(&self) -> Vec<(f64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.auc.map(|a| (r.sim_clock, a)))
            .collect()
    }

    /// Mean reported decode residual over iterations that carry one
    /// (`None` when the scheme never reported — i.e. exact recovery).
    pub fn mean_decode_residual(&self) -> Option<f64> {
        let vals: Vec<f64> =
            self.records.iter().filter_map(|r| r.decode_residual).collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }

    /// CSV with one row per iteration.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "iter,sim_time,sim_clock,master_compute,worker_compute,n_responders,floats,wire_bytes,decode_residual,loss,auc,rung\n",
        );
        for r in &self.records {
            let _ = writeln!(
                s,
                "{},{:.6},{:.6},{:.6},{:.6},{},{},{},{},{},{},{}",
                r.iter,
                r.sim_time,
                r.sim_clock,
                r.master_compute,
                r.worker_compute,
                r.responders.len(),
                r.floats_transmitted,
                r.wire_bytes,
                r.decode_residual.map_or(String::new(), |v| format!("{v:.6}")),
                r.loss.map_or(String::new(), |v| format!("{v:.6}")),
                r.auc.map_or(String::new(), |v| format!("{v:.6}")),
                r.rung.as_str(),
            );
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, t: f64, clock: f64, auc: Option<f64>) -> IterationRecord {
        IterationRecord {
            iter,
            sim_time: t,
            sim_clock: clock,
            master_compute: 0.0,
            worker_compute: 0.0,
            responders: vec![0, 1],
            floats_transmitted: 10,
            wire_bytes: 148, // 2 responders × framed_result_bytes(5 floats each)
            decode_residual: None,
            loss: None,
            auc,
            rung: LadderRung::Exact,
        }
    }

    #[test]
    fn mean_decode_residual_skips_exact_runs() {
        let mut log = RunLog::new("t");
        log.push(rec(0, 1.0, 1.0, None));
        assert_eq!(log.mean_decode_residual(), None);
        let mut r = rec(1, 1.0, 2.0, None);
        r.decode_residual = Some(0.5);
        log.push(r);
        let mut r = rec(2, 1.0, 3.0, None);
        r.decode_residual = Some(1.5);
        log.push(r);
        assert_eq!(log.mean_decode_residual(), Some(1.0));
    }

    #[test]
    fn decoder_cache_hit_rate_counts() {
        let mut log = RunLog::new("t");
        assert_eq!(log.decoder_cache_hit_rate(), None);
        log.decoder_cache_misses = 2;
        log.decoder_cache_hits = 6;
        assert_eq!(log.decoder_cache_hit_rate(), Some(0.75));
    }

    #[test]
    fn decoder_cache_hit_rate_is_per_decode_not_per_iteration() {
        // 10 iterations, but 2 of them were served stale (no decode at
        // all): the rate's denominator is the 8 decodes, not the 10
        // iterations — 6 hits is 6/8, not 6/10.
        let mut log = RunLog::new("t");
        for i in 0..10 {
            let mut r = rec(i, 1.0, i as f64 + 1.0, None);
            if i >= 8 {
                r.rung = LadderRung::Stale;
            }
            log.push(r);
        }
        log.decoder_cache_hits = 6;
        log.decoder_cache_misses = 2;
        assert_eq!(log.records.len(), 10);
        assert_eq!(log.decoder_cache_hit_rate(), Some(0.75));
        assert_ne!(log.decoder_cache_hit_rate(), Some(0.6));
    }

    #[test]
    fn aggregates() {
        let mut log = RunLog::new("test");
        log.push(rec(0, 2.0, 2.0, None));
        log.push(rec(1, 4.0, 6.0, Some(0.9)));
        assert_eq!(log.total_sim_time(), 6.0);
        assert_eq!(log.mean_iteration_sim_time(), 3.0);
        assert_eq!(log.total_floats_transmitted(), 20);
        assert_eq!(log.total_wire_bytes(), 296);
        assert_eq!(log.final_auc(), Some(0.9));
        assert_eq!(log.auc_curve(), vec![(6.0, 0.9)]);
        assert!(log.telemetry.is_none(), "untraced runs carry no telemetry digest");
    }

    #[test]
    fn sim_time_quantiles_come_from_the_histogram() {
        let mut log = RunLog::new("t");
        assert_eq!(log.sim_time_quantiles(), None);
        let mut clock = 0.0;
        for i in 0..100 {
            let t = (i + 1) as f64 * 0.01; // 0.01 .. 1.0
            clock += t;
            log.push(rec(i, t, clock, None));
        }
        let (p50, p95, p99) = log.sim_time_quantiles().unwrap();
        assert!(p50 <= p95 && p95 <= p99, "quantiles must be monotone");
        // within the histogram's ≈9% bucketing error of the true values
        assert!((p50 / 0.50 - 1.0).abs() < 0.10, "p50 = {p50}");
        assert!((p95 / 0.95 - 1.0).abs() < 0.10, "p95 = {p95}");
        assert!((p99 / 0.99 - 1.0).abs() < 0.10, "p99 = {p99}");
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut log = RunLog::new("t");
        log.push(rec(0, 1.0, 1.0, Some(0.8)));
        let csv = log.to_csv();
        assert!(csv.starts_with("iter,"));
        assert!(csv.lines().next().unwrap().ends_with(",rung"));
        assert!(csv.lines().next().unwrap().contains(",floats,wire_bytes,"));
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("0.800000"));
        assert!(csv.contains(",10,148,"), "floats then framed wire bytes");
        assert!(csv.lines().nth(1).unwrap().ends_with(",exact"));
    }

    #[test]
    fn rung_counts_tally_by_variant() {
        let mut log = RunLog::new("t");
        log.push(rec(0, 1.0, 1.0, None));
        let mut r = rec(1, 1.0, 2.0, None);
        r.rung = LadderRung::Degraded;
        log.push(r);
        let mut r = rec(2, 1.0, 3.0, None);
        r.rung = LadderRung::Stale;
        log.push(r);
        assert_eq!(log.rung_counts(), (1, 1, 1));
        assert!(log.faults.is_empty());
    }
}
