//! A minimal Rust lexer for the in-repo linter.
//!
//! Purpose-built for `gradcode lint`: it produces a flat token stream
//! with 1-based line/column positions plus the list of comments (the
//! carrier for `// lint: allow(...)` directives), and it understands
//! exactly the lexical obstacles that would otherwise break
//! token-level rules — nested block comments, raw and byte strings,
//! char literals vs. lifetimes, and numeric literals with radix
//! prefixes, underscores, and type suffixes. It is deliberately *not*
//! a parser: where block structure matters, the rules recover it by
//! delimiter matching over this token stream.
//!
//! The lexer is lossy in ways that do not matter to the rules: token
//! text is kept verbatim (except raw identifiers, which drop their
//! `r#` prefix so `r#fn` and `fn` compare equal), whitespace is
//! discarded, and an unterminated string or comment simply runs to end
//! of file instead of erroring — a linter must keep going on code that
//! does not compile yet.

/// Token classification, as coarse as the rules need.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (keywords are not distinguished).
    Ident,
    /// Numeric literal, suffix included (`16_384usize`, `0x6743_0003`).
    Num,
    /// String, byte string, raw string, or raw byte string literal.
    Str,
    /// Char literal (`'x'`, `'\n'`).
    Char,
    /// Lifetime (`'a`, `'_`, `'static`).
    Lifetime,
    /// Punctuation; multi-character operators arrive as one token.
    Punct,
}

/// One lexed token with its source position.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based source line.
    pub line: u32,
    /// 1-based source column (in bytes; the sources are ASCII).
    pub col: u32,
}

/// The result of [`lex`]: tokens plus comments (with their start line).
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    /// `(start_line, full_text)` per comment, in source order.
    pub comments: Vec<(u32, String)>,
}

/// Multi-character operators, longest first so `<<=` wins over `<<`.
const PUNCTS: [&str; 24] = [
    "<<=", ">>=", "..=", "...", "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "+=",
    "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>", "..",
];

/// Byte-offset end (exclusive) of a raw/byte-raw string starting at
/// `i`, or `None` if `i` does not start one. Unterminated raw strings
/// run to end of input.
fn raw_string_end(b: &[u8], i: usize) -> Option<usize> {
    let mut p = i;
    if p < b.len() && b[p] == b'b' {
        p += 1;
    }
    if p >= b.len() || b[p] != b'r' {
        return None;
    }
    p += 1;
    let hash_start = p;
    while p < b.len() && b[p] == b'#' {
        p += 1;
    }
    let hashes = p - hash_start;
    if p >= b.len() || b[p] != b'"' {
        return None;
    }
    let mut j = p + 1;
    while j < b.len() {
        if b[j] == b'"' {
            let mut h = 0;
            while h < hashes && j + 1 + h < b.len() && b[j + 1 + h] == b'#' {
                h += 1;
            }
            if h == hashes {
                return Some(j + 1 + hashes);
            }
        }
        j += 1;
    }
    Some(b.len())
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_continue(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Slice `src[a..z]` as an owned String; bad UTF-8 boundaries (only
/// possible in pathological non-ASCII input) degrade lossily instead
/// of panicking.
fn span(src: &str, a: usize, z: usize) -> String {
    match src.get(a..z) {
        Some(s) => s.to_string(),
        None => String::from_utf8_lossy(&src.as_bytes()[a..z]).into_owned(),
    }
}

/// Advance the cursor by `k` bytes, tracking line/column.
fn advance(b: &[u8], i: &mut usize, line: &mut u32, col: &mut u32, k: usize) {
    for _ in 0..k {
        if *i < b.len() && b[*i] == b'\n' {
            *line += 1;
            *col = 1;
        } else {
            *col += 1;
        }
        *i += 1;
    }
}

/// Tokenize `src`. Never fails: malformed input yields a best-effort
/// token stream (see module docs).
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut col = 1u32;

    while i < n {
        let c = b[i];

        if c == b' ' || c == b'\t' || c == b'\r' || c == b'\n' {
            advance(b, &mut i, &mut line, &mut col, 1);
            continue;
        }

        // Line comment.
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            out.comments.push((line, span(src, i, j)));
            advance(b, &mut i, &mut line, &mut col, j - i);
            continue;
        }

        // Block comment, nesting honored (Rust block comments nest).
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start_line = line;
            let mut depth = 0i32;
            let mut j = i;
            while j < n {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    j += 1;
                }
            }
            out.comments.push((start_line, span(src, i, j)));
            advance(b, &mut i, &mut line, &mut col, j - i);
            continue;
        }

        // Raw string / raw byte string — checked before plain strings
        // and identifiers so `r#"…"#` does not lex as ident + string.
        if let Some(end) = raw_string_end(b, i) {
            out.toks.push(Tok { kind: TokKind::Str, text: span(src, i, end), line, col });
            advance(b, &mut i, &mut line, &mut col, end - i);
            continue;
        }

        // String / byte string.
        if c == b'"' || (c == b'b' && i + 1 < n && b[i + 1] == b'"') {
            let mut j = i + if c == b'b' { 2 } else { 1 };
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            let j = j.min(n);
            out.toks.push(Tok { kind: TokKind::Str, text: span(src, i, j), line, col });
            advance(b, &mut i, &mut line, &mut col, j - i);
            continue;
        }

        // Lifetime or char literal. `'a` (not followed by a closing
        // quote) is a lifetime; `'a'`, `'\n'` are char literals.
        if c == b'\'' {
            let is_lifetime = i + 1 < n
                && is_ident_start(b[i + 1])
                && (i + 2 >= n || b[i + 2] != b'\'');
            if is_lifetime {
                let mut j = i + 1;
                while j < n && is_ident_continue(b[j]) {
                    j += 1;
                }
                out.toks.push(Tok { kind: TokKind::Lifetime, text: span(src, i, j), line, col });
                advance(b, &mut i, &mut line, &mut col, j - i);
                continue;
            }
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                } else if b[j] == b'\'' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            let j = j.min(n);
            out.toks.push(Tok { kind: TokKind::Char, text: span(src, i, j), line, col });
            advance(b, &mut i, &mut line, &mut col, j - i);
            continue;
        }

        // Raw identifier: lex as the bare name so rules see `r#fn` as `fn`.
        if c == b'r' && i + 2 < n && b[i + 1] == b'#' && is_ident_start(b[i + 2]) {
            let mut j = i + 2;
            while j < n && is_ident_continue(b[j]) {
                j += 1;
            }
            out.toks.push(Tok { kind: TokKind::Ident, text: span(src, i + 2, j), line, col });
            advance(b, &mut i, &mut line, &mut col, j - i);
            continue;
        }

        // Identifier / keyword.
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_continue(b[j]) {
                j += 1;
            }
            out.toks.push(Tok { kind: TokKind::Ident, text: span(src, i, j), line, col });
            advance(b, &mut i, &mut line, &mut col, j - i);
            continue;
        }

        // Numeric literal.
        if c.is_ascii_digit() {
            let mut j = i;
            let two = if i + 2 <= n { &b[i..i + 2] } else { &b[i..n] };
            if two == b"0x" || two == b"0X" || two == b"0o" || two == b"0O" || two == b"0b"
                || two == b"0B"
            {
                j = i + 2;
                while j < n && is_ident_continue(b[j]) {
                    j += 1;
                }
            } else {
                while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                    j += 1;
                }
                // Fraction: a dot only counts if a digit follows, so
                // `0..n` and `x.method()` stay untouched.
                if j < n && b[j] == b'.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                    j += 1;
                    while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                        j += 1;
                    }
                }
                // Exponent.
                let has_exp = j < n
                    && (b[j] == b'e' || b[j] == b'E')
                    && ((j + 1 < n && b[j + 1].is_ascii_digit())
                        || (j + 1 < n
                            && (b[j + 1] == b'+' || b[j + 1] == b'-')
                            && j + 2 < n
                            && b[j + 2].is_ascii_digit()));
                if has_exp {
                    j += 2;
                    while j < n && b[j].is_ascii_digit() {
                        j += 1;
                    }
                }
                // Type suffix (`f32`, `usize`, …).
                while j < n && is_ident_continue(b[j]) {
                    j += 1;
                }
            }
            out.toks.push(Tok { kind: TokKind::Num, text: span(src, i, j), line, col });
            advance(b, &mut i, &mut line, &mut col, j - i);
            continue;
        }

        // Punctuation: longest multi-char operator first.
        let mut matched = 0usize;
        for p in PUNCTS {
            if b[i..].starts_with(p.as_bytes()) {
                out.toks.push(Tok { kind: TokKind::Punct, text: p.to_string(), line, col });
                matched = p.len();
                break;
            }
        }
        if matched == 0 {
            out.toks.push(Tok { kind: TokKind::Punct, text: span(src, i, i + 1), line, col });
            matched = 1;
        }
        advance(b, &mut i, &mut line, &mut col, matched);
    }

    out
}
