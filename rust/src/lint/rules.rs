//! The rule registry: each rule walks the token stream of one file and
//! appends [`Finding`]s. Rules are deliberately syntactic — they match
//! token shapes, not types — so they stay cheap, std-only, and easy to
//! reason about; the corresponding invariants are documented per rule
//! and in `rust/DESIGN.md`.

use super::lexer::{Tok, TokKind};
use super::{Finding, RULE_ADHOC_CHUNK, RULE_FLOAT_REDUCE, RULE_LOCK_IO, RULE_PANIC,
    RULE_WALLCLOCK, RULE_WIRE_DRIFT};

/// Method/path call names that perform socket or stream I/O; used by
/// the `lock-across-io` rule.
const IO_CALLS: [&str; 6] =
    ["write_all", "read_exact", "read_to_end", "flush", "connect", "accept"];

/// The fixed registry of `coordinator/wire.rs` layout constants, in
/// fingerprint serialization order. Must match
/// `wire::layout_fingerprint` exactly.
const WIRE_REGISTRY: [&str; 15] = [
    "MAGIC",
    "TAG_HELLO",
    "TAG_SETUP",
    "TAG_TASK",
    "TAG_RESULT",
    "TAG_SHUTDOWN",
    "SCHEME_POLY",
    "SCHEME_RANDOM",
    "SCHEME_UNCODED",
    "SCHEME_APPROX",
    "SCHEME_HETERO",
    "FRAME_OVERHEAD",
    "RESULT_HEADER_BYTES",
    "RESULT_METRICS_BYTES",
    "MAX_PAYLOAD",
];

fn push(
    findings: &mut Vec<Finding>,
    file: &str,
    t_line: u32,
    t_col: u32,
    rule: &'static str,
    msg: String,
) {
    findings.push(Finding { file: file.to_string(), line: t_line, col: t_col, rule, msg });
}

/// Index of the close delimiter matching the open one at `open_idx`
/// (one of `(`/`[`/`{`). Unbalanced input returns the last index.
pub(crate) fn match_delim(toks: &[Tok], open_idx: usize) -> usize {
    let cl = match toks[open_idx].text.as_str() {
        "(" => ")",
        "[" => "]",
        "{" => "}",
        _ => return open_idx,
    };
    let mut depth = 0i32;
    for (k, t) in toks.iter().enumerate().skip(open_idx) {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => {
                    depth -= 1;
                    if depth == 0 && t.text == cl {
                        return k;
                    }
                }
                _ => {}
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// Token-index ranges (inclusive) covered by `#[cfg(test)]` or
/// `#[test]` items: the attribute itself through the close brace of
/// the item body. Rules skip findings inside these ranges — test code
/// may panic and measure wall-clock freely.
pub(crate) fn cfg_test_ranges(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut k = 0usize;
    while k + 2 < toks.len() {
        if toks[k].text == "#" && toks[k + 1].text == "[" {
            let close = match_delim(toks, k + 1);
            let mut has_test = false;
            let mut has_cfg = false;
            let mut only_test = true;
            for t in &toks[k + 2..close] {
                if t.kind == TokKind::Ident {
                    match t.text.as_str() {
                        "test" => has_test = true,
                        "cfg" => has_cfg = true,
                        _ => only_test = false,
                    }
                    if t.text != "test" {
                        only_test = false;
                    }
                }
            }
            if has_test && (has_cfg || only_test) {
                // Find the item body: the first `{` at nesting depth 0
                // before a `;` (a `;` means an item with no body).
                let mut j = close + 1;
                let mut depth = 0i32;
                while j < toks.len() {
                    let tx = toks[j].text.as_str();
                    if tx == "{" && depth == 0 {
                        let end = match_delim(toks, j);
                        ranges.push((k, end));
                        j = end;
                        break;
                    }
                    if tx == ";" && depth == 0 {
                        break;
                    }
                    if tx == "(" || tx == "[" {
                        depth += 1;
                    }
                    if tx == ")" || tx == "]" {
                        depth -= 1;
                    }
                    j += 1;
                }
                k = j;
            }
        }
        k += 1;
    }
    ranges
}

pub(crate) fn in_ranges(idx: usize, ranges: &[(usize, usize)]) -> bool {
    ranges.iter().any(|&(a, b)| a <= idx && idx <= b)
}

/// Parse `// lint: allow(<rule>) <reason>` directives out of the
/// comment list. The reason may be empty here; suppression (in
/// `lint_source`) requires it non-empty, so a bare `allow(...)` is
/// visible but toothless — every exemption must say why.
pub(crate) fn parse_allows(comments: &[(u32, String)]) -> Vec<(u32, String, String)> {
    let mut allows = Vec::new();
    for (line, text) in comments {
        let Some(p) = text.find("lint:") else { continue };
        let rest = text[p + 5..].trim_start();
        let Some(rest) = rest.strip_prefix("allow(") else { continue };
        let Some(q) = rest.find(')') else { continue };
        let rule = &rest[..q];
        if rule.is_empty()
            || !rule.bytes().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'-')
        {
            continue;
        }
        let reason = rest[q + 1..].trim();
        allows.push((*line, rule.to_string(), reason.to_string()));
    }
    allows
}

fn first_upper(s: &str) -> bool {
    s.chars().next().map_or(false, |c| c.is_uppercase())
}

/// `panic-in-lib`: `.unwrap()` / `.expect()` / `panic!` / `todo!` in
/// library code. A panic on the master unwinds the training loop and
/// every worker connection; the distributed path must degrade through
/// typed errors (`WireError`, `anyhow::Result`) instead. Scope:
/// `rust/src` only, excluding `main.rs` (user-facing binary),
/// `testkit/` (test support — panicking asserts are its API), and
/// `#[cfg(test)]` blocks.
fn rule_panic_in_lib(
    path: &str,
    toks: &[Tok],
    test_ranges: &[(usize, usize)],
    findings: &mut Vec<Finding>,
) {
    if !path.contains("/src/") || path.ends_with("main.rs") || path.contains("/testkit/") {
        return;
    }
    for (k, t) in toks.iter().enumerate() {
        if in_ranges(k, test_ranges) || t.kind != TokKind::Ident {
            continue;
        }
        match t.text.as_str() {
            "unwrap" | "expect" => {
                if k > 0
                    && toks[k - 1].text == "."
                    && k + 1 < toks.len()
                    && toks[k + 1].text == "("
                {
                    push(findings, path, t.line, t.col, RULE_PANIC,
                        format!("`.{}()` in library code", t.text));
                }
            }
            "panic" | "todo" => {
                if k + 1 < toks.len() && toks[k + 1].text == "!" {
                    if k > 0 && toks[k - 1].text == "::" {
                        continue;
                    }
                    push(findings, path, t.line, t.col, RULE_PANIC,
                        format!("`{}!` in library code", t.text));
                }
            }
            _ => {}
        }
    }
}

/// `wallclock-entropy`: `Instant::now` / `SystemTime::now` outside the
/// `obs/` and `bench/` allowlists. Wall-clock readings in the decode
/// or seeding path silently break the determinism contract (bitwise
/// reproducibility across thread counts and reruns); real-time
/// measurement belongs in the telemetry layer.
fn rule_wallclock(
    path: &str,
    toks: &[Tok],
    test_ranges: &[(usize, usize)],
    findings: &mut Vec<Finding>,
) {
    if !path.contains("/src/") || path.contains("/obs/") || path.contains("/bench/") {
        return;
    }
    for (k, t) in toks.iter().enumerate() {
        if in_ranges(k, test_ranges) || t.kind != TokKind::Ident {
            continue;
        }
        if (t.text == "Instant" || t.text == "SystemTime")
            && k + 2 < toks.len()
            && toks[k + 1].text == "::"
            && toks[k + 2].text == "now"
        {
            push(findings, path, t.line, t.col, RULE_WALLCLOCK,
                format!("`{}::now` outside the obs/bench allowlist", t.text));
        }
    }
}

/// Identifiers bound locally inside token range `[a, b)`: closure
/// parameters (including nested closures), `let` pattern names, and
/// `for` loop bindings. Used to tell captured state from scratch
/// variables in `float-reduce-outside-tree`.
fn closure_locals(toks: &[Tok], a: usize, b: usize) -> Vec<String> {
    let mut locals = Vec::new();
    let mut k = a;
    while k < b {
        let t = &toks[k];
        if t.kind == TokKind::Punct && t.text == "|" {
            let mut j = k + 1;
            while j < b && toks[j].text != "|" {
                if toks[j].kind == TokKind::Ident && !first_upper(&toks[j].text) {
                    locals.push(toks[j].text.clone());
                }
                j += 1;
            }
            k = j + 1;
            continue;
        }
        if t.kind == TokKind::Ident && t.text == "let" {
            let mut j = k + 1;
            while j < b && toks[j].text != "=" && toks[j].text != ";" {
                if toks[j].kind == TokKind::Ident && !first_upper(&toks[j].text) {
                    locals.push(toks[j].text.clone());
                }
                j += 1;
            }
            k = j;
            continue;
        }
        if t.kind == TokKind::Ident && t.text == "for" {
            let mut j = k + 1;
            while j < b && toks[j].text != "in" {
                if toks[j].kind == TokKind::Ident && !first_upper(&toks[j].text) {
                    locals.push(toks[j].text.clone());
                }
                j += 1;
            }
            k = j;
            continue;
        }
        k += 1;
    }
    locals
}

/// Walk left from `idx` (exclusive) over an lvalue chain — index
/// groups, call groups, `.`/`::` segments, derefs — to its base
/// identifier (`parts[i].0 +=` → `parts`).
fn base_ident_before(toks: &[Tok], idx: usize) -> Option<String> {
    let mut k = idx as isize - 1;
    while k >= 0 {
        let t = &toks[k as usize];
        if t.text == "]" || t.text == ")" {
            let (open, close) = if t.text == "]" { ("[", "]") } else { ("(", ")") };
            let mut depth = 0i32;
            while k >= 0 {
                let x = toks[k as usize].text.as_str();
                if x == close {
                    depth += 1;
                }
                if x == open {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                k -= 1;
            }
            k -= 1;
            continue;
        }
        if t.kind == TokKind::Ident {
            if k >= 1 {
                let prev = toks[k as usize - 1].text.as_str();
                if prev == "." || prev == "::" {
                    k -= 2;
                    continue;
                }
            }
            return Some(t.text.clone());
        }
        if t.text == "." || t.text == "*" {
            k -= 1;
            continue;
        }
        return None;
    }
    None
}

/// `float-reduce-outside-tree`: cross-chunk floating-point reduction
/// that bypasses `pool::tree_combine`. Two shapes are flagged:
/// (a) `+=`/`-=` into *captured* (non-locally-bound) state inside a
/// `map_indexed`/`for_each_chunk_mut` closure — a data race at worst,
/// and even when synchronized the combine order depends on thread
/// scheduling, so sums stop being bitwise reproducible; and
/// (b) an iterator fold (`.sum`/`.fold`/`.product`/`.reduce`) chained
/// directly onto a `map_indexed(...)` result — a sequential
/// left-to-right reduction whose rounding differs from the fixed
/// binary-tree order every other consumer uses.
fn rule_float_reduce(
    path: &str,
    toks: &[Tok],
    test_ranges: &[(usize, usize)],
    findings: &mut Vec<Finding>,
) {
    for (k, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident
            || (t.text != "map_indexed" && t.text != "for_each_chunk_mut")
        {
            continue;
        }
        if in_ranges(k, test_ranges) {
            continue;
        }
        if k + 1 >= toks.len() || toks[k + 1].text != "(" {
            continue;
        }
        let close = match_delim(toks, k + 1);

        // Shape (b): fold chained on the map_indexed result.
        if t.text == "map_indexed" {
            let mut j = close + 1;
            while j + 1 < toks.len() && toks[j].text == "." {
                let name = toks[j + 1].text.clone();
                if matches!(name.as_str(), "sum" | "fold" | "product" | "reduce") {
                    push(findings, path, toks[j + 1].line, toks[j + 1].col, RULE_FLOAT_REDUCE,
                        format!("chunk partials combined with `.{name}` — use pool::tree_combine"));
                    break;
                }
                j += 2;
                if j < toks.len() && toks[j].text == "::" {
                    // Turbofish: skip `::<…>`.
                    j += 1;
                    if j < toks.len() && toks[j].text == "<" {
                        let mut depth = 0i32;
                        while j < toks.len() {
                            if toks[j].text == "<" {
                                depth += 1;
                            }
                            if toks[j].text == ">" {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            j += 1;
                        }
                        j += 1;
                    }
                }
                if j < toks.len() && toks[j].text == "(" {
                    j = match_delim(toks, j) + 1;
                }
            }
        }

        // Shape (a): captured accumulation inside the closure.
        let locals = closure_locals(toks, k + 2, close);
        for j in k + 2..close {
            if toks[j].kind == TokKind::Punct && (toks[j].text == "+=" || toks[j].text == "-=")
            {
                if let Some(base) = base_ident_before(toks, j) {
                    if !locals.contains(&base) && !first_upper(&base) {
                        push(findings, path, toks[j].line, toks[j].col, RULE_FLOAT_REDUCE,
                            format!(
                                "`{base} {}` accumulates into captured state inside a pool closure",
                                toks[j].text
                            ));
                    }
                }
            }
        }
    }
}

/// `adhoc-chunk-literal`: a numeric chunk size at a
/// `for_each_chunk_mut` call site with no named `*_CHUNK`/`*_ROWS`
/// constant in the expression. The fixed chunk grid *is* the
/// determinism contract — a drive-by literal changes partial
/// boundaries and silently changes every downstream sum. Expressions
/// like `2 * DECODE_CHUNK_V` pass; a bare `4096` does not.
fn rule_chunk_literal(
    path: &str,
    toks: &[Tok],
    test_ranges: &[(usize, usize)],
    findings: &mut Vec<Finding>,
) {
    for (k, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || t.text != "for_each_chunk_mut" {
            continue;
        }
        if in_ranges(k, test_ranges) {
            continue;
        }
        if k + 1 >= toks.len() || toks[k + 1].text != "(" {
            continue;
        }
        // Skip the definition itself (`fn for_each_chunk_mut(...)`).
        if k > 0 && toks[k - 1].text == "fn" {
            continue;
        }
        let close = match_delim(toks, k + 1);
        // Split the argument list at top-level commas.
        let mut args: Vec<(usize, usize)> = Vec::new();
        let mut depth = 0i32;
        let mut start = k + 2;
        for j in k + 2..=close.min(toks.len().saturating_sub(1)) {
            let tx = toks[j].text.as_str();
            if matches!(tx, "(" | "[" | "{") {
                depth += 1;
            } else if matches!(tx, ")" | "]" | "}") {
                if depth == 0 && j == close {
                    args.push((start, j));
                    break;
                }
                depth -= 1;
            } else if tx == "," && depth == 0 {
                args.push((start, j));
                start = j + 1;
            }
        }
        if args.len() < 2 {
            continue;
        }
        let (a, b) = args[1];
        let seg = &toks[a..b];
        let lit = seg.iter().find(|x| x.kind == TokKind::Num);
        let has_const = seg.iter().any(|x| {
            x.kind == TokKind::Ident
                && first_upper(&x.text)
                && x.text.bytes().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == b'_')
                && (x.text.contains("CHUNK") || x.text.contains("ROWS"))
        });
        if let Some(x) = lit {
            if !has_const {
                push(findings, path, x.line, x.col, RULE_ADHOC_CHUNK,
                    format!(
                        "literal chunk size `{}` at a pool call site — use a named *_CHUNK constant",
                        x.text
                    ));
            }
        }
    }
}

/// `lock-across-io`: a `MutexGuard` (from `.lock()` or
/// `lock_ignore_poison(..)`) still live when a blocking socket/stream
/// call runs in the same block. Holding a guard across `write_all` on
/// a slow peer turns one straggler into a whole-master stall — the
/// exact failure mode gradient coding exists to avoid. Release the
/// guard (scope it or `drop(guard)`) before the I/O.
fn rule_lock_across_io(
    path: &str,
    toks: &[Tok],
    test_ranges: &[(usize, usize)],
    findings: &mut Vec<Finding>,
) {
    let mut depth = 0i32;
    let mut guards: Vec<(String, i32)> = Vec::new();
    let mut k = 0usize;
    while k < toks.len() {
        let t = &toks[k];
        if t.text == "{" {
            depth += 1;
        } else if t.text == "}" {
            depth -= 1;
            guards.retain(|&(_, d)| d <= depth);
        } else if t.kind == TokKind::Ident && t.text == "let" && !in_ranges(k, test_ranges) {
            // Collect the pattern idents, then scan the RHS for a lock
            // acquisition.
            let mut j = k + 1;
            let mut pat: Vec<String> = Vec::new();
            while j < toks.len() && toks[j].text != "=" && toks[j].text != ";" {
                let x = &toks[j];
                if x.kind == TokKind::Ident
                    && !first_upper(&x.text)
                    && x.text != "mut"
                    && x.text != "ref"
                    && x.text != "let"
                {
                    pat.push(x.text.clone());
                }
                j += 1;
            }
            if j < toks.len() && toks[j].text == "=" {
                let mut d2 = 0i32;
                let mut j2 = j + 1;
                let mut has_lock = false;
                while j2 < toks.len() {
                    let tx = toks[j2].text.as_str();
                    if matches!(tx, "(" | "[" | "{") {
                        d2 += 1;
                    } else if matches!(tx, ")" | "]" | "}") {
                        if d2 == 0 {
                            break;
                        }
                        d2 -= 1;
                    } else if tx == ";" && d2 == 0 {
                        break;
                    }
                    if toks[j2].kind == TokKind::Ident
                        && (tx == "lock" || tx == "lock_ignore_poison")
                    {
                        has_lock = true;
                    }
                    j2 += 1;
                }
                if has_lock {
                    if let Some(name) = pat.last() {
                        guards.push((name.clone(), depth));
                    }
                }
                k = j2;
                continue;
            }
        } else if t.kind == TokKind::Ident
            && t.text == "drop"
            && k + 1 < toks.len()
            && toks[k + 1].text == "("
        {
            let close = match_delim(toks, k + 1);
            guards.retain(|(n, _)| {
                !toks[k + 2..close].iter().any(|x| x.kind == TokKind::Ident && x.text == *n)
            });
            k = close;
            continue;
        } else if t.kind == TokKind::Ident
            && IO_CALLS.contains(&t.text.as_str())
            && !guards.is_empty()
            && !in_ranges(k, test_ranges)
            && k > 0
            && (toks[k - 1].text == "." || toks[k - 1].text == "::")
            && k + 1 < toks.len()
            && toks[k + 1].text == "("
        {
            if let Some((g, _)) = guards.last() {
                push(findings, path, t.line, t.col, RULE_LOCK_IO,
                    format!("`{}` I/O while guard `{g}` is live — release the lock first", t.text));
            }
        }
        k += 1;
    }
}

/// Strip a Rust integer type suffix (`u8`…`usize`, `i8`…`isize`).
fn strip_int_suffix(s: &str) -> &str {
    for suf in ["usize", "isize", "u128", "i128", "u64", "i64", "u32", "i32", "u16", "i16",
        "u8", "i8"]
    {
        if let Some(stripped) = s.strip_suffix(suf) {
            return stripped;
        }
    }
    s
}

fn parse_int(text: &str) -> Result<u64, ()> {
    let cleaned: String = text.chars().filter(|&c| c != '_').collect();
    let t = strip_int_suffix(&cleaned);
    let (digits, radix) = if let Some(r) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        (r, 16)
    } else if let Some(r) = t.strip_prefix("0o") {
        (r, 8)
    } else if let Some(r) = t.strip_prefix("0b").or_else(|| t.strip_prefix("0B")) {
        (r, 2)
    } else {
        (t, 10)
    };
    u64::from_str_radix(digits, radix).map_err(|_| ())
}

/// Tiny const-expression evaluator over tokens `[a, b)`: integer
/// literals, `+ - * <<`, parentheses. Precedence (tightest first):
/// `*`, then `+ -`, then `<<` — enough for every layout constant in
/// `wire.rs` (`4 + 1 + 4`, `1 << 26`).
struct ConstParser<'a> {
    toks: &'a [Tok],
    pos: usize,
    end: usize,
}

impl ConstParser<'_> {
    fn peek(&self) -> Option<&str> {
        if self.pos < self.end {
            Some(self.toks[self.pos].text.as_str())
        } else {
            None
        }
    }

    fn expr(&mut self) -> Result<u64, ()> {
        let mut v = self.add()?;
        while self.peek() == Some("<<") {
            self.pos += 1;
            let w = self.add()?;
            v = if w >= 64 { 0 } else { v << w };
        }
        Ok(v)
    }

    fn add(&mut self) -> Result<u64, ()> {
        let mut v = self.mul()?;
        while matches!(self.peek(), Some("+") | Some("-")) {
            let minus = self.peek() == Some("-");
            self.pos += 1;
            let w = self.mul()?;
            v = if minus { v.wrapping_sub(w) } else { v.wrapping_add(w) };
        }
        Ok(v)
    }

    fn mul(&mut self) -> Result<u64, ()> {
        let mut v = self.atom()?;
        while self.peek() == Some("*") {
            self.pos += 1;
            v = v.wrapping_mul(self.atom()?);
        }
        Ok(v)
    }

    fn atom(&mut self) -> Result<u64, ()> {
        if self.pos >= self.end {
            return Err(());
        }
        let t = &self.toks[self.pos];
        if t.text == "(" {
            self.pos += 1;
            let v = self.expr()?;
            if self.peek() == Some(")") {
                self.pos += 1;
            }
            return Ok(v);
        }
        if t.kind == TokKind::Num {
            self.pos += 1;
            return parse_int(&t.text);
        }
        Err(())
    }
}

fn eval_const_expr(toks: &[Tok], a: usize, b: usize) -> Result<u64, ()> {
    ConstParser { toks, pos: a, end: b }.expr()
}

/// `wire-layout-drift`: re-derives the FNV-1a-64 fingerprint of the
/// frame-layout constants in `coordinator/wire.rs` (serialized as
/// `"NAME=<decimal>;"` in registry order) and compares it to the
/// recorded `WIRE_LAYOUT_FINGERPRINT`. A layout change without a
/// `MAGIC` bump means an old peer mis-parses frames instead of failing
/// the Hello handshake — and the chaos/fuzz layer's corruption oracles
/// assume layout and MAGIC move together.
fn rule_wire_layout(path: &str, toks: &[Tok], findings: &mut Vec<Finding>) {
    if !path.ends_with("coordinator/wire.rs") {
        return;
    }
    let mut values: Vec<(String, u64)> = Vec::new();
    let mut recorded: Option<u64> = None;
    for (k, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident
            || t.text != "const"
            || k + 1 >= toks.len()
            || toks[k + 1].kind != TokKind::Ident
        {
            continue;
        }
        let name = toks[k + 1].text.clone();
        let mut j = k + 2;
        while j < toks.len() && toks[j].text != "=" {
            if toks[j].text == ";" {
                break;
            }
            j += 1;
        }
        if j >= toks.len() || toks[j].text != "=" {
            continue;
        }
        let mut e = j + 1;
        while e < toks.len() && toks[e].text != ";" {
            e += 1;
        }
        let Ok(v) = eval_const_expr(toks, j + 1, e) else { continue };
        if name == "WIRE_LAYOUT_FINGERPRINT" {
            recorded = Some(v);
        } else if WIRE_REGISTRY.contains(&name.as_str())
            && !values.iter().any(|(n, _)| *n == name)
        {
            values.push((name, v));
        }
    }
    let missing: Vec<&str> = WIRE_REGISTRY
        .iter()
        .copied()
        .filter(|nm| !values.iter().any(|(n, _)| n == nm))
        .collect();
    if !missing.is_empty() {
        push(findings, path, 1, 1, RULE_WIRE_DRIFT,
            format!("layout constants missing: {missing:?}"));
        return;
    }
    let mut data = String::new();
    for nm in WIRE_REGISTRY {
        if let Some((_, v)) = values.iter().find(|(n, _)| n == nm) {
            data.push_str(nm);
            data.push('=');
            data.push_str(&v.to_string());
            data.push(';');
        }
    }
    let h = super::fnv1a64(data.as_bytes());
    match recorded {
        None => push(findings, path, 1, 1, RULE_WIRE_DRIFT,
            format!("no WIRE_LAYOUT_FINGERPRINT recorded; expected {h:#018x}")),
        Some(r) if r != h => push(findings, path, 1, 1, RULE_WIRE_DRIFT,
            format!(
                "frame layout drifted: fingerprint {h:#018x} != recorded {r:#018x} — bump MAGIC and re-pin"
            )),
        Some(_) => {}
    }
}

/// Run every rule over one file's token stream.
pub(crate) fn run_all(
    path: &str,
    toks: &[Tok],
    test_ranges: &[(usize, usize)],
    findings: &mut Vec<Finding>,
) {
    rule_panic_in_lib(path, toks, test_ranges, findings);
    rule_wallclock(path, toks, test_ranges, findings);
    rule_float_reduce(path, toks, test_ranges, findings);
    rule_chunk_literal(path, toks, test_ranges, findings);
    rule_lock_across_io(path, toks, test_ranges, findings);
    rule_wire_layout(path, toks, findings);
}
