//! # `gradcode lint` — in-repo static analysis
//!
//! A zero-dependency analysis pass that machine-enforces the crate's
//! hand-written invariants: bitwise-deterministic float reduction
//! (everything cross-chunk goes through `pool::tree_combine` on the
//! fixed chunk grid), panic hygiene on the distributed path, lock
//! discipline around socket I/O, seeded-RNG purity, and wire-layout
//! versioning. The contracts themselves are documented in
//! `rust/DESIGN.md`; this module is what turns violating them from a
//! review comment into a CI failure — there is no clippy-plugin
//! mechanism available offline, so the crate carries its own.
//!
//! Architecture, bottom up:
//! - [`lexer`] — a small comment/string-aware Rust lexer (tokens with
//!   positions; no external parser).
//! - `rules` — six token-level rules, each tied to one invariant:
//!   `float-reduce-outside-tree`, `adhoc-chunk-literal`,
//!   `panic-in-lib`, `lock-across-io`, `wallclock-entropy`,
//!   `wire-layout-drift`.
//! - This module — the per-file driver ([`lint_source`]), the tree
//!   walker ([`lint_tree`] over `rust/src`, `rust/tests`,
//!   `rust/benches`), the grandfathering [`Baseline`], and the JSON
//!   report used as a CI artifact.
//!
//! Suppression: a finding is silenced by `// lint: allow(<rule-id>)
//! <reason>` on the same or the preceding line. The reason is
//! mandatory — an allow without one is ignored — and suppressed
//! findings stay visible in the `--json` summary. Grandfathering: the
//! committed `lint.baseline` (`rule<TAB>file<TAB>count` lines) caps
//! how many findings per rule/file are tolerated without failing
//! `--deny`; the repo ships with it empty and the goal is to keep it
//! that way.
//!
//! The linter lints itself (this directory is under `rust/src`), so
//! everything here propagates errors instead of panicking.

pub mod lexer;
mod rules;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub const RULE_FLOAT_REDUCE: &str = "float-reduce-outside-tree";
pub const RULE_ADHOC_CHUNK: &str = "adhoc-chunk-literal";
pub const RULE_PANIC: &str = "panic-in-lib";
pub const RULE_LOCK_IO: &str = "lock-across-io";
pub const RULE_WALLCLOCK: &str = "wallclock-entropy";
pub const RULE_WIRE_DRIFT: &str = "wire-layout-drift";

/// Every rule id, in reporting order.
pub const RULE_IDS: [&str; 6] = [
    RULE_FLOAT_REDUCE,
    RULE_ADHOC_CHUNK,
    RULE_PANIC,
    RULE_LOCK_IO,
    RULE_WALLCLOCK,
    RULE_WIRE_DRIFT,
];

/// One diagnostic: `file:line:col rule-id message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}:{} {} {}", self.file, self.line, self.col, self.rule, self.msg)
    }
}

/// Per-file lint result: findings that stand, and findings silenced by
/// a reasoned `// lint: allow(...)`.
#[derive(Debug, Default)]
pub struct FileReport {
    pub live: Vec<Finding>,
    pub suppressed: Vec<Finding>,
}

/// Whole-tree lint result.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    pub live: Vec<Finding>,
    pub suppressed: Vec<Finding>,
}

/// FNV-1a 64-bit hash — the fingerprint primitive shared with
/// `coordinator::wire::layout_fingerprint`, kept here so the linter
/// and the runtime constant can never disagree on the algorithm.
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Lint one source text. `path_label` is the repo-relative path with
/// forward slashes (e.g. `rust/src/coordinator/wire.rs`); rules use it
/// for scoping (`/src/`-only rules, the `obs/`/`bench/` wall-clock
/// allowlist, the `testkit/` panic exemption, the wire.rs fingerprint).
pub fn lint_source(path_label: &str, src: &str) -> FileReport {
    let lexed = lexer::lex(src);
    let test_ranges = rules::cfg_test_ranges(&lexed.toks);
    let allows = rules::parse_allows(&lexed.comments);
    let mut findings = Vec::new();
    rules::run_all(path_label, &lexed.toks, &test_ranges, &mut findings);

    let mut report = FileReport::default();
    for f in findings {
        let suppressed = allows.iter().any(|(al, rule, reason)| {
            rule == f.rule && (*al == f.line || *al + 1 == f.line) && !reason.is_empty()
        });
        if suppressed {
            report.suppressed.push(f);
        } else {
            report.live.push(f);
        }
    }
    report
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = Vec::new();
    for e in std::fs::read_dir(dir)? {
        entries.push(e?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().map_or(false, |x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `<root>/rust/{src,tests,benches}`,
/// deterministically ordered. Findings carry root-relative paths.
pub fn lint_tree(root: &Path) -> std::io::Result<Report> {
    let mut files = Vec::new();
    for sub in ["rust/src", "rust/tests", "rust/benches"] {
        collect_rs(&root.join(sub), &mut files)?;
    }
    let mut report = Report::default();
    for path in files {
        let src = std::fs::read_to_string(&path)?;
        let rel = path.strip_prefix(root).unwrap_or(&path);
        let label = rel.to_string_lossy().replace('\\', "/");
        let fr = lint_source(&label, &src);
        report.files_scanned += 1;
        report.live.extend(fr.live);
        report.suppressed.extend(fr.suppressed);
    }
    let key = |f: &Finding| (f.file.clone(), f.line, f.col, f.rule);
    report.live.sort_by_key(key);
    report.suppressed.sort_by_key(key);
    Ok(report)
}

/// Grandfathered findings: `(rule, file) -> tolerated count`. Parsed
/// from `lint.baseline` (`rule<TAB>file<TAB>count` lines, `#`
/// comments). Findings beyond the tolerated count are "new" and fail
/// `--deny`.
#[derive(Debug, Default)]
pub struct Baseline {
    entries: BTreeMap<(String, String), usize>,
}

impl Baseline {
    pub fn parse(text: &str) -> Result<Baseline, String> {
        let mut entries = BTreeMap::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split('\t');
            match (it.next(), it.next(), it.next()) {
                (Some(rule), Some(file), Some(count)) => {
                    let c: usize = count
                        .trim()
                        .parse()
                        .map_err(|_| format!("baseline line {}: bad count {count:?}", idx + 1))?;
                    *entries.entry((rule.to_string(), file.to_string())).or_insert(0) += c;
                }
                _ => {
                    return Err(format!(
                        "baseline line {}: expected rule<TAB>file<TAB>count",
                        idx + 1
                    ))
                }
            }
        }
        Ok(Baseline { entries })
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Split live findings into `(new, grandfathered)`: per
    /// `(rule, file)`, the first `count` findings (in report order) are
    /// covered by the baseline, the rest are new.
    pub fn split(&self, live: Vec<Finding>) -> (Vec<Finding>, Vec<Finding>) {
        let mut used: BTreeMap<(String, String), usize> = BTreeMap::new();
        let mut fresh = Vec::new();
        let mut grandfathered = Vec::new();
        for f in live {
            let key = (f.rule.to_string(), f.file.clone());
            let allowed = self.entries.get(&key).copied().unwrap_or(0);
            let u = used.entry(key).or_insert(0);
            if *u < allowed {
                *u += 1;
                grandfathered.push(f);
            } else {
                fresh.push(f);
            }
        }
        (fresh, grandfathered)
    }
}

/// Serialize the current live findings as baseline content (used by
/// `--update-baseline`). An empty report yields a header-only file.
pub fn render_baseline(report: &Report) -> String {
    let mut counts: BTreeMap<(&'static str, String), usize> = BTreeMap::new();
    for f in &report.live {
        *counts.entry((f.rule, f.file.clone())).or_insert(0) += 1;
    }
    let mut out = String::from(
        "# gradlint baseline — grandfathered findings, one `rule<TAB>file<TAB>count` per line.\n\
         # Regenerate with `gradcode lint --update-baseline`. The goal is an empty file:\n\
         # fix findings or justify them inline with `// lint: allow(<rule>) <reason>`.\n",
    );
    for ((rule, file), c) in &counts {
        out.push_str(&format!("{rule}\t{file}\t{c}\n"));
    }
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_finding(f: &Finding, extra: &str) -> String {
    format!(
        "{{\"file\":\"{}\",\"line\":{},\"col\":{},\"rule\":\"{}\",\"message\":\"{}\"{extra}}}",
        json_escape(&f.file),
        f.line,
        f.col,
        f.rule,
        json_escape(&f.msg)
    )
}

/// Machine-readable report for `gradcode lint --json` (the CI
/// artifact). `fresh` and `grandfathered` partition the live findings
/// per the baseline; suppressed findings are listed with their counts
/// so reasoned `allow`s stay auditable.
pub fn report_json(
    files_scanned: usize,
    fresh: &[Finding],
    grandfathered: &[Finding],
    suppressed: &[Finding],
) -> String {
    let list = |fs: &[Finding], extra: &str| -> String {
        let items: Vec<String> = fs.iter().map(|f| json_finding(f, extra)).collect();
        items.join(",")
    };
    format!(
        "{{\"files_scanned\":{files_scanned},\
         \"new\":{},\"baselined\":{},\"suppressed\":{},\
         \"findings\":[{}],\
         \"baselined_findings\":[{}],\
         \"suppressed_findings\":[{}]}}",
        fresh.len(),
        grandfathered.len(),
        suppressed.len(),
        list(fresh, ",\"baselined\":false"),
        list(grandfathered, ",\"baselined\":true"),
        list(suppressed, "")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_known_vectors() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn baseline_roundtrip_and_split() {
        let b = Baseline::parse("# comment\npanic-in-lib\trust/src/x.rs\t2\n").unwrap();
        let mk = |line| Finding {
            file: "rust/src/x.rs".into(),
            line,
            col: 1,
            rule: RULE_PANIC,
            msg: "m".into(),
        };
        let (fresh, old) = b.split(vec![mk(1), mk(2), mk(3)]);
        assert_eq!(old.len(), 2);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].line, 3);
    }

    #[test]
    fn baseline_rejects_malformed_lines() {
        assert!(Baseline::parse("panic-in-lib rust/src/x.rs 2\n").is_err());
        assert!(Baseline::parse("panic-in-lib\trust/src/x.rs\tmany\n").is_err());
    }

    #[test]
    fn json_report_escapes_and_counts() {
        let f = Finding {
            file: "rust/src/a\"b.rs".into(),
            line: 3,
            col: 7,
            rule: RULE_WALLCLOCK,
            msg: "quote \" and\nnewline".into(),
        };
        let s = report_json(5, &[f.clone()], &[], &[f]);
        assert!(s.contains("\"files_scanned\":5"));
        assert!(s.contains("\"new\":1"));
        assert!(s.contains("\"suppressed\":1"));
        assert!(s.contains("a\\\"b.rs"));
        assert!(s.contains("\\nnewline"));
    }
}
