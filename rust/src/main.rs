//! `gradcode` — the leader binary.
//!
//! Subcommands:
//! - `info`         PJRT platform + artifact inventory (needs `--features pjrt`)
//! - `train`        run coded distributed training on synthetic data
//!                  (`--scheme approx --quorum 0.7` selects the
//!                  approximate partial-recovery regime; `--scheme hetero
//!                  --profile bimodal:0.5:4` the heterogeneous one;
//!                  `--chaos crash=0.02,drop=0.05` arms fault injection)
//! - `chaos-report` train under an injected fault plan and dump the
//!                  fault log, rung tally, and the simulator's binomial
//!                  prediction of the degraded fraction
//! - `trace-report` summarize a telemetry JSONL captured with
//!                  `train --trace <path>` (or `worker --trace`): phase
//!                  breakdown, straggler attribution, wire counters;
//!                  `--chrome out.json` exports a Perfetto-loadable trace;
//!                  `--prom` renders the merged trace as Prometheus text
//! - `flight-dump`  pretty-print a flight-recorder dump (the bounded ring
//!                  of recent iteration/fault events written on abort, or
//!                  wherever `GRADCODE_FLIGHT_DUMP` points)
//! - `ci-gate`      bench-regression gate: compare fresh `BENCH_*.json`
//!                  (from the `ci.sh` bench smokes, in `target/bench/`)
//!                  against the committed repo-root baselines and fail on
//!                  regressed headline metrics
//! - `lint`         in-repo static analysis: walks rust/{src,tests,benches}
//!                  with the crate's own lexer + rule registry and reports
//!                  determinism / panic-hygiene / lock-discipline /
//!                  wire-versioning violations (`--deny` gates CI; `--json`
//!                  is the machine artifact; `lint.baseline` grandfathers)
//! - `plan`         §VI model: optimal (d, s, m) for given delay parameters
//! - `plan-hetero`  heterogeneous load planner: optimized per-worker load
//!                  vector and predicted speedup over uniform placement
//! - `quorum`       §VI model extended to partial recovery: expected time
//!                  and residual per quorum size
//! - `stability`    condition-number / reconstruction-error sweep
//!
//! Examples live in `examples/`; the table/figure regenerators in
//! `rust/benches/`.

use gradcode::chaos::{ChaosConfig, ChaosSpec};
use gradcode::cli::{App, Command};
use gradcode::coding::{
    max_condition_number, reconstruction_error, ApproxCode, GradientCode, HeteroCode,
    PolynomialCode, RandomCode, SchemeConfig,
};
use gradcode::coordinator::{
    train, ExecutionMode, OptChoice, SchemeSpec, SpeedProfile, TrainConfig,
};
use gradcode::data::{train_test_split, CategoricalConfig, DenseDataset, SyntheticCategorical};
use gradcode::metrics::RunLog;
use gradcode::simulator::{optimal_triple, DelayParams};

fn app() -> App {
    App::new("gradcode", "communication-computation efficient gradient coding")
        .command(Command::new("info", "PJRT platform + artifact inventory"))
        .command(
            Command::new("train", "coded distributed training on synthetic data")
                .flag("n", "10", "number of workers (= data subsets)")
                .flag("s", "1", "straggler tolerance")
                .flag("m", "2", "communication reduction factor")
                .flag("scheme", "poly", "poly | random | naive | approx | hetero")
                .flag("approx-d", "3", "replication d for --scheme approx")
                .flag("quorum", "0.7", "responder fraction for --scheme approx")
                .flag(
                    "profile",
                    "uniform",
                    "fleet speed profile: uniform | linear[:R] | bimodal[:F[:R]] | custom:v1,v2,…",
                )
                .flag("iters", "200", "training iterations")
                .flag("rows", "640", "training rows")
                .flag("lr", "0.01", "learning rate")
                .flag("momentum", "0.9", "NAG momentum")
                .flag("seed", "7", "experiment seed")
                .flag("eval-every", "10", "evaluation period")
                .flag(
                    "chaos",
                    "",
                    "fault-injection spec: crash=P,drop=P,corrupt=P,dup=P,delay=P,reset=P[,delay_secs=S][,restart=K][,seed=N]; empty = off",
                )
                .flag(
                    "trace",
                    "",
                    "write telemetry JSONL to this path and print the phase breakdown; empty = off",
                )
                .flag(
                    "threads",
                    "0",
                    "pool threads for the parallel hot paths (0 = GRADCODE_THREADS or all cores); results are bitwise identical either way",
                )
                .flag(
                    "metrics-addr",
                    "",
                    "serve a live Prometheus text snapshot on this address (e.g. 127.0.0.1:9184) for the duration of the run; empty = off",
                )
                .flag(
                    "metrics-linger",
                    "0",
                    "with --metrics-addr: after training, keep serving up to this many seconds until at least one scrape landed (lets CI scrape a short run)",
                )
                .switch("pjrt", "use the AOT PJRT backend (needs --features pjrt + artifacts)")
                .switch("no-delays", "disable straggler injection")
                .switch("csv", "dump per-iteration CSV to stdout"),
        )
        .command(
            Command::new(
                "ci-gate",
                "compare fresh BENCH_*.json against committed baselines; fail on regression",
            )
            .flag("current", "target/bench", "directory holding the freshly produced BENCH_*.json")
            .flag("baseline", ".", "directory holding the committed baseline BENCH_*.json")
            .flag("tol", "0.15", "allowed relative regression of each headline metric"),
        )
        .command(
            Command::new(
                "lint",
                "static analysis over rust/{src,tests,benches}: determinism, panic-hygiene, lock-discipline, wire-versioning",
            )
            .flag("root", ".", "repository root holding rust/src, rust/tests, rust/benches")
            .flag("baseline", "lint.baseline", "grandfathered-findings file, relative to --root")
            .switch("json", "machine-readable JSON report on stdout (the CI artifact)")
            .switch("deny", "exit non-zero on any finding not covered by the baseline")
            .switch("update-baseline", "rewrite the baseline from the current findings and exit"),
        )
        .command(
            Command::new(
                "trace-report",
                "summarize a telemetry JSONL (from train/worker --trace): phase table, stragglers, counters",
            )
            .flag("chrome", "", "also write a Chrome trace-event JSON here (load in Perfetto / chrome://tracing)")
            .switch("csv", "dump per-phase stats as CSV")
            .switch("prom", "render the merged trace as a Prometheus text snapshot (same renderer as --metrics-addr)"),
        )
        .command(
            Command::new(
                "flight-dump",
                "pretty-print a flight-recorder dump (target/flight_dump.jsonl unless a path or GRADCODE_FLIGHT_DUMP says otherwise)",
            ),
        )
        .command(
            Command::new(
                "chaos-report",
                "train under injected faults and dump the fault log + rung tally",
            )
            .flag("n", "6", "number of workers (= data subsets)")
            .flag("s", "2", "straggler tolerance")
            .flag("m", "1", "communication reduction factor")
            .flag("iters", "100", "training iterations")
            .flag("rows", "480", "training rows")
            .flag("lr", "0.02", "learning rate")
            .flag("seed", "7", "experiment seed")
            .flag(
                "chaos",
                "drop=0.1,crash=0.01,corrupt=0.02",
                "fault-injection spec (same grammar as train --chaos)",
            )
            .switch("csv", "dump the fault-log CSV to stdout"),
        )
        .command(
            Command::new("plan", "optimal (d,s,m) from the §VI runtime model")
                .flag("n", "10", "number of workers")
                .flag("lambda1", "0.6", "computation straggling rate")
                .flag("t1", "1.5", "min per-subset computation time")
                .flag("lambda2", "0.1", "communication straggling rate")
                .flag("t2", "6", "min full-vector communication time"),
        )
        .command(
            Command::new(
                "plan-hetero",
                "heterogeneous load planner: optimized load vector + predicted speedup",
            )
            .flag("n", "10", "number of workers")
            .flag("s", "1", "straggler tolerance")
            .flag("m", "2", "communication reduction factor")
            .flag(
                "profile",
                "bimodal:0.5:4",
                "fleet speed profile: uniform | linear[:R] | bimodal[:F[:R]] | custom:v1,v2,…",
            )
            .flag("max-groups", "3", "maximum speed groups the planner may form")
            .flag("lambda1", "1.2", "computation straggling rate")
            .flag("t1", "1", "min per-subset computation time")
            .flag("lambda2", "0.2", "communication straggling rate")
            .flag("t2", "6", "min full-vector communication time"),
        )
        .command(
            Command::new("quorum", "partial-recovery tradeoff: E[T] and E[residual] per quorum")
                .flag("n", "10", "number of workers")
                .flag("d", "3", "replication (subsets per worker)")
                .flag("samples", "2000", "Monte-Carlo samples per quorum size")
                .flag("seed", "1", "sampling seed")
                .flag("lambda1", "0.6", "computation straggling rate")
                .flag("t1", "1.5", "min per-subset computation time")
                .flag("lambda2", "0.1", "communication straggling rate")
                .flag("t2", "6", "min full-vector communication time"),
        )
        .command(
            Command::new("stability", "condition-number and error sweep")
                .flag("n", "10", "number of workers")
                .flag("s", "2", "straggler tolerance")
                .flag("m", "2", "communication reduction factor")
                .flag("scheme", "poly", "poly | random")
                .flag("dim", "64", "gradient dimension for error trials")
                .flag("trials", "20", "round-trip trials")
                .flag("budget", "2000", "max straggler patterns to sweep"),
        )
        .command(
            Command::new("grid", "E[T_tot] grid for all (d,m) at given delay params")
                .flag("n", "8", "number of workers")
                .flag("lambda1", "0.8", "computation straggling rate")
                .flag("t1", "1.6", "min per-subset computation time")
                .flag("lambda2", "0.1", "communication straggling rate")
                .flag("t2", "6", "min full-vector communication time"),
        )
        .command(
            Command::new("leader", "TCP master: coordinate remote workers")
                .flag("listen", "127.0.0.1:7070", "listen address")
                .flag("n", "4", "number of workers")
                .flag("s", "1", "straggler tolerance")
                .flag("m", "2", "communication reduction factor")
                .flag("scheme", "poly", "poly | random | naive | approx | hetero")
                .flag("approx-d", "3", "replication d for --scheme approx")
                .flag("quorum", "0.7", "responder fraction for --scheme approx")
                .flag(
                    "profile",
                    "uniform",
                    "fleet speed profile for --scheme hetero (uniform | linear[:R] | bimodal[:F[:R]] | custom:…)",
                )
                .flag("iters", "100", "training iterations")
                .flag("rows", "256", "training rows (shared-seed data)")
                .flag("dim", "512", "gradient dimension")
                .flag("lr", "0.02", "learning rate")
                .flag("data-seed", "2018", "shared dataset seed")
                .flag("checkpoint", "", "optional checkpoint path (save/resume)"),
        )
        .command(
            Command::new("worker", "TCP worker: serve coded gradients")
                .flag("connect", "127.0.0.1:7070", "master address")
                .flag("id", "0", "worker id (0-based)")
                .flag("n", "4", "total workers (all workers must agree so the shared --chaos plan lines up)")
                .flag("chaos-iters", "100", "iterations the --chaos plan covers")
                .flag(
                    "chaos",
                    "",
                    "fault-injection spec for this fleet (same grammar and seed on every worker); empty = off",
                )
                .flag(
                    "trace",
                    "",
                    "write this worker's telemetry JSONL (compute spans, wire counters) to this path; empty = off",
                ),
        )
}

fn cmd_leader(a: gradcode::cli::Args) -> anyhow::Result<()> {
    use gradcode::checkpoint::Checkpoint;
    use gradcode::coordinator::remote::{
        dataset_from_setup, decode_gather, scheme_from_setup, RemoteMaster,
    };
    use gradcode::coordinator::wire::Setup;
    use gradcode::coding::quorum_count;
    use gradcode::coordinator::wire::{
        SCHEME_APPROX, SCHEME_HETERO, SCHEME_POLY, SCHEME_RANDOM, SCHEME_UNCODED,
    };
    let n = a.get_usize("n");
    let (s_flag, m_flag) = (a.get_usize("s"), a.get_usize("m"));
    let base = |kind: u8, d: u32, s: u32, m: u32| {
        Setup::homogeneous(
            n as u32,
            d,
            s,
            m,
            kind,
            a.get_u64("data-seed") ^ 0x5c,
            a.get_u64("data-seed"),
            a.get_usize("rows") as u32,
            a.get_usize("dim") as u32,
        )
    };
    let setup = match a.get_str("scheme") {
        "poly" => base(SCHEME_POLY, (s_flag + m_flag) as u32, s_flag as u32, m_flag as u32),
        "random" => {
            base(SCHEME_RANDOM, (s_flag + m_flag) as u32, s_flag as u32, m_flag as u32)
        }
        "naive" => base(SCHEME_UNCODED, 1, 0, 1),
        "approx" => {
            let q = a.get_f64("quorum");
            anyhow::ensure!(q > 0.0 && q <= 1.0, "quorum fraction must be in (0,1]");
            let quorum = quorum_count(n, q) as u32;
            let d = a.get_usize("approx-d") as u32;
            Setup {
                quorum,
                ..base(SCHEME_APPROX, d, n as u32 - quorum, 1)
            }
        }
        "hetero" => {
            let profile = SpeedProfile::parse(a.get_str("profile"))
                .map_err(|e| anyhow::anyhow!(e))?;
            // Round to the milli-unit wire precision FIRST and build the
            // reference from the rounded speeds: the workers only ever
            // see `speeds_milli`, so the shipped load vector must come
            // from exactly those values or the handshake cross-check
            // would reject a valid deployment.
            let speeds_milli: Vec<u32> = profile
                .try_speeds(n)
                .map_err(|e| anyhow::anyhow!(e))?
                .iter()
                .map(|&x| (x * 1000.0).round().max(1.0) as u32)
                .collect();
            let speeds: Vec<f64> =
                speeds_milli.iter().map(|&x| x as f64 / 1000.0).collect();
            let reference = HeteroCode::from_speeds(n, s_flag, m_flag, &speeds)?;
            Setup {
                loads: reference.loads().iter().map(|&d| d as u32).collect(),
                speeds_milli,
                ..base(
                    SCHEME_HETERO,
                    reference.config().d as u32,
                    s_flag as u32,
                    m_flag as u32,
                )
            }
        }
        other => anyhow::bail!("unknown scheme {other:?}"),
    };
    println!("leader: waiting for {} workers on {}", setup.n, a.get_str("listen"));
    let mut master = RemoteMaster::listen(a.get_str("listen"), setup.clone())?;
    println!("leader: all workers connected");
    let code = scheme_from_setup(&setup)?;
    let train_ds = dataset_from_setup(&setup);
    let lr = a.get_f64("lr") as f32;
    let ck_path = a.get_str("checkpoint").to_string();
    let (start_iter, beta0) = if !ck_path.is_empty()
        && std::path::Path::new(&ck_path).exists()
    {
        let ck = Checkpoint::load(std::path::Path::new(&ck_path))?;
        anyhow::ensure!(ck.beta.len() == setup.dim as usize, "checkpoint dim mismatch");
        println!("leader: resumed from {ck_path} at iter {}", ck.iter);
        (ck.iter, ck.beta)
    } else {
        (0, vec![0.0f32; setup.dim as usize])
    };
    let mut opt = gradcode::optim::Nag::new(beta0, lr, 0.9);
    use gradcode::optim::Optimizer;
    let mut cache = std::collections::HashMap::new();
    let iters = a.get_usize("iters") as u64;
    for iter in start_iter..iters {
        let gather = master.run_iteration(iter, opt.eval_point())?;
        if !gather.complete {
            // Deadline expired below quorum (workers crashed or reset):
            // skip the update rather than dying — a stale-gradient step
            // of the kind the in-process trainer's ladder takes.
            println!(
                "iter {iter:>4}: gather incomplete ({} of {} responders{}), skipping update",
                gather.results.len(),
                setup.wait_for(),
                if gather.rejected.is_empty() {
                    String::new()
                } else {
                    format!(", {} checksum-rejected", gather.rejected.len())
                }
            );
            continue;
        }
        let grad = decode_gather(code.as_ref(), &gather, &mut cache)?;
        opt.step(&grad);
        if iter % 10 == 0 || iter + 1 == iters {
            let loss = gradcode::model::LogisticModel::loss(&train_ds, opt.iterate());
            println!(
                "iter {iter:>4}: loss {loss:.5}, quorum in {:.1} ms",
                gather.elapsed * 1e3
            );
            if !ck_path.is_empty() {
                Checkpoint::new(iter + 1, opt.iterate().to_vec())
                    .save(std::path::Path::new(&ck_path))?;
            }
        }
    }
    master.shutdown();
    println!("leader: done");
    Ok(())
}

fn cmd_worker(a: gradcode::cli::Args) -> anyhow::Result<()> {
    let id = a.get_usize("id");
    // The fault plan is a fleet-wide schedule: every worker builds the
    // same plan from the shared spec (same seed, same n) and consults
    // only its own row, exactly like the in-process cluster does.
    let plan = match a.get_str("chaos") {
        "" => None,
        spec => {
            let spec = ChaosSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
            let n = a.get_usize("n");
            anyhow::ensure!(id < n, "--id {id} out of range for --n {n}");
            let plan = gradcode::chaos::FaultPlan::random(
                n,
                a.get_usize("chaos-iters") as u64,
                &spec,
            );
            println!(
                "worker {id}: chaos armed ({} scheduled faults fleet-wide, seed {:#x})",
                plan.len(),
                spec.seed
            );
            Some(plan)
        }
    };
    println!("worker {id}: connecting to {}", a.get_str("connect"));
    let trace_path = a.get_str("trace").to_string();
    let rec = if trace_path.is_empty() {
        gradcode::obs::Recorder::disabled()
    } else {
        gradcode::obs::Recorder::enabled()
    };
    let served =
        gradcode::coordinator::run_worker_traced(a.get_str("connect"), id, plan, &rec)?;
    if !trace_path.is_empty() {
        std::fs::write(&trace_path, rec.to_jsonl())?;
        println!("worker {id}: trace -> {trace_path}");
    }
    println!("worker {id}: served {served} tasks, shutting down");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_info() -> anyhow::Result<()> {
    use gradcode::runtime::Manifest;
    println!("platform: {}", gradcode::runtime::platform_name()?);
    let dir = Manifest::default_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({}): {} entries", dir.display(), m.len());
            for k in m.worker_keys() {
                println!(
                    "  worker n={} d={} m={} rows={} l={}",
                    k.n, k.d, k.m, k.rows, k.dim
                );
            }
        }
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_info() -> anyhow::Result<()> {
    println!("platform: PJRT disabled (rebuild with `--features pjrt`)");
    println!("artifacts: not inspected without the pjrt feature");
    Ok(())
}

/// PJRT training path; compiled out (with a clear error) without the
/// `pjrt` feature so the default offline build has no `xla` dependency.
#[cfg(feature = "pjrt")]
fn run_pjrt_train(
    cfg: TrainConfig,
    scheme: SchemeSpec,
    train_ds: &DenseDataset,
    test_ds: &DenseDataset,
    rec: &gradcode::obs::Recorder,
) -> anyhow::Result<RunLog> {
    use gradcode::coordinator::Trainer;
    use gradcode::runtime::{Manifest, PjrtBackend};
    use std::sync::Arc;
    let n = cfg.n;
    let code = scheme.build(n)?;
    // PJRT artifacts are fixed-shape: pad to the artifact dims.
    let padded = train_ds.pad_cols(512);
    anyhow::ensure!(
        padded.rows / n == 64,
        "PJRT mode needs rows such that rows/n = 64 (artifact shape); \
         use --rows {}",
        64 * n * 5 / 4
    );
    let backend =
        Arc::new(PjrtBackend::new(&Manifest::default_dir(), code.as_ref(), &padded)?);
    let mut tr = Trainer::with_backend(cfg, code, backend, &padded, Some(test_ds))?;
    tr.attach_recorder(rec);
    tr.run()
}

#[cfg(not(feature = "pjrt"))]
fn run_pjrt_train(
    _cfg: TrainConfig,
    _scheme: SchemeSpec,
    _train_ds: &DenseDataset,
    _test_ds: &DenseDataset,
    _rec: &gradcode::obs::Recorder,
) -> anyhow::Result<RunLog> {
    anyhow::bail!("--pjrt requires rebuilding with `--features pjrt` (xla dependency)")
}

fn cmd_train(a: gradcode::cli::Args) -> anyhow::Result<()> {
    // Resize the global pool before any hot path touches it; 0 keeps the
    // GRADCODE_THREADS / core-count default.
    let threads = a.get_usize("threads");
    if threads > 0 {
        gradcode::pool::set_global_threads(threads);
    }
    let n = a.get_usize("n");
    let s = a.get_usize("s");
    let m = a.get_usize("m");
    let profile =
        SpeedProfile::parse(a.get_str("profile")).map_err(|e| anyhow::anyhow!(e))?;
    // Fail here (not mid-run) when e.g. a custom profile doesn't match n.
    profile.try_speeds(n).map_err(|e| anyhow::anyhow!(e))?;
    let scheme = match a.get_str("scheme") {
        "poly" => SchemeSpec::Poly { s, m },
        "random" => SchemeSpec::Random { s, m, seed: a.get_u64("seed") },
        "naive" => SchemeSpec::Uncoded,
        "approx" => SchemeSpec::Approx {
            d: a.get_usize("approx-d"),
            quorum: a.get_f64("quorum"),
        },
        "hetero" => SchemeSpec::Hetero { s, m, profile: profile.clone() },
        other => anyhow::bail!("unknown scheme {other:?}"),
    };
    let gen = SyntheticCategorical::new(
        CategoricalConfig { columns: 10, cardinality: (16, 48), ..Default::default() },
        a.get_u64("seed"),
    );
    let ds = gen.generate(a.get_usize("rows"), a.get_u64("seed") + 1);
    let (train_ds, test_ds) = train_test_split(&ds, 0.2, a.get_u64("seed") + 2);
    let cfg = TrainConfig {
        n,
        scheme: scheme.clone(),
        iters: a.get_usize("iters"),
        opt: OptChoice::Nag { lr: a.get_f64("lr") as f32, momentum: a.get_f64("momentum") as f32 },
        eval_every: a.get_usize("eval-every"),
        delays: if a.get_bool("no-delays") { None } else { Some(DelayParams::table_vi1()) },
        mode: ExecutionMode::Virtual,
        seed: a.get_u64("seed"),
        minibatch: None,
        quorum: None,
        // --profile describes the fleet; the hetero scheme also adapts
        // its placement to it.
        fleet: Some(profile),
        chaos: parse_chaos_flag(&a, n)?,
    };
    // An empty --trace keeps the recorder disabled (zero-cost); a path
    // arms it across the trainer/cluster stack. A live metrics endpoint
    // needs the recorder too (it renders the recorder's counters and
    // phase stats), so --metrics-addr arms it even without --trace.
    let trace_path = a.get_str("trace").to_string();
    let metrics_addr = a.get_str("metrics-addr").to_string();
    let rec = if trace_path.is_empty() && metrics_addr.is_empty() {
        gradcode::obs::Recorder::disabled()
    } else {
        gradcode::obs::Recorder::enabled()
    };
    let registry = gradcode::obs::MetricsRegistry::new(&rec);
    let server = if metrics_addr.is_empty() {
        None
    } else {
        // The conventional build-info constant, set before the endpoint
        // opens: a scrape is never empty, even one that lands before the
        // first iteration records anything.
        registry.set_gauge("build_info", &[("version", env!("CARGO_PKG_VERSION"))], 1.0);
        let srv = registry.serve(&metrics_addr)?;
        println!("metrics: serving Prometheus text on http://{}/metrics", srv.addr());
        Some(srv)
    };
    let log = if a.get_bool("pjrt") {
        // The AOT artifacts are fixed-shape per (n, d, m) with uniform
        // equal shards; the hetero scheme's per-worker loads and
        // weighted subsets don't fit that contract.
        anyhow::ensure!(
            !matches!(scheme, SchemeSpec::Hetero { .. }),
            "--pjrt does not support --scheme hetero (per-worker loads \
             don't match the fixed-shape artifacts); use the rust backend"
        );
        run_pjrt_train(cfg, scheme, &train_ds, &test_ds, &rec)?
    } else {
        let mut tr = gradcode::coordinator::Trainer::new(cfg, &train_ds, Some(&test_ds))?;
        tr.attach_recorder(&rec);
        tr.run()?
    };
    println!(
        "scheme={} iters={} sim_time={:.2}s mean_iter={:.3}s floats={} wire_bytes={} final_loss={:.4} final_auc={:.4}",
        log.scheme,
        log.records.len(),
        log.total_sim_time(),
        log.mean_iteration_sim_time(),
        log.total_floats_transmitted(),
        log.total_wire_bytes(),
        log.final_loss().unwrap_or(f64::NAN),
        log.final_auc().unwrap_or(f64::NAN),
    );
    if let Some((p50, p95, p99)) = log.sim_time_quantiles() {
        println!(
            "iteration sim-time quantiles: p50 {p50:.4}s  p95 {p95:.4}s  p99 {p99:.4}s"
        );
    }
    if let Some(res) = log.mean_decode_residual() {
        println!("mean decode residual = {res:.5} (approximate recovery)");
    }
    if let Some(rate) = log.decoder_cache_hit_rate() {
        println!(
            "decoder cache: {:.1}% hits ({} hits / {} misses)",
            rate * 100.0,
            log.decoder_cache_hits,
            log.decoder_cache_misses
        );
    }
    if !log.faults.is_empty() {
        println!("chaos: {}", log.faults.summary());
    }
    if !trace_path.is_empty() {
        if let Some(tel) = &log.telemetry {
            print!("{}", tel.render());
        }
        std::fs::write(&trace_path, rec.to_jsonl())?;
        println!(
            "trace: {} events -> {trace_path} (inspect with `gradcode trace-report {trace_path}`)",
            rec.events().len()
        );
    }
    for w in &log.health_warnings {
        println!("{w}");
    }
    if a.get_bool("csv") {
        print!("{}", log.to_csv());
    }
    if let Some(srv) = server {
        // Let a scraper (e.g. the CI smoke) catch a short run: serve
        // until the first scrape lands or the linger budget runs out.
        let linger_ms = a.get_usize("metrics-linger") as u64 * 1000;
        let mut waited = 0u64;
        while srv.hits() == 0 && waited < linger_ms {
            std::thread::sleep(std::time::Duration::from_millis(50));
            waited += 50;
        }
        println!("metrics: served {} scrape(s) on {}", srv.hits(), srv.addr());
        srv.shutdown();
    }
    Ok(())
}

/// Headline metrics the bench-regression gate tracks, one per bench
/// artifact: `(file, dotted path, higher_is_better, noise_floor)`.
///
/// Every headline is a ratio (speedup or overhead fraction), so the
/// comparison is largely machine-independent even though the underlying
/// benches measure wall clock. `noise_floor` guards lower-is-better
/// metrics whose baseline can sit near zero: the regression threshold is
/// computed from `max(baseline, floor)`.
const GATE_HEADLINES: &[(&str, &str, bool, f64)] = &[
    ("BENCH_hotpath.json", "train_speedup", true, 0.0),
    ("BENCH_obs.json", "overhead_frac", false, 0.05),
    ("BENCH_obs.json", "metrics_overhead_frac", false, 0.05),
    ("BENCH_hetero.json", "bimodal_margin.realized_speedup", true, 0.0),
];

fn cmd_lint(a: gradcode::cli::Args) -> anyhow::Result<()> {
    use anyhow::Context as _;
    use gradcode::lint;
    let root = std::path::PathBuf::from(a.get_str("root"));
    let baseline_path = root.join(a.get_str("baseline"));
    let report = lint::lint_tree(&root)
        .with_context(|| format!("linting {}", root.display()))?;

    if a.get_bool("update-baseline") {
        std::fs::write(&baseline_path, lint::render_baseline(&report))
            .with_context(|| format!("writing {}", baseline_path.display()))?;
        println!(
            "lint: wrote {} ({} grandfathered finding(s))",
            baseline_path.display(),
            report.live.len()
        );
        return Ok(());
    }

    let baseline = if baseline_path.is_file() {
        let text = std::fs::read_to_string(&baseline_path)
            .with_context(|| format!("reading {}", baseline_path.display()))?;
        lint::Baseline::parse(&text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", baseline_path.display()))?
    } else {
        lint::Baseline::default()
    };
    let (fresh, grandfathered) = baseline.split(report.live);

    if a.get_bool("json") {
        println!(
            "{}",
            lint::report_json(report.files_scanned, &fresh, &grandfathered, &report.suppressed)
        );
    } else {
        for f in &fresh {
            println!("{f}");
        }
        println!(
            "lint: {} file(s), {} finding(s) ({} baselined), {} suppressed",
            report.files_scanned,
            fresh.len() + grandfathered.len(),
            grandfathered.len(),
            report.suppressed.len()
        );
    }
    if a.get_bool("deny") && !fresh.is_empty() {
        anyhow::bail!(
            "lint: {} finding(s) not covered by {} — fix them, or justify with `// lint: allow(<rule>) <reason>`",
            fresh.len(),
            baseline_path.display()
        );
    }
    Ok(())
}

fn cmd_ci_gate(a: gradcode::cli::Args) -> anyhow::Result<()> {
    use gradcode::bench::{parse_json, Table};
    let current_dir = std::path::PathBuf::from(a.get_str("current"));
    let baseline_dir = std::path::PathBuf::from(a.get_str("baseline"));
    let tol = a.get_f64("tol");
    anyhow::ensure!(tol >= 0.0 && tol < 1.0, "--tol must be in [0, 1)");

    // Read one headline metric out of a BENCH json, with a reason string
    // on every failure path so SKIP rows are self-explanatory.
    let read_metric = |dir: &std::path::Path, file: &str, path: &str| -> Result<f64, String> {
        let full = dir.join(file);
        let text = std::fs::read_to_string(&full)
            .map_err(|_| format!("missing {}", full.display()))?;
        let doc = parse_json(&text).map_err(|e| format!("{}: {e}", full.display()))?;
        let v = doc
            .get_path(path)
            .ok_or_else(|| format!("{}: no field {path:?}", full.display()))?;
        v.as_f64().ok_or_else(|| format!("{}: {path:?} is not a number", full.display()))
    };

    let mut table = Table::new(
        &format!("bench regression gate, tol = {:.0}%", tol * 100.0),
        &["artifact", "metric", "baseline", "current", "delta", "status"],
    );
    let mut failures = Vec::new();
    let mut skips = Vec::new();
    for &(file, path, higher_better, floor) in GATE_HEADLINES {
        let base = read_metric(&baseline_dir, file, path);
        let cur = read_metric(&current_dir, file, path);
        let (row, status) = match (&base, &cur) {
            (Ok(b), Ok(c)) => {
                let delta = c / b - 1.0;
                // Higher-is-better fails when current drops more than tol
                // below baseline; lower-is-better when it rises more than
                // tol above the noise-floored baseline.
                let fail = if higher_better {
                    *c < b * (1.0 - tol)
                } else {
                    *c > b.max(floor) * (1.0 + tol)
                };
                (
                    [format!("{b:.4}"), format!("{c:.4}"), format!("{delta:+.1}%", delta = delta * 100.0)],
                    if fail { "FAIL" } else { "PASS" },
                )
            }
            (Err(e), _) | (_, Err(e)) => {
                skips.push(format!("{file} {path}: {e}"));
                (["—".into(), "—".into(), "—".into()], "SKIP")
            }
        };
        if status == "FAIL" {
            failures.push(format!(
                "{file}: {path} regressed beyond {:.0}% (baseline {}, current {})",
                tol * 100.0,
                row[0],
                row[1]
            ));
        }
        table.row(&[
            file.to_string(),
            path.to_string(),
            row[0].clone(),
            row[1].clone(),
            row[2].clone(),
            status.to_string(),
        ]);
    }
    table.print();
    if !skips.is_empty() {
        println!("skipped comparisons (not failures):");
        for s in &skips {
            println!("  - {s}");
        }
        println!(
            "  run the bench smokes (./ci.sh without --quick) and promote fresh \
             baselines with `./ci.sh --update-baselines`"
        );
    }
    if !failures.is_empty() {
        anyhow::bail!(
            "ci-gate: {} headline metric(s) regressed:\n  {}",
            failures.len(),
            failures.join("\n  ")
        );
    }
    println!("ci-gate: OK ({} compared, {} skipped)", GATE_HEADLINES.len() - skips.len(), skips.len());
    Ok(())
}

fn cmd_trace_report(a: gradcode::cli::Args) -> anyhow::Result<()> {
    use gradcode::obs::Recorder;
    let files = a.positional();
    anyhow::ensure!(
        !files.is_empty(),
        "usage: gradcode trace-report <trace.jsonl>… [--chrome out.json] [--csv]"
    );
    // Multiple files (e.g. a master trace plus per-worker traces from
    // `worker --trace`) merge into one stream: the JSONL format is
    // line-oriented and replay is order-insensitive per aggregate.
    let mut text = String::new();
    for f in files {
        let chunk = std::fs::read_to_string(f)
            .map_err(|e| anyhow::anyhow!("reading {f}: {e}"))?;
        text.push_str(&chunk);
        if !text.ends_with('\n') {
            text.push('\n');
        }
    }
    let rec = Recorder::from_jsonl(&text).map_err(|e| anyhow::anyhow!(e))?;
    let summary = rec.summary();
    print!("{}", summary.render());
    if a.get_bool("csv") {
        println!("phase,count,total,mean,p50,p90,p99,max");
        for p in &summary.phases {
            println!(
                "{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6}",
                p.phase, p.count, p.total, p.mean, p.p50, p.p90, p.p99, p.max
            );
        }
    }
    let chrome = a.get_str("chrome");
    if !chrome.is_empty() {
        std::fs::write(chrome, rec.to_chrome())?;
        println!(
            "chrome trace -> {chrome} (load in Perfetto or chrome://tracing)"
        );
    }
    if a.get_bool("prom") {
        // Same renderer the live --metrics-addr endpoint uses, fed by
        // the replayed recorder — so offline traces and live scrapes
        // produce the same exposition format.
        print!("{}", gradcode::obs::MetricsRegistry::new(&rec).render());
    }
    Ok(())
}

fn cmd_flight_dump(a: gradcode::cli::Args) -> anyhow::Result<()> {
    use anyhow::Context as _;
    let files = a.positional();
    let path = match files.first() {
        Some(f) => std::path::PathBuf::from(f),
        None => gradcode::obs::flight::dump_path(),
    };
    let text = std::fs::read_to_string(&path).with_context(|| {
        format!(
            "reading {} (no dump? aborted runs write one; override with \
             GRADCODE_FLIGHT_DUMP or pass a path)",
            path.display()
        )
    })?;
    let events =
        gradcode::obs::flight::parse_dump(&text).map_err(|e| anyhow::anyhow!(e))?;
    print!("{}", gradcode::obs::flight::render_events(&events));
    println!("{} event(s) from {}", events.len(), path.display());
    Ok(())
}

/// `--chaos <spec>` → a [`ChaosConfig`] for an `n`-worker run (empty
/// spec = chaos off, which also forbids degraded iterations).
fn parse_chaos_flag(a: &gradcode::cli::Args, n: usize) -> anyhow::Result<Option<ChaosConfig>> {
    match a.get_str("chaos") {
        "" => Ok(None),
        spec => {
            let spec = ChaosSpec::parse(spec).map_err(|e| anyhow::anyhow!(e))?;
            Ok(Some(ChaosConfig::from_spec(n, a.get_usize("iters") as u64, &spec)))
        }
    }
}

fn cmd_chaos_report(a: gradcode::cli::Args) -> anyhow::Result<()> {
    use gradcode::simulator::degraded_fraction;
    let n = a.get_usize("n");
    let s = a.get_usize("s");
    let iters = a.get_usize("iters");
    let spec = ChaosSpec::parse(a.get_str("chaos")).map_err(|e| anyhow::anyhow!(e))?;
    let gen = SyntheticCategorical::new(
        CategoricalConfig { columns: 10, cardinality: (16, 48), ..Default::default() },
        a.get_u64("seed"),
    );
    let ds = gen.generate(a.get_usize("rows"), a.get_u64("seed") + 1);
    let cfg = TrainConfig {
        n,
        scheme: SchemeSpec::Poly { s, m: a.get_usize("m") },
        iters,
        opt: OptChoice::Nag { lr: a.get_f64("lr") as f32, momentum: 0.9 },
        eval_every: iters.max(1),
        delays: Some(DelayParams::table_vi1()),
        mode: ExecutionMode::Virtual,
        seed: a.get_u64("seed"),
        minibatch: None,
        quorum: None,
        fleet: None,
        chaos: Some(ChaosConfig::from_spec(n, iters as u64, &spec)),
    };
    let (log, _beta) = train(cfg, &ds, None)?;
    let (exact, degraded, stale) = log.rung_counts();
    println!("chaos spec: {spec:?}");
    println!(
        "run: n={n} s={s} iters={iters}  injected={} checksum_rejects={}",
        log.faults.injected(),
        log.faults.checksum_rejects()
    );
    println!("rungs: {}", log.faults.summary());
    println!(
        "degraded fraction: observed {:.3} ({} of {iters})",
        (degraded + stale) as f64 / iters as f64,
        degraded + stale
    );
    // The binomial tail models i.i.d. per-iteration silence; persistent
    // crash/reset windows violate that, so only predict when they're off.
    if spec.crash == 0.0 && spec.reset == 0.0 {
        println!(
            "degraded fraction: binomial prediction {:.3} (P[Bin({n}, {}) > {s}])",
            degraded_fraction(n, s, spec.drop),
            spec.drop
        );
    }
    println!("final loss: {:.5}", log.final_loss().unwrap_or(f64::NAN));
    println!("exact/degraded/stale = {exact}/{degraded}/{stale}");
    if a.get_bool("csv") {
        print!("{}", log.faults.to_csv());
    }
    Ok(())
}

fn cmd_plan_hetero(a: gradcode::cli::Args) -> anyhow::Result<()> {
    use gradcode::simulator::hetero::{
        expected_fleet_time, expected_hetero_time, plan_loads_opts, PlanOpts,
    };
    let n = a.get_usize("n");
    let s = a.get_usize("s");
    let m = a.get_usize("m");
    anyhow::ensure!(s + m <= n, "infeasible: need s + m <= n (got {s} + {m} > {n})");
    let params = DelayParams {
        lambda1: a.get_f64("lambda1"),
        t1: a.get_f64("t1"),
        lambda2: a.get_f64("lambda2"),
        t2: a.get_f64("t2"),
    };
    let profile =
        SpeedProfile::parse(a.get_str("profile")).map_err(|e| anyhow::anyhow!(e))?;
    let speeds = profile.try_speeds(n).map_err(|e| anyhow::anyhow!(e))?;
    let opts = PlanOpts { max_groups: a.get_usize("max-groups"), ..PlanOpts::default() };
    let plan = plan_loads_opts(&params, &speeds, s, m, opts);

    println!("fleet: n = {n}, profile = {}, params = {params:?}", profile.label());
    println!(
        "speeds: [{}]",
        speeds.iter().map(|x| format!("{x:.2}")).collect::<Vec<_>>().join(", ")
    );
    let mut table = gradcode::bench::Table::new(
        &format!("optimized plan, s = {s}, m = {m}"),
        &["group", "workers", "d", "need", "subset weight"],
    );
    for (gi, g) in plan.groups.iter().enumerate() {
        table.row(&[
            gi.to_string(),
            format!("{:?}", g.workers),
            g.d.to_string(),
            (g.workers.len() - (g.d - m)).to_string(),
            format!("{:.3}", g.weight),
        ]);
    }
    table.print();
    println!("load vector d_w: {:?}", plan.loads);
    println!(
        "Σ d_w = {} (Theorem-1 floor n(s+m) = {})",
        plan.loads.iter().sum::<usize>(),
        n * (s + m)
    );
    let heuristic = HeteroCode::from_speeds(n, s, m, &speeds)?;
    let heuristic_time = expected_hetero_time(&params, &heuristic);
    let naive = expected_fleet_time(&params, &speeds, 1, 0, 1);
    println!();
    println!("E[T] optimized plan        = {:.4} s", plan.expected_time);
    println!("E[T] from_speeds heuristic = {heuristic_time:.4} s (what `--scheme hetero` deploys)");
    println!("E[T] uniform poly (d=s+m)  = {:.4} s", plan.uniform_time);
    println!("E[T] naive uncoded         = {naive:.4} s");
    println!(
        "predicted speedup over uniform placement: {:.2}x{}",
        plan.speedup,
        if plan.speedup <= 1.0 {
            "  (uniform fleet: stick with the homogeneous scheme)"
        } else {
            ""
        }
    );
    Ok(())
}

fn cmd_quorum(a: gradcode::cli::Args) -> anyhow::Result<()> {
    use gradcode::simulator::approx::quorum_tradeoff;
    let n = a.get_usize("n");
    let d = a.get_usize("d");
    let params = DelayParams {
        lambda1: a.get_f64("lambda1"),
        t1: a.get_f64("t1"),
        lambda2: a.get_f64("lambda2"),
        t2: a.get_f64("t2"),
    };
    let code = ApproxCode::new(n, d, n)?;
    let curve = quorum_tradeoff(&params, &code, a.get_usize("samples"), a.get_u64("seed"));
    let mut table = gradcode::bench::Table::new(
        &format!("partial recovery tradeoff, n = {n}, d = {d}, {params:?}"),
        &["quorum", "fraction", "E[T] (s)", "E[residual]"],
    );
    for pt in &curve {
        table.row(&[
            pt.quorum.to_string(),
            format!("{:.2}", pt.fraction),
            format!("{:.4}", pt.expected_time),
            format!("{:.4}", pt.expected_residual),
        ]);
    }
    table.print();
    println!(
        "exact recovery is the quorum = {n} row; every row above trades residual for time"
    );
    Ok(())
}

fn cmd_plan(a: gradcode::cli::Args) -> anyhow::Result<()> {
    let params = DelayParams {
        lambda1: a.get_f64("lambda1"),
        t1: a.get_f64("t1"),
        lambda2: a.get_f64("lambda2"),
        t2: a.get_f64("t2"),
    };
    let n = a.get_usize("n");
    let best = optimal_triple(&params, n);
    let naive = gradcode::simulator::optimize::naive_choice(&params, n);
    let m1 = gradcode::simulator::optimize::optimal_triple_m1(&params, n);
    println!("n = {n}, params = {params:?}");
    println!(
        "optimal: (d={}, s={}, m={})  E[T] = {:.4}",
        best.d, best.s, best.m, best.expected_runtime
    );
    println!(
        "best m=1 ([11]-[13]): (d={}, s={})  E[T] = {:.4}  (+{:.0}%)",
        m1.d,
        m1.s,
        m1.expected_runtime,
        100.0 * (m1.expected_runtime / best.expected_runtime - 1.0)
    );
    println!(
        "naive: E[T] = {:.4}  (+{:.0}%)",
        naive.expected_runtime,
        100.0 * (naive.expected_runtime / best.expected_runtime - 1.0)
    );
    Ok(())
}

fn cmd_grid(a: gradcode::cli::Args) -> anyhow::Result<()> {
    use gradcode::simulator::order_stats::expected_total_runtime;
    let n = a.get_usize("n");
    let params = DelayParams {
        lambda1: a.get_f64("lambda1"),
        t1: a.get_f64("t1"),
        lambda2: a.get_f64("lambda2"),
        t2: a.get_f64("t2"),
    };
    let header: Vec<String> = std::iter::once("m \\ d".to_string())
        .chain((1..=n).map(|d| d.to_string()))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = gradcode::bench::Table::new(
        &format!("E[T_tot], s = d - m, n = {n}, {params:?}"),
        &header_refs,
    );
    for m in 1..=n {
        let mut row = vec![m.to_string()];
        for d in 1..=n {
            row.push(if m > d {
                String::new()
            } else {
                format!("{:.4}", expected_total_runtime(&params, n, d, d - m, m))
            });
        }
        table.row(&row);
    }
    table.print();
    let best = optimal_triple(&params, n);
    println!("optimum: (d={}, s={}, m={}) -> {:.4}", best.d, best.s, best.m, best.expected_runtime);
    Ok(())
}

fn cmd_stability(a: gradcode::cli::Args) -> anyhow::Result<()> {
    let n = a.get_usize("n");
    let s = a.get_usize("s");
    let m = a.get_usize("m");
    let cfg = SchemeConfig::tight(n, s, m)?;
    let (report, err) = match a.get_str("scheme") {
        "poly" => {
            let c = PolynomialCode::new(cfg)?;
            (
                max_condition_number(&c, a.get_usize("budget"), 1),
                reconstruction_error(&c, a.get_usize("dim"), a.get_usize("trials"), 2),
            )
        }
        "random" => {
            let c = RandomCode::new(cfg, 1)?;
            (
                max_condition_number(&c, a.get_usize("budget"), 1),
                reconstruction_error(&c, a.get_usize("dim"), a.get_usize("trials"), 2),
            )
        }
        other => anyhow::bail!("unknown scheme {other:?}"),
    };
    println!(
        "scheme={} n={n} d={} s={s} m={m}",
        a.get_str("scheme"),
        cfg.d
    );
    println!(
        "worst cond = {:.3e} over {} patterns (exhaustive: {}), at stragglers {:?}",
        report.worst_cond, report.patterns, report.exhaustive, report.worst_stragglers
    );
    println!("worst ℓ∞ reconstruction rel-error = {err:.3e}");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    match app.dispatch(&argv) {
        Ok((name, args)) => match name.as_str() {
            "info" => cmd_info(),
            "train" => cmd_train(args),
            "trace-report" => cmd_trace_report(args),
            "flight-dump" => cmd_flight_dump(args),
            "ci-gate" => cmd_ci_gate(args),
            "lint" => cmd_lint(args),
            "chaos-report" => cmd_chaos_report(args),
            "plan" => cmd_plan(args),
            "plan-hetero" => cmd_plan_hetero(args),
            "quorum" => cmd_quorum(args),
            "stability" => cmd_stability(args),
            "grid" => cmd_grid(args),
            "leader" => cmd_leader(args),
            "worker" => cmd_worker(args),
            _ => unreachable!(),
        },
        Err(gradcode::cli::CliError::HelpRequested) => {
            println!("{}", app.help());
            Ok(())
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", app.help());
            std::process::exit(2);
        }
    }
}
