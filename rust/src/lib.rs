//! # gradcode
//!
//! A production-grade reproduction of *Communication-Computation Efficient
//! Gradient Coding* (Ye & Abbe, ICML 2018): distributed synchronous
//! gradient descent where workers both replicate data subsets (to tolerate
//! `s` stragglers) and code across gradient-vector components (to cut
//! per-worker communication by a factor `m`), achieving the optimal
//! tradeoff `d >= s + m` (with `k = n` data subsets). On top of the exact
//! schemes, the crate implements the *approximate* operating regime
//! (partial recovery): the master proceeds at a configurable responder
//! quorum and a least-squares partial decoder returns the
//! minimum-ℓ2-error gradient estimate with a computed error bound.
//!
//! The crate is the L3 (rust) layer of a three-layer stack:
//! - L1: Pallas kernels (`python/compile/kernels/`) for the partial
//!   gradient and the coded-encode hot spots,
//! - L2: a JAX model (`python/compile/model.py`) AOT-lowered to HLO text,
//! - L3: this crate — coordinator, coding math, runtime model, and the
//!   PJRT runtime that executes the AOT artifacts on the request path
//!   with no python anywhere.
//!
//! Module map (see `rust/DESIGN.md` for the per-experiment index):
//! - [`coding`] — the paper's constructions: §III polynomial scheme,
//!   §IV random-matrix scheme, encode/decode, stability certification,
//!   plus the approximate partial-recovery scheme and the heterogeneous
//!   group-based scheme (speed-proportional placement).
//! - [`simulator`] — §VI probabilistic runtime model and optimal-triple
//!   search; the virtual cluster used by the figure benches; the quorum
//!   extension predicting time and residual under partial recovery; the
//!   heterogeneous-fleet extension (speed profiles, group order
//!   statistics, load planner).
//! - [`coordinator`] — master/worker threads, transport, training loop,
//!   the wait-for-quorum policy, and per-worker fleet profiles with the
//!   group-quorum gather rule.
//! - [`chaos`] — deterministic fault injection (crash/drop/corrupt/
//!   duplicate/delay/reset plans), the gather deadline policy, the
//!   degradation ladder the trainer walks when responders run short, and
//!   the fault log surfaced through metrics and the CLI.
//! - [`obs`] — zero-dependency telemetry: RAII phase spans, counters,
//!   log-bucketed latency histograms, JSONL + Chrome-trace export,
//!   per-worker straggler attribution with §VI-model deviation, a
//!   Prometheus-text metrics registry with a std-`TcpListener` scrape
//!   endpoint (`--metrics-addr`), an always-on flight-recorder ring
//!   dumped on abort, and a declared-vs-realized straggler health
//!   watchdog (`health_status` gauge).
//! - [`lint`] — the in-repo static-analysis pass (`gradcode lint`):
//!   a std-only lexer + rule registry machine-enforcing the crate's
//!   determinism, panic-hygiene, lock-discipline, and wire-versioning
//!   invariants, with a committed (empty) `lint.baseline` and inline
//!   reasoned suppressions.
//! - [`pool`] — std-only fork/join thread pool behind every hot path
//!   (virtual-worker compute, encode/decode, row-chunked gradients,
//!   Monte-Carlo sweeps); deterministic: fixed chunk grids + binary-tree
//!   combine order make results bitwise identical for any thread count
//!   (`GRADCODE_THREADS` / `--threads`).
//! - `runtime` — PJRT execution of AOT artifacts (`xla` crate); compiled
//!   only with the `pjrt` cargo feature, since the `xla` dependency is
//!   not available in the offline build environment.
//! - [`data`], [`optim`], [`model`] — dataset/AUC, optimizers, pure-rust
//!   logistic reference backend.
//! - [`linalg`], [`rngs`], [`cli`], [`testkit`], `bench`, [`metrics`]
//!   — substrates (no external crates available offline).

#![deny(rustdoc::broken_intra_doc_links)]

pub mod bench;
pub mod chaos;
pub mod checkpoint;
pub mod cli;
pub mod coding;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod lint;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod optim;
pub mod pool;
pub mod rngs;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod simulator;
pub mod testkit;
