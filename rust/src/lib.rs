//! # gradcode
//!
//! A production-grade reproduction of *Communication-Computation Efficient
//! Gradient Coding* (Ye & Abbe, ICML 2018): distributed synchronous
//! gradient descent where workers both replicate data subsets (to tolerate
//! `s` stragglers) and code across gradient-vector components (to cut
//! per-worker communication by a factor `m`), achieving the optimal
//! tradeoff `d >= s + m` (with `k = n` data subsets).
//!
//! The crate is the L3 (rust) layer of a three-layer stack:
//! - L1: Pallas kernels (`python/compile/kernels/`) for the partial
//!   gradient and the coded-encode hot spots,
//! - L2: a JAX model (`python/compile/model.py`) AOT-lowered to HLO text,
//! - L3: this crate — coordinator, coding math, runtime model, and the
//!   PJRT runtime that executes the AOT artifacts on the request path
//!   with no python anywhere.
//!
//! Module map (see DESIGN.md for the per-experiment index):
//! - [`coding`] — the paper's constructions: §III polynomial scheme,
//!   §IV random-matrix scheme, encode/decode, stability certification.
//! - [`simulator`] — §VI probabilistic runtime model and optimal-triple
//!   search; also the virtual cluster used by the figure benches.
//! - [`coordinator`] — master/worker threads, transport, training loop.
//! - [`runtime`] — PJRT execution of AOT artifacts (`xla` crate).
//! - [`data`], [`optim`], [`model`] — dataset/AUC, optimizers, pure-rust
//!   logistic reference backend.
//! - [`linalg`], [`rngs`], [`cli`], [`testkit`], `bench`, [`metrics`]
//!   — substrates (no external crates available offline).

pub mod bench;
pub mod checkpoint;
pub mod cli;
pub mod coding;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod rngs;
pub mod runtime;
pub mod simulator;
pub mod testkit;
