//! Trace-event model and its two serializations.
//!
//! Events are serialized two ways:
//!
//! - **JSONL** (one [`bench::JsonObject`](crate::bench::JsonObject) per
//!   line) — the interchange format written by `train --trace` and read
//!   back by the `trace-report` subcommand. The parser here is
//!   deliberately minimal: it only handles recorder-authored lines
//!   (flat objects, no nested containers, no commas inside strings).
//! - **Chrome trace-event JSON** — an array of `B`/`E` duration pairs
//!   and `i` instants, loadable in `about://tracing` or Perfetto. Wall-
//!   clock master events render under pid 0 and virtual-clock worker
//!   events under pid 1, one named thread (track) per worker.

use crate::bench::{json_string, JsonObject};

/// Which timeline an event's timestamps live on. The master's own
/// phases are measured in wall time; per-worker response spans in the
/// simulator's virtual time. The Chrome exporter keeps the two on
/// separate process tracks so the scales are never mixed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Clock {
    Wall,
    Virtual,
}

impl Clock {
    pub fn label(&self) -> &'static str {
        match self {
            Clock::Wall => "wall",
            Clock::Virtual => "virtual",
        }
    }

    pub fn parse(s: &str) -> Option<Clock> {
        match s {
            "wall" => Some(Clock::Wall),
            "virtual" => Some(Clock::Virtual),
            _ => None,
        }
    }
}

/// One recorded event. Timestamps and durations are in seconds from
/// the recorder's epoch (its construction time for wall events, the
/// start of the run for virtual ones).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A closed duration span (phase or per-worker response).
    /// `used` is only set on worker-response spans: `Some(true)` when
    /// the response landed inside the deciding quorum prefix.
    Span {
        phase: String,
        worker: Option<usize>,
        iter: Option<u64>,
        ts: f64,
        dur: f64,
        clock: Clock,
        used: Option<bool>,
    },
    /// A point event (fault injections, wait-rule outcomes).
    Instant {
        name: String,
        worker: Option<usize>,
        iter: Option<u64>,
        ts: f64,
        clock: Clock,
    },
    /// A counter's final value (emitted on export so counters survive
    /// the JSONL round trip).
    Counter { name: String, value: i64 },
}

fn opt_usize_raw(v: Option<usize>) -> String {
    v.map(|w| w.to_string()).unwrap_or_else(|| "null".into())
}

fn opt_u64_raw(v: Option<u64>) -> String {
    v.map(|i| i.to_string()).unwrap_or_else(|| "null".into())
}

fn opt_bool_raw(v: Option<bool>) -> String {
    match v {
        Some(true) => "true".into(),
        Some(false) => "false".into(),
        None => "null".into(),
    }
}

impl TraceEvent {
    /// One JSONL line (no trailing newline).
    pub fn to_jsonl(&self) -> String {
        match self {
            TraceEvent::Span { phase, worker, iter, ts, dur, clock, used } => JsonObject::new()
                .field_str("type", "span")
                .field_str("phase", phase)
                .field_num("ts", *ts)
                .field_num("dur", *dur)
                .field_str("clock", clock.label())
                .field_raw("worker", &opt_usize_raw(*worker))
                .field_raw("iter", &opt_u64_raw(*iter))
                .field_raw("used", &opt_bool_raw(*used))
                .build(),
            TraceEvent::Instant { name, worker, iter, ts, clock } => JsonObject::new()
                .field_str("type", "instant")
                .field_str("name", name)
                .field_num("ts", *ts)
                .field_str("clock", clock.label())
                .field_raw("worker", &opt_usize_raw(*worker))
                .field_raw("iter", &opt_u64_raw(*iter))
                .build(),
            TraceEvent::Counter { name, value } => JsonObject::new()
                .field_str("type", "counter")
                .field_str("name", name)
                .field_int("value", *value)
                .build(),
        }
    }

    /// Parse one recorder-authored JSONL line. Blank lines yield
    /// `Ok(None)`; anything else unparseable is an error.
    pub fn from_jsonl(line: &str) -> Result<Option<TraceEvent>, String> {
        let line = line.trim();
        if line.is_empty() {
            return Ok(None);
        }
        let kind = field_str(line, "type").ok_or_else(|| format!("no \"type\" in: {line}"))?;
        let ev = match kind.as_str() {
            "span" => TraceEvent::Span {
                phase: field_str(line, "phase").ok_or("span without phase")?,
                worker: field_opt_usize(line, "worker"),
                iter: field_opt_u64(line, "iter"),
                ts: field_f64(line, "ts").ok_or("span without ts")?,
                dur: field_f64(line, "dur").ok_or("span without dur")?,
                clock: field_clock(line)?,
                used: field_opt_bool(line, "used"),
            },
            "instant" => TraceEvent::Instant {
                name: field_str(line, "name").ok_or("instant without name")?,
                worker: field_opt_usize(line, "worker"),
                iter: field_opt_u64(line, "iter"),
                ts: field_f64(line, "ts").ok_or("instant without ts")?,
                clock: field_clock(line)?,
            },
            "counter" => TraceEvent::Counter {
                name: field_str(line, "name").ok_or("counter without name")?,
                value: field_f64(line, "value").ok_or("counter without value")? as i64,
            },
            other => return Err(format!("unknown event type {other:?}")),
        };
        Ok(Some(ev))
    }
}

/// Raw text of a top-level field's value (recorder-authored lines only:
/// flat objects, strings free of commas/braces).
fn field_raw<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = line[start..].trim_start();
    let mut end = rest.len();
    let mut in_str = false;
    for (i, c) in rest.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' | '}' if !in_str => {
                end = i;
                break;
            }
            _ => {}
        }
    }
    Some(rest[..end].trim())
}

fn field_str(line: &str, key: &str) -> Option<String> {
    let raw = field_raw(line, key)?;
    let inner = raw.strip_prefix('"')?.strip_suffix('"')?;
    Some(inner.to_string())
}

fn field_f64(line: &str, key: &str) -> Option<f64> {
    field_raw(line, key)?.parse().ok()
}

fn field_opt_usize(line: &str, key: &str) -> Option<usize> {
    field_raw(line, key).and_then(|r| r.parse().ok())
}

fn field_opt_u64(line: &str, key: &str) -> Option<u64> {
    field_raw(line, key).and_then(|r| r.parse().ok())
}

fn field_opt_bool(line: &str, key: &str) -> Option<bool> {
    match field_raw(line, key) {
        Some("true") => Some(true),
        Some("false") => Some(false),
        _ => None,
    }
}

fn field_clock(line: &str) -> Result<Clock, String> {
    let s = field_str(line, "clock").ok_or("event without clock")?;
    Clock::parse(&s).ok_or_else(|| format!("unknown clock {s:?}"))
}

/// Chrome trace pid for a clock: wall-clock master events on process 0,
/// virtual-clock worker events on process 1.
fn pid_of(clock: Clock) -> u32 {
    match clock {
        Clock::Wall => 0,
        Clock::Virtual => 1,
    }
}

/// Chrome trace tid: the master timeline is thread 0; worker `w` gets
/// its own thread `w + 1` (one track per worker).
fn tid_of(worker: Option<usize>) -> u32 {
    worker.map(|w| w as u32 + 1).unwrap_or(0)
}

fn chrome_args(iter: Option<u64>, used: Option<bool>) -> String {
    let mut obj = JsonObject::new();
    if let Some(i) = iter {
        obj = obj.field_int("iter", i as i64);
    }
    if let Some(u) = used {
        obj = obj.field_raw("used", if u { "true" } else { "false" });
    }
    obj.build()
}

/// Render events as a Chrome trace-event JSON array (`about://tracing`
/// / Perfetto "JSON Array Format"). Spans become matched `B`/`E`
/// pairs; instants become scoped `i` events; every (pid, tid) in use
/// gets `process_name`/`thread_name` metadata so the timeline shows one
/// labeled track per worker.
pub fn chrome_trace(events: &[TraceEvent]) -> String {
    let mut out: Vec<String> = Vec::new();
    let mut tracks: Vec<(u32, u32)> = Vec::new();
    for ev in events {
        match ev {
            TraceEvent::Span { phase, worker, iter, ts, dur, clock, used } => {
                let (pid, tid) = (pid_of(*clock), tid_of(*worker));
                if !tracks.contains(&(pid, tid)) {
                    tracks.push((pid, tid));
                }
                let ts_us = ts * 1e6;
                let end_us = (ts + dur.max(0.0)) * 1e6;
                out.push(
                    JsonObject::new()
                        .field_str("name", phase)
                        .field_str("cat", "obs")
                        .field_str("ph", "B")
                        .field_num("ts", ts_us)
                        .field_int("pid", pid as i64)
                        .field_int("tid", tid as i64)
                        .field_raw("args", &chrome_args(*iter, *used))
                        .build(),
                );
                out.push(
                    JsonObject::new()
                        .field_str("name", phase)
                        .field_str("cat", "obs")
                        .field_str("ph", "E")
                        .field_num("ts", end_us)
                        .field_int("pid", pid as i64)
                        .field_int("tid", tid as i64)
                        .build(),
                );
            }
            TraceEvent::Instant { name, worker, iter, ts, clock } => {
                let (pid, tid) = (pid_of(*clock), tid_of(*worker));
                if !tracks.contains(&(pid, tid)) {
                    tracks.push((pid, tid));
                }
                out.push(
                    JsonObject::new()
                        .field_str("name", name)
                        .field_str("cat", "obs")
                        .field_str("ph", "i")
                        .field_str("s", "t")
                        .field_num("ts", ts * 1e6)
                        .field_int("pid", pid as i64)
                        .field_int("tid", tid as i64)
                        .field_raw("args", &chrome_args(*iter, None))
                        .build(),
                );
            }
            TraceEvent::Counter { .. } => {} // counters have no timeline position
        }
    }
    let mut meta: Vec<String> = Vec::new();
    for pid in [0u32, 1u32] {
        if tracks.iter().any(|&(p, _)| p == pid) {
            let pname = if pid == 0 { "master (wall clock)" } else { "workers (virtual clock)" };
            meta.push(
                JsonObject::new()
                    .field_str("name", "process_name")
                    .field_str("ph", "M")
                    .field_int("pid", pid as i64)
                    .field_int("tid", 0)
                    .field_raw("args", &format!("{{\"name\": {}}}", json_string(pname)))
                    .build(),
            );
        }
    }
    for &(pid, tid) in &tracks {
        let tname =
            if tid == 0 { "master".to_string() } else { format!("worker {}", tid - 1) };
        meta.push(
            JsonObject::new()
                .field_str("name", "thread_name")
                .field_str("ph", "M")
                .field_int("pid", pid as i64)
                .field_int("tid", tid as i64)
                .field_raw("args", &format!("{{\"name\": {}}}", json_string(&tname)))
                .build(),
        );
    }
    meta.extend(out);
    format!("[\n{}\n]\n", meta.join(",\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent::Span {
                phase: "decode".into(),
                worker: None,
                iter: Some(3),
                ts: 1.5,
                dur: 0.25,
                clock: Clock::Wall,
                used: None,
            },
            TraceEvent::Span {
                phase: "worker_response".into(),
                worker: Some(2),
                iter: Some(3),
                ts: 10.0,
                dur: 4.0,
                clock: Clock::Virtual,
                used: Some(false),
            },
            TraceEvent::Instant {
                name: "fault:crash".into(),
                worker: Some(1),
                iter: Some(4),
                ts: 2.0,
                clock: Clock::Wall,
            },
            TraceEvent::Counter { name: "wire.tx_frames".into(), value: 42 },
        ]
    }

    #[test]
    fn jsonl_round_trips_every_variant() {
        for ev in sample_events() {
            let line = ev.to_jsonl();
            let back = TraceEvent::from_jsonl(&line).unwrap().unwrap();
            assert_eq!(back, ev, "line was: {line}");
        }
        assert_eq!(TraceEvent::from_jsonl("  ").unwrap(), None);
        assert!(TraceEvent::from_jsonl("{\"type\": \"mystery\"}").is_err());
    }

    #[test]
    fn chrome_trace_is_an_array_with_matched_pairs_and_tracks() {
        let events = sample_events();
        let json = chrome_trace(&events);
        let trimmed = json.trim();
        assert!(trimmed.starts_with('[') && trimmed.ends_with(']'));
        let b = json.matches("\"ph\": \"B\"").count();
        let e = json.matches("\"ph\": \"E\"").count();
        assert_eq!(b, 2);
        assert_eq!(b, e, "every B needs a matching E");
        assert_eq!(json.matches("\"ph\": \"i\"").count(), 1);
        // one named track per worker, plus the master track
        assert!(json.contains("\"worker 2\""));
        assert!(json.contains("\"worker 1\""));
        assert!(json.contains("\"master\""));
        assert!(json.contains("\"thread_name\""));
        // counters carry no timeline position
        assert!(!json.contains("wire.tx_frames"));
    }
}
