//! Zero-dependency telemetry: spans, counters, latency histograms, and
//! straggler attribution for the trainer/cluster/wire stack.
//!
//! The paper's contribution is a running-time tradeoff, so the repro
//! needs to see *where* an iteration's time goes — not just one
//! `sim_time` scalar. This module provides the measurement substrate:
//!
//! - [`Recorder`] — a cheaply cloneable handle (shared interior behind
//!   one mutex) collecting [`TraceEvent`]s, monotonic counters, and
//!   per-phase [`Histogram`]s. A disabled recorder ([`Recorder::disabled`])
//!   holds no interior at all: every call is a branch on `None` and
//!   returns immediately, so untraced runs pay nothing.
//! - [`SpanGuard`] — RAII phase spans: [`Recorder::span`] opens one,
//!   dropping it (including during unwind) records the duration.
//! - [`trace`] — the event model plus JSONL and Chrome trace-event
//!   exporters (one timeline track per worker).
//! - [`straggler`] — per-worker response distributions, straggle
//!   counts, and realized-vs-§VI-model deviation.
//! - [`metrics`] — the *live* layer: a [`MetricsRegistry`] fed by the
//!   recorder, a Prometheus text renderer, and the `--metrics-addr`
//!   scrape endpoint ([`ScrapeServer`]).
//! - [`flight`] — the always-on flight recorder: a bounded ring of
//!   recent events, dumped automatically on abort
//!   ([`FlightDumpGuard`]).
//! - [`health`] — the straggler-regime watchdog comparing realized
//!   iteration times against the declared-profile §VI model
//!   ([`HealthWatchdog`]).
//!
//! The coordinator threads a recorder through every layer:
//! [`Trainer`](crate::coordinator::Trainer) emits per-iteration phase
//! spans, [`Cluster`](crate::coordinator::Cluster) records per-worker
//! gather latencies and wait-rule outcomes, `wire.rs` byte counters
//! land via [`WireCounters`](crate::coordinator::wire::WireCounters),
//! and chaos fault events are tagged into the same stream.
//!
//! ```
//! use gradcode::obs::{phase, Recorder};
//!
//! let rec = Recorder::enabled();
//! {
//!     let _g = rec.span(phase::DECODE).iter(0);
//!     // ... decode work ...
//! } // guard drop records the span
//! rec.add("decoder.cache_hits", 1);
//! let summary = rec.summary();
//! assert_eq!(summary.phases[0].phase, phase::DECODE);
//! assert_eq!(summary.counters[0], ("decoder.cache_hits".into(), 1));
//! ```

pub mod flight;
pub mod health;
pub mod hist;
pub mod metrics;
pub mod straggler;
pub mod trace;

pub use flight::{FlightDumpGuard, FlightEvent, FlightRecorder};
pub use health::{HealthConfig, HealthStatus, HealthWatchdog};
pub use hist::Histogram;
pub use metrics::{MetricsRegistry, ScrapeServer};
pub use straggler::{StragglerReport, WorkerObs, WorkerStat};
pub use trace::{chrome_trace, Clock, TraceEvent};

use crate::bench::Table;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

/// Canonical phase names. The five `MASTER_PHASES` partition the
/// master's wall time inside one `ITERATION` span; `WORKER_COMPUTE` and
/// `WORKER_RESPONSE` overlap `GATHER_WAIT` (they happen on the worker
/// clock) and are reported separately, never summed with the rest.
pub mod phase {
    pub const ITERATION: &str = "iteration";
    pub const BROADCAST: &str = "broadcast";
    pub const GATHER_WAIT: &str = "gather_wait";
    pub const DECODE: &str = "decode";
    pub const STEP: &str = "step";
    pub const EVAL: &str = "eval";
    pub const WORKER_COMPUTE: &str = "worker_compute";
    pub const WORKER_RESPONSE: &str = "worker_response";
    /// Mutually exclusive master-side phases; their totals should sum
    /// to (within bookkeeping slack of) the `ITERATION` total.
    pub const MASTER_PHASES: [&str; 5] = [BROADCAST, GATHER_WAIT, DECODE, STEP, EVAL];
    /// Display order for phase tables.
    pub const DISPLAY_ORDER: [&str; 7] =
        [ITERATION, BROADCAST, GATHER_WAIT, WORKER_COMPUTE, DECODE, STEP, EVAL];
}

/// Instant-event name recorded when a worker contributes nothing to an
/// iteration (crashed, silent, or rejected).
pub const MISSED_EVENT: &str = "worker_missed";

#[derive(Debug, Default)]
struct Inner {
    events: Vec<TraceEvent>,
    counters: BTreeMap<String, i64>,
    phase_hists: BTreeMap<String, Histogram>,
    workers: BTreeMap<usize, WorkerObs>,
}

/// Telemetry recorder handle. Clones share the same interior, so the
/// trainer, cluster, and CLI can all hold one. All methods take `&self`
/// and are thread-safe (a single interior mutex; events are recorded at
/// iteration granularity, so contention is negligible).
#[derive(Debug, Clone)]
pub struct Recorder {
    inner: Option<Arc<Mutex<Inner>>>,
    epoch: Instant,
}

impl Default for Recorder {
    /// The default recorder is disabled (zero-cost).
    fn default() -> Self {
        Recorder::disabled()
    }
}

impl Recorder {
    /// A recording instance.
    pub fn enabled() -> Recorder {
        Recorder { inner: Some(Arc::new(Mutex::new(Inner::default()))), epoch: Instant::now() }
    }

    /// A no-op instance: holds no storage, every call returns
    /// immediately after one `Option` branch.
    pub fn disabled() -> Recorder {
        Recorder { inner: None, epoch: Instant::now() }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Seconds since this recorder's epoch (wall clock).
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn lock(&self) -> Option<MutexGuard<'_, Inner>> {
        // Tolerate poisoning: telemetry must keep working while a
        // panic unwinds (the span-RAII-on-panic contract).
        self.inner.as_ref().map(|m| m.lock().unwrap_or_else(|e| e.into_inner()))
    }

    /// Open a wall-clock span; the returned guard records it on drop
    /// (including during panic unwind). Label with
    /// [`SpanGuard::worker`] / [`SpanGuard::iter`].
    pub fn span(&self, phase: &'static str) -> SpanGuard {
        SpanGuard {
            inner: self.inner.clone(),
            phase,
            worker: None,
            iter: None,
            epoch: self.epoch,
            start: Instant::now(),
        }
    }

    /// Record an already-measured span (used for virtual-clock worker
    /// timelines, where there is no live guard to drop).
    pub fn record_span(
        &self,
        phase: &str,
        worker: Option<usize>,
        iter: Option<u64>,
        ts: f64,
        dur: f64,
        clock: Clock,
    ) {
        if let Some(mut g) = self.lock() {
            g.phase_hists.entry(phase.to_string()).or_default().record(dur);
            g.events.push(TraceEvent::Span {
                phase: phase.to_string(),
                worker,
                iter,
                ts,
                dur,
                clock,
                used: None,
            });
        }
    }

    /// Record one worker response for an iteration: a span on the
    /// worker's own track plus the per-worker latency/straggle
    /// aggregates behind the [`StragglerReport`]. `used` marks a
    /// response inside the deciding quorum prefix.
    pub fn record_worker_response(
        &self,
        worker: usize,
        iter: u64,
        ts: f64,
        dur: f64,
        used: bool,
        clock: Clock,
    ) {
        if let Some(mut g) = self.lock() {
            let obs = g.workers.entry(worker).or_default();
            obs.latency.record(dur);
            if used {
                obs.used += 1;
            } else {
                obs.straggled += 1;
            }
            g.events.push(TraceEvent::Span {
                phase: phase::WORKER_RESPONSE.to_string(),
                worker: Some(worker),
                iter: Some(iter),
                ts,
                dur,
                clock,
                used: Some(used),
            });
        }
    }

    /// Record that a worker contributed nothing this iteration
    /// (crashed, silent, or checksum-rejected).
    pub fn worker_missed(&self, worker: usize, iter: u64) {
        if let Some(mut g) = self.lock() {
            g.workers.entry(worker).or_default().missed += 1;
            let ts = self.epoch.elapsed().as_secs_f64();
            g.events.push(TraceEvent::Instant {
                name: MISSED_EVENT.to_string(),
                worker: Some(worker),
                iter: Some(iter),
                ts,
                clock: Clock::Wall,
            });
        }
    }

    /// Record a wall-clock point event (fault injections, wait-rule
    /// outcomes).
    pub fn instant(&self, name: &str, worker: Option<usize>, iter: Option<u64>) {
        if let Some(mut g) = self.lock() {
            let ts = self.epoch.elapsed().as_secs_f64();
            g.events.push(TraceEvent::Instant {
                name: name.to_string(),
                worker,
                iter,
                ts,
                clock: Clock::Wall,
            });
        }
    }

    /// Add to a monotonic counter (creates it at zero).
    pub fn add(&self, name: &str, delta: i64) {
        if let Some(mut g) = self.lock() {
            *g.counters.entry(name.to_string()).or_insert(0) += delta;
        }
    }

    /// Set a gauge to an absolute value.
    pub fn set(&self, name: &str, value: i64) {
        if let Some(mut g) = self.lock() {
            g.counters.insert(name.to_string(), value);
        }
    }

    /// Record a sample into a named histogram without emitting an
    /// event (e.g. per-worker compute seconds).
    pub fn observe(&self, name: &str, value: f64) {
        if let Some(mut g) = self.lock() {
            g.phase_hists.entry(name.to_string()).or_default().record(value);
        }
    }

    /// Snapshot of all recorded events, in record order.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.lock().map(|g| g.events.clone()).unwrap_or_default()
    }

    /// Snapshot of all counters, sorted by name.
    pub fn counters(&self) -> Vec<(String, i64)> {
        self.lock()
            .map(|g| g.counters.iter().map(|(k, v)| (k.clone(), *v)).collect())
            .unwrap_or_default()
    }

    /// Per-phase latency statistics, canonical phases first.
    pub fn phase_stats(&self) -> Vec<PhaseStat> {
        let Some(g) = self.lock() else { return Vec::new() };
        let mut out: Vec<PhaseStat> = Vec::new();
        for name in phase::DISPLAY_ORDER {
            if let Some(h) = g.phase_hists.get(name) {
                out.push(PhaseStat::from_hist(name, h));
            }
        }
        for (name, h) in &g.phase_hists {
            if !phase::DISPLAY_ORDER.contains(&name.as_str()) {
                out.push(PhaseStat::from_hist(name, h));
            }
        }
        out
    }

    /// Build the per-worker straggler report (no model attached; use
    /// [`StragglerReport::set_model`] for the deviation line).
    pub fn straggler_report(&self) -> StragglerReport {
        let Some(g) = self.lock() else { return StragglerReport::default() };
        StragglerReport {
            workers: g.workers.iter().map(|(w, o)| WorkerStat::from_obs(*w, o)).collect(),
            ..StragglerReport::default()
        }
    }

    /// Full summary: phase stats, counters, and the straggler report.
    pub fn summary(&self) -> TelemetrySummary {
        TelemetrySummary {
            phases: self.phase_stats(),
            counters: self.counters(),
            stragglers: self.straggler_report(),
        }
    }

    /// Serialize everything as JSONL (events in record order, then one
    /// `counter` line per counter). This is the `--trace <path>` file
    /// format and the input of `trace-report`.
    pub fn to_jsonl(&self) -> String {
        let Some(g) = self.lock() else { return String::new() };
        let mut out = String::new();
        for ev in &g.events {
            out.push_str(&ev.to_jsonl());
            out.push('\n');
        }
        for (name, value) in &g.counters {
            out.push_str(
                &TraceEvent::Counter { name: name.clone(), value: *value }.to_jsonl(),
            );
            out.push('\n');
        }
        out
    }

    /// Rebuild a recorder from [`Recorder::to_jsonl`] output, replaying
    /// every event through the aggregation paths (phase histograms,
    /// worker observations, counters).
    pub fn from_jsonl(text: &str) -> Result<Recorder, String> {
        let rec = Recorder::enabled();
        for (no, line) in text.lines().enumerate() {
            let Some(ev) = TraceEvent::from_jsonl(line).map_err(|e| format!("line {}: {e}", no + 1))?
            else {
                continue;
            };
            match ev {
                TraceEvent::Span { phase, worker, iter, ts, dur, clock, used } => {
                    match (used, worker, iter) {
                        (Some(u), Some(w), Some(i)) => {
                            rec.record_worker_response(w, i, ts, dur, u, clock)
                        }
                        _ => rec.record_span(&phase, worker, iter, ts, dur, clock),
                    }
                }
                TraceEvent::Instant { name, worker, iter, ts, clock } => {
                    if let Some(mut g) = rec.lock() {
                        if name == MISSED_EVENT {
                            if let Some(w) = worker {
                                g.workers.entry(w).or_default().missed += 1;
                            }
                        }
                        g.events.push(TraceEvent::Instant { name, worker, iter, ts, clock });
                    }
                }
                TraceEvent::Counter { name, value } => rec.set(&name, value),
            }
        }
        Ok(rec)
    }

    /// Render all events as a Chrome trace-event JSON array (see
    /// [`trace::chrome_trace`]).
    pub fn to_chrome(&self) -> String {
        chrome_trace(&self.events())
    }
}

/// RAII span: created by [`Recorder::span`], records its duration when
/// dropped — including during panic unwind, so traces stay balanced
/// even when an iteration dies.
#[must_use = "a span records on drop; binding it to _ closes it immediately"]
pub struct SpanGuard {
    inner: Option<Arc<Mutex<Inner>>>,
    phase: &'static str,
    worker: Option<usize>,
    iter: Option<u64>,
    epoch: Instant,
    start: Instant,
}

impl SpanGuard {
    /// Label the span with a worker id.
    pub fn worker(mut self, w: usize) -> Self {
        self.worker = Some(w);
        self
    }

    /// Label the span with an iteration number.
    pub fn iter(mut self, i: u64) -> Self {
        self.iter = Some(i);
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(inner) = self.inner.take() else { return };
        let ts = self.start.duration_since(self.epoch).as_secs_f64();
        let dur = self.start.elapsed().as_secs_f64();
        let mut g = inner.lock().unwrap_or_else(|e| e.into_inner());
        g.phase_hists.entry(self.phase.to_string()).or_default().record(dur);
        g.events.push(TraceEvent::Span {
            phase: self.phase.to_string(),
            worker: self.worker,
            iter: self.iter,
            ts,
            dur,
            clock: Clock::Wall,
        });
    }
}

/// Aggregate latency statistics for one phase.
#[derive(Debug, Clone)]
pub struct PhaseStat {
    pub phase: String,
    pub count: u64,
    pub total: f64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl PhaseStat {
    fn from_hist(name: &str, h: &Histogram) -> PhaseStat {
        PhaseStat {
            phase: name.to_string(),
            count: h.count(),
            total: h.sum(),
            mean: h.mean(),
            p50: h.p50(),
            p90: h.p90(),
            p99: h.p99(),
            max: h.max(),
        }
    }
}

/// The run-level telemetry digest stored on
/// [`RunLog::telemetry`](crate::metrics::RunLog) and rendered by
/// `train` / `trace-report`.
#[derive(Debug, Clone, Default)]
pub struct TelemetrySummary {
    /// Per-phase stats, canonical phases first (see [`phase`]).
    pub phases: Vec<PhaseStat>,
    /// Counter name/value pairs, sorted by name.
    pub counters: Vec<(String, i64)>,
    /// Per-worker straggler attribution.
    pub stragglers: StragglerReport,
}

impl TelemetrySummary {
    /// Total seconds spent in a phase across the run.
    pub fn phase_total(&self, name: &str) -> Option<f64> {
        self.phases.iter().find(|p| p.phase == name).map(|p| p.total)
    }

    /// Sum of the mutually exclusive master phases
    /// ([`phase::MASTER_PHASES`]).
    pub fn master_phase_sum(&self) -> f64 {
        phase::MASTER_PHASES.iter().filter_map(|p| self.phase_total(p)).sum()
    }

    /// Total seconds inside `iteration` spans.
    pub fn iteration_total(&self) -> f64 {
        self.phase_total(phase::ITERATION).unwrap_or(0.0)
    }

    /// Render the phase-breakdown table. The `share` column is each
    /// phase's fraction of the `iteration` total (blank for overlapping
    /// worker-clock phases, which are excluded from the sum contract).
    pub fn render_phases(&self) -> String {
        let mut t = Table::new(
            "phase breakdown",
            &["phase", "count", "total_s", "mean_s", "p50_s", "p99_s", "max_s", "share"],
        );
        let iter_total = self.iteration_total();
        for p in &self.phases {
            let share = if phase::MASTER_PHASES.contains(&p.phase.as_str()) && iter_total > 0.0
            {
                format!("{:.1}%", 100.0 * p.total / iter_total)
            } else {
                String::new()
            };
            t.row(&[
                p.phase.clone(),
                p.count.to_string(),
                format!("{:.4}", p.total),
                format!("{:.6}", p.mean),
                format!("{:.6}", p.p50),
                format!("{:.6}", p.p99),
                format!("{:.6}", p.max),
                share,
            ]);
        }
        t.render()
    }

    /// Render the full digest: phases, stragglers, counters.
    pub fn render(&self) -> String {
        let mut out = self.render_phases();
        out.push('\n');
        out.push_str(&self.stragglers.render());
        if !self.counters.is_empty() {
            out.push('\n');
            for (name, value) in &self.counters {
                out.push_str(&format!("counter {name} = {value}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_a_noop() {
        let rec = Recorder::disabled();
        assert!(!rec.is_enabled());
        {
            let _g = rec.span(phase::DECODE).iter(7).worker(1);
        }
        rec.add("c", 3);
        rec.observe("h", 1.0);
        rec.record_worker_response(0, 0, 0.0, 1.0, true, Clock::Virtual);
        rec.worker_missed(1, 0);
        rec.instant("fault:crash", Some(1), Some(0));
        assert!(rec.events().is_empty());
        assert!(rec.counters().is_empty());
        assert!(rec.phase_stats().is_empty());
        assert!(rec.to_jsonl().is_empty());
        let s = rec.summary();
        assert!(s.phases.is_empty() && s.counters.is_empty() && s.stragglers.workers.is_empty());
    }

    #[test]
    fn clones_share_storage_and_spans_nest() {
        let rec = Recorder::enabled();
        let clone = rec.clone();
        {
            let _outer = rec.span(phase::ITERATION).iter(0);
            {
                let _inner = clone.span(phase::DECODE).iter(0);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        let evs = rec.events();
        assert_eq!(evs.len(), 2, "both guards recorded into the shared interior");
        // inner guard drops first, so it is recorded first
        let (inner_dur, outer_dur) = match (&evs[0], &evs[1]) {
            (
                TraceEvent::Span { phase: p0, dur: d0, .. },
                TraceEvent::Span { phase: p1, dur: d1, .. },
            ) => {
                assert_eq!(p0, phase::DECODE);
                assert_eq!(p1, phase::ITERATION);
                (*d0, *d1)
            }
            other => panic!("expected two spans, got {other:?}"),
        };
        assert!(inner_dur <= outer_dur, "nested span cannot outlast its parent");
        assert!(outer_dur >= 0.002, "slept 2ms inside the outer span");
    }

    #[test]
    fn counters_and_gauges() {
        let rec = Recorder::enabled();
        rec.add("frames", 2);
        rec.add("frames", 3);
        rec.set("gauge", 9);
        rec.set("gauge", 4);
        assert_eq!(rec.counters(), vec![("frames".into(), 5), ("gauge".into(), 4)]);
    }

    #[test]
    fn jsonl_round_trip_preserves_aggregates() {
        let rec = Recorder::enabled();
        rec.record_span(phase::DECODE, None, Some(0), 0.0, 0.5, Clock::Wall);
        rec.record_span(phase::DECODE, None, Some(1), 1.0, 0.7, Clock::Wall);
        rec.record_worker_response(3, 0, 0.0, 2.0, true, Clock::Virtual);
        rec.record_worker_response(3, 1, 2.0, 4.0, false, Clock::Virtual);
        rec.worker_missed(4, 1);
        rec.instant("fault:crash", Some(4), Some(1));
        rec.add("wire.tx_frames", 11);
        let back = Recorder::from_jsonl(&rec.to_jsonl()).unwrap();
        assert_eq!(back.events().len(), rec.events().len());
        assert_eq!(back.counters(), rec.counters());
        let (a, b) = (rec.summary(), back.summary());
        assert_eq!(a.phases.len(), b.phases.len());
        assert_eq!(a.phase_total(phase::DECODE), b.phase_total(phase::DECODE));
        let (wa, wb) = (&a.stragglers.workers, &b.stragglers.workers);
        assert_eq!(wa.len(), wb.len());
        assert_eq!((wa[0].used, wa[0].straggled), (wb[0].used, wb[0].straggled));
        assert_eq!(wa[1].missed, wb[1].missed);
        assert_eq!(wa[0].p90, wb[0].p90);
    }

    #[test]
    fn summary_orders_canonical_phases_first() {
        let rec = Recorder::enabled();
        rec.observe("zz_custom", 1.0);
        rec.record_span(phase::STEP, None, None, 0.0, 0.1, Clock::Wall);
        rec.record_span(phase::BROADCAST, None, None, 0.0, 0.2, Clock::Wall);
        let names: Vec<String> = rec.summary().phases.iter().map(|p| p.phase.clone()).collect();
        assert_eq!(names, vec!["broadcast", "step", "zz_custom"]);
        let s = rec.summary();
        assert!((s.master_phase_sum() - 0.3).abs() < 1e-12);
        assert_eq!(s.iteration_total(), 0.0);
        assert!(s.render().contains("phase breakdown"));
    }
}
