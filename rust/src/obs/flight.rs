//! Always-on flight recorder: a fixed-capacity ring of recent
//! span/fault events, dumped automatically when a run aborts.
//!
//! The `--trace` recorder is opt-in and unbounded; postmortems need the
//! opposite — bounded memory, always armed. [`FlightRecorder`] keeps
//! the last [`DEFAULT_CAPACITY`] events in a ring behind one short-held
//! mutex (push is O(1): a slot overwrite, no allocation beyond the
//! event itself), so it stays on even in untraced production runs.
//!
//! Producers: the trainer records one event per iteration (rung,
//! responders, sim time) and every [`FaultLog`](crate::chaos::FaultLog)
//! entry is mirrored here at its single chokepoint, so chaos faults land
//! in the ring whether or not telemetry is armed.
//!
//! Consumers: [`FlightDumpGuard`] dumps the ring to a JSONL file when
//! dropped while still armed — the trainer arms one around the training
//! loop and disarms it on clean completion, so a ladder-abort error
//! return or a panic unwind writes the black box automatically. The
//! `gradcode flight-dump` subcommand renders a dump file as a table.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::bench::Table;

/// Ring capacity of the process-global recorder: enough for the last
/// few hundred iterations of events without unbounded growth.
pub const DEFAULT_CAPACITY: usize = 1024;

/// Environment override for the automatic dump path.
pub const DUMP_ENV: &str = "GRADCODE_FLIGHT_DUMP";

/// Default automatic dump path (relative to the working directory).
pub const DEFAULT_DUMP_PATH: &str = "target/flight_dump.jsonl";

/// One ring entry.
#[derive(Debug, Clone, PartialEq)]
pub struct FlightEvent {
    /// Monotonic sequence number since process start (never wraps).
    pub seq: u64,
    /// Seconds since the recorder's epoch (process start for the
    /// global instance).
    pub ts: f64,
    /// Stable event kind: `"iteration"`, a fault label
    /// (`"crash"`, `"checksum_reject"`, …), `"health"`, ….
    pub kind: String,
    /// Worker involved, if any.
    pub worker: Option<usize>,
    /// Iteration, if any.
    pub iter: Option<u64>,
    /// Free-form detail.
    pub detail: String,
}

#[derive(Debug)]
struct Ring {
    buf: Vec<FlightEvent>,
    next_seq: u64,
    capacity: usize,
}

/// Fixed-capacity event ring. Clones share the interior. The process
/// holds one global instance ([`global`]); tests build local ones.
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    inner: Arc<Mutex<Ring>>,
    epoch: Instant,
}

impl FlightRecorder {
    pub fn with_capacity(capacity: usize) -> FlightRecorder {
        let capacity = capacity.max(1);
        FlightRecorder {
            inner: Arc::new(Mutex::new(Ring {
                buf: Vec::with_capacity(capacity),
                next_seq: 0,
                capacity,
            })),
            epoch: Instant::now(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Ring> {
        // Poison-tolerant: the flight recorder is most valuable while a
        // panic unwinds.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Record one event (always on; O(1), one short lock).
    pub fn record(&self, kind: &str, worker: Option<usize>, iter: Option<u64>, detail: &str) {
        let ts = self.epoch.elapsed().as_secs_f64();
        let mut g = self.lock();
        let seq = g.next_seq;
        g.next_seq += 1;
        let ev = FlightEvent {
            seq,
            ts,
            kind: kind.to_string(),
            worker,
            iter,
            detail: detail.to_string(),
        };
        if g.buf.len() < g.capacity {
            g.buf.push(ev);
        } else {
            let cap = g.capacity;
            g.buf[(seq % cap as u64) as usize] = ev;
        }
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.lock().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.lock().capacity
    }

    /// Total events ever recorded (including overwritten ones).
    pub fn total_recorded(&self) -> u64 {
        self.lock().next_seq
    }

    /// Drop all held events (sequence numbers keep counting).
    pub fn clear(&self) {
        self.lock().buf.clear();
    }

    /// The held events in sequence order (oldest first).
    pub fn snapshot(&self) -> Vec<FlightEvent> {
        let mut evs = self.lock().buf.clone();
        evs.sort_by_key(|e| e.seq);
        evs
    }

    /// Write the ring to `path` as JSONL (snapshot under lock, write
    /// outside). Returns the number of events dumped.
    pub fn dump_to(&self, path: &Path) -> std::io::Result<usize> {
        let events = self.snapshot();
        let text = render_jsonl(&events);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, text)?;
        Ok(events.len())
    }
}

/// The process-global flight recorder.
pub fn global() -> &'static FlightRecorder {
    static GLOBAL: OnceLock<FlightRecorder> = OnceLock::new();
    GLOBAL.get_or_init(|| FlightRecorder::with_capacity(DEFAULT_CAPACITY))
}

/// The automatic dump path: [`DUMP_ENV`] override or
/// [`DEFAULT_DUMP_PATH`].
pub fn dump_path() -> PathBuf {
    std::env::var(DUMP_ENV).map(PathBuf::from).unwrap_or_else(|_| PathBuf::from(DEFAULT_DUMP_PATH))
}

/// Dump-on-drop guard: while armed, dropping it (error return, panic
/// unwind, or plain scope exit) dumps the global ring to its path.
/// Call [`FlightDumpGuard::disarm`] on the clean-completion path.
#[must_use = "the guard dumps on drop; bind it for the scope of the run"]
#[derive(Debug)]
pub struct FlightDumpGuard {
    path: PathBuf,
    armed: bool,
}

impl FlightDumpGuard {
    /// Arm a guard that dumps to `path` on drop.
    pub fn arm(path: PathBuf) -> FlightDumpGuard {
        FlightDumpGuard { path, armed: true }
    }

    /// Arm a guard on the default/env-configured path.
    pub fn arm_default() -> FlightDumpGuard {
        FlightDumpGuard::arm(dump_path())
    }

    /// The run completed cleanly: no dump on drop.
    pub fn disarm(&mut self) {
        self.armed = false;
    }
}

impl Drop for FlightDumpGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        match global().dump_to(&self.path) {
            Ok(n) => eprintln!(
                "flight recorder: dumped {n} event(s) to {} (render with `gradcode flight-dump`)",
                self.path.display()
            ),
            Err(e) => eprintln!(
                "flight recorder: dump to {} failed: {e}",
                self.path.display()
            ),
        }
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Render events as the dump-file JSONL format.
pub fn render_jsonl(events: &[FlightEvent]) -> String {
    let mut out = String::new();
    for e in events {
        let worker = e.worker.map_or("null".to_string(), |w| w.to_string());
        let iter = e.iter.map_or("null".to_string(), |i| i.to_string());
        let _ = writeln!(
            out,
            "{{\"seq\":{},\"ts\":{:.9},\"kind\":\"{}\",\"worker\":{},\"iter\":{},\"detail\":\"{}\"}}",
            e.seq,
            e.ts,
            json_escape(&e.kind),
            worker,
            iter,
            json_escape(&e.detail),
        );
    }
    out
}

fn json_unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('t') => out.push('\t'),
            Some('u') => {
                let hex: String = chars.by_ref().take(4).collect();
                if let Some(c) = u32::from_str_radix(&hex, 16).ok().and_then(char::from_u32) {
                    out.push(c);
                }
            }
            Some(c) => out.push(c),
            None => {}
        }
    }
    out
}

/// The raw text of field `key` in a one-line JSON object: for string
/// values the unquoted-but-still-escaped content, otherwise the bare
/// token.
fn field_raw<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    if let Some(inner) = rest.strip_prefix('"') {
        let mut esc = false;
        for (i, c) in inner.char_indices() {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                return Some(&inner[..i]);
            }
        }
        None
    } else {
        let end = rest.find([',', '}'])?;
        Some(rest[..end].trim())
    }
}

/// Parse a dump file produced by [`render_jsonl`].
pub fn parse_dump(text: &str) -> Result<Vec<FlightEvent>, String> {
    let mut out = Vec::new();
    for (no, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let err = |what: &str| format!("line {}: {what}", no + 1);
        let seq = field_raw(line, "seq")
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| err("missing/invalid seq"))?;
        let ts = field_raw(line, "ts")
            .and_then(|s| s.parse::<f64>().ok())
            .ok_or_else(|| err("missing/invalid ts"))?;
        let kind = json_unescape(field_raw(line, "kind").ok_or_else(|| err("missing kind"))?);
        let detail =
            json_unescape(field_raw(line, "detail").ok_or_else(|| err("missing detail"))?);
        let worker = match field_raw(line, "worker") {
            None | Some("null") => None,
            Some(s) => Some(s.parse::<usize>().map_err(|_| err("invalid worker"))?),
        };
        let iter = match field_raw(line, "iter") {
            None | Some("null") => None,
            Some(s) => Some(s.parse::<u64>().map_err(|_| err("invalid iter"))?),
        };
        out.push(FlightEvent { seq, ts, kind, worker, iter, detail });
    }
    Ok(out)
}

/// Render events as the `flight-dump` table.
pub fn render_events(events: &[FlightEvent]) -> String {
    let mut t = Table::new(
        "flight recorder (oldest first)",
        &["seq", "ts_s", "kind", "worker", "iter", "detail"],
    );
    for e in events {
        t.row(&[
            e.seq.to_string(),
            format!("{:.6}", e.ts),
            e.kind.clone(),
            e.worker.map_or(String::new(), |w| w.to_string()),
            e.iter.map_or(String::new(), |i| i.to_string()),
            e.detail.clone(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_keeping_the_most_recent_events() {
        let fr = FlightRecorder::with_capacity(4);
        for i in 0..10u64 {
            fr.record("iteration", None, Some(i), &format!("event {i}"));
        }
        assert_eq!(fr.len(), 4);
        assert_eq!(fr.total_recorded(), 10);
        let evs = fr.snapshot();
        let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "only the newest capacity-many survive");
        assert_eq!(evs[3].detail, "event 9");
        // timestamps are monotone in sequence order
        for w in evs.windows(2) {
            assert!(w[0].ts <= w[1].ts);
        }
    }

    #[test]
    fn jsonl_round_trips_including_escapes() {
        let fr = FlightRecorder::with_capacity(8);
        fr.record("fault:\"quoted\"", Some(3), Some(7), "back\\slash\nnewline");
        fr.record("iteration", None, None, "");
        let text = render_jsonl(&fr.snapshot());
        let back = parse_dump(&text).expect("parses");
        assert_eq!(back, fr.snapshot());
        assert!(parse_dump("{\"seq\":bogus}").is_err());
    }

    #[test]
    fn dump_guard_writes_only_while_armed() {
        let dir = std::env::temp_dir().join(format!(
            "gradcode_flight_{}_{}",
            std::process::id(),
            // distinguish parallel test binaries without wall-clock
            line!()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        global().record("test_guard", None, None, "armed path");
        let armed_path = dir.join("armed.jsonl");
        {
            let _g = FlightDumpGuard::arm(armed_path.clone());
        }
        let dumped = std::fs::read_to_string(&armed_path).expect("armed guard dumped");
        assert!(dumped.contains("test_guard"));
        let disarmed_path = dir.join("disarmed.jsonl");
        {
            let mut g = FlightDumpGuard::arm(disarmed_path.clone());
            g.disarm();
        }
        assert!(!disarmed_path.exists(), "disarmed guard must not dump");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
