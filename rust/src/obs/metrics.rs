//! Live metrics: a std-only registry with a Prometheus text renderer
//! and a `TcpListener` scrape endpoint.
//!
//! The telemetry [`Recorder`](super::Recorder) is strictly post-hoc —
//! its JSONL/Chrome exports are read after the run. This module makes
//! the same measurements observable *while training runs*, the way the
//! paper's EC2 experiments were operated:
//!
//! - [`MetricsRegistry`] — counters, gauges, and latency histograms
//!   (reusing [`Histogram`]) keyed by name + label set, plus a clone of
//!   the run's `Recorder` so every existing instrumentation site feeds
//!   the scrape output without double bookkeeping.
//! - [`MetricsRegistry::render`] — the Prometheus text exposition
//!   format (`# HELP`/`# TYPE`, label escaping, summaries with
//!   `quantile` labels). The registry and recorder are snapshotted
//!   under their locks and the text is rendered outside, so a scrape
//!   never blocks the train loop.
//! - [`ScrapeServer`] — a one-thread accept loop behind `--metrics-addr`
//!   serving `GET /metrics` over plain HTTP/1.0.
//!
//! Naming: every series is prefixed `gradcode_` and recorder counter
//! names are sanitized (`wire.tx_frames` → `gradcode_wire_tx_frames`).
//! Per-worker fleet counters (`fleet.worker.<id>.<field>`) are folded
//! into labeled series: `gradcode_fleet_<field>{worker="<id>"}`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use super::{Histogram, PhaseStat, Recorder};

/// A series key: metric name plus sorted label pairs.
type Series = (String, Vec<(String, String)>);

#[derive(Debug, Default)]
struct RegistryInner {
    counters: BTreeMap<Series, i64>,
    gauges: BTreeMap<Series, f64>,
    hists: BTreeMap<Series, Histogram>,
}

/// Live metrics registry. Clones share the same interior; the train
/// loop writes through the existing [`Recorder`] sites, the registry
/// adds its own counters/gauges/histograms for metrics with labels.
#[derive(Debug, Clone)]
pub struct MetricsRegistry {
    inner: Arc<Mutex<RegistryInner>>,
    rec: Recorder,
}

impl MetricsRegistry {
    /// A registry fed by `rec`: everything the recorder collects
    /// (counters, phase histograms) appears in the scrape output.
    pub fn new(rec: &Recorder) -> MetricsRegistry {
        MetricsRegistry {
            inner: Arc::new(Mutex::new(RegistryInner::default())),
            rec: rec.clone(),
        }
    }

    /// The recorder feeding this registry.
    pub fn recorder(&self) -> &Recorder {
        &self.rec
    }

    fn lock(&self) -> MutexGuard<'_, RegistryInner> {
        // Tolerate poisoning: metrics must survive a panicking scrape
        // thread the same way the recorder survives unwinding spans.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn series(name: &str, labels: &[(&str, &str)]) -> Series {
        let mut ls: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        ls.sort();
        (name.to_string(), ls)
    }

    /// Add to a monotonic counter (created at zero).
    pub fn inc(&self, name: &str, labels: &[(&str, &str)], delta: i64) {
        let key = Self::series(name, labels);
        *self.lock().counters.entry(key).or_insert(0) += delta;
    }

    /// Set a gauge to an absolute value.
    pub fn set_gauge(&self, name: &str, labels: &[(&str, &str)], value: f64) {
        let key = Self::series(name, labels);
        self.lock().gauges.insert(key, value);
    }

    /// Record a sample into a labeled histogram (rendered as a
    /// Prometheus summary).
    pub fn observe(&self, name: &str, labels: &[(&str, &str)], value: f64) {
        let key = Self::series(name, labels);
        self.lock().hists.entry(key).or_default().record(value);
    }

    /// Snapshot of the registry's own series (the recorder snapshots
    /// itself inside its accessors).
    fn snapshot(&self) -> RegistryInner {
        let g = self.lock();
        RegistryInner {
            counters: g.counters.clone(),
            gauges: g.gauges.clone(),
            hists: g.hists.clone(),
        }
    }

    /// Render the full Prometheus text exposition: registry series plus
    /// everything the recorder has collected. Locks are held only while
    /// cloning the snapshots; the text assembles outside.
    pub fn render(&self) -> String {
        let own = self.snapshot();
        let rec_counters = self.rec.counters();
        let rec_phases = self.rec.phase_stats();
        render_text(&own, &rec_counters, &rec_phases)
    }

    /// Start the scrape endpoint on `addr` (e.g. `127.0.0.1:9100`;
    /// port 0 picks a free port — read it back from
    /// [`ScrapeServer::addr`]).
    pub fn serve(&self, addr: &str) -> anyhow::Result<ScrapeServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let hits = Arc::new(AtomicU64::new(0));
        let reg = self.clone();
        let stop2 = Arc::clone(&stop);
        let hits2 = Arc::clone(&hits);
        let handle = std::thread::Builder::new()
            .name("metrics-scrape".into())
            .spawn(move || accept_loop(listener, reg, stop2, hits2))?;
        Ok(ScrapeServer { addr: local, stop, hits, handle: Some(handle) })
    }
}

/// Scrape-endpoint handle: one accept-loop thread serving
/// [`MetricsRegistry::render`] snapshots. Dropping (or
/// [`ScrapeServer::shutdown`]) stops the thread.
#[derive(Debug)]
pub struct ScrapeServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    hits: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

impl ScrapeServer {
    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of scrapes served so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::SeqCst)
    }

    /// Stop the accept loop and join the thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept call; the loop re-checks the flag first.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ScrapeServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// The accept loop: no locks are ever held here — `reg.render()`
/// snapshots under its own scoped locks and returns an owned string
/// before any socket write happens.
fn accept_loop(
    listener: TcpListener,
    reg: MetricsRegistry,
    stop: Arc<AtomicBool>,
    hits: Arc<AtomicU64>,
) {
    loop {
        let conn = listener.accept();
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let Ok((stream, _)) = conn else { continue };
        serve_one(stream, &reg, &hits);
    }
}

/// Serve one scrape: drain the request head (best effort, bounded),
/// render, respond, close.
fn serve_one(mut stream: TcpStream, reg: &MetricsRegistry, hits: &AtomicU64) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let mut head = [0u8; 1024];
    let mut seen = 0usize;
    while seen < head.len() {
        match stream.read(&mut head[seen..]) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                seen += n;
                if head[..seen].windows(4).any(|w| w == b"\r\n\r\n") {
                    break;
                }
            }
        }
    }
    let body = reg.render();
    let header = format!(
        "HTTP/1.0 200 OK\r\ncontent-type: text/plain; version=0.0.4\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        body.len()
    );
    if stream.write_all(header.as_bytes()).is_ok() {
        let _ = stream.write_all(body.as_bytes());
        let _ = stream.flush();
    }
    hits.fetch_add(1, Ordering::SeqCst);
}

/// Escape a label value per the exposition format: backslash, double
/// quote, and newline.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Escape a `# HELP` text: backslash and newline only.
pub fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Sanitize an internal dotted name into a metric name:
/// `wire.tx_frames` → `gradcode_wire_tx_frames`.
pub fn metric_name(name: &str) -> String {
    let mut s = String::with_capacity(name.len() + 9);
    s.push_str("gradcode_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() || c == '_' {
            s.push(c);
        } else {
            s.push('_');
        }
    }
    s
}

fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

fn fmt_labels(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut s = String::from("{");
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{k}=\"{}\"", escape_label(v));
    }
    s.push('}');
    s
}

fn fmt_labels_with(labels: &[(String, String)], extra: (&str, &str)) -> String {
    let mut all = labels.to_vec();
    all.push((extra.0.to_string(), extra.1.to_string()));
    fmt_labels(&all)
}

/// One family of samples sharing a metric name and a `# TYPE`.
struct Family {
    help: String,
    typ: &'static str,
    /// `(suffix-plus-labels, value)` pairs appended verbatim to the
    /// family name (`_sum{...}`, `{quantile="0.5"}`, or empty).
    samples: Vec<(String, String)>,
}

/// Get-or-create the family for `name` (one `# TYPE` per name).
fn fam<'a>(
    families: &'a mut BTreeMap<String, Family>,
    name: &str,
    typ: &'static str,
    help: String,
) -> &'a mut Family {
    families
        .entry(name.to_string())
        .or_insert_with(|| Family { help, typ, samples: Vec::new() })
}

/// Assemble the exposition text from owned snapshots (no locks here).
fn render_text(
    own: &RegistryInner,
    rec_counters: &[(String, i64)],
    rec_phases: &[PhaseStat],
) -> String {
    // name -> family, BTreeMap for stable output order.
    let mut families: BTreeMap<String, Family> = BTreeMap::new();

    // Recorder counters: gauges (the recorder mixes monotonic adds and
    // absolute sets, so `gauge` is the honest type). Per-worker fleet
    // counters fold into labeled series.
    for (name, value) in rec_counters {
        if let Some((id, field)) = parse_fleet_counter(name) {
            let mname = metric_name(&format!("fleet.{field}"));
            let f = fam(
                &mut families,
                &mname,
                "gauge",
                format!("per-worker fleet metric `{field}` from the wire metrics block"),
            );
            f.samples.push((
                fmt_labels(&[("worker".to_string(), id.to_string())]),
                value.to_string(),
            ));
        } else {
            let mname = metric_name(name);
            // raw name here — escape_help runs once, at output time
            let f = fam(
                &mut families,
                &mname,
                "gauge",
                format!("recorder counter `{name}`"),
            );
            f.samples.push((String::new(), value.to_string()));
        }
    }

    // Recorder phase histograms: one summary family, labeled by phase.
    if !rec_phases.is_empty() {
        let f = fam(
            &mut families,
            "gradcode_phase_seconds",
            "summary",
            "per-phase latency (seconds) from the telemetry recorder".to_string(),
        );
        for p in rec_phases {
            let labels = vec![("phase".to_string(), p.phase.clone())];
            for (q, v) in [("0.5", p.p50), ("0.9", p.p90), ("0.99", p.p99)] {
                f.samples.push((fmt_labels_with(&labels, ("quantile", q)), fmt_f64(v)));
            }
            f.samples.push((format!("_sum{}", fmt_labels(&labels)), fmt_f64(p.total)));
            f.samples.push((format!("_count{}", fmt_labels(&labels)), p.count.to_string()));
        }
    }

    // Registry's own series.
    for ((name, labels), value) in &own.counters {
        let mname = metric_name(name);
        let f = fam(
            &mut families,
            &mname,
            "counter",
            format!("registry counter `{name}`"),
        );
        f.samples.push((fmt_labels(labels), value.to_string()));
    }
    for ((name, labels), value) in &own.gauges {
        let mname = metric_name(name);
        let f = fam(
            &mut families,
            &mname,
            "gauge",
            format!("registry gauge `{name}`"),
        );
        f.samples.push((fmt_labels(labels), fmt_f64(*value)));
    }
    for ((name, labels), h) in &own.hists {
        let mname = metric_name(name);
        let f = fam(
            &mut families,
            &mname,
            "summary",
            format!("registry histogram `{name}`"),
        );
        for (q, v) in [("0.5", h.p50()), ("0.9", h.p90()), ("0.99", h.p99())] {
            f.samples.push((fmt_labels_with(labels, ("quantile", q)), fmt_f64(v)));
        }
        f.samples.push((format!("_sum{}", fmt_labels(labels)), fmt_f64(h.sum())));
        f.samples.push((format!("_count{}", fmt_labels(labels)), h.count().to_string()));
    }

    let mut out = String::new();
    for (name, f) in &families {
        let _ = writeln!(out, "# HELP {name} {}", escape_help(&f.help));
        let _ = writeln!(out, "# TYPE {name} {}", f.typ);
        for (labels_or_suffix, value) in &f.samples {
            let _ = writeln!(out, "{name}{labels_or_suffix} {value}");
        }
    }
    out
}

/// `fleet.worker.<id>.<field>` → `(id, field)`.
fn parse_fleet_counter(name: &str) -> Option<(&str, &str)> {
    let rest = name.strip_prefix("fleet.worker.")?;
    let dot = rest.find('.')?;
    let (id, field) = (&rest[..dot], &rest[dot + 1..]);
    if id.is_empty() || field.is_empty() || !id.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    Some((id, field))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escaping_covers_backslash_quote_newline() {
        assert_eq!(escape_label(r#"a\b"c"#), r#"a\\b\"c"#);
        assert_eq!(escape_label("x\ny"), "x\\ny");
        assert_eq!(escape_help("h\\elp\nline"), "h\\\\elp\\nline");
        assert_eq!(metric_name("wire.tx_frames"), "gradcode_wire_tx_frames");
        assert_eq!(metric_name("weird-name:1"), "gradcode_weird_name_1");
    }

    #[test]
    fn render_groups_type_lines_once_per_family() {
        let rec = Recorder::enabled();
        rec.set("wire.tx_frames", 7);
        rec.set("fleet.worker.0.compute_us", 1200);
        rec.set("fleet.worker.1.compute_us", 3400);
        rec.observe("decode", 0.25);
        let m = MetricsRegistry::new(&rec);
        m.inc("scrapes", &[], 1);
        m.set_gauge("health_status", &[], 1.0);
        m.observe("iteration_seconds", &[("mode", "virtual")], 0.5);
        let text = m.render();
        assert_eq!(text.matches("# TYPE gradcode_fleet_compute_us gauge").count(), 1);
        assert!(text.contains("gradcode_fleet_compute_us{worker=\"0\"} 1200"));
        assert!(text.contains("gradcode_fleet_compute_us{worker=\"1\"} 3400"));
        assert!(text.contains("gradcode_wire_tx_frames 7"));
        assert!(text.contains("# TYPE gradcode_scrapes counter"));
        assert!(text.contains("gradcode_scrapes 1"));
        assert!(text.contains("gradcode_health_status 1"));
        assert!(text.contains("# TYPE gradcode_phase_seconds summary"));
        assert!(text.contains("gradcode_phase_seconds{phase=\"decode\",quantile=\"0.5\"}"));
        assert!(text.contains("gradcode_phase_seconds_count{phase=\"decode\"} 1"));
        assert!(text
            .contains("gradcode_iteration_seconds{mode=\"virtual\",quantile=\"0.9\"}"));
        // every # TYPE appears exactly once per family
        for fam in ["gradcode_phase_seconds", "gradcode_health_status"] {
            assert_eq!(text.matches(&format!("# TYPE {fam} ")).count(), 1, "{fam}");
        }
    }

    #[test]
    fn scrape_server_serves_and_shuts_down() {
        let rec = Recorder::enabled();
        rec.set("wire.tx_frames", 42);
        let m = MetricsRegistry::new(&rec);
        let srv = m.serve("127.0.0.1:0").expect("bind");
        let addr = srv.addr();
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut resp = String::new();
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK"), "{resp}");
        assert!(resp.contains("# TYPE"), "{resp}");
        assert!(resp.contains("gradcode_wire_tx_frames 42"), "{resp}");
        assert_eq!(srv.hits(), 1);
        srv.shutdown();
        // the port is released: a fresh connect is refused or accepted
        // by nobody — either way a second scrape can no longer succeed
        let dead = TcpStream::connect(addr)
            .and_then(|mut s| {
                s.set_read_timeout(Some(Duration::from_millis(200)))?;
                s.write_all(b"GET / HTTP/1.0\r\n\r\n")?;
                let mut buf = String::new();
                s.read_to_string(&mut buf)?;
                Ok(buf)
            })
            .unwrap_or_default();
        assert!(!dead.contains("200 OK"), "server still answering after shutdown");
    }
}
