//! Log-bucketed latency histogram.
//!
//! Values (seconds) land in geometrically spaced buckets — [`SUB`]
//! sub-buckets per octave, so any quantile estimate carries at most a
//! `2^(1/SUB) - 1 ≈ 9%` relative bucketing error — with exact `count`,
//! `sum`, `min`, and `max` kept on the side. Histograms merge
//! losslessly (bucket-wise addition), which is what lets per-worker
//! response distributions aggregate into fleet-level tail statistics
//! without storing every sample.

/// Smallest representable value (1 ns); everything below clamps here.
const MIN_VALUE: f64 = 1e-9;
/// Sub-buckets per octave (power of two). 8 ⇒ ≤ ~9% relative error.
const SUB: usize = 8;
/// Bucket count: covers `MIN_VALUE · 2^(NUM_BUCKETS/SUB)` ≈ 1e9 s.
const NUM_BUCKETS: usize = 480;

/// A fixed-memory log-bucketed histogram of non-negative `f64` samples.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: vec![0; NUM_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Build from an iterator of samples.
    pub fn from_values<I: IntoIterator<Item = f64>>(values: I) -> Self {
        let mut h = Histogram::new();
        for v in values {
            h.record(v);
        }
        h
    }

    /// Bucket index of a value (clamped at both ends).
    pub fn bucket_index(v: f64) -> usize {
        if !(v > MIN_VALUE) {
            return 0;
        }
        (((v / MIN_VALUE).log2() * SUB as f64).floor() as usize).min(NUM_BUCKETS - 1)
    }

    /// `[lower, upper)` bounds of bucket `i`.
    pub fn bucket_bounds(i: usize) -> (f64, f64) {
        let lower = MIN_VALUE * 2f64.powf(i as f64 / SUB as f64);
        let upper = MIN_VALUE * 2f64.powf((i + 1) as f64 / SUB as f64);
        (lower, upper)
    }

    /// Record one sample. NaN is ignored; negatives clamp to zero.
    pub fn record(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        let v = v.max(0.0);
        self.counts[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact minimum recorded sample (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum recorded sample (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Quantile estimate: the geometric midpoint of the bucket holding
    /// the `ceil(q·count)`-th sample, clamped to the exact `[min, max]`
    /// range. `q >= 1` returns the exact max; an empty histogram returns
    /// zero. Bucketing error is bounded by one sub-bucket (≈ 9%).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if q >= 1.0 {
            return self.max;
        }
        let target = ((q.max(0.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                let (lo, hi) = Self::bucket_bounds(i);
                return (lo * hi).sqrt().clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Merge another histogram into this one (bucket-wise; exact for
    /// count/sum/min/max, lossless for the bucket counts).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_contiguous_and_monotone() {
        for i in 0..NUM_BUCKETS - 1 {
            let (lo, hi) = Histogram::bucket_bounds(i);
            let (lo2, _) = Histogram::bucket_bounds(i + 1);
            assert!(lo < hi, "bucket {i} degenerate");
            assert!((hi - lo2).abs() / hi < 1e-12, "bucket {i} upper != next lower");
        }
        // the index function respects its own bounds
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(MIN_VALUE), 0);
        assert_eq!(Histogram::bucket_index(f64::MAX), NUM_BUCKETS - 1);
        let mut prev = 0usize;
        for e in -25..10 {
            let v = 10f64.powi(e);
            let i = Histogram::bucket_index(v);
            let (lo, hi) = Histogram::bucket_bounds(i);
            if i > 0 && i < NUM_BUCKETS - 1 {
                assert!(lo <= v * (1.0 + 1e-12) && v < hi, "{v} not in [{lo}, {hi})");
            }
            assert!(i >= prev, "index must be monotone in the value");
            prev = i;
        }
    }

    #[test]
    fn quantiles_are_monotone_and_bounded() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3); // 1 ms .. 1 s
        }
        let mut prev = 0.0;
        for q in [0.0, 0.1, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= prev, "quantile({q}) = {v} < {prev}");
            assert!(v >= h.min() && v <= h.max());
            prev = v;
        }
        // ≤ one sub-bucket of relative error on a uniform stream
        assert!((h.p50() / 0.5 - 1.0).abs() < 0.10, "p50 = {}", h.p50());
        assert!((h.p99() / 0.99 - 1.0).abs() < 0.10, "p99 = {}", h.p99());
        assert_eq!(h.quantile(1.0), 1.0, "q = 1 is the exact max");
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.5005).abs() < 1e-9);
    }

    #[test]
    fn merge_matches_combined_stream() {
        let mut a = Histogram::from_values((1..=500).map(|i| i as f64 * 1e-3));
        let b = Histogram::from_values((501..=1000).map(|i| i as f64 * 1e-3));
        let combined = Histogram::from_values((1..=1000).map(|i| i as f64 * 1e-3));
        a.merge(&b);
        assert_eq!(a.count(), combined.count());
        assert_eq!(a.min(), combined.min());
        assert_eq!(a.max(), combined.max());
        assert!((a.sum() - combined.sum()).abs() < 1e-9);
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), combined.quantile(q), "merge must be lossless");
        }
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        let mut h = Histogram::new();
        h.record(f64::NAN); // ignored
        assert!(h.is_empty());
        h.record(-1.0); // clamps to zero
        assert_eq!(h.count(), 1);
        assert_eq!(h.max(), 0.0);
    }
}
