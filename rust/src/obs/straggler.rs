//! Per-worker straggler attribution.
//!
//! The gather loop feeds every worker response into a per-worker
//! [`Histogram`] along with whether the response landed inside the
//! deciding quorum prefix. Responses outside that prefix ("straggles")
//! and missing responses are what the wait rule actually paid for, so
//! the report ranks workers by `straggled + missed`, breaking ties on
//! the p90 response latency. The report also carries the §VI-model
//! prediction for the configured wait rule so realized-vs-model
//! deviation is a first-class output.

use super::hist::Histogram;
use crate::bench::Table;

/// Aggregated response-time distribution and outcome counts for one
/// worker.
#[derive(Debug, Clone, Default)]
pub struct WorkerObs {
    /// Response latencies (virtual seconds in simulated mode, wall
    /// seconds in real-time/TCP mode).
    pub latency: Histogram,
    /// Responses inside the deciding quorum prefix.
    pub used: u64,
    /// Responses that arrived but were not needed for the quorum.
    pub straggled: u64,
    /// Iterations with no usable response (crashed, silent, rejected).
    pub missed: u64,
}

/// One worker's row in the [`StragglerReport`].
#[derive(Debug, Clone)]
pub struct WorkerStat {
    pub worker: usize,
    pub responses: u64,
    pub used: u64,
    pub straggled: u64,
    pub missed: u64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl WorkerStat {
    pub fn from_obs(worker: usize, obs: &WorkerObs) -> Self {
        WorkerStat {
            worker,
            responses: obs.latency.count(),
            used: obs.used,
            straggled: obs.straggled,
            missed: obs.missed,
            mean: obs.latency.mean(),
            p50: obs.latency.p50(),
            p90: obs.latency.p90(),
            p99: obs.latency.p99(),
            max: obs.latency.max(),
        }
    }

    /// Primary ranking key: iterations where this worker did not
    /// contribute to the deciding quorum.
    pub fn straggle_count(&self) -> u64 {
        self.straggled + self.missed
    }
}

/// Fleet-level straggler summary: per-worker tail latencies and
/// straggle counts, plus the realized-vs-§VI-model deviation for the
/// run's wait rule.
#[derive(Debug, Clone, Default)]
pub struct StragglerReport {
    /// One row per observed worker, in worker order.
    pub workers: Vec<WorkerStat>,
    /// §VI-model expected per-iteration wait time for this fleet and
    /// wait rule (None when the run had no delay model).
    pub model_expected: Option<f64>,
    /// Realized mean per-iteration sim time.
    pub realized_mean: f64,
    /// `(realized - model) / model`; None without a model.
    pub deviation: Option<f64>,
}

impl StragglerReport {
    /// Attach the model prediction and realized mean, deriving the
    /// relative deviation.
    pub fn set_model(&mut self, model_expected: Option<f64>, realized_mean: f64) {
        self.realized_mean = realized_mean;
        self.model_expected = model_expected;
        self.deviation = model_expected
            .filter(|m| *m > 0.0)
            .map(|m| (realized_mean - m) / m);
    }

    /// Workers ranked worst-first: by straggle count, then p90 latency,
    /// then worker id. The id tiebreak makes the order total — without
    /// it, workers tied on both keys (common in symmetric fleets) kept
    /// whatever order the sort left them in, and reports were not
    /// reproducible across runs.
    pub fn ranked(&self) -> Vec<&WorkerStat> {
        let mut rows: Vec<&WorkerStat> = self.workers.iter().collect();
        rows.sort_by(|a, b| {
            b.straggle_count()
                .cmp(&a.straggle_count())
                .then(b.p90.partial_cmp(&a.p90).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.worker.cmp(&b.worker))
        });
        rows
    }

    /// Ids of the `k` worst stragglers.
    pub fn top_stragglers(&self, k: usize) -> Vec<usize> {
        self.ranked().into_iter().take(k).map(|w| w.worker).collect()
    }

    /// Render the per-worker table plus the model-deviation line.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "straggler report (ranked worst-first)",
            &["worker", "responses", "used", "straggled", "missed", "p50", "p90", "p99", "max"],
        );
        for w in self.ranked() {
            t.row(&[
                w.worker.to_string(),
                w.responses.to_string(),
                w.used.to_string(),
                w.straggled.to_string(),
                w.missed.to_string(),
                format!("{:.4}", w.p50),
                format!("{:.4}", w.p90),
                format!("{:.4}", w.p99),
                format!("{:.4}", w.max),
            ]);
        }
        let mut out = t.render();
        match (self.model_expected, self.deviation) {
            (Some(m), Some(d)) => out.push_str(&format!(
                "realized mean iter time {:.4}s vs \u{a7}VI model {:.4}s ({:+.1}% deviation)\n",
                self.realized_mean,
                m,
                d * 100.0
            )),
            _ => out.push_str(&format!(
                "realized mean iter time {:.4}s (no delay model configured)\n",
                self.realized_mean
            )),
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(worker: usize, used: u64, straggled: u64, missed: u64, p90: f64) -> WorkerStat {
        WorkerStat {
            worker,
            responses: used + straggled,
            used,
            straggled,
            missed,
            mean: p90 * 0.8,
            p50: p90 * 0.7,
            p90,
            p99: p90 * 1.1,
            max: p90 * 1.2,
        }
    }

    #[test]
    fn ranking_prefers_straggle_count_then_tail_latency() {
        let mut r = StragglerReport::default();
        r.workers = vec![
            stat(0, 10, 0, 0, 1.0),
            stat(1, 2, 8, 0, 2.0),
            stat(2, 2, 5, 3, 1.5), // same straggle count as 1, slower tail? no: 8 each
            stat(3, 10, 0, 0, 9.0),
        ];
        let ranked = r.top_stragglers(4);
        // 1 and 2 both have 8 straggles; 1 has the higher p90 tail
        assert_eq!(&ranked[..2], &[1, 2]);
        // among the clean workers, the slow tail ranks ahead
        assert_eq!(&ranked[2..], &[3, 0]);
    }

    #[test]
    fn deviation_requires_a_model() {
        let mut r = StragglerReport::default();
        r.set_model(None, 2.0);
        assert!(r.deviation.is_none());
        assert!(r.render().contains("no delay model"));
        r.set_model(Some(1.6), 2.0);
        let d = r.deviation.unwrap();
        assert!((d - 0.25).abs() < 1e-12, "(2.0-1.6)/1.6 = 0.25, got {d}");
        assert!(r.render().contains("+25.0%"));
    }
}
