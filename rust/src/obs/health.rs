//! Straggler-regime health watchdog: realized iteration time vs the
//! declared-profile §VI model, per window.
//!
//! The (d, s, m) code the run was planned with is only optimal for the
//! fleet profile it was planned *against*
//! ([`simulator::expected_wait_time`](crate::simulator::expected_wait_time)
//! under the declared [`SpeedProfile`](crate::coordinator::SpeedProfile)
//! and wait rule). If the realized straggler regime drifts — a uniform
//! fleet turned bimodal, a slow group slowed further — the declared
//! model's prediction stops matching the realized per-iteration wait
//! times, and the operator should re-plan.
//!
//! [`HealthWatchdog`] consumes one realized iteration time per step and
//! every `window` iterations compares the window mean against the model
//! expectation. Deviation beyond `threshold` flips the
//! [`HEALTH_GAUGE`] gauge to degraded and emits a warning (surfaced via
//! `RunLog::health_warnings` and the live metrics endpoint).

use crate::obs::Recorder;

/// Gauge name exported through the recorder/metrics registry:
/// `1` healthy, `0` degraded, `-1` before the first full window.
pub const HEALTH_GAUGE: &str = "health_status";

/// Watchdog knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthConfig {
    /// Iterations per comparison window.
    pub window: usize,
    /// Relative deviation `|realized/expected - 1|` tolerated before a
    /// window is flagged. The §VI model is a mean-field prediction, so
    /// the default leaves generous room for sampling noise.
    pub threshold: f64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        HealthConfig { window: 10, threshold: 0.5 }
    }
}

/// Watchdog verdict after the most recent complete window.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthStatus {
    /// No complete window yet.
    Unknown,
    /// Last window within threshold of the declared-profile model.
    Healthy,
    /// Last window deviated beyond threshold: the declared profile no
    /// longer fits the realized straggler regime.
    Degraded,
}

impl HealthStatus {
    /// Gauge encoding (see [`HEALTH_GAUGE`]).
    pub fn gauge(self) -> i64 {
        match self {
            HealthStatus::Unknown => -1,
            HealthStatus::Healthy => 1,
            HealthStatus::Degraded => 0,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            HealthStatus::Unknown => "unknown",
            HealthStatus::Healthy => "healthy",
            HealthStatus::Degraded => "degraded",
        }
    }
}

/// Per-window straggler-regime estimator (see the module doc).
#[derive(Debug, Clone)]
pub struct HealthWatchdog {
    /// Expected per-iteration wait time under the declared profile.
    expected: f64,
    cfg: HealthConfig,
    window: Vec<f64>,
    status: HealthStatus,
    warnings: Vec<String>,
}

impl HealthWatchdog {
    /// `expected` is the §VI-model per-iteration wait time computed for
    /// the *declared* fleet profile and the run's wait rule.
    pub fn new(expected: f64, cfg: HealthConfig) -> HealthWatchdog {
        HealthWatchdog {
            expected,
            cfg,
            window: Vec::with_capacity(cfg.window.max(1)),
            status: HealthStatus::Unknown,
            warnings: Vec::new(),
        }
    }

    /// Feed one realized iteration time (same clock/units as the model:
    /// simulated seconds under a delay model). Returns a warning string
    /// when the window that just completed deviates beyond threshold.
    pub fn observe(&mut self, iter: u64, realized: f64) -> Option<String> {
        self.window.push(realized);
        if self.window.len() < self.cfg.window.max(1) {
            return None;
        }
        let mean = self.window.iter().sum::<f64>() / self.window.len() as f64;
        self.window.clear();
        let deviation =
            if self.expected > 0.0 { (mean - self.expected) / self.expected } else { 0.0 };
        if deviation.abs() > self.cfg.threshold {
            self.status = HealthStatus::Degraded;
            let warning = format!(
                "health: window ending at iter {iter}: realized mean iteration time \
                 {mean:.4}s deviates {:+.1}% from the declared-profile model \
                 ({:.4}s) — the fleet's straggler regime drifted; re-plan (d, s, m)",
                deviation * 100.0,
                self.expected
            );
            self.warnings.push(warning.clone());
            Some(warning)
        } else {
            self.status = HealthStatus::Healthy;
            None
        }
    }

    /// Verdict after the most recent complete window.
    pub fn status(&self) -> HealthStatus {
        self.status
    }

    /// Model expectation this watchdog compares against.
    pub fn expected(&self) -> f64 {
        self.expected
    }

    /// All warnings raised so far, in order.
    pub fn warnings(&self) -> &[String] {
        &self.warnings
    }

    /// Export the current verdict as the [`HEALTH_GAUGE`] gauge.
    pub fn export(&self, rec: &Recorder) {
        rec.set(HEALTH_GAUGE, self.status.gauge());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_only_beyond_threshold_and_only_on_full_windows() {
        let mut w = HealthWatchdog::new(1.0, HealthConfig { window: 4, threshold: 0.5 });
        assert_eq!(w.status(), HealthStatus::Unknown);
        for i in 0..3 {
            assert!(w.observe(i, 10.0).is_none(), "window not complete yet");
            assert_eq!(w.status(), HealthStatus::Unknown);
        }
        let warning = w.observe(3, 10.0).expect("10x the model must fire");
        assert!(warning.contains("+900.0%"), "{warning}");
        assert_eq!(w.status(), HealthStatus::Degraded);
        assert_eq!(w.warnings().len(), 1);
        // a healthy window flips the status back
        for i in 4..7 {
            assert!(w.observe(i, 1.1).is_none());
        }
        assert!(w.observe(7, 1.1).is_none(), "10% off is within threshold");
        assert_eq!(w.status(), HealthStatus::Healthy);
        assert_eq!(w.warnings().len(), 1, "healthy windows add no warnings");
    }

    #[test]
    fn too_fast_also_fires_and_gauge_encodes_status() {
        let mut w = HealthWatchdog::new(10.0, HealthConfig { window: 2, threshold: 0.5 });
        assert_eq!(HealthStatus::Unknown.gauge(), -1);
        w.observe(0, 1.0);
        let warning = w.observe(1, 1.0).expect("10x faster than the model also fires");
        assert!(warning.contains("-90.0%"), "{warning}");
        assert_eq!(w.status().gauge(), 0);
        let rec = Recorder::enabled();
        w.export(&rec);
        assert_eq!(rec.counters(), vec![(HEALTH_GAUGE.to_string(), 0)]);
    }

    #[test]
    fn zero_expected_never_divides_by_zero() {
        let mut w = HealthWatchdog::new(0.0, HealthConfig { window: 1, threshold: 0.5 });
        assert!(w.observe(0, 5.0).is_none());
        assert_eq!(w.status(), HealthStatus::Healthy);
    }
}
