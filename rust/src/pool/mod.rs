//! Pool — std-only fork/join thread pool for the compute hot paths.
//!
//! The paper's figure of merit is wall-clock time per iteration, yet a
//! single-threaded reproduction is bounded by one core no matter how
//! good the coding scheme is. This module supplies the parallel
//! substrate used by the encode/compute/decode hot paths
//! ([`crate::coding`], [`crate::coordinator`], [`crate::model`],
//! [`crate::linalg`], [`crate::simulator`]) under the repo's offline
//! constraint: no crates.io, `std` only (consistent with the vendored
//! `anyhow`).
//!
//! # Design
//!
//! **Fixed workers, caller participates.** [`ThreadPool::new`]`(k)`
//! spawns `k - 1` worker threads; the submitting thread is the k-th
//! worker of every fork/join region, so `k = 1` degrades to a plain
//! serial loop with no queue traffic at all (the deterministic
//! single-thread fallback).
//!
//! **Work-stealing-lite.** There are no per-worker deques to steal
//! from. A fork/join region shares one atomic claim counter: every
//! participant (caller + helpers) grabs the next unclaimed index until
//! none remain. For the coarse, similarly-sized tasks in this codebase
//! (per-worker coded gradients, row chunks, Monte-Carlo blocks) this
//! self-balances exactly like stealing would, with two orders of
//! magnitude less machinery. See `rust/DESIGN.md` for the rationale.
//!
//! **Scoped borrows without `transmute`.** [`ThreadPool::map_indexed`]
//! lends stack-borrowing closures to the workers through a raw pointer
//! guarded by a *gate* (an `RwLock<bool>`): helpers take the read lock
//! and check the gate before dereferencing; after the completion latch
//! trips, the caller takes the write lock and disarms, which blocks
//! until every in-gate helper has exited. A stale queued job that runs
//! after the region ended sees the disarmed gate and returns without
//! touching the dead stack frame.
//!
//! **Panic capture.** Each task body runs under
//! [`std::panic::catch_unwind`]; a panicking task fails the
//! *submitting* `map_indexed` call (the first payload is re-thrown on
//! the caller's thread) and the pool remains usable for subsequent
//! submissions — no poisoning.
//!
//! **Determinism.** Results come back ordered by index regardless of
//! which thread computed them, and the chunked reductions built on top
//! ([`tree_combine`]) combine partials in a fixed binary-tree order, so
//! every consumer is bitwise identical for any thread count. Callers
//! must derive their chunk grids from data sizes only — never from
//! [`ThreadPool::threads`].
//!
//! **Nested regions flatten.** A task that itself calls `map_indexed`
//! runs the nested region inline on its own thread (a thread-local
//! flag marks pool workers), so total concurrency is exactly the pool
//! width and re-entrant submission cannot deadlock on the shared queue.
//!
//! # Configuration
//!
//! The process-wide pool ([`global`]) sizes itself from the
//! `GRADCODE_THREADS` environment variable (unset, empty, `0`, or
//! unparsable mean "auto" = [`std::thread::available_parallelism`]);
//! the CLI's `--threads` flag calls [`set_global_threads`] which takes
//! precedence over the environment.
//!
//! ```
//! use gradcode::pool::{tree_combine, ThreadPool};
//!
//! let pool = ThreadPool::new(4);
//! let squares = pool.map_indexed(8, |i| (i * i) as u64);
//! assert_eq!(squares, vec![0, 1, 4, 9, 16, 25, 36, 49]);
//! let total = tree_combine(squares, |a, b| a + b).unwrap();
//! assert_eq!(total, 140);
//! ```

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock};
use std::thread;

/// A queued unit of work handed to a helper thread.
type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// True while this thread is executing a pool task; nested
    /// fork/join regions run inline instead of re-entering the queue.
    static IN_POOL_TASK: Cell<bool> = Cell::new(false);
}

fn in_pool_task() -> bool {
    IN_POOL_TASK.with(|f| f.get())
}

/// RAII marker for "this thread is inside a pool task".
struct TaskGuard {
    prev: bool,
}

impl TaskGuard {
    fn enter() -> Self {
        let prev = IN_POOL_TASK.with(|f| f.replace(true));
        TaskGuard { prev }
    }
}

impl Drop for TaskGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_POOL_TASK.with(|f| f.set(prev));
    }
}

/// Lock helper: the pool must keep working even if a task panicked
/// while a lock was held elsewhere (same idiom as the obs recorder).
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Shared state between the pool handle and its worker threads.
struct Shared {
    queue: Mutex<VecDeque<Job>>,
    /// Signalled when a job is queued or shutdown begins.
    available: Condvar,
    shutdown: AtomicBool,
}

impl Shared {
    fn worker_loop(&self) {
        loop {
            let job = {
                let mut q = lock_ignore_poison(&self.queue);
                loop {
                    if let Some(job) = q.pop_front() {
                        break job;
                    }
                    if self.shutdown.load(Ordering::Acquire) {
                        return;
                    }
                    q = self
                        .available
                        .wait(q)
                        .unwrap_or_else(|e| e.into_inner());
                }
            };
            job();
        }
    }
}

/// Counts outstanding tasks of one fork/join region; the caller blocks
/// on it until every claimed index has finished.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    fn new(count: usize) -> Self {
        Latch { remaining: Mutex::new(count), done: Condvar::new() }
    }

    fn complete_one(&self) {
        let mut left = lock_ignore_poison(&self.remaining);
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = lock_ignore_poison(&self.remaining);
        while *left > 0 {
            left = self.done.wait(left).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Arms the raw runner pointer a fork/join region lends to helpers.
/// Helpers hold the read lock while executing; the caller disarms
/// through the write lock, which cannot be acquired until every
/// in-flight helper has left the region.
struct Gate {
    armed: RwLock<bool>,
}

/// Raw pointer to the region's stack-allocated runner closure. Sending
/// it to helper threads is sound because the [`Gate`] protocol
/// guarantees no dereference after the caller's frame dies.
struct SendPtr(*const (dyn Fn() + Sync));
unsafe impl Send for SendPtr {}

/// Raw base pointer for [`ThreadPool::for_each_chunk_mut`]; chunks are
/// disjoint by construction, so concurrent `&mut` reborrows are sound.
struct SendMutPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendMutPtr<T> {}
unsafe impl<T: Send> Sync for SendMutPtr<T> {}

/// Fixed-width fork/join thread pool (see the module docs).
pub struct ThreadPool {
    /// `None` when `threads == 1`: every call degrades to an inline
    /// serial loop and no worker threads exist.
    shared: Option<Arc<Shared>>,
    workers: Vec<thread::JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Build a pool of `threads` total workers (the caller counts as
    /// one, so `threads - 1` OS threads are spawned). `threads` is
    /// clamped to at least 1.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        if threads == 1 {
            return ThreadPool { shared: None, workers: Vec::new(), threads };
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let mut workers = Vec::with_capacity(threads - 1);
        for i in 0..threads - 1 {
            let sh = Arc::clone(&shared);
            match thread::Builder::new()
                .name(format!("gradcode-pool-{i}"))
                .spawn(move || sh.worker_loop())
            {
                Ok(handle) => workers.push(handle),
                // Degrade to however many helpers the OS gave us; the
                // submitting thread always participates, so a smaller
                // (even empty) pool stays correct, just slower.
                Err(_) => break,
            }
        }
        if workers.is_empty() {
            shared.shutdown.store(true, Ordering::SeqCst);
            return ThreadPool { shared: None, workers: Vec::new(), threads: 1 };
        }
        let threads = workers.len() + 1;
        ThreadPool { shared: Some(shared), workers, threads }
    }

    /// Total workers participating in a fork/join region (including
    /// the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), f(1), …, f(count - 1)` across the pool and return the
    /// results ordered by index. The closure may borrow from the
    /// caller's stack; it must be `Sync` because several threads call
    /// it concurrently (on distinct indices).
    ///
    /// Runs inline — a plain ordered loop — when the pool is
    /// single-threaded, `count <= 1`, or the calling thread is itself
    /// executing a pool task (nested region).
    ///
    /// If any task panics, the first payload (in index order) is
    /// re-thrown on the calling thread after the region has fully quiesced;
    /// the pool stays usable.
    pub fn map_indexed<R, F>(&self, count: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let shared = match &self.shared {
            Some(sh) if count > 1 && !in_pool_task() => sh,
            _ => return (0..count).map(f).collect(),
        };

        // One slot per index; tasks write their own slot, so slots are
        // never contended (the Mutex is for Sync, not for blocking).
        let slots: Vec<Mutex<Option<thread::Result<R>>>> =
            (0..count).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let latch = Latch::new(count);

        // The region's runner: claim indices until none remain. Every
        // participant — caller and helpers alike — executes this same
        // closure; results land in index-addressed slots, so assignment
        // order does not affect the output.
        let runner = |_thread_is_helper: ()| {
            let _guard = TaskGuard::enter();
            loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= count {
                    break;
                }
                let out = catch_unwind(AssertUnwindSafe(|| f(i)));
                *lock_ignore_poison(&slots[i]) = Some(out);
                latch.complete_one();
            }
        };
        let runner_obj = || runner(());
        let runner_ref: &(dyn Fn() + Sync) = &runner_obj;
        let gate = Arc::new(Gate { armed: RwLock::new(true) });

        // Lend the runner to at most (threads - 1) helpers; more would
        // never find an unclaimed index.
        let helpers = (self.threads - 1).min(count - 1);
        {
            // Erase the borrow's lifetime so the job closure is
            // 'static-queueable; the Gate protocol re-establishes the
            // "no use after the frame dies" guarantee dynamically.
            let raw = runner_ref as *const (dyn Fn() + Sync)
                as *const (dyn Fn() + Sync + 'static);
            let mut q = lock_ignore_poison(&shared.queue);
            for _ in 0..helpers {
                let gate = Arc::clone(&gate);
                let job_ptr = SendPtr(raw);
                q.push_back(Box::new(move || {
                    let armed = gate
                        .armed
                        .read()
                        .unwrap_or_else(|e| e.into_inner());
                    if *armed {
                        // SAFETY: the gate is armed, so the caller's
                        // frame (runner + slots + latch) is alive and
                        // stays alive until the write-lock disarm,
                        // which cannot proceed while we hold the read
                        // lock.
                        unsafe { (*job_ptr.0)() }
                    }
                }));
            }
            shared.available.notify_all();
        }

        // The caller is the region's first worker.
        runner_obj();
        latch.wait();

        // Disarm: blocks until every helper inside the gate has left,
        // making it safe for this frame (and `f`) to die. Helpers that
        // never ran their job will see `false` and return immediately.
        *gate.armed.write().unwrap_or_else(|e| e.into_inner()) = false;

        let mut results = Vec::with_capacity(count);
        let mut panic: Option<Box<dyn std::any::Any + Send>> = None;
        for slot in slots {
            let taken = lock_ignore_poison(&slot).take();
            // lint: allow(panic-in-lib) the latch is released only after every slot is written; an empty slot is a pool bug worth crashing on
            match taken.expect("latch guarantees every slot is filled") {
                Ok(r) => results.push(r),
                Err(e) => {
                    if panic.is_none() {
                        panic = Some(e);
                    }
                }
            }
        }
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
        results
    }

    /// Split `data` into consecutive chunks of at most `chunk` elements
    /// and run `f(chunk_index, chunk_slice)` for each, in parallel.
    /// The chunk grid depends only on `data.len()` and `chunk`, never
    /// on the thread count — callers that write per-element outputs
    /// get bitwise-identical results for any pool width.
    pub fn for_each_chunk_mut<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let len = data.len();
        if len == 0 {
            return;
        }
        let chunk = chunk.max(1);
        let n_chunks = (len + chunk - 1) / chunk;
        let base = SendMutPtr(data.as_mut_ptr());
        self.map_indexed(n_chunks, |c| {
            let start = c * chunk;
            let end = (start + chunk).min(len);
            // SAFETY: chunks [start, end) are pairwise disjoint and in
            // bounds, so each task holds the only reference to its
            // elements for the duration of the region.
            let slice =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
            f(c, slice);
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            shared.shutdown.store(true, Ordering::Release);
            // Wake sleepers; the lock round-trip orders the store
            // against a worker that checked `shutdown` just before
            // blocking on the condvar.
            drop(lock_ignore_poison(&shared.queue));
            shared.available.notify_all();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Combine chunk partials in a fixed binary-tree order: pairs
/// `(0,1), (2,3), …` reduce into a half-sized level, repeated until one
/// value remains. The shape depends only on `parts.len()`, so
/// floating-point reductions are bitwise identical for any thread
/// count (unlike a "first finished folds first" scheme).
pub fn tree_combine<R>(parts: Vec<R>, mut reduce: impl FnMut(R, R) -> R) -> Option<R> {
    let mut level = parts;
    while level.len() > 1 {
        let mut up = Vec::with_capacity((level.len() + 1) / 2);
        let mut it = level.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => up.push(reduce(a, b)),
                None => up.push(a),
            }
        }
        level = up;
    }
    level.pop()
}

/// Parse a `GRADCODE_THREADS`-style value: unset, empty, `0`, or
/// unparsable all mean "auto" (`None`).
pub fn parse_threads(value: Option<&str>) -> Option<usize> {
    match value?.trim().parse::<usize>() {
        Ok(0) | Err(_) => None,
        Ok(k) => Some(k),
    }
}

/// Thread count the global pool would use if built right now:
/// `GRADCODE_THREADS` if set and nonzero, else
/// [`std::thread::available_parallelism`].
pub fn configured_threads() -> usize {
    parse_threads(std::env::var("GRADCODE_THREADS").ok().as_deref())
        .unwrap_or_else(|| {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

static GLOBAL: Mutex<Option<Arc<ThreadPool>>> = Mutex::new(None);

/// The process-wide pool used by the hot paths. Built lazily on first
/// use from [`configured_threads`]; replaceable via
/// [`set_global_threads`].
pub fn global() -> Arc<ThreadPool> {
    let mut g = lock_ignore_poison(&GLOBAL);
    Arc::clone(g.get_or_insert_with(|| Arc::new(ThreadPool::new(configured_threads()))))
}

/// Replace the global pool with one of exactly `threads` workers
/// (clamped to at least 1). The CLI's `--threads` flag lands here; it
/// overrides `GRADCODE_THREADS`. Regions already running on the old
/// pool finish normally — they hold their own `Arc`.
pub fn set_global_threads(threads: usize) {
    let pool = Arc::new(ThreadPool::new(threads.max(1)));
    *lock_ignore_poison(&GLOBAL) = Some(pool);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn map_indexed_orders_results_by_index() {
        for threads in [1, 2, 4, 7] {
            let pool = ThreadPool::new(threads);
            let out = pool.map_indexed(33, |i| i * 3);
            assert_eq!(out, (0..33).map(|i| i * 3).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_indexed_handles_trivial_counts() {
        let pool = ThreadPool::new(4);
        assert_eq!(pool.map_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map_indexed(1, |i| i + 10), vec![10]);
    }

    #[test]
    fn many_regions_reuse_the_same_pool() {
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let out = pool.map_indexed(8, move |i| i + round);
            assert_eq!(out, (0..8).map(|i| i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn panic_fails_the_call_without_poisoning_the_pool() {
        let pool = ThreadPool::new(3);
        let attempt = catch_unwind(AssertUnwindSafe(|| {
            pool.map_indexed(16, |i| {
                if i == 5 {
                    panic!("task 5 exploded");
                }
                i
            })
        }));
        assert!(attempt.is_err(), "panicking task must fail the join");
        // The pool must keep accepting and completing work.
        let out = pool.map_indexed(16, |i| i * 2);
        assert_eq!(out, (0..16).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn nested_regions_run_inline_without_deadlock() {
        let pool = ThreadPool::new(2);
        let out = pool.map_indexed(4, |i| {
            // Re-entrant submission from inside a task: must flatten,
            // not block on the already-busy queue.
            let inner = pool.map_indexed(3, move |j| i * 10 + j);
            inner.iter().sum::<usize>()
        });
        let expect: Vec<usize> =
            (0..4).map(|i| (0..3).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn for_each_chunk_mut_covers_every_element_once() {
        for threads in [1, 4] {
            let pool = ThreadPool::new(threads);
            let mut data = vec![0u32; 1003];
            let hits = AtomicUsize::new(0);
            pool.for_each_chunk_mut(&mut data, 64, |c, chunk| {
                hits.fetch_add(chunk.len(), Ordering::Relaxed);
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v = (c * 64 + k) as u32;
                }
            });
            assert_eq!(hits.load(Ordering::Relaxed), 1003);
            assert!(data.iter().enumerate().all(|(i, &v)| v == i as u32));
        }
    }

    #[test]
    fn tree_combine_is_a_fixed_shape() {
        // Shape check via strings: ((0+1)+(2+3))+(4) for 5 leaves.
        let parts: Vec<String> = (0..5).map(|i| i.to_string()).collect();
        let combined =
            tree_combine(parts, |a, b| format!("({a}+{b})")).unwrap();
        assert_eq!(combined, "(((0+1)+(2+3))+4)");
    }

    #[test]
    fn tree_combine_matches_serial_sum() {
        let parts: Vec<u64> = (0..17).collect();
        assert_eq!(tree_combine(parts, |a, b| a + b), Some(136));
        assert_eq!(tree_combine(Vec::<u64>::new(), |a, b| a + b), None);
    }

    #[test]
    fn parse_threads_semantics() {
        assert_eq!(parse_threads(None), None);
        assert_eq!(parse_threads(Some("")), None);
        assert_eq!(parse_threads(Some("0")), None);
        assert_eq!(parse_threads(Some("junk")), None);
        assert_eq!(parse_threads(Some("1")), Some(1));
        assert_eq!(parse_threads(Some(" 8 ")), Some(8));
    }

    #[test]
    fn single_thread_pool_has_no_workers() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.map_indexed(5, |i| i), vec![0, 1, 2, 3, 4]);
        let pool0 = ThreadPool::new(0);
        assert_eq!(pool0.threads(), 1, "0 clamps to 1");
    }
}
