//! Thread-pool determinism and robustness, end to end.
//!
//! The pool's contract is that the thread count is invisible in the
//! results: fixed chunk grids plus the fixed binary-tree combine order
//! make every hot path bitwise identical at any width. These tests pin
//! that contract at the highest level (a full coded training run), at
//! the Monte-Carlo layer, and at the pool API itself — including the
//! panic-capture path, under a watchdog so a deadlock fails instead of
//! hanging the suite.

use std::sync::Mutex;
use std::time::Duration;

use gradcode::coordinator::{train, SchemeSpec, TrainConfig};
use gradcode::data::{CategoricalConfig, SyntheticCategorical};
use gradcode::metrics::RunLog;
use gradcode::pool::{self, ThreadPool};
use gradcode::simulator::{DelayParams, VirtualCluster};
use gradcode::testkit::with_watchdog;

/// Tests in one binary run concurrently; everything that resizes the
/// global pool (or touches `GRADCODE_THREADS`) serializes on this.
static GLOBAL_POOL: Mutex<()> = Mutex::new(());

fn lock_global() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL_POOL.lock().unwrap_or_else(|e| e.into_inner())
}

/// One full poly-scheme virtual-cluster train at the current global
/// pool width.
fn train_once() -> (RunLog, Vec<f32>) {
    let gen = SyntheticCategorical::new(
        CategoricalConfig { columns: 10, cardinality: (16, 48), ..Default::default() },
        9,
    );
    let ds = gen.generate(360, 10);
    let cfg = TrainConfig::quick(6, SchemeSpec::Poly { s: 1, m: 2 }, 15);
    train(cfg, &ds, None).expect("train")
}

/// The deterministic projection of a run: everything except measured
/// wall-clock (`master_compute` / `worker_compute` vary freely).
fn deterministic_digest(log: &RunLog, beta: &[f32]) -> Vec<u64> {
    let mut d: Vec<u64> = beta.iter().map(|x| u64::from(x.to_bits())).collect();
    d.push(log.final_loss().unwrap_or(f64::NAN).to_bits());
    for r in &log.records {
        d.push(r.iter as u64);
        d.push(r.sim_time.to_bits());
        d.push(r.sim_clock.to_bits());
        d.push(r.floats_transmitted as u64);
        d.push(r.wire_bytes as u64);
        d.extend(r.responders.iter().map(|&w| w as u64));
    }
    d
}

#[test]
fn full_train_is_bitwise_identical_across_thread_counts() {
    let _g = lock_global();
    let digests: Vec<Vec<u64>> = [1usize, 4]
        .iter()
        .map(|&threads| {
            pool::set_global_threads(threads);
            let (log, beta) = train_once();
            deterministic_digest(&log, &beta)
        })
        .collect();
    assert_eq!(
        digests[0], digests[1],
        "gradients/losses/schedule changed between 1 and 4 threads"
    );
}

#[test]
fn monte_carlo_mean_is_bitwise_identical_across_thread_counts() {
    let _g = lock_global();
    let p = DelayParams::table_vi1();
    let means: Vec<u64> = [1usize, 4]
        .iter()
        .map(|&threads| {
            pool::set_global_threads(threads);
            // > MC_CHUNK trials so several blocks actually fan out.
            VirtualCluster::new(&p, 8, 4, 1, 3, 77).mean_iteration_time(5000).to_bits()
        })
        .collect();
    assert_eq!(means[0], means[1]);
}

#[test]
fn panicking_task_fails_its_join_without_poisoning_the_pool() {
    // Local pool: no global state involved, no lock needed.
    with_watchdog(Duration::from_secs(30), "pool-panic", || {
        let pool = ThreadPool::new(3);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map_indexed(16, |i| {
                if i == 7 {
                    panic!("task 7 exploded");
                }
                i * i
            })
        }));
        assert!(caught.is_err(), "the submitting call must observe the panic");
        // The pool keeps working after the failed region.
        let ok = pool.map_indexed(8, |i| i + 1);
        assert_eq!(ok, (1..=8).collect::<Vec<_>>());
    });
}

#[test]
fn nested_map_indexed_completes_under_watchdog() {
    with_watchdog(Duration::from_secs(30), "pool-nested", || {
        let pool = ThreadPool::new(4);
        let nested = pool.map_indexed(6, |i| {
            // Inner regions run inline inside pool tasks — this must not
            // deadlock even though the closure re-enters the same pool.
            pool.map_indexed(5, |j| i * 10 + j).iter().sum::<usize>()
        });
        let want: Vec<usize> = (0..6).map(|i| (0..5).map(|j| i * 10 + j).sum()).collect();
        assert_eq!(nested, want);
    });
}

#[test]
fn gradcode_threads_env_pins_the_pool_width() {
    let _g = lock_global();
    std::env::set_var("GRADCODE_THREADS", "1");
    assert_eq!(pool::configured_threads(), 1);
    std::env::set_var("GRADCODE_THREADS", "3");
    assert_eq!(pool::configured_threads(), 3);
    std::env::remove_var("GRADCODE_THREADS");
    assert!(pool::configured_threads() >= 1);
    // And the parse rules the env override uses:
    assert_eq!(pool::parse_threads(Some("2")), Some(2));
    assert_eq!(pool::parse_threads(Some("0")), None);
    assert_eq!(pool::parse_threads(Some("")), None);
    assert_eq!(pool::parse_threads(Some("lots")), None);
    assert_eq!(pool::parse_threads(None), None);
}
